"""End-to-end training driver: LookaheadKV modules on a ~100M llama-family
model, with model-generated responses, cosine schedule, checkpointing, and
periodic eval — the paper's Algorithm 1 as a real run.

    # full run (~100M model, a few hundred steps; hours on this 1-core CPU,
    # minutes on accelerators):
    PYTHONPATH=src python examples/train_e2e.py --arch tiny-llama --steps 300

    # quick verification (reduced model, ~2 min):
    PYTHONPATH=src python examples/train_e2e.py --smoke --steps 40
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io as ckpt
from repro.common.config import TrainConfig
from repro.configs import get_config, get_smoke_config
from repro.core import objective
from repro.core.lookahead import init_lookahead_params, lookahead_count
from repro.data import synthetic
from repro.models import transformer as tf
from repro.optim import adam


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny-llama")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (fast CPU verification)")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--n-in", type=int, default=0,
                    help="prompt length (default: 256 full / 64 smoke)")
    ap.add_argument("--n-out", type=int, default=0,
                    help="response length (default: 32 full / 12 smoke)")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--model-generated", action="store_true",
                    help="generate Y with the target model (paper default; "
                    "slower) instead of source responses (paper §D)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt", default="experiments/ckpt/lkv.npz")
    ap.add_argument("--eval-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    n_in = args.n_in or (64 if args.smoke else 256)
    n_out = args.n_out or (12 if args.smoke else 32)
    key = jax.random.PRNGKey(args.seed)
    params = tf.init_params(key, cfg)
    lkv = init_lookahead_params(jax.random.PRNGKey(args.seed + 1), cfg,
                                params["layers"])
    from repro.common.pytree import tree_size

    print(f"arch={cfg.name} params={tree_size(params):,} "
          f"trainable={lookahead_count(lkv):,} "
          f"({100*lookahead_count(lkv)/tree_size(params):.3f}%) "
          f"n_in={n_in} n_out={n_out}")

    tc = TrainConfig(steps=args.steps, lr=args.lr, batch_size=args.batch,
                     n_in=n_in, n_out=n_out, seed=args.seed)
    it = synthetic.MixtureIterator(
        cfg, args.batch, n_in, n_out, seed=args.seed,
        gen_params=params if args.model_generated else None,
        temperature=args.temperature)

    @jax.jit
    def step(lkv, opt, x, xy):
        def loss_fn(l):
            return objective.lkv_loss(params, cfg, l, x, xy, n_in)[0]

        loss, grads = jax.value_and_grad(loss_fn)(lkv)
        lkv, opt, m = adam.update(lkv, grads, opt, tc)
        return lkv, opt, loss, m["grad_norm"]

    @jax.jit
    def eval_recall(lkv, x, xy):
        s_gt = objective.gt_scores(params, cfg, xy, n_in)
        s_p = objective.lookahead_scores(params, cfg, lkv, x)
        k = max(n_in // 8, 4)
        _, tp = jax.lax.top_k(s_p, k)
        _, tg = jax.lax.top_k(s_gt, k)
        hits = (tp[..., :, None] == tg[..., None, :]).any(-1).sum(-1)
        return jnp.mean(hits / k)

    opt = adam.init(lkv)
    t0 = time.time()
    for i in range(args.steps):
        b = next(it)
        x = jnp.asarray(b.x)
        xy = jnp.concatenate([x, jnp.asarray(b.y)], axis=1)
        lkv, opt, loss, gn = step(lkv, opt, x, xy)
        if i % args.eval_every == 0 or i == args.steps - 1:
            r = float(eval_recall(lkv, x, xy))
            dt = time.time() - t0
            print(f"step {i:4d}  loss {float(loss):.4f}  gnorm "
                  f"{float(gn):.2f}  recall@{max(n_in//8,4)} {r:.3f}  "
                  f"({dt:.0f}s)")
    ckpt.save(args.ckpt, lkv, metadata={"arch": cfg.name,
                                        "steps": args.steps})
    print(f"saved lookahead modules -> {args.ckpt}")


if __name__ == "__main__":
    main()
