"""Quickstart: train LookaheadKV modules on a small model, evict, compare.

    PYTHONPATH=src python examples/quickstart.py

Walks the full loop in ~2 minutes on CPU: build a llama-family smoke model →
train lookahead tokens + selective LoRA against GT importance scores →
prefill with eviction under several policies → report kept-set quality.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import EvictionConfig, TrainConfig
from repro.configs import get_smoke_config
from repro.core import objective, policies
from repro.core.lookahead import init_lookahead_params, lookahead_count
from repro.data import synthetic
from repro.models import transformer as tf
from repro.optim import adam


def main():
    cfg = get_smoke_config("smollm-135m")
    key = jax.random.PRNGKey(0)
    params = tf.init_params(key, cfg)
    lkv = init_lookahead_params(jax.random.PRNGKey(1), cfg, params["layers"])
    from repro.common.pytree import tree_size

    print(f"model: {cfg.name}  params={tree_size(params):,}  "
          f"lookahead params={lookahead_count(lkv):,} "
          f"({100*lookahead_count(lkv)/tree_size(params):.2f}%)")

    # --- train the lookahead modules (paper Algorithm 1) ---
    tc = TrainConfig(steps=80, lr=1e-3, warmup_frac=0.05)
    it = synthetic.MixtureIterator(cfg, 4, 96, 16, seed=0)

    @jax.jit
    def step(lkv, opt, x, xy):
        def loss_fn(l):
            return objective.lkv_loss(params, cfg, l, x, xy, x.shape[1])[0]

        loss, grads = jax.value_and_grad(loss_fn)(lkv)
        lkv, opt, _ = adam.update(lkv, grads, opt, tc)
        return lkv, opt, loss

    opt = adam.init(lkv)
    for i in range(tc.steps):
        b = next(it)
        x = jnp.asarray(b.x)
        xy = jnp.concatenate([x, jnp.asarray(b.y)], axis=1)
        lkv, opt, loss = step(lkv, opt, x, xy)
        if i % 20 == 0 or i == tc.steps - 1:
            print(f"  step {i:3d}  KL loss {float(loss):.4f}")

    # --- evict with different policies and compare kept sets ---
    rng = np.random.default_rng(7)
    nb = synthetic.make_needle_batch(rng, 4, 96, cfg.vocab_size)
    x = jnp.asarray(nb.x)
    ev = EvictionConfig(budget=16, draft_len=8)
    print(f"\nneedle-survival at budget={ev.budget} (96-token prompts):")
    for m in ("random", "streaming_llm", "snapkv", "laq", "lookaheadkv"):
        res = policies.run_eviction(m, params, cfg, x, evict=ev,
                                    lkv_params=lkv)
        pos = np.asarray(res.cache["attn"]["pos"])
        mask = np.asarray(res.cache["attn"]["mask"])
        surv = []
        for bb in range(4):
            want = set(nb.answer_pos[bb].tolist())
            for l in range(cfg.num_layers):
                for h in range(cfg.attn.num_kv_heads):
                    kept = set(pos[l, bb, mask[l, bb, :, h], h].tolist())
                    surv.append(len(want & kept) / len(want))
        print(f"  {m:15s} {np.mean(surv):.3f}")
    print("\n(decode continues from any of these caches via tf.decode_step)")


if __name__ == "__main__":
    main()
