"""Tour of all ten assigned architectures: forward, (eviction-)prefill, and
two decode steps on reduced configs — the quickest way to see every family
(dense / MoE / SSM / hybrid / VLM / audio) run through the same API.

    PYTHONPATH=src python examples/multiarch_tour.py
"""

import time

import jax
import jax.numpy as jnp

from repro.common.config import EvictionConfig
from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.core.lookahead import init_lookahead_params
from repro.models import transformer as tf


def main():
    key = jax.random.PRNGKey(0)
    print(f"{'arch':25s} {'type':8s} {'full params':>14s} "
          f"{'technique':>10s} {'status'}")
    for aid in ARCH_IDS:
        full = get_config(aid)
        cfg = get_smoke_config(aid)
        t0 = time.time()
        params = tf.init_params(key, cfg)
        B, S = 2, 48
        x = (jax.random.normal(key, (B, S, cfg.d_model))
             if cfg.embeds_in else
             jax.random.randint(key, (B, S), 0, cfg.vocab_size))
        kw = {}
        if cfg.is_encoder_decoder:
            kw["encoder_embeds"] = jax.random.normal(
                key, (B, cfg.encoder.num_frames, cfg.d_model))
        if cfg.technique_applies and cfg.lookahead:
            lkv = init_lookahead_params(key, cfg, params["layers"])
            res = tf.prefill(params, cfg, x, lkv_params=lkv,
                             policy="lookaheadkv",
                             evict=EvictionConfig(budget=16),
                             extra_slots=4, **kw)
        else:
            res = tf.prefill(params, cfg, x, want_ssm_cache=True, **kw)
        tok = jnp.argmax(res.logits, -1)[:, None]
        lg, cache = tf.decode_step(params, cfg, tok, res.cache)
        lg, cache = tf.decode_step(
            params, cfg, jnp.argmax(lg, -1)[:, None], cache)
        ok = bool(jnp.isfinite(lg).all())
        tech = "applies" if full.technique_applies else "n/a (ssm)"
        print(f"{aid:25s} {full.arch_type:8s} {full.num_params():>14,} "
              f"{tech:>10s} ok={ok} ({time.time()-t0:.1f}s)")


if __name__ == "__main__":
    main()
