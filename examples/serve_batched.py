"""Serving with KV-cache eviction: lockstep batches and continuous batching.

    PYTHONPATH=src python examples/serve_batched.py [--policy lookaheadkv]

Three demos over one small model with (quickly trained) lookahead modules:

1. **Policy comparison** (the paper's inference path): a same-length batch
   served policy-by-policy through the lockstep ``ServingEngine``,
   reporting TTFT, tokens, and the cache-shrink ratio — the paper's memory
   headline (O(n_in) -> O(budget) cache per layer/head).
2. **Mixed-length traffic** through the ``ContinuousEngine``: prompts of
   any length stream through one compiled ``(1, chunk)`` prefill program,
   interleaved with a fixed set of decode slots — retiring requests free
   their slot for queued ones mid-stream, and every request reports its
   *own* TTFT and TPOT.  Post-eviction caches are shape-uniform across
   prompt lengths, which is exactly what makes slot reuse a constant-shape
   scatter.
3. **Prefix reuse**: every request opens with one shared system prompt;
   the radix-trie prompt cache (``serving/prefix_cache.py``) resumes each
   admission from the prefix's chunk-boundary ``(KV, ScoreState)``
   snapshot — served tokens are asserted identical, TTFT drops, and the
   engine reports hit-rate / shared tokens / resident bytes.
4. **Paged KV memory**: decode caches live in a shared ``KVBlockPool``
   (``serving/kv_pool.py``) instead of dense per-slot buffers.  At the
   *same* device byte budget, the dense engine affords a fixed handful of
   slots while the paged engine admits by free-block count — short
   prompts keep few post-eviction rows, so eviction-freed blocks turn
   into extra admitted requests (peak concurrency rises), tokens stay
   bit-identical, and retiring requests hand their blocks to the queue.
"""

import argparse
import os
import time
import warnings

import jax
import numpy as np

from repro.checkpoint import io as ckpt
from repro.common.config import EvictionConfig, TrainConfig
from repro.configs import get_smoke_config
from repro.core import objective
from repro.core.lookahead import init_lookahead_params
from repro.core.policies import MULTI_PASS
from repro.data import synthetic
from repro.models import transformer as tf
from repro.optim import adam
from repro.serving import (BucketedEngine, ContinuousEngine, KVBlockPool,
                           PrefixCache, Request, ServingEngine)


def get_or_train_lkv(cfg, params, path="experiments/ckpt/serve_lkv.npz"):
    lkv = init_lookahead_params(jax.random.PRNGKey(1), cfg, params["layers"])
    if os.path.exists(path):
        print(f"loading lookahead modules from {path}")
        return ckpt.load(path, like=lkv)
    print("training lookahead modules (60 steps)...")
    tc = TrainConfig(steps=60, lr=1e-3)
    it = synthetic.MixtureIterator(cfg, 4, 96, 16, seed=0)

    @jax.jit
    def step(lkv, opt, x, xy):
        def loss_fn(l):
            return objective.lkv_loss(params, cfg, l, x, xy, x.shape[1])[0]

        loss, grads = jax.value_and_grad(loss_fn)(lkv)
        lkv, opt, _ = adam.update(lkv, grads, opt, tc)
        return lkv, opt, loss

    import jax.numpy as jnp

    opt = adam.init(lkv)
    for _ in range(tc.steps):
        b = next(it)
        x = jnp.asarray(b.x)
        xy = jnp.concatenate([x, jnp.asarray(b.y)], axis=1)
        lkv, opt, _ = step(lkv, opt, x, xy)
    ckpt.save(path, lkv)
    return lkv


def compare_policies(cfg, params, lkv, args):
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, args.n_in).astype(np.int32)
               for _ in range(args.batch)]
    policies_to_run = ([args.policy] if args.policy else
                       ["snapkv", "streaming_llm", "lookaheadkv", "laq"])
    print(f"{'policy':15s} {'ttft_ms':>9s} {'toks/req':>9s} "
          f"{'cache_ratio':>12s}")
    for pol in policies_to_run:
        with warnings.catch_warnings():  # the lockstep baseline is deprecated
            warnings.simplefilter("ignore", DeprecationWarning)
            eng = ServingEngine(params, cfg, policy=pol,
                                evict=EvictionConfig(budget=args.budget,
                                                     draft_len=8),
                                lkv_params=lkv, max_new_tokens=args.max_new,
                                eos_id=-1)
        reqs = [Request(uid=i, prompt=p, max_new_tokens=args.max_new)
                for i, p in enumerate(prompts)]
        t0 = time.time()
        done = eng.serve(reqs)
        wall = time.time() - t0
        cb = eng.cache_bytes(args.n_in)
        print(f"{pol:15s} {done[0].ttft_s*1e3:9.1f} "
              f"{np.mean([len(r.out_tokens) for r in done]):9.1f} "
              f"{cb['ratio']:11.1f}x  (batch wall {wall:.2f}s)")


def serve_mixed_traffic(cfg, params, lkv, args):
    policy = args.policy or "lookaheadkv"
    print(f"\n-- continuous batching: mixed-length traffic ({policy}) --")
    rng = np.random.default_rng(1)
    lens = rng.choice([24, 40, 56, 72, 96], size=args.requests)
    arrivals = np.cumsum(rng.exponential(0.05, args.requests))
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(n)).astype(np.int32),
                    max_new_tokens=args.max_new, arrival_s=float(t))
            for i, (n, t) in enumerate(zip(lens, arrivals))]
    kw = dict(policy=policy, evict=EvictionConfig(budget=args.budget,
                                                  draft_len=8),
              lkv_params=lkv, num_slots=args.slots,
              max_new_tokens=args.max_new, eos_id=-1)
    if policy in MULTI_PASS or policy == "full":
        # draft-based baselines and 'full' cannot stream prefill chunks;
        # serve them through the deprecated bucketed engine
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            eng = BucketedEngine(params, cfg, buckets=(32, 64, 128), **kw)
    else:
        eng = ContinuousEngine(params, cfg, chunk=32, max_context=128, **kw)
    t0 = time.time()
    done = eng.run(reqs)
    wall = time.time() - t0
    print(f"{'uid':>4s} {'n_in':>5s} {'slot':>4s} {'ttft_ms':>8s} "
          f"{'tpot_ms':>8s} {'toks':>5s}")
    for r in sorted(done, key=lambda r: r.uid):
        print(f"{r.uid:4d} {len(r.prompt):5d} {r.slot:4d} "
              f"{r.ttft_s*1e3:8.1f} {r.tpot_s*1e3:8.2f} "
              f"{len(r.out_tokens):5d}")
    toks = sum(len(r.out_tokens) for r in done)
    cache = (eng.chunk_cache if isinstance(eng, ContinuousEngine)
             else eng.prefill_cache)
    print(f"{len(done)} requests / {toks} tokens in {wall:.2f}s "
          f"({toks/wall:.1f} tok/s); compile cache {cache.stats()}")


def serve_shared_prefixes(cfg, params, lkv, args):
    """Demo 3: prefix-aware KV reuse.  Every request opens with the same
    system prompt; with the radix-trie prompt cache the engine resumes
    each admission from the shared prefix's chunk-boundary snapshot —
    same tokens, a fraction of the prefill."""
    policy = args.policy or "lookaheadkv"
    if policy in MULTI_PASS or policy == "full":
        return  # prefix reuse rides the chunked streaming engine only
    print(f"\n-- prefix reuse: shared system prompt ({policy}) --")
    chunk = 32
    rng = np.random.default_rng(2)
    system = rng.integers(0, cfg.vocab_size, 2 * chunk).astype(np.int32)
    reqs = []
    for i in range(args.requests):
        user = rng.integers(0, cfg.vocab_size,
                            int(rng.integers(8, 40))).astype(np.int32)
        reqs.append(Request(uid=i, prompt=np.concatenate([system, user]),
                            max_new_tokens=args.max_new,
                            arrival_s=0.02 * i))
    kw = dict(policy=policy, evict=EvictionConfig(budget=args.budget),
              lkv_params=lkv, num_slots=args.slots, chunk=chunk,
              max_context=128, max_new_tokens=args.max_new, eos_id=-1)

    def replay(prefix_cache):
        eng = ContinuousEngine(params, cfg, prefix_cache=prefix_cache, **kw)

        def clones():
            return [r.clone() for r in reqs]

        eng.run(clones())  # warmup: compiles (and, cache-on, fills the trie)
        done = eng.run(clones())
        return eng, {r.uid: r.out_tokens for r in done}, np.mean(
            [r.ttft_s for r in done])

    _, base, ttft_off = replay(None)
    cache = PrefixCache(chunk=chunk, max_bytes=64 << 20)
    eng, got, ttft_on = replay(cache)
    assert got == base, "prefix reuse changed served tokens"
    # per-run counters come off the typed metrics registry (the legacy
    # ``eng.stats`` dict is a deprecated view of the same numbers)
    m = eng.metrics
    hits = int(m.value("serving_prefix_hits_total"))
    misses = int(m.value("serving_prefix_misses_total"))
    skipped = int(m.value("serving_prefix_tokens_skipped_total"))
    prompt_tokens = sum(len(r.prompt) for r in reqs)
    print(f"ttft mean: {ttft_off*1e3:.1f}ms uncached -> {ttft_on*1e3:.1f}ms "
          f"with prefix cache (tokens identical)")
    print(f"hit-rate {hits / max(hits + misses, 1):.2f}; {skipped} of "
          f"{prompt_tokens} prompt tokens served from the trie; "
          f"{cache.stats()['bytes'] / 1e6:.2f} MB resident")


def serve_paged_pool(cfg, params, lkv, args):
    """Demo 4: paged KV memory — admission rises as eviction frees blocks.

    Both engines get the *same* decode-KV byte budget: dense spends it on
    a fixed set of uniform slots, paged pools it into blocks.  Short
    prompts keep few rows after eviction, so the paged engine fits more
    live requests into the same bytes — watch ``peak concurrency`` rise
    while the served tokens stay bit-identical."""
    policy = args.policy or "lookaheadkv"
    if policy in MULTI_PASS or policy == "full":
        return  # paged decode rides the chunked streaming engine only
    print(f"\n-- paged KV memory: block pool vs dense slots ({policy}) --")
    budget, max_new, block, dense_slots = 48, 24, 4, 2
    evict = EvictionConfig(budget=budget)
    cap = tf.decode_cache_capacity(cfg, policy, evict, n_keys_max=1 << 30)
    # equal byte budget: the rows dense_slots dense slots hold, in blocks
    n_blocks = dense_slots * (cap + max_new + 1) // block
    rng = np.random.default_rng(3)
    lens = rng.choice([8, 12, 16, 24, 40], size=args.requests,
                      p=[0.35, 0.25, 0.2, 0.12, 0.08])
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(n)).astype(np.int32),
                    max_new_tokens=max_new, arrival_s=0.002 * i)
            for i, n in enumerate(lens)]
    kw = dict(policy=policy, evict=evict, lkv_params=lkv, chunk=32,
              max_context=64, max_new_tokens=max_new, eos_id=-1,
              decode_chunk=1)

    def replay(eng):
        eng.run([r.clone() for r in reqs])  # warmup: compile off the clock
        t0 = time.time()
        done = eng.run([r.clone() for r in reqs])
        wall = time.time() - t0
        return {r.uid: r.out_tokens for r in done}, wall, eng

    dense_tok, dense_wall, dense_eng = replay(
        ContinuousEngine(params, cfg, num_slots=dense_slots, **kw))
    pool = KVBlockPool(cfg, block_size=block, num_blocks=int(n_blocks))
    paged_tok, paged_wall, paged_eng = replay(
        ContinuousEngine(params, cfg, num_slots=3 * dense_slots,
                         kv_pool=pool, **kw))
    assert paged_tok == dense_tok, "paged serving changed tokens"
    # pool geometry straight from the pool; run counters off the registry
    s = pool.stats()
    mp = paged_eng.metrics
    print(f"equal KV budget: dense {dense_eng.kv_device_bytes() / 1e3:.0f}KB"
          f" ({dense_slots} slots) vs paged "
          f"{paged_eng.kv_device_bytes() / 1e3:.0f}KB "
          f"({s['blocks_total']} x {block}-row blocks)")
    print(f"peak concurrency: dense "
          f"{int(dense_eng.metrics.value('serving_max_concurrency'))} -> "
          f"paged {int(mp.value('serving_max_concurrency'))} "
          f"(tokens bit-identical; wall {dense_wall:.2f}s -> "
          f"{paged_wall:.2f}s)")
    print(f"pool high water {s['high_water_blocks']}/{s['blocks_total']} "
          f"blocks, {int(mp.value('serving_preemptions_total'))} "
          f"preemptions, {int(mp.value('serving_admission_blocked_total'))} "
          f"gated admissions")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="",
                    help="single policy; default compares several")
    ap.add_argument("--budget", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--n-in", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--requests", type=int, default=12,
                    help="mixed-traffic request count")
    ap.add_argument("--slots", type=int, default=4,
                    help="continuous-engine decode slots")
    args = ap.parse_args()

    cfg = get_smoke_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    lkv = get_or_train_lkv(cfg, params)
    compare_policies(cfg, params, lkv, args)
    serve_mixed_traffic(cfg, params, lkv, args)
    serve_shared_prefixes(cfg, params, lkv, args)
    serve_paged_pool(cfg, params, lkv, args)


if __name__ == "__main__":
    main()
