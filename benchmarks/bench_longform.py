"""Paper Fig. 5 (LongProc HTML→TSV proxy): long-form output generation.

The paper's hypothesis: LookaheadKV — trained to compress the attention
pattern of the *entire* future response — beats draft-based methods whose
observation window covers only a short draft, and the gap grows with output
length.

Proxy without datasets: teacher-forced long responses.  GT importance from
a LONG response (n_out up to 48) is the target; each method's kept set is
compared against the long-response GT-oracle kept set.  Draft methods see
only ``draft_len=8`` pseudo-tokens — structurally the paper's setup.
Also reports the Ada-KV adaptive head allocation on top of LookaheadKV
(beyond-paper composable axis).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from benchmarks.common import trained_model
from repro.common.config import EvictionConfig
from repro.core import policies
from repro.data import synthetic
from repro.models import transformer as tf

OUT_LENS = (12, 24, 48)
BUDGET = 16


def _kept_sets(cache):
    pos = np.asarray(cache["attn"]["pos"])
    mask = np.asarray(cache["attn"]["mask"])
    L, B, C, KV = pos.shape
    return {
        (l, b, h): set(pos[l, b, mask[l, b, :, h], h].tolist())
        for l in range(L) for b in range(B) for h in range(KV)
    }


def _overlap(a, g):
    return float(np.mean([len(a[k] & g[k]) / max(len(g[k]), 1) for k in g]))


def run(report):
    cfg, params, lkv, _ = trained_model()
    rng = np.random.default_rng(11)
    for n_out in OUT_LENS:
        it = synthetic.MixtureIterator(cfg, 4, 96, n_out, seed=100 + n_out)
        b = next(it)
        x = jnp.asarray(b.x)
        xy = jnp.concatenate([x, jnp.asarray(b.y)], axis=1)
        ev = EvictionConfig(budget=BUDGET, draft_len=8)
        gt = tf.prefill(params, cfg, xy, policy="gt_oracle",
                        gt_boundary=x.shape[1], evict=ev)
        gt_sets = _kept_sets(gt.cache)
        rows = {}
        for m in ("snapkv", "laq", "lookaheadkv"):
            res = policies.run_eviction(m, params, cfg, x, evict=ev,
                                        lkv_params=lkv)
            rows[m] = _overlap(_kept_sets(res.cache), gt_sets)
        # Ada-KV on top of lookaheadkv (beyond-paper)
        ev_ad = dataclasses.replace(ev, head_alloc="adaptive")
        res = policies.run_eviction("lookaheadkv", params, cfg, x,
                                    evict=ev_ad, lkv_params=lkv)
        rows["lookaheadkv+adakv"] = _overlap(_kept_sets(res.cache), gt_sets)
        for m, v in rows.items():
            note = ""
            if m.endswith("adakv") and cfg.attn.num_kv_heads == 1:
                note = " [kv=1: adaptive==uniform by construction]"
            report(f"longform/{m}/out{n_out}", None,
                   f"gt_overlap={v:.3f} (budget={BUDGET}, draft=8){note}")
