"""Paper Fig. 4 (bottom) / Fig. 6 (RULER proxy): fixed budget, growing
context.  Needle-survival per method as the prompt grows — the paper's claim
is that LookaheadKV trained at short context generalizes to longer ones."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import trained_model
from repro.common.config import EvictionConfig
from repro.core import policies
from repro.data import synthetic

CONTEXTS = (64, 128, 256)
BUDGET = 16
METHODS = ("random", "streaming_llm", "snapkv", "lookaheadkv")


def _survival(cache, answer_pos):
    pos = np.asarray(cache["attn"]["pos"])
    mask = np.asarray(cache["attn"]["mask"])
    L, B, C, KV = pos.shape
    out = []
    for b in range(B):
        want = set(answer_pos[b].tolist())
        for l in range(L):
            for h in range(KV):
                kept = set(pos[l, b, mask[l, b, :, h], h].tolist())
                out.append(len(want & kept) / len(want))
    return float(np.mean(out))


def run(report):
    # trained at N_IN=96 — evaluated beyond its training context (paper §5.4)
    cfg, params, lkv, _ = trained_model()
    ev = EvictionConfig(budget=BUDGET, draft_len=8)
    rng = np.random.default_rng(3)
    for ctx in CONTEXTS:
        nb = synthetic.make_needle_batch(rng, 4, ctx, cfg.vocab_size)
        x = jnp.asarray(nb.x)
        for m in METHODS:
            res = policies.run_eviction(m, params, cfg, x, evict=ev,
                                        lkv_params=lkv)
            s = _survival(res.cache, nb.answer_pos)
            report(f"context_scaling/{m}/ctx{ctx}", None,
                   f"needle_survival={s:.3f} (budget={BUDGET})")
