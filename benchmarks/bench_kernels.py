"""Kernel micro-benchmarks: wall-time of the jnp fallbacks on CPU (ordering/
regression tracking) + analytic VMEM working-set check of the Pallas tilings
(the quantity that must stay under ~16 MB on v5e) + the paged flash-decode
roofline budget (``paged_decode_verdict``, gated by ci_smoke)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_call
from repro.kernels import ops

# -- paged flash-decode roofline budget ------------------------------------
#
# (B, depth, block_size) points spanning the serving geometries; the block
# sizes are the kernel-parity sweep's {16, 64, 128}.  The achieved-bandwidth
# budget is *analytic* (HBM bytes the kernel's tiling must touch vs the
# bytes any exact decode must stream — valid on any host), while the
# kernel-vs-gather race is *measured*: on TPU the Pallas kernel itself, on
# CPU the streaming jnp fallback that implements the same block scan (the
# dispatch ops.paged_decode_attention actually takes there at these depths).
PAGED_POINTS = ((4, 2048, 16), (4, 2048, 64), (2, 4096, 128), (8, 1024, 64))
PAGED_KV, PAGED_GROUP, PAGED_HD = 2, 4, 64
#: the kernel's touched-bytes budget: at most 1/0.85 ≈ 1.18× the ideal
#: traffic, i.e. ≥ 85% of roofline bandwidth when HBM-bound at peak
ROOFLINE_FRAC = 0.85


def _paged_traffic_bytes(B, depth, bs, *, KV=PAGED_KV, H=PAGED_KV * PAGED_GROUP,
                         hd=PAGED_HD, itemsize=4):
    """HBM bytes one paged-decode call's tiling actually streams: whole K/V
    blocks (padding the depth up to the block grid), the int32 pos + bool
    mask metadata tiles, and the q/out rows."""
    nb = -(-depth // bs)
    kv = 2 * B * nb * bs * KV * hd * itemsize
    meta = B * nb * bs * KV * (4 + 1)  # pos int32 + mask bool
    io = 2 * B * H * hd * itemsize  # q in, out back
    return kv + meta + io


def _paged_ideal_bytes(B, depth, *, KV=PAGED_KV, H=PAGED_KV * PAGED_GROUP,
                       hd=PAGED_HD, itemsize=4):
    """The model-derived lower bound: any exact decode must stream every
    logical K and V row once, plus the q/out rows."""
    return 2 * B * depth * KV * hd * itemsize + 2 * B * H * hd * itemsize


def _paged_case(B, depth, bs, seed=0):
    rng = np.random.default_rng(seed)
    KV, hd = PAGED_KV, PAGED_HD
    H = PAGED_KV * PAGED_GROUP
    nb = -(-depth // bs)
    N = 1 + B * nb
    q = jnp.asarray(rng.standard_normal((B, H, hd)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((N, bs, KV, hd)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((N, bs, KV, hd)), jnp.float32)
    mp = jnp.asarray(rng.random((N, bs, KV)) < 0.9).at[0].set(False)
    pos = jnp.asarray(rng.integers(0, depth, (N, bs, KV)), jnp.int32)
    tbl = jnp.asarray(1 + np.arange(B * nb, dtype=np.int32).reshape(B, nb))
    return q, kp, vp, mp, pos, tbl


def paged_decode_rows():
    """One row per PAGED_POINTS entry: measured kernel-path and gather wall
    time plus the analytic roofline fraction.  Shared with
    ``bench_roofline`` (nightly sweep artifact)."""
    from repro.kernels import ref

    on_tpu = jax.default_backend() == "tpu"
    if on_tpu:
        from repro.kernels import paged_attention as pk

        kernel_fn = jax.jit(
            lambda q, k, v, m, t: pk.paged_decode_attention_pallas(
                q, k, v, m, t))
        path = "kernel"
    else:
        kernel_fn = jax.jit(
            lambda q, k, v, m, t: ops._paged_decode_streaming(q, k, v, m, t))
        path = "fallback"
    gather_fn = jax.jit(
        lambda q, k, v, m, t: ref.paged_decode_attention(q, k, v, m, t))

    rows = []
    for (B, depth, bs) in PAGED_POINTS:
        q, kp, vp, mp, _, tbl = _paged_case(B, depth, bs)
        us = time_call(kernel_fn, q, kp, vp, mp, tbl)
        gather_us = time_call(gather_fn, q, kp, vp, mp, tbl)
        touched = _paged_traffic_bytes(B, depth, bs)
        ideal = _paged_ideal_bytes(B, depth)
        rows.append({
            "B": B, "depth": depth, "block_size": bs, "path": path,
            "us": us, "gather_us": gather_us,
            "touched_bytes": touched, "ideal_bytes": ideal,
            "roofline_frac": ideal / touched,
            "achieved_gbps": touched / us * 1e-3,
        })
    return rows


def _vmem_bytes_flash(block_q, block_k, hd):
    # q tile + k tile + v tile + f32 accumulators
    return (block_q * hd * 2 + 2 * block_k * hd * 2
            + block_q * (hd + 2) * 4)


def _vmem_bytes_lookahead(n_obs, block_k, hd):
    return n_obs * hd * 2 + block_k * hd * 2 + 2 * n_obs * 4 + block_k * 4


def _vmem_bytes_ssd(chunk, bh, hd, ds):
    return (chunk * bh * (hd + 2) * 4 + 2 * chunk * ds * 4
            + bh * hd * ds * 4 + chunk * chunk * (bh + 1) * 4)


def _vmem_bytes_chunk_masses(C, block_k, hd):
    # fused score kernel: q tile + k/v tiles + f32 (m, l, acc) scratch +
    # the (block_k,) f32 mass output tile
    return (C * hd * 2 + 2 * block_k * hd * 2
            + C * (hd + 2) * 4 + block_k * 4)


def run(report):
    fits_all = True

    def vmem_row(name, vm):
        nonlocal fits_all
        fits_all &= vm < 16e6
        report(name, None, f"vmem_kb={vm/1024:.0f} fits_16MB={vm < 16e6}")

    for (bq, bk, hd) in ((128, 128, 128), (256, 512, 128), (128, 1024, 256)):
        vmem_row(f"kernels/flash_vmem/bq{bq}_bk{bk}_hd{hd}",
                 _vmem_bytes_flash(bq, bk, hd))
    for (no, bk, hd) in ((32, 512, 128), (32, 2048, 128), (128, 1024, 256)):
        vmem_row(f"kernels/lookahead_vmem/obs{no}_bk{bk}",
                 _vmem_bytes_lookahead(no, bk, hd))
    for (C, bk, hd) in ((128, 512, 128), (256, 512, 128), (256, 1024, 256)):
        vmem_row(f"kernels/chunk_masses_vmem/C{C}_bk{bk}_hd{hd}",
                 _vmem_bytes_chunk_masses(C, bk, hd))
    for (ck, bh, hd, ds) in ((128, 8, 64, 128), (128, 8, 64, 16)):
        vmem_row(f"kernels/ssd_vmem/chunk{ck}_bh{bh}_ds{ds}",
                 _vmem_bytes_ssd(ck, bh, hd, ds))
    # the CI smoke gate keys off this row: every tiling must fit v5e VMEM
    report("kernels/vmem_verdict", None, "pass" if fits_all else "fail")

    # CPU wall-time of the fallbacks (regression tracking)
    key = jax.random.PRNGKey(0)
    B, S, H, KV, hd = 1, 4096, 4, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    fa = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, causal=True))
    report("kernels/flash_fallback_4k", time_call(fa, q, k, v),
           "B1 S4096 H4 hd64 f32")
    qo = q[:, :32]
    ls = jax.jit(lambda qo, k: ops.lookahead_score(qo, k, S - 32))
    report("kernels/lookahead_fallback_4k", time_call(ls, qo, k),
           "n_obs=32 S4096")
    qd = q[:, 0, :, :]
    da = jax.jit(lambda qd, k, v: ops.decode_attention(qd, k, v))
    report("kernels/decode_fallback_4k", time_call(da, qd, k, v), "S4096")
    qc = q[:, :256]
    cm = jax.jit(lambda qc, k, v: ops.chunk_attention(
        qc, k, v, q_offset=jnp.asarray(S - 256, jnp.int32),
        score_masses=True, n_total=jnp.asarray(S, jnp.int32))[1])
    report("kernels/chunk_masses_fallback_4k", time_call(cm, qc, k, v),
           "fused-score streaming fallback C256 S4096")
    nh, ds = 8, 64
    x = jax.random.normal(ks[0], (B, 1024, nh, 32))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, 1024, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)))
    Bm = jax.random.normal(ks[0], (B, 1024, 1, ds))
    Cm = jax.random.normal(ks[1], (B, 1024, 1, ds))
    sc = jax.jit(lambda *a: ops.ssd_scan(*a, chunk=128))
    report("kernels/ssd_fallback_1k", time_call(sc, x, dt, A, Bm, Cm),
           "S1024 nh8 ds64")

    # paged flash-decode roofline budget: the kernel path must stay within
    # the analytic bandwidth budget at every point AND win the measured
    # race against the gather fallback (the O(depth) HBM copy it replaced)
    # wherever the depth is >= 2k
    ok_frac = ok_race = True
    for r in paged_decode_rows():
        name = (f"kernels/paged_decode_{r['path']}"
                f"_B{r['B']}_d{r['depth']}_bs{r['block_size']}")
        report(name, r["us"],
               f"gather_us={r['gather_us']:.0f} "
               f"roofline_frac={r['roofline_frac']:.3f} "
               f"touched_mb={r['touched_bytes']/1e6:.1f}")
        ok_frac &= r["roofline_frac"] >= ROOFLINE_FRAC
        if r["depth"] >= 2048:
            ok_race &= r["us"] < r["gather_us"]
    report("kernels/paged_decode_verdict", None,
           "pass" if (ok_frac and ok_race) else
           f"fail frac_ok={ok_frac} beats_gather={ok_race}")
