"""Kernel micro-benchmarks: wall-time of the jnp fallbacks on CPU (ordering/
regression tracking) + analytic VMEM working-set check of the Pallas tilings
(the quantity that must stay under ~16 MB on v5e)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import time_call
from repro.kernels import ops


def _vmem_bytes_flash(block_q, block_k, hd):
    # q tile + k tile + v tile + f32 accumulators
    return (block_q * hd * 2 + 2 * block_k * hd * 2
            + block_q * (hd + 2) * 4)


def _vmem_bytes_lookahead(n_obs, block_k, hd):
    return n_obs * hd * 2 + block_k * hd * 2 + 2 * n_obs * 4 + block_k * 4


def _vmem_bytes_ssd(chunk, bh, hd, ds):
    return (chunk * bh * (hd + 2) * 4 + 2 * chunk * ds * 4
            + bh * hd * ds * 4 + chunk * chunk * (bh + 1) * 4)


def _vmem_bytes_chunk_masses(C, block_k, hd):
    # fused score kernel: q tile + k/v tiles + f32 (m, l, acc) scratch +
    # the (block_k,) f32 mass output tile
    return (C * hd * 2 + 2 * block_k * hd * 2
            + C * (hd + 2) * 4 + block_k * 4)


def run(report):
    fits_all = True

    def vmem_row(name, vm):
        nonlocal fits_all
        fits_all &= vm < 16e6
        report(name, None, f"vmem_kb={vm/1024:.0f} fits_16MB={vm < 16e6}")

    for (bq, bk, hd) in ((128, 128, 128), (256, 512, 128), (128, 1024, 256)):
        vmem_row(f"kernels/flash_vmem/bq{bq}_bk{bk}_hd{hd}",
                 _vmem_bytes_flash(bq, bk, hd))
    for (no, bk, hd) in ((32, 512, 128), (32, 2048, 128), (128, 1024, 256)):
        vmem_row(f"kernels/lookahead_vmem/obs{no}_bk{bk}",
                 _vmem_bytes_lookahead(no, bk, hd))
    for (C, bk, hd) in ((128, 512, 128), (256, 512, 128), (256, 1024, 256)):
        vmem_row(f"kernels/chunk_masses_vmem/C{C}_bk{bk}_hd{hd}",
                 _vmem_bytes_chunk_masses(C, bk, hd))
    for (ck, bh, hd, ds) in ((128, 8, 64, 128), (128, 8, 64, 16)):
        vmem_row(f"kernels/ssd_vmem/chunk{ck}_bh{bh}_ds{ds}",
                 _vmem_bytes_ssd(ck, bh, hd, ds))
    # the CI smoke gate keys off this row: every tiling must fit v5e VMEM
    report("kernels/vmem_verdict", None, "pass" if fits_all else "fail")

    # CPU wall-time of the fallbacks (regression tracking)
    key = jax.random.PRNGKey(0)
    B, S, H, KV, hd = 1, 4096, 4, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    fa = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, causal=True))
    report("kernels/flash_fallback_4k", time_call(fa, q, k, v),
           "B1 S4096 H4 hd64 f32")
    qo = q[:, :32]
    ls = jax.jit(lambda qo, k: ops.lookahead_score(qo, k, S - 32))
    report("kernels/lookahead_fallback_4k", time_call(ls, qo, k),
           "n_obs=32 S4096")
    qd = q[:, 0, :, :]
    da = jax.jit(lambda qd, k, v: ops.decode_attention(qd, k, v))
    report("kernels/decode_fallback_4k", time_call(da, qd, k, v), "S4096")
    qc = q[:, :256]
    cm = jax.jit(lambda qc, k, v: ops.chunk_attention(
        qc, k, v, q_offset=jnp.asarray(S - 256, jnp.int32),
        score_masses=True, n_total=jnp.asarray(S, jnp.int32))[1])
    report("kernels/chunk_masses_fallback_4k", time_call(cm, qc, k, v),
           "fused-score streaming fallback C256 S4096")
    nh, ds = 8, 64
    x = jax.random.normal(ks[0], (B, 1024, nh, 32))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, 1024, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)))
    Bm = jax.random.normal(ks[0], (B, 1024, 1, ds))
    Cm = jax.random.normal(ks[1], (B, 1024, 1, ds))
    sc = jax.jit(lambda *a: ops.ssd_scan(*a, chunk=128))
    report("kernels/ssd_fallback_1k", time_call(sc, x, dt, A, Bm, Cm),
           "S1024 nh8 ds64")
