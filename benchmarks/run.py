"""Benchmark orchestrator — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only accuracy,ttft,...]

Prints ``name,us_per_call,derived`` CSV lines (us_per_call empty for
quality/derived metrics).
"""

from __future__ import annotations

import argparse
import sys
import time

SUITES = {
    "accuracy": "benchmarks.bench_accuracy",        # Fig 4 / Tables 9-14
    "ttft": "benchmarks.bench_ttft",                # Table 3/15, Fig 3
    "ablation": "benchmarks.bench_ablation",        # Table 5
    "temperature": "benchmarks.bench_temperature",  # Tables 4 + 8
    "context": "benchmarks.bench_context_scaling",  # RULER figs
    "longform": "benchmarks.bench_longform",        # Fig 5 (LongProc proxy)
    "roofline": "benchmarks.bench_roofline",        # §Roofline (dry-run)
    "kernels": "benchmarks.bench_kernels",          # kernel micro-bench
    "serving": "benchmarks.bench_serving",          # continuous vs lockstep
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated suite names (default: all)")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(SUITES)

    print("name,us_per_call,derived")

    def report(name, us, derived=""):
        us_s = f"{us:.1f}" if us is not None else ""
        print(f"{name},{us_s},{derived}", flush=True)

    failures = []
    for name in names:
        mod_name = SUITES[name]
        t0 = time.time()
        try:
            mod = __import__(mod_name, fromlist=["run"])
            mod.run(report)
            report(f"{name}/_suite_seconds", None, f"{time.time()-t0:.1f}")
        except Exception as e:  # keep the harness going
            import traceback

            traceback.print_exc(file=sys.stderr)
            failures.append((name, repr(e)))
            report(f"{name}/_suite_error", None, repr(e))
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
