"""Prefix-reuse benchmark: a Zipf-shared prompt trace served through the
chunked ``ContinuousEngine`` with the radix-trie prompt cache on vs. off.

    PYTHONPATH=src python -m benchmarks.bench_prefix [--requests 14]

The trace (``repro.data.synthetic.make_prefix_trace``) mirrors production
prefix sharing: a small pool of multi-chunk system-prompt-style prefixes
with Zipf popularity, per-request suffixes of mixed length (including
zero — exact-duplicate prompts, the full-hit case), Poisson arrivals.

Reported:

* aggregate TTFT (mean and p95) with the cache off vs. on, and the ratio;
* the cache's hit rate, shared-prefix tokens skipped, and resident bytes;
* the TTFT of a *fully cached* prompt (served alone on a warmed cache)
  against the wall time of a single uncached chunk-prefill step — a full
  hit admits with zero prefill chunks, so it must come in under one chunk.

PASS requires both: full-hit TTFT < one uncached chunk's prefill time, and
>= 2x aggregate mean-TTFT improvement on the Zipf trace.  ``run(report)``
feeds the same verdict to ``benchmarks.ci_smoke``.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import clone_requests, engine_stats, ttft_stats
from repro.common.config import EvictionConfig
from repro.configs import get_smoke_config
from repro.data.synthetic import make_prefix_trace
from repro.models import transformer as tf
from repro.serving import ContinuousEngine, PrefixCache, Request

CHUNK = 64
# TTFT benchmark: one token per request (retire at admission) so the
# off/on comparison isolates the prefill path instead of mixing in decode
MAX_NEW = 1
BUDGET = 16
POLICY = "h2o"  # cumulative scoring: cheapest finalize, fused-mass prefill


def _requests(cfg, *, n_requests, seed):
    trace = make_prefix_trace(
        seed, n_requests, cfg.vocab_size, chunk=CHUNK, n_prefixes=3,
        prefix_chunks=(6,), suffix_lens=(0, 0, 33, 64), zipf_a=1.3,
        rate_hz=200.0)
    return [Request(uid=i, prompt=p, max_new_tokens=MAX_NEW, arrival_s=a)
            for i, (p, a) in enumerate(trace)]


_clone = clone_requests
_ttft = ttft_stats


def _engine(cfg, params, *, prefix_cache=None, max_len):
    return ContinuousEngine(
        params, cfg, policy=POLICY, evict=EvictionConfig(budget=BUDGET),
        num_slots=4, chunk=CHUNK, max_context=max_len,
        max_new_tokens=MAX_NEW, eos_id=-1, decode_chunk=2,
        prefix_cache=prefix_cache)




def _chunk_step_time(cfg, params, eng, reps=20):
    """Median wall time of one compiled, uncached chunk-prefill step."""
    fn = eng.chunk_cache.get("chunk", CHUNK, 1, POLICY)
    state = tf.init_chunk_state(cfg, POLICY, 1, eng._base_cap)
    rng = np.random.default_rng(0)
    blk = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, CHUNK))
                      .astype(np.int32))
    n = jnp.asarray(4 * CHUNK, jnp.int32)
    fn(params, state, blk, n)[1].block_until_ready()  # compile
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(params, state, blk, n)[1].block_until_ready()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def bench(n_requests=14, seed=0):
    cfg = get_smoke_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _requests(cfg, n_requests=n_requests, seed=seed)
    max_len = max(len(r.prompt) for r in reqs)
    eng_off = _engine(cfg, params, max_len=max_len)
    cache = PrefixCache(chunk=CHUNK, max_bytes=256 << 20)
    eng_on = _engine(cfg, params, prefix_cache=cache, max_len=max_len)
    # warmup replays: compile every program.  The cache-on engine needs
    # two — the first populates the trie, the second takes the *hit* path
    # and compiles the chain-materialize programs the timed replay reuses
    eng_off.run(_clone(reqs))
    eng_on.run(_clone(reqs))
    eng_on.run(_clone(reqs))
    res = {"off": _ttft(eng_off.run(_clone(reqs)))}
    done_on = eng_on.run(_clone(reqs))
    es = engine_stats(eng_on)
    res["on"] = _ttft(done_on)
    res["on"].update(
        hit_rate=es["prefix"]["hit_rate"],
        cached_token_frac=es["prefix"]["cached_token_frac"],
        tokens_skipped=es["prefix_tokens_skipped"],
        cache_bytes=cache.stats()["bytes"],
        entries=cache.stats()["entries"],
    )
    # fully cached prompts admitted on a warm, idle engine: TTFT must be
    # below even a single uncached chunk's prefill step.  A same-run warm
    # request absorbs the per-``run()`` setup (live-cache allocation), and
    # spaced late arrivals each admit on an idle engine, so the median
    # measures steady-state admission, not engine init or one-shot jitter.
    rng = np.random.default_rng(seed + 1)
    fulls = [Request(uid=10_000 + i,
                     prompt=reqs[0].prompt[:6 * CHUNK].copy(),
                     max_new_tokens=MAX_NEW, arrival_s=0.4 + 0.2 * i)
             for i in range(3)]
    warm = Request(uid=9_999, prompt=rng.integers(
        0, cfg.vocab_size, CHUNK).astype(np.int32), max_new_tokens=MAX_NEW)
    done = {r.uid: r for r in eng_on.run([warm] + fulls)}
    assert all(done[f.uid].cached_prefix_tokens == len(f.prompt)
               for f in fulls), "warmed trace did not cover the prefix"
    res["full_hit_ttft_s"] = float(np.median(
        [done[f.uid].ttft_s for f in fulls]))
    res["chunk_step_s"] = _chunk_step_time(cfg, params, eng_off)
    res["ttft_speedup"] = (res["off"]["ttft_mean_ms"]
                           / max(res["on"]["ttft_mean_ms"], 1e-9))
    return res


def _verdict(res) -> tuple[bool, str]:
    under_chunk = res["full_hit_ttft_s"] < res["chunk_step_s"]
    speedup = res["ttft_speedup"] >= 2.0
    ok = under_chunk and speedup
    return ok, (
        f"{'PASS' if ok else 'FAIL'}: full-hit TTFT "
        f"{1e3 * res['full_hit_ttft_s']:.2f}ms vs one chunk "
        f"{1e3 * res['chunk_step_s']:.2f}ms "
        f"({'under' if under_chunk else 'NOT under'}); aggregate TTFT "
        f"{res['ttft_speedup']:.2f}x ({'>=' if speedup else 'BELOW'} 2x), "
        f"hit-rate {res['on']['hit_rate']:.2f}")


def run(report):
    """benchmarks.ci_smoke entry point."""
    res = bench()
    report("prefix/ttft_mean_off_ms", None,
           f"{res['off']['ttft_mean_ms']:.1f}")
    report("prefix/ttft_mean_on_ms", None,
           f"{res['on']['ttft_mean_ms']:.1f}")
    report("prefix/ttft_speedup", None, f"{res['ttft_speedup']:.2f}x")
    report("prefix/hit_rate", None, f"{res['on']['hit_rate']:.2f}")
    report("prefix/cached_token_frac", None,
           f"{res['on']['cached_token_frac']:.2f}")
    report("prefix/cache_bytes", None, f"{res['on']['cache_bytes']}")
    report("prefix/full_hit_ttft_ms", None,
           f"{1e3 * res['full_hit_ttft_s']:.2f}")
    report("prefix/chunk_step_ms", None, f"{1e3 * res['chunk_step_s']:.2f}")
    ok, verdict = _verdict(res)
    report("prefix/reuse_verdict", None, "pass" if ok else "fail")
    print(verdict)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=14)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    res = bench(args.requests, args.seed)
    print(f"{'engine':8s} {'ttft_ms':>9s} {'ttft_p95':>9s}")
    for name in ("off", "on"):
        m = res[name]
        print(f"{name:8s} {m['ttft_mean_ms']:9.1f} {m['ttft_p95_ms']:9.1f}")
    on = res["on"]
    print(f"hit-rate {on['hit_rate']:.2f}  cached-token-frac "
          f"{on['cached_token_frac']:.2f}  entries {on['entries']}  "
          f"bytes {on['cache_bytes']}")
    print(_verdict(res)[1])


if __name__ == "__main__":
    main()
