"""Sharded-serving benchmark: tensor-parallel paged decode over a forced
host device mesh, at model = {1, 2, 4}.

    PYTHONPATH=src python -m benchmarks.bench_sharded

The measurement child re-execs under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the parent's JAX
is typically already initialized single-device, and the flag only takes
effect before the backend loads), serves one Poisson trace through the
paged ``ContinuousEngine`` at each model-axis width, and reports:

* decode throughput (tok/s) and per-device decode throughput (tok/s
  divided by the mesh's device count — on a *forced host* mesh every
  "device" timeshares one CPU, so wall throughput is flat-to-worse as
  model grows; the per-device number is the figure that transfers to a
  real accelerator mesh);
* KV pool bytes per shard — the number tensor parallelism actually
  scales: each shard holds only its kv-head slice of every block.

``sharded/scaling_verdict`` (gated in ``benchmarks.ci_smoke``) passes iff

* per-shard pool bytes scale exactly as total/model at model = 2 and 4
  (the pool's kv-head dim is sharded, block tables replicated),
* every config emits bit-identical tokens (same uid -> same sequence) —
  the tentpole bit-exactness contract, re-checked here end-to-end on the
  bench trace (``tests/test_sharded_serving.py`` is the adversarial
  version with kept-set equality), and
* sharded wall throughput stays above ``TPUT_FLOOR`` x the single-device
  run.  The bound is deliberately loose (0.1x): 8 forced host "devices"
  timeshare one CPU, so sharding *cannot* speed this host up — the gate
  only catches pathological shard_map overhead (e.g. a per-step
  recompile), while real scaling is the per-device column on an
  accelerator mesh.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

MODEL_WIDTHS = (1, 2, 4)
N_DEVICES = 8
CHUNK = 32
MAX_NEW = 8
N_REQUESTS = 6
TPUT_FLOOR = 0.1  # see module docstring: a pathology guard, not a target
_MARK = "BENCH_SHARDED_JSON:"


def _child_bench() -> dict:
    """Runs inside the forced-8-device subprocess."""
    import dataclasses

    import jax
    import numpy as np

    from benchmarks.common import make_poisson_trace
    from repro.common.config import EvictionConfig
    from repro.configs import get_smoke_config
    from repro.core.lookahead import init_lookahead_params
    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer as tf
    from repro.serving import ContinuousEngine, KVBlockPool

    base = get_smoke_config("smollm-135m")
    # smollm's single kv head can't shard: widen to 8 q / 4 kv heads (the
    # same geometry tests/test_sharded_serving.py proves bit-exact)
    cfg = dataclasses.replace(
        base, name="smollm-smoke-tp", d_model=128,
        attn=dataclasses.replace(base.attn, num_heads=8, num_kv_heads=4,
                                 head_dim=16))
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    lkv = init_lookahead_params(jax.random.PRNGKey(1), cfg,
                                params["layers"])
    out: dict = {"devices": len(jax.devices()), "configs": {}}
    for model in MODEL_WIDTHS:
        mesh = make_host_mesh(model=model) if model > 1 else None
        pool = KVBlockPool(cfg, block_size=16, num_blocks=128, mesh=mesh)
        eng = ContinuousEngine(
            params, cfg, policy="lookaheadkv",
            evict=EvictionConfig(budget=16), lkv_params=lkv, num_slots=3,
            chunk=CHUNK, max_context=2 * CHUNK, max_new_tokens=MAX_NEW,
            eos_id=-1, kv_pool=pool, mesh=mesh)
        # near-burst arrivals: admission order must be identical across
        # widths or token comparison measures scheduler timing, not math
        trace = make_poisson_trace(
            N_REQUESTS, cfg.vocab_size, (17, 24, 31, 48), seed=0,
            max_new=MAX_NEW, gap_s=1e-6)
        eng.run([r.clone() for r in trace])  # compile off the clock
        done = eng.run([r.clone() for r in trace])
        from benchmarks.common import engine_stats
        es = engine_stats(eng)
        toks = sum(len(r.out_tokens) for r in done)
        steps = max(es.get("decode_steps", 0), 1)
        decode_s = max(es.get("decode_time_s", 0.0), 1e-9)
        s = es["kv_pool"]
        out["configs"][str(model)] = {
            "tok_per_s": toks / decode_s,
            "decode_step_ms": 1e3 * decode_s / steps,
            "bytes_total": s["bytes_total"],
            "bytes_per_shard": s.get("bytes_total_per_shard",
                                     s["bytes_total"]),
            "mesh": es.get("mesh"),
            "tokens": {int(r.uid): [int(t) for t in r.out_tokens]
                       for r in done},
        }
    return out


def bench() -> dict:
    """Spawn the forced-multi-device child and collect its measurements."""
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    env["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={N_DEVICES}"
    ).strip()
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_sharded", "--child"],
        capture_output=True, text=True, env=env, timeout=900)
    for line in proc.stdout.splitlines():
        if line.startswith(_MARK):
            return json.loads(line[len(_MARK):])
    raise RuntimeError(
        f"sharded bench child failed (rc={proc.returncode}):\n"
        f"{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}")


def _verdict(res) -> tuple[bool, str]:
    cfgs = res["configs"]
    base = cfgs["1"]
    kv_ok = all(
        cfgs[str(m)]["bytes_per_shard"] == base["bytes_total"] // m
        for m in MODEL_WIDTHS if m > 1)
    tok_ok = all(cfgs[str(m)]["tokens"] == base["tokens"]
                 for m in MODEL_WIDTHS if m > 1)
    tput_ok = all(
        cfgs[str(m)]["tok_per_s"] >= TPUT_FLOOR * base["tok_per_s"]
        for m in MODEL_WIDTHS if m > 1)
    ok = kv_ok and tok_ok and tput_ok
    shards = " ".join(
        f"model={m}:{cfgs[str(m)]['bytes_per_shard']}B/shard"
        for m in MODEL_WIDTHS)
    return ok, (
        f"{'PASS' if ok else 'FAIL'}: per-shard KV bytes "
        f"{'scale as total/model' if kv_ok else 'do NOT scale'} "
        f"({shards}); tokens "
        f"{'bit-identical' if tok_ok else 'DIVERGE'} across widths; "
        f"sharded throughput {'within' if tput_ok else 'BELOW'} the "
        f"{TPUT_FLOOR}x host-mesh floor")


def run(report):
    """benchmarks.ci_smoke entry point."""
    from benchmarks.common import report_rows

    res = bench()
    for m in MODEL_WIDTHS:
        c = res["configs"][str(m)]
        devices = res["devices"]
        report_rows(report, "sharded", {
            f"model{m}_tok_per_s": f"{c['tok_per_s']:.1f}",
            f"model{m}_tok_per_s_per_device":
                f"{c['tok_per_s'] / devices:.1f}",
            f"model{m}_decode_step_ms": f"{c['decode_step_ms']:.2f}",
            f"model{m}_kv_bytes_per_shard": f"{c['bytes_per_shard']}",
            f"model{m}_mesh": str(c["mesh"] or "single-device"),
        })
    ok, verdict = _verdict(res)
    report("sharded/scaling_verdict", None, "pass" if ok else "fail")
    print(verdict)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", action="store_true",
                    help="internal: run the measurement in-process")
    args = ap.parse_args()
    if args.child:
        print(_MARK + json.dumps(_child_bench()), flush=True)
        return
    res = bench()
    devices = res["devices"]
    print(f"{'model':>5s} {'tok/s':>8s} {'tok/s/dev':>10s} "
          f"{'step_ms':>8s} {'B/shard':>10s} {'mesh':>24s}")
    for m in MODEL_WIDTHS:
        c = res["configs"][str(m)]
        print(f"{m:5d} {c['tok_per_s']:8.1f} "
              f"{c['tok_per_s'] / devices:10.1f} "
              f"{c['decode_step_ms']:8.2f} {c['bytes_per_shard']:10d} "
              f"{str(c['mesh'] or 'single-device'):>24s}")
    print(_verdict(res)[1])


if __name__ == "__main__":
    main()
