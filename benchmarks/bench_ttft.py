"""Paper Table 3 / 15 + Fig. 3: theoretical + empirical TTFT cost analysis.

Theoretical: the Davies et al. (2025)-style analytical model the paper uses
(§B): per phase, latency = max(FLOPs / (peak·eff_f), bytes / (bw·eff_m)),
H100 constants, eff_f = 0.7, eff_m = 0.9, batch 1, half precision, C = 128,
lookahead/window/draft = 32.  Reproduces the paper's structure exactly for
LLaMA3.1-8B at 4K–32K and derives the headline "LAQ overhead / LKV overhead"
ratio (paper: up to 14.5×).

Empirical: wall-clock prefill+evict on the CPU smoke model (ordering only —
CPU microseconds are not H100 milliseconds).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import time_call, trained_model
from repro.common.config import EvictionConfig
from repro.configs import get_config
from repro.core import policies

# H100 SXM, half precision (paper §B)
PEAK = 989e12
BW = 3.35e12
EFF_F, EFF_M = 0.7, 0.9

BUDGET = 128
N_LOOK = 32
DRAFT = 32


def _phase(flops, bytes_):
    return max(flops / (PEAK * EFF_F), bytes_ / (BW * EFF_M))


def _model_stats(cfg):
    n = cfg.num_params()
    a = cfg.attn
    kv_per_tok = cfg.num_layers * a.kv_dim * 2 * 2  # K+V bf16 bytes
    return n, kv_per_tok


def theoretical_ttft(cfg, ctx: int, method: str, draft_cfg=None) -> dict:
    """Returns {compute_tflops, mem_gb, ttft_ms, overhead_ms}."""
    n, kv_tok = _model_stats(cfg)
    w_bytes = 2 * n

    def prefill(tokens, model_n=n, model_w=w_bytes, model_kv=kv_tok):
        fl = 2 * model_n * tokens
        # + attention quadratic term
        a = cfg.attn
        fl += 4 * tokens * tokens * a.q_dim * cfg.num_layers / 2
        by = model_w + tokens * model_kv
        return fl, by

    def decode_steps(steps, cache_tokens, model_n=n, model_w=w_bytes,
                     model_kv=kv_tok):
        fl = steps * 2 * model_n
        by = steps * (model_w + cache_tokens * model_kv)
        return fl, by

    base_f, base_b = prefill(ctx)
    t_base = _phase(base_f, base_b)

    if method == "forward":
        f, b = base_f, base_b
        t = t_base
    elif method == "snapkv":
        # reuses prefill attention; score pass over window×ctx is ~free
        f = base_f + 2 * 32 * ctx * cfg.attn.q_dim * cfg.num_layers
        b = base_b + ctx * kv_tok / 1024  # score reads are tiny
        t = _phase(f, b)
    elif method == "lookaheadkv":
        # 32 extra rows through the model (+LoRA ~0.5%) + fused score kernel
        ext_f = 2 * n * N_LOOK * 1.005
        score_f = 2 * N_LOOK * ctx * cfg.attn.q_dim * cfg.num_layers
        f = base_f + ext_f + score_f
        b = base_b + ctx * kv_tok  # score kernel streams K once
        t = _phase(f, b)
    elif method == "speckv":
        dn, dkv = _model_stats(draft_cfg)
        dpre_f, dpre_b = prefill(ctx, dn, 2 * dn, dkv)
        ddec_f, ddec_b = decode_steps(DRAFT, ctx, dn, 2 * dn, dkv)
        scr_f = 2 * DRAFT * ctx * cfg.attn.q_dim * cfg.num_layers \
            + 2 * n * DRAFT
        f = base_f + dpre_f + ddec_f + scr_f
        b = base_b + dpre_b + ddec_b + ctx * kv_tok
        t = t_base + _phase(dpre_f, dpre_b) + _phase(ddec_f, ddec_b) \
            + _phase(scr_f, ctx * kv_tok)
    elif method == "laq":
        # phase 2: 32 decode steps re-reading ALL weights each step — the
        # paper's 445 GB memory-traffic column
        ddec_f, ddec_b = decode_steps(DRAFT, BUDGET)
        scr_f = 2 * DRAFT * ctx * cfg.attn.q_dim * cfg.num_layers
        scr_b = ctx * kv_tok  # re-read full prompt KV
        f = base_f + ddec_f + scr_f
        b = base_b + ddec_b + scr_b
        t = t_base + _phase(ddec_f, ddec_b) + _phase(scr_f, scr_b)
    else:
        raise ValueError(method)
    return {
        "tflops": f / 1e12,
        "mem_gb": b / 1e9,
        "ttft_ms": t * 1e3,
        "overhead_ms": (t - t_base) * 1e3,
    }


def run(report):
    cfg = get_config("llama3-8b")
    draft = get_config("tiny-llama")
    headline = {}
    for ctx in (4096, 8192, 16384, 32768):
        for m in ("forward", "lookaheadkv", "snapkv", "speckv", "laq"):
            r = theoretical_ttft(cfg, ctx, m, draft_cfg=draft)
            report(
                f"ttft_theory/{m}/ctx{ctx}", None,
                f"tflops={r['tflops']:.0f} mem_gb={r['mem_gb']:.0f} "
                f"ttft_ms={r['ttft_ms']:.1f} overhead_ms={r['overhead_ms']:.2f}",
            )
            headline[(m, ctx)] = r["overhead_ms"]
    ratio = headline[("laq", 32768)] / max(headline[("lookaheadkv", 32768)],
                                           1e-9)
    pct = 100 * headline[("lookaheadkv", 32768)] / (
        theoretical_ttft(cfg, 32768, "forward")["ttft_ms"])
    report("ttft_theory/headline", None,
           f"LAQ/LKV theoretical overhead ratio @32K = {ratio:.0f}x "
           f"(paper Table 3 theoretical: 239.26/1.74 = 137x; the quoted "
           f"14.5x is the paper's *empirical* 553.68/38.04); "
           f"LKV overhead = {pct:.2f}% of TTFT (paper: <=2.16%)")

    # empirical (CPU smoke model; ordering only)
    scfg, params, lkv, _ = trained_model()
    tokens = jax.random.randint(jax.random.PRNGKey(0), (1, 96), 0,
                                scfg.vocab_size)
    ev = EvictionConfig(budget=16, draft_len=8)
    for m in ("snapkv", "lookaheadkv", "laq"):
        fn = jax.jit(lambda t, m=m: policies.run_eviction(
            m, params, scfg, t, evict=ev, lkv_params=lkv).logits)
        us = time_call(fn, tokens)
        report(f"ttft_empirical_cpu/{m}", us, "prefill+evict wall (smoke)")
