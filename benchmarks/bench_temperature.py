"""Paper Table 4 + Table 8: stochastic-decoding robustness.

Table 8 reproduction: similarity (recall@k, Kendall τ) between importance
scores induced by greedy responses vs temperature-sampled responses of the
target model, and vs a *different* (draft) model's greedy response — the
paper finds temperature deviations smaller than cross-model deviation, which
justifies greedy training data.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import (eval_batch, kendall_tau, recall_at_k,
                               trained_model)
from repro.core import objective, policies
from repro.models import transformer as tf

TEMPS = (0.2, 0.8)
N_GEN = 12


def _scores_for_response(params, cfg, x, y):
    xy = jnp.concatenate([x, y.astype(x.dtype)], axis=1)
    return objective.gt_scores(params, cfg, xy, x.shape[1])


def _generate(params, cfg, x, temperature, key):
    res = tf.prefill(params, cfg, x, policy="full", extra_slots=N_GEN + 1)
    toks, _ = policies.sample_decode(params, cfg, res.logits, res.cache,
                                     N_GEN, temperature=temperature, key=key)
    return toks


def run(report):
    cfg, params, lkv, _ = trained_model()
    b, x, xy = eval_batch(cfg, seed=77)
    key = jax.random.PRNGKey(0)

    y_greedy = _generate(params, cfg, x, 0.0, key)
    s_greedy = _scores_for_response(params, cfg, x, y_greedy)

    for t in TEMPS:
        y_t = _generate(params, cfg, x, t, jax.random.PRNGKey(int(t * 100)))
        s_t = _scores_for_response(params, cfg, x, y_t)
        r = recall_at_k(s_t, s_greedy, k=16)
        tau = kendall_tau(s_t, s_greedy)
        report(f"temperature/T{t}", None,
               f"recall@16={r:.3f} kendall_tau={tau:.3f} (vs greedy GT)")

    # cross-model deviation (SpecKV setting): draft model's greedy response
    from repro.configs import get_smoke_config

    dcfg = get_smoke_config("tiny-llama")
    dparams = tf.init_params(jax.random.PRNGKey(5), dcfg)
    y_draft = _generate(dparams, dcfg, x, 0.0, key)
    s_draft = _scores_for_response(params, cfg, x, y_draft)
    r = recall_at_k(s_draft, s_greedy, k=16)
    tau = kendall_tau(s_draft, s_greedy)
    report("temperature/draft-model", None,
           f"recall@16={r:.3f} kendall_tau={tau:.3f} "
           f"(paper: below all temperature settings)")
