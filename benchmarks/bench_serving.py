"""Serving benchmark: chunked vs bucketed continuous batching (and the
lockstep baseline) on Poisson traces, including a long-tail trace.

    PYTHONPATH=src python -m benchmarks.bench_serving [--long-tail]
    PYTHONPATH=src python -m benchmarks.bench_serving --long-tail \
        --long-len 8192 --n-long 2

One trace, replayed FCFS through each engine:

* **lockstep** (deprecated ``ServingEngine``): a batch must share one
  prompt length and prefill+decode run to completion before the next batch.
* **bucketed** (deprecated ``BucketedEngine``): pad-to-bucket *monolithic*
  prefill feeding fixed decode slots — every live slot stalls for the whole
  prefill of an admitted prompt, and each (bucket, batch, padded) shape
  compiles its own program.
* **chunked** (``ContinuousEngine``): one compiled ``(1, chunk)`` prefill
  program with streaming eviction scores, interleaved with decode under a
  token-budget step.

The **long-tail trace** plants a few 8k–16k prompts amid short traffic —
the shape that breaks the bucket ladder: the long prompts compile fresh
power-of-two bucket programs and stall every live decode slot for whole
monolithic prefills.  Reported per engine: throughput, p95 TTFT, p95 TPOT,
max decode stall (worst gap between consecutive token emissions of any
request), the jit-compile count, and the peak device KV bytes the engine
reserves (``kv_bytes_peak`` — BENCH_*.json tracks the memory trajectory
across PRs; ``benchmarks/bench_paged.py`` is the bench that *varies* it).  The chunked engine must compile
strictly fewer programs and cut p95 TPOT / decode stall under the long
tail — the bench prints an explicit PASS/FAIL verdict line.

``bench_decode_evict`` (``--decode-evict``; always part of the CI
``run`` entry) replays a **long-generation** trace through the paged
pool with decode-time eviction off vs on at equal KV pool bytes: the
``serving/decode_evict_verdict`` row passes iff sweeps reclaim whole
blocks mid-generation and lift peak concurrency, with every generation
still completing at full length.
"""

from __future__ import annotations

import argparse
import time
import warnings

import jax
import numpy as np

from benchmarks.common import (clone_requests, decode_step_stats,
                               engine_stats, make_poisson_trace, ttft_stats)
from repro.common.config import EvictionConfig
from repro.configs import get_smoke_config
from repro.core.lookahead import init_lookahead_params
from repro.models import transformer as tf
from repro.serving import (BucketedEngine, ChunkingConfig, ContinuousEngine,
                           DecodeEvictionConfig, KVBlockPool, ServingConfig,
                           ServingEngine)

# Heterogeneous short lengths (9 distinct values over 3 compile buckets).
PROMPT_LENS = (17, 24, 31, 41, 48, 60, 75, 90, 120)
BUCKETS = (32, 64, 128)
CHUNK = 64
MAX_NEW = 16
BUDGET = 16


def make_trace(n_requests: int, rate_hz: float, seed: int, vocab: int,
               *, long_tail: bool = False, long_len: int = 8192,
               n_long: int = 2):
    """Poisson arrivals, uniform mix over PROMPT_LENS; with ``long_tail``,
    ``n_long`` prompts of ``long_len`` tokens are planted mid-trace."""
    long_uids = set()
    if long_tail and n_long:
        assert n_long <= max(n_requests // 3, 1), \
            "long tail would dominate the trace; raise --requests"
        # consecutive mid-trace uids: guaranteed n_long *distinct* plants
        # (an index formula that rounds, e.g. linspace, can collide and
        # silently shrink the tail)
        start = n_requests // 3
        long_uids = set(range(start, start + n_long))
    return make_poisson_trace(n_requests, vocab, PROMPT_LENS, seed=seed,
                              max_new=MAX_NEW, rate_hz=rate_hz,
                              long_uids=long_uids, long_len=long_len)


_clone = clone_requests


def _metrics(reqs, wall, *, tracks_gaps: bool = True):
    toks = sum(len(r.out_tokens) for r in reqs)
    tpot = np.array([r.tpot_s for r in reqs if r.tpot_s > 0])
    gaps = np.array([r.max_gap_s for r in reqs])
    m = {
        "wall_s": wall,
        "tok_per_s": toks / wall,
        "tpot_mean_ms": 1e3 * tpot.mean() if len(tpot) else 0.0,
        "tpot_p95_ms": 1e3 * np.percentile(tpot, 95) if len(tpot) else 0.0,
        # nan (printed as n/a) when the engine has no per-chunk emission
        # timestamps — the lockstep engine decodes a batch in one blocking
        # call, so a 0.0 here would misread as "never stalls"
        "stall_max_ms": (1e3 * gaps.max() if len(gaps) and tracks_gaps
                         else float("nan")),
    }
    m.update(ttft_stats(reqs))
    return m


def run_lockstep(eng, reqs, *, max_batch=4):
    """FCFS trace replay under the lockstep contract: serve the queue head
    together with every *arrived* request of the same prompt length."""
    queue = sorted(reqs, key=lambda r: r.arrival_s)
    done = []
    t0 = time.perf_counter()
    while queue:
        now = time.perf_counter() - t0
        arrived = [r for r in queue if r.arrival_s <= now]
        if not arrived:
            time.sleep(max(queue[0].arrival_s - now, 0.0))
            continue
        head = arrived[0]
        batch = [r for r in arrived
                 if len(r.prompt) == len(head.prompt)][:max_batch]
        for r in batch:
            queue.remove(r)
        serve_start = time.perf_counter() - t0
        eng.serve(batch)
        serve_end = time.perf_counter() - t0
        for r in batch:
            # r.ttft_s is still serve-relative here: split decode time off
            # first, then rebase TTFT onto the trace clock (queue wait incl.)
            decode_s = serve_end - serve_start - r.ttft_s
            r.tpot_s = decode_s / max(len(r.out_tokens) - 1, 1)
            r.ttft_s = serve_start + r.ttft_s - r.arrival_s
        done += batch
    m = _metrics(done, time.perf_counter() - t0, tracks_gaps=False)
    m["kv_bytes_peak"] = eng.kv_device_bytes(max_batch)
    return m


def run_bucketed(eng, reqs):
    t0 = time.perf_counter()
    done = eng.run(reqs)
    wall = time.perf_counter() - t0
    m = _metrics(done, wall)
    m["compiles"] = (eng.prefill_cache.compile_count()
                     + len(eng._decode_fns))
    m["compile_cache"] = eng.prefill_cache.stats()
    m["kv_bytes_peak"] = eng.kv_device_bytes()
    m.update(decode_step_stats(eng))
    return m


def run_chunked(eng, reqs):
    t0 = time.perf_counter()
    done = eng.run(reqs)
    wall = time.perf_counter() - t0
    m = _metrics(done, wall)
    m["compiles"] = (eng.chunk_cache.compile_count()
                     + len(eng._decode_fns))
    m["compile_cache"] = eng.chunk_cache.stats()
    s = engine_stats(eng)
    m["engine_stats"] = s
    m["kv_bytes_peak"] = eng.kv_device_bytes()
    # the serving mesh (None = single-device): BENCH_*.json rows must say
    # which device topology produced their numbers
    m["mesh"] = s.get("mesh")
    m.update(decode_step_stats(eng))
    return m


def bench(n_requests=24, rate_hz=20.0, policy="lookaheadkv", slots=4,
          seed=0, warmup=True, long_tail=False, long_len=8192, n_long=2,
          lockstep=False):
    cfg = get_smoke_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    lkv = init_lookahead_params(jax.random.PRNGKey(1), cfg, params["layers"])
    trace = make_trace(n_requests, rate_hz, seed, cfg.vocab_size,
                       long_tail=long_tail, long_len=long_len, n_long=n_long)
    kw = dict(policy=policy, evict=EvictionConfig(budget=BUDGET),
              lkv_params=lkv, max_new_tokens=MAX_NEW, eos_id=-1)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        bucket_eng = BucketedEngine(params, cfg, num_slots=slots,
                                    buckets=BUCKETS, **kw)
        lock_eng = ServingEngine(params, cfg, **kw) if lockstep else None
    chunk_eng = ContinuousEngine(
        params, cfg,
        ServingConfig(policy=policy, evict=EvictionConfig(budget=BUDGET),
                      chunking=ChunkingConfig(
                          chunk=CHUNK, max_context=max(PROMPT_LENS) + CHUNK),
                      num_slots=slots, max_new_tokens=MAX_NEW, eos_id=-1),
        lkv_params=lkv)
    bucket_eng.warmup(PROMPT_LENS, batch_sizes=(1, 2, slots))
    chunk_eng.warmup(PROMPT_LENS)
    if warmup:  # one untimed replay per engine compiles every program
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            run_bucketed(bucket_eng, _clone(trace))
            if lock_eng is not None:
                run_lockstep(lock_eng, _clone(trace))
        run_chunked(chunk_eng, _clone(trace))
    out = {}
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        out["bucketed"] = run_bucketed(bucket_eng, _clone(trace))
        if lock_eng is not None:
            out["lockstep"] = run_lockstep(lock_eng, _clone(trace))
    out["chunked"] = run_chunked(chunk_eng, _clone(trace))
    return out


def bench_decode_evict(n_requests=8, policy="lookaheadkv", seed=0, *,
                       max_new=48, interval=16, block_size=16,
                       pool_blocks=10, slots=4, warmup=True):
    """Long-generation trace on the paged pool, decode-time eviction off
    vs on, at **equal KV pool bytes** (identical pool geometry).

    Off, every admitted request must reserve ``budget + max_new + 1``
    rows of pool for its whole lifetime; on, a slot's footprint is
    bounded at ``budget + interval`` rows because periodic sweeps
    re-evict the grown cache and free the tail blocks mid-generation —
    so the same pool admits more concurrent requests.  Reported per
    config: throughput, peak concurrency, pool high water, and the
    blocks reclaimed by sweeps."""
    cfg = get_smoke_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    lkv = init_lookahead_params(jax.random.PRNGKey(1), cfg, params["layers"])
    trace = make_poisson_trace(n_requests, cfg.vocab_size, PROMPT_LENS[:5],
                               seed=seed, max_new=max_new, rate_hz=100.0)

    def engine(enabled):
        pool = KVBlockPool(cfg, block_size=block_size,
                           num_blocks=pool_blocks)
        sc = ServingConfig(
            policy=policy, evict=EvictionConfig(budget=BUDGET),
            decode_evict=DecodeEvictionConfig(enabled=enabled,
                                              interval=interval),
            chunking=ChunkingConfig(chunk=CHUNK,
                                    max_context=max(PROMPT_LENS) + CHUNK),
            num_slots=slots, max_new_tokens=max_new, eos_id=-1,
            kv_pool=pool)
        return ContinuousEngine(params, cfg, sc, lkv_params=lkv), pool

    out = {}
    for name, enabled in (("paged", False), ("paged_evict", True)):
        eng, pool = engine(enabled)
        if warmup:
            eng.run(_clone(trace))
        t0 = time.perf_counter()
        done = eng.run(_clone(trace))
        wall = time.perf_counter() - t0
        es = engine_stats(eng)
        s = es["kv_pool"]
        out[name] = {
            "wall_s": wall,
            "tok_per_s": sum(len(r.out_tokens) for r in done) / wall,
            "full_length": all(len(r.out_tokens) == max_new for r in done),
            "max_concurrency": es["max_concurrency"],
            "pool_bytes": s["bytes_total"],
            "high_water_blocks": s["high_water_blocks"],
            "sweeps": es.get("decode_evict_sweeps", 0),
            "blocks_reclaimed": s["blocks_reclaimed_decode"],
            "preemptions": es["preemptions"],
        }
    return out


def _decode_evict_verdict(res) -> tuple[bool, str]:
    off, on = res["paged"], res["paged_evict"]
    assert off["pool_bytes"] == on["pool_bytes"], \
        "the comparison is only meaningful at equal KV pool bytes"
    more = on["max_concurrency"] > off["max_concurrency"]
    reclaims = on["blocks_reclaimed"] > 0
    complete = on["full_length"] and off["full_length"]
    ok = more and reclaims and complete
    return ok, (f"{'PASS' if ok else 'FAIL'}: at "
                f"{off['pool_bytes'] / 1e6:.2f} MB of pool, decode "
                f"eviction lifts peak concurrency "
                f"{off['max_concurrency']} -> {on['max_concurrency']} "
                f"({'more' if more else 'NOT more'}); "
                f"{on['sweeps']} sweeps reclaimed "
                f"{on['blocks_reclaimed']} blocks mid-generation "
                f"({'some' if reclaims else 'NONE'}); generations "
                f"{'complete' if complete else 'TRUNCATED'}")


def _verdict(res) -> tuple[bool, str]:
    b, c = res["bucketed"], res["chunked"]
    fewer = c["compiles"] < b["compiles"]
    faster = c["tpot_p95_ms"] < b["tpot_p95_ms"]
    ok = fewer and faster
    return ok, (f"{'PASS' if ok else 'FAIL'}: chunked compiles "
                f"{c['compiles']} vs bucketed {b['compiles']} "
                f"({'strictly fewer' if fewer else 'NOT fewer'}); "
                f"p95 TPOT {c['tpot_p95_ms']:.2f}ms vs "
                f"{b['tpot_p95_ms']:.2f}ms "
                f"({'lower' if faster else 'NOT lower'})")


def run(report):
    """benchmarks.run entry point: a compact long-tail trace."""
    res = bench(n_requests=12, rate_hz=20.0, long_tail=True, long_len=2048,
                n_long=1, warmup=True)
    for name in ("bucketed", "chunked"):
        m = res[name]
        report(f"serving/{name}_tok_per_s", None, f"{m['tok_per_s']:.1f}")
        report(f"serving/{name}_ttft_p95_ms", None,
               f"{m['ttft_p95_ms']:.0f}")
        report(f"serving/{name}_tpot_p95_ms", None,
               f"{m['tpot_p95_ms']:.2f}")
        report(f"serving/{name}_stall_max_ms", None,
               f"{m['stall_max_ms']:.0f}")
        report(f"serving/{name}_compiles", None, f"{m['compiles']}")
        report(f"serving/{name}_decode_step_ms", None,
               f"{m['decode_step_ms']:.2f} path={m['decode_path']}")
        # peak device KV bytes per config: BENCH_*.json tracks the memory
        # trajectory across PRs, not just latency/throughput
        report(f"serving/{name}_kv_bytes_peak", None,
               f"{m['kv_bytes_peak']}")
    report("serving/chunked_mesh", None,
           str(res["chunked"]["mesh"] or "single-device"))
    ok, verdict = _verdict(res)
    report("serving/longtail_verdict", None, "pass" if ok else "fail")
    speed = (res["chunked"]["tok_per_s"]
             / max(res["bucketed"]["tok_per_s"], 1e-9))
    report("serving/chunked_speedup", None, f"{speed:.2f}x")
    # decode-time eviction on the paged pool: concurrency at equal KV bytes
    de = bench_decode_evict(n_requests=6, warmup=True)
    for name in ("paged", "paged_evict"):
        m = de[name]
        report(f"serving/{name}_tok_per_s", None, f"{m['tok_per_s']:.1f}")
        report(f"serving/{name}_max_concurrency", None,
               str(m["max_concurrency"]))
    report("serving/decode_evict_sweeps", None,
           str(de["paged_evict"]["sweeps"]))
    report("serving/decode_evict_blocks_reclaimed", None,
           str(de["paged_evict"]["blocks_reclaimed"]))
    ok_de, _ = _decode_evict_verdict(de)
    report("serving/decode_evict_verdict", None, "pass" if ok_de else "fail")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=20.0,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--policy", default="lookaheadkv")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-warmup", action="store_true")
    ap.add_argument("--long-tail", action="store_true",
                    help="plant a few long prompts amid short traffic")
    ap.add_argument("--long-len", type=int, default=8192,
                    help="long-tail prompt length (8k-16k is the target)")
    ap.add_argument("--n-long", type=int, default=2)
    ap.add_argument("--lockstep", action="store_true",
                    help="also replay through the lockstep baseline")
    ap.add_argument("--decode-evict", action="store_true",
                    help="also run the paged-pool decode-eviction "
                         "comparison (concurrency at equal KV bytes)")
    args = ap.parse_args()
    res = bench(args.requests, args.rate, args.policy, args.slots,
                args.seed, warmup=not args.no_warmup,
                long_tail=args.long_tail, long_len=args.long_len,
                n_long=args.n_long, lockstep=args.lockstep)
    print(f"{'engine':10s} {'tok/s':>8s} {'ttft_ms':>9s} {'ttft_p95':>9s} "
          f"{'tpot_ms':>8s} {'tpot_p95':>9s} {'stall_ms':>9s} "
          f"{'compiles':>8s} {'wall_s':>7s} {'step_ms':>8s} {'path':>9s}")
    for name, m in res.items():
        stall = (f"{m['stall_max_ms']:9.1f}"
                 if np.isfinite(m["stall_max_ms"]) else f"{'n/a':>9s}")
        step = (f"{m['decode_step_ms']:8.2f}"
                if "decode_step_ms" in m else f"{'n/a':>8s}")
        print(f"{name:10s} {m['tok_per_s']:8.1f} {m['ttft_mean_ms']:9.1f} "
              f"{m['ttft_p95_ms']:9.1f} {m['tpot_mean_ms']:8.2f} "
              f"{m['tpot_p95_ms']:9.2f} {stall} "
              f"{m.get('compiles', 0):8d} {m['wall_s']:7.2f} "
              f"{step} {m.get('decode_path', 'n/a'):>9s}")
    ratio = (res["chunked"]["tok_per_s"]
             / max(res["bucketed"]["tok_per_s"], 1e-9))
    print(f"chunked/bucketed throughput: {ratio:.2f}x  "
          f"(chunked cache: {res['chunked']['compile_cache']}, "
          f"engine: {res['chunked']['engine_stats']})")
    if args.long_tail:
        print(_verdict(res)[1])
    if args.decode_evict:
        de = bench_decode_evict(args.requests, args.policy, args.seed,
                                warmup=not args.no_warmup)
        for name, m in de.items():
            print(f"{name:12s} {m['tok_per_s']:8.1f} tok/s  concurrency "
                  f"{m['max_concurrency']}  high water "
                  f"{m['high_water_blocks']} blocks  {m['sweeps']} sweeps  "
                  f"{m['blocks_reclaimed']} reclaimed  "
                  f"{m['preemptions']} preemptions")
        print(_decode_evict_verdict(de)[1])


if __name__ == "__main__":
    main()
