"""Serving benchmark: continuous batching vs the lockstep engine on a
Poisson mixed-length trace.

    PYTHONPATH=src python -m benchmarks.bench_serving [--requests 24]

One trace, two engines.  Requests arrive with exponential interarrival
times and prompt lengths drawn from three distinct buckets; both engines
replay the same trace FCFS:

* **lockstep** (the seed engine's contract): a batch must share one prompt
  length, and prefill+decode run to completion before the next batch — it
  can only batch same-length requests that have *already arrived*, so
  mixed traffic degenerates toward batch-1 serves and queued requests wait
  behind whole decode runs.
* **continuous**: bucketed prefill feeds fixed decode slots; finished
  requests retire mid-stream and queued requests take their slots, so the
  decode batch stays full across heterogeneous lengths.

Reported per engine: aggregate throughput (generated tokens / wall) and
per-request TTFT / TPOT percentiles (per-request timing is the point —
the old engine stamped one batch-level TTFT on everyone).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.common.config import EvictionConfig
from repro.configs import get_smoke_config
from repro.core.lookahead import init_lookahead_params
from repro.models import transformer as tf
from repro.serving import ContinuousEngine, Request, ServingEngine

# Heterogeneous lengths (9 distinct values over 3 compile buckets): the
# lockstep engine can only batch *identical* lengths, so realistic length
# spread forces it toward batch-1 serves; the continuous engine pads to
# buckets and keeps its decode slots full regardless.
PROMPT_LENS = (17, 24, 31, 41, 48, 60, 75, 90, 120)
BUCKETS = (32, 64, 128)
MAX_NEW = 16
BUDGET = 16


def make_trace(n_requests: int, rate_hz: float, seed: int, vocab: int):
    """Poisson arrivals, uniform mix over PROMPT_LENS."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, n_requests))
    reqs = []
    for i in range(n_requests):
        n = int(rng.choice(PROMPT_LENS))
        reqs.append(Request(
            uid=i, prompt=rng.integers(0, vocab, n).astype(np.int32),
            max_new_tokens=MAX_NEW, arrival_s=float(arrivals[i])))
    return reqs


def _clone(reqs):
    return [Request(uid=r.uid, prompt=r.prompt,
                    max_new_tokens=r.max_new_tokens, arrival_s=r.arrival_s)
            for r in reqs]


def _metrics(reqs, wall):
    toks = sum(len(r.out_tokens) for r in reqs)
    ttft = np.array([r.ttft_s for r in reqs])
    tpot = np.array([r.tpot_s for r in reqs if r.tpot_s > 0])
    return {
        "wall_s": wall,
        "tok_per_s": toks / wall,
        "ttft_mean_ms": 1e3 * ttft.mean(),
        "ttft_p95_ms": 1e3 * np.percentile(ttft, 95),
        "tpot_mean_ms": 1e3 * tpot.mean() if len(tpot) else 0.0,
    }


def run_lockstep(eng, reqs, *, max_batch=4):
    """FCFS trace replay under the lockstep contract: serve the queue head
    together with every *arrived* request of the same prompt length."""
    queue = sorted(reqs, key=lambda r: r.arrival_s)
    done = []
    t0 = time.perf_counter()
    while queue:
        now = time.perf_counter() - t0
        arrived = [r for r in queue if r.arrival_s <= now]
        if not arrived:
            time.sleep(max(queue[0].arrival_s - now, 0.0))
            continue
        head = arrived[0]
        batch = [r for r in arrived
                 if len(r.prompt) == len(head.prompt)][:max_batch]
        for r in batch:
            queue.remove(r)
        serve_start = time.perf_counter() - t0
        eng.serve(batch)
        serve_end = time.perf_counter() - t0
        for r in batch:
            # r.ttft_s is still serve-relative here: split decode time off
            # first, then rebase TTFT onto the trace clock (queue wait incl.)
            decode_s = serve_end - serve_start - r.ttft_s
            r.tpot_s = decode_s / max(len(r.out_tokens) - 1, 1)
            r.ttft_s = serve_start + r.ttft_s - r.arrival_s
        done += batch
    return _metrics(done, time.perf_counter() - t0)


def run_continuous(eng, reqs):
    t0 = time.perf_counter()
    done = eng.run(reqs)
    wall = time.perf_counter() - t0
    m = _metrics(done, wall)
    m["compile_cache"] = eng.prefill_cache.stats()
    return m


def bench(n_requests=24, rate_hz=20.0, policy="lookaheadkv", slots=4,
          seed=0, warmup=True, report=print):
    cfg = get_smoke_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    lkv = init_lookahead_params(jax.random.PRNGKey(1), cfg, params["layers"])
    trace = make_trace(n_requests, rate_hz, seed, cfg.vocab_size)
    lock_eng = ServingEngine(params, cfg, policy=policy,
                             evict=EvictionConfig(budget=BUDGET),
                             lkv_params=lkv, max_new_tokens=MAX_NEW,
                             eos_id=-1)
    cont_eng = ContinuousEngine(params, cfg, policy=policy,
                                evict=EvictionConfig(budget=BUDGET),
                                lkv_params=lkv, num_slots=slots,
                                buckets=BUCKETS, max_new_tokens=MAX_NEW,
                                eos_id=-1)
    cont_eng.warmup(PROMPT_LENS, batch_sizes=(1, 2, slots))
    if warmup:  # one untimed replay per engine compiles every program
        run_lockstep(lock_eng, _clone(trace))
        run_continuous(cont_eng, _clone(trace))
    lock = run_lockstep(lock_eng, _clone(trace))
    cont = run_continuous(cont_eng, _clone(trace))
    return lock, cont


def run(report):
    """benchmarks.run entry point."""
    lock, cont = bench(report=report)
    for name, m in (("lockstep", lock), ("continuous", cont)):
        report(f"serving/{name}_tok_per_s", None, f"{m['tok_per_s']:.1f}")
        report(f"serving/{name}_ttft_p95_ms", None, f"{m['ttft_p95_ms']:.0f}")
    report("serving/continuous_speedup", None,
           f"{cont['tok_per_s'] / max(lock['tok_per_s'], 1e-9):.2f}x")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=20.0,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--policy", default="lookaheadkv")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-warmup", action="store_true")
    args = ap.parse_args()
    lock, cont = bench(args.requests, args.rate, args.policy, args.slots,
                       args.seed, warmup=not args.no_warmup)
    print(f"{'engine':12s} {'tok/s':>8s} {'ttft_ms':>9s} {'ttft_p95':>9s} "
          f"{'tpot_ms':>8s} {'wall_s':>7s}")
    for name, m in (("lockstep", lock), ("continuous", cont)):
        print(f"{name:12s} {m['tok_per_s']:8.1f} {m['ttft_mean_ms']:9.1f} "
              f"{m['ttft_p95_ms']:9.1f} {m['tpot_mean_ms']:8.2f} "
              f"{m['wall_s']:7.2f}")
    ratio = cont["tok_per_s"] / max(lock["tok_per_s"], 1e-9)
    print(f"continuous/lockstep throughput: {ratio:.2f}x  "
          f"(compile cache: {cont['compile_cache']})")


if __name__ == "__main__":
    main()
