"""CI benchmark smoke gate: run the kernel and serving benchmarks on tiny
CPU configs, write a ``BENCH_ci.json`` artifact, and fail (exit 1) when any
benchmark's own PASS/FAIL verdict fails.

    PYTHONPATH=src python -m benchmarks.ci_smoke [--out BENCH_ci.json]

Gated verdicts:

* ``kernels/vmem_verdict``     — every Pallas tiling's analytic VMEM
  working set (including the fused score kernel) fits v5e's ~16 MB;
* ``serving/longtail_verdict`` — on the compact long-tail trace the
  chunked engine compiles strictly fewer programs than the bucketed
  baseline *and* cuts p95 TPOT;
* ``serving/decode_evict_verdict`` — on a long-generation paged-pool
  trace at equal KV bytes, decode-time eviction sweeps reclaim whole
  blocks mid-generation and lift peak concurrency, with every
  generation still completing at full length;
* ``prefix/reuse_verdict``     — on the Zipf shared-prefix trace the
  radix-trie prompt cache admits a fully cached prompt faster than one
  uncached chunk prefills, with >= 2x aggregate TTFT improvement;
* ``paged/admission_verdict``  — at an equal KV byte budget the paged
  block-pool engine admits >= 1.5x the concurrent requests of the dense
  engine on a mixed-length Zipf trace, p95 TTFT no worse (within the
  CPU dispatch-noise guard);
* ``kernels/paged_decode_verdict`` — the gather-free paged flash-decode
  path stays within the analytic HBM roofline budget (touched bytes
  <= ideal/0.85) at every (B, depth, block_size) point *and* measures
  strictly faster than the dense-gather oracle wherever depth >= 2k;
* ``sharded/scaling_verdict``  — on a forced 8-device host mesh the
  tensor-parallel paged engine's per-shard KV pool bytes scale exactly
  as total/model at model = {2, 4} and every width emits bit-identical
  tokens to single-device serving;
* ``lookahead/quality_verdict`` — the full learning loop (trace harvest
  -> gt_oracle distillation -> checkpoint -> serving load path): the
  trained predictor beats the untrained one on per-(layer, head) oracle
  kept-set overlap over held-out trace records, the distillation loss
  decreases, and the trained checkpoint serves end-to-end through
  ``ServingConfig.lkv_checkpoint``;
* ``obs/overhead_verdict``     — the observability layer is near-free
  and honest: obs-on serving throughput within 3% of obs-off on the CI
  long-tail trace, every admitted request closes a well-nested span
  tree in the emitted trace, and the streaming drift gauge matches the
  offline ``bench_lookahead_quality`` computation on the same records
  to float tolerance (also writes the ``BENCH_obs_metrics.json`` /
  ``BENCH_obs_trace.json`` artifacts).

The JSON artifact carries every reported benchmark row plus the verdict
map, so a red gate links straight to the number that moved.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

# every row name ending in ``_verdict`` gates the job
SUITES = ("benchmarks.bench_kernels", "benchmarks.bench_serving",
          "benchmarks.bench_prefix", "benchmarks.bench_paged",
          "benchmarks.bench_sharded", "benchmarks.bench_lookahead_quality",
          "benchmarks.bench_obs")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_ci.json")
    args = ap.parse_args()

    rows: list[dict] = []
    verdicts: dict[str, str] = {}
    errors: list[str] = []

    def report(name, us, derived=""):
        rows.append({"name": name, "us_per_call": us, "derived": derived})
        if name.endswith("_verdict"):
            verdicts[name] = derived
        print(f"{name},{'' if us is None else f'{us:.1f}'},{derived}",
              flush=True)

    for mod_name in SUITES:
        t0 = time.time()
        try:
            mod = __import__(mod_name, fromlist=["run"])
            mod.run(report)
            report(f"{mod_name}/_suite_seconds", None,
                   f"{time.time() - t0:.1f}")
        except Exception as e:  # a crashed suite is a failed gate
            import traceback

            traceback.print_exc(file=sys.stderr)
            errors.append(f"{mod_name}: {e!r}")

    ok = bool(verdicts) and not errors and all(
        v == "pass" for v in verdicts.values())
    payload = {
        "pass": ok,
        "verdicts": verdicts,
        "errors": errors,
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"\nBENCH_ci: {'PASS' if ok else 'FAIL'} "
          f"verdicts={verdicts} errors={errors} -> {args.out}")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
