"""Observability-layer bench: overhead, trace completeness, drift parity.

The paper's core serving claim is *negligible runtime overhead* for
learned eviction — the observability layer that verifies the claim must
itself be near-free, or its numbers are fiction.  Three checks, one
gated ``obs/overhead_verdict``:

1. **Overhead** — the CI long-tail trace (``bench_serving``'s shape)
   replayed through two identical chunked engines, obs off (no tracer,
   registry only — the always-on cost) vs obs on (span tracer attached,
   which also flips the engine's timers to device-synced mode).  Best-of
   interleaved trials; obs-on throughput must land within
   ``OVERHEAD_BUDGET`` (3%) of obs-off.
2. **Trace completeness** — the obs-on replay's trace must satisfy the
   structural span invariants (``validate_trace``: well-nested, closed,
   monotone per track) and every admitted request must close a full
   span tree: >= 1 ``prefill_chunk``, a ``finalize``, a ``first_token``
   instant, a ``decode`` span, final outcome ``done``.
3. **Drift parity** — a small trace served with a ``DriftMonitor``
   attached; the streaming ``lookahead_drift_overlap`` gauge must match
   an *offline* recomputation on the ring's records — raw
   ``objective.gt_scores`` / ``objective.lookahead_scores`` calls plus
   the shared ``kept_overlaps`` (the ``bench_lookahead_quality``
   machinery) — to within ``DRIFT_TOL``.

Artifacts: ``BENCH_obs_metrics.json`` (the obs-on engine's registry
snapshot) and ``BENCH_obs_trace.json`` (Chrome trace-event JSON — load
it in https://ui.perfetto.dev), uploaded by CI next to ``BENCH_ci.json``.

    PYTHONPATH=src python -m benchmarks.bench_obs
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_serving import (BUDGET, CHUNK, MAX_NEW, PROMPT_LENS,
                                      make_trace)
from benchmarks.common import clone_requests
from repro.common.config import EvictionConfig
from repro.configs import get_smoke_config
from repro.core import objective
from repro.core.lookahead import init_lookahead_params
from repro.models import transformer as tf
from repro.obs import DriftMonitor, TraceRecorder, kept_overlaps
from repro.obs.trace import request_span_trees, validate_trace
from repro.serving import (ChunkingConfig, ContinuousEngine, Request,
                           ServingConfig)

OVERHEAD_BUDGET = 0.03  # obs-on tok/s within 3% of obs-off
DRIFT_TOL = 1e-6  # streaming gauge vs offline recomputation
SLOTS = 4

METRICS_OUT = "BENCH_obs_metrics.json"
TRACE_OUT = "BENCH_obs_trace.json"


def _engine(params, cfg, lkv, **obs):
    sc = ServingConfig(
        policy="lookaheadkv", evict=EvictionConfig(budget=BUDGET),
        chunking=ChunkingConfig(chunk=CHUNK,
                                max_context=max(PROMPT_LENS) + CHUNK),
        num_slots=SLOTS, max_new_tokens=MAX_NEW, eos_id=-1, **obs)
    return ContinuousEngine(params, cfg, sc, lkv_params=lkv)


def _tree_complete(trees) -> bool:
    """A served request's span forest ends in a closed ``done`` tree
    carrying the full phase skeleton."""
    if not trees or trees[-1]["end_args"].get("outcome") != "done":
        return False
    names = [n["name"] for t in trees for n in _nodes(t)]
    instants = [i["name"] for t in trees for n in _nodes(t)
                for i in n["instants"]]
    return ("prefill_chunk" in names and "finalize" in names
            and "decode" in names and "first_token" in instants)


def _nodes(tree):
    yield tree
    for c in tree["children"]:
        yield from _nodes(c)


def bench_overhead(params, cfg, lkv, *, n_requests=12, rate_hz=20.0,
                   seed=0, trials=2):
    """Obs-off vs obs-on replays of the CI long-tail trace.  Returns the
    per-config metrics plus the final obs-on engine + trace (for the
    completeness check and the artifacts)."""
    trace = make_trace(n_requests, rate_hz, seed, cfg.vocab_size,
                       long_tail=True, long_len=2048, n_long=1)
    eng_off = _engine(params, cfg, lkv)
    eng_on = _engine(params, cfg, lkv, trace=TraceRecorder())
    for eng in (eng_off, eng_on):
        eng.run(clone_requests(trace))  # compile off the clock
    res = {"obs_off": {"tok_per_s": 0.0}, "obs_on": {"tok_per_s": 0.0}}
    last_trace, last_done = None, None
    # trials interleave off/on so a host load spike hits both; best-of
    # damps the one-sided noise a shared CI runner adds
    for _ in range(trials):
        for name, eng in (("obs_off", eng_off), ("obs_on", eng_on)):
            if name == "obs_on":
                eng.set_trace(TraceRecorder())  # fresh trace per replay
            t0 = time.perf_counter()
            done = eng.run(clone_requests(trace))
            wall = time.perf_counter() - t0
            tps = sum(len(r.out_tokens) for r in done) / wall
            res[name]["tok_per_s"] = max(res[name]["tok_per_s"], tps)
            res[name]["wall_s"] = wall
            if name == "obs_on":
                last_trace, last_done = eng.trace, done
    summary = validate_trace(last_trace)  # raises on a broken trace
    complete = all(
        _tree_complete(request_span_trees(last_trace, r.uid))
        for r in last_done)
    res["trace"] = {"complete": complete, "requests": len(last_done),
                    **summary}
    return res, eng_on, last_trace


def bench_drift(params, cfg, lkv, *, seed=1):
    """Serve a small trace with a ``DriftMonitor`` riding the retirement
    hook, then recompute the overlap offline on the ring's records."""
    rng = np.random.default_rng(seed)
    lens = (41, 48, 60, 41)  # > BUDGET so the kept set is non-vacuous
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        n).astype(np.int32),
                    max_new_tokens=8, arrival_s=0.01 * i)
            for i, n in enumerate(lens)]
    mon = DriftMonitor(params, cfg, lkv, budget=BUDGET, ring_size=8,
                       sample_every=1, eval_every=10_000)  # eval after run
    eng = _engine(params, cfg, lkv, drift=mon)
    eng.run([r.clone() for r in reqs])
    online = mon.evaluate()
    gauge = eng.metrics.value("lookahead_drift_overlap")
    # offline recomputation: raw objective calls + the shared kept-set
    # machinery — no DriftMonitor code on this side of the comparison
    ovs: list[float] = []
    for x, y in mon._ring:
        xy = jnp.asarray(np.concatenate([x, y]))[None]
        gt = np.asarray(
            objective.gt_scores(params, cfg, xy, len(x))[:, 0], np.float32)
        pred = np.asarray(
            objective.lookahead_scores(params, cfg, lkv,
                                       jnp.asarray(x)[None])[:, 0],
            np.float32)
        ovs.extend(kept_overlaps(pred, gt, BUDGET))
    offline = float(np.mean(ovs))
    return {"online": online, "gauge": gauge, "offline": offline,
            "records": len(mon._ring), "abs_err": abs(online - offline)}


def _verdict(res, drift) -> tuple[bool, str]:
    off, on = res["obs_off"]["tok_per_s"], res["obs_on"]["tok_per_s"]
    within = on >= off * (1.0 - OVERHEAD_BUDGET)
    complete = res["trace"]["complete"]
    parity = (drift["abs_err"] <= DRIFT_TOL
              and abs(drift["gauge"] - drift["online"]) <= DRIFT_TOL)
    ok = within and complete and parity
    return ok, (
        f"{'PASS' if ok else 'FAIL'}: obs-on {on:.1f} tok/s vs obs-off "
        f"{off:.1f} ({100 * (1 - on / max(off, 1e-9)):+.1f}% overhead, "
        f"budget {100 * OVERHEAD_BUDGET:.0f}%, "
        f"{'within' if within else 'OVER'}); span trees "
        f"{'complete' if complete else 'INCOMPLETE'} over "
        f"{res['trace']['requests']} requests "
        f"({res['trace']['spans']} spans); drift gauge "
        f"{drift['gauge']:.6f} vs offline {drift['offline']:.6f} "
        f"(|err| {drift['abs_err']:.2e}, "
        f"{'parity' if parity else 'DIVERGED'})")


def bench(*, n_requests=12, trials=2, seed=0):
    cfg = get_smoke_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    lkv = init_lookahead_params(jax.random.PRNGKey(1), cfg, params["layers"])
    res, eng_on, trace = bench_overhead(params, cfg, lkv,
                                        n_requests=n_requests, seed=seed,
                                        trials=trials)
    drift = bench_drift(params, cfg, lkv, seed=seed + 1)
    return res, drift, eng_on, trace


def run(report):
    """benchmarks.run / ci_smoke entry point."""
    res, drift, eng_on, trace = bench()
    eng_on.metrics.to_json(METRICS_OUT)
    trace.to_chrome(TRACE_OUT)
    off, on = res["obs_off"]["tok_per_s"], res["obs_on"]["tok_per_s"]
    report("obs/off_tok_per_s", None, f"{off:.1f}")
    report("obs/on_tok_per_s", None, f"{on:.1f}")
    report("obs/overhead_pct", None,
           f"{100 * (1 - on / max(off, 1e-9)):+.1f}")
    report("obs/trace_spans", None, str(res["trace"]["spans"]))
    report("obs/trace_events", None, str(res["trace"]["events"]))
    report("obs/drift_overlap", None, f"{drift['gauge']:.4f}")
    report("obs/drift_abs_err", None, f"{drift['abs_err']:.2e}")
    ok, verdict = _verdict(res, drift)
    print(verdict)
    report("obs/overhead_verdict", None, "pass" if ok else "fail")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--trials", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    res, drift, eng_on, trace = bench(n_requests=args.requests,
                                      trials=args.trials, seed=args.seed)
    eng_on.metrics.to_json(METRICS_OUT)
    trace.to_chrome(TRACE_OUT)
    for name in ("obs_off", "obs_on"):
        m = res[name]
        print(f"{name:8s} {m['tok_per_s']:8.1f} tok/s  "
              f"wall {m['wall_s']:.2f}s")
    t = res["trace"]
    print(f"trace: {t['events']} events, {t['spans']} spans over "
          f"{t['tracks']} tracks; complete={t['complete']}")
    print(f"drift: gauge {drift['gauge']:.6f} offline "
          f"{drift['offline']:.6f} over {drift['records']} records")
    print(_verdict(res, drift)[1])
    print(f"artifacts: {METRICS_OUT}, {TRACE_OUT}")


if __name__ == "__main__":
    main()
