"""Shared benchmark scaffolding: a small trained model cached across
benchmark modules (training once keeps `python -m benchmarks.run` tractable
on the 1-core CPU container), timing helpers, metric utilities
(recall@k, Kendall's τ — the paper's Table 8 metrics), and the serving
trace/report helpers the serving benches share (Poisson/Zipf request
traces, TTFT rows, decode-step stats)."""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import EvictionConfig, TrainConfig
from repro.configs import get_smoke_config
from repro.core import objective
from repro.core.lookahead import init_lookahead_params
from repro.data import synthetic
from repro.models import transformer as tf
from repro.optim import adam

N_IN, N_OUT = 96, 16
BATCH = 4


@functools.lru_cache(maxsize=4)
def trained_model(arch: str = "smollm-135m", steps: int = 120,
                  n_lookahead: int | None = None, lora_mode: str = "all",
                  seed: int = 0):
    """(cfg, params, lkv) with lookahead modules trained on the synthetic
    mixture.  lora_mode: all | qv | emb-only (Table 5 ablation axes)."""
    import dataclasses

    cfg = get_smoke_config(arch)
    lk = cfg.lookahead
    if n_lookahead is not None or lora_mode != "all":
        targets = lk.lora_targets
        if lora_mode == "qv":
            targets = ("wq", "wv")
        elif lora_mode == "emb-only":
            targets = ()
        lk = dataclasses.replace(
            lk, n_lookahead=n_lookahead or lk.n_lookahead,
            lora_targets=targets)
        cfg = dataclasses.replace(cfg, lookahead=lk)
    key = jax.random.PRNGKey(seed)
    params = tf.init_params(key, cfg)
    lkv = init_lookahead_params(jax.random.PRNGKey(seed + 1), cfg,
                                params["layers"])
    tc = TrainConfig(steps=steps, lr=1e-3, warmup_frac=0.05)
    it = synthetic.MixtureIterator(cfg, BATCH, N_IN, N_OUT, seed=seed)

    @jax.jit
    def step(lkv, opt, x, xy):
        def loss_fn(l):
            return objective.lkv_loss(params, cfg, l, x, xy, x.shape[1])[0]

        loss, grads = jax.value_and_grad(loss_fn)(lkv)
        lkv, opt, _ = adam.update(lkv, grads, opt, tc)
        return lkv, opt, loss

    opt = adam.init(lkv)
    for _ in range(steps):
        b = next(it)
        x = jnp.asarray(b.x)
        xy = jnp.concatenate([x, jnp.asarray(b.y)], axis=1)
        lkv, opt, loss = step(lkv, opt, x, xy)
    return cfg, params, lkv, float(loss)


def recall_at_k(s_pred, s_gt, k: int) -> float:
    _, tp = jax.lax.top_k(s_pred, k)
    _, tg = jax.lax.top_k(s_gt, k)
    hits = (tp[..., :, None] == tg[..., None, :]).any(-1).sum(-1)
    return float(jnp.mean(hits / k))


def kendall_tau(s_pred, s_gt, samples: int = 2000, seed: int = 0) -> float:
    """Sampled Kendall rank correlation over the key axis."""
    rng = np.random.default_rng(seed)
    p = np.asarray(s_pred, np.float64).reshape(-1, s_pred.shape[-1])
    g = np.asarray(s_gt, np.float64).reshape(-1, s_gt.shape[-1])
    n = p.shape[-1]
    i = rng.integers(0, n, samples)
    j = rng.integers(0, n, samples)
    ok = i != j
    i, j = i[ok], j[ok]
    sp = np.sign(p[:, i] - p[:, j])
    sg = np.sign(g[:, i] - g[:, j])
    return float((sp * sg).mean())


def time_call(fn, *args, iters: int = 3, **kw) -> float:
    """Median wall-time (µs) of a jitted call (post-warmup)."""
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def eval_batch(cfg, seed: int = 1234, batch: int = BATCH):
    it = synthetic.MixtureIterator(cfg, batch, N_IN, N_OUT, seed=seed)
    b = next(it)
    x = jnp.asarray(b.x)
    xy = jnp.concatenate([x, jnp.asarray(b.y)], axis=1)
    return b, x, xy


# --- serving-bench trace + report helpers (shared by bench_serving /
# bench_paged / bench_prefix / bench_sharded) ---


def make_poisson_trace(n_requests: int, vocab: int, prompt_lens, *,
                       seed: int, max_new: int, rate_hz: float = None,
                       gap_s: float = None, zipf: bool = False,
                       long_uids=frozenset(), long_len: int = 8192):
    """Poisson-arrival random-token ``Request`` trace.

    Prompt lengths are drawn from ``prompt_lens`` — uniformly, or
    Zipf-weighted by rank (``zipf=True``: mostly short, a tail of longer
    ones).  ``long_uids`` plants ``long_len``-token prompts at those uids
    (the long-tail shape that breaks bucketed serving).  Arrival gaps are
    exponential with mean ``1/rate_hz`` (or ``gap_s`` directly).
    """
    from repro.serving import Request

    assert (rate_hz is None) != (gap_s is None), \
        "pass exactly one of rate_hz / gap_s"
    rng = np.random.default_rng(seed)
    scale = gap_s if gap_s is not None else 1.0 / rate_hz
    arrivals = np.cumsum(rng.exponential(scale, n_requests))
    lens_arr = np.asarray(prompt_lens)
    if zipf:
        w = 1.0 / np.arange(1, len(lens_arr) + 1)
        lens = rng.choice(lens_arr, size=n_requests, p=w / w.sum())
    else:
        lens = rng.choice(lens_arr, size=n_requests)
    return [
        Request(uid=i,
                prompt=rng.integers(
                    0, vocab,
                    long_len if i in long_uids else int(lens[i])
                ).astype(np.int32),
                max_new_tokens=max_new, arrival_s=float(arrivals[i]))
        for i in range(n_requests)
    ]


def clone_requests(reqs):
    return [r.clone() for r in reqs]


def ttft_stats(done) -> dict:
    """Mean / p95 time-to-first-token over finished requests (ms)."""
    t = np.array([r.ttft_s for r in done])
    return {"ttft_mean_ms": 1e3 * float(t.mean()),
            "ttft_p95_ms": 1e3 * float(np.percentile(t, 95))}


def engine_stats(eng) -> dict:
    """The engine's per-run stats as a plain dict, read from the typed
    metrics registry when the engine has one (``ContinuousEngine``) and
    from the legacy ``stats`` dict otherwise (deprecated engines) — the
    benches' one accessor, so none of them reaches into engine
    internals."""
    if getattr(eng, "metrics", None) is not None:
        from repro.serving.engine import _LegacyStatsView
        return _LegacyStatsView(eng)._as_dict()
    return dict(eng.stats)


def decode_step_stats(eng) -> dict:
    """Per-token decode step wall cost and the dispatch tier that served
    it (kernel / gather / fallback / dense) — read from the engine's
    metrics registry (legacy dict on the deprecated engines)."""
    s = engine_stats(eng)
    steps = max(s.get("decode_steps", 0), 1)
    return {
        "decode_step_ms": 1e3 * s.get("decode_time_s", 0.0) / steps,
        "decode_path": s.get("decode_path", "dense"),
    }


def report_rows(report, prefix: str, rows: dict):
    """Emit ``{prefix}/{key} -> value`` rows through a ci_smoke/run
    ``report`` callback (values pre-formatted strings)."""
    for key, val in rows.items():
        report(f"{prefix}/{key}", None, val)
