"""Paper Table 5: 2-D ablation over lookahead size × trainable modules.

Axes: n_lookahead ∈ {4, 8, 16, 32} × modules ∈ {emb-only, qv, all}.
Metric: recall@k of predicted vs GT scores after a short training run, plus
the eviction-time overhead (extra forward rows, analytic %).
Expected (paper): both axes help; saturation in lookahead size; "all" LoRA
placement is worth a small latency premium.
"""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import (N_IN, eval_batch, recall_at_k, trained_model)
from repro.core import objective

SIZES = (4, 8, 16)
MODES = ("emb-only", "qv", "all")


def run(report):
    for mode in MODES:
        for n_look in SIZES:
            cfg, params, lkv, final_loss = trained_model(
                n_lookahead=n_look, lora_mode=mode, steps=80)
            b, x, xy = eval_batch(cfg)
            s_gt = objective.gt_scores(params, cfg, xy, x.shape[1])
            s_pred = objective.lookahead_scores(params, cfg, lkv, x)
            r = recall_at_k(s_pred, s_gt, k=16)
            overhead = 100.0 * n_look / N_IN  # extra prefill rows
            report(f"ablation/{mode}/n{n_look}", None,
                   f"recall@16={r:.3f} kl={final_loss:.4f} "
                   f"overhead~{overhead:.1f}%")
