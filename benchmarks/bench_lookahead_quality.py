"""The learning loop, measured: harvest -> distill -> serve -> quality.

Runs the paper's full training story end-to-end at smoke scale and gates
it (``lookahead/quality_verdict``):

1. **Harvest** — a Zipf-prefix / Poisson-arrival trace is served through
   ``ContinuousEngine`` with the gt_oracle capture hook
   (``data/harvest.py``): each retired request's prompt is scored by its
   *generated* continuation under the frozen model.
2. **Distill** — ``launch/train.py --harvest`` trains the LoRA tree +
   lookahead tokens against the harvested targets and writes a trainer
   checkpoint.
3. **Serve** — the checkpoint loads back through
   ``ServingConfig.lkv_checkpoint`` and serves the lookaheadkv policy
   end-to-end.
4. **Quality** — on *held-out* trace records (fresh seed, real generated
   futures), the trained predictor's per-(layer, head) kept set — the
   top-``budget`` of its raw scores, what the KL objective distills —
   must overlap the gt_oracle kept set more than the untrained
   (random-init) tree's; the full eviction pipeline's kept-set overlap
   (GQA-reduced + pooled, per KV head) and downstream needle-survival
   deltas vs snapkv/h2o ride along as reported rows.

The gate evaluates the budget-relevant band (prompts up to ~3x the
largest budget, where most Zipf trace traffic lives); overlap on the
long-record tail is reported ungated — at smoke scale (2 layers, 512
vocab, a few dozen harvested records) the predictor does not yet
generalize past its training horizon, and gating on that tail would
measure data volume, not the learning loop.  Likewise the pipeline-level
overlap is reported, not gated: with 1 KV head per layer the
GQA+maxpool reduction leaves too few independent kept sets for a stable
comparison at this scale.

Verdict: trained > untrained on per-(layer, head) oracle overlap AND the
distillation loss decreased AND serving through the checkpoint completed
every generation.
"""

from __future__ import annotations

import os
import tempfile
from collections import defaultdict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_accuracy import _kept_sets, _needle_survival, _overlap
from repro.common.config import EvictionConfig
from repro.configs import get_smoke_config
from repro.core import objective, policies
from repro.core.lookahead import init_lookahead_params, load_lookahead_params
from repro.data import harvest, synthetic
from repro.launch import train as train_mod
from repro.models import transformer as tf
from repro.serving import (ChunkingConfig, ContinuousEngine, Request,
                           ServingConfig)

ARCH = "smollm-135m"
SEED = 0
CHUNK = 32
MAX_NEW = 12
HARVEST_REQUESTS = 48
HELDOUT_REQUESTS = 24
DISTILL_STEPS = 200
BUDGETS = (16, 24)
NEEDLE_BUDGET = 24
# the gated band: prompts between the largest budget (eviction must bite)
# and ~3x it (within the harvested training horizon)
GATE_LEN = (32, 80)


def _eval_batches(records, min_len: int, max_len: int = 10**9,
                  max_batch: int = 8):
    """Same-length (x, xy) eval batches from held-out harvest records in a
    prompt-length band, with their *real* generated futures as the oracle's
    observation."""
    groups = defaultdict(list)
    for r in records:
        if min_len <= len(r["x"]) <= max_len:
            groups[(len(r["x"]), len(r["y"]))].append(r)
    batches = []
    for (n_in, _), rs in sorted(groups.items(),
                                key=lambda kv: -len(kv[1])):
        rs = rs[:max_batch]
        x = jnp.asarray(np.stack([r["x"] for r in rs]))
        xy = jnp.concatenate(
            [x, jnp.asarray(np.stack([r["y"] for r in rs]))], axis=1)
        batches.append((x, xy))
    return batches


# Per-(layer, head) top-``budget`` kept set of a raw score tensor (L, H, n)
# — the predictor's selection before GQA pooling, the quantity the
# distillation objective actually trains.  Shared with the serving drift
# monitor so the online gauge and this offline bench agree by construction.
from repro.obs.quality import head_kept_sets as _head_kept_sets  # noqa: E402


def _predicted_scores(params, cfg, trees, records):
    """Per-record raw lookahead scores (L, H, n) for each named tree,
    batched by prompt length (one compile per distinct length)."""
    groups = defaultdict(list)
    for i, r in enumerate(records):
        groups[len(r["x"])].append(i)
    out = {name: [None] * len(records) for name in trees}
    for _, idxs in sorted(groups.items()):
        x = jnp.asarray(np.stack([records[i]["x"] for i in idxs]))
        for name, lkv in trees.items():
            s = np.asarray(objective.lookahead_scores(params, cfg, lkv, x))
            for j, i in enumerate(idxs):
                out[name][i] = s[:, j]
    return out


def _overlap_vs_oracle(params, cfg, batches, ev, trees):
    """Mean (and per-layer) kept-set overlap with the gt_oracle kept set
    for each named lkv tree, the oracle pass computed once per batch."""
    ovs = {name: [] for name in trees}
    per_layer: dict = {name: defaultdict(list) for name in trees}
    for x, xy in batches:
        gt = tf.prefill(params, cfg, xy, policy="gt_oracle",
                        gt_boundary=x.shape[1], evict=ev)
        gt_sets = _kept_sets(gt.cache)
        for name, lkv in trees.items():
            res = policies.run_eviction("lookaheadkv", params, cfg, x,
                                        evict=ev, lkv_params=lkv)
            sets = _kept_sets(res.cache)
            ovs[name].append(_overlap(sets, gt_sets))
            for (layer, b, h), g in gt_sets.items():
                per_layer[name][layer].append(
                    len(sets[(layer, b, h)] & g) / max(len(g), 1))
    return ({name: float(np.mean(v)) for name, v in ovs.items()},
            {name: {k: float(np.mean(v)) for k, v in sorted(d.items())}
             for name, d in per_layer.items()})


def run(report):
    cfg = get_smoke_config(ARCH)
    params = tf.init_params(jax.random.PRNGKey(SEED), cfg)
    tmp = tempfile.mkdtemp(prefix="lkv_quality_")
    hdir, ck = os.path.join(tmp, "data"), os.path.join(tmp, "lkv.npz")

    # 1) harvest a served trace
    w = harvest.harvest_trace(
        params, cfg, out_dir=hdir, requests=HARVEST_REQUESTS, policy="h2o",
        budget=64, chunk=CHUNK, max_new=MAX_NEW, max_obs=MAX_NEW,
        num_slots=4, seed=11)
    report("lookahead/harvest_records", None, str(w.records_written))

    # 2) distill against the harvested targets (same seed as the engine's
    # model init, so the checkpoint matches `params` at serve time)
    out = train_mod.main([
        "--arch", ARCH, "--smoke", "--harvest", hdir,
        "--steps", str(DISTILL_STEPS), "--batch", "4",
        "--ckpt", ck, "--ckpt-every", "50", "--seed", str(SEED)])
    losses = out["losses"]
    loss_decreased = losses[-1] < losses[0]
    report("lookahead/distill_loss", None,
           f"first={losses[0]:.4f} last={losses[-1]:.4f}")

    # 3) serve the trained checkpoint end-to-end via ServingConfig
    rng = np.random.default_rng(7)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, int(n))
                    .astype(np.int32),
                    max_new_tokens=MAX_NEW)
            for i, n in enumerate((96, 64, 112, 80))]
    sc = ServingConfig(
        policy="lookaheadkv", evict=EvictionConfig(budget=32, draft_len=8),
        chunking=ChunkingConfig(chunk=CHUNK, max_context=128),
        num_slots=2, max_new_tokens=MAX_NEW, eos_id=-1, lkv_checkpoint=ck)
    eng = ContinuousEngine(params, cfg, sc)
    done = eng.run(reqs)
    served_ok = (len(done) == len(reqs)
                 and all(len(r.out_tokens) == MAX_NEW for r in done))
    report("lookahead/serve_ttft_ms", None,
           f"{1e3 * float(np.mean([r.ttft_s for r in done])):.1f}")

    # 4) trained vs untrained oracle-overlap on held-out trace records
    heldout = os.path.join(tmp, "heldout")
    harvest.harvest_trace(
        params, cfg, out_dir=heldout, requests=HELDOUT_REQUESTS,
        policy="h2o", budget=64, chunk=CHUNK, max_new=MAX_NEW,
        max_obs=MAX_NEW, num_slots=4, seed=23)
    records = harvest.load_records(heldout)
    trained = load_lookahead_params(ck, cfg, params["layers"])
    untrained = init_lookahead_params(jax.random.PRNGKey(SEED + 1), cfg,
                                      params["layers"])
    trees = {"trained": trained, "untrained": untrained}
    pred = _predicted_scores(params, cfg, trees, records)

    gate_t, gate_u = [], []
    for budget in BUDGETS:
        ovs = {n: defaultdict(list) for n in trees}
        layer_ovs = {n: defaultdict(list) for n in trees}
        for i, r in enumerate(records):
            n_in = len(r["x"])
            if n_in <= budget:
                continue
            band = ("band" if GATE_LEN[0] <= n_in <= GATE_LEN[1]
                    else "tail")
            gt_sets = _head_kept_sets(r["s"], budget)
            for name in trees:
                sets = _head_kept_sets(pred[name][i], budget)
                for key, g in gt_sets.items():
                    ov = len(sets[key] & g) / budget
                    ovs[name][band].append(ov)
                    if band == "band":
                        layer_ovs[name][key[0]].append(ov)
        t = float(np.mean(ovs["trained"]["band"]))
        u = float(np.mean(ovs["untrained"]["band"]))
        layers = " ".join(
            f"L{k}:{np.mean(layer_ovs['trained'][k]):.3f}vs"
            f"{np.mean(layer_ovs['untrained'][k]):.3f}"
            for k in sorted(layer_ovs["trained"]))
        report(f"lookahead/oracle_overlap/b{budget}", None,
               f"trained={t:.3f} untrained={u:.3f} "
               f"(n={len(ovs['trained']['band'])}) [{layers}]")
        gate_t.append(t)
        gate_u.append(u)
        if ovs["trained"]["tail"]:  # past the training horizon: ungated
            report(f"lookahead/oracle_overlap_longtail/b{budget}", None,
                   f"trained={np.mean(ovs['trained']['tail']):.3f} "
                   f"untrained={np.mean(ovs['untrained']['tail']):.3f}")

    # full eviction pipeline (GQA-reduced, pooled, per KV head) through the
    # real prefill+evict path — reported, not gated (see module docstring)
    batches = _eval_batches(records, *GATE_LEN)
    ev = EvictionConfig(budget=BUDGETS[-1], draft_len=8)
    pvs, _ = _overlap_vs_oracle(params, cfg, batches, ev, trees)
    report(f"lookahead/pipeline_overlap/b{BUDGETS[-1]}", None,
           f"trained={pvs['trained']:.3f} untrained={pvs['untrained']:.3f}")

    # downstream deltas vs the heuristic baselines (end-task proxy)
    nb = synthetic.make_needle_batch(np.random.default_rng(5), 4, 96,
                                     cfg.vocab_size)
    nx = jnp.asarray(nb.x)
    ev = EvictionConfig(budget=NEEDLE_BUDGET, draft_len=8)
    for m, lkv in (("snapkv", None), ("h2o", None),
                   ("lookaheadkv_untrained", untrained),
                   ("lookaheadkv_trained", trained)):
        res = policies.run_eviction(m.split("_")[0], params, cfg, nx,
                                    evict=ev, lkv_params=lkv)
        surv = _needle_survival(res.cache, nb.answer_pos)
        report(f"lookahead/needle/{m}/b{NEEDLE_BUDGET}", None, f"{surv:.3f}")

    # long-form deltas (bench_longform's Fig. 5 proxy): pipeline kept-set
    # overlap vs a LONG teacher-forced future, harvest-trained tree riding
    lf = next(synthetic.MixtureIterator(cfg, 4, 96, 48, seed=148))
    lx = jnp.asarray(lf.x)
    lxy = jnp.concatenate([lx, jnp.asarray(lf.y)], axis=1)
    ev = EvictionConfig(budget=16, draft_len=8)
    gt = tf.prefill(params, cfg, lxy, policy="gt_oracle",
                    gt_boundary=lx.shape[1], evict=ev)
    gt_sets = _kept_sets(gt.cache)
    for m, lkv in (("snapkv", None), ("h2o", None),
                   ("lookaheadkv_untrained", untrained),
                   ("lookaheadkv_trained", trained)):
        res = policies.run_eviction(m.split("_")[0], params, cfg, lx,
                                    evict=ev, lkv_params=lkv)
        ov = _overlap(_kept_sets(res.cache), gt_sets)
        report(f"lookahead/longform_overlap/{m}/n48", None, f"{ov:.3f}")

    # gate on the mean over the budget sweep (single-budget kept sets on
    # the 2-layer / 1-kv-head smoke model are noisy)
    ov_t, ov_u = float(np.mean(gate_t)), float(np.mean(gate_u))
    ok = ov_t > ov_u and loss_decreased and served_ok
    report("lookahead/quality_verdict", None, "pass" if ok else (
        f"fail: overlap trained={ov_t:.3f} untrained={ov_u:.3f} "
        f"loss_decreased={loss_decreased} served_ok={served_ok}"))


if __name__ == "__main__":
    def report(name, us, derived=""):
        print(f"{name},{'' if us is None else f'{us:.1f}'},{derived}")

    run(report)
