"""Paper Fig. 4 / Tables 9–14 (proxy): eviction quality across methods ×
budgets.

Without pretrained weights, absolute LongBench scores are not reproducible;
the *orderings* the paper claims are.  Two measures per (method, budget):

  gt_overlap — mean per-head overlap of the kept set with the GT-oracle
               kept set (the quantity eviction is optimizing);
  needle_acc — teacher-forced needle retention: fraction of needle-value
               positions that survive eviction (end-task proxy).

Expected ordering (paper): lookaheadkv > {laq} > snapkv/pyramidkv >
streaming_llm ≈ random, gaps widening at small budgets.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import eval_batch, trained_model
from repro.common.config import EvictionConfig
from repro.core import policies
from repro.data import synthetic
from repro.models import transformer as tf

METHODS = ("random", "streaming_llm", "snapkv", "pyramidkv", "tova",
           "laq", "lookaheadkv")
BUDGETS = (8, 16, 32, 64)


def _kept_sets(cache):
    pos = np.asarray(cache["attn"]["pos"])
    mask = np.asarray(cache["attn"]["mask"])
    L, B, C, KV = pos.shape
    out = {}
    for l in range(L):
        for b in range(B):
            for h in range(KV):
                out[(l, b, h)] = set(pos[l, b, mask[l, b, :, h], h].tolist())
    return out


def _overlap(a: dict, g: dict) -> float:
    return float(np.mean([
        len(a[k] & g[k]) / max(len(g[k]), 1) for k in g
    ]))


def _needle_survival(cache, answer_pos) -> float:
    pos = np.asarray(cache["attn"]["pos"])
    mask = np.asarray(cache["attn"]["mask"])
    L, B, C, KV = pos.shape
    surv = []
    for b in range(B):
        want = set(answer_pos[b].tolist())
        for l in range(L):
            for h in range(KV):
                kept = set(pos[l, b, mask[l, b, :, h], h].tolist())
                surv.append(len(want & kept) / len(want))
    return float(np.mean(surv))


def run(report):
    cfg, params, lkv, _ = trained_model()
    b, x, xy = eval_batch(cfg)
    rng = np.random.default_rng(5)
    nb = synthetic.make_needle_batch(rng, 4, 96, cfg.vocab_size)
    nx = jnp.asarray(nb.x)
    nxy = jnp.concatenate([nx, jnp.asarray(nb.y)], axis=1)

    for budget in BUDGETS:
        ev = EvictionConfig(budget=budget, draft_len=8)
        gt = tf.prefill(params, cfg, xy, policy="gt_oracle",
                        gt_boundary=x.shape[1], evict=ev)
        gt_sets = _kept_sets(gt.cache)
        for m in METHODS:
            res = policies.run_eviction(m, params, cfg, x, evict=ev,
                                        lkv_params=lkv)
            ov = _overlap(_kept_sets(res.cache), gt_sets)
            nres = policies.run_eviction(m, params, cfg, nx, evict=ev,
                                         lkv_params=lkv)
            acc = _needle_survival(nres.cache, nb.answer_pos)
            report(f"accuracy/{m}/b{budget}", None,
                   f"gt_overlap={ov:.3f} needle_survival={acc:.3f}")
