"""Paged-KV serving benchmark: concurrency at a fixed device-byte budget.

    PYTHONPATH=src python -m benchmarks.bench_paged [--trials 2]

The claim under test is the whole point of ``serving/kv_pool.py``: at an
*equal KV byte budget*, eviction-freed blocks let the paged engine admit
strictly more concurrent requests than the dense engine — because a dense
slot reserves ``capacity + margin`` rows for its whole lifetime while a
paged request only holds blocks for the rows it actually uses (kept
post-eviction rows plus decode appends), and retiring requests return
their blocks to the pool for the next admission.

Setup: one mixed-length Zipf trace (mostly short prompts, a tail of
longer ones — the shape where eviction frees the most memory) replayed
through two ``ContinuousEngine`` configurations whose decode KV gets the
same byte budget:

* **dense** — the budget buys ``DENSE_SLOTS`` dense slots;
* **paged** — the same bytes become a ``KVBlockPool``; admission is gated
  by free blocks (append growth reserved at admission, so no preemption
  churn), with more scheduler slots than the dense engine can afford.

Verdict (machine-readable, gated in ``benchmarks/ci_smoke.py``):

* peak admitted concurrency: paged ≥ ``CONC_RATIO``× dense;
* p95 TTFT no worse, within a ``TTFT_NOISE`` dispatch-noise guard — on
  this compute-bound CPU host extra concurrency cannot make tokens
  arrive faster (total FLOPs/s is the binding constraint; per-token cost
  is already *lower* paged: wider decode batches amortize dispatch), so
  the gate checks paging adds no latency penalty beyond noise.  On a
  memory-bound accelerator the freed bytes are the throughput headroom.

Tokens are not checked here — bit-identity of paged vs dense serving is
``tests/test_kv_pool.py``'s job.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (decode_step_stats, engine_stats,
                               make_poisson_trace, ttft_stats)
from repro.common.config import EvictionConfig
from repro.configs import get_smoke_config
from repro.core.lookahead import init_lookahead_params
from repro.models import transformer as tf
from repro.serving import ContinuousEngine, KVBlockPool

BUDGET = 64  # eviction budget (large vs the short prompts: kept = prompt)
MAX_NEW = 40  # long decodes keep slots busy -> dense is slot-bound
BLOCK = 4  # pool block size (rows): fine blocks cut fragmentation
CHUNK = 32
DENSE_SLOTS = 4  # the byte budget = exactly this many dense slots
PAGED_SLOTS = 7
N_REQUESTS = 40
ARRIVAL_GAP_S = 0.003  # near-burst offered load
# Zipf-weighted prompt lengths: mostly short (few kept rows), some long
PROMPT_LENS = (8, 12, 16, 24, 32, 48)
CONC_RATIO = 1.5
TTFT_NOISE = 1.25  # CPU dispatch-noise guard on the "no worse" gate


def make_trace(seed: int, vocab: int):
    return make_poisson_trace(N_REQUESTS, vocab, PROMPT_LENS, seed=seed,
                              max_new=MAX_NEW, gap_s=ARRIVAL_GAP_S,
                              zipf=True)


def _byte_budget(cfg, evict) -> tuple[int, int]:
    """(pool block count, dense-equivalent slot bytes) at equal budget."""
    cap = tf.decode_cache_capacity(cfg, "lookaheadkv", evict,
                                   n_keys_max=1 << 30)
    depth = cap + MAX_NEW + 1
    per_row = 2 * cfg.num_layers * cfg.attn.kv_dim \
        * jnp.dtype(cfg.dtype).itemsize
    block_bytes = BLOCK * per_row
    n_blocks = DENSE_SLOTS * depth * per_row // block_bytes
    return int(n_blocks), depth * per_row


def bench(seed: int = 0, trials: int = 3):
    cfg = get_smoke_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    lkv = init_lookahead_params(jax.random.PRNGKey(1), cfg,
                                params["layers"])
    evict = EvictionConfig(budget=BUDGET)
    n_blocks, _ = _byte_budget(cfg, evict)
    kw = dict(policy="lookaheadkv", evict=evict, lkv_params=lkv,
              chunk=CHUNK, max_context=max(PROMPT_LENS) + CHUNK,
              max_new_tokens=MAX_NEW, eos_id=-1, decode_chunk=1)
    engines = {
        "dense": ContinuousEngine(params, cfg, num_slots=DENSE_SLOTS, **kw),
        "paged": ContinuousEngine(
            params, cfg, num_slots=PAGED_SLOTS,
            kv_pool=KVBlockPool(cfg, block_size=BLOCK,
                                num_blocks=n_blocks), **kw),
    }
    for eng in engines.values():  # compile everything off the clock
        eng.run(make_trace(seed, cfg.vocab_size))
    out: dict = {}
    # trials interleave dense/paged so a host load spike hits both, and
    # the min-p95 per engine damps the jitter a shared runner adds
    for _ in range(trials):
        for name, eng in engines.items():
            done = eng.run(make_trace(seed, cfg.vocab_size))
            es = engine_stats(eng)
            m = {
                "max_concurrency": es["max_concurrency"],
                "kv_bytes": eng.kv_device_bytes(),
                "preemptions": es.get("preemptions", 0),
            }
            m.update(ttft_stats(done))
            m.update(decode_step_stats(eng))
            best = out.get(name)
            if best is None or m["ttft_p95_ms"] < best["ttft_p95_ms"]:
                m["max_concurrency"] = max(
                    m["max_concurrency"],
                    best["max_concurrency"] if best else 0)
                out[name] = m
            else:
                best["max_concurrency"] = max(best["max_concurrency"],
                                              m["max_concurrency"])
    out["paged"]["kv_pool"] = engine_stats(engines["paged"])["kv_pool"]
    return out


def _verdict(res) -> tuple[bool, str]:
    d, p = res["dense"], res["paged"]
    ratio = p["max_concurrency"] / max(d["max_concurrency"], 1)
    conc_ok = ratio >= CONC_RATIO
    ttft_ok = p["ttft_p95_ms"] <= d["ttft_p95_ms"] * TTFT_NOISE
    ok = conc_ok and ttft_ok
    return ok, (
        f"{'PASS' if ok else 'FAIL'}: at equal KV bytes "
        f"({p['kv_bytes']} vs {d['kv_bytes']}) paged admits "
        f"{p['max_concurrency']} concurrent vs dense "
        f"{d['max_concurrency']} ({ratio:.2f}x, "
        f"{'>=' if conc_ok else 'BELOW'} {CONC_RATIO}x); p95 TTFT "
        f"{p['ttft_p95_ms']:.0f}ms vs {d['ttft_p95_ms']:.0f}ms "
        f"({'within' if ttft_ok else 'OUTSIDE'} the {TTFT_NOISE}x guard)")


def run(report):
    """benchmarks.run / ci_smoke entry point."""
    res = bench()
    for name in ("dense", "paged"):
        m = res[name]
        report(f"paged/{name}_max_concurrency", None,
               f"{m['max_concurrency']}")
        report(f"paged/{name}_ttft_p95_ms", None, f"{m['ttft_p95_ms']:.0f}")
        report(f"paged/{name}_kv_bytes", None, f"{m['kv_bytes']}")
        report(f"paged/{name}_decode_step_ms", None,
               f"{m['decode_step_ms']:.2f} path={m['decode_path']}")
    pool = res["paged"]["kv_pool"]
    report("paged/pool_high_water_blocks", None,
           f"{pool['high_water_blocks']}/{pool['blocks_total']}")
    report("paged/preemptions", None, f"{res['paged']['preemptions']}")
    ok, verdict = _verdict(res)
    report("paged/admission_verdict", None, "pass" if ok else "fail")
    print(verdict)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trials", type=int, default=3)
    args = ap.parse_args()
    res = bench(args.seed, args.trials)
    print(f"{'engine':8s} {'conc':>5s} {'ttft_p95':>9s} {'ttft_mean':>10s} "
          f"{'kv_bytes':>9s} {'preempt':>8s} {'step_ms':>8s} "
          f"{'path':>9s}")
    for name, m in res.items():
        print(f"{name:8s} {m['max_concurrency']:5d} "
              f"{m['ttft_p95_ms']:9.0f} {m['ttft_mean_ms']:10.0f} "
              f"{m['kv_bytes']:9d} {m['preemptions']:8d} "
              f"{m['decode_step_ms']:8.2f} {m['decode_path']:>9s}")
    print(f"pool: {res['paged']['kv_pool']}")
    print(_verdict(res)[1])


if __name__ == "__main__":
    main()
