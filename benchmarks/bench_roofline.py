"""§Roofline table generator (deliverable g): reads the dry-run JSONs in
experiments/dryrun/ and prints the per-(arch × shape × mesh) roofline terms,
dominant bottleneck, and MODEL_FLOPS/HLO_FLOPs ratio.  Also emits the
markdown table consumed by EXPERIMENTS.md, plus the *measured* paged
flash-decode roofline rows (``bench_kernels.paged_decode_rows``) the
nightly sweep archives."""

from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def paged_decode_table() -> str:
    """Markdown table of the paged flash-decode budget: measured kernel-path
    vs gather wall time and the analytic achieved-fraction-of-roofline at
    each (B, depth, block_size) point."""
    from benchmarks.bench_kernels import ROOFLINE_FRAC, paged_decode_rows

    rows = [
        "| B | depth | block | path | path (µs) | gather (µs) | speedup "
        "| roofline frac | budget |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in paged_decode_rows():
        rows.append(
            f"| {r['B']} | {r['depth']} | {r['block_size']} | {r['path']} "
            f"| {r['us']:.0f} | {r['gather_us']:.0f} "
            f"| {r['gather_us']/r['us']:.2f}× "
            f"| {r['roofline_frac']:.3f} "
            f"| {'ok' if r['roofline_frac'] >= ROOFLINE_FRAC else 'MISS'} |"
        )
    return "\n".join(rows)


def load_results(mesh: str | None = None) -> list[dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        if mesh and r.get("mesh") != mesh:
            continue
        out.append(r)
    return out


def markdown_table(results: list[dict]) -> str:
    rows = [
        "| arch | shape | mesh | compute (ms) | memory (ms) | collective (ms)"
        " | bottleneck | useful-FLOP ratio | peak MB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in results:
        if r.get("status") == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"— | — | — | skipped | — | — |")
            continue
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"— | — | — | ERROR | — | — |")
            continue
        rl = r["roofline"]
        peak = r["memory"].get("peak_memory_in_bytes")
        peak_mb = f"{peak/1e6:.0f}" if peak else "?"
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rl['compute_s']*1e3:.2f} | {rl['memory_s']*1e3:.2f} "
            f"| {rl['collective_s']*1e3:.2f} | {rl['bottleneck']} "
            f"| {rl['useful_flop_ratio']:.3f} | {peak_mb} |"
        )
    return "\n".join(rows)


def run(report):
    # measured paged flash-decode budget (always available — no dry-run
    # artifacts needed); the pass/fail gate itself lives in bench_kernels
    from benchmarks.bench_kernels import paged_decode_rows

    for r in paged_decode_rows():
        report(
            f"roofline/paged_decode/B{r['B']}_d{r['depth']}"
            f"_bs{r['block_size']}", r["us"],
            f"path={r['path']} gather_us={r['gather_us']:.0f} "
            f"roofline_frac={r['roofline_frac']:.3f} "
            f"achieved_gbps={r['achieved_gbps']:.1f}",
        )

    results = load_results()
    if not results:
        report("roofline/missing", None,
               "run `python -m repro.launch.dryrun_all` first")
        return
    ok = [r for r in results if r.get("status") == "ok"]
    skipped = [r for r in results if r.get("status") == "skipped"]
    bad = [r for r in results if r.get("status") not in ("ok", "skipped")]
    report("roofline/combos", None,
           f"ok={len(ok)} skipped={len(skipped)} errors={len(bad)}")
    for r in ok:
        rl = r["roofline"]
        report(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", None,
            f"compute={rl['compute_s']*1e3:.2f}ms "
            f"memory={rl['memory_s']*1e3:.2f}ms "
            f"collective={rl['collective_s']*1e3:.2f}ms "
            f"bound={rl['bottleneck']} useful={rl['useful_flop_ratio']:.3f}",
        )
    # worst offenders (the hillclimb shortlist)
    def frac(r):
        rl = r["roofline"]
        dom = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
        return rl["compute_s"] / dom if dom else 0.0

    pod = [r for r in ok if r["mesh"] == "pod"]
    if pod:
        worst = min(pod, key=frac)
        coll = max(pod, key=lambda r: r["roofline"]["collective_s"])
        report("roofline/worst_compute_fraction", None,
               f"{worst['arch']}×{worst['shape']} frac={frac(worst):.3f}")
        report("roofline/most_collective_bound", None,
               f"{coll['arch']}×{coll['shape']} "
               f"coll={coll['roofline']['collective_s']*1e3:.1f}ms")


if __name__ == "__main__":
    print(markdown_table(load_results()))
    print()
    print("## Paged flash-decode budget (measured)")
    print()
    print(paged_decode_table())
