"""Rotary position embeddings: standard RoPE and qwen2-vl's M-RoPE.

All functions are pure and shape-polymorphic over the batch/seq dims:

    q: (B, S, H, hd)   positions: (B, S) int32   ->  rotated q

M-RoPE (arXiv:2409.12191) splits the head dim into three sections driven by
(temporal, height, width) position streams.  For the language backbone in this
repo the three streams are supplied by ``input_specs`` (text tokens use
t == h == w == absolute index, which makes M-RoPE coincide with RoPE — the
structure is what the dry-run exercises).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """(head_dim//2,) inverse frequencies, f32."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)


def _rotate(x: jnp.ndarray, angles: jnp.ndarray) -> jnp.ndarray:
    """x: (..., hd), angles: (..., hd//2) broadcastable."""
    cos = jnp.cos(angles)
    sin = jnp.sin(angles)
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_rope(
    x: jnp.ndarray,  # (B, S, H, hd)
    positions: jnp.ndarray,  # (B, S) int32
    theta: float,
) -> jnp.ndarray:
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd//2,)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (B, S, hd//2)
    return _rotate(x, angles[:, :, None, :])


def apply_mrope(
    x: jnp.ndarray,  # (B, S, H, hd)
    positions: jnp.ndarray,  # (3, B, S) int32: temporal, height, width
    theta: float,
    sections: tuple,  # (t, h, w) half-dim section sizes, sum == hd//2
) -> jnp.ndarray:
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = rope_freqs(hd, theta)  # (hd//2,)
    # Build per-frequency position source by section.
    sec_ids = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=hd // 2
    )  # (hd//2,) in {0,1,2}
    pos = positions.astype(jnp.float32)  # (3, B, S)
    # (B, S, hd//2): pick the stream per frequency slot.
    pos_per_freq = jnp.take(pos, sec_ids, axis=0)  # (hd//2, B, S)
    pos_per_freq = jnp.moveaxis(pos_per_freq, 0, -1)  # (B, S, hd//2)
    angles = pos_per_freq * freqs
    return _rotate(x, angles[:, :, None, :])


def text_mrope_positions(positions: jnp.ndarray) -> jnp.ndarray:
    """Text-only stream: t == h == w == absolute position.  (B,S)->(3,B,S)."""
    return jnp.broadcast_to(positions[None], (3,) + positions.shape)
