"""Mamba-2 block (SSD — state-space duality, arXiv:2405.21060).

in_proj fans the hidden state out to (z, x, B, C, dt); a short causal conv
mixes x/B/C locally; the SSD scan (``repro.kernels.ops.ssd_scan`` — Pallas on
TPU, chunked jnp elsewhere) runs the selective state-space recurrence; a
gated RMSNorm and out_proj close the block.

Decode keeps a constant-size recurrent cache: the conv tail (last conv_width-1
inputs) and the SSM state (nh, hd, ds) — this is why SSM archs run the
``long_500k`` shape that full-attention archs cannot.

Single-layer params:
    in_proj: (D, 2*di + 2*G*ds + nh)   [z | x | B | C | dt]
    conv_w: (cw, di + 2*G*ds), conv_b: (di + 2*G*ds)
    A_log: (nh,), D_skip: (nh,), dt_bias: (nh,), norm: (di,)
    out_proj: (di, D)
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig, SSMConfig
from repro.kernels import ops
from repro.models.layers import dense_init, linear, rms_norm

# B/C share a single group in our configs (Mamba-2 default ngroups=1).
NGROUPS = 1


def dims(cfg: ModelConfig):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.num_heads(cfg.d_model)
    conv_dim = di + 2 * NGROUPS * s.d_state
    return s, di, nh, conv_dim


def init(key, cfg: ModelConfig) -> dict:
    s, di, nh, conv_dim = dims(cfg)
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    d_in_proj = 2 * di + 2 * NGROUPS * s.d_state + nh
    lo, hi = s.a_init_range
    a_init = jax.random.uniform(ks[2], (nh,), jnp.float32, lo, hi)
    # dt_bias s.t. softplus(dt_bias) spans [dt_min, dt_max] log-uniformly
    u = jax.random.uniform(ks[3], (nh,), jnp.float32)
    dt = jnp.exp(u * (jnp.log(s.dt_max) - jnp.log(s.dt_min)) + jnp.log(s.dt_min))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    return {
        "in_proj": dense_init(ks[0], cfg.d_model, d_in_proj, dtype),
        "conv_w": (jax.random.normal(ks[1], (s.conv_width, conv_dim), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(a_init),
        "D_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": dt_bias,
        "norm": jnp.zeros((di,), dtype),
        "out_proj": dense_init(ks[4], di, cfg.d_model, dtype),
    }


class SSMCache(NamedTuple):
    conv: jnp.ndarray  # (B, conv_width - 1, conv_dim) rolling conv tail
    state: jnp.ndarray  # (B, nh, hd, ds) f32 SSM state


def init_cache(cfg: ModelConfig, batch: int) -> dict:
    s, di, nh, conv_dim = dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_dim), jnp.dtype(cfg.dtype)),
        "state": jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jnp.ndarray):
    s, di, nh, _ = dims(cfg)
    gds = NGROUPS * s.d_state
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * gds], axis=-1)
    return z, xbc, dt  # xbc = [x | B | C] shares the conv


def _split_xbc(cfg: ModelConfig, xbc: jnp.ndarray):
    s, di, nh, _ = dims(cfg)
    gds = NGROUPS * s.d_state
    x, Bm, Cm = jnp.split(xbc, [di, di + gds], axis=-1)
    shp = xbc.shape[:-1]
    x = x.reshape(*shp, nh, s.head_dim)
    Bm = Bm.reshape(*shp, NGROUPS, s.d_state)
    Cm = Cm.reshape(*shp, NGROUPS, s.d_state)
    return x, Bm, Cm


def _causal_conv(w: jnp.ndarray, b: jnp.ndarray, xbc: jnp.ndarray,
                 tail: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv over the seq axis.  xbc: (B, S, C)."""
    cw = w.shape[0]
    if tail is None:
        pad = jnp.zeros((xbc.shape[0], cw - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = tail
    xp = jnp.concatenate([pad, xbc], axis=1)  # (B, S+cw-1, C)
    out = sum(
        xp[:, i : i + xbc.shape[1]] * w[i][None, None, :] for i in range(cw)
    )
    return jax.nn.silu(out + b[None, None, :])


def apply(
    p: dict,
    cfg: ModelConfig,
    h: jnp.ndarray,  # (B, S, D)
    *,
    lora: Optional[dict] = None,
    lora_mask: Optional[jnp.ndarray] = None,
    lora_scale: float = 1.0,
    initial_state: Optional[jnp.ndarray] = None,
    conv_tail: Optional[jnp.ndarray] = None,  # (B, cw-1, conv_dim) carry-in
) -> tuple[jnp.ndarray, dict]:
    """Full-sequence SSD pass.  Returns (out (B,S,D), cache for decode).

    ``initial_state``/``conv_tail`` chain segments: the hybrid prefill runs
    the real prompt first (whose final state becomes the decode cache) and
    then the appended lookahead rows, so the cached recurrent state is not
    polluted by observation tokens (they are discarded after scoring).
    """
    s, di, nh, conv_dim = dims(cfg)
    B, S, _ = h.shape

    def _l(name):
        return None if lora is None else lora.get(name)

    zxbcdt = linear(h, p["in_proj"], lora=_l("in_proj"), lora_mask=lora_mask,
                    lora_scale=lora_scale)
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    xbc_preconv = xbc
    xbc = _causal_conv(p["conv_w"], p["conv_b"], xbc, tail=conv_tail)
    x, Bm, Cm = _split_xbc(cfg, xbc)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    A = -jnp.exp(p["A_log"])  # (nh,) negative rates

    y, final_state = ops.ssd_scan(
        x, dt, A, Bm, Cm, chunk=s.chunk_size, initial_state=initial_state
    )  # f32
    y = y + p["D_skip"][None, None, :, None] * x.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(h.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = linear(y, p["out_proj"], lora=_l("out_proj"), lora_mask=lora_mask,
                 lora_scale=lora_scale)
    # cache: conv tail = last (cw-1) *pre-conv* xbc rows (prepend the carry-in
    # so short segments still have a full tail).
    if conv_tail is not None:
        xbc_preconv = jnp.concatenate([conv_tail, xbc_preconv], axis=1)
    cache = {"conv": xbc_preconv[:, -(s.conv_width - 1):], "state": final_state}
    return out, cache


def step(
    p: dict,
    cfg: ModelConfig,
    h1: jnp.ndarray,  # (B, 1, D)
    cache: dict,
) -> tuple[jnp.ndarray, dict]:
    """Single-token recurrent step.  Returns (out (B,1,D), new cache)."""
    s, di, nh, conv_dim = dims(cfg)
    B = h1.shape[0]
    zxbcdt = linear(h1, p["in_proj"])  # (B, 1, ·)
    z, xbc_new, dt_raw = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([cache["conv"], xbc_new], axis=1)  # (B, cw, C)
    xbc = sum(
        conv_in[:, i : i + 1] * p["conv_w"][i][None, None, :]
        for i in range(s.conv_width)
    )
    xbc = jax.nn.silu(xbc + p["conv_b"][None, None, :])
    x, Bm, Cm = _split_xbc(cfg, xbc)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,1,nh)
    A = -jnp.exp(p["A_log"])
    y, new_state = ops.ssd_step(
        x[:, 0], dt[:, 0], A, Bm[:, 0], Cm[:, 0], cache["state"]
    )
    y = y.astype(jnp.float32) + p["D_skip"][None, :, None] * x[:, 0].astype(jnp.float32)
    y = y.reshape(B, 1, di).astype(h1.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = linear(y, p["out_proj"])
    new_cache = {"conv": conv_in[:, 1:], "state": new_state}
    return out, new_cache
