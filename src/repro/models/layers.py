"""Primitive layers: RMSNorm, LoRA-aware linear, embeddings, init helpers.

Parameters are plain nested dicts (pytrees).  Each module provides an
``init(key, ...) -> params`` and a pure ``apply``-style function.  Per-layer
parameters are stacked along a leading ``L`` axis by ``transformer.py`` (via
``jax.vmap`` over per-layer PRNG keys) so the whole depth runs under one
``jax.lax.scan`` — this keeps the HLO O(1) in depth, which is what makes the
512-device dry-run compiles tractable.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def dense_init(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = 1.0 / jnp.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def linear(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: Optional[jnp.ndarray] = None,
    *,
    lora: Optional[dict] = None,
    lora_mask: Optional[jnp.ndarray] = None,
    lora_scale: float = 1.0,
) -> jnp.ndarray:
    """y = x @ w (+ b) (+ selective LoRA on masked rows).

    The LoRA path is the paper's *lookahead LoRA*: the low-rank update is
    applied only where ``lora_mask`` (broadcastable to x[..., :1]) is 1 —
    normal-token rows are numerically untouched (tested invariant).
    """
    y = x @ w
    if b is not None:
        y = y + b
    if lora is not None and lora_mask is not None:
        xm = x * lora_mask.astype(x.dtype)
        delta = (xm @ lora["a"].astype(x.dtype)) @ lora["b"].astype(x.dtype)
        y = y + delta * jnp.asarray(lora_scale, x.dtype)
    return y


def lora_init(key, d_in: int, d_out: int, rank: int) -> dict:
    """Standard LoRA init: a ~ N(0, 1/r), b = 0.  Stored in f32 (trainable)."""
    ka, _ = jax.random.split(key)
    return {
        "a": jax.random.normal(ka, (d_in, rank), jnp.float32) / jnp.sqrt(rank),
        "b": jnp.zeros((rank, d_out), jnp.float32),
    }


def activation(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(f"unknown activation {kind}")
