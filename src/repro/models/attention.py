"""Grouped-query attention block with RoPE / M-RoPE, QKV bias, sliding-window
and local:global patterns, lookahead-LoRA hooks, KV caches, and the
importance-score capture path used by the eviction policies.

Single-layer params (stacked along L by transformer.py):

    {"wq": (D, H*hd), "wk": (D, KV*hd), "wv": (D, KV*hd), "wo": (H*hd, D),
     ["bq","bk","bv"]: biases when cfg.attn.qkv_bias}
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.common.config import AttentionConfig, ModelConfig
from repro.kernels import ops
from repro.models import rope
from repro.models.layers import dense_init, linear


class AttnInputs(NamedTuple):
    """Per-call dynamic context for the attention block."""

    positions: jnp.ndarray  # (B, S) absolute positions of the q rows
    mrope_positions: Optional[jnp.ndarray] = None  # (3, B, S)
    lookahead_mask: Optional[jnp.ndarray] = None  # (B, S, 1) selective-LoRA mask
    # decode-time cache (see transformer.make_attn_cache): dict with
    # k: (B, C, KV, hd), v: idem, pos: (B, C), mask: (B, C)
    cache: Optional[dict] = None
    cache_cursor: Optional[jnp.ndarray] = None  # scalar int32 insert index
    # production mesh for shard_map'd decode attention (split-cache path)
    mesh: Optional[object] = None


def init(key, cfg: ModelConfig, *, cross: bool = False) -> dict:
    a = cfg.attn
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, a.q_dim, dtype),
        "wk": dense_init(ks[1], cfg.d_model, a.kv_dim, dtype),
        "wv": dense_init(ks[2], cfg.d_model, a.kv_dim, dtype),
        "wo": dense_init(ks[3], a.q_dim, cfg.d_model, dtype),
    }
    if a.qkv_bias and not cross:
        p["bq"] = jnp.zeros((a.q_dim,), dtype)
        p["bk"] = jnp.zeros((a.kv_dim,), dtype)
        p["bv"] = jnp.zeros((a.kv_dim,), dtype)
    return p


def _lora_for(lora: Optional[dict], name: str) -> Optional[dict]:
    if lora is None:
        return None
    return lora.get(name)


def qkv(
    p: dict,
    a: AttentionConfig,
    h: jnp.ndarray,  # (B, S, D)
    inp: AttnInputs,
    *,
    lora: Optional[dict] = None,
    lora_scale: float = 1.0,
    rotary: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Project + rotate.  Returns q (B,S,H,hd), k/v (B,S,KV,hd)."""
    B, S, _ = h.shape
    lm = inp.lookahead_mask
    smesh = model_shard_mesh(inp.mesh, a)
    if smesh is not None:
        q, k, v = _sharded_qkv_project(p, h, lm, lora, lora_scale, smesh)
    else:
        q = linear(h, p["wq"], p.get("bq"), lora=_lora_for(lora, "wq"),
                   lora_mask=lm, lora_scale=lora_scale)
        k = linear(h, p["wk"], p.get("bk"), lora=_lora_for(lora, "wk"),
                   lora_mask=lm, lora_scale=lora_scale)
        v = linear(h, p["wv"], p.get("bv"), lora=_lora_for(lora, "wv"),
                   lora_mask=lm, lora_scale=lora_scale)
    q = q.reshape(B, S, a.num_heads, a.head_dim)
    k = k.reshape(B, S, a.num_kv_heads, a.head_dim)
    v = v.reshape(B, S, a.num_kv_heads, a.head_dim)
    q, k, v = (pin_heads(q, smesh), pin_heads(k, smesh),
               pin_heads(v, smesh))
    if rotary:
        if a.mrope and inp.mrope_positions is not None:
            q = rope.apply_mrope(q, inp.mrope_positions, a.rope_theta, a.mrope_sections)
            k = rope.apply_mrope(k, inp.mrope_positions, a.rope_theta, a.mrope_sections)
        else:
            q = rope.apply_rope(q, inp.positions, a.rope_theta)
            k = rope.apply_rope(k, inp.positions, a.rope_theta)
    return q, k, v


def prefill_attention(
    p: dict,
    a: AttentionConfig,
    h: jnp.ndarray,
    inp: AttnInputs,
    *,
    is_global: jnp.ndarray | bool = True,
    lora: Optional[dict] = None,
    lora_scale: float = 1.0,
    causal: bool = True,
    rotary: bool = True,
    kv_mask: Optional[jnp.ndarray] = None,  # (B, S) valid-key mask
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Full-sequence attention.  Returns (out, q, k, v).

    ``is_global`` may be a traced bool (scanned local/global flag): local
    layers apply the sliding-window mask, global layers don't.  Both cases
    share one kernel call by selecting the window value (huge = unbounded).
    ``kv_mask`` excludes keys (bucket-padded prompt rows) from every query.
    """
    q, k, v = qkv(p, a, h, inp, lora=lora, lora_scale=lora_scale, rotary=rotary)
    window = layer_window(a, is_global) if causal else None
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              kv_mask=kv_mask)
    B, S = h.shape[:2]
    out = out.reshape(B, S, a.q_dim)
    out = linear(out, p["wo"], lora=_lora_for(lora, "wo"),
                 lora_mask=inp.lookahead_mask, lora_scale=lora_scale)
    return out, q, k, v


def chunk_prefill_attention(
    p: dict,
    a: AttentionConfig,
    h: jnp.ndarray,  # (B, C, D) chunk hidden states
    inp: AttnInputs,  # positions = q_offset + arange(C)
    k_buf: jnp.ndarray,  # (B, K, KV, hd) materialized prompt keys so far
    v_buf: jnp.ndarray,
    *,
    q_offset,  # scalar int32 (traced) — absolute position of chunk row 0
    is_global: jnp.ndarray | bool = True,
    score_masses: bool = False,  # fused eviction-score partials (h2o)
    n_total=None,  # scalar int32 — true prompt length (masks pad rows)
    lora: Optional[dict] = None,
    lora_scale: float = 1.0,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray,
           Optional[jnp.ndarray]]:
    """Streaming-prefill attention: project + rotate the chunk, append its
    K/V into the prompt buffer at ``q_offset``, and attend the chunk's
    queries over prior-chunk keys plus causal self-attention within the
    chunk (``ops.chunk_attention``).  Returns (out, q, k_buf', v_buf',
    masses) — the rotary-encoded q feeds the streaming eviction scores, the
    updated buffers carry the materialized KV to the next chunk, and
    ``masses`` is the fused per-key column-mass partial (B, H, K) when
    ``score_masses`` is set (None otherwise): the cumulative (h2o) policy's
    chunk contribution, emitted by the attention kernel itself with rows at
    or past ``n_total`` masked to zero.

    The buffer must be deep enough for the write (``q_offset + C <= K``);
    ``jax.lax.dynamic_update_slice`` would otherwise silently clamp the
    start index and corrupt earlier chunks' keys.
    """
    q, k, v = qkv(p, a, h, inp, lora=lora, lora_scale=lora_scale)
    k_buf = jax.lax.dynamic_update_slice(
        k_buf, k.astype(k_buf.dtype), (0, q_offset, 0, 0))
    v_buf = jax.lax.dynamic_update_slice(
        v_buf, v.astype(v_buf.dtype), (0, q_offset, 0, 0))
    window = layer_window(a, is_global)
    masses = None
    smesh = model_shard_mesh(inp.mesh, a)
    if smesh is not None:
        out, masses = _sharded_chunk_attention(
            q, k_buf, v_buf, q_offset=q_offset, window=window,
            score_masses=score_masses, n_total=n_total, mesh=smesh)
    elif score_masses:
        out, masses = ops.chunk_attention(
            q, k_buf, v_buf, q_offset=q_offset, window=window,
            score_masses=True, n_total=n_total)
    else:
        out = ops.chunk_attention(q, k_buf, v_buf, q_offset=q_offset,
                                  window=window)
    B, C = h.shape[:2]
    out = out.reshape(B, C, a.q_dim)
    out = sharded_wo_linear(out, p["wo"], smesh, lora=_lora_for(lora, "wo"),
                            lm=inp.lookahead_mask, ls=lora_scale)
    return out, q, k_buf, v_buf, masses


_HUGE_WINDOW = 1 << 30


def layer_window(a: AttentionConfig, is_global) -> "int | jnp.ndarray | None":
    """Resolve the attention window for one layer.

    Returns None (full attention), a static int (uniform sliding window) or a
    traced int32 scalar (scanned local/global pattern: global layers get a
    window larger than any sequence, which the masks treat as unbounded).
    """
    patterned = a.global_every > 0 or len(a.global_layers) > 0
    if patterned:
        if isinstance(is_global, bool):
            return None if is_global else a.sliding_window
        return jnp.where(
            jnp.asarray(is_global),
            jnp.int32(_HUGE_WINDOW),
            jnp.int32(a.sliding_window),
        )
    if a.sliding_window > 0:
        return a.sliding_window
    return None


# -- tensor-parallel kernel dispatch ----------------------------------------
#
# With a ("data", "model") serving mesh, attention runs per model shard
# over its local head slice: contiguous kv-head shards own exactly their q
# heads' GQA groups (H = G·KV keeps group boundaries shard-aligned), and
# every per-head reduction sweeps the full sequence in the *same order* as
# the unsharded call — so per-head outputs, the fused score-mass partials,
# and the eviction kept-sets derived from them are bit-identical to
# single-device serving, with no collective inside the attention block
# (shards combine downstream, in the row-sharded ``wo`` matmul).  Pallas
# kernels have no GSPMD partition rule, so the forced-Pallas dispatch
# *requires* these shard_map wrappers to stay on the kernel path.


def _shard_map(f, mesh, in_specs, out_specs):
    """Version shim: ``jax.shard_map(check_vma=)`` landed after 0.4.x,
    where the API lives at ``jax.experimental.shard_map`` with the
    replication check spelled ``check_rep``."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as sm
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=False)


def pin_activations(h, mesh):
    """Pin a scan-carried activation to batch-only sharding (feature dim
    unsharded).  Left to itself, GSPMD may feature-shard the carry between
    layers to suit the row-sharded ``wo``/``w_down`` matmuls — turning
    every ``rms_norm`` mean into a psum of per-shard partials whose
    different summation order perturbs activations by bf16 ulps, and with
    them the eviction scores sharded serving promises to keep bit-exact."""
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return h
    if int(mesh.shape["model"]) == 1:
        return h
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    bspec = _batch_spec(mesh, h.shape[0])
    return jax.lax.with_sharding_constraint(
        h, NamedSharding(mesh, P(bspec, *(None,) * (h.ndim - 1))))


def pin_heads(x, smesh):
    """Pin a (B, S, heads, hd) projection to head-sharded on "model" — the
    canonical Megatron column split.  Unpinned, GSPMD is free to realize
    the projection as a contraction-split dot (psum of per-shard partial
    sums over d_model), whose different summation association perturbs the
    result by bf16 ulps vs the single-device program."""
    if smesh is None:
        return x
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    return jax.lax.with_sharding_constraint(
        x, NamedSharding(smesh, P(None, None, "model", None)))


def _sharded_qkv_project(p, h, lm, lora, lora_scale, smesh):
    """q/k/v projections column-parallel under shard_map.

    GSPMD is free to realize ``h @ w`` as a contraction-split dot (psum of
    per-shard partials over d_model) whose summation association differs
    from the single-device dot by bf16 ulps — and
    ``with_sharding_constraint`` pins layouts, not dot algorithms, so it
    cannot forbid that choice (observed: the observation pass's
    LoRA-bearing k projection drifts inside ``lax.scan`` even with its
    inputs and outputs pinned).  Under shard_map each shard computes its
    local head columns with the full d_model contraction in the
    single-device order, making the projection bit-exact by construction.
    The LoRA delta (observation rows) rides along: ``xm @ A`` is computed
    in full on every shard (A is replicated and rank-tiny) and ``@ B``
    takes the local column slice.
    """
    from jax.sharding import PartitionSpec as P

    names = ("wq", "wk", "wv")
    ws = {n: p[n] for n in names}
    bs = {n: p["b" + n[1:]] for n in names
          if p.get("b" + n[1:]) is not None}
    lo = {n: lora[n] for n in names
          if lora is not None and lora.get(n) is not None}
    w_specs = {n: P(None, "model") for n in ws}
    b_specs = {n: P("model") for n in bs}
    lo_specs = {n: {"a": P(None, None), "b": P(None, "model")} for n in lo}
    bspec = _batch_spec(smesh, h.shape[0])
    have_lm = lm is not None

    def local(hh, wsl, bsl, losl, *rest):
        lml = rest[0] if have_lm else None
        outs = tuple(
            linear(hh, wsl[n], bsl.get(n), lora=losl.get(n),
                   lora_mask=lml, lora_scale=lora_scale)
            for n in names)
        return outs

    arrs = [h, ws, bs, lo]
    specs = [P(bspec, None, None), w_specs, b_specs, lo_specs]
    if have_lm:
        arrs.append(lm)
        specs.append(P(bspec, None, None))
    cspec = P(bspec, None, "model")
    return _shard_map(local, smesh, tuple(specs), (cspec,) * 3)(*arrs)


def replicated_apply(fn, smesh, *args):
    """Run ``fn(*args)`` identically on every shard under shard_map.

    The escape hatch for small computations that must be bit-exact vs the
    single-device program but whose dots GSPMD may re-associate (the
    lookahead-LoRA deltas on the observation rows): inside shard_map there
    is no partitioner, so each shard gathers the operands (declared fully
    replicated) and performs the complete single-device computation in the
    single-device order.  Redundant across shards — reserve it for
    observation-sized work, not the serving hot path.
    """
    if smesh is None:
        return fn(*args)
    from jax.sharding import PartitionSpec as P

    in_specs = tuple(P() for _ in args)
    return _shard_map(lambda *a: fn(*a), smesh, in_specs, P())(*args)


def sharded_wo_linear(out_flat, w, smesh, *, lora=None, lm=None, ls=1.0):
    """Attention out-projection with the contraction in single-device order.

    ``out @ wo`` contracts over the head-sharded dim, and GSPMD's
    realization of that dot is shape-dependent: at some (chunk, length)
    points it psum-splits the contraction, re-associating the bf16 sums
    vs the single-device program.  Here the head-sharded attention output
    is all-gathered *inside* shard_map, then each shard computes the full
    contraction for its local slice of output columns (column-parallel on
    d_model) — no psum ever touches the reduction, so bits match the
    single-device matmul by construction.  The LoRA delta (observation
    rows) follows the same pattern: full ``xm @ A``, column-sliced
    ``@ B``.
    """
    if smesh is None:
        return linear(out_flat, w, lora=lora, lora_mask=lm, lora_scale=ls)
    if w.shape[-1] % int(smesh.shape["model"]):
        return replicated_apply(
            lambda o, wl, lo, lml: linear(o, wl, lora=lo, lora_mask=lml,
                                          lora_scale=ls),
            smesh, out_flat, w, lora, lm)
    from jax.sharding import PartitionSpec as P

    bspec = _batch_spec(smesh, out_flat.shape[0])
    have_lora = lora is not None and lm is not None

    def local(o, wl, *rest):
        of = jax.lax.all_gather(o, "model", axis=2, tiled=True)
        lo = rest[0] if have_lora else None
        lml = rest[1] if have_lora else None
        return linear(of, wl, lora=lo, lora_mask=lml, lora_scale=ls)

    arrs = [out_flat, w]
    specs = [P(bspec, None, "model"), P(None, "model")]
    if have_lora:
        arrs += [lora, lm]
        specs += [{"a": P(None, None), "b": P(None, "model")},
                  P(bspec, None, None)]
    return _shard_map(local, smesh, tuple(specs),
                      P(bspec, None, "model"))(*arrs)


def model_shard_mesh(mesh, a: AttentionConfig):
    """The mesh when per-shard head dispatch applies, else None.

    kv heads must divide the "model" axis (q heads then divide too, since
    ``H = G · KV``); anything else degrades to the unsharded call — the
    same replication fallback ``param_specs`` uses for the projections.
    """
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return None
    msize = int(mesh.shape["model"])
    if msize == 1 or a.num_kv_heads % msize or a.num_heads % msize:
        return None
    return mesh


def _batch_spec(mesh, B: int):
    """Shard the batch over the data axes when it divides them."""
    dp = tuple(n for n in mesh.axis_names if n != "model")
    total = 1
    for n in dp:
        total *= int(mesh.shape[n])
    return dp if (dp and B % total == 0) else None


def _sharded_chunk_attention(q, k_buf, v_buf, *, q_offset, window,
                             score_masses, n_total, mesh):
    """``ops.chunk_attention`` per model shard over local head slices."""
    from jax.sharding import PartitionSpec as P

    bspec = _batch_spec(mesh, q.shape[0])
    hspec = P(bspec, None, "model", None)  # heads/kv-heads on axis 2
    traced_window = window is not None and not isinstance(window, int)

    arrs = [q, k_buf, v_buf, jnp.asarray(q_offset, jnp.int32)]
    specs = [hspec, hspec, hspec, P()]
    if traced_window:
        arrs.append(jnp.asarray(window, jnp.int32))
        specs.append(P())
    if n_total is not None:
        arrs.append(jnp.asarray(n_total, jnp.int32))
        specs.append(P())

    def local(qv, kv, vv, off, *rest):
        win = rest[0] if traced_window else window
        if not score_masses:
            return (ops.chunk_attention(qv, kv, vv, q_offset=off,
                                        window=win),)
        nt = rest[-1] if n_total is not None else None
        return ops.chunk_attention(qv, kv, vv, q_offset=off, window=win,
                                   score_masses=True, n_total=nt)

    out_specs = (hspec, P(bspec, "model", None)) if score_masses else (hspec,)
    res = _shard_map(local, mesh, tuple(specs), out_specs)(*arrs)
    return (res[0], res[1]) if score_masses else (res[0], None)


def sharded_lookahead_score(q_obs, k_buf, n_prompt, *, q_offset, window,
                            row_valid=None, smesh=None):
    """``ops.lookahead_score`` per model shard (observation-pass scoring).

    Scores are per q-head, so each shard scores its local heads over the
    full key sequence — same reduction order as unsharded, no collective.
    ``smesh`` is a mesh already vetted by ``model_shard_mesh`` (None runs
    the plain call).
    """
    if smesh is None:
        return ops.lookahead_score(q_obs, k_buf, n_prompt, q_offset=q_offset,
                                   window=window, row_valid=row_valid)
    from jax.sharding import PartitionSpec as P

    bspec = _batch_spec(smesh, q_obs.shape[0])
    hspec = P(bspec, None, "model", None)
    traced_window = window is not None and not isinstance(window, int)
    traced_offset = q_offset is not None and not isinstance(q_offset, int)

    arrs = [q_obs, k_buf]
    specs = [hspec, hspec]
    if traced_offset:
        arrs.append(jnp.asarray(q_offset, jnp.int32))
        specs.append(P())
    if traced_window:
        arrs.append(jnp.asarray(window, jnp.int32))
        specs.append(P())
    if row_valid is not None:
        arrs.append(row_valid)
        specs.append(P(bspec, None))

    def local(qv, kv, *rest):
        i = 0
        off = q_offset
        if traced_offset:
            off = rest[i]
            i += 1
        win = window
        if traced_window:
            win = rest[i]
            i += 1
        rv = rest[i] if row_valid is not None else None
        return ops.lookahead_score(qv, kv, n_prompt, q_offset=off,
                                   window=win, row_valid=rv)

    return _shard_map(local, smesh, tuple(specs),
                      P(bspec, "model", None))(*arrs)


def _sharded_paged_decode(q1, k1, v1, pool, table, pb, off, write_ok,
                          new_pos_kv, new_pos, *, window, depth, mesh):
    """Paged append + ``ops.paged_decode_attention`` per model shard.

    The batch stays *replicated* here (no data-axis sharding): the pool
    has no batch dim, so every data rank must apply the full batch's
    scatter to keep its pool replica identical — sharding the batch would
    silently fork the replicas (check_vma=False cannot catch it).
    """
    from jax.sharding import PartitionSpec as P

    kvspec = P(None, None, "model", None)  # pool k/v (N, bs, KV, hd)
    mspec = P(None, None, "model")  # pool pos/mask (N, bs, KV)
    traced_window = window is not None and not isinstance(window, int)

    arrs = [q1, k1, v1, pool["k"], pool["v"], pool["pos"], pool["mask"],
            table, pb, off, write_ok, new_pos_kv, new_pos]
    specs = [P(None, "model", None), P(None, "model", None),
             P(None, "model", None), kvspec, kvspec, mspec, mspec,
             P(None, None), P(None), P(None), P(None),
             P(None, "model"), P(None)]
    if traced_window:
        arrs.append(jnp.asarray(window, jnp.int32))
        specs.append(P())

    def local(qv, kn, vn, pk, pv, ppos, pmask, tab, pbv, offv, wok,
              npkv, np1, *rest):
        win = rest[0] if traced_window else window
        kvl = kn.shape[-2]
        pk = pk.at[pbv, offv].set(kn.astype(pk.dtype))
        pv = pv.at[pbv, offv].set(vn.astype(pv.dtype))
        ppos = ppos.at[pbv, offv].set(npkv)
        pmask = pmask.at[pbv, offv].set(
            jnp.broadcast_to(wok[:, None], (wok.shape[0], kvl)))
        out = ops.paged_decode_attention(
            qv, pk, pv, pmask, tab, pos_pool=ppos, new_pos=np1,
            window=win, depth=depth)
        return out, pk, pv, ppos, pmask

    out_specs = (P(None, "model", None), kvspec, kvspec, mspec, mspec)
    return _shard_map(local, mesh, tuple(specs), out_specs)(*arrs)


def decode_attention_step(
    p: dict,
    a: AttentionConfig,
    h1: jnp.ndarray,  # (B, 1, D) current token hidden
    inp: AttnInputs,
    *,
    window=None,
) -> tuple[jnp.ndarray, dict]:
    """One decode step against the cache.  Returns (out (B,1,D), new cache).

    Cache layout (leading L axis stripped by the layer scan):
        k/v: (B, C, KV, hd);  pos/mask: (B, C, KV) — *per kv head*, because
        eviction keeps different token positions per head.

    ``inp.cache_cursor`` is either a scalar (lockstep serving: every sequence
    appends at the same slot) or a (B,) vector (continuous batching: slots
    admitted at different times carry independent write cursors; the append
    becomes a per-sequence one-hot scatter).
    """
    cache = inp.cache
    B = h1.shape[0]
    KV = a.num_kv_heads
    q, k_new, v_new = qkv(p, a, h1, inp)
    cursor = inp.cache_cursor
    new_pos = jnp.broadcast_to(inp.positions[:, :, None], (B, 1, KV))
    if getattr(cursor, "ndim", 0) == 1:  # per-slot cursors
        C = cache["k"].shape[1]
        sel = jnp.arange(C)[None, :] == jnp.clip(cursor, 0, C - 1)[:, None]
        sel &= (cursor < C)[:, None]  # full caches stop appending
        k = jnp.where(sel[..., None, None], k_new, cache["k"])
        v = jnp.where(sel[..., None, None], v_new, cache["v"])
        pos = jnp.where(sel[..., None], new_pos, cache["pos"])
        mask = cache["mask"] | sel[..., None]
    else:
        k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, cursor, 0, 0))
        v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, cursor, 0, 0))
        pos = jax.lax.dynamic_update_slice(cache["pos"], new_pos,
                                           (0, cursor, 0))
        mask = jax.lax.dynamic_update_slice(
            cache["mask"], jnp.ones((B, 1, KV), bool), (0, cursor, 0)
        )
    att_mask = mask
    if window is not None:
        att_mask = mask & ((new_pos[:, :1] - pos) < window)
    out = ops.decode_attention(q[:, 0], k, v, kv_mask=att_mask)
    out = out.reshape(B, 1, a.q_dim)
    out = sharded_wo_linear(out, p["wo"], model_shard_mesh(inp.mesh, a))
    new_cache = {"k": k, "v": v, "pos": pos, "mask": mask}
    return out, new_cache


def decode_attention_step_paged(
    p: dict,
    a: AttentionConfig,
    h1: jnp.ndarray,  # (B, 1, D) current token hidden
    inp: AttnInputs,
    *,
    table: jnp.ndarray,  # (B, nb) int32 physical block ids (0 = null)
    depth: int,  # static dense-equivalent cache depth (capacity + margin)
    active: Optional[jnp.ndarray] = None,  # (B,) live-slot mask
    window=None,
) -> tuple[jnp.ndarray, dict]:
    """One decode step against the *paged* cache (``serving/kv_pool.py``).

    ``inp.cache`` holds this layer's slice of the shared block pool
    ({k, v: (N, bs, KV, hd); pos, mask: (N, bs, KV)}); a slot's logical
    cache row ``c`` lives at ``(table[b, c // bs], c % bs)``.  The step

    1. appends the new token's K/V at the slot's cursor row — a scatter
       into the slot's own block.  Because the pool is shared, a write
       from a retired / empty slot cannot be reverted with a per-slot
       select the way the dense path does: writes are gated *here* —
       inactive or full slots route their scatter to the null block (id
       0), whose mask stays False (the routed mask value is exactly
       ``False``), so zombie decodes never corrupt a neighbour's blocks;
    2. attends straight out of the pool via ``ops.paged_decode_attention``
       — the Pallas kernel streams K/V/mask/pos tiles through the
       scalar-prefetched block table, so no dense ``(B, depth, ...)``
       copy of the cache ever materializes on the kernel path.  Sliding
       windows ride along: the kernel (and both jnp fallbacks) apply
       ``new_pos - pos < window`` from the pool's ``pos`` metadata, with
       a *traced* window prefetched like the table.

    The bit-exactness contract lives in the jnp dispatch: at serving
    depths ``ops.paged_decode_attention`` falls back to the gather
    oracle (``ref.paged_decode_attention`` with ``depth``), which
    materializes bitwise the rows the dense cache would hold, slices to
    the same static ``depth`` and reduces in the same order as
    ``decode_attention_step`` — so paged serving emits bit-identical
    tokens to dense serving there (tests/test_kv_pool.py proves it per
    policy).  The kernel path is exact-parity within fp tolerance
    (tests/test_paged_decode.py) and is held to a roofline bandwidth
    budget by ``benchmarks/bench_kernels.py``.

    Decode-time eviction rides an optional ``"score"`` leaf in the pool
    slice: when present ((B, depth, KV) cumulative softmax masses), the
    attention call fuses the step's per-row masses
    (``score_masses=True``) and the updated accumulator is returned in
    the new cache dict — the streaming analogue of the dense
    ``decode_attention_step_evicting`` score recurrence, consumed by the
    serving engine's periodic evict-and-compact sweep.  The attention
    output is bitwise unchanged by scoring on every kernel tier.
    """
    pool = inp.cache  # this layer's pool slice
    score = pool.get("score")  # (B, depth, KV) decode-eviction masses
    B = h1.shape[0]
    KV = a.num_kv_heads
    bs = pool["k"].shape[1]
    nb = table.shape[1]
    assert depth <= nb * bs, "block table shallower than the logical cache"
    q, k_new, v_new = qkv(p, a, h1, inp)
    cursor = inp.cache_cursor  # (B,) per-slot append cursors
    new_pos = jnp.broadcast_to(inp.positions[:, :, None], (B, 1, KV))

    # -- append (null-routed for inactive / full slots) --
    write_ok = cursor < depth  # full caches stop appending (as dense)
    if active is not None:
        write_ok &= active
    jb = jnp.clip(cursor // bs, 0, nb - 1)
    off = jnp.clip(cursor - jb * bs, 0, bs - 1)
    pb = jnp.take_along_axis(table, jb[:, None], axis=1)[:, 0]
    # a live slot whose append block is missing (table entry 0 — the
    # engine's ensure step should have grown it) must not mark a null-
    # block row valid: that would hand a phantom zero-payload key to
    # every slot whose gaps/tails read that row
    write_ok &= pb != 0
    pb = jnp.where(write_ok, pb, 0)
    smesh = model_shard_mesh(inp.mesh, a)
    if smesh is not None:
        assert score is None, \
            "decode-time eviction scoring is single-device (the engine " \
            "rejects mesh + decode_evict on the paged pool)"
        out, pk, pv, ppos, pmask = _sharded_paged_decode(
            q[:, 0], k_new[:, 0], v_new[:, 0], pool, table, pb, off,
            write_ok, new_pos[:, 0], inp.positions[:, 0],
            window=window, depth=depth, mesh=smesh)
        out = out.reshape(B, 1, a.q_dim)
        out = sharded_wo_linear(out, p["wo"], smesh)
        return out, {"k": pk, "v": pv, "pos": ppos, "mask": pmask}
    pk = pool["k"].at[pb, off].set(k_new[:, 0].astype(pool["k"].dtype))
    pv = pool["v"].at[pb, off].set(v_new[:, 0].astype(pool["v"].dtype))
    ppos = pool["pos"].at[pb, off].set(new_pos[:, 0])
    pmask = pool["mask"].at[pb, off].set(
        jnp.broadcast_to(write_ok[:, None], (B, KV)))

    # -- attend in pool layout: the kernel streams tiles through the
    # block table, the jnp gather fallback reproduces the dense step's
    # exact reduction (no dense view is built here on any path) --
    new_cache = {"k": pk, "v": pv, "pos": ppos, "mask": pmask}
    if score is not None:
        from repro.core.scoring import decode_mass_update

        out, masses = ops.paged_decode_attention(
            q[:, 0], pk, pv, pmask, table, pos_pool=ppos,
            new_pos=inp.positions[:, 0], window=window, depth=depth,
            score_masses=True)
        new_cache["score"] = score + decode_mass_update(
            masses, KV, active=write_ok)
    else:
        out = ops.paged_decode_attention(
            q[:, 0], pk, pv, pmask, table, pos_pool=ppos,
            new_pos=inp.positions[:, 0], window=window, depth=depth)
    out = out.reshape(B, 1, a.q_dim)
    out = linear(out, p["wo"])
    return out, new_cache


def cross_attention(
    p: dict,
    a: AttentionConfig,
    h: jnp.ndarray,  # (B, Sq, D) decoder hidden
    enc_k: jnp.ndarray,  # (B, Se, KV, hd) precomputed encoder keys
    enc_v: jnp.ndarray,
    *,
    enc_mask: Optional[jnp.ndarray] = None,
    lora: Optional[dict] = None,
    lora_mask: Optional[jnp.ndarray] = None,
    lora_scale: float = 1.0,
) -> jnp.ndarray:
    """Whisper-style decoder→encoder cross attention (no positions)."""
    B, Sq, _ = h.shape
    q = linear(h, p["wq"], p.get("bq"), lora=_lora_for(lora, "wq"),
               lora_mask=lora_mask, lora_scale=lora_scale)
    q = q.reshape(B, Sq, a.num_heads, a.head_dim)
    out = ops.flash_attention(q, enc_k, enc_v, causal=False, kv_mask=enc_mask)
    out = out.reshape(B, Sq, a.q_dim)
    return linear(out, p["wo"], lora=_lora_for(lora, "wo"),
                  lora_mask=lora_mask, lora_scale=lora_scale)


def cross_attention_decode_evicted(
    p: dict,
    a: AttentionConfig,
    h1: jnp.ndarray,  # (B, 1, D)
    cross_cache: dict,  # k/v (B, Cc, KV, hd), mask (B, Cc, KV)
) -> jnp.ndarray:
    """Single-token cross attention over an *evicted* encoder cache (per-head
    kept sets => per-head masks; beyond-paper cross-KV eviction)."""
    B = h1.shape[0]
    q = linear(h1, p["wq"], p.get("bq")).reshape(B, 1, a.num_heads, a.head_dim)
    out = ops.decode_attention(q[:, 0], cross_cache["k"], cross_cache["v"],
                               kv_mask=cross_cache["mask"])
    return linear(out.reshape(B, 1, a.q_dim), p["wo"])


def encode_kv(p: dict, a: AttentionConfig, h_enc: jnp.ndarray):
    """Project encoder states once into cross-attention K/V."""
    B, Se, _ = h_enc.shape
    k = linear(h_enc, p["wk"], p.get("bk")).reshape(B, Se, a.num_kv_heads, a.head_dim)
    v = linear(h_enc, p["wv"], p.get("bv")).reshape(B, Se, a.num_kv_heads, a.head_dim)
    return k, v


def decode_attention_step_evicting(
    p: dict,
    a: AttentionConfig,
    h1: jnp.ndarray,  # (B, 1, D)
    inp: AttnInputs,
    *,
    window=None,
) -> tuple[jnp.ndarray, dict]:
    """Decoding-stage eviction step (beyond-paper: the paper names decode
    eviction as future work).  The cache carries a ``score`` field —
    cumulative attention mass per slot (H2O-style heavy hitters, per kv
    head).  While capacity remains, behave like the plain step; once full,
    the new token overwrites the *lowest-cumulative-score* slot (never the
    newest), so the cache stays within its budget during generation.
    """
    cache = inp.cache
    B = h1.shape[0]
    KV, hd = a.num_kv_heads, a.head_dim
    C = cache["k"].shape[1]
    G = a.num_heads // KV
    q, k_new, v_new = qkv(p, a, h1, inp)

    # one-step attention distribution of the new query over current slots,
    # grouped by kv head: (B, KV, G, C)
    qg = q[:, 0].reshape(B, KV, G, hd).astype(jnp.float32)
    logits = jnp.einsum(
        "bkgd,bckd->bkgc", qg, cache["k"].astype(jnp.float32)
    ) / jnp.sqrt(jnp.float32(hd))
    mask_bkc = jnp.moveaxis(cache["mask"], 1, 2)  # (B, KV, C)
    logits = jnp.where(mask_bkc[:, :, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).mean(axis=2)  # (B, KV, C)
    add = jnp.moveaxis(probs, 1, 2)  # (B, C, KV)
    score = cache["score"] + jnp.where(cache["mask"], add, 0.0)

    cursor = inp.cache_cursor
    if getattr(cursor, "ndim", 0) == 1:  # per-slot cursors (continuous batch)
        cursor = cursor[:, None]  # (B, 1) broadcasts against (B, KV)
    full = cursor >= C
    victim = jnp.argmin(jnp.where(cache["mask"], score, jnp.inf), axis=1)
    slot = jnp.where(full, victim, jnp.minimum(cursor, C - 1))  # (B, KV)
    onehot = jax.nn.one_hot(slot, C, axis=1, dtype=jnp.float32)  # (B, C, KV)
    sel = onehot[..., None].astype(cache["k"].dtype)  # (B, C, KV, 1)
    k = cache["k"] * (1 - sel) + k_new * sel  # k_new (B,1,KV,hd) broadcasts
    v = cache["v"] * (1 - sel) + v_new * sel
    new_pos = jnp.broadcast_to(inp.positions[:, :, None], (B, 1, KV))
    pos = jnp.where(onehot > 0, new_pos, cache["pos"])
    mask = cache["mask"] | (onehot > 0)
    score = jnp.where(onehot > 0, add, score)  # fresh slot restarts its tally

    att_mask = mask
    if window is not None:
        att_mask = mask & ((new_pos - pos) < window)
    out = ops.decode_attention(q[:, 0], k, v, kv_mask=att_mask)
    out = linear(out.reshape(B, 1, a.q_dim), p["wo"])
    new_cache = {"k": k, "v": v, "pos": pos, "mask": mask, "score": score}
    return out, new_cache


def _frozen_cache_stats(q, k, v, mask, *, mesh=None):
    """Flash-decode stats over the frozen (possibly sequence-sharded) prompt
    cache.  With a mesh whose "model" axis divides the cache length, the
    computation runs under shard_map: each model rank reduces its local
    sequence shard and the partials merge with pmax/psum — per-layer
    collective traffic drops from gathering the full K/V (33 MB per layer on
    qwen2-vl) to the (B, H[, hd]) stat tensors (§Perf decode iteration)."""
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return ops.decode_attention_stats(q, k, v, kv_mask=mask)
    msize = mesh.shape["model"]
    C = k.shape[1]
    B = q.shape[0]
    if C % msize != 0:
        return ops.decode_attention_stats(q, k, v, kv_mask=mask)
    from jax.sharding import PartitionSpec as P

    dp = tuple(n for n in mesh.axis_names if n != "model")
    dp_total = 1
    for a in dp:
        dp_total *= int(mesh.shape[a])
    bspec = dp if B % dp_total == 0 else None

    def local(qv, kv, vv, mv):
        m, l, acc = ops.decode_attention_stats(qv, kv, vv, kv_mask=mv)
        gm = jax.lax.pmax(m, "model")
        corr = jnp.exp(m - gm)
        gl = jax.lax.psum(l * corr, "model")
        gacc = jax.lax.psum(acc * corr[..., None], "model")
        return gm, gl, gacc

    return _shard_map(
        local, mesh,
        (P(bspec, None, None), P(bspec, "model", None, None),
         P(bspec, "model", None, None), P(bspec, "model", None)),
        (P(bspec, None), P(bspec, None), P(bspec, None, None)),
    )(q, k, v, mask)


def decode_attention_step_split(
    p: dict,
    a: AttentionConfig,
    h1: jnp.ndarray,  # (B, 1, D)
    inp: AttnInputs,
    *,
    window=None,
) -> tuple[jnp.ndarray, dict]:
    """Split-cache decode (§Perf decode iteration): the prompt cache is
    *frozen* (read-only — it may stay sequence-sharded on "model" with no
    per-step resharding) and new tokens append into a small *replicated*
    hot ring buffer.  The two segments attend independently and merge via
    online-softmax stats — numerically identical to single-cache attention.

    cache = {k, v, pos, mask (frozen, (B,C,KV,·)),
             hot_k, hot_v, hot_pos, hot_mask ((B,Hb,KV,·))}
    """
    cache = inp.cache
    B = h1.shape[0]
    KV = a.num_kv_heads
    Hb = cache["hot_k"].shape[1]
    q, k_new, v_new = qkv(p, a, h1, inp)
    cursor = inp.cache_cursor  # counts hot-buffer appends (ring)
    slot = jnp.mod(cursor, Hb)
    hot_k = jax.lax.dynamic_update_slice(cache["hot_k"], k_new,
                                         (0, slot, 0, 0))
    hot_v = jax.lax.dynamic_update_slice(cache["hot_v"], v_new,
                                         (0, slot, 0, 0))
    new_pos = jnp.broadcast_to(inp.positions[:, :, None], (B, 1, KV))
    hot_pos = jax.lax.dynamic_update_slice(cache["hot_pos"], new_pos,
                                           (0, slot, 0))
    hot_mask = jax.lax.dynamic_update_slice(
        cache["hot_mask"], jnp.ones((B, 1, KV), bool), (0, slot, 0))

    froz_mask = cache["mask"]
    hm = hot_mask
    if window is not None:
        froz_mask = froz_mask & ((new_pos - cache["pos"]) < window)
        hm = hm & ((new_pos - hot_pos) < window)
    s1 = _frozen_cache_stats(q[:, 0], cache["k"], cache["v"], froz_mask,
                             mesh=inp.mesh)
    s2 = ops.decode_attention_stats(q[:, 0], hot_k, hot_v, kv_mask=hm)
    out = ops.merge_attention_stats([s1, s2]).astype(h1.dtype)
    out = linear(out.reshape(B, 1, a.q_dim), p["wo"])
    new_cache = dict(cache)
    new_cache.update({"hot_k": hot_k, "hot_v": hot_v, "hot_pos": hot_pos,
                      "hot_mask": hot_mask})
    return out, new_cache
