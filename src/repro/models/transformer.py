"""Unified decoder(-encoder) stack covering all ten assigned architectures.

One parameter tree + three entry points:

* ``prefill``      — full-sequence forward with optional lookahead rows,
                     per-layer importance scoring, and in-scan KV eviction.
                     Used for the LookaheadKV training passes (GT pass and
                     lookahead pass), plain LM training, and the deprecated
                     bucketed serving path.
* ``prefill_chunk`` / ``prefill_finalize`` — streaming prefill: fixed
                     (B, chunk) blocks with online score accumulation, one
                     eviction at prompt end.  The serving prefill path.
* ``decode_step``  — single-token step against the (possibly evicted) cache.
* ``encode``       — whisper bidirectional encoder over stub frame embeddings.

Per-layer parameters are stacked along a leading ``L`` axis and the depth
runs under ``jax.lax.scan`` — HLO size is O(1) in depth, which keeps the
512-device dry-run compiles tractable (DESIGN.md §4).

Block composition by arch type:
    dense / vlm / moe : h += attn(ln1(h));            h += ffn|moe(ln2(h))
    ssm (mamba2)      : h += ssd(ln1(h))              (no FFN when d_ff == 0)
    hybrid (hymba)    : h += ½·(attn(u) + ssd(u)),  u = ln1(h);  h += ffn(ln2(h))
    audio (whisper)   : encoder blocks (bidir attn + ffn);
                        decoder blocks (self-attn + cross-attn + ffn)
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import EvictionConfig, ModelConfig
from repro.core import eviction as ev
from repro.core import scoring
from repro.core.lookahead import append_lookahead, lora_scale
from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.attention import AttnInputs, layer_window
from repro.models.layers import dense_init, embed_init, rms_norm
from repro.models.rope import text_mrope_positions

# Policies that derive scores from observation-row attention.
OBS_POLICIES = ("lookaheadkv", "gt_oracle", "snapkv", "pyramidkv", "tova", "h2o")
POSITION_POLICIES = ("full", "random", "streaming_llm")


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _zeros(shape, dtype):
    return jnp.zeros(shape, dtype)


def _init_layer(key, cfg: ModelConfig, *, with_cross: bool = False) -> dict:
    ks = jax.random.split(key, 6)
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    p: dict = {"ln1": _zeros((d,), dtype)}
    if cfg.uses_attention:
        p["attn"] = attn_mod.init(ks[0], cfg)
    if cfg.uses_ssm:
        p["ssm"] = ssm_mod.init(ks[1], cfg)
    if with_cross:
        p["cross"] = attn_mod.init(ks[2], cfg, cross=True)
        p["ln_cross"] = _zeros((d,), dtype)
    if cfg.moe is not None:
        p["moe"] = moe_mod.init(ks[3], cfg)
        p["ln2"] = _zeros((d,), dtype)
    elif cfg.d_ff > 0:
        p["mlp"] = mlp_mod.init(ks[4], cfg)
        p["ln2"] = _zeros((d,), dtype)
    return p


def _init_encoder_layer(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 2)
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    return {
        "ln1": _zeros((d,), dtype),
        "attn": attn_mod.init(ks[0], cfg),
        "ln2": _zeros((d,), dtype),
        "mlp": mlp_mod.init(ks[1], cfg),
    }


def init_params(key, cfg: ModelConfig) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    k_emb, k_layers, k_enc, k_head = jax.random.split(key, 4)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    with_cross = cfg.is_encoder_decoder
    layers = jax.vmap(
        lambda k: _init_layer(k, cfg, with_cross=with_cross)
    )(layer_keys)
    params = {
        "embed": embed_init(k_emb, cfg.padded_vocab, cfg.d_model, dtype),
        "layers": layers,
        "final_norm": _zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, cfg.d_model,
                                       cfg.padded_vocab, dtype)
    if cfg.is_encoder_decoder:
        ek = jax.random.split(k_enc, cfg.encoder.num_layers + 1)
        params["encoder"] = {
            "layers": jax.vmap(lambda k: _init_encoder_layer(k, cfg))(ek[:-1]),
            "pos_emb": (jax.random.normal(
                ek[-1], (cfg.encoder.num_frames, cfg.d_model), jnp.float32
            ) * 0.02).astype(dtype),
            "final_norm": _zeros((cfg.d_model,), dtype),
        }
    return params


def is_global_flags(cfg: ModelConfig) -> Optional[np.ndarray]:
    """Per-layer bool array for local:global patterns, or None if uniform."""
    if cfg.attn is None:
        return None
    a = cfg.attn
    if a.global_layers:
        f = np.zeros(cfg.num_layers, bool)
        f[list(a.global_layers)] = True
        return f
    if a.global_every > 0:
        idx = np.arange(cfg.num_layers)
        return (idx % a.global_every) == (a.global_every - 1)
    return None


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed(params: dict, cfg: ModelConfig, inputs: jnp.ndarray) -> jnp.ndarray:
    if cfg.embeds_in and jnp.issubdtype(inputs.dtype, jnp.floating):
        return inputs.astype(jnp.dtype(cfg.dtype))
    return jnp.take(params["embed"], inputs, axis=0)


def unembed(params: dict, cfg: ModelConfig, h: jnp.ndarray) -> jnp.ndarray:
    """Logits over the *padded* vocab; pad rows masked to -inf (they carry
    zero probability under softmax/argmax/categorical)."""
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        w = params["embed"]
        logits = jnp.einsum("...d,vd->...v", h, w).astype(jnp.float32)
    else:
        logits = (h @ params["lm_head"]).astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask, -1e30, logits)
    return logits


# ---------------------------------------------------------------------------
# Whisper encoder
# ---------------------------------------------------------------------------


def encode(params: dict, cfg: ModelConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, F, D) stub embeddings -> (B, F, D) encoder states."""
    enc = params["encoder"]
    h = frames.astype(jnp.dtype(cfg.dtype)) + enc["pos_emb"][None]
    a = cfg.attn
    B, F, _ = h.shape
    inp = AttnInputs(positions=jnp.broadcast_to(jnp.arange(F), (B, F)))

    def body(h, lp):
        u = rms_norm(h, lp["ln1"], cfg.norm_eps)
        out, *_ = attn_mod.prefill_attention(
            lp["attn"], a, u, inp, causal=False, rotary=False
        )
        h = h + out
        u = rms_norm(h, lp["ln2"], cfg.norm_eps)
        h = h + mlp_mod.apply(lp["mlp"], cfg, u)
        return h, None

    h, _ = jax.lax.scan(body, h, enc["layers"])
    return rms_norm(h, enc["final_norm"], cfg.norm_eps)


def encode_cross_kv(params: dict, cfg: ModelConfig, h_enc: jnp.ndarray):
    """Stacked (L, B, Se, KV, hd) cross K/V for every decoder layer."""
    a = cfg.attn
    cross = params["layers"]["cross"]
    return jax.vmap(lambda cp: attn_mod.encode_kv(cp, a, h_enc))(cross)


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------


class PrefillResult(NamedTuple):
    logits: Optional[jnp.ndarray]  # (B, V) last-real-row logits, or (B, S, V)
    cache: Any  # decode cache pytree or None
    scores: Optional[jnp.ndarray]  # (L, B, H, n_score) f32
    aux: jnp.ndarray  # MoE load-balance loss (scalar f32)


def _policy_budget_schedule(cfg: ModelConfig, policy: str, budget: int,
                            beta: float):
    L = cfg.num_layers
    if policy == "pyramidkv":
        budgets = ev.pyramid_budgets(L, budget, beta)
        capacity = int(2.0 * beta / (beta + 1.0) * budget) + 1
    else:
        budgets = ev.uniform_budgets(L, budget)
        capacity = budget
    return budgets, capacity


def prefill(
    params: dict,
    cfg: ModelConfig,
    inputs: jnp.ndarray,  # (B, S) int tokens or (B, S, D) embeds
    *,
    lkv_params: Optional[dict] = None,  # lookahead rows + selective LoRA on
    policy: Optional[str] = None,  # eviction policy; None => no attn cache
    evict: Optional[EvictionConfig] = None,
    extra_slots: int = 0,  # empty tail capacity for decode appends
    capture_scores: bool = False,  # stack per-layer per-head scores (training)
    gt_boundary: Optional[int] = None,  # GT pass: X|Y boundary in ``inputs``
    mrope_positions: Optional[jnp.ndarray] = None,  # (3, B, S)
    encoder_embeds: Optional[jnp.ndarray] = None,  # whisper (B, F, D)
    want_logits: str = "last",  # "last" | "all" | "none"
    want_ssm_cache: bool = False,
    prompt_lens: Optional[jnp.ndarray] = None,  # (B,) true lens, <= n_real
    seeds: Optional[jnp.ndarray] = None,  # (B,) per-request seeds (random)
) -> PrefillResult:
    """``prompt_lens`` enables bucket-padded prefill (continuous-batching
    serving): inputs are right-padded to a shared bucket length, and every
    consumer of the padded rows is masked — they are invalid attention keys,
    carry zero eviction score, and never enter the decode cache.  Appended
    observation rows take positions after each request's *true* length, so
    the lookaheadkv scoring pass is exact under padding (its observation
    queries are learned rows at static offsets, unlike the sliding
    observation windows of the snapkv-family baselines, which become
    approximate for padded requests)."""
    a = cfg.attn
    lk = cfg.lookahead
    evict = evict or EvictionConfig()
    use_lookahead_rows = (policy == "lookaheadkv") or (
        capture_scores and lkv_params is not None and gt_boundary is None
    )
    if prompt_lens is not None:
        assert not cfg.uses_ssm and not cfg.is_encoder_decoder, \
            "bucket-padded prefill supports attention-only archs"
        assert gt_boundary is None, "prompt_lens and gt_boundary are exclusive"

    h = embed(params, cfg, inputs)
    B, n_real = h.shape[:2]
    lookahead_mask = None
    if use_lookahead_rows:
        assert lkv_params is not None, "lookaheadkv needs trained modules"
        h, lookahead_mask = append_lookahead(h, lkv_params)
    S = h.shape[1]
    col = jnp.arange(S)
    positions = jnp.broadcast_to(col, (B, S))
    key_valid = None  # (B, S) valid-key mask under bucket padding
    if prompt_lens is not None:
        pl = prompt_lens.astype(jnp.int32)
        # observation rows sit right after each request's true prompt, not
        # after the padding, so their rotary positions match unpadded prefill
        positions = jnp.where(col[None, :] < n_real, positions,
                              pl[:, None] + (col[None, :] - n_real))
        key_valid = (col[None, :] < pl[:, None]) | (col[None, :] >= n_real)
    mrope = None
    if a is not None and a.mrope:
        if mrope_positions is None:
            mrope = text_mrope_positions(positions)
        elif mrope_positions.shape[2] < S:  # extend for lookahead rows
            extra = S - mrope_positions.shape[2]
            mx = mrope_positions.max(axis=2, keepdims=True)
            ext = mx + 1 + jnp.broadcast_to(jnp.arange(extra), (3, B, extra))
            mrope = jnp.concatenate([mrope_positions, ext], axis=2)
        else:
            mrope = mrope_positions

    # --- score/eviction geometry (static) ---
    needs_scores = capture_scores or (policy in OBS_POLICIES)
    obs_policy = policy if policy in OBS_POLICIES else None
    if capture_scores and obs_policy is None:
        obs_policy = "gt_oracle" if gt_boundary is not None else "lookaheadkv"
    window_size = lk.window_size if lk else 32
    if obs_policy in ("lookaheadkv",):
        boundary = n_real  # obs rows appended after the real prompt
    elif obs_policy == "gt_oracle":
        assert gt_boundary is not None
        boundary = gt_boundary
    elif obs_policy in ("snapkv", "pyramidkv"):
        boundary = S - window_size
    elif obs_policy == "tova":
        boundary = S - 1
    elif obs_policy == "h2o":
        boundary = S
    else:
        boundary = S
    n_obs = S - boundary if obs_policy != "h2o" else S
    n_keys = boundary if obs_policy in ("lookaheadkv", "gt_oracle") else n_real
    do_evict = policy is not None and cfg.uses_attention
    adaptive_heads = (do_evict and evict.head_alloc == "adaptive"
                      and policy not in ("full",))
    if do_evict:
        budgets, _ = _policy_budget_schedule(
            cfg, policy, evict.budget if policy != "full" else n_keys,
            evict.pyramid_beta,
        )
        # the one source of truth for cache depth — the serving engines size
        # their live slot caches with the same function
        capacity = decode_cache_capacity(cfg, policy, evict, n_keys_max=n_keys)
    else:
        budgets = jnp.zeros((cfg.num_layers,), jnp.int32)
        capacity = 0

    # hybrid archs need their recurrent cache whenever a decode cache is built
    want_ssm_cache = want_ssm_cache or (do_evict and cfg.uses_ssm)

    flags = is_global_flags(cfg)
    patterned = flags is not None
    ls = lora_scale(cfg) if (lkv_params is not None and use_lookahead_rows) else 1.0
    lora_tree = (lkv_params or {}).get("lora") if use_lookahead_rows else None

    inp = AttnInputs(
        positions=positions, mrope_positions=mrope,
        lookahead_mask=lookahead_mask,
    )

    # whisper: run encoder once, stack cross K/V as scan xs
    cross_kv = None
    if cfg.is_encoder_decoder:
        assert encoder_embeds is not None, "whisper needs frame embeddings"
        h_enc = encode(params, cfg, encoder_embeds)
        cross_kv = encode_cross_kv(params, cfg, h_enc)

    xs: dict = {"p": params["layers"]}
    if lora_tree is not None:
        xs["lora"] = lora_tree
    if patterned:
        xs["flag"] = jnp.asarray(flags)
    if do_evict:
        xs["budget"] = budgets
    if cross_kv is not None:
        xs["ck"], xs["cv"] = cross_kv

    def body(h, x):
        lp = x["p"]
        lora_l = x.get("lora")
        flag = x.get("flag", True)
        ys: dict = {}
        q = k = v = None
        if cfg.uses_attention or cfg.uses_ssm:
            u = rms_norm(h, lp["ln1"], cfg.norm_eps)
            delta = 0.0
            if cfg.uses_attention:
                a_out, q, k, v = attn_mod.prefill_attention(
                    lp["attn"], a, u, inp, is_global=flag,
                    lora=None if lora_l is None else lora_l.get("attn"),
                    lora_scale=ls, kv_mask=key_valid,
                )
                delta = delta + a_out
            if cfg.uses_ssm:
                # Observation rows (lookahead tokens or a draft suffix) must
                # not pollute the cached recurrent state: run the real prompt
                # first, cache its state, then chain the observation segment.
                split = None
                if use_lookahead_rows:
                    split = n_real
                elif gt_boundary is not None:
                    split = gt_boundary
                if split is not None and split < S:
                    s_out1, ssm_cache = ssm_mod.apply(
                        lp["ssm"], cfg, u[:, :split]
                    )
                    s_out2, _ = ssm_mod.apply(
                        lp["ssm"], cfg, u[:, split:],
                        lora=(lora_l.get("ssm")
                              if (lora_l and use_lookahead_rows) else None),
                        lora_mask=jnp.ones((B, S - split, 1), u.dtype),
                        lora_scale=ls,
                        initial_state=ssm_cache["state"],
                        conv_tail=ssm_cache["conv"],
                    )
                    s_out = jnp.concatenate([s_out1, s_out2], axis=1)
                else:
                    s_out, ssm_cache = ssm_mod.apply(lp["ssm"], cfg, u)
                delta = delta + s_out
                if want_ssm_cache:
                    ys["ssm"] = ssm_cache
            if cfg.hybrid:
                delta = delta * 0.5
            h = h + delta
        if cfg.is_encoder_decoder:
            u_cross = rms_norm(h, lp["ln_cross"], cfg.norm_eps)
            h = h + attn_mod.cross_attention(
                lp["cross"], a, u_cross, x["ck"], x["cv"],
                lora=None if lora_l is None else lora_l.get("cross"),
                lora_mask=lookahead_mask, lora_scale=ls,
            )
            if do_evict and evict.cross_budget > 0 and n_obs > 0:
                # beyond-paper: evict the *encoder* KV with the same
                # observation queries (non-causal: all frames visible)
                B_, Se = u_cross.shape[0], x["ck"].shape[1]
                qc = attn_mod.linear(
                    u_cross[:, boundary:], lp["cross"]["wq"],
                    lp["cross"].get("bq"),
                ).reshape(B_, -1, a.num_heads, a.head_dim)
                sc = scoring.observation_scores(qc, x["ck"], Se, q_offset=Se)
                sc = scoring.postprocess(
                    sc, a.num_kv_heads, lk.pool_kernel if lk else 7)
                ys["cross_cache"] = dict(ev.evict_layer(
                    sc, x["ck"], x["cv"], min(evict.cross_budget, Se)
                )._asdict())
        h, ys["aux"] = _ffn_residual(h, lp, cfg, lora_l=lora_l,
                                     lora_mask=lookahead_mask, ls=ls)

        # ---- scoring + eviction (attention archs only) ----
        if cfg.uses_attention and needs_scores and obs_policy is not None:
            win = layer_window(a, flag)
            if obs_policy == "h2o":
                s_qh = scoring.observation_scores(
                    q, k, n_keys, window=win, q_offset=0,
                    kv_mask=None if key_valid is None else key_valid[:, :n_keys],
                )
            else:
                s_qh = scoring.observation_scores(
                    q[:, boundary:], k, boundary, window=win,
                    kv_mask=None if key_valid is None else key_valid[:, :boundary],
                )
            if capture_scores:
                ys["scores"] = s_qh
        if do_evict and cfg.uses_attention:
            prompt_valid = None if key_valid is None else key_valid[:, :n_keys]
            if policy in OBS_POLICIES:
                s_kv = scoring.postprocess(
                    s_qh, a.num_kv_heads, lk.pool_kernel if lk else 7
                )
                if policy in ("snapkv", "pyramidkv", "tova"):
                    # scored keys cover [0, boundary); force-keep the window
                    pad = n_keys - s_kv.shape[-1]
                    if pad > 0:
                        s_kv = jnp.pad(s_kv, ((0, 0), (0, 0), (0, pad)))
                    s_kv = ev.keep_window(s_kv, S - boundary)
            else:
                s_kv = ev.position_scores(
                    policy, n_keys, B, a.num_kv_heads, sink=evict.sink,
                    seeds=seeds,
                )
            if prompt_valid is not None:
                # padded keys rank last (max-pool may have bled real-neighbour
                # mass into them) and are masked out of the cache regardless
                s_kv = jnp.where(prompt_valid[:, None, :], s_kv, -1e30)
            hb = None
            if adaptive_heads:
                # -1e30 pad sentinels would corrupt the head-mass totals
                s_mass = s_kv if prompt_valid is None else jnp.maximum(s_kv, 0.0)
                hb = ev.adaptive_head_budgets(s_mass, evict.budget, capacity)
            cache_l = ev.evict_layer(
                s_kv, k[:, :n_keys], v[:, :n_keys], capacity,
                layer_budget=None if adaptive_heads else x.get("budget"),
                head_budgets=hb, extra_slots=extra_slots,
                key_mask=prompt_valid,
            )
            ys["cache"] = dict(cache_l._asdict())
        return h, ys

    h, ys = jax.lax.scan(body, h, xs)

    scores = ys.get("scores") if capture_scores else None
    aux = ys["aux"].sum()

    cache = None
    if do_evict or (want_ssm_cache and cfg.uses_ssm):
        cache = {}
        if "cache" in ys:
            cache["attn"] = ys["cache"]
            cache["cursor"] = jnp.asarray(capacity + 0, jnp.int32)
        if "ssm" in ys:
            cache["ssm"] = ys["ssm"]
        if cross_kv is not None:
            if "cross_cache" in ys:
                cache["cross"] = ys["cross_cache"]
            else:
                cache["cross"] = {"k": xs["ck"], "v": xs["cv"]}
        if prompt_lens is not None:
            cache["next_pos"] = pl[:, None]
        else:
            next_pos = gt_boundary if gt_boundary is not None else n_real
            cache["next_pos"] = jnp.full((B, 1), next_pos, jnp.int32)

    logits = None
    if want_logits == "last":
        # for GT/draft-scoring passes the "current" position is the X|Y
        # boundary, not the end of the appended observation rows
        if prompt_lens is not None:  # last *real* row per request
            logits = unembed(params, cfg, h[jnp.arange(B), pl - 1])
        else:
            row = (gt_boundary if gt_boundary is not None else n_real) - 1
            logits = unembed(params, cfg, h[:, row])
    elif want_logits == "all":
        logits = unembed(params, cfg, h[:, :n_real])
    return PrefillResult(logits=logits, cache=cache, scores=scores, aux=aux)


# ---------------------------------------------------------------------------
# Chunked prefill (streaming eviction scores)
# ---------------------------------------------------------------------------
#
# ``prefill`` above runs the whole prompt as one program — one compile per
# (prompt-bucket, batch) shape, and a long prompt monopolizes the device for
# its whole forward pass.  The chunked path streams fixed-size (B, chunk)
# token blocks instead:
#
#   * each chunk projects its K/V and appends them into a materialized
#     prompt buffer (``attention.chunk_prefill_attention`` — cross-chunk
#     flash attention over prior keys + causal self-attention, with a
#     *traced* chunk offset, so one compiled program serves every chunk of
#     every prompt length);
#   * a per-policy ``ScoreState`` (core/scoring.py) accumulates eviction
#     scores online — h2o sums per-key column masses chunk by chunk, taking
#     them directly from the attention kernel's *fused* second output
#     (``ops.chunk_attention(..., score_masses=True)``; no dense (C, K)
#     probability block on the hot path), the snapkv/pyramidkv/tova family
#     rolls the newest observation-window queries, and lookaheadkv/
#     gt_oracle defer to a final observation pass — both scored at prompt
#     end through the masked streaming ``ops.lookahead_score`` primitive;
#   * ``prefill_finalize`` runs the *same* ``evict_layer`` once at prompt
#     end, so the evicted cache matches monolithic prefill exactly (same
#     kept (layer, head, position) sets; logits bitwise on the reference
#     path, within fp tolerance otherwise).
#
# Chunked prefill serves attention(-plus-FFN/MoE) decoder-only archs — the
# same family the continuous-batching engine admits.


class ChunkState(NamedTuple):
    """Carried state of a streaming prefill: the materialized prompt KV and
    the policy's streaming score accumulator.  Buffer depth ``K`` bounds
    the prompt (plus observation rows) — it is HBM that limits prompt
    length, not a compile-time bucket table."""

    k: jnp.ndarray  # (L, B, K, KV, hd) prompt keys; col j = position j
    v: jnp.ndarray  # (L, B, K, KV, hd)
    score: scoring.ScoreState
    pos: jnp.ndarray  # () int32 — tokens streamed so far


def chunkable(cfg: ModelConfig) -> bool:
    a = cfg.attn
    return (cfg.uses_attention and not cfg.uses_ssm
            and not cfg.is_encoder_decoder and not a.mrope
            and not cfg.embeds_in)


def init_chunk_state(cfg: ModelConfig, policy: str, batch: int,
                     capacity: int) -> ChunkState:
    """Fresh streaming-prefill state with a ``capacity``-deep KV buffer.

    ``capacity`` must cover the prompt *plus* any appended observation rows
    (lookaheadkv's learned rows / gt_oracle's response suffix)."""
    assert chunkable(cfg), "chunked prefill serves attention-only archs"
    a = cfg.attn
    lk = cfg.lookahead
    dtype = jnp.dtype(cfg.dtype)
    L = cfg.num_layers
    kv = jnp.zeros((L, batch, capacity, a.num_kv_heads, a.head_dim), dtype)
    score = scoring.init_score_state(
        policy, L, batch, a.num_heads, a.head_dim, capacity,
        window_size=lk.window_size if lk else 32, dtype=dtype,
    )
    return ChunkState(k=kv, v=jnp.zeros_like(kv), score=score,
                      pos=jnp.zeros((), jnp.int32))


def snapshot_chunk_state(state: ChunkState, n: int) -> ChunkState:
    """Chunk-boundary snapshot of a streaming prefill: the first ``n``
    buffer columns of K/V plus the trimmed ``ScoreState`` — everything a
    later request sharing this ``n``-token prompt prefix needs to resume
    at ``pos = n``.

    Soundness (why the snapshot is shareable): every ``prefill_chunk``
    quantity at a boundary covered by *full* chunks is a pure function of
    the prefix tokens alone — attention is causal, the traced ``n_total``
    only gates rows at or past it (all prefix rows are valid whenever the
    requesting prompt is at least ``n`` long), and per-request seeds enter
    only at finalize.  So the snapshot taken while serving one request is
    bit-identical to the state any other request would have computed for
    the same prefix, at the same buffer capacity."""
    assert n <= state.k.shape[2], "snapshot deeper than the KV buffer"
    return ChunkState(
        k=state.k[:, :, :n], v=state.v[:, :, :n],
        score=state.score.snapshot(n), pos=jnp.asarray(n, jnp.int32),
    )


def resume_chunk_state(snap: ChunkState, capacity: int) -> ChunkState:
    """Inverse of ``snapshot_chunk_state``: zero-pad the trimmed buffers
    back to ``capacity`` (fresh buffers are zero-initialized, so the
    restored state is bitwise the state a request would have reached by
    streaming the prefix itself) and resume at ``pos = n``."""
    n = snap.k.shape[2]
    assert capacity >= n, f"capacity {capacity} < snapshot depth {n}"
    width = [(0, 0), (0, 0), (0, capacity - n), (0, 0), (0, 0)]
    return ChunkState(
        k=jnp.pad(snap.k, width), v=jnp.pad(snap.v, width),
        score=snap.score.restore(capacity), pos=jnp.asarray(n, jnp.int32),
    )


def _ffn_residual(h, lp, cfg: ModelConfig, *, lora_l=None, lora_mask=None,
                  ls: float = 1.0, smesh=None):
    """The post-attention half of a block (MoE or MLP residual) — the one
    definition shared by monolithic prefill, the chunk step, the
    observation pass (which thread the lookahead LoRA), and decode.
    Returns (h, aux) where aux is the MoE load-balance loss (zero
    otherwise).  With ``smesh`` (tensor-sharded serving) every FFN dot
    must keep the single-device summation order — GSPMD's realization is
    shape-dependent, so the dense MLP runs manual column-parallel TP
    (``mlp.apply_sharded``) and MoE runs replicated under shard_map
    (``attention.replicated_apply`` — exact but redundant; sharded-exact
    MoE dispatch is out of scope)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe is not None:
        u = rms_norm(h, lp["ln2"], cfg.norm_eps)
        moe_lora = None
        if lora_l is not None and lora_l.get("moe"):
            moe_lora = lora_l["moe"].get("shared")
        apply = (moe_mod.apply_sparse if cfg.moe.dispatch == "sparse"
                 else moe_mod.apply)
        fn = lambda pp, uu, lo, lm: apply(pp, cfg, uu, lora=lo,
                                          lora_mask=lm, lora_scale=ls)
        if smesh is not None:
            mo, aux = attn_mod.replicated_apply(
                fn, smesh, lp["moe"], u, moe_lora, lora_mask)
        else:
            mo, aux = fn(lp["moe"], u, moe_lora, lora_mask)
        h = h + mo
    elif cfg.d_ff > 0:
        u = rms_norm(h, lp["ln2"], cfg.norm_eps)
        mlp_lora = None if lora_l is None else lora_l.get("mlp")
        mo = mlp_mod.apply_sharded(lp["mlp"], cfg, u, smesh, lora=mlp_lora,
                                   lora_mask=lora_mask, lora_scale=ls)
        h = h + mo
    return h, aux


def prefill_chunk(
    params: dict,
    cfg: ModelConfig,
    state: ChunkState,
    tokens: jnp.ndarray,  # (B, chunk) int tokens; rows past n_total are pad
    n_total: jnp.ndarray,  # () int32 — true prompt length (shared across B)
    *,
    policy: str,
    mesh=None,  # serving mesh: per-shard head dispatch in the chunk kernel
) -> tuple[ChunkState, jnp.ndarray]:
    """Process one fixed-size prompt chunk starting at ``state.pos``.

    Returns (state', logits (B, V) of the chunk's last *real* row) — the
    caller keeps the final chunk's logits as the prompt's next-token
    distribution.  Pad rows in a partial final chunk are harmless: causal
    masking hides their keys from every real row, they carry zero score
    weight, and the finalize step masks their buffer columns out of the
    cache.
    """
    a = cfg.attn
    assert chunkable(cfg), "chunked prefill serves attention-only archs"
    h = embed(params, cfg, tokens)
    B, C = h.shape[:2]
    s = state.pos
    positions = jnp.broadcast_to(s + jnp.arange(C), (B, C))
    inp = AttnInputs(positions=positions, mesh=mesh)
    smesh = attn_mod.model_shard_mesh(mesh, a)
    flags = is_global_flags(cfg)

    xs: dict = {"p": params["layers"], "k": state.k, "v": state.v}
    if flags is not None:
        xs["flag"] = jnp.asarray(flags)
    if state.score.acc is not None:
        xs["acc"] = state.score.acc
    if state.score.qbuf is not None:
        xs["qbuf"] = state.score.qbuf

    # cumulative policies take their per-chunk column-mass partials straight
    # from the attention kernel's fused second output — no dense score block
    want_masses = policy in scoring.STREAMING_CUMULATIVE

    def body(h, x):
        lp = x["p"]
        flag = x.get("flag", True)
        h = attn_mod.pin_activations(h, mesh)
        u = rms_norm(h, lp["ln1"], cfg.norm_eps)
        out, q, k_buf, v_buf, masses = attn_mod.chunk_prefill_attention(
            lp["attn"], a, u, inp, x["k"], x["v"], q_offset=s,
            is_global=flag, score_masses=want_masses, n_total=n_total,
        )
        h = h + out
        h, _ = _ffn_residual(h, lp, cfg, smesh=smesh)
        ys: dict = {"k": k_buf, "v": v_buf}
        acc_l, qbuf_l = scoring.update_layer_scores(
            policy, x.get("acc"), x.get("qbuf"), q, masses_l=masses,
            q_offset=s, n_total=n_total,
        )
        if acc_l is not None:
            ys["acc"] = acc_l
        if qbuf_l is not None:
            ys["qbuf"] = qbuf_l
        return h, ys

    h, ys = jax.lax.scan(body, h, xs)

    score = state.score
    if score.acc is not None:
        score = score._replace(
            acc=ys["acc"],
            cnt=score.cnt + jnp.clip(n_total - s, 0, C).astype(jnp.float32),
        )
    if score.qbuf is not None:
        score = score._replace(qbuf=ys["qbuf"])
    row = jnp.clip(n_total - 1 - s, 0, C - 1)
    logits = unembed(params, cfg, h[jnp.arange(B), row])
    return (
        ChunkState(k=ys["k"], v=ys["v"], score=score, pos=s + C),
        logits,
    )


def _chunk_observation_pass(
    params: dict,
    cfg: ModelConfig,
    state: ChunkState,
    n_total: jnp.ndarray,
    *,
    policy: str,
    lkv_params: Optional[dict],
    obs_tokens: Optional[jnp.ndarray],
    mesh=None,
):
    """Final-chunk observation forward for lookaheadkv / gt_oracle: run the
    observation rows (learned lookahead rows / the GT response suffix)
    through the stack against the materialized prompt KV, appending their
    keys after the prompt so each row's softmax includes the observation
    keys exactly as in monolithic prefill.  Returns (k_buf, v_buf,
    obs_masses (L, B, H, K))."""
    a = cfg.attn
    B = state.k.shape[1]
    if policy == "lookaheadkv":
        assert lkv_params is not None, "lookaheadkv needs trained modules"
        emb = lkv_params["emb"].astype(jnp.dtype(cfg.dtype))
        n_obs = emb.shape[0]
        h = jnp.broadcast_to(emb[None], (B, n_obs, emb.shape[1]))
        lora_tree = lkv_params.get("lora")
        ls = lora_scale(cfg)
        lmask = jnp.ones((B, n_obs, 1), h.dtype)
    else:  # gt_oracle: the response rows are the observation window
        assert obs_tokens is not None, "gt_oracle needs the response rows"
        h = embed(params, cfg, obs_tokens)
        n_obs = h.shape[1]
        lora_tree, ls, lmask = None, 1.0, None
    positions = jnp.broadcast_to(n_total + jnp.arange(n_obs), (B, n_obs))
    inp = AttnInputs(positions=positions, lookahead_mask=lmask, mesh=mesh)
    smesh = attn_mod.model_shard_mesh(mesh, a)
    flags = is_global_flags(cfg)

    xs: dict = {"p": params["layers"], "k": state.k, "v": state.v}
    if lora_tree is not None:
        xs["lora"] = lora_tree
    if flags is not None:
        xs["flag"] = jnp.asarray(flags)

    K = state.k.shape[2]

    def body(h, x):
        lp = x["p"]
        lora_l = x.get("lora")
        flag = x.get("flag", True)
        h = attn_mod.pin_activations(h, mesh)
        u = rms_norm(h, lp["ln1"], cfg.norm_eps)
        out, q, k_buf, v_buf, _ = attn_mod.chunk_prefill_attention(
            lp["attn"], a, u, inp, x["k"], x["v"], q_offset=n_total,
            is_global=flag,
            lora=None if lora_l is None else lora_l.get("attn"),
            lora_scale=ls,
        )
        h = h + out
        h, _ = _ffn_residual(h, lp, cfg, lora_l=lora_l, lora_mask=lmask,
                             ls=ls, smesh=smesh)
        # the masked streaming primitive scores the observation rows over
        # the whole buffer (mean over the n_obs rows, traced row base)
        masses = attn_mod.sharded_lookahead_score(
            q, k_buf, K, q_offset=n_total, window=layer_window(a, flag),
            smesh=smesh,
        )
        return h, {"k": k_buf, "v": v_buf, "obs": masses}

    _, ys = jax.lax.scan(body, h, xs)
    return ys["k"], ys["v"], ys["obs"]


def prefill_finalize(
    params: dict,
    cfg: ModelConfig,
    state: ChunkState,
    n_total: jnp.ndarray,  # () int32 true prompt length
    *,
    policy: str,
    evict: Optional[EvictionConfig] = None,
    lkv_params: Optional[dict] = None,
    obs_tokens: Optional[jnp.ndarray] = None,  # (B, n_obs) gt_oracle only
    extra_slots: int = 0,
    seeds: Optional[jnp.ndarray] = None,  # (B,) request seeds (random policy)
    mesh=None,  # serving mesh: per-shard observation / window scoring
) -> dict:
    """Close a streaming prefill: run the deferred observation pass (if the
    policy has one), turn the accumulated ``ScoreState`` into eviction
    scores, and run ``evict_layer`` once per layer over the materialized
    buffer — producing the same decode-cache pytree as monolithic
    ``prefill`` (same kept slots; shapes sized by the buffer depth, with
    surplus slots masked invalid)."""
    a = cfg.attn
    lk = cfg.lookahead
    evict = evict or EvictionConfig()
    L, B, K = state.k.shape[:3]
    kbuf, vbuf = state.k, state.v
    obs_masses = None
    if policy in scoring.FINAL_OBS:
        kbuf, vbuf, obs_masses = _chunk_observation_pass(
            params, cfg, state, n_total, policy=policy,
            lkv_params=lkv_params, obs_tokens=obs_tokens, mesh=mesh,
        )
    smesh = attn_mod.model_shard_mesh(mesh, a)
    budgets, _ = _policy_budget_schedule(
        cfg, policy, evict.budget if policy != "full" else K,
        evict.pyramid_beta,
    )
    capacity = decode_cache_capacity(cfg, policy, evict, n_keys_max=K)
    adaptive = evict.head_alloc == "adaptive" and policy not in ("full",)
    key_mask = jnp.broadcast_to(jnp.arange(K)[None] < n_total, (B, K))
    flags = is_global_flags(cfg)

    xs: dict = {"k": kbuf, "v": vbuf, "budget": budgets}
    if flags is not None:
        xs["flag"] = jnp.asarray(flags)
    if state.score.acc is not None:
        xs["acc"] = state.score.acc
    if state.score.qbuf is not None:
        xs["qbuf"] = state.score.qbuf
    if obs_masses is not None:
        xs["obs"] = obs_masses

    def body(carry, x):
        flag = x.get("flag", True)
        if policy in OBS_POLICIES:
            s_kv = scoring.finalize_layer_scores(
                policy, x["k"], n_total,
                acc_l=x.get("acc"), cnt=state.score.cnt,
                qbuf_l=x.get("qbuf"), obs_masses_l=x.get("obs"),
                num_kv_heads=a.num_kv_heads,
                pool_kernel=lk.pool_kernel if lk else 7,
                window_size=lk.window_size if lk else 32,
                window=layer_window(a, flag),
                smesh=smesh,
            )
        else:
            s_kv = ev.position_scores(
                policy, K, B, a.num_kv_heads, sink=evict.sink, seeds=seeds,
            )
            s_kv = jnp.where(key_mask[:, None, :], s_kv, -1e30)
        hb = None
        if adaptive:
            hb = ev.adaptive_head_budgets(
                jnp.maximum(s_kv, 0.0), evict.budget, capacity)
        cache_l = ev.evict_layer(
            s_kv, x["k"], x["v"], capacity,
            layer_budget=None if adaptive else x.get("budget"),
            head_budgets=hb, extra_slots=extra_slots, key_mask=key_mask,
        )
        return carry, dict(cache_l._asdict())

    _, attn_cache = jax.lax.scan(body, 0, xs)
    return {
        "attn": attn_cache,
        "cursor": jnp.asarray(capacity, jnp.int32),
        "next_pos": jnp.broadcast_to(n_total, (B, 1)).astype(jnp.int32),
    }


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def init_decode_cache(
    cfg: ModelConfig, batch: int, capacity: int, *, fill_len: int = 0,
    hot_slots: int = 0, per_slot_cursor: bool = False,
) -> dict:
    """Fresh cache pytree (used directly and via jax.eval_shape for the
    dry-run ShapeDtypeStructs).  ``fill_len`` marks the first slots valid —
    decode-shape dry-runs model a cache already holding ``seq_len`` tokens.

    ``per_slot_cursor`` gives every batch row (serving slot) its own append
    cursor — the continuous-batching layout where slots admit and retire
    requests independently."""
    dtype = jnp.dtype(cfg.dtype)
    L = cfg.num_layers
    cache: dict = {}
    if cfg.uses_attention:
        a = cfg.attn
        KV, hd = a.num_kv_heads, a.head_dim
        valid = jnp.arange(capacity) < fill_len
        cache["attn"] = {
            "k": jnp.zeros((L, batch, capacity, KV, hd), dtype),
            "v": jnp.zeros((L, batch, capacity, KV, hd), dtype),
            "pos": jnp.broadcast_to(
                jnp.arange(capacity, dtype=jnp.int32)[None, None, :, None],
                (L, batch, capacity, KV),
            ),
            "mask": jnp.broadcast_to(
                valid[None, None, :, None], (L, batch, capacity, KV)
            ),
        }
        cache["cursor"] = (
            jnp.full((batch,), fill_len, jnp.int32) if per_slot_cursor
            else jnp.asarray(fill_len, jnp.int32)
        )
        if hot_slots:
            assert not per_slot_cursor, \
                "split-cache decode uses the shared hot-ring counter"
            # split-cache decode: frozen prompt cache + replicated hot ring
            cache["attn"]["hot_k"] = jnp.zeros((L, batch, hot_slots, KV, hd),
                                               dtype)
            cache["attn"]["hot_v"] = jnp.zeros((L, batch, hot_slots, KV, hd),
                                               dtype)
            cache["attn"]["hot_pos"] = jnp.zeros((L, batch, hot_slots, KV),
                                                 jnp.int32)
            cache["attn"]["hot_mask"] = jnp.zeros((L, batch, hot_slots, KV),
                                                  bool)
            cache["cursor"] = jnp.asarray(0, jnp.int32)  # hot-ring counter
    if cfg.uses_ssm:
        s, di, nh, conv_dim = ssm_mod.dims(cfg)
        cache["ssm"] = {
            "conv": jnp.zeros((L, batch, s.conv_width - 1, conv_dim), dtype),
            "state": jnp.zeros((L, batch, nh, s.head_dim, s.d_state), jnp.float32),
        }
    if cfg.is_encoder_decoder:
        a = cfg.attn
        cache["cross"] = {
            "k": jnp.zeros((L, batch, cfg.encoder.num_frames, a.num_kv_heads,
                            a.head_dim), dtype),
            "v": jnp.zeros((L, batch, cfg.encoder.num_frames, a.num_kv_heads,
                            a.head_dim), dtype),
        }
    cache["next_pos"] = jnp.full((batch, 1), fill_len, jnp.int32)
    return cache


def add_decode_eviction_scores(cache: dict) -> dict:
    """Arm a decode cache for decoding-stage eviction (beyond-paper; see
    attention.decode_attention_step_evicting): valid prefill slots start
    with unit cumulative score — they already won prefill eviction."""
    attn = dict(cache["attn"])
    attn["score"] = cache["attn"]["mask"].astype(jnp.float32)
    out = dict(cache)
    out["attn"] = attn
    return out


# ---------------------------------------------------------------------------
# Slot-cache surgery (continuous-batching serving)
# ---------------------------------------------------------------------------
#
# Post-eviction decode caches are shape-uniform across prompt lengths — every
# request's cache is (budget + margin) slots regardless of n_in.  That is the
# property the continuous-batching engine exploits: a freshly prefilled
# request's cache pytree can be scattered into any free slot of the live
# slot-batched cache, mid-stream, without reshaping anything.

_SLOT_AXIS_0 = ("next_pos", "cursor")  # every other top-level group is (L, B, …)


def _slot_axis(path) -> int:
    top = None
    for p in path:
        if hasattr(p, "key"):
            top = str(p.key)
            break
    return 0 if top in _SLOT_AXIS_0 else 1


def decode_cache_capacity(cfg: ModelConfig, policy: str,
                          evict: EvictionConfig, *, n_keys_max: int) -> int:
    """Static kept-slot capacity of the decode cache that a prefill under
    ``policy`` produces for prompts up to ``n_keys_max`` tokens — the
    shape-uniformity contract the slot scheduler relies on."""
    _, capacity = _policy_budget_schedule(
        cfg, policy, evict.budget if policy != "full" else n_keys_max,
        evict.pyramid_beta,
    )
    if evict.head_alloc == "adaptive" and policy not in ("full",):
        capacity = int(evict.budget * evict.adaptive_ceiling)
    return min(capacity, n_keys_max)


def pad_cache_capacity(cache: dict, capacity: int) -> dict:
    """Right-pad the attention slot axis to ``capacity`` (mask=False): small
    buckets clamp the kept capacity below the budget, so their caches are
    shallower — padding restores the uniform live-cache shape."""
    attn = cache.get("attn")
    if attn is None:
        return cache
    C = attn["k"].shape[2]
    if C == capacity:
        return cache
    assert C < capacity, f"cache deeper ({C}) than live capacity ({capacity})"
    padded = {}
    for name, leaf in attn.items():
        if name.startswith("hot_"):
            padded[name] = leaf
            continue
        width = [(0, 0)] * leaf.ndim
        width[2] = (0, capacity - C)
        padded[name] = jnp.pad(leaf, width)
    out = dict(cache)
    out["attn"] = padded
    return out


def insert_request_cache(live: dict, req: dict, slot) -> dict:
    """Scatter a batch-1 request cache (from a bucketed prefill) into slot
    ``slot`` of the live slot-batched cache.  The request cache is
    capacity-padded first; its scalar cursor lands in the live per-slot
    cursor vector.  ``slot`` may be traced (the insert jits cleanly)."""
    if "attn" in live:
        req = pad_cache_capacity(req, live["attn"]["k"].shape[2])

    def ins(path, lv, rv):
        return jax.lax.dynamic_update_slice_in_dim(
            lv, rv.astype(lv.dtype), slot, axis=_slot_axis(path))

    out = jax.tree_util.tree_map_with_path(
        ins,
        {k: v for k, v in live.items() if k != "cursor"},
        {k: v for k, v in req.items() if k != "cursor"},
    )
    if "cursor" in live:
        out["cursor"] = jax.lax.dynamic_update_slice(
            live["cursor"],
            jnp.reshape(req["cursor"], (1,)).astype(live["cursor"].dtype),
            (slot,),
        )
    return out


def extract_request_cache(live: dict, slot) -> dict:
    """Slice slot ``slot`` back out as a batch-1 request cache — the inverse
    of ``insert_request_cache`` up to capacity padding."""

    def ext(path, lv):
        return jax.lax.dynamic_slice_in_dim(lv, slot, 1,
                                            axis=_slot_axis(path))

    out = jax.tree_util.tree_map_with_path(
        ext, {k: v for k, v in live.items() if k != "cursor"})
    if "cursor" in live:
        cur = live["cursor"]
        out["cursor"] = (jax.lax.dynamic_slice(cur, (slot,), (1,))
                         if cur.ndim else cur)
    return out


def select_cache_slots(active: jnp.ndarray, new_cache: dict,
                       old_cache: dict) -> dict:
    """Per-slot select between two structurally identical decode caches:
    slot b advances to ``new_cache`` where ``active[b]``, else keeps
    ``old_cache`` — retired / empty slots don't advance even though decode
    computes over the full slot batch."""

    def sel(path, new_leaf, old_leaf):
        if old_leaf.ndim == 0:  # legacy shared scalar cursor
            return new_leaf
        shape = [1] * new_leaf.ndim
        shape[_slot_axis(path)] = active.shape[0]
        return jnp.where(active.reshape(shape), new_leaf, old_leaf)

    return jax.tree_util.tree_map_with_path(sel, new_cache, old_cache)


def decode_step(
    params: dict,
    cfg: ModelConfig,
    token: jnp.ndarray,  # (B, 1) int tokens or (B, 1, D) embeds
    cache: dict,
    *,
    mrope_positions: Optional[jnp.ndarray] = None,  # (3, B, 1)
    mesh=None,  # enables shard_map'd frozen-cache attention (split cache)
    active: Optional[jnp.ndarray] = None,  # (B,) live slots (paged cache)
    paged_depth: Optional[int] = None,  # static dense-equivalent depth
) -> tuple[jnp.ndarray, dict]:
    """One decode step.  Returns (logits (B, V) f32, updated cache).

    A *paged* cache (``"pool"`` key — see serving/kv_pool.py) carries the
    shared per-layer block-pool arrays plus a per-slot block table under
    ``cache["attn"]["table"]``.  Because the pool is shared across slots,
    retired slots cannot be rolled back with ``select_cache_slots`` the
    way dense serving does — ``active`` gates the append scatter and the
    cursor / position advance in-step instead.  ``paged_depth`` is the
    static logical cache depth (the dense engine's capacity + margin):
    the Pallas kernel path attends in pool layout (dead rows beyond it
    are masked), while the jnp gather fallback slices its view to it so
    that path stays shape- and bit-identical to dense serving.

    Decode-time eviction scoring needs no plumbing here: when the serving
    engine threads a ``"score"`` leaf ((L, B, depth, KV) cumulative
    masses) inside ``cache["pool"]``, the layer scan slices it per layer
    like any other pool leaf and ``decode_attention_step_paged`` returns
    the accumulated copy in its cache dict, so the updated buffer rides
    ``ys`` back out with zero signature changes.
    """
    a = cfg.attn
    paged = "pool" in cache
    h = embed(params, cfg, token)
    B = h.shape[0]
    positions = cache["next_pos"]  # (B, 1)
    mrope = None
    if a is not None and a.mrope:
        mrope = (mrope_positions if mrope_positions is not None
                 else text_mrope_positions(positions))
    cursor = cache.get("cursor")
    flags = is_global_flags(cfg)
    patterned = flags is not None

    xs: dict = {"p": params["layers"]}
    if patterned:
        xs["flag"] = jnp.asarray(flags)
    if paged:
        assert paged_depth is not None, "paged decode needs its static depth"
        xs["attn_cache"] = cache["pool"]  # per-layer pool slices (L leading)
    elif cfg.uses_attention and "attn" in cache:
        xs["attn_cache"] = cache["attn"]
    if cfg.uses_ssm:
        xs["ssm_cache"] = cache["ssm"]
    cross_evicted = (cfg.is_encoder_decoder
                     and "mask" in cache.get("cross", {}))
    if cfg.is_encoder_decoder:
        if cross_evicted:
            xs["cross_cache"] = cache["cross"]
        else:
            xs["ck"] = cache["cross"]["k"]
            xs["cv"] = cache["cross"]["v"]

    inp_base = AttnInputs(
        positions=positions, mrope_positions=mrope,
        cache_cursor=cursor, mesh=mesh,
    )
    smesh = None if a is None else attn_mod.model_shard_mesh(mesh, a)

    def body(h, x):
        lp = x["p"]
        flag = x.get("flag", True)
        ys: dict = {}
        if cfg.uses_attention or cfg.uses_ssm:
            h = attn_mod.pin_activations(h, mesh)
            u = rms_norm(h, lp["ln1"], cfg.norm_eps)
            delta = 0.0
            if cfg.uses_attention and "attn_cache" in x:
                inp = inp_base._replace(cache=x["attn_cache"])
                win = layer_window(a, flag)
                if paged:
                    a_out, new_c = attn_mod.decode_attention_step_paged(
                        lp["attn"], a, u, inp, window=win,
                        table=cache["attn"]["table"], depth=paged_depth,
                        active=active)
                else:
                    if "hot_k" in x["attn_cache"]:
                        step_fn = attn_mod.decode_attention_step_split
                    elif "score" in x["attn_cache"]:
                        step_fn = attn_mod.decode_attention_step_evicting
                    else:
                        step_fn = attn_mod.decode_attention_step
                    a_out, new_c = step_fn(lp["attn"], a, u, inp, window=win)
                delta = delta + a_out
                ys["attn_cache"] = new_c
            if cfg.uses_ssm:
                s_out, new_s = ssm_mod.step(lp["ssm"], cfg, u, x["ssm_cache"])
                delta = delta + s_out
                ys["ssm_cache"] = new_s
            if cfg.hybrid:
                delta = delta * 0.5
            h = h + delta
        if cfg.is_encoder_decoder:
            u = rms_norm(h, lp["ln_cross"], cfg.norm_eps)
            if cross_evicted:
                h = h + attn_mod.cross_attention_decode_evicted(
                    lp["cross"], a, u, x["cross_cache"])
            else:
                h = h + attn_mod.cross_attention(lp["cross"], a, u,
                                                 x["ck"], x["cv"])
        h, _ = _ffn_residual(h, lp, cfg, smesh=smesh)
        return h, ys

    h, ys = jax.lax.scan(body, h, xs)
    logits = unembed(params, cfg, h[:, 0])

    new_cache = dict(cache)
    if paged:
        # pool writes were already active-gated in-step (null-routed);
        # the per-slot cursor / position advance is gated here for the
        # same reason — no post-hoc select over the shared pool exists
        new_cache["pool"] = ys["attn_cache"]
        adv_c = jnp.minimum(cursor + 1, paged_depth)
        adv_p = positions + 1
        if active is not None:
            adv_c = jnp.where(active, adv_c, cursor)
            adv_p = jnp.where(active[:, None], adv_p, positions)
        new_cache["cursor"] = adv_c
        new_cache["next_pos"] = adv_p
        return logits, new_cache
    if "attn_cache" in ys:
        new_cache["attn"] = ys["attn_cache"]
        if "hot_k" in cache["attn"]:
            new_cache["cursor"] = cursor + 1  # hot-ring counter
        else:
            cap = cache["attn"]["k"].shape[2]
            new_cache["cursor"] = jnp.minimum(cursor + 1, cap)
    if "ssm_cache" in ys:
        new_cache["ssm"] = ys["ssm_cache"]
    new_cache["next_pos"] = positions + 1
    return logits, new_cache
