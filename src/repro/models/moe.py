"""Fine-grained mixture-of-experts FFN (DeepSeek-MoE, Phi-3.5-MoE).

Shared experts always run; routed experts are selected per token (top-k
softmax gating).  Two dispatch strategies:

* ``apply``  — *dense* dispatch: every expert runs over every token, the
  gate combine zeroes non-selected outputs.  Token axis is processed in
  chunks (``lax.map`` over the sequence) so the (chunk, E_local, Fe)
  intermediate stays VMEM/HBM-bounded.  Shape-static, trivially
  expert-parallel (experts shard on "model"; the combine contracts locally,
  no all-to-all), and exactly differentiable — this is the paper-faithful
  baseline the dry-run lowers.  Cost: E/K× extra FFN FLOPs, which the
  roofline's MODEL_FLOPS/HLO_FLOPs ratio surfaces honestly (§Perf hillclimbs
  it away via ``apply_sparse``).
* ``apply_sparse`` — sort-based capacity dispatch (GShard/Switch-style token
  dropping): top-k FLOPs only, at the cost of gather/scatter + (under SPMD)
  dispatch collectives.  Used by the beyond-paper perf variant.

Params:
    router: (D, E) f32
    experts: {w_gate/w_up: (E, D, Fe), w_down: (E, Fe, D)}
    shared:  {w_gate/w_up: (D, Sh*Fe), w_down: (Sh*Fe, D)}  (fused)
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models.layers import activation, dense_init, linear

# sequence-chunk length for the dense dispatch path: bounds the live
# (B_local, chunk, E_local, Fe) intermediate to tens of MB per device.
_CHUNK = 256


def init(key, cfg: ModelConfig) -> dict:
    m = cfg.moe
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    E, D, Fe = m.num_experts, cfg.d_model, m.d_expert

    def experts_init(k, d_in, d_out):
        keys = jax.random.split(k, E)
        return jax.vmap(lambda kk: dense_init(kk, d_in, d_out, dtype))(keys)

    p = {
        "router": dense_init(ks[0], D, E, jnp.float32),  # router in f32
        "experts": {
            "w_gate": experts_init(ks[1], D, Fe),
            "w_up": experts_init(ks[2], D, Fe),
            "w_down": experts_init(ks[3], Fe, D),
        },
    }
    if m.num_shared_experts > 0:
        Fs = m.num_shared_experts * Fe
        p["shared"] = {
            "w_gate": dense_init(ks[4], D, Fs, dtype),
            "w_up": dense_init(ks[5], D, Fs, dtype),
            "w_down": dense_init(ks[6], Fs, D, dtype),
        }
    return p


def _route(p: dict, cfg: ModelConfig, h: jnp.ndarray):
    """Router: returns (combine (B,S,E) f32, aux loss scalar, gates, idx)."""
    m = cfg.moe
    E, K = m.num_experts, m.top_k
    logits = h.astype(jnp.float32) @ p["router"]  # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)  # (B,S,K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # (B,S,K,E)
    combine = jnp.einsum("bske,bsk->bse", onehot, gate_vals)
    frac_tokens = jnp.mean(onehot.sum(axis=2), axis=(0, 1))  # (E,)
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs) * m.load_balance_coef
    return combine, aux, gate_vals, gate_idx


def _shared_out(p, cfg, h, *, lora=None, lora_mask=None, lora_scale=1.0):
    def _l(name):
        return None if lora is None else lora.get(name)

    sg = linear(h, p["shared"]["w_gate"], lora=_l("w_gate"),
                lora_mask=lora_mask, lora_scale=lora_scale)
    su = linear(h, p["shared"]["w_up"], lora=_l("w_up"),
                lora_mask=lora_mask, lora_scale=lora_scale)
    sy = activation(sg, cfg.act) * su
    return linear(sy, p["shared"]["w_down"], lora=_l("w_down"),
                  lora_mask=lora_mask, lora_scale=lora_scale)


def apply(
    p: dict,
    cfg: ModelConfig,
    h: jnp.ndarray,  # (B, S, D)
    *,
    lora: Optional[dict] = None,
    lora_mask: Optional[jnp.ndarray] = None,
    lora_scale: float = 1.0,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Dense-dispatch MoE.  Returns (out (B,S,D), aux_loss scalar)."""
    B, S, D = h.shape
    combine, aux, _, _ = _route(p, cfg, h)
    hx = h
    chunk = min(_CHUNK, S)
    pad = (-S) % chunk
    if pad:
        hx = jnp.pad(hx, ((0, 0), (0, pad), (0, 0)))
        combine = jnp.pad(combine, ((0, 0), (0, pad), (0, 0)))
    nchunks = hx.shape[1] // chunk
    hx = jnp.moveaxis(hx.reshape(B, nchunks, chunk, D), 1, 0)
    cmb = jnp.moveaxis(
        combine.reshape(B, nchunks, chunk, -1), 1, 0
    )  # (n, B, chunk, E)

    ew = p["experts"]

    def one_chunk(args):
        hc, cc = args  # (B, chunk, D), (B, chunk, E)
        g = jnp.einsum("bsd,edf->bsef", hc, ew["w_gate"])
        u = jnp.einsum("bsd,edf->bsef", hc, ew["w_up"])
        y = activation(g, cfg.act) * u
        eo = jnp.einsum("bsef,efd->bsed", y, ew["w_down"])
        return jnp.einsum("bsed,bse->bsd", eo.astype(jnp.float32), cc)

    outs = jax.lax.map(one_chunk, (hx, cmb))  # (n, B, chunk, D) f32
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nchunks * chunk, D)[:, :S]
    out = out.astype(h.dtype)

    if "shared" in p:
        out = out + _shared_out(p, cfg, h, lora=lora, lora_mask=lora_mask,
                                lora_scale=lora_scale)
    return out, aux


def apply_sparse(
    p: dict,
    cfg: ModelConfig,
    h: jnp.ndarray,
    *,
    capacity: Optional[int] = None,
    lora: Optional[dict] = None,
    lora_mask: Optional[jnp.ndarray] = None,
    lora_scale: float = 1.0,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sort-based capacity dispatch: only top-k expert FLOPs per token.

    Tokens beyond an expert's capacity are dropped (their routed contribution
    is zero; the residual stream and shared experts still flow).
    """
    m = cfg.moe
    B, S, D = h.shape
    E, K = m.num_experts, m.top_k
    N = B * S
    NK = N * K
    cap = capacity or max(1, int(m.capacity_factor * NK / E))
    hf = h.reshape(N, D)

    combine, aux, gate_vals, gate_idx = _route(p, cfg, h)
    del combine
    flat_e = gate_idx.reshape(NK)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, jnp.arange(E))  # (E,)
    rank_sorted = jnp.arange(NK) - first[sorted_e]
    slot = jnp.zeros((NK,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))

    within = slot < cap
    s_idx = jnp.where(within, slot, cap)  # cap row = overflow bin
    tok = jnp.arange(NK) // K
    buf = jnp.zeros((E, cap + 1, D), h.dtype).at[flat_e, s_idx].set(hf[tok])
    xbuf = buf[:, :cap]  # (E, cap, D)

    ew = p["experts"]
    g = jnp.einsum("ecd,edf->ecf", xbuf, ew["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xbuf, ew["w_up"])
    y = activation(g, cfg.act) * u
    ybuf = jnp.einsum("ecf,efd->ecd", y, ew["w_down"])  # (E, cap, D)

    yk = ybuf[flat_e, jnp.minimum(s_idx, cap - 1)]  # (NK, D)
    w = gate_vals.reshape(NK) * within.astype(jnp.float32)
    out = jnp.einsum(
        "nkd,nk->nd",
        yk.reshape(N, K, D).astype(jnp.float32),
        w.reshape(N, K),
    ).reshape(B, S, D).astype(h.dtype)

    if "shared" in p:
        out = out + _shared_out(p, cfg, h, lora=lora, lora_mask=lora_mask,
                                lora_scale=lora_scale)
    return out, aux
