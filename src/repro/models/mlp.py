"""Gated MLP (SwiGLU / GeGLU) block with lookahead-LoRA hooks.

Params: {"w_gate": (D, F), "w_up": (D, F), "w_down": (F, D)}
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models.layers import activation, dense_init, linear


def init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    f = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, cfg.d_model, f, dtype),
        "w_up": dense_init(k2, cfg.d_model, f, dtype),
        "w_down": dense_init(k3, f, cfg.d_model, dtype),
    }


def apply(
    p: dict,
    cfg: ModelConfig,
    h: jnp.ndarray,
    *,
    lora: Optional[dict] = None,
    lora_mask: Optional[jnp.ndarray] = None,
    lora_scale: float = 1.0,
) -> jnp.ndarray:
    def _l(name):
        return None if lora is None else lora.get(name)

    g = linear(h, p["w_gate"], lora=_l("w_gate"), lora_mask=lora_mask,
               lora_scale=lora_scale)
    u = linear(h, p["w_up"], lora=_l("w_up"), lora_mask=lora_mask,
               lora_scale=lora_scale)
    y = activation(g, cfg.act) * u
    return linear(y, p["w_down"], lora=_l("w_down"), lora_mask=lora_mask,
                  lora_scale=lora_scale)
