"""Gated MLP (SwiGLU / GeGLU) block with lookahead-LoRA hooks.

Params: {"w_gate": (D, F), "w_up": (D, F), "w_down": (F, D)}
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models.layers import activation, dense_init, linear


def init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    f = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, cfg.d_model, f, dtype),
        "w_up": dense_init(k2, cfg.d_model, f, dtype),
        "w_down": dense_init(k3, f, cfg.d_model, dtype),
    }


def apply(
    p: dict,
    cfg: ModelConfig,
    h: jnp.ndarray,
    *,
    lora: Optional[dict] = None,
    lora_mask: Optional[jnp.ndarray] = None,
    lora_scale: float = 1.0,
) -> jnp.ndarray:
    def _l(name):
        return None if lora is None else lora.get(name)

    g = linear(h, p["w_gate"], lora=_l("w_gate"), lora_mask=lora_mask,
               lora_scale=lora_scale)
    u = linear(h, p["w_up"], lora=_l("w_up"), lora_mask=lora_mask,
               lora_scale=lora_scale)
    y = activation(g, cfg.act) * u
    return linear(y, p["w_down"], lora=_l("w_down"), lora_mask=lora_mask,
                  lora_scale=lora_scale)


def apply_sharded(
    p: dict,
    cfg: ModelConfig,
    h: jnp.ndarray,
    smesh,
    *,
    lora: Optional[dict] = None,
    lora_mask: Optional[jnp.ndarray] = None,
    lora_scale: float = 1.0,
) -> jnp.ndarray:
    """``apply`` under manual tensor parallelism, bit-exact vs ``apply``.

    Column-parallel gate/up (full d_model contraction per local d_ff
    column), elementwise gating on the local columns, then the activation
    is all-gathered *inside* shard_map so w_down — column-parallel on its
    *output* dim — contracts the full d_ff in single-device order.  No
    psum ever touches a reduction, which is what GSPMD cannot promise:
    its dot realization is shape-dependent and may re-associate the bf16
    sums.  LoRA deltas ride along (A replicated → full contraction, B
    column-sliced like its base weight).  Falls back to a replicated
    (redundant but exact) evaluation when the mesh doesn't divide d_ff
    or d_model.
    """
    if smesh is None:
        return apply(p, cfg, h, lora=lora, lora_mask=lora_mask,
                     lora_scale=lora_scale)
    from repro.models import attention as attn_mod

    msize = int(smesh.shape["model"])
    if p["w_gate"].shape[1] % msize or p["w_down"].shape[1] % msize:
        return attn_mod.replicated_apply(
            lambda hh, pp, lo, lm: apply(pp, cfg, hh, lora=lo, lora_mask=lm,
                                         lora_scale=lora_scale),
            smesh, h, p, lora, lora_mask)
    from jax.sharding import PartitionSpec as P

    bspec = attn_mod._batch_spec(smesh, h.shape[0])
    names = ("w_gate", "w_up", "w_down")
    have_lora = lora is not None and lora_mask is not None
    lsub = {n: lora[n] for n in names
            if have_lora and lora.get(n) is not None}

    def local(hh, pp, *rest):
        lo = rest[0] if have_lora else {}
        lm = rest[1] if have_lora else None

        def _l(name):
            return lo.get(name)

        g = linear(hh, pp["w_gate"], lora=_l("w_gate"), lora_mask=lm,
                   lora_scale=lora_scale)
        u = linear(hh, pp["w_up"], lora=_l("w_up"), lora_mask=lm,
                   lora_scale=lora_scale)
        y = activation(g, cfg.act) * u
        yf = jax.lax.all_gather(y, "model", axis=2, tiled=True)
        return linear(yf, pp["w_down"], lora=_l("w_down"), lora_mask=lm,
                      lora_scale=lora_scale)

    arrs = [h, {n: p[n] for n in names}]
    specs = [P(bspec, None, None), {n: P(None, "model") for n in names}]
    if have_lora:
        arrs += [lsub, lora_mask]
        specs += [{n: {"a": P(None, None), "b": P(None, "model")}
                   for n in lsub},
                  P(bspec, None, None)]
    return attn_mod._shard_map(local, smesh, tuple(specs),
                               P(bspec, None, "model"))(*arrs)
