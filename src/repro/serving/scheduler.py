"""Request-level scheduling for continuous-batching serving.

The scheduler owns everything *about requests* and nothing about tensors:
a FCFS arrival queue, a fixed set of decode slots, and the per-request
state machine

    QUEUED ──admit──> PREFILL ──place──> DECODE ──retire──> DONE

``ContinuousEngine`` (engine.py) drives it with a *token-budget step*:
each engine iteration spends ``token_budget`` tokens of work, split
between one decode chunk for every live slot and as many prefill chunks
of the in-flight prompt as the leftover budget covers (``plan_step``).
Decode therefore advances every iteration — a 16k prompt streams through
in chunk-sized slices between decode chunks instead of stalling every
live slot for its whole forward pass.  ``next_request`` hands the engine
the FCFS head once a slot is free; the deprecated ``BucketedEngine``
still uses the group admission path (``next_prefill_group``).

Timing is per-request (this is where the lockstep engine's batch-level
``ttft_s`` stamp is fixed): TTFT is measured from the moment a request
becomes schedulable (its arrival) to its first emitted token, TPOT is
the mean inter-token time after the first, and ``max_gap_s`` records the
worst stall between consecutive token emissions (the decode-stall metric
in ``benchmarks/bench_serving.py``).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from enum import Enum
from typing import Callable, Optional

import numpy as np


def plan_step(
    *,
    token_budget: int,
    chunk: int,
    n_active: int,
    decode_steps: int,
    prefill_pending: bool,
) -> tuple[int, int]:
    """Split one engine iteration's token budget between decode and prefill.

    Decode is first-class: every live slot advances ``decode_steps`` tokens
    each iteration.  The remaining budget buys prefill chunks for the
    in-flight prompt — at least one whenever a prefill is pending (progress
    guarantee), at most what the budget covers (decode-latency guarantee:
    no live slot waits longer than one token-budget step between its decode
    chunks).  Returns (decode_steps, prefill_chunks).
    """
    assert token_budget > 0 and chunk > 0
    d = decode_steps if n_active > 0 else 0
    room = max(token_budget - n_active * d, 0)
    p = 0
    if prefill_pending:
        p = max(room // chunk, 1)
    return d, p


class RequestState(str, Enum):
    QUEUED = "queued"      # submitted (possibly not yet arrived)
    PREFILL = "prefill"    # pulled into a prefill micro-batch
    DECODE = "decode"      # occupying a decode slot
    DONE = "done"


@dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (n_in,) int32
    max_new_tokens: int
    out_tokens: list = field(default_factory=list)
    ttft_s: float = 0.0
    done: bool = False
    # per-request randomness (the ``random`` eviction policy): rows are
    # decorrelated via ``jax.random.fold_in`` — defaults to ``uid`` so two
    # requests in one batch never share an eviction pattern
    seed: Optional[int] = None
    # -- continuous-batching fields ------------------------------------
    arrival_s: float = 0.0  # trace-clock offset at which the request arrives
    state: RequestState = RequestState.QUEUED
    slot: Optional[int] = None
    enqueue_s: float = 0.0  # engine clock when the request became schedulable
    first_token_s: Optional[float] = None
    finish_s: Optional[float] = None
    tpot_s: float = 0.0  # mean seconds per output token after the first
    max_gap_s: float = 0.0  # worst stall between consecutive token emissions
    # -- paged-KV fields -----------------------------------------------
    # wall time of the last token emitted before a preemption, so the
    # client-visible stall (preempt -> re-admission re-emit) still lands
    # in ``max_gap_s`` even though the request changes slots
    preempt_emit_s: Optional[float] = None
    # -- prefix-cache fields -------------------------------------------
    cached_prefix_tokens: int = 0  # prompt tokens resumed from a cache hit
    admission_cache: Optional[dict] = None  # mask/pos of the admitted cache
    # (engine's ``capture_admission`` debug flag; the differential trace
    # harness compares kept sets through this)
    retirement_cache: Optional[dict] = None  # mask/pos at retirement — the
    # paged engine's final kept set under decode-time eviction (same
    # ``capture_admission`` flag; None on the dense engines)

    @property
    def eviction_seed(self) -> int:
        return self.uid if self.seed is None else self.seed

    def clone(self) -> "Request":
        """Fresh un-served copy carrying every field that shapes serving
        (uid/prompt/seed/budget/arrival) — the one replay helper used by
        benchmarks, examples, and the differential trace harness, so a new
        serving-relevant field only needs to be added here."""
        return Request(uid=self.uid, prompt=self.prompt, seed=self.seed,
                       max_new_tokens=self.max_new_tokens,
                       arrival_s=self.arrival_s)


class SlotScheduler:
    """Fixed decode slots + FCFS arrival queue with bucket-grouped admission.

    ``bucket_for`` maps a prompt length to its compile bucket; an admission
    group is the queue head plus every other *arrived* request sharing the
    head's bucket, capped by free slots and ``max_prefill_batch`` — so one
    prefill program serves the whole group.
    """

    def __init__(
        self,
        num_slots: int,
        *,
        bucket_for: Callable[[int], int],
        max_prefill_batch: Optional[int] = None,
        admission_gate: Optional[Callable[[Request], bool]] = None,
    ):
        assert num_slots > 0
        self.num_slots = num_slots
        self._bucket_for = bucket_for
        self.max_prefill_batch = max_prefill_batch or num_slots
        # paged-KV admission: with a block pool bound, a free slot is no
        # longer sufficient — the gate checks the pool can cover the FCFS
        # head's worst-case block need before the engine starts its prefill
        self._admission_gate = admission_gate
        self._pool = None  # bound KVBlockPool (observability only)
        self.preemptions = 0
        self._pending: list[Request] = []  # submitted, arrival in the future
        self._queue: list[Request] = []  # arrived, awaiting admission (FCFS)
        self._free: list[int] = list(range(num_slots - 1, -1, -1))
        self.running: dict[int, Request] = {}
        self.finished: list[Request] = []

    # -- intake ---------------------------------------------------------
    def submit(self, req: Request) -> None:
        req.state = RequestState.QUEUED
        req.enqueue_s = req.arrival_s
        self._pending.append(req)
        self._pending.sort(key=lambda r: r.arrival_s)

    def poll_arrivals(self, now: float) -> None:
        while self._pending and self._pending[0].arrival_s <= now:
            self._queue.append(self._pending.pop(0))

    def next_arrival(self) -> Optional[float]:
        return self._pending[0].arrival_s if self._pending else None

    # -- state ----------------------------------------------------------
    def free_slots(self) -> int:
        return len(self._free)

    def has_work(self) -> bool:
        return bool(self._pending or self._queue or self.running)

    def has_arrived(self, now: float) -> bool:
        """True when a request is admissible right now (arrived, queued)."""
        self.poll_arrivals(now)
        return bool(self._queue)

    # -- admission / retirement ------------------------------------------
    def next_request(self, now: float) -> Optional[Request]:
        """FCFS head for chunked prefill (one in-flight prompt at a time),
        or None when nothing has arrived, no slot is free to land in, or
        the admission gate (paged KV: free-block count) rejects the head.
        The gate blocks FCFS — no skip-ahead — so admission order, and
        therefore served tokens, stay deterministic under memory
        pressure."""
        self.poll_arrivals(now)
        if not self._queue or not self._free:
            return None
        if (self._admission_gate is not None
                and not self._admission_gate(self._queue[0])):
            return None
        req = self._queue.pop(0)
        req.state = RequestState.PREFILL
        return req

    def push_front(self, req: Request) -> None:
        """Return an un-placed request (admission found the pool dry after
        its prefill) to the queue head; it re-prefills when blocks free."""
        req.state = RequestState.QUEUED
        self._queue.insert(0, req)

    def requeue(self, req: Request) -> int:
        """Preempt-to-queue (paged KV, pool dry): yank a *running* request
        back to the head of the arrival queue.  Its slot frees, its decode
        state is abandoned (the engine released the blocks), and it will
        re-prefill from scratch when blocks are available — greedy decode
        is deterministic, so the re-served tokens are identical.  Returns
        the freed slot."""
        slot = req.slot
        assert slot is not None and self.running.get(slot) is req
        del self.running[slot]
        self._free.append(slot)
        req.slot = None
        req.state = RequestState.QUEUED
        req.done = False
        self.preemptions += 1
        self._queue.insert(0, req)
        return slot

    def next_prefill_group(self, now: float) -> Optional[list[Request]]:
        """The next same-bucket admission group, or None if nothing is
        admissible (no arrived requests, or no free slot)."""
        self.poll_arrivals(now)
        if not self._queue or not self._free:
            return None
        cap = min(len(self._free), self.max_prefill_batch)
        head_bucket = self._bucket_for(len(self._queue[0].prompt))
        group = [r for r in self._queue
                 if self._bucket_for(len(r.prompt)) == head_bucket][:cap]
        for r in group:
            self._queue.remove(r)
            r.state = RequestState.PREFILL
        return group

    def place(self, req: Request) -> int:
        slot = self._free.pop()
        req.slot = slot
        req.state = RequestState.DECODE
        self.running[slot] = req
        return slot

    def bind_pool(self, pool) -> None:
        """Attach the engine's ``KVBlockPool`` for observability: the
        scheduler never touches device memory, but operators read
        admission pressure here."""
        self._pool = pool

    def pool_stats(self) -> dict:
        """Block-pool utilization (empty when serving dense caches), plus
        the scheduler-side pressure signals: queued-but-arrived requests
        and preemption count."""
        if self._pool is None:
            return {}
        s = dict(self._pool.stats())
        s["queued"] = len(self._queue)
        s["preemptions"] = self.preemptions
        return s

    def prefix_stats(self) -> dict:
        """Aggregate prefix-reuse accounting over finished requests: how
        many admissions hit the prompt cache and what fraction of all
        prompt tokens were served from shared-prefix snapshots."""
        total = sum(len(r.prompt) for r in self.finished)
        cached = sum(r.cached_prefix_tokens for r in self.finished)
        hits = sum(1 for r in self.finished if r.cached_prefix_tokens > 0)
        return {
            "requests": len(self.finished),
            "prefix_hits": hits,
            "hit_rate": hits / len(self.finished) if self.finished else 0.0,
            "cached_tokens": cached,
            "prompt_tokens": total,
            "cached_token_frac": cached / total if total else 0.0,
        }

    def bind_metrics(self, registry) -> None:
        """Mirror scheduler occupancy as ``scheduler_*`` callback gauges.
        The engine builds a fresh scheduler per ``run()`` and re-binds it;
        ``set_fn`` re-binding hands the series to the new instance."""
        registry.gauge(
            "scheduler_queue_depth",
            "Arrived requests awaiting admission (FCFS queue)."
        ).set_fn(lambda: len(self._queue))
        registry.gauge(
            "scheduler_pending",
            "Submitted requests whose arrival offset is in the future."
        ).set_fn(lambda: len(self._pending))
        registry.gauge(
            "scheduler_running",
            "Requests currently occupying a decode slot."
        ).set_fn(lambda: len(self.running))
        registry.gauge(
            "scheduler_free_slots", "Decode slots with no request placed."
        ).set_fn(lambda: len(self._free))
        registry.gauge(
            "scheduler_finished", "Requests retired so far this run."
        ).set_fn(lambda: len(self.finished))

    def retire(self, req: Request, *, now: float) -> int:
        """Free the request's slot; returns it for the engine to reuse."""
        slot = req.slot
        del self.running[slot]
        self._free.append(slot)
        req.state = RequestState.DONE
        req.done = True
        req.finish_s = now
        n = len(req.out_tokens)
        if req.first_token_s is not None and n > 1:
            req.tpot_s = (now - req.first_token_s) / (n - 1)
        self.finished.append(req)
        return slot
