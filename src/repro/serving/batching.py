"""Serving compile caches: the chunked cache, and the deprecated buckets.

Serving traffic has arbitrary prompt lengths; XLA programs have static
shapes.  The current bridge is *chunked prefill*: prompts stream through a
fixed ``(batch, chunk)`` token program whose chunk offset and true prompt
length are **traced** arguments, so ``ChunkCompileCache`` compiles exactly
one prefill-step program and one finalize program per
``(chunk, batch, policy)`` — prompt length never enters the key.  The only
recompile source left is KV-buffer growth when a prompt exceeds the
engine's current context capacity (geometric, so O(log max_len) compiles
over a serving lifetime), which ``compile_count()`` makes observable.

The previous bridge — pad-to-bucket prefill with programs per
``(bucket, batch, policy, padded)`` — is **deprecated** but kept importable
(``bucket_for`` / ``pad_to_bucket`` / ``batch_bucket`` /
``PrefillCompileCache``) so ``BucketedEngine`` can still serve as the
benchmark baseline; see ``benchmarks/bench_serving.py``.
"""

from __future__ import annotations

import warnings
from typing import Callable

import jax
import numpy as np

DEFAULT_BUCKETS = (32, 64, 128, 256, 512, 1024)


def _compile_count(fns: dict) -> int:
    """Actual XLA compilations across jitted entries (cache entries ×
    traced shape signatures); falls back to one per entry when the private
    jit API is unavailable."""
    total = 0
    for fn in fns.values():
        try:
            total += fn._cache_size()
        except Exception:  # pragma: no cover - older jax
            total += 1
    return total


class _CompileTracedJit:
    """Thin jitted-callable proxy that surfaces XLA compilations as trace
    events.  A jitted program compiles lazily on the first call with a new
    shape signature; the proxy detects that via the ``_cache_size`` delta
    around each call (the same private API ``_compile_count`` reads) and
    emits a ``jit_compile`` instant on the engine track with the cache key
    and the call's wall time.  With no trace attached (the default) a call
    is a single extra attribute read."""

    __slots__ = ("fn", "_cache", "_key")

    def __init__(self, fn, cache: "ChunkCompileCache", key):
        self.fn = fn
        self._cache = cache
        self._key = key

    def _cache_size(self) -> int:
        return self.fn._cache_size()

    def __call__(self, *args, **kwargs):
        tr = self._cache.trace
        if tr is None:
            return self.fn(*args, **kwargs)
        try:
            before = self.fn._cache_size()
        except Exception:  # pragma: no cover - older jax
            before = None
        import time as _time
        t = _time.perf_counter()
        out = self.fn(*args, **kwargs)
        if before is not None:
            try:
                compiled = self.fn._cache_size() > before
            except Exception:  # pragma: no cover - older jax
                compiled = False
            if compiled:
                tr.instant("jit_compile", tr.ENGINE, key=str(self._key),
                           ms=(_time.perf_counter() - t) * 1e3)
        return out


class ChunkCompileCache:
    """jit compile cache for chunked prefill, keyed ``(kind, chunk, batch,
    policy)`` — no prompt-length ladder, no padded/exact split.

    ``build(kind, policy)`` returns the python callable to jit (``kind`` is
    ``"chunk"`` for the per-chunk step or ``"finalize"`` for the
    evict-at-prompt-end program).  ``compile_count()`` reports actual XLA
    compilations (cache entries × traced shape signatures), so buffer-growth
    recompiles are visible alongside key misses.
    """

    def __init__(self, build: Callable[[str, str], Callable],
                 mesh_sig=None):
        self._build = build
        self._fns: dict = {}
        self.hits = 0
        self.misses = 0
        # Sharded serving: programs compiled against one device mesh are
        # not reusable on another, so a non-trivial mesh signature (from
        # ``common.sharding.mesh_signature``) joins the key.  Meshless
        # engines keep the bare 4-tuple keys tests pin.
        self._mesh_sig = mesh_sig
        # observability hooks (repro.obs): the engine points ``trace`` at
        # its TraceRecorder so XLA compilations show up as engine-track
        # events next to the serving spans they stall
        self.trace = None

    def get(self, kind: str, chunk: int, batch: int, policy: str):
        key = (kind, chunk, batch, policy)
        if self._mesh_sig is not None:
            key = key + (self._mesh_sig,)
        fn = self._fns.get(key)
        if fn is None:
            self.misses += 1
            fn = _CompileTracedJit(jax.jit(self._build(kind, policy)),
                                   self, key)
            self._fns[key] = fn
        else:
            self.hits += 1
        return fn

    @property
    def keys(self):
        return sorted(self._fns)

    def compile_count(self) -> int:
        return _compile_count(self._fns)

    def stats(self) -> dict:
        # ``keys`` lets tests pin the exact program set: a prefix-cache hit
        # resumes with buffer shapes identical to a cold prefill, so serving
        # a hit must neither add a key nor a compiled shape signature.
        return {"entries": len(self._fns), "hits": self.hits,
                "misses": self.misses, "compiles": self.compile_count(),
                "keys": self.keys}

    def bind_metrics(self, registry) -> None:
        """Mirror ``stats()`` as ``compile_cache_*`` callback gauges on the
        engine's registry (``keys`` is a list and stays out)."""
        from repro.obs.metrics import bind_stat_gauges
        bind_stat_gauges(registry, "compile_cache", self.stats,
                         keys=("entries", "hits", "misses", "compiles"))


# ---------------------------------------------------------------------------
# Deprecated: prompt-length buckets (kept for BucketedEngine comparisons)
# ---------------------------------------------------------------------------


def _warn_bucketed(what: str) -> None:
    warnings.warn(
        f"{what} is deprecated: chunked prefill (ChunkCompileCache + the "
        "chunked ContinuousEngine) replaced the bucket ladder; the bucketed "
        "utilities remain only so BucketedEngine can serve as a benchmark "
        "baseline", DeprecationWarning, stacklevel=3,
    )


def next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


# private non-warning forms: BucketedEngine (itself deprecated, warned once
# at construction) uses these internally so the warning fires only at the
# public entry points

def _bucket_for(n: int, buckets=DEFAULT_BUCKETS) -> int:
    for b in buckets:
        if n <= b:
            return b
    return next_pow2(n)


def _batch_bucket(n: int, cap: int) -> int:
    assert n > 0 and cap > 0
    return min(next_pow2(n), cap)


def _pad_to_bucket(prompts: list, bucket: int, batch: int, *,
                   pad_id: int = 0) -> tuple[np.ndarray, np.ndarray]:
    assert len(prompts) <= batch
    tokens = np.full((batch, bucket), pad_id, np.int32)
    lens = np.full((batch,), bucket, np.int32)
    for i, p in enumerate(prompts):
        n = len(p)
        assert n <= bucket, f"prompt len {n} exceeds bucket {bucket}"
        tokens[i, :n] = p
        lens[i] = n
    return tokens, lens


def bucket_for(n: int, buckets=DEFAULT_BUCKETS) -> int:
    """Deprecated.  Smallest configured bucket >= n; beyond the largest, the
    next power of two (the compile cache keeps working for outlier
    prompts)."""
    _warn_bucketed("bucket_for")
    return _bucket_for(n, buckets)


def batch_bucket(n: int, cap: int) -> int:
    """Deprecated.  Compile batch size for an n-request group: next power of
    two, capped."""
    _warn_bucketed("batch_bucket")
    return _batch_bucket(n, cap)


def pad_to_bucket(
    prompts: list, bucket: int, batch: int, *, pad_id: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """Deprecated.  Right-pad prompts to ``bucket`` and the group to
    ``batch`` rows.

    Returns (tokens (batch, bucket) int32, lens (batch,) int32).  Dummy
    rows carry lens == bucket so they take the unmasked fast path; their
    outputs are discarded by the caller.
    """
    _warn_bucketed("pad_to_bucket")
    return _pad_to_bucket(prompts, bucket, batch, pad_id=pad_id)


class PrefillCompileCache:
    """Deprecated.  jit compile cache keyed ``(bucket, batch, policy,
    padded)`` — the bucket-ladder predecessor of ``ChunkCompileCache``,
    kept for ``BucketedEngine``.

    ``build(policy, padded)`` returns the python callable to jit; the
    ``padded`` variant threads per-request ``prompt_lens`` masking through
    prefill, the exact variant skips it (keeping the maskless kernel fast
    path when every prompt in the group fills its bucket exactly).
    """

    def __init__(self, build: Callable[[str, bool], Callable]):
        _warn_bucketed("PrefillCompileCache")
        self._build = build
        self._fns: dict = {}
        self.hits = 0
        self.misses = 0

    def get(self, bucket: int, batch: int, policy: str, padded: bool):
        key = (bucket, batch, policy, padded)
        fn = self._fns.get(key)
        if fn is None:
            self.misses += 1
            fn = jax.jit(self._build(policy, padded))
            self._fns[key] = fn
        else:
            self.hits += 1
        return fn

    def warm(self, keys) -> None:
        """Pre-instantiate jit wrappers for (bucket, batch, policy, padded)
        keys (compilation itself still happens on first call)."""
        for key in keys:
            if key not in self._fns:
                self._fns[key] = jax.jit(self._build(key[2], key[3]))

    @property
    def keys(self):
        return sorted(self._fns)

    def compile_count(self) -> int:
        return _compile_count(self._fns)

    def stats(self) -> dict:
        return {"entries": len(self._fns), "hits": self.hits,
                "misses": self.misses}
