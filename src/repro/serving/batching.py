"""Prompt-length bucketing and the bucketed jit compile cache.

Serving traffic has arbitrary prompt lengths; XLA programs have static
shapes.  The bridge is a small set of *buckets*: prompts are right-padded
to the nearest bucket and prefill programs are compiled once per
``(bucket, batch, policy, padded)`` key.  Batch sizes are bucketed to
powers of two for the same reason — a 3-request admission group runs the
batch-4 program with one dummy row rather than compiling a batch-3 one.

``PrefillCompileCache`` is deliberately explicit (rather than leaning on
``jax.jit``'s internal shape cache): keys can be warmed ahead of traffic,
and hit/miss/compile counts are observable — recompiles in the serving
hot path are a bug, and this makes them visible.
"""

from __future__ import annotations

from typing import Callable

import jax
import numpy as np

DEFAULT_BUCKETS = (32, 64, 128, 256, 512, 1024)


def next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def bucket_for(n: int, buckets=DEFAULT_BUCKETS) -> int:
    """Smallest configured bucket >= n; beyond the largest, the next power
    of two (the compile cache keeps working for outlier prompts)."""
    for b in buckets:
        if n <= b:
            return b
    return next_pow2(n)


def batch_bucket(n: int, cap: int) -> int:
    """Compile batch size for an n-request group: next power of two, capped."""
    assert n > 0 and cap > 0
    return min(next_pow2(n), cap)


def pad_to_bucket(
    prompts: list, bucket: int, batch: int, *, pad_id: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """Right-pad prompts to ``bucket`` and the group to ``batch`` rows.

    Returns (tokens (batch, bucket) int32, lens (batch,) int32).  Dummy
    rows carry lens == bucket so they take the unmasked fast path; their
    outputs are discarded by the caller.
    """
    assert len(prompts) <= batch
    tokens = np.full((batch, bucket), pad_id, np.int32)
    lens = np.full((batch,), bucket, np.int32)
    for i, p in enumerate(prompts):
        n = len(p)
        assert n <= bucket, f"prompt len {n} exceeds bucket {bucket}"
        tokens[i, :n] = p
        lens[i] = n
    return tokens, lens


class PrefillCompileCache:
    """jit compile cache keyed on ``(bucket, batch, policy, padded)``.

    ``build(policy, padded)`` returns the python callable to jit; the
    ``padded`` variant threads per-request ``prompt_lens`` masking through
    prefill, the exact variant skips it (keeping the maskless kernel fast
    path when every prompt in the group fills its bucket exactly).
    """

    def __init__(self, build: Callable[[str, bool], Callable]):
        self._build = build
        self._fns: dict = {}
        self.hits = 0
        self.misses = 0

    def get(self, bucket: int, batch: int, policy: str, padded: bool):
        key = (bucket, batch, policy, padded)
        fn = self._fns.get(key)
        if fn is None:
            self.misses += 1
            fn = jax.jit(self._build(policy, padded))
            self._fns[key] = fn
        else:
            self.hits += 1
        return fn

    def warm(self, keys) -> None:
        """Pre-instantiate jit wrappers for (bucket, batch, policy, padded)
        keys (compilation itself still happens on first call)."""
        for key in keys:
            if key not in self._fns:
                self._fns[key] = jax.jit(self._build(key[2], key[3]))

    @property
    def keys(self):
        return sorted(self._fns)

    def stats(self) -> dict:
        return {"entries": len(self._fns), "hits": self.hits,
                "misses": self.misses}
