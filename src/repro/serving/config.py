"""Unified serving configuration: one ``ServingConfig`` object instead of
the ~17 keyword arguments ``ContinuousEngine`` historically grew.

Grouping
--------
``ServingConfig`` holds the per-engine scalars (policy, slots, caps) plus
grouped sub-configs:

* ``evict``        — prefill eviction (``common.config.EvictionConfig``)
* ``decode_evict`` — decoding-stage eviction (``DecodeEvictionConfig``):
  the one schema consumed by all three engines.  The deprecated dense
  engines use ``margin_rows`` to size their fixed cache margin; the paged
  ``ContinuousEngine`` uses ``interval`` as the sweep period — its cache
  grows block-by-block and is compacted back to ``capacity`` every
  ``interval`` generated rows, returning the freed blocks to the pool.
* ``chunking``     — prefill chunk geometry and the token-budget step.

Live objects (``kv_pool``, ``prefix_cache``, ``sampling``, ``mesh``) ride
the config as plain fields: they configure the engine exactly like the
old kwargs did, they are just no longer positional noise.

Backwards compatibility: ``ServingConfig.from_legacy`` maps the old
kwarg names; ``ContinuousEngine(params, cfg, **old_kwargs)`` still works
through it (with a ``DeprecationWarning``), and ``decode_evict`` accepts
a plain bool anywhere via ``DecodeEvictionConfig.coerce``.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional

from repro.common.config import EvictionConfig

__all__ = ["ChunkingConfig", "DecodeEvictionConfig", "ServingConfig"]


@dataclass(frozen=True)
class DecodeEvictionConfig:
    """Decoding-stage eviction (beyond-paper), one schema for all engines.

    ``enabled=False`` keeps the pre-eviction behavior: the decode cache
    holds ``max_new_tokens + 1`` append rows so a generation can never
    overrun it.  Enabled:

    * dense engines — the cache keeps only ``margin`` append rows; once
      full, each new token overwrites the lowest cumulative-attention
      slot in-step (``attention.decode_attention_step_evicting``).
    * paged ``ContinuousEngine`` — the cache grows block-by-block and a
      periodic sweep (every ``interval`` generated rows) re-evicts it
      down to ``capacity`` under the streamed H2O masses, compacts the
      kept rows into the head of the block run and frees the tail
      blocks back to the ``KVBlockPool``.
    """

    enabled: bool = False
    # paged: rows of decode growth between sweeps.  Reclaim granularity
    # is the pool block — intervals below ``block_size`` still compact
    # correctly but free no whole block, so size interval >= block_size
    # (ideally a multiple) for the sweeps to actually return memory.
    interval: int = 64
    margin: int = 8  # dense: append rows kept beyond the eviction capacity

    def __post_init__(self):
        assert self.interval >= 1, "sweep interval must be >= 1 row"
        assert self.margin >= 1, "decode margin must be >= 1 row"

    @classmethod
    def coerce(cls, value) -> "DecodeEvictionConfig":
        """Accept the legacy ``decode_evict`` spellings: a bool (the old
        kwarg), None, or an already-built config."""
        if isinstance(value, cls):
            return value
        if value is None:
            return cls()
        assert isinstance(value, bool), \
            f"decode_evict must be a bool or DecodeEvictionConfig, got " \
            f"{type(value).__name__}"
        return cls(enabled=value)

    def margin_rows(self, max_new_tokens: int) -> int:
        """Dense-cache append rows beyond the eviction capacity — the
        thrice-copied ``8 if decode_evict else max_new_tokens + 1`` rule
        all three engines used to inline."""
        return self.margin if self.enabled else max_new_tokens + 1


@dataclass(frozen=True)
class ChunkingConfig:
    """Streaming-prefill geometry of the chunked continuous engine."""

    chunk: int = 128  # prefill chunk rows (one compiled (1, chunk) program)
    max_context: int = 1024  # base KV-buffer rung; longer prompts climb
    token_budget: Optional[int] = None  # per-step budget (None: derived)
    decode_chunk: int = 8  # largest jitted decode chunk

    def __post_init__(self):
        assert self.chunk >= 1 and self.decode_chunk >= 1


# legacy ContinuousEngine kwarg -> (ServingConfig path, coercion)
_LEGACY_FIELDS = {
    "policy": "policy",
    "evict": "evict",
    "num_slots": "num_slots",
    "max_new_tokens": "max_new_tokens",
    "eos_id": "eos_id",
    "decode_evict": "decode_evict",
    "chunk": "chunking.chunk",
    "max_context": "chunking.max_context",
    "token_budget": "chunking.token_budget",
    "decode_chunk": "chunking.decode_chunk",
    "sampling": "sampling",
    "kv_pool": "kv_pool",
    "prefix_cache": "prefix_cache",
    "reserve_appends": "reserve_appends",
    "capture_admission": "capture_admission",
    "mesh": "mesh",
}


@dataclass
class ServingConfig:
    """Everything that shapes a ``ContinuousEngine``, in one object."""

    policy: str = "lookaheadkv"
    evict: EvictionConfig = field(default_factory=EvictionConfig)
    decode_evict: DecodeEvictionConfig = field(
        default_factory=DecodeEvictionConfig)
    chunking: ChunkingConfig = field(default_factory=ChunkingConfig)
    num_slots: int = 4
    max_new_tokens: int = 64  # per-request cap (sizes the cache margin)
    eos_id: int = 0
    sampling: Any = None  # policies.Sampling | None (None = greedy)
    kv_pool: Any = None  # serving.kv_pool.KVBlockPool | None
    prefix_cache: Any = None  # serving.prefix_cache.PrefixCache | None
    reserve_appends: bool = True  # guarantee admitted requests' growth
    capture_admission: bool = False  # stash mask/pos on each Request
    mesh: Any = None  # ("data", "model") mesh: tensor-parallel serving
    # trained lookahead modules (npz from launch/train.py): loaded at
    # engine init when ``lkv_params`` is not passed directly — the serving
    # half of the harvest -> distill -> serve loop
    lkv_checkpoint: Optional[str] = None
    # gt_oracle capture hook (data.harvest.HarvestWriter | None): called
    # as ``hook.on_retire(request)`` when a request retires, while its
    # generated continuation — the "future" the oracle needs — is in hand
    harvest: Any = None
    # observability (repro.obs).  ``trace`` is an obs.trace.TraceRecorder
    # the engine emits per-request spans into; ``drift`` is an
    # obs.quality.DriftMonitor fed from the retirement hook.  Both bind
    # to the engine's metrics registry at construction.
    trace: Any = None  # obs.trace.TraceRecorder | None
    drift: Any = None  # obs.quality.DriftMonitor | None
    # device-sync the engine's timers (block on each chunk's output
    # arrays before stamping) so they measure execution, not dispatch,
    # under JAX async dispatch.  None (default): sync exactly when a
    # trace is attached — untimed serving keeps the async pipeline.
    sync_timers: Optional[bool] = None

    def __post_init__(self):
        self.decode_evict = DecodeEvictionConfig.coerce(self.decode_evict)
        if self.evict is None:
            self.evict = EvictionConfig()

    @classmethod
    def from_legacy(cls, **kwargs) -> "ServingConfig":
        """Build a config from the old ``ContinuousEngine.__init__`` kwarg
        names (the deprecation shim).  Unknown names raise, exactly like
        the old signature would."""
        unknown = set(kwargs) - set(_LEGACY_FIELDS)
        if unknown:
            raise TypeError(
                f"unknown ContinuousEngine kwargs: {sorted(unknown)}")
        top: dict = {}
        chunking: dict = {}
        for name, value in kwargs.items():
            path = _LEGACY_FIELDS[name]
            if path.startswith("chunking."):
                chunking[path.split(".", 1)[1]] = value
            else:
                top[path] = value
        if chunking:
            top["chunking"] = ChunkingConfig(**chunking)
        return cls(**top)

    def legacy_kwargs(self) -> dict:
        """The old kwarg dict equivalent to this config (round-trip
        companion of ``from_legacy``; ``decode_evict`` stays a config —
        ``from_legacy`` coerces bools, not the reverse)."""
        out = {}
        for name, path in _LEGACY_FIELDS.items():
            obj: Any = self
            for part in path.split("."):
                obj = getattr(obj, part)
            out[name] = obj
        return out

    def replace(self, **changes) -> "ServingConfig":
        return dataclasses.replace(self, **changes)
