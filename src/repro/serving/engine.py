"""Serving engines: lockstep (paper-shaped) and continuous batching.

``ServingEngine`` is the original compact shape: one same-length batch at a
time, prefill and decode in lockstep.  ``ContinuousEngine`` decouples the
two phases behind a slot scheduler (scheduler.py) and a bucketed compile
cache (batching.py):

    arrivals ──> FCFS queue ──> per-bucket prefill ──> decode slots
                                   (pad-to-bucket,       (one slot-batched
                                    compile cache)        chunked loop)

Finished requests retire and queued requests are inserted into the freed
slots mid-stream.  This is enabled precisely by the paper's eviction: every
request's post-eviction decode cache has the same static shape
``(budget_capacity + margin)`` regardless of its original prompt length, so
a freshly prefilled request's cache pytree can be scattered into the live
decode cache (``transformer.insert_request_cache``) without reshaping —
cache bytes stay O(budget), and the decode batch stays full under
heterogeneous traffic.
"""

from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import EvictionConfig, ModelConfig
from repro.core import policies
from repro.models import transformer as tf
from repro.serving.batching import (DEFAULT_BUCKETS, PrefillCompileCache,
                                    batch_bucket, bucket_for, pad_to_bucket)
from repro.serving.scheduler import Request, RequestState, SlotScheduler

__all__ = ["Request", "RequestState", "ServingEngine", "ContinuousEngine",
           "cache_bytes"]


def cache_bytes(cfg: ModelConfig, capacity: int, n_in: int) -> dict:
    """Analytic cache footprint: full vs evicted (the paper's headline)."""
    if cfg.attn is None:
        return {"full": 0, "evicted": 0, "ratio": 1.0}
    per_tok = cfg.num_layers * cfg.attn.kv_dim * 2 * 2  # K+V, bf16
    return {
        "full": n_in * per_tok,
        "evicted": capacity * per_tok,
        "ratio": n_in / max(capacity, 1),
    }


class ServingEngine:
    """Lockstep batch engine: every request in a batch shares one prompt
    length, and prefill/decode run back-to-back for the whole batch."""

    def __init__(
        self,
        params: dict,
        cfg: ModelConfig,
        *,
        policy: str = "lookaheadkv",
        evict: Optional[EvictionConfig] = None,
        lkv_params: Optional[dict] = None,
        draft_params: Optional[dict] = None,
        draft_cfg: Optional[ModelConfig] = None,
        max_new_tokens: int = 64,
        eos_id: int = 0,
        decode_evict: bool = False,
    ):
        self.params, self.cfg = params, cfg
        self.policy = policy
        self.evict = evict if evict is not None else EvictionConfig()
        self.lkv_params = lkv_params
        self.draft_params, self.draft_cfg = draft_params, draft_cfg
        self.max_new_tokens = max_new_tokens
        self.eos_id = eos_id
        # decoding-stage eviction (beyond-paper): the cache stays at
        # ``budget + margin`` even for long generations — new tokens evict
        # the lowest cumulative-attention slots once capacity is reached.
        self.decode_evict = decode_evict
        self.decode_margin = (8 if decode_evict else max_new_tokens + 1)
        self._prefill_fn = jax.jit(self._prefill)
        self._decode_fn = jax.jit(self._decode)

    # -- jit bodies ---------------------------------------------------------
    def _prefill(self, params, lkv, tokens):
        res = policies.run_eviction(
            self.policy, params, self.cfg, tokens, evict=self.evict,
            lkv_params=lkv, draft_params=self.draft_params,
            draft_cfg=self.draft_cfg, extra_slots=self.decode_margin,
        )
        if self.decode_evict:
            res = res._replace(cache=tf.add_decode_eviction_scores(res.cache))
        return res

    def _decode(self, params, first_token, cache):
        return policies.greedy_decode(
            params, self.cfg, first_token, cache, self.max_new_tokens
        )

    # -- public API ----------------------------------------------------------
    def serve(self, requests: list[Request]) -> list[Request]:
        """Serve a batch of same-length requests.

        ``ttft_s`` here is *batch-level by construction* — all requests
        prefill together, so they share one first-token time.  Per-request
        TTFT under mixed traffic is what ``ContinuousEngine`` reports.
        """
        assert requests, "empty batch"
        n_in = len(requests[0].prompt)
        assert all(len(r.prompt) == n_in for r in requests), \
            "bucket requests by prompt length"
        tokens = jnp.asarray(np.stack([r.prompt for r in requests]))
        t0 = time.perf_counter()
        res = self._prefill_fn(self.params, self.lkv_params, tokens)
        res.logits.block_until_ready()
        ttft = time.perf_counter() - t0
        first = jnp.argmax(res.logits, -1)[:, None].astype(jnp.int32)
        toks, _ = self._decode_fn(self.params, first, res.cache)
        toks = np.asarray(toks)  # (B, max_new_tokens)
        for i, r in enumerate(requests):
            seq = toks[i].tolist()
            if self.eos_id in seq:
                seq = seq[: seq.index(self.eos_id) + 1]
            r.out_tokens = seq
            r.ttft_s = ttft
            r.first_token_s = ttft
            r.done = True
            r.state = RequestState.DONE
        return requests

    def cache_bytes(self, n_in: int) -> dict:
        cap = self.evict.budget + self.decode_margin
        return cache_bytes(self.cfg, cap, n_in)


class ContinuousEngine:
    """Continuous-batching engine: a slot-batched decode loop with
    per-bucket prefill and mid-stream admission/retirement.

    The decode loop runs in *chunks* (a jitted ``lax.scan`` of 1/2/4/…
    steps with a per-slot active mask) so host dispatch is amortized while
    admission latency stays bounded; chunk length tracks the *longest*
    remaining token budget among live slots, so a nearly-finished slot may
    overshoot its budget inside a chunk — the surplus tokens are truncated
    at collect time (greedy decode is prefix-stable, so truncation never
    changes the kept tokens) and the slot retires at the chunk boundary.

    Exactness: tokens match isolated lockstep serving bit-for-bit for
    ``lookaheadkv`` and the position policies even when prompts are padded
    to their bucket (padded rows are masked everywhere — see
    ``transformer.prefill``'s ``prompt_lens``).  The snapkv-family
    baselines are exact when a prompt fills its bucket and approximate
    otherwise (their sliding observation windows overlap the padding).
    Multi-pass policies (laq/speckv) are grouped by exact prompt length
    instead of bucketed.
    """

    #: decode chunk lengths we are willing to compile
    _CHUNK_SIZES = (1, 2, 4, 8, 16)

    def __init__(
        self,
        params: dict,
        cfg: ModelConfig,
        *,
        policy: str = "lookaheadkv",
        evict: Optional[EvictionConfig] = None,
        lkv_params: Optional[dict] = None,
        draft_params: Optional[dict] = None,
        draft_cfg: Optional[ModelConfig] = None,
        num_slots: int = 4,
        buckets: tuple = DEFAULT_BUCKETS,
        max_prefill_batch: Optional[int] = None,
        max_new_tokens: int = 64,  # per-request cap (sizes the cache margin)
        eos_id: int = 0,
        decode_evict: bool = False,
        decode_chunk: int = 8,
    ):
        assert cfg.uses_attention and not cfg.uses_ssm \
            and not cfg.is_encoder_decoder, \
            "continuous batching serves attention-only archs"
        assert policy != "gt_oracle", "gt_oracle needs the future; not servable"
        self.params, self.cfg = params, cfg
        self.policy = policy
        self.evict = evict if evict is not None else EvictionConfig()
        self.lkv_params = lkv_params
        self.draft_params, self.draft_cfg = draft_params, draft_cfg
        self.num_slots = num_slots
        self.buckets = tuple(sorted(buckets))
        self.max_prefill_batch = max_prefill_batch or num_slots
        self.max_new_tokens = max_new_tokens
        self.eos_id = eos_id
        self.decode_evict = decode_evict
        self.decode_margin = (8 if decode_evict else max_new_tokens + 1)
        self._chunks = tuple(c for c in self._CHUNK_SIZES if c <= decode_chunk)
        # multi-pass policies draft with the compressed cache; their prefill
        # can't mask padding, so their groups use exact prompt lengths
        self._exact_only = policy in policies.MULTI_PASS
        self.capacity = tf.decode_cache_capacity(
            cfg, policy, self.evict, n_keys_max=max(self.buckets))
        self.prefill_cache = PrefillCompileCache(self._build_prefill)
        self._decode_fns: dict = {}
        self._insert_fn = jax.jit(tf.insert_request_cache)

    # -- compile-cache bodies ------------------------------------------------
    def _build_prefill(self, policy: str, padded: bool):
        def fn(params, lkv, tokens, lens):
            res = policies.run_eviction(
                policy, params, self.cfg, tokens, evict=self.evict,
                lkv_params=lkv, draft_params=self.draft_params,
                draft_cfg=self.draft_cfg, extra_slots=self.decode_margin,
                prompt_lens=lens if padded else None,
            )
            if self.decode_evict:
                res = res._replace(
                    cache=tf.add_decode_eviction_scores(res.cache))
            return res

        return fn

    def _decode_fn(self, steps: int):
        fn = self._decode_fns.get(steps)
        if fn is None:
            def body(params, tok, cache, active):
                return policies.decode_chunk(
                    params, self.cfg, tok, cache, steps, active=active)

            fn = jax.jit(body)
            self._decode_fns[steps] = fn
        return fn

    # -- geometry ------------------------------------------------------------
    def _bucket(self, n: int) -> int:
        if self._exact_only:
            return n
        b = bucket_for(n, self.buckets)
        if self.policy == "full" and b > max(self.buckets):
            raise ValueError(
                f"policy 'full' caches whole prompts; len {n} exceeds the "
                f"largest bucket {max(self.buckets)}")
        return b

    def cache_bytes(self, n_in: int) -> dict:
        return cache_bytes(self.cfg, self.capacity + self.decode_margin, n_in)

    def warmup(self, prompt_lens, batch_sizes=(1,)) -> None:
        """Pre-build compile-cache entries for expected traffic shapes."""
        keys = []
        for n in prompt_lens:
            b = self._bucket(n)
            for nb in batch_sizes:
                nb = batch_bucket(nb, self.max_prefill_batch)
                keys.append((b, nb, self.policy, n != b))
        self.prefill_cache.warm(keys)

    # -- serving loop --------------------------------------------------------
    def run(self, requests: list[Request]) -> list[Request]:
        """Serve ``requests`` to completion; returns them in finish order.

        ``arrival_s`` offsets are interpreted on the wall clock relative to
        the start of the call: a request is schedulable once the engine
        clock passes its arrival.  All timing fields (``ttft_s``,
        ``tpot_s``, ``finish_s``) are per-request, measured on that clock.
        """
        sched = SlotScheduler(self.num_slots, bucket_for=self._bucket,
                              max_prefill_batch=self.max_prefill_batch)
        for r in requests:
            assert r.max_new_tokens <= self.max_new_tokens, \
                "request exceeds the engine's max_new_tokens cache margin"
            sched.submit(r)
        t0 = time.perf_counter()
        live = tf.init_decode_cache(self.cfg, self.num_slots,
                                    self.capacity + self.decode_margin,
                                    per_slot_cursor=True)
        if self.decode_evict:
            live = tf.add_decode_eviction_scores(live)
        tok = jnp.zeros((self.num_slots, 1), jnp.int32)
        active = np.zeros(self.num_slots, bool)
        remaining = np.zeros(self.num_slots, np.int64)

        while sched.has_work():
            # admission: fill freed slots from the queue, one bucket group
            # per prefill program.  ``now`` refreshes inside the loop so
            # requests that arrived during a (multi-second, possibly
            # compile-including) prefill are admissible immediately.
            while True:
                now = time.perf_counter() - t0
                group = sched.next_prefill_group(now)
                if not group:
                    break
                tok, live = self._admit(group, sched, tok, live, active,
                                        remaining, t0)
            if active.any():
                steps = self._pick_chunk(remaining, active)
                fn = self._decode_fn(steps)
                tok, live, toks = fn(self.params, tok, live,
                                     jnp.asarray(active))
                self._collect(np.asarray(toks), steps, sched, active,
                              remaining, t0)
            else:
                nxt = sched.next_arrival()
                if nxt is None:
                    break  # defensive: nothing queued, nothing running
                wait = nxt - (time.perf_counter() - t0)
                if wait > 0:
                    time.sleep(min(wait, 0.05))
        return sched.finished

    # -- internals -----------------------------------------------------------
    def _pick_chunk(self, remaining, active) -> int:
        """Largest configured chunk no bigger than the *longest* remaining
        stream: slots that finish mid-chunk simply have their surplus tokens
        truncated at collect time (greedy decode makes outputs prefix-stable,
        so overshoot wastes a few slot-steps but never changes tokens), which
        keeps the host-dispatch count low near retirements."""
        room = max(int(remaining[active].max()), 1)
        steps = 1
        for c in self._chunks:
            if c <= room:
                steps = c
        return steps

    def _admit(self, group, sched, tok, live, active, remaining, t0):
        lens = [len(r.prompt) for r in group]
        bucket = self._bucket(max(lens))
        padded = any(n != bucket for n in lens)
        nb = batch_bucket(len(group), self.max_prefill_batch)
        tokens, lens_arr = pad_to_bucket([r.prompt for r in group], bucket, nb)
        fn = self.prefill_cache.get(bucket, nb, self.policy, padded)
        res = fn(self.params, self.lkv_params, jnp.asarray(tokens),
                 jnp.asarray(lens_arr))
        res.logits.block_until_ready()
        now = time.perf_counter() - t0
        first = np.asarray(jnp.argmax(res.logits, -1).astype(jnp.int32))
        for i, r in enumerate(group):
            slot = sched.place(r)
            req_cache = tf.extract_request_cache(res.cache, i)
            live = self._insert_fn(live, req_cache, slot)
            tok = tok.at[slot, 0].set(int(first[i]))
            r.out_tokens = [int(first[i])]
            r.first_token_s = now
            r.ttft_s = now - r.enqueue_s
            if r.out_tokens[-1] == self.eos_id or r.max_new_tokens <= 1:
                sched.retire(r, now=now)
                active[slot] = False
            else:
                active[slot] = True
                remaining[slot] = r.max_new_tokens - 1
        return tok, live

    def _collect(self, toks, steps, sched, active, remaining, t0):
        now = time.perf_counter() - t0
        for slot in np.nonzero(active)[0]:
            r = sched.running[slot]
            take = min(steps, int(remaining[slot]))  # drop overshoot tokens
            finished = False
            for t in toks[slot, :take].tolist():
                r.out_tokens.append(int(t))
                if int(t) == self.eos_id:
                    finished = True
                    break
            remaining[slot] -= steps
            if finished or remaining[slot] <= 0:
                sched.retire(r, now=now)
                active[slot] = False
