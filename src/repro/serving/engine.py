"""Serving engines: chunked continuous batching (current), plus the
deprecated lockstep and bucketed engines kept as benchmark baselines.

``ContinuousEngine`` streams prefill in fixed-size chunks and interleaves
them with decode (vLLM-style mixed steps):

    arrivals ──> FCFS queue ──> chunked prefill ──> decode slots
                                 (one compiled       (one slot-batched
                                  (1, chunk) step,    chunked loop)
                                  streaming scores)
                        ▲                    │
                        └── token-budget step: every iteration runs one
                            decode chunk for the live slots *and* up to
                            budget/chunk prefill chunks of the in-flight
                            prompt — decode never stalls behind a prompt,
                            and prompt length is bounded by HBM (the KV
                            buffer grows geometrically), not by a bucket
                            table.

Admission still exploits the paper's eviction: every request's
post-eviction decode cache has the same static shape
``(budget_capacity + margin)`` regardless of prompt length, so a freshly
prefilled request's cache pytree is scattered into any free slot of the
live decode cache (``transformer.insert_request_cache``) without
reshaping.

Deprecated (importable, warn on construction):

* ``ServingEngine`` — the paper-shaped lockstep engine (one same-length
  batch, prefill and decode back-to-back).
* ``BucketedEngine`` — the previous continuous engine: pad-to-bucket
  prefill with a compile cache keyed ``(bucket, batch, policy, padded)``.
  A long prompt monopolizes the device for its whole (monolithic) prefill
  and prompts beyond the largest bucket force fresh compiles; kept so
  ``benchmarks/bench_serving.py`` can quantify exactly that.
"""

from __future__ import annotations

import time
import warnings
from collections.abc import Mapping
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import EvictionConfig, ModelConfig
from repro.core import policies
from repro.core.eviction import select_topk
from repro.kernels import ops
from repro.kernels.ref import NEG_INF
from repro.models import transformer as tf
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import request_track
from repro.serving.batching import (DEFAULT_BUCKETS, ChunkCompileCache,
                                    PrefillCompileCache, _batch_bucket,
                                    _bucket_for, _pad_to_bucket)
from repro.serving.config import (ChunkingConfig, DecodeEvictionConfig,
                                  ServingConfig)
from repro.serving.kv_pool import KVBlockPool
from repro.serving.prefix_cache import PrefixCache
from repro.serving.scheduler import (Request, RequestState, SlotScheduler,
                                     plan_step)

__all__ = ["Request", "RequestState", "ServingEngine", "ContinuousEngine",
           "BucketedEngine", "ServingConfig", "DecodeEvictionConfig",
           "ChunkingConfig", "cache_bytes", "paged_sweep"]


def cache_bytes(cfg: ModelConfig, capacity: int, n_in: int) -> dict:
    """Analytic cache footprint: full vs evicted (the paper's headline)."""
    if cfg.attn is None:
        return {"full": 0, "evicted": 0, "ratio": 1.0}
    per_tok = cfg.num_layers * cfg.attn.kv_dim * 2 * 2  # K+V, bf16
    return {
        "full": n_in * per_tok,
        "evicted": capacity * per_tok,
        "ratio": n_in / max(capacity, 1),
    }


def _request_seeds(requests) -> jnp.ndarray:
    return jnp.asarray([r.eviction_seed for r in requests], jnp.int32)


def _snapshot(arr: np.ndarray) -> jnp.ndarray:
    """Freeze a host mirror for async dispatch.

    jax stages host→device transfers lazily, so an argument buffer the
    engine mutates in place after the call (cursor / position advance,
    retirement bookkeeping) can be read by the device *mid-flight* — the
    PR 5 bimodal-tokens race.  Hand jax a private copy, and mark that
    copy read-only so the next mirror added to the engine cannot silently
    reintroduce the race by reusing a handed-off buffer as its mirror:
    any in-place write to it raises instead of corrupting a dispatch.
    """
    c = np.array(arr)  # always a fresh contiguous buffer, never a view
    c.flags.writeable = False
    return jnp.asarray(c)


@partial(jax.jit,
         static_argnames=("capacity", "depth", "block_size", "nb_keep"))
def paged_sweep(pool: dict, score: jnp.ndarray, table: jnp.ndarray,
                slot: jnp.ndarray, *, capacity: int, depth: int,
                block_size: int, nb_keep: int) -> tuple[dict, jnp.ndarray]:
    """Evict-and-compact one slot's paged decode cache in place.

    The device half of a decode-eviction sweep: gather the slot's dense
    ``[0, depth)`` view through its block table, keep the ``capacity``
    highest cumulative-attention rows per (layer, kv head) — the same
    H2O heavy-hitter rule the dense ``decode_attention_step_evicting``
    applies per step, batched over the whole window — compact them into
    the first ``nb_keep`` blocks of the run (temporal order preserved,
    exactly like prefill eviction), and zero everything past them.  The
    host then frees the tail blocks ``[nb_keep, nb)`` back to the pool
    and resets the slot's cursor to ``capacity``.

    Every block covering ``[0, depth)`` must be real (non-null) when
    this runs — the host fills table gaps first — because the compacted
    rows are scattered back through those same table entries.

    ``score`` is the engine's ``(L, num_slots, depth, KV)`` cumulative
    mass buffer; kept rows carry their tallies across sweeps (H2O
    semantics), evicted and padded rows restart at zero.
    """
    bs = block_size
    nb = -(-depth // bs)  # blocks covering logical rows [0, depth)
    row = table[slot, :nb]  # (nb,) physical block ids

    def dense(leaf):  # (L, NB, bs, ...) -> (L, depth, ...)
        g = leaf[:, row]
        return g.reshape((g.shape[0], nb * bs) + g.shape[3:])[:, :depth]

    k = dense(pool["k"])  # (L, depth, KV, hd)
    v = dense(pool["v"])
    pos = dense(pool["pos"])  # (L, depth, KV)
    mask = dense(pool["mask"])
    sc = score[:, slot]  # (L, depth, KV) cumulative masses
    # top-capacity rows per (layer, kv head); invalid rows can never win
    # except on overflow, where their gathered mask stays False
    sel = jnp.moveaxis(jnp.where(mask, sc, NEG_INF), 1, 2)  # (L, KV, depth)
    idx, selmask = select_topk(sel, capacity)  # (L, KV, cap), temporal order

    def take(x):  # (L, depth, KV[, hd]) -> (L, cap, KV[, hd])
        xt = jnp.moveaxis(x, 1, 2)  # (L, KV, depth, ...)
        ix = idx if xt.ndim == 3 else idx[..., None]
        g = jnp.take_along_axis(xt, ix.astype(jnp.int32), axis=2)
        return jnp.moveaxis(g, 2, 1)

    kept = take(mask) & jnp.moveaxis(selmask, 1, 2)  # (L, cap, KV)
    k = jnp.where(kept[..., None], take(k), 0)
    v = jnp.where(kept[..., None], take(v), 0)
    pos = jnp.where(kept, take(pos), 0)
    sc_keep = jnp.where(kept, take(sc), 0.0)

    def pad(x, rows):  # (L, cap, ...) -> (L, rows, ...)
        cfgpad = [(0, 0)] * x.ndim
        cfgpad[1] = (0, rows - x.shape[1])
        return jnp.pad(x, cfgpad)

    def blk(x):  # (L, cap, ...) -> (L, nb_keep, bs, ...)
        x = pad(x, nb_keep * bs)
        return x.reshape((x.shape[0], nb_keep, bs) + x.shape[2:])

    keep_ids = row[:nb_keep]
    newpool = {
        "k": pool["k"].at[:, keep_ids].set(blk(k)),
        "v": pool["v"].at[:, keep_ids].set(blk(v)),
        "pos": pool["pos"].at[:, keep_ids].set(blk(pos)),
        "mask": pool["mask"].at[:, keep_ids].set(blk(kept)),
    }
    score = score.at[:, slot].set(pad(sc_keep, depth))
    return newpool, score


class ServingEngine:
    """Deprecated lockstep batch engine: every request in a batch shares one
    prompt length, and prefill/decode run back-to-back for the whole batch.
    Kept as the paper-shaped baseline for benchmarks and exactness tests."""

    def __init__(
        self,
        params: dict,
        cfg: ModelConfig,
        *,
        policy: str = "lookaheadkv",
        evict: Optional[EvictionConfig] = None,
        lkv_params: Optional[dict] = None,
        draft_params: Optional[dict] = None,
        draft_cfg: Optional[ModelConfig] = None,
        max_new_tokens: int = 64,
        eos_id: int = 0,
        decode_evict: bool = False,
    ):
        warnings.warn(
            "ServingEngine (lockstep) is deprecated; serve through the "
            "chunked ContinuousEngine", DeprecationWarning, stacklevel=2)
        self.params, self.cfg = params, cfg
        self.policy = policy
        self.evict = evict if evict is not None else EvictionConfig()
        self.lkv_params = lkv_params
        self.draft_params, self.draft_cfg = draft_params, draft_cfg
        self.max_new_tokens = max_new_tokens
        self.eos_id = eos_id
        # decoding-stage eviction (beyond-paper): the cache stays at
        # ``budget + margin`` even for long generations — new tokens evict
        # the lowest cumulative-attention slots once capacity is reached.
        # The dense engines only consume the margin rule; the paged
        # ContinuousEngine also reads ``interval`` (sweep period).
        self.decode_evict = DecodeEvictionConfig.coerce(decode_evict)
        self.decode_margin = self.decode_evict.margin_rows(max_new_tokens)
        self._prefill_fn = jax.jit(self._prefill)
        self._decode_fn = jax.jit(self._decode)

    # -- jit bodies ---------------------------------------------------------
    def _prefill(self, params, lkv, tokens, seeds):
        res = policies.run_eviction(
            self.policy, params, self.cfg, tokens, evict=self.evict,
            lkv_params=lkv, draft_params=self.draft_params,
            draft_cfg=self.draft_cfg, extra_slots=self.decode_margin,
            seeds=seeds,
        )
        if self.decode_evict.enabled:
            res = res._replace(cache=tf.add_decode_eviction_scores(res.cache))
        return res

    def _decode(self, params, first_token, cache):
        return policies.greedy_decode(
            params, self.cfg, first_token, cache, self.max_new_tokens
        )

    # -- public API ----------------------------------------------------------
    def serve(self, requests: list[Request]) -> list[Request]:
        """Serve a batch of same-length requests.

        ``ttft_s`` here is *batch-level by construction* — all requests
        prefill together, so they share one first-token time.  Per-request
        TTFT under mixed traffic is what ``ContinuousEngine`` reports.
        """
        assert requests, "empty batch"
        n_in = len(requests[0].prompt)
        assert all(len(r.prompt) == n_in for r in requests), \
            "batch requests by prompt length"
        tokens = jnp.asarray(np.stack([r.prompt for r in requests]))
        t0 = time.perf_counter()
        res = self._prefill_fn(self.params, self.lkv_params, tokens,
                               _request_seeds(requests))
        res.logits.block_until_ready()
        ttft = time.perf_counter() - t0
        first = jnp.argmax(res.logits, -1)[:, None].astype(jnp.int32)
        toks, _ = self._decode_fn(self.params, first, res.cache)
        toks = np.asarray(toks)  # (B, max_new_tokens)
        for i, r in enumerate(requests):
            seq = toks[i].tolist()
            if self.eos_id in seq:
                seq = seq[: seq.index(self.eos_id) + 1]
            r.out_tokens = seq
            r.ttft_s = ttft
            r.first_token_s = ttft
            r.done = True
            r.state = RequestState.DONE
        return requests

    def cache_bytes(self, n_in: int) -> dict:
        cap = self.evict.budget + self.decode_margin
        return cache_bytes(self.cfg, cap, n_in)

    def kv_device_bytes(self, batch: int = 1) -> int:
        """K+V bytes of one served batch's decode cache (the lockstep
        engine holds no persistent slot cache between batches)."""
        a = self.cfg.attn
        if a is None:
            return 0
        per_row = 2 * self.cfg.num_layers * a.kv_dim \
            * jnp.dtype(self.cfg.dtype).itemsize
        return batch * (self.evict.budget + self.decode_margin) * per_row


class _InflightPrefill:
    """Host-side cursor of the one streaming prefill in flight.  ``tip``
    is the deepest pinned prefix-cache entry along this request's prompt
    (the resume point on a hit, then each freshly inserted boundary)."""

    __slots__ = ("req", "state", "n", "s", "logits", "tip")

    def __init__(self, req: Request, state, n: int):
        self.req, self.state, self.n = req, state, n
        self.s = 0
        self.logits = None
        self.tip = None


class _SlotDecodeMixin:
    """The slot-batched decode loop shared by both continuous engines:
    jitted chunks of 1/2/4/… steps with per-slot cursors and an active
    mask.  Expects ``self.params/cfg/eos_id/_chunks`` and a
    ``self._decode_fns`` dict."""

    #: decode chunk lengths we are willing to compile
    _CHUNK_SIZES = (1, 2, 4, 8, 16)

    def _decode_fn(self, steps: int):
        fn = self._decode_fns.get(steps)
        if fn is None:
            sampling = getattr(self, "sampling", None)
            mesh = getattr(self, "mesh", None)

            def body(params, tok, cache, active, seeds):
                return policies.decode_chunk(
                    params, self.cfg, tok, cache, steps, active=active,
                    sampling=sampling, seeds=seeds, mesh=mesh)

            fn = jax.jit(body)
            self._decode_fns[steps] = fn
        return fn

    def _pick_chunk(self, remaining, active) -> int:
        """Largest configured chunk no bigger than the *longest* remaining
        stream: slots that finish mid-chunk simply have their surplus tokens
        truncated at collect time (greedy decode makes outputs prefix-stable,
        so overshoot wastes a few slot-steps but never changes tokens), which
        keeps the host-dispatch count low near retirements."""
        if not active.any():
            return 1
        room = max(int(remaining[active].max()), 1)
        steps = 1
        for c in self._chunks:
            if c <= room:
                steps = c
        return steps

    def _collect(self, toks, steps, sched, active, remaining, last_emit, t0):
        now = time.perf_counter() - t0
        for slot in np.nonzero(active)[0]:
            r = sched.running[slot]
            r.max_gap_s = max(r.max_gap_s, now - last_emit[slot])
            last_emit[slot] = now
            take = min(steps, int(remaining[slot]))  # drop overshoot tokens
            finished = False
            for t in toks[slot, :take].tolist():
                r.out_tokens.append(int(t))
                if int(t) == self.eos_id:
                    finished = True
                    break
            remaining[slot] -= steps
            if finished or remaining[slot] <= 0:
                sched.retire(r, now=now)
                active[slot] = False
                self._on_retire(slot, r)
                m = getattr(self, "_m_retired", None)
                if m is not None:
                    m.inc()
                tr = getattr(self, "trace", None)
                tid = request_track(r.uid)
                if tr is not None:
                    tr.end("decode", tid)
                # gt_oracle harvest: the retired request carries the very
                # future the oracle policy needs (its generated tokens), so
                # this is the one moment importance targets can be captured
                # from live traffic (deprecated engines lack the hook)
                h = getattr(self, "harvest", None)
                if h is not None:
                    if tr is not None:
                        tr.begin("harvest", tid)
                    h.on_retire(r)
                    if tr is not None:
                        tr.end("harvest", tid)
                # lookahead drift monitor (repro.obs.quality): same moment,
                # same reason — the generated future is in hand
                d = getattr(self, "drift", None)
                if d is not None:
                    d.on_retire(r)
                if tr is not None:
                    tr.instant("retire", tid, tokens=len(r.out_tokens))
                    tr.end("request", tid, outcome="done")
                self._release_slot(slot)

    def _on_retire(self, slot: int, req: Request) -> None:
        """Retirement hook, called while the slot's cache still exists:
        the paged engine captures the request's final kept set here when
        ``capture_admission`` asks for it (the paged counterpart of the
        dense engines' inspectable slot cache)."""

    def _release_slot(self, slot: int) -> None:
        """Retirement hook: the paged engine returns the slot's KV blocks
        to the pool here — the memory half of retiring (dense slot caches
        have nothing to free)."""


class _LegacyStatsView(Mapping):
    """Read-only mapping reproducing the pre-registry ``engine.stats``
    dict — same keys, same conditional presence — from the typed metrics
    registry, so external readers keep working through the deprecation.
    Empty before the first ``run()``; the nested component dicts
    (``prefix_cache`` / ``prefix`` / ``kv_pool``) are computed live from
    the components instead of being frozen at run end."""

    __slots__ = ("_eng",)

    def __init__(self, eng: "ContinuousEngine"):
        self._eng = eng

    def _as_dict(self) -> dict:
        e = self._eng
        if not e._run_started:
            return {}
        v = e.metrics.value
        d = {
            "prefill_chunks": int(v("serving_prefill_chunks_total")),
            "decode_chunks": int(v("serving_decode_chunks_total")),
            "decode_steps": int(v("serving_decode_steps_total")),
            "decode_time_s": float(v("serving_decode_seconds_total")),
            "max_prefill_between_decode":
                int(v("serving_max_prefill_between_decode")),
            "max_concurrency": int(v("serving_max_concurrency")),
        }
        d.update(e._run_info)
        if e.prefix_cache is not None:
            d["prefix_hits"] = int(v("serving_prefix_hits_total"))
            d["prefix_misses"] = int(v("serving_prefix_misses_total"))
            d["prefix_tokens_skipped"] = \
                int(v("serving_prefix_tokens_skipped_total"))
            d["prefix_cache"] = e.prefix_cache.stats()
            if e._last_sched is not None:
                d["prefix"] = e._last_sched.prefix_stats()
        if e.pool is not None:
            d["preemptions"] = int(v("serving_preemptions_total"))
            d["admission_blocked"] = \
                int(v("serving_admission_blocked_total"))
            if e._score_dev is not None:
                d["decode_evict_sweeps"] = \
                    int(v("serving_decode_evict_sweeps_total"))
            if e._last_sched is not None:
                d["kv_pool"] = e._last_sched.pool_stats()
        return d

    def __getitem__(self, key):
        return self._as_dict()[key]

    def __iter__(self):
        return iter(self._as_dict())

    def __len__(self):
        return len(self._as_dict())

    def __repr__(self):
        return f"_LegacyStatsView({self._as_dict()!r})"


class ContinuousEngine(_SlotDecodeMixin):
    """Chunked continuous-batching engine: streaming prefill interleaved
    with a slot-batched decode loop under a token-budget step.

    Prefill runs the fixed ``(1, chunk)`` program of
    ``transformer.prefill_chunk`` — chunk offset and true prompt length are
    traced, so the compile cache holds exactly one step program and one
    finalize program per ``(chunk, batch, policy)`` key regardless of
    traffic shape.  Streaming ``ScoreState`` accumulation makes the final
    eviction identical to monolithic prefill (see tests/test_chunked_
    prefill.py), so serving tokens still match the isolated lockstep
    engine bit-for-bit.  Eviction scores ride the attention kernels
    themselves: cumulative (h2o) prefill takes its per-chunk column-mass
    partials from ``ops.chunk_attention``'s fused second output, and the
    finalize program scores observation windows through the masked
    streaming ``ops.lookahead_score`` primitive — no dense (chunk × buffer)
    probability block exists anywhere in the serving hot path
    (``stats["score_path"]`` records which backend provided the partials).

    The decode loop is unchanged from the bucketed engine: jitted chunks of
    1/2/4/… steps with per-slot cursors and an active mask; a slot that
    finishes mid-chunk has its surplus tokens truncated at collect time
    (greedy decode is prefix-stable) and retires at the chunk boundary.

    With ``prefix_cache`` set (a ``serving.prefix_cache.PrefixCache``),
    admissions consult a radix trie of chunk-boundary ``(KV, ScoreState)``
    snapshots: a hit resumes streaming at the shared prefix's end — the
    cached prefix's attention *and* its eviction-score accumulation are
    both skipped — and a prompt that is exactly a cached prefix admits
    with zero prefill chunks (TTFT ~ one finalize).  Because the resumed
    state is bit-identical to what the request would have streamed itself,
    served tokens and kept sets are unchanged (the differential trace
    suite in tests/test_prefix_cache.py asserts this per policy).
    """

    def __init__(
        self,
        params: dict,
        cfg: ModelConfig,
        config: Optional[ServingConfig] = None,
        *,
        lkv_params: Optional[dict] = None,
        **legacy,
    ):
        if legacy:
            assert config is None, \
                "pass either a ServingConfig or legacy kwargs, not both"
            warnings.warn(
                "ContinuousEngine(**kwargs) is deprecated; build a "
                "serving.config.ServingConfig and pass it as ``config`` "
                "(see the README's Serving API migration table)",
                DeprecationWarning, stacklevel=2)
            config = ServingConfig.from_legacy(**legacy)
        elif config is None:
            config = ServingConfig()
        self.config = config
        policy = config.policy
        num_slots = config.num_slots
        chunk = config.chunking.chunk
        max_context = config.chunking.max_context
        token_budget = config.chunking.token_budget
        decode_chunk = config.chunking.decode_chunk
        max_new_tokens = config.max_new_tokens
        kv_pool = config.kv_pool
        prefix_cache = config.prefix_cache
        mesh = config.mesh
        assert tf.chunkable(cfg), \
            "chunked continuous batching serves attention-only decoder archs"
        assert policy in policies.SINGLE_PASS and policy != "gt_oracle", \
            "multi-pass policies (and gt_oracle) cannot stream; use " \
            "BucketedEngine for those baselines"
        assert policy != "full", \
            "policy 'full' caches whole prompts — its decode cache is not " \
            "shape-uniform; use BucketedEngine"
        self.params, self.cfg = params, cfg
        self.policy = policy
        self.evict = config.evict
        if config.lkv_checkpoint:
            assert lkv_params is None, \
                "pass trained modules either as lkv_params or as " \
                "config.lkv_checkpoint, not both"
            from repro.core.lookahead import load_lookahead_params
            lkv_params = load_lookahead_params(
                config.lkv_checkpoint, cfg, params["layers"])
        self.lkv_params = lkv_params
        # gt_oracle capture hook (the harvest half of the learning loop):
        # called per retired request in ``_collect``
        self.harvest = config.harvest
        # tensor-parallel serving: commit the params to their param_specs
        # shardings (Megatron GQA rules — q/o on heads, k/v on kv heads
        # over "model") so every jitted program below lowers sharded, and
        # thread the mesh into the chunk / finalize / decode bodies, where
        # attention.py shard_maps the kernels over each shard's local head
        # slice.  Lookahead params are tiny and replicate.
        self.mesh = mesh
        self._mesh_sig = None
        if mesh is not None:
            from jax.sharding import NamedSharding

            from repro.common.sharding import (lkv_specs, mesh_signature,
                                               param_specs)

            self._mesh_sig = mesh_signature(mesh)
            self.params = jax.tree.map(
                lambda x, sp: jax.device_put(x, NamedSharding(mesh, sp)),
                params, param_specs(cfg, mesh))
            if lkv_params is not None:
                self.lkv_params = jax.tree.map(
                    lambda x, sp: jax.device_put(x, NamedSharding(mesh, sp)),
                    lkv_params, lkv_specs(lkv_params))
            if kv_pool is not None:
                assert kv_pool.model_shards == int(mesh.shape["model"]), \
                    "kv pool built for a different mesh: pass the same " \
                    "mesh to KVBlockPool(..., mesh=...)"
        self.num_slots = num_slots
        self.chunk = chunk
        self.max_new_tokens = max_new_tokens
        self.eos_id = config.eos_id
        self.decode_evict = config.decode_evict
        # one margin rule for all engines (serving/config.py): a dense
        # cache keeps ``margin_rows`` append rows beyond the eviction
        # capacity; the paged pool under decode eviction keeps
        # ``interval`` rows — the growth window between evict-and-compact
        # sweeps — instead of the worst-case ``max_new_tokens + 1``
        if kv_pool is not None and self.decode_evict.enabled:
            self.decode_margin = self.decode_evict.interval
        else:
            self.decode_margin = self.decode_evict.margin_rows(max_new_tokens)
        self._chunks = tuple(c for c in self._CHUNK_SIZES if c <= decode_chunk)
        self.token_budget = token_budget or (chunk + num_slots * decode_chunk)
        # the decode-slot capacity must be budget-bound, not context-bound,
        # so context growth never reshapes the live cache
        self.capacity = tf.decode_cache_capacity(
            cfg, policy, self.evict, n_keys_max=1 << 30)
        # context rungs are chunk * 2^k.  All standard traffic (prompts
        # within ``max_context``) shares the single base rung — one
        # compiled chunk shape; longer prompts climb to the smallest rung
        # that fits, so a 16k outlier neither inflates the prefill cost of
        # later short prompts (it gets its own rung) nor adds more than
        # O(log max_len) compiled shapes over a serving lifetime
        self._base_cap = self._rung(max(max_context, self.capacity))
        self._ctx_cap = self._base_cap  # high-water mark (observability)
        self.chunk_cache = ChunkCompileCache(self._build,
                                             mesh_sig=self._mesh_sig)
        self._decode_fns: dict = {}
        self._insert_fn = jax.jit(tf.insert_request_cache)
        # fused sampling epilogue (core/policies.py): temperature / top-k /
        # top-p run inside the jitted decode chunk with per-request keys
        # folded on token position — greedy (None / temperature 0) keeps
        # the bit-exact differential contract
        self.sampling = config.sampling
        self._seeds_h = np.zeros(num_slots, np.int32)
        # prefix-aware KV reuse: chunk-boundary (KV, ScoreState) snapshots
        # shared across requests via a radix trie (serving/prefix_cache.py).
        # A hit resumes mid-prefill with identical streamed state, so the
        # served tokens and kept sets are bit-equal to an uncached serve.
        self.prefix_cache = prefix_cache
        if prefix_cache is not None:
            prefix_cache.bind(chunk=chunk, policy=policy, model=self.params)
        # paged KV memory (serving/kv_pool.py): decode caches live in a
        # shared block pool instead of dense per-slot buffers — eviction
        # frees real device blocks, and admission is gated by free-block
        # count (scheduler admission_gate) rather than slot count alone.
        self.pool = kv_pool
        self._paged_depth = self.capacity + self.decode_margin
        # decode-time streaming eviction on the paged pool: the engine
        # holds the per-slot cumulative attention masses (fed by the fused
        # kernel's second output each decode chunk) and periodically
        # evicts-and-compacts any slot whose cursor reaches the paged
        # depth, freeing the tail blocks back to the pool mid-generation
        self._score_dev: Optional[jnp.ndarray] = None
        if kv_pool is not None:
            if self.decode_evict.enabled:
                assert mesh is None, \
                    "decode-time eviction on the paged pool is " \
                    "single-device (mesh-sharded serving keeps the dense " \
                    "decode_evict path)"
                a = cfg.attn
                self._score_dev = jnp.zeros(
                    (cfg.num_layers, num_slots, self._paged_depth,
                     a.num_kv_heads), jnp.float32)
            self._nb_max = kv_pool.blocks_for(self._paged_depth)
            assert kv_pool.usable_blocks >= self._nb_max + 1, \
                "pool cannot hold even one request's worst-case cache; " \
                "raise --kv-pool-mb or shrink --kv-block-size"
            # host mirrors of the device block tables / cursors — the
            # allocator needs them synchronously, and the advance rule is
            # deterministic (active slots move `steps` per decode chunk),
            # so mirrors never drift from the device state they shadow
            self._table_h = np.zeros((num_slots, self._nb_max), np.int32)
            self._table_dev = _snapshot(self._table_h)
            self._cursor_h = np.zeros(num_slots, np.int32)
            self._npos_h = np.zeros(num_slots, np.int32)
            self._slot_blocks: dict[int, list[int]] = {
                s: [] for s in range(num_slots)}
            self._admit_seq = np.full(num_slots, -1, np.int64)
            self._admit_counter = 0
            # admission policy: with ``reserve_appends`` (default) every
            # admission reserves its worst-case decode-append blocks, so a
            # running request can never be starved by a later one — the
            # vLLM-style watermark.  Without it admission is optimistic
            # (more concurrency when generations end early) and the
            # preempt-to-queue path is the safety valve.
            self.reserve_appends = config.reserve_appends
            self._slot_reserved = np.zeros(num_slots, np.int64)
            bs = kv_pool.block_size
            # block indices only decode appends can touch: [capacity, depth)
            self._append_jbs = list(range(
                self.capacity // bs, (self._paged_depth - 1) // bs + 1))
            if prefix_cache is not None and prefix_cache.pool is not None:
                assert prefix_cache.pool is kv_pool, \
                    "prefix cache bound to a different block pool"
        self.capture_admission = config.capture_admission
        # -- observability (repro.obs) ----------------------------------
        # one typed registry per engine replaces the historical ad-hoc
        # ``stats`` dict (kept below as a deprecated read-only view);
        # components mirror their state through callback gauges, the
        # tracer (when attached) receives per-request spans
        self.metrics = MetricsRegistry()
        self.drift = config.drift
        self.trace = None
        self._sync_timers = False
        self._run_started = False  # legacy view: {} before the first run()
        self._last_sched: Optional[SlotScheduler] = None
        self._run_info: dict = {}
        self._uid_seq: dict = {}  # uid -> first admission_seq (replay link)
        self._serve_seq = 0
        self._register_metrics()
        self.chunk_cache.bind_metrics(self.metrics)
        if self.pool is not None:
            self.pool.bind_metrics(self.metrics)
        if self.prefix_cache is not None:
            self.prefix_cache.bind_metrics(self.metrics)
        self.set_trace(config.trace)

    # -- observability ----------------------------------------------------
    def _register_metrics(self) -> None:
        m = self.metrics
        sync_note = (
            "Host perf_counter timer; whether it measures synced execution "
            "(the engine blocks on the chunk's output arrays before "
            "stamping) or async dispatch plus the token sync is recorded "
            "in serving_build's sync_timers key.")
        self._m_prefill_chunks = m.counter(
            "serving_prefill_chunks_total",
            "Prefill chunk programs dispatched.")
        self._m_prefill_seconds = m.counter(
            "serving_prefill_seconds_total",
            "Wall seconds spent in prefill chunk programs. " + sync_note)
        self._m_prefill_chunk_hist = m.histogram(
            "serving_prefill_chunk_seconds",
            "Per-prefill-chunk wall time distribution. " + sync_note)
        self._m_decode_chunks = m.counter(
            "serving_decode_chunks_total",
            "Slot-batched decode chunk programs dispatched.")
        self._m_decode_steps = m.counter(
            "serving_decode_steps_total",
            "Decode steps advanced (chunk dispatches x chunk length).")
        self._m_decode_seconds = m.counter(
            "serving_decode_seconds_total",
            "Wall seconds spent in decode chunks (the legacy "
            "stats['decode_time_s']). " + sync_note)
        self._m_decode_chunk_hist = m.histogram(
            "serving_decode_chunk_seconds",
            "Per-decode-chunk wall time distribution. " + sync_note)
        self._m_ttft = m.histogram(
            "serving_ttft_seconds",
            "Time to first token per request, from schedulability to the "
            "first emitted token (re-admissions keep the original stamp).")
        self._m_max_prefill_between_decode = m.gauge(
            "serving_max_prefill_between_decode",
            "Worst count of prefill chunks run between two decode chunks "
            "while slots were live — the decode-stall bound the "
            "token-budget step enforces.")
        self._m_max_concurrency = m.gauge(
            "serving_max_concurrency",
            "High-water mark of concurrently running requests.")
        self._m_requests = m.counter(
            "serving_requests_total", "Requests submitted to run().")
        self._m_retired = m.counter(
            "serving_requests_retired_total",
            "Requests retired (finished) across admission and decode.")
        self._m_prefix_hits = m.counter(
            "serving_prefix_hits_total",
            "Admissions resumed from a prefix-cache snapshot.")
        self._m_prefix_misses = m.counter(
            "serving_prefix_misses_total",
            "Admissions that probed the prefix cache and missed.")
        self._m_prefix_tokens_skipped = m.counter(
            "serving_prefix_tokens_skipped_total",
            "Prompt tokens whose prefill (attention and score "
            "accumulation) was skipped via prefix-cache hits.")
        self._m_preemptions = m.counter(
            "serving_preemptions_total",
            "Running requests preempted to the queue (paged pool dry).")
        self._m_admission_blocked = m.counter(
            "serving_admission_blocked_total",
            "Prefilled admissions bounced back to the queue head because "
            "the pool could not place their kept rows.")
        self._m_sweeps = m.counter(
            "serving_decode_evict_sweeps_total",
            "Decode-time evict-and-compact sweeps on the paged pool.")
        self._m_build = m.info(
            "serving_build",
            "Engine build facts: score/decode dispatch path, device mesh, "
            "and whether timers are device-synced (sync_timers).")

    def set_trace(self, trace) -> None:
        """Attach (or detach, with ``None``) an ``obs.trace.TraceRecorder``.

        Resolves the timer-sync mode: ``config.sync_timers`` when set,
        else sync exactly when tracing — so untimed serving keeps the
        async-dispatch pipeline — and propagates the recorder to the
        compile cache (jit_compile events) and the drift monitor."""
        self.trace = trace
        st = self.config.sync_timers
        self._sync_timers = bool(trace is not None if st is None else st)
        if trace is not None:
            trace.sync = self._sync_timers
        self.chunk_cache.trace = trace
        if self.drift is not None:
            self.drift.bind(metrics=self.metrics, trace=trace)

    @property
    def stats(self) -> "_LegacyStatsView":
        """Deprecated: the historical per-run ``stats`` dict, as a
        read-only view computed from the metrics registry.  Read
        ``engine.metrics`` (``value()`` / ``snapshot()`` /
        ``prometheus_text()``) instead."""
        warnings.warn(
            "ContinuousEngine.stats is deprecated; read the typed metrics "
            "registry at engine.metrics (see the README's stats() -> "
            "registry migration table)", DeprecationWarning, stacklevel=2)
        return _LegacyStatsView(self)

    # -- compile-cache bodies ------------------------------------------------
    def _build(self, kind: str, policy: str):
        if kind == "chunk":
            def fn(params, state, tokens, n_total):
                return tf.prefill_chunk(params, self.cfg, state, tokens,
                                        n_total, policy=policy,
                                        mesh=self.mesh)
        else:  # finalize
            def fn(params, lkv, state, n_total, seeds):
                cache = tf.prefill_finalize(
                    params, self.cfg, state, n_total, policy=policy,
                    evict=self.evict, lkv_params=lkv,
                    extra_slots=self.decode_margin, seeds=seeds,
                    mesh=self.mesh,
                )
                if self.decode_evict.enabled:
                    cache = tf.add_decode_eviction_scores(cache)
                return cache

        return fn

    # -- geometry ------------------------------------------------------------
    def _rung(self, need: int) -> int:
        """Smallest chunk * 2^k >= ``need`` (the geometric buffer ladder)."""
        r = self.chunk
        while r < need:
            r *= 2
        return r

    def _request_context(self, n_prompt: int) -> int:
        """KV-buffer depth for one request: the base rung for everything
        within ``max_context``, else the smallest ladder rung that fits the
        prompt + observation rows.  A new rung recompiles the two chunk
        programs once — O(log max_len) compiles over a serving lifetime,
        vs one per bucket for the deprecated ladder."""
        need = policies.chunk_capacity_for(self.cfg, self.policy, n_prompt,
                                           self.chunk)
        cap = max(self._rung(need), self._base_cap)
        self._ctx_cap = max(self._ctx_cap, cap)  # high-water mark
        return cap

    def cache_bytes(self, n_in: int) -> dict:
        """Analytic full-vs-evicted footprint — plus, when serving paged,
        the *actual* pool utilization (blocks used/free, prefix-pinned
        bytes, high-water mark) instead of dense-capacity theory:
        ``evicted`` becomes the measured peak per-request block footprint
        once traffic has been served."""
        out = cache_bytes(self.cfg, self.capacity + self.decode_margin, n_in)
        if self.pool is not None:
            s = self.pool.stats()
            out["pool"] = s
            peak = int(self.metrics.value("serving_max_concurrency"))
            if peak:
                # measured peak per-request footprint (prefix-cache pins
                # are shared capital, not per-request cost)
                decode_hw = max(
                    s["bytes_high_water"] - s["bytes_pinned_prefix"],
                    s["block_bytes"])
                out["evicted"] = decode_hw // peak
                out["ratio"] = out["full"] / max(out["evicted"], 1)
        return out

    def kv_device_bytes(self) -> int:
        """Device bytes the decode KV actually reserves: the whole block
        pool when paged, the dense ``num_slots × (capacity + margin)``
        slot cache otherwise (K+V payload, the paper's headline unit)."""
        if self.pool is not None:
            return self.pool.stats()["bytes_total"]
        a = self.cfg.attn
        per_row = 2 * self.cfg.num_layers * a.kv_dim \
            * jnp.dtype(self.cfg.dtype).itemsize
        return self.num_slots * (self.capacity + self.decode_margin) * per_row

    def warmup(self, prompt_lens=(), batch_sizes=(1,)) -> None:
        """Pre-instantiate the (chunk, batch, policy) compile-cache entries.
        ``prompt_lens`` only pre-sizes the KV-buffer ladder — prompt length
        is a traced argument, not a compile key."""
        for n in prompt_lens:
            self._request_context(n)
        self.chunk_cache.get("chunk", self.chunk, 1, self.policy)
        self.chunk_cache.get("finalize", self.chunk, 1, self.policy)

    # -- serving loop --------------------------------------------------------
    def run(self, requests: list[Request]) -> list[Request]:
        """Serve ``requests`` to completion; returns them in finish order.

        ``arrival_s`` offsets are interpreted on the wall clock relative to
        the start of the call.  Each loop iteration is one token-budget
        step: at most ``plan_step(...)`` prefill chunks of the in-flight
        prompt, then one decode chunk for every live slot — so no live
        slot's decode ever waits longer than one step behind a prompt of
        *any* length.
        """
        sched = SlotScheduler(
            self.num_slots, bucket_for=lambda n: self.chunk,
            max_prefill_batch=1,
            admission_gate=self._admission_gate if self.pool is not None
            else None)
        for r in requests:
            assert r.max_new_tokens <= self.max_new_tokens, \
                "request exceeds the engine's max_new_tokens cache margin"
            sched.submit(r)
        t0 = time.perf_counter()
        if self.pool is not None:
            sched.bind_pool(self.pool)
            live = None  # paged state: block tables + pool, no dense cache
            if self._score_dev is not None:  # clean tallies across runs
                self._score_dev = jnp.zeros_like(self._score_dev)
        else:
            live = tf.init_decode_cache(self.cfg, self.num_slots,
                                        self.capacity + self.decode_margin,
                                        per_slot_cursor=True)
            if self.decode_evict.enabled:
                live = tf.add_decode_eviction_scores(live)
        tok = jnp.zeros((self.num_slots, 1), jnp.int32)
        active = np.zeros(self.num_slots, bool)
        remaining = np.zeros(self.num_slots, np.int64)
        last_emit = np.zeros(self.num_slots, np.float64)
        # fused Pallas scoring requires a *static* per-layer window —
        # patterned local:global archs trace the window inside the layer
        # scan, which routes ops.chunk_attention to the jnp fallback
        static_window = tf.is_global_flags(self.cfg) is None
        # fresh collection epoch per run (the historical per-run stats
        # semantics benches rely on: warm up, then time the same engine);
        # callback gauges mirror live component state and are untouched
        self.metrics.reset()
        self._run_started = True
        self._last_sched = sched
        self._uid_seq = {}
        sched.bind_metrics(self.metrics)
        self._run_info = {
            "score_path": ("pallas-fused"
                           if ops.use_pallas() and static_window
                           else "jnp-fallback"),
            # which paged_decode_attention tier serves this run
            # (kernel / gather / fallback); "dense" when unpooled
            "decode_path": (ops.paged_decode_path(self._paged_depth)
                            if self.pool is not None else "dense"),
            # device mesh this engine serves on (None: single device);
            # bench rows carry it next to decode_path
            "mesh": ({n: int(self.mesh.shape[n])
                      for n in self.mesh.axis_names}
                     if self.mesh is not None else None),
        }
        self._m_build.set(sync_timers=self._sync_timers, **self._run_info)
        self._m_requests.inc(len(requests))

        try:
            self._run_loop(sched, tok, live, active, remaining, last_emit,
                           t0)
        finally:
            if self.pool is not None:
                # a failed run must not leak blocks into the next one (a
                # clean run has already freed every slot at retirement)
                for s in range(self.num_slots):
                    self._free_slot_blocks(s)
        return sched.finished

    def _run_loop(self, sched, tok, live, active, remaining, last_emit,
                  t0) -> None:
        pf: Optional[_InflightPrefill] = None
        since_decode = 0
        try:
            while sched.has_work() or pf is not None:
                now = time.perf_counter() - t0
                if pf is None:
                    req = sched.next_request(now)
                    if req is not None:
                        pf = self._begin_prefill(req)
                if pf is not None:
                    steps = self._pick_chunk(remaining, active) if active.any() \
                        else max(self._chunks)
                    _, n_chunks = plan_step(
                        token_budget=self.token_budget, chunk=self.chunk,
                        n_active=int(active.sum()), decode_steps=steps,
                        prefill_pending=True,
                    )
                    for _ in range(n_chunks):
                        if pf.s < pf.n:  # a full prefix-cache hit has no chunks
                            self._prefill_step(pf)
                            if active.any():  # only live slots can be stalled
                                since_decode += 1
                        if pf.s >= pf.n:
                            tok, live = self._admit(pf, sched, tok, live, active,
                                                    remaining, last_emit, t0)
                            pf = None
                            break
                self._m_max_concurrency.max(len(sched.running))
                if active.any():
                    self._m_max_prefill_between_decode.max(since_decode)
                    since_decode = 0
                    steps = self._pick_chunk(remaining, active)
                    if self.pool is not None:
                        if self._score_dev is not None:
                            # decode-time eviction: compact every slot
                            # whose cursor reached the paged depth, then
                            # cap the chunk so no active cursor can
                            # overrun the depth mid-chunk (the sweep
                            # trigger is checked only between chunks)
                            self._decode_evict_sweep(sched, active,
                                                     remaining, last_emit)
                            if not active.any():
                                continue
                            room = int(np.min(
                                (self._paged_depth - self._cursor_h)[active]))
                            steps = max(c for c in self._chunks
                                        if c <= max(room, 1))
                        # grow every live slot's append blocks before the
                        # chunk runs — a missing block would null-route the
                        # appends; preempts the latest admission when dry
                        self._ensure_append_blocks(sched, active, remaining,
                                                   last_emit, steps)
                        if not active.any():
                            continue  # every live slot was preempted
                        dispatched = active.copy()
                        fn = self._decode_fn_paged(steps)
                        tr = self.trace
                        if tr is not None:
                            tr.begin("decode_chunk", tr.ENGINE, steps=steps,
                                     slots=int(active.sum()))
                        t_dec = time.perf_counter()
                        # _snapshot the host mirrors before handing them
                        # to jax: dispatch is async and the host->device
                        # staging of an argument can happen after this
                        # call returns, so a buffer we mutate in place
                        # below (cursor/npos advance, retirement
                        # bookkeeping) would race the device read
                        if self._score_dev is not None:
                            tok, ptree, toks, self._score_dev = fn(
                                self.params, tok, self._table_dev,
                                _snapshot(self._cursor_h),
                                _snapshot(self._npos_h[:, None]),
                                self.pool.tree(), _snapshot(active),
                                _snapshot(self._seeds_h), self._score_dev)
                        else:
                            tok, ptree, toks = fn(
                                self.params, tok, self._table_dev,
                                _snapshot(self._cursor_h),
                                _snapshot(self._npos_h[:, None]),
                                self.pool.tree(), _snapshot(active),
                                _snapshot(self._seeds_h))
                        if self._sync_timers:
                            # device-time attribution: block on the whole
                            # output pytree so the stamp below measures
                            # execution, not dispatch
                            jax.block_until_ready((tok, ptree, toks))
                        self.pool.set_tree(ptree)
                        # mirror the device advance rule exactly: slots
                        # active at dispatch move `steps`, cursors clamp
                        self._cursor_h[dispatched] = np.minimum(
                            self._cursor_h[dispatched] + steps,
                            self._paged_depth)
                        self._npos_h[dispatched] += steps
                    else:
                        fn = self._decode_fn(steps)
                        tr = self.trace
                        if tr is not None:
                            tr.begin("decode_chunk", tr.ENGINE, steps=steps,
                                     slots=int(active.sum()))
                        t_dec = time.perf_counter()
                        tok, live, toks = fn(self.params, tok, live,
                                             jnp.asarray(active),
                                             jnp.asarray(self._seeds_h))
                        if self._sync_timers:
                            jax.block_until_ready((tok, live, toks))
                    toks_np = np.asarray(toks)  # device sync: tokens landed
                    dt = time.perf_counter() - t_dec
                    if tr is not None:
                        tr.end("decode_chunk", tr.ENGINE)
                    self._m_decode_chunks.inc()
                    self._m_decode_steps.inc(steps)
                    self._m_decode_seconds.inc(dt)
                    self._m_decode_chunk_hist.observe(dt)
                    self._collect(toks_np, steps, sched, active,
                                  remaining, last_emit, t0)
                elif pf is None:
                    now2 = time.perf_counter() - t0
                    if sched.has_arrived(now2):
                        if self.pool is not None and not sched.running:
                            # nothing can retire to free blocks: reclaim
                            # prefix-cache pins or fail loudly instead of
                            # spinning on a gated queue head
                            self._reclaim_for_head(sched)
                        continue  # a request is admissible right now
                    nxt = sched.next_arrival()
                    if nxt is None:
                        break  # defensive: nothing queued, nothing running
                    wait = nxt - (time.perf_counter() - t0)
                    if wait > 0:
                        time.sleep(min(wait, 0.05))
        finally:
            # an exception escaping the loop must not leak a trie pin: the
            # cache outlives run() calls, and a leaked ref would make the
            # pinned entry unevictable forever
            if (pf is not None and pf.tip is not None
                    and self.prefix_cache is not None):
                self.prefix_cache.release(pf.tip)
                pf.tip = None

    # -- internals -----------------------------------------------------------
    def _begin_prefill(self, req: Request) -> _InflightPrefill:
        n = len(req.prompt)
        cap = self._request_context(n)
        tr = self.trace
        tid = request_track(req.uid)
        if tr is not None:
            # one "request" span per serve attempt; a re-serve (preemption
            # replay, or an admission bounced off a dry pool) opens a new
            # span whose replay_of carries the original admission_seq —
            # the replay <-> original link the span tests assert
            seq = self._serve_seq
            self._serve_seq += 1
            args = {"uid": req.uid, "admission_seq": seq, "n_prompt": n}
            if req.uid in self._uid_seq:
                args["replay_of"] = self._uid_seq[req.uid]
            else:
                self._uid_seq[req.uid] = seq
            tr.begin("request", tid, **args)
        if self.prefix_cache is not None:
            if tr is not None:
                tr.begin("prefix_probe", tid)
            # only snapshots streamed under this request's KV-buffer rung
            # match — the condition for a bitwise-identical resume
            entry = self.prefix_cache.lookup(req.prompt, capacity=cap)
            if entry is not None:
                # materialize before pinning: if it raises there is no
                # _InflightPrefill yet, so a pin taken here could never be
                # released by the loop's finally
                state, logits = self.prefix_cache.materialize(entry, cap)
                self.prefix_cache.acquire(entry)
                pf = _InflightPrefill(req, state, n)
                pf.s = entry.depth
                pf.logits = logits  # the boundary chunk's next-token logits
                pf.tip = entry
                req.cached_prefix_tokens = entry.depth
                self._m_prefix_hits.inc()
                self._m_prefix_tokens_skipped.inc(entry.depth)
                if tr is not None:
                    tr.end("prefix_probe", tid, hit=True, depth=entry.depth)
                return pf
            self._m_prefix_misses.inc()
            if tr is not None:
                tr.end("prefix_probe", tid, hit=False, depth=0)
        state = tf.init_chunk_state(self.cfg, self.policy, 1, cap)
        return _InflightPrefill(req, state, n)

    def _prefill_step(self, pf: _InflightPrefill) -> None:
        blk = np.zeros((1, self.chunk), np.int32)
        seg = pf.req.prompt[pf.s:pf.s + self.chunk]
        blk[0, :len(seg)] = seg
        fn = self.chunk_cache.get("chunk", self.chunk, 1, self.policy)
        tr = self.trace
        if tr is not None:
            tr.begin("prefill_chunk", request_track(pf.req.uid), s=pf.s)
        t_pf = time.perf_counter()
        pf.state, pf.logits = fn(self.params, pf.state, jnp.asarray(blk),
                                 jnp.asarray(pf.n, jnp.int32))
        if self._sync_timers:
            # device-time attribution: without the block the stamp below
            # measures dispatch only (JAX async dispatch)
            jax.block_until_ready(pf.logits)
        dt = time.perf_counter() - t_pf
        if tr is not None:
            tr.end("prefill_chunk", request_track(pf.req.uid))
        pf.s += self.chunk
        self._m_prefill_chunks.inc()
        self._m_prefill_seconds.inc(dt)
        self._m_prefill_chunk_hist.observe(dt)
        # cache the boundary just crossed (whole-chunk prefixes only — a
        # partial final chunk contains pad rows and is never cacheable)
        if self.prefix_cache is not None and pf.s <= pf.n:
            entry = self.prefix_cache.insert(
                pf.req.prompt[:pf.s], state=pf.state, logits=pf.logits,
                parent=pf.tip)
            if entry is not None:
                self.prefix_cache.acquire(entry)
                if pf.tip is not None:  # the parent link keeps it alive now
                    self.prefix_cache.release(pf.tip)
                pf.tip = entry

    def _admit(self, pf, sched, tok, live, active, remaining, last_emit, t0):
        r = pf.req
        tr = self.trace
        tid = request_track(r.uid)
        fn = self.chunk_cache.get("finalize", self.chunk, 1, self.policy)
        seeds = _request_seeds([r])
        if tr is not None:
            tr.begin("finalize", tid)
        cache = fn(self.params, self.lkv_params, pf.state,
                   jnp.asarray(pf.n, jnp.int32), seeds)
        if self.prefix_cache is not None and pf.tip is not None:
            self.prefix_cache.release(pf.tip)
            pf.tip = None
        if self.capture_admission:
            r.admission_cache = {
                key: np.asarray(val) for key, val in cache["attn"].items()
                if key in ("mask", "pos", "score")
            }
        if self._sync_timers:
            jax.block_until_ready(cache)
        pf.logits.block_until_ready()
        if tr is not None:
            tr.end("finalize", tid)
        if self.pool is not None:
            slot = self._paged_place(sched, r, cache)
            if slot is None:
                # the gate's headroom was eaten by running slots' appends
                # during this prefill: back to the queue head, re-prefill
                # when blocks free (FCFS order and served tokens unchanged
                # — greedy decode is deterministic)
                self._m_admission_blocked.inc()
                sched.push_front(r)
                if tr is not None:
                    tr.end("request", tid, outcome="admission_blocked")
                return tok, live
        else:
            slot = sched.place(r)
            live = self._insert_fn(live, cache, slot)
        now = time.perf_counter() - t0
        self._seeds_h[slot] = r.eviction_seed
        first = self._first_token(pf.logits, r.eviction_seed, pf.n)
        tok = tok.at[slot, 0].set(first)
        r.out_tokens = [first]
        if tr is not None:
            tr.instant("first_token", tid, token=first)
        if r.first_token_s is None:
            # a re-admitted (preempted) request keeps its original stamp:
            # the client received its first token then, and the replayed
            # tokens are bit-identical — the preemption shows up in
            # max_gap_s / tpot_s, where the stall honestly belongs
            r.first_token_s = now
            r.ttft_s = now - r.enqueue_s
            self._m_ttft.observe(r.ttft_s)
        if r.preempt_emit_s is not None:
            # the client-visible stall spans preemption to this re-emit
            r.max_gap_s = max(r.max_gap_s, now - r.preempt_emit_s)
            r.preempt_emit_s = None
        last_emit[slot] = now
        if first == self.eos_id or r.max_new_tokens <= 1:
            sched.retire(r, now=now)
            active[slot] = False
            self._on_retire(slot, r)
            self._release_slot(slot)
            self._m_retired.inc()
            if tr is not None:
                tr.instant("retire", tid, tokens=len(r.out_tokens))
                tr.end("request", tid, outcome="done")
        else:
            active[slot] = True
            remaining[slot] = r.max_new_tokens - 1
            if tr is not None:
                tr.begin("decode", tid)
        return tok, live

    # -- paged-KV internals (serving/kv_pool.py) --------------------------
    #
    # The decode cache of every live slot is a run of pool blocks behind a
    # per-slot block table; the *logical* layout is bit-identical to the
    # dense engine's (kept rows at [0, capacity), appends from `capacity`),
    # with never-valid gaps and not-yet-grown tails backed by the null
    # block.  Admission writes only the blocks that cover actual kept rows
    # — that is where eviction quality becomes freed memory — and append
    # blocks grow one at a time ahead of each decode chunk.

    def _request_blocks(self, n_prompt: int) -> tuple[int, int]:
        """(worst-case kept-data blocks, append blocks beyond them) for a
        prompt of ``n_prompt`` tokens — the admission cost model.  Short
        prompts and tight budgets need fewer data blocks than the dense
        engine's uniform ``capacity + margin`` rows: that delta is the
        concurrency eviction buys.  Under decode-time eviction the
        worst case shrinks again — the slot's whole window is
        ``capacity + interval`` rows instead of ``capacity +
        max_new_tokens + 1`` — but sweeps eventually materialize *every*
        block of it (gap blocks included), so the append promise is the
        full window minus the admitted data blocks."""
        data = self.pool.blocks_for(min(n_prompt, self.capacity))
        if self._score_dev is not None:
            return data, self._nb_max - data
        appends = sum(1 for jb in self._append_jbs if jb >= data)
        return data, appends

    def _admission_gate(self, req: Request) -> bool:
        """Free-block admission: the FCFS head admits only when the pool
        can cover its worst-case kept rows — plus, under
        ``reserve_appends``, its whole future decode growth (no-preempt
        guarantee); optimistic admission asks only one append block.
        Blocks evictable from the prefix cache count as free — the engine
        reclaims them on demand."""
        data, appends = self._request_blocks(len(req.prompt))
        need = data + (appends if self.reserve_appends else 1)
        free = self.pool.available_blocks()
        if self.prefix_cache is not None and self.prefix_cache.pool is not None:
            free += self.prefix_cache.evictable_pool_blocks()
        return free >= need

    def _alloc_blocks(self, n: int) -> Optional[np.ndarray]:
        """Pool allocation that reclaims prefix-cache blocks on demand:
        live requests outrank cached prefixes."""
        ids = self.pool.alloc(n)
        if ids is None and (self.prefix_cache is not None
                            and self.prefix_cache.pool is not None):
            # shortfall vs *available* blocks: ordinary allocs may not dip
            # into append reservations, so reclaiming only down to the
            # free-list size would under-evict and leave alloc failing
            if self.prefix_cache.evict_pool_blocks(
                    n - self.pool.available_blocks()):
                ids = self.pool.alloc(n)
        return ids

    def _reserve_blocks(self, n: int) -> bool:
        """`pool.reserve` with the same reclaim-from-prefix-cache fallback
        as `_alloc_blocks`.  Without it the admission gate (which counts
        evictable prefix blocks as free) and a failing reserve would agree
        to disagree forever: the gate re-admits, the reserve re-fails with
        the pool state unchanged — a livelock."""
        if self.pool.reserve(n):
            return True
        if self.prefix_cache is not None and self.prefix_cache.pool is not None:
            if self.prefix_cache.evict_pool_blocks(
                    n - self.pool.available_blocks()):
                return self.pool.reserve(n)
        return False

    def _paged_place(self, sched, r: Request, cache: dict) -> Optional[int]:
        """Write the admitted cache's kept rows into freshly allocated
        blocks and point a slot's table at them.  Returns the slot, or
        None when the pool cannot cover the kept rows right now."""
        mask = cache["attn"]["mask"]  # (L, 1, C, KV)
        C = mask.shape[2]
        rows = jnp.arange(C, dtype=jnp.int32)[None, None, :, None]
        used = int(jnp.max(jnp.where(mask, rows, 0))) + 1
        ids = self._alloc_blocks(self.pool.blocks_for(used))
        if ids is None:
            return None
        if self._score_dev is not None:
            # sweeps compact through every block of [0, depth), so gap
            # blocks below the append window count toward the promise too
            outstanding = self._nb_max - len(ids)
        else:
            outstanding = sum(1 for jb in self._append_jbs if jb >= len(ids))
        if self.reserve_appends and not self._reserve_blocks(outstanding):
            self.pool.free(ids)  # promise can't be kept: don't admit
            return None
        self.pool.write_cache(cache["attn"], ids)
        slot = sched.place(r)
        self._slot_reserved[slot] = outstanding if self.reserve_appends else 0
        self._admit_seq[slot] = self._admit_counter
        self._admit_counter += 1
        self._slot_blocks[slot] = [int(b) for b in ids]
        self._table_h[slot] = 0
        self._table_h[slot, :len(ids)] = ids
        self._table_dev = _snapshot(self._table_h)
        self._cursor_h[slot] = self.capacity  # appends start where dense do
        self._npos_h[slot] = int(cache["next_pos"][0, 0])
        if self._score_dev is not None:
            # arm the slot's cumulative tallies exactly as the dense
            # engine's add_decode_eviction_scores seeds its score field
            # (finalize already attached it: valid kept rows = unit mass)
            sc = cache["attn"]["score"]  # (L, 1, C, KV)
            assert sc.shape[2] == self._paged_depth, \
                "admitted cache depth must match the paged window"
            self._seed_score(slot, sc)
        return slot

    def _decode_fn_paged(self, steps: int):
        scored = self._score_dev is not None
        fn = self._decode_fns.get(("paged", steps, scored))
        if fn is None:
            depth = self._paged_depth
            sampling = self.sampling
            mesh = self.mesh

            if scored:
                # the score buffer rides *inside* the pool dict: the
                # transformer layer scan slices its (L, S, depth, KV)
                # leaf per layer like every other pool leaf, and the
                # attention step adds the fused kernel's masses to it —
                # no signature changes anywhere below decode_chunk
                def body(params, tok, table, cursor, next_pos, pool,
                         active, seeds, score):
                    pool = dict(pool, score=score)
                    cache = {"attn": {"table": table}, "pool": pool,
                             "cursor": cursor, "next_pos": next_pos}
                    last, cache, toks = policies.decode_chunk(
                        params, self.cfg, tok, cache, steps, active=active,
                        paged_depth=depth, sampling=sampling, seeds=seeds,
                        mesh=mesh)
                    newpool = dict(cache["pool"])
                    newscore = newpool.pop("score")
                    return last, newpool, toks, newscore
            else:
                def body(params, tok, table, cursor, next_pos, pool,
                         active, seeds):
                    cache = {"attn": {"table": table}, "pool": pool,
                             "cursor": cursor, "next_pos": next_pos}
                    last, cache, toks = policies.decode_chunk(
                        params, self.cfg, tok, cache, steps, active=active,
                        paged_depth=depth, sampling=sampling, seeds=seeds,
                        mesh=mesh)
                    return last, cache["pool"], toks

            fn = jax.jit(body)
            self._decode_fns[("paged", steps, scored)] = fn
        return fn

    def _seed_score(self, slot: int, score: jnp.ndarray) -> None:
        """Write an admitted request's initial cumulative-score plane
        ((L, 1, depth, KV), from ``add_decode_eviction_scores``) into the
        engine's per-slot score buffer."""
        fn = self._decode_fns.get("seed_score")
        if fn is None:
            def body(buf, sc, slot):
                return buf.at[:, slot].set(sc[:, 0].astype(jnp.float32))

            fn = jax.jit(body)
            self._decode_fns["seed_score"] = fn
        self._score_dev = fn(self._score_dev, score,
                             jnp.asarray(slot, jnp.int32))

    def _decode_evict_sweep(self, sched, active, remaining,
                            last_emit) -> None:
        """Evict-and-compact every live slot whose cursor reached the
        paged depth: run the jitted ``paged_sweep`` (keep the
        ``capacity`` heaviest rows, compact them into the head blocks),
        free the tail blocks back to the pool mid-generation, and reset
        the slot's cursor to ``capacity``.  Table gaps below the kept
        window (short admissions never allocated them) are materialized
        first — the compaction scatter needs real blocks to land in."""
        bs = self.pool.block_size
        nb = self.pool.blocks_for(self._paged_depth)
        nb_keep = self.pool.blocks_for(self.capacity)
        for slot in np.nonzero(active)[0].tolist():
            if not active[slot]:
                continue  # preempted by an earlier slot's gap fill
            if int(self._cursor_h[slot]) < self._paged_depth:
                continue
            aborted = False
            for jb in range(nb):
                if self._table_h[slot, jb] != 0:
                    continue
                if self._slot_reserved[slot] > 0:
                    ids = self.pool.alloc(1, from_reserved=True)
                    assert ids is not None  # reserves stay on the free list
                    self._slot_reserved[slot] -= 1
                else:
                    ids = self._alloc_blocks(1)
                while ids is None:
                    victim = self._latest_admitted_active(active)
                    assert victim is not None, "pool exhausted with no slots"
                    self._preempt(victim, sched, active, remaining,
                                  last_emit)
                    if not active[slot]:
                        break  # this slot was its own latest admission
                    ids = self._alloc_blocks(1)
                if not active[slot]:
                    aborted = True
                    break
                # a reallocated block may carry stale validity rows; the
                # sweep *gathers* through the table before its scatter
                # overwrites them, so invalidate up front
                self.pool.zero_mask(ids)
                self._table_h[slot, jb] = int(ids[0])
                self._slot_blocks[slot].append(int(ids[0]))
            if aborted:
                continue
            tr = self.trace
            if tr is not None:
                tr.begin("paged_sweep", request_track(sched.running[slot].uid))
            self._table_dev = _snapshot(self._table_h)
            ptree, self._score_dev = paged_sweep(
                self.pool.tree(), self._score_dev, self._table_dev,
                jnp.asarray(slot, jnp.int32), capacity=self.capacity,
                depth=self._paged_depth, block_size=bs, nb_keep=nb_keep)
            self.pool.set_tree(ptree)
            # the compacted tail is dead weight now: free it (the whole
            # point — blocks return to the pool mid-generation) and
            # re-promise the same count for the next growth window
            freed = [int(self._table_h[slot, jb]) for jb in
                     range(nb_keep, nb)]
            self.pool.free_run(freed)
            fs = set(freed)
            self._slot_blocks[slot] = [
                b for b in self._slot_blocks[slot] if b not in fs]
            self._table_h[slot, nb_keep:nb] = 0
            if self.reserve_appends:
                ok = self.pool.reserve(len(freed))
                assert ok  # the freed blocks are on the free list
                self._slot_reserved[slot] += len(freed)
            self._cursor_h[slot] = self.capacity
            self._table_dev = _snapshot(self._table_h)
            if self._sync_timers:
                jax.block_until_ready(self._score_dev)
            if tr is not None:
                tr.end("paged_sweep", request_track(sched.running[slot].uid),
                       blocks_freed=len(freed))
            self._m_sweeps.inc()

    def _on_retire(self, slot: int, req: Request) -> None:
        if not (self.capture_admission and self.pool is not None):
            return
        fn = self._decode_fns.get("retire_gather")
        if fn is None:
            nb, bs = self._nb_max, self.pool.block_size
            depth = self._paged_depth

            def body(pos, mask, row, horizon):
                def dense(leaf):  # (L, NB, bs, KV) -> (L, depth, KV)
                    g = leaf[:, row[:nb]]
                    L = g.shape[0]
                    return g.reshape(L, nb * bs, -1)[:, :depth]

                p = dense(pos)
                # clip at the emitted-token horizon: decode chunks may
                # overshoot a finishing request (surplus tokens are
                # truncated at collect time) and whether those surplus
                # rows fit the cache depends only on the margin, so they
                # are not part of the request's kept set
                return p, dense(mask) & (p < horizon)

            fn = jax.jit(body)
            self._decode_fns["retire_gather"] = fn
        t = self.pool.tree()
        horizon = len(req.prompt) + max(len(req.out_tokens) - 1, 0)
        pos, mask = fn(t["pos"], t["mask"], _snapshot(self._table_h[slot]),
                       jnp.asarray(horizon, jnp.int32))
        req.retirement_cache = {"pos": np.asarray(pos),
                                "mask": np.asarray(mask)}

    def _first_token(self, logits, seed: int, pos: int) -> int:
        """The admission token, sampled with the same fused-epilogue logic
        (and the same (seed, position) key) the decode chunks use — or
        host argmax when greedy."""
        s = self.sampling
        if s is None or s.temperature <= 0.0:
            return int(jnp.argmax(logits[0]))
        fn = self._decode_fns.get("first")
        if fn is None:
            def body(logits, seed, pos):
                keys = policies.fold_keys(seed[None], pos[None])
                return policies.sample_logits(
                    logits, keys, temperature=s.temperature,
                    top_k=s.top_k, top_p=s.top_p)[0]

            fn = jax.jit(body)
            self._decode_fns["first"] = fn
        return int(fn(logits, jnp.asarray(seed, jnp.int32),
                      jnp.asarray(pos, jnp.int32)))

    def _free_slot_blocks(self, slot: int) -> None:
        ids = self._slot_blocks[slot]
        if ids:
            self.pool.free(ids)
            self._slot_blocks[slot] = []
        if self._slot_reserved[slot]:
            self.pool.unreserve(int(self._slot_reserved[slot]))
            self._slot_reserved[slot] = 0
        self._table_h[slot] = 0

    def _release_slot(self, slot: int) -> None:
        if self.pool is not None:
            # the device table row is stale until the next admission
            # overwrites it — harmless: the slot is inactive, its reads
            # are discarded and its writes are null-routed
            self._free_slot_blocks(slot)

    def _latest_admitted_active(self, active) -> Optional[int]:
        live = np.nonzero(active)[0]
        if len(live) == 0:
            return None
        return int(live[np.argmax(self._admit_seq[live])])

    def _preempt(self, slot: int, sched, active, remaining,
                 last_emit) -> None:
        """Preempt-to-queue: abandon a running slot's decode state, free
        its blocks, and push its request back to the FCFS head for a
        from-scratch re-serve (deterministic greedy decode ⇒ identical
        tokens).  The original ``ttft_s`` / ``first_token_s`` stamps are
        kept — the client already received those tokens and the replay is
        bit-identical — so the stall lands in ``max_gap_s``/``tpot_s``
        (see ``_admit``)."""
        r = sched.running[slot]
        tr = self.trace
        if tr is not None:
            tid = request_track(r.uid)
            tr.instant("preempt", tid, emitted=len(r.out_tokens))
            tr.end("decode", tid)
            tr.end("request", tid, outcome="preempted")
        sched.requeue(r)
        r.out_tokens = []  # rebuilt bit-identically by the re-serve
        r.preempt_emit_s = last_emit[slot]  # the stall starts here
        r.cached_prefix_tokens = 0
        r.admission_cache = None
        self._free_slot_blocks(slot)
        active[slot] = False
        remaining[slot] = 0
        self._m_preemptions.inc()

    def _ensure_append_blocks(self, sched, active, remaining, last_emit,
                              steps: int) -> None:
        """Allocate the append blocks every live slot needs for the next
        ``steps`` decode tokens.  When the pool runs dry, the latest
        admission is preempted to the queue (LIFO victims preserve FCFS
        finish order) until the remaining slots fit — the engine-sizing
        assert guarantees a lone request always fits."""
        bs = self.pool.block_size
        changed = False
        for slot in np.nonzero(active)[0].tolist():
            if not active[slot]:
                continue  # preempted by an earlier slot's reclaim
            cur = int(self._cursor_h[slot])
            last = min(cur + steps - 1, self._paged_depth - 1)
            for jb in range(cur // bs, last // bs + 1):
                if self._table_h[slot, jb] != 0:
                    continue
                if self._slot_reserved[slot] > 0:
                    # redeem this slot's admission-time promise — cannot
                    # fail (the pool keeps reserved blocks on the free
                    # list), which is the no-preempt guarantee
                    ids = self.pool.alloc(1, from_reserved=True)
                    assert ids is not None
                    self._slot_reserved[slot] -= 1
                else:
                    ids = self._alloc_blocks(1)
                while ids is None:
                    victim = self._latest_admitted_active(active)
                    assert victim is not None, "pool exhausted with no slots"
                    self._preempt(victim, sched, active, remaining,
                                  last_emit)
                    changed = True
                    if not active[slot]:
                        break  # this slot was its own latest admission
                    ids = self._alloc_blocks(1)
                if not active[slot]:
                    break
                # a reallocated block may carry its previous owner's stale
                # validity rows — invalidate before the table exposes it
                self.pool.zero_mask(ids)
                self._table_h[slot, jb] = int(ids[0])
                self._slot_blocks[slot].append(int(ids[0]))
                changed = True
        if changed:
            self._table_dev = _snapshot(self._table_h)

    def _reclaim_for_head(self, sched) -> None:
        """Nothing is running yet the queue head stays gated: every
        missing block is pinned by the prefix cache.  Reclaim until the
        gate passes, or fail with a sizing error instead of spinning."""
        while True:
            if not sched._queue:
                return
            if self._admission_gate(sched._queue[0]):
                return
            pc = self.prefix_cache
            if pc is None or pc.pool is None or not pc.evict_pool_blocks(1):
                raise RuntimeError(
                    "kv pool too small for the queue head even with the "
                    "prefix cache emptied; raise --kv-pool-mb")


class BucketedEngine(_SlotDecodeMixin):
    """Deprecated continuous-batching engine with pad-to-bucket prefill.

    A slot-batched decode loop (``_SlotDecodeMixin``) fed by per-bucket
    *monolithic* prefill: one compile per ``(bucket, batch, policy,
    padded)`` key, prompts beyond the largest bucket escalate to
    power-of-two buckets, and every live decode slot stalls for the whole
    prefill of an admitted prompt.  Kept (with its exactness guarantees)
    as the benchmark baseline the chunked engine is measured against.

    Exactness: tokens match isolated lockstep serving bit-for-bit for
    ``lookaheadkv`` and the position policies even when prompts are padded
    to their bucket (padded rows are masked everywhere — see
    ``transformer.prefill``'s ``prompt_lens``).  The snapkv-family
    baselines are exact when a prompt fills its bucket and approximate
    otherwise (their sliding observation windows overlap the padding).
    Multi-pass policies (laq/speckv) are grouped by exact prompt length
    instead of bucketed.
    """

    def __init__(
        self,
        params: dict,
        cfg: ModelConfig,
        *,
        policy: str = "lookaheadkv",
        evict: Optional[EvictionConfig] = None,
        lkv_params: Optional[dict] = None,
        draft_params: Optional[dict] = None,
        draft_cfg: Optional[ModelConfig] = None,
        num_slots: int = 4,
        buckets: tuple = DEFAULT_BUCKETS,
        max_prefill_batch: Optional[int] = None,
        max_new_tokens: int = 64,  # per-request cap (sizes the cache margin)
        eos_id: int = 0,
        decode_evict: bool = False,
        decode_chunk: int = 8,
    ):
        warnings.warn(
            "BucketedEngine (pad-to-bucket prefill) is deprecated; serve "
            "through the chunked ContinuousEngine", DeprecationWarning,
            stacklevel=2)
        assert cfg.uses_attention and not cfg.uses_ssm \
            and not cfg.is_encoder_decoder, \
            "continuous batching serves attention-only archs"
        assert policy != "gt_oracle", "gt_oracle needs the future; not servable"
        self.params, self.cfg = params, cfg
        self.policy = policy
        self.evict = evict if evict is not None else EvictionConfig()
        self.lkv_params = lkv_params
        self.draft_params, self.draft_cfg = draft_params, draft_cfg
        self.num_slots = num_slots
        self.buckets = tuple(sorted(buckets))
        self.max_prefill_batch = max_prefill_batch or num_slots
        self.max_new_tokens = max_new_tokens
        self.eos_id = eos_id
        self.decode_evict = DecodeEvictionConfig.coerce(decode_evict)
        self.decode_margin = self.decode_evict.margin_rows(max_new_tokens)
        self._chunks = tuple(c for c in self._CHUNK_SIZES if c <= decode_chunk)
        # multi-pass policies draft with the compressed cache; their prefill
        # can't mask padding, so their groups use exact prompt lengths
        self._exact_only = policy in policies.MULTI_PASS
        self.capacity = tf.decode_cache_capacity(
            cfg, policy, self.evict, n_keys_max=max(self.buckets))
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            self.prefill_cache = PrefillCompileCache(self._build_prefill)
        self._decode_fns: dict = {}
        self._insert_fn = jax.jit(tf.insert_request_cache)
        self.sampling = None  # the deprecated baseline decodes greedily
        self._seeds_h = np.zeros(num_slots, np.int32)
        self.stats: dict = {}

    # -- compile-cache bodies ------------------------------------------------
    def _build_prefill(self, policy: str, padded: bool):
        def fn(params, lkv, tokens, lens, seeds):
            res = policies.run_eviction(
                policy, params, self.cfg, tokens, evict=self.evict,
                lkv_params=lkv, draft_params=self.draft_params,
                draft_cfg=self.draft_cfg, extra_slots=self.decode_margin,
                prompt_lens=lens if padded else None, seeds=seeds,
            )
            if self.decode_evict.enabled:
                res = res._replace(
                    cache=tf.add_decode_eviction_scores(res.cache))
            return res

        return fn

    # -- geometry ------------------------------------------------------------
    def _bucket(self, n: int) -> int:
        if self._exact_only:
            return n
        b = _bucket_for(n, self.buckets)
        if self.policy == "full" and b > max(self.buckets):
            raise ValueError(
                f"policy 'full' caches whole prompts; len {n} exceeds the "
                f"largest bucket {max(self.buckets)}")
        return b

    def cache_bytes(self, n_in: int) -> dict:
        return cache_bytes(self.cfg, self.capacity + self.decode_margin, n_in)

    def kv_device_bytes(self) -> int:
        """K+V bytes of the dense live slot cache (see the paged engine's
        pool-aware counterpart)."""
        a = self.cfg.attn
        per_row = 2 * self.cfg.num_layers * a.kv_dim \
            * jnp.dtype(self.cfg.dtype).itemsize
        return self.num_slots * (self.capacity + self.decode_margin) * per_row

    def warmup(self, prompt_lens, batch_sizes=(1,)) -> None:
        """Pre-build compile-cache entries for expected traffic shapes."""
        keys = []
        for n in prompt_lens:
            b = self._bucket(n)
            for nb in batch_sizes:
                nb = _batch_bucket(nb, self.max_prefill_batch)
                keys.append((b, nb, self.policy, n != b))
        self.prefill_cache.warm(keys)

    # -- serving loop --------------------------------------------------------
    def run(self, requests: list[Request]) -> list[Request]:
        """Serve ``requests`` to completion; returns them in finish order.

        ``arrival_s`` offsets are interpreted on the wall clock relative to
        the start of the call: a request is schedulable once the engine
        clock passes its arrival.  All timing fields (``ttft_s``,
        ``tpot_s``, ``finish_s``) are per-request, measured on that clock.
        """
        sched = SlotScheduler(self.num_slots, bucket_for=self._bucket,
                              max_prefill_batch=self.max_prefill_batch)
        for r in requests:
            assert r.max_new_tokens <= self.max_new_tokens, \
                "request exceeds the engine's max_new_tokens cache margin"
            sched.submit(r)
        t0 = time.perf_counter()
        live = tf.init_decode_cache(self.cfg, self.num_slots,
                                    self.capacity + self.decode_margin,
                                    per_slot_cursor=True)
        if self.decode_evict.enabled:
            live = tf.add_decode_eviction_scores(live)
        tok = jnp.zeros((self.num_slots, 1), jnp.int32)
        active = np.zeros(self.num_slots, bool)
        remaining = np.zeros(self.num_slots, np.int64)
        last_emit = np.zeros(self.num_slots, np.float64)
        # deprecated engine, legacy dict stats.  ``decode_time_s`` is a
        # host timer stamped after the np.asarray sync on the sampled
        # tokens only — under JAX async dispatch it bounds execution
        # loosely (dispatch + token materialization), unlike the chunked
        # engine's sync_timers-gated metrics (repro.obs)
        self.stats = {"decode_chunks": 0, "decode_steps": 0,
                      "decode_time_s": 0.0, "decode_path": "dense"}

        while sched.has_work():
            # admission: fill freed slots from the queue, one bucket group
            # per prefill program.  ``now`` refreshes inside the loop so
            # requests that arrived during a (multi-second, possibly
            # compile-including) prefill are admissible immediately.
            while True:
                now = time.perf_counter() - t0
                group = sched.next_prefill_group(now)
                if not group:
                    break
                tok, live = self._admit(group, sched, tok, live, active,
                                        remaining, last_emit, t0)
            if active.any():
                steps = self._pick_chunk(remaining, active)
                fn = self._decode_fn(steps)
                t_dec = time.perf_counter()
                tok, live, toks = fn(self.params, tok, live,
                                     jnp.asarray(active),
                                     jnp.asarray(self._seeds_h))
                toks_np = np.asarray(toks)  # device sync: tokens landed
                self.stats["decode_chunks"] += 1
                self.stats["decode_steps"] += steps
                self.stats["decode_time_s"] += time.perf_counter() - t_dec
                self._collect(toks_np, steps, sched, active,
                              remaining, last_emit, t0)
            else:
                nxt = sched.next_arrival()
                if nxt is None:
                    break  # defensive: nothing queued, nothing running
                wait = nxt - (time.perf_counter() - t0)
                if wait > 0:
                    time.sleep(min(wait, 0.05))
        return sched.finished

    # -- internals -----------------------------------------------------------
    def _admit(self, group, sched, tok, live, active, remaining, last_emit,
               t0):
        lens = [len(r.prompt) for r in group]
        bucket = self._bucket(max(lens))
        padded = any(n != bucket for n in lens)
        nb = _batch_bucket(len(group), self.max_prefill_batch)
        tokens, lens_arr = _pad_to_bucket([r.prompt for r in group], bucket,
                                          nb)
        seeds = np.zeros((nb,), np.int32)
        seeds[:len(group)] = [r.eviction_seed for r in group]
        fn = self.prefill_cache.get(bucket, nb, self.policy, padded)
        res = fn(self.params, self.lkv_params, jnp.asarray(tokens),
                 jnp.asarray(lens_arr), jnp.asarray(seeds))
        res.logits.block_until_ready()
        now = time.perf_counter() - t0
        first = np.asarray(jnp.argmax(res.logits, -1).astype(jnp.int32))
        for i, r in enumerate(group):
            slot = sched.place(r)
            req_cache = tf.extract_request_cache(res.cache, i)
            live = self._insert_fn(live, req_cache, slot)
            tok = tok.at[slot, 0].set(int(first[i]))
            r.out_tokens = [int(first[i])]
            r.first_token_s = now
            r.ttft_s = now - r.enqueue_s
            last_emit[slot] = now
            if r.out_tokens[-1] == self.eos_id or r.max_new_tokens <= 1:
                sched.retire(r, now=now)
                active[slot] = False
            else:
                active[slot] = True
                remaining[slot] = r.max_new_tokens - 1
        return tok, live
