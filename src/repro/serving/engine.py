"""Batched serving engine: prefill → evict → decode with a budgeted cache.

A deliberately compact production shape: fixed-size request slots (static
shapes => one compiled program per (batch, n_in) bucket), per-policy jit'd
prefill and a jit'd decode loop.  The cache the decoder sees is *only* the
evicted budget cache — this is where the paper's memory win materializes:
cache bytes drop from O(n_in) to O(budget + max_new_tokens) per layer/head.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import EvictionConfig, ModelConfig
from repro.core import policies
from repro.models import transformer as tf


@dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (n_in,) int32
    max_new_tokens: int
    out_tokens: list = field(default_factory=list)
    ttft_s: float = 0.0
    done: bool = False


class ServingEngine:
    def __init__(
        self,
        params: dict,
        cfg: ModelConfig,
        *,
        policy: str = "lookaheadkv",
        evict: EvictionConfig = EvictionConfig(),
        lkv_params: Optional[dict] = None,
        draft_params: Optional[dict] = None,
        draft_cfg: Optional[ModelConfig] = None,
        max_new_tokens: int = 64,
        eos_id: int = 0,
        decode_evict: bool = False,
    ):
        self.params, self.cfg = params, cfg
        self.policy, self.evict = policy, evict
        self.lkv_params = lkv_params
        self.draft_params, self.draft_cfg = draft_params, draft_cfg
        self.max_new_tokens = max_new_tokens
        self.eos_id = eos_id
        # decoding-stage eviction (beyond-paper): the cache stays at
        # ``budget + margin`` even for long generations — new tokens evict
        # the lowest cumulative-attention slots once capacity is reached.
        self.decode_evict = decode_evict
        self.decode_margin = (8 if decode_evict else max_new_tokens + 1)
        self._prefill_fn = jax.jit(self._prefill)
        self._decode_fn = jax.jit(self._decode)

    # -- jit bodies ---------------------------------------------------------
    def _prefill(self, params, lkv, tokens):
        res = policies.run_eviction(
            self.policy, params, self.cfg, tokens, evict=self.evict,
            lkv_params=lkv, draft_params=self.draft_params,
            draft_cfg=self.draft_cfg, extra_slots=self.decode_margin,
        )
        if self.decode_evict:
            from repro.models import transformer as tf

            res = res._replace(cache=tf.add_decode_eviction_scores(res.cache))
        return res

    def _decode(self, params, first_token, cache):
        return policies.greedy_decode(
            params, self.cfg, first_token, cache, self.max_new_tokens
        )

    # -- public API ----------------------------------------------------------
    def serve(self, requests: list[Request]) -> list[Request]:
        """Serve a batch of same-length requests."""
        assert requests, "empty batch"
        n_in = len(requests[0].prompt)
        assert all(len(r.prompt) == n_in for r in requests), \
            "bucket requests by prompt length"
        tokens = jnp.asarray(np.stack([r.prompt for r in requests]))
        t0 = time.perf_counter()
        res = self._prefill_fn(self.params, self.lkv_params, tokens)
        res.logits.block_until_ready()
        ttft = time.perf_counter() - t0
        first = jnp.argmax(res.logits, -1)[:, None].astype(jnp.int32)
        toks, _ = self._decode_fn(self.params, first, res.cache)
        toks = np.asarray(toks)  # (B, max_new_tokens)
        for i, r in enumerate(requests):
            seq = toks[i].tolist()
            if self.eos_id in seq:
                seq = seq[: seq.index(self.eos_id) + 1]
            r.out_tokens = seq
            r.ttft_s = ttft
            r.done = True
        return requests

    def cache_bytes(self, n_in: int) -> dict:
        """Analytic cache footprint: full vs evicted (the paper's headline)."""
        cfg = self.cfg
        if cfg.attn is None:
            return {"full": 0, "evicted": 0, "ratio": 1.0}
        a = cfg.attn
        per_tok = cfg.num_layers * a.kv_dim * 2 * 2  # K+V, bf16
        cap = self.evict.budget + self.decode_margin
        return {
            "full": n_in * per_tok,
            "evicted": cap * per_tok,
            "ratio": n_in / max(cap, 1),
        }
