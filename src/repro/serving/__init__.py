"""Serving subsystem: lockstep and continuous-batching engines.

    scheduler.py — request state machine, FCFS queue, fixed decode slots
    batching.py  — prompt-length buckets + the jit compile cache
    engine.py    — ServingEngine (lockstep) and ContinuousEngine
"""

from repro.serving.batching import (DEFAULT_BUCKETS, PrefillCompileCache,
                                    batch_bucket, bucket_for, pad_to_bucket)
from repro.serving.engine import (ContinuousEngine, Request, RequestState,
                                  ServingEngine, cache_bytes)
from repro.serving.scheduler import SlotScheduler

__all__ = [
    "ContinuousEngine", "DEFAULT_BUCKETS", "PrefillCompileCache", "Request",
    "RequestState", "ServingEngine", "SlotScheduler", "batch_bucket",
    "bucket_for", "cache_bytes", "pad_to_bucket",
]
