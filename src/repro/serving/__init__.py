"""Serving subsystem: chunked continuous batching (+ deprecated baselines).

    scheduler.py — request state machine, FCFS queue, fixed decode slots,
                   the token-budget step planner (``plan_step``), paged-KV
                   admission gate / preempt-to-queue
    batching.py  — ChunkCompileCache (keyed (chunk, batch, policy)) and the
                   deprecated bucket utilities
    kv_pool.py   — KVBlockPool: paged decode-KV memory (per-layer device
                   block pool, free-list allocator, refcounted blocks)
    config.py    — ServingConfig / DecodeEvictionConfig / ChunkingConfig:
                   the unified engine configuration (one object instead of
                   the historical kwarg pile; legacy kwargs still map
                   through ``ServingConfig.from_legacy``)
    prefix_cache.py — radix-trie prompt cache: refcounted chunk-boundary
                   (KV, ScoreState) snapshots shared across requests,
                   optionally pinned as block runs in the KV pool
    engine.py    — ContinuousEngine (chunked prefill interleaved with
                   decode, optional prefix-aware KV reuse and paged KV
                   memory); deprecated ServingEngine (lockstep) and
                   BucketedEngine (pad-to-bucket prefill)
"""

from repro.serving.batching import (DEFAULT_BUCKETS, ChunkCompileCache,
                                    PrefillCompileCache, batch_bucket,
                                    bucket_for, pad_to_bucket)
from repro.serving.config import (ChunkingConfig, DecodeEvictionConfig,
                                  ServingConfig)
from repro.serving.engine import (BucketedEngine, ContinuousEngine, Request,
                                  RequestState, ServingEngine, cache_bytes)
from repro.serving.kv_pool import KVBlockPool
from repro.serving.prefix_cache import PrefixCache, PrefixEntry
from repro.serving.scheduler import SlotScheduler, plan_step

__all__ = [
    "BucketedEngine", "ChunkCompileCache", "ChunkingConfig",
    "ContinuousEngine", "DEFAULT_BUCKETS", "DecodeEvictionConfig",
    "KVBlockPool", "PrefillCompileCache", "PrefixCache", "PrefixEntry",
    "Request", "RequestState", "ServingConfig", "ServingEngine",
    "SlotScheduler", "batch_bucket", "bucket_for", "cache_bytes",
    "pad_to_bucket", "plan_step",
]
