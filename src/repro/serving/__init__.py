"""Serving subsystem: chunked continuous batching (+ deprecated baselines).

    scheduler.py — request state machine, FCFS queue, fixed decode slots,
                   the token-budget step planner (``plan_step``)
    batching.py  — ChunkCompileCache (keyed (chunk, batch, policy)) and the
                   deprecated bucket utilities
    prefix_cache.py — radix-trie prompt cache: refcounted chunk-boundary
                   (KV, ScoreState) snapshots shared across requests
    engine.py    — ContinuousEngine (chunked prefill interleaved with
                   decode, optional prefix-aware KV reuse); deprecated
                   ServingEngine (lockstep) and BucketedEngine
                   (pad-to-bucket prefill)
"""

from repro.serving.batching import (DEFAULT_BUCKETS, ChunkCompileCache,
                                    PrefillCompileCache, batch_bucket,
                                    bucket_for, pad_to_bucket)
from repro.serving.engine import (BucketedEngine, ContinuousEngine, Request,
                                  RequestState, ServingEngine, cache_bytes)
from repro.serving.prefix_cache import PrefixCache, PrefixEntry
from repro.serving.scheduler import SlotScheduler, plan_step

__all__ = [
    "BucketedEngine", "ChunkCompileCache", "ContinuousEngine",
    "DEFAULT_BUCKETS", "PrefillCompileCache", "PrefixCache", "PrefixEntry",
    "Request", "RequestState", "ServingEngine", "SlotScheduler",
    "batch_bucket", "bucket_for", "cache_bytes", "pad_to_bucket",
    "plan_step",
]
