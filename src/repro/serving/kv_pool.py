"""Paged KV memory: a global device-resident block pool for decode caches.

The eviction paper's value proposition is a *smaller* KV cache — but a
dense slot cache pads every request to one uniform ``capacity + margin``
depth, so evicted positions free zero device bytes and concurrency is
fixed at engine construction.  ``KVBlockPool`` converts eviction quality
into actual capacity: the decode KV of every live request lives in
fixed-size **blocks** drawn from one shared pool, a request only holds
blocks for rows it actually uses (kept post-eviction rows plus the decode
tokens generated so far), and retiring / preempting a request returns its
blocks to the free list for the next admission.  Better eviction → fewer
kept rows → fewer blocks per request → more concurrent requests at a
fixed ``--kv-pool-mb`` byte budget.

Layout (vLLM-style, per layer)
------------------------------
One ``(num_blocks, block_size, kv_heads, head_dim)`` array per layer for
each of K and V (stacked along a leading ``L`` axis so the decode layer
scan strips it), plus matching ``(num_blocks, block_size, kv_heads)``
``pos``/``mask`` metadata — eviction keeps *different token positions per
kv head*, so validity is per-head exactly as in the dense cache.  A
request's **block table** is a ``(nb,)`` int32 row of physical block ids:
logical cache row ``c`` lives at ``(table[c // bs], c % bs)``.  The table
is shared across layers (block ``j`` holds the same logical rows of every
layer), so one table gather reconstructs the whole per-slot view.

Block 0 is the reserved **null block**: never allocated, its mask rows
are permanently False.  Unallocated table entries point at it, so a
ragged table (kept rows << capacity, appends not yet grown) reads as a
dense cache whose missing rows are simply masked invalid — the property
that makes paged decode bit-identical to the dense path.

Allocation is host-side (a free list + per-block refcounts — refcounts
let prefix-cache entries share one physical copy of a common prompt
prefix across requests); all device mutation goes through the jitted
write helpers below, keyed by block count so a serving lifetime compiles
O(distinct admission sizes) tiny scatter programs.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig

__all__ = ["KVBlockPool"]


class KVBlockPool:
    """Global paged KV store: device block arrays + a host free-list
    allocator with per-block refcounts.

    Exactly one of ``num_blocks`` / ``pool_mb`` sizes the pool; ``pool_mb``
    counts K+V payload bytes (the headline the paper budgets), with the
    int32/bool ``pos``/``mask`` metadata reported separately in
    ``stats()``.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        *,
        block_size: int = 16,
        num_blocks: Optional[int] = None,
        pool_mb: Optional[float] = None,
        mesh=None,
    ):
        assert cfg.attn is not None, "paged KV serves attention archs"
        assert block_size > 0
        a = cfg.attn
        L, KV, hd = cfg.num_layers, a.num_kv_heads, a.head_dim
        dtype = jnp.dtype(cfg.dtype)
        self.block_size = block_size
        # K+V payload bytes of one block across all layers
        self.block_bytes = 2 * L * block_size * KV * hd * dtype.itemsize
        if num_blocks is None:
            assert pool_mb is not None, "size the pool: num_blocks or pool_mb"
            num_blocks = int(pool_mb * (1 << 20)) // self.block_bytes
        num_blocks += 1  # block 0 is the reserved null block
        assert num_blocks >= 2, "pool too small for even one block"
        self.num_blocks = num_blocks
        N = num_blocks
        self.k = jnp.zeros((L, N, block_size, KV, hd), dtype)
        self.v = jnp.zeros((L, N, block_size, KV, hd), dtype)
        self.pos = jnp.zeros((L, N, block_size, KV), jnp.int32)
        self.mask = jnp.zeros((L, N, block_size, KV), bool)
        # tensor-parallel serving: the device arrays shard their kv-head
        # dim over "model" (each shard holds whole blocks of its local
        # head slice), while the host allocator below is head-oblivious —
        # every block id means the same rows on every shard, so the free
        # list and block tables need no mesh awareness at all.
        self.mesh = None
        self.model_shards = 1
        if mesh is not None:
            from repro.common.sharding import pool_specs

            specs = pool_specs(cfg, mesh)
            assert specs is not None, (
                f"kv heads ({KV}) must divide the model axis "
                f"({dict(getattr(mesh, 'shape', {}))}) to shard the pool")
            self.mesh = mesh
            self.model_shards = int(mesh.shape["model"])
            put = {
                n: jax.device_put(
                    getattr(self, n),
                    jax.sharding.NamedSharding(mesh, specs[n]))
                for n in ("k", "v", "pos", "mask")
            }
            self.set_tree(put)
        # host allocator state: ids 1..N-1 are allocatable
        self._free: list[int] = list(range(N - 1, 0, -1))
        self._refs = np.zeros(N, np.int32)
        # blocks promised to admitted requests' future decode appends but
        # not yet handed out — ordinary allocs may not dip into them, so
        # an admitted request can always grow to its cap without
        # preempting anyone (the preempt path stays as the safety valve
        # for optimistic admission, see ContinuousEngine.reserve_appends)
        self.reserved = 0
        self.high_water = 0  # peak blocks in use over the pool's lifetime
        self.pinned_blocks = 0  # blocks held by prefix-cache entries
        # blocks returned mid-generation by decode-eviction sweeps (the
        # engine's evict-and-compact step) — retirement frees not included
        self.blocks_reclaimed_decode = 0
        self._write_fns: dict = {}  # jitted scatter programs, keyed by shape

    # -- geometry ---------------------------------------------------------
    @property
    def usable_blocks(self) -> int:
        return self.num_blocks - 1  # minus the null block

    def blocks_for(self, rows: int) -> int:
        """Blocks needed to hold ``rows`` logical cache rows."""
        return -(-max(rows, 0) // self.block_size)

    def free_blocks(self) -> int:
        return len(self._free)

    def available_blocks(self) -> int:
        """Free blocks not promised to an admitted request's growth."""
        return len(self._free) - self.reserved

    def used_blocks(self) -> int:
        return self.usable_blocks - len(self._free)

    # -- allocator --------------------------------------------------------
    def alloc(self, n: int, *,
              from_reserved: bool = False) -> Optional[np.ndarray]:
        """Take ``n`` blocks (each with refcount 1), or None if the free
        list cannot cover them — the caller decides whether to preempt,
        evict a prefix entry, or queue-wait.  Never partially allocates.

        ``from_reserved`` redeems part of an earlier ``reserve``: it may
        consume the promised headroom ordinary allocations must not touch.
        """
        assert n >= 0
        limit = len(self._free) if from_reserved \
            else len(self._free) - self.reserved
        if n > limit:
            return None
        if from_reserved:
            assert self.reserved >= n, "redeeming more than was reserved"
            self.reserved -= n
        ids = np.asarray([self._free.pop() for _ in range(n)], np.int32)
        self._refs[ids] = 1
        self.high_water = max(self.high_water, self.used_blocks())
        return ids

    def reserve(self, n: int) -> bool:
        """Promise ``n`` free blocks to a request's future appends (its
        decode growth can then never run the pool dry).  False when the
        unreserved headroom cannot cover the promise."""
        assert n >= 0
        if n > len(self._free) - self.reserved:
            return False
        self.reserved += n
        return True

    def unreserve(self, n: int) -> None:
        """Return an unredeemed promise (retirement / preemption)."""
        assert 0 <= n <= self.reserved
        self.reserved -= n

    def incref(self, ids) -> None:
        """Share blocks (prefix-cache chains): one more owner per block."""
        ids = np.asarray(ids, np.int32)
        assert (self._refs[ids] > 0).all(), "incref of an unallocated block"
        self._refs[ids] += 1

    def free(self, ids) -> None:
        """Drop one reference per block; blocks return to the free list at
        refcount zero.  Double-frees fail loudly — a freed block may
        already belong to another request."""
        for b in np.asarray(ids, np.int32).tolist():
            assert b != 0, "freeing the null block"
            assert self._refs[b] > 0, f"double-free of block {b}"
            self._refs[b] -= 1
            if self._refs[b] == 0:
                self._free.append(int(b))

    def free_run(self, ids) -> None:
        """Return a *partial* block run of a **live** request — the tail
        blocks a decode-eviction sweep compacted away mid-generation.
        Semantically ``free`` (the request keeps its remaining blocks and
        its slot), tracked separately as ``blocks_reclaimed_decode`` so
        observability distinguishes eviction-driven reclaim from ordinary
        retirement frees."""
        ids = np.asarray(ids, np.int32)
        self.free(ids)
        self.blocks_reclaimed_decode += len(ids)

    def note_pinned(self, delta: int) -> None:
        """Prefix-cache accounting: blocks pinned by resident prompt-prefix
        entries (they are allocated, but no decode slot owns them)."""
        self.pinned_blocks += delta
        assert self.pinned_blocks >= 0

    # -- device views -----------------------------------------------------
    def tree(self) -> dict:
        """The pool's device arrays as the pytree the paged decode step
        consumes (and returns updated — see ``set_tree``)."""
        return {"k": self.k, "v": self.v, "pos": self.pos, "mask": self.mask}

    def set_tree(self, tree: dict) -> None:
        self.k, self.v = tree["k"], tree["v"]
        self.pos, self.mask = tree["pos"], tree["mask"]

    # -- jitted device mutation -------------------------------------------
    def write_cache(self, attn_cache: dict, ids: np.ndarray) -> None:
        """Scatter a freshly admitted request's dense decode cache (the
        ``prefill_finalize`` output: k/v (L, 1, C, KV, hd), pos/mask
        (L, 1, C, KV)) into blocks ``ids`` — rows [0, len(ids)·bs), i.e.
        every row up to the last valid kept row, rounded up to whole
        blocks.  Rows past C pad with mask=False (a partial tail block)."""
        n = len(ids)
        assert n > 0
        fn = self._write_fns.get(("cache", n))
        if fn is None:
            bs = self.block_size

            def write(pool, cache, ids):
                rows = len(ids) * bs

                def blk(x):  # (L, 1, C, ...) -> (L, n, bs, ...)
                    x = x[:, 0]
                    pad = [(0, 0)] * x.ndim
                    pad[1] = (0, max(rows - x.shape[1], 0))
                    x = jnp.pad(x, pad)[:, :rows]
                    return x.reshape((x.shape[0], len(ids), bs)
                                     + x.shape[2:])

                return {
                    "k": pool["k"].at[:, ids].set(blk(cache["k"])),
                    "v": pool["v"].at[:, ids].set(blk(cache["v"])),
                    "pos": pool["pos"].at[:, ids].set(blk(cache["pos"])),
                    "mask": pool["mask"].at[:, ids].set(blk(cache["mask"])),
                }

            fn = jax.jit(write)
            self._write_fns[("cache", n)] = fn
        self.set_tree(fn(self.tree(), attn_cache, jnp.asarray(ids)))

    def write_span(self, k: jnp.ndarray, v: jnp.ndarray,
                   ids: np.ndarray) -> None:
        """Store a prefix-cache span — streaming-prefill KV columns
        (L, 1, span, KV, hd) with span = len(ids)·bs — into blocks
        ``ids``.  Only K/V payload: prefix blocks never enter a slot's
        block table, so their pos/mask metadata is never read."""
        n = len(ids)
        assert n > 0 and k.shape[2] == n * self.block_size
        fn = self._write_fns.get(("span", n))
        if fn is None:
            bs = self.block_size

            def write(pk, pv, k, v, ids):
                def blk(x):  # (L, 1, n*bs, KV, hd) -> (L, n, bs, KV, hd)
                    x = x[:, 0]
                    return x.reshape((x.shape[0], len(ids), bs) + x.shape[2:])

                return pk.at[:, ids].set(blk(k)), pv.at[:, ids].set(blk(v))

            fn = jax.jit(write)
            self._write_fns[("span", n)] = fn
        self.k, self.v = fn(self.k, self.v, k, v, jnp.asarray(ids))

    def zero_mask(self, ids) -> None:
        """Invalidate every row of blocks ``ids`` — required when a freed
        block is reallocated as a decode *append* block: its previous
        owner's stale mask rows would otherwise read as valid cache
        entries.  (Admission data blocks need no zeroing: ``write_cache``
        overwrites the full mask.)  Padding with the null block id is
        harmless — its mask is already all-False."""
        ids = np.asarray(ids, np.int32)
        W = 4  # fixed scatter width: one compiled program, not one per count
        fn = self._write_fns.get(("zero", W))
        if fn is None:
            def zero(mask, ids):
                upd = jnp.zeros((mask.shape[0], len(ids)) + mask.shape[2:],
                                bool)
                return mask.at[:, ids].set(upd)

            fn = jax.jit(zero)
            self._write_fns[("zero", W)] = fn
        for s in range(0, len(ids), W):
            grp = np.zeros(W, np.int32)
            seg = ids[s:s + W]
            grp[:len(seg)] = seg
            self.mask = fn(self.mask, jnp.asarray(grp))

    # -- observability ----------------------------------------------------
    def check(self) -> None:
        """Allocator invariants (cheap; the kv-pool test suite calls this
        after every adversarial step): the pool is conserved, the free
        list holds no duplicates or live blocks, and the null block is
        never handed out."""
        assert len(set(self._free)) == len(self._free), "free-list duplicate"
        assert 0 not in self._free, "null block on the free list"
        assert (self._refs[self._free] == 0).all(), "live block marked free"
        live = int((self._refs[1:] > 0).sum())
        assert live + len(self._free) == self.usable_blocks, "pool leak"
        assert 0 <= self.reserved <= len(self._free), "reservation overhang"
        assert self._refs[0] == 0

    def stats(self) -> dict:
        used = self.used_blocks()
        shards = self.model_shards
        return {
            # mesh shape + per-shard utilization: block counts are global
            # (the allocator is shard-oblivious), bytes divide evenly over
            # the kv-head shards
            "mesh_model": shards,
            "bytes_total_per_shard":
                self.usable_blocks * self.block_bytes // shards,
            "bytes_used_per_shard": used * self.block_bytes // shards,
            "block_size": self.block_size,
            "block_bytes": self.block_bytes,
            "blocks_total": self.usable_blocks,
            "blocks_used": used,
            "blocks_free": len(self._free),
            "blocks_reserved": self.reserved,
            "blocks_pinned_prefix": self.pinned_blocks,
            "blocks_reclaimed_decode": self.blocks_reclaimed_decode,
            "high_water_blocks": self.high_water,
            "bytes_total": self.usable_blocks * self.block_bytes,
            "bytes_used": used * self.block_bytes,
            "bytes_pinned_prefix": self.pinned_blocks * self.block_bytes,
            "bytes_high_water": self.high_water * self.block_bytes,
            # int32 pos + bool mask metadata, outside the K+V budget
            "metadata_bytes": int(self.pos.nbytes + self.mask.nbytes),
        }

    def bind_metrics(self, registry) -> None:
        """Mirror ``stats()`` as ``kv_pool_*`` callback gauges on the
        engine's registry (collection-time reads, no hot-path writes)."""
        from repro.obs.metrics import bind_stat_gauges
        bind_stat_gauges(registry, "kv_pool", self.stats)
