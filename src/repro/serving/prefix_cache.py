"""Prefix-aware KV reuse for chunked prefill: a token-level radix trie over
chunk-aligned prompt prefixes.

Real serving traffic is dominated by shared prompt prefixes (system
prompts, RAG context, few-shot headers).  The chunked-prefill architecture
makes prefix reuse unusually cheap to make *exact*: scoring is causal and
streamed per chunk, so at any chunk boundary the pair

    (materialized KV buffer columns [0, n),  scoring.ScoreState)

is a pure function of the first ``n`` prompt tokens — bit-identical across
every request that shares them (per-request randomness such as
``Request.seed`` never touches streamed state; the random policy folds
seeds in at finalize).  A cache hit therefore skips not just the prefix's
attention FLOPs but its eviction-score accumulation too, and the resumed
request still finishes with exactly the tokens and kept sets it would have
produced uncached (``tests/test_prefix_cache.py`` proves this
differentially over randomized traces).

Structure
---------
``PrefixCache`` is a radix trie (compressed token edges) whose nodes may
carry an entry at *chunk-aligned* depths only — partial-chunk prefixes are
never cached and never match.  Each entry owns

* the KV **block** spanning ``(parent_entry.depth, depth]`` — blocks are
  deduplicated along the chain, so a 3-chunk entry and a 2-chunk entry
  sharing two chunks store those two chunks once;
* a full (trimmed) ``ScoreState`` snapshot at its boundary;
* the last chunk's next-token logits, so a prompt that *is* a cached
  prefix admits with zero prefill chunks (TTFT ~ one finalize).

Entries are refcounted: ``refs`` counts child entries (a parent's blocks
are part of every descendant's chain) plus in-flight pins
(``acquire``/``release`` around a request's streaming prefill).  Eviction
is LRU over unpinned, childless entries under a byte budget — the budget
is respected after every insert, and an insert that cannot fit by evicting
unpinned entries is simply skipped (the request still serves; it just
doesn't populate the cache).

With a ``KVBlockPool`` bound (paged serving), an entry's KV span lives as
a **pinned run of pool blocks** instead of a private device copy: shared
prefixes occupy the same physical pool the decode caches draw from (one
copy, refcount-shared along the chain via parent entries), the
``max_bytes`` budget caps how much of the pool the cache may pin, and the
engine reclaims unpinned entries on demand when live requests need the
blocks — cached prefixes never outrank running traffic.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tf

__all__ = ["PrefixCache", "PrefixEntry"]


class _Node:
    """Radix-trie node: a compressed token edge, children keyed by their
    edge's first token, and (at chunk-aligned depths) a cache entry."""

    __slots__ = ("edge", "children", "entry", "depth", "parent")

    def __init__(self, edge: np.ndarray, depth: int,
                 parent: Optional["_Node"]):
        self.edge = edge  # (len,) int token segment labelling the in-edge
        self.children: dict[int, _Node] = {}
        self.entry: Optional[PrefixEntry] = None
        self.depth = depth  # tokens from root through this edge
        self.parent = parent


class PrefixEntry:
    """One cached chunk-boundary snapshot (see module docstring)."""

    __slots__ = ("depth", "start", "parent", "k_block", "v_block", "blocks",
                 "score", "logits", "nbytes", "refs", "node", "src_capacity")

    def __init__(self, *, depth, start, parent, score, logits, node,
                 src_capacity, k_block=None, v_block=None, blocks=None,
                 block_bytes=0):
        self.depth = depth  # prefix length (chunk-aligned)
        self.start = start  # parent entry's depth; blocks cover [start, depth)
        self.parent: Optional[PrefixEntry] = parent
        # KV-buffer depth the donor streamed under.  Bit-exactness of a
        # resumed prefill is guaranteed only when the requester computes
        # under the *same* buffer shape (identical compiled programs,
        # identical reduction order) — lookup filters on it, and chains are
        # capacity-homogeneous by construction (insert only links parents
        # of the same src_capacity), so a hit never mixes rungs.
        self.src_capacity = src_capacity
        self.k_block = k_block  # (L, 1, depth-start, KV, hd), or None when
        self.v_block = v_block  # the span lives in the shared block pool:
        self.blocks = blocks  # (n,) int32 pinned pool block ids
        self.score = score  # trimmed scoring.ScoreState at ``depth``
        self.logits = logits  # (1, V) last-chunk logits (row depth-1)
        self.node = node
        self.refs = 0  # child entries + in-flight pins; evictable at 0
        if k_block is not None:
            span_bytes = k_block.nbytes + v_block.nbytes
        else:  # pool-backed: caller sizes the span in whole blocks
            span_bytes = (0 if blocks is None else len(blocks)) * block_bytes
        self.nbytes = (
            span_bytes + logits.nbytes
            + sum(leaf.nbytes for leaf in jax.tree.leaves(score))
        )


def _common_len(a: np.ndarray, b: np.ndarray) -> int:
    n = min(len(a), len(b))
    neq = np.nonzero(a[:n] != b[:n])[0]
    return int(neq[0]) if len(neq) else n


class PrefixCache:
    """Radix-trie prompt cache with refcount pinning, LRU order, and a hard
    byte budget.  One cache serves one ``(chunk, policy, model)`` binding —
    the engine asserts/binds on construction (``ScoreState`` is
    policy-shaped; chunk alignment defines which depths are cacheable)."""

    def __init__(self, *, chunk: int, max_bytes: int,
                 policy: Optional[str] = None, pool=None):
        assert chunk > 0 and max_bytes > 0
        self.chunk = chunk
        self.max_bytes = max_bytes
        self.policy = policy  # bound by the first engine that adopts it
        # paged mode: entry KV spans are pinned runs of this KVBlockPool's
        # blocks (one physical copy shared with decode) instead of private
        # device arrays.  Chunk boundaries must land on block boundaries.
        self.pool = pool
        if pool is not None:
            assert chunk % pool.block_size == 0, \
                "chunk must be a multiple of the pool block size"
        # the bound params tree, held strongly: identity (``is``) stays
        # valid for the cache's lifetime (a bare id() could be reused
        # after GC and let a different model's weights silently pass)
        self._model = None
        self._root = _Node(np.zeros(0, np.int32), 0, None)
        self._lru: OrderedDict[PrefixEntry, None] = OrderedDict()
        # jitted chain-concat programs keyed (block spans, capacity): hot
        # prefixes rematerialize through one fused program instead of a
        # string of eagerly dispatched concat/pad ops (full-hit TTFT).
        # LRU-bounded so long-lived servers with varied chain shapes don't
        # retain compiled programs forever.  A dropped shape recompiles on
        # its next materialize — which can be a hit — so the cap sits well
        # above realistic chain-shape counts (chains are (chunk,)*n for
        # n <= max_context/chunk, times a handful of capacity rungs).
        self._mat_fns: OrderedDict = OrderedDict()
        self.max_materialize_programs = 128
        self.bytes = 0
        self.hits = 0
        self.misses = 0
        self.inserts = 0
        self.evictions = 0
        self.tokens_hit = 0  # prefix tokens served from cache

    # -- binding ---------------------------------------------------------
    def bind(self, *, chunk: int, policy: str, model=None) -> None:
        """Adopt (or verify) the serving binding; a cache never serves two
        policies, chunk sizes, or models — its snapshots would not be
        resumable.  ``model`` is the params tree itself (engines sharing
        one cache must share the same parameter object)."""
        assert chunk == self.chunk, \
            f"cache built for chunk {self.chunk}, engine uses {chunk}"
        assert self.policy in (None, policy), \
            f"cache bound to policy {self.policy!r}, engine uses {policy!r}"
        assert model is None or self._model is None or self._model is model, \
            "cache bound to a different model's parameters — snapshots " \
            "computed under one set of weights cannot serve another"
        self.policy = policy
        if model is not None:
            self._model = model

    # -- lookup / pinning ------------------------------------------------
    def lookup(self, prompt: np.ndarray,
               capacity: Optional[int] = None) -> Optional[PrefixEntry]:
        """Deepest cached chunk-aligned prefix of ``prompt`` (token-exact),
        or None.  With ``capacity`` given, only entries whose donor
        streamed under that same KV-buffer depth match — the condition
        under which the resumed state is bitwise what the requester would
        have computed itself.  Touches the hit chain's LRU recency; the
        caller pins the returned entry with ``acquire`` while resuming."""
        prompt = np.asarray(prompt)
        node, pos = self._root, 0
        best: Optional[PrefixEntry] = None
        while pos < len(prompt):
            child = node.children.get(int(prompt[pos]))
            if child is None:
                break
            m = _common_len(child.edge, prompt[pos:])
            if m < len(child.edge):
                break  # partial edge: no entry can sit mid-edge
            pos += m
            node = child
            if node.entry is not None and (
                    capacity is None
                    or node.entry.src_capacity == capacity):
                best = node.entry
        if best is None:
            self.misses += 1
            return None
        self.hits += 1
        self.tokens_hit += best.depth
        e = best
        while e is not None:  # whole chain was effectively used
            self._lru.move_to_end(e)
            e = e.parent
        return best

    def acquire(self, entry: PrefixEntry) -> None:
        """Pin ``entry`` (and, transitively via child refs, its chain)."""
        entry.refs += 1

    def release(self, entry: PrefixEntry) -> None:
        assert entry.refs > 0, "refcount underflow"
        entry.refs -= 1

    # -- insert ----------------------------------------------------------
    def insert(
        self,
        prefix: np.ndarray,  # the first ``depth`` prompt tokens
        *,
        state: tf.ChunkState,  # streaming state with pos >= len(prefix)
        logits: jnp.ndarray,  # (1, V) the boundary chunk's logits
        parent: Optional[PrefixEntry] = None,  # the request's current tip
    ) -> Optional[PrefixEntry]:
        """Cache the chunk boundary at ``len(prefix)``.  Returns the entry
        (existing or new, unpinned — the caller re-pins), or None when the
        byte budget cannot admit it or the boundary is already cached for
        a different KV-buffer depth (rung) than ``state`` streams under —
        chains stay capacity-homogeneous so hits are bitwise sound.

        A freshly created entry's chain-materialize program is built here
        (via one throwaway materialize) rather than on the first hit: the
        chain shape is fixed at insert, and a hit at the entry always
        materializes at its ``src_capacity``, so hits never pay the
        compile on the TTFT path.  The cost lands on cold misses instead —
        a first-seen prompt depth compiles one program per novel chain
        shape during its own (already slow, streaming) prefill; shapes are
        shared process-wide, so warm traffic never compiles."""
        prefix = np.asarray(prefix)
        depth = len(prefix)
        src_capacity = state.k.shape[2]
        assert depth > 0 and depth % self.chunk == 0, \
            "only whole-chunk prefixes are cacheable"
        assert parent is None or parent.src_capacity == src_capacity, \
            "chain would mix KV-buffer rungs"
        node = self._insert_node(prefix)
        if node.entry is not None:
            if node.entry.src_capacity != src_capacity:
                return None  # boundary owned by another rung's snapshot
            self._lru.move_to_end(node.entry)
            return node.entry
        start = parent.depth if parent is not None else 0
        if self.pool is not None:
            nblk = (depth - start) // self.pool.block_size
            entry = PrefixEntry(
                depth=depth, start=start, parent=parent, blocks=None,
                block_bytes=self.pool.block_bytes,
                score=state.score.snapshot(depth), logits=logits, node=node,
                src_capacity=src_capacity,
            )
            entry.nbytes += nblk * self.pool.block_bytes
            if not self._make_room(entry.nbytes):
                self._prune_node(node)
                return None
            ids = self.pool.alloc(nblk)
            if ids is None:
                # budget ok but the pool itself is consumed by live decode
                # caches — running traffic outranks cached prefixes
                self._prune_node(node)
                return None
            self.pool.write_span(state.k[:, :, start:depth],
                                 state.v[:, :, start:depth], ids)
            self.pool.note_pinned(nblk)
            entry.blocks = ids
        else:
            entry = PrefixEntry(
                depth=depth, start=start, parent=parent,
                k_block=state.k[:, :, start:depth],
                v_block=state.v[:, :, start:depth],
                score=state.score.snapshot(depth), logits=logits, node=node,
                src_capacity=src_capacity,
            )
            if not self._make_room(entry.nbytes):
                self._prune_node(node)  # drop the entry-less leaf we created
                return None
        node.entry = entry
        if parent is not None:
            parent.refs += 1
        self._lru[entry] = None
        self.bytes += entry.nbytes
        self.inserts += 1
        if self.pool is not None:
            key = ("pool", depth // self.pool.block_size, src_capacity)
        else:
            spans = tuple(c.depth - c.start for c in self._chain(entry))
            key = (spans, src_capacity)
        if key not in self._mat_fns:
            self.materialize(entry, src_capacity)  # compile + warm
        return entry

    def _insert_node(self, tokens: np.ndarray) -> _Node:
        """Walk/extend the trie to the node ending exactly at ``tokens``,
        splitting edges as needed."""
        node, pos = self._root, 0
        while pos < len(tokens):
            first = int(tokens[pos])
            child = node.children.get(first)
            if child is None:
                new = _Node(tokens[pos:].copy(), len(tokens), node)
                node.children[first] = new
                return new
            m = _common_len(child.edge, tokens[pos:])
            if m == len(child.edge):
                pos += m
                node = child
                continue
            # split the edge at the divergence (or early-end) point
            split = _Node(child.edge[:m].copy(), node.depth + m, node)
            child.edge = child.edge[m:]
            child.parent = split
            split.children[int(child.edge[0])] = child
            node.children[first] = split
            pos += m
            node = split
        return node

    # -- eviction --------------------------------------------------------
    def _protected_bytes(self) -> int:
        """Bytes that eviction can never reclaim right now: entries with an
        in-flight pin plus their ancestor chains (child refs alone cascade
        away once the leaves go; pins do not)."""
        children: dict[int, int] = {}
        for e in self._lru:
            if e.parent is not None:
                children[id(e.parent)] = children.get(id(e.parent), 0) + 1
        protected: set[int] = set()
        for e in self._lru:
            if e.refs > children.get(id(e), 0):  # has at least one pin
                a: Optional[PrefixEntry] = e
                while a is not None and id(a) not in protected:
                    protected.add(id(a))
                    a = a.parent
        return sum(e.nbytes for e in self._lru if id(e) in protected)

    def _make_room(self, need: int) -> bool:
        if self.bytes + need <= self.max_bytes:
            return True
        # feasibility first: refuse before evicting anything, so a doomed
        # insert can't churn cached prefixes it gains nothing from
        if self._protected_bytes() + need > self.max_bytes:
            return False
        while self.bytes + need > self.max_bytes:
            if not self._evict_one():
                return False  # defensive; feasibility said this can't hit
        return True

    def _evict_one(self) -> bool:
        for entry in self._lru:  # OrderedDict iterates LRU -> MRU
            if entry.refs == 0:
                self._remove(entry)
                return True
        return False

    def _remove(self, entry: PrefixEntry) -> None:
        assert entry.refs == 0, "evicting a pinned or parented entry"
        del self._lru[entry]
        self.bytes -= entry.nbytes
        self.evictions += 1
        entry.node.entry = None
        if entry.blocks is not None:  # return the pinned run to the pool
            self.pool.free(entry.blocks)
            self.pool.note_pinned(-len(entry.blocks))
            entry.blocks = None
        if entry.parent is not None:
            self.release(entry.parent)
        self._prune_node(entry.node)

    # -- pool reclaim (paged serving) -------------------------------------
    def evictable_pool_blocks(self) -> int:
        """Pool blocks reclaimable *right now* (unpinned childless
        entries).  An underestimate — evicting a leaf can make its parent
        evictable — which only makes the admission gate conservative."""
        if self.pool is None:
            return 0
        return sum(len(e.blocks) for e in self._lru
                   if e.refs == 0 and e.blocks is not None)

    def evict_pool_blocks(self, need: int) -> bool:
        """Evict LRU unpinned entries until at least ``need`` pool blocks
        returned to the free list (cascading up freed chains).  Returns
        True iff the need was fully met — live decode traffic calls this
        when the pool runs dry, so cached prefixes yield to admissions."""
        if self.pool is None:
            return False
        freed = 0
        while freed < need:
            victim = next((e for e in self._lru
                           if e.refs == 0 and e.blocks is not None), None)
            if victim is None:
                return False
            freed += len(victim.blocks)
            self._remove(victim)
        return True

    @staticmethod
    def _prune_node(node: _Node) -> None:
        """Drop now-useless trie nodes (no entry, no children) so token
        edges don't leak host memory — after an eviction and after a
        budget-rejected insert alike."""
        while (node.parent is not None and node.entry is None
               and not node.children):
            del node.parent.children[int(node.edge[0])]
            node = node.parent

    # -- materialization -------------------------------------------------
    @staticmethod
    def _chain(entry: PrefixEntry) -> list:
        chain = []
        e: Optional[PrefixEntry] = entry
        while e is not None:
            chain.append(e)
            e = e.parent
        chain.reverse()
        return chain

    def materialize(self, entry: PrefixEntry, capacity: int
                    ) -> tuple[tf.ChunkState, jnp.ndarray]:
        """Rebuild a resumable ``ChunkState`` (capacity-deep buffers,
        ``pos = entry.depth``) from the entry's block chain, plus the
        boundary logits (the next-token distribution when the requesting
        prompt is exactly the cached prefix)."""
        chain = self._chain(entry)
        depth = entry.depth
        if self.pool is not None:
            # pool-backed: the whole prefix is one block-id gather — the
            # chain's runs concatenate in depth order, and gathers are
            # exact, so the resumed state is bitwise the streamed one
            ids = np.concatenate([c.blocks for c in chain])
            key = ("pool", len(ids), capacity)
            fn = self._mat_fns.get(key)
            if fn is None:
                bs = self.pool.block_size

                def build(pk, pv, ids, score):
                    def flat(x):  # (L, n, bs, KV, hd) -> (L, 1, depth, ...)
                        return x.reshape((x.shape[0], 1, -1) + x.shape[3:])

                    snap = tf.ChunkState(
                        k=flat(pk[:, ids]), v=flat(pv[:, ids]), score=score,
                        pos=jnp.asarray(len(ids) * bs, jnp.int32))
                    return tf.resume_chunk_state(snap, capacity)

                fn = jax.jit(build)
                self._mat_fns[key] = fn
                while len(self._mat_fns) > self.max_materialize_programs:
                    self._mat_fns.popitem(last=False)
            else:
                self._mat_fns.move_to_end(key)
            state = fn(self.pool.k, self.pool.v, jnp.asarray(ids),
                       entry.score)
            return state, entry.logits
        spans = tuple(c.depth - c.start for c in chain)
        fn = self._mat_fns.get((spans, capacity))
        if fn is None:
            def build(ks, vs, score):
                snap = tf.ChunkState(
                    k=jnp.concatenate(ks, axis=2),
                    v=jnp.concatenate(vs, axis=2),
                    score=score, pos=jnp.asarray(depth, jnp.int32))
                return tf.resume_chunk_state(snap, capacity)

            fn = jax.jit(build)
            self._mat_fns[(spans, capacity)] = fn
            while len(self._mat_fns) > self.max_materialize_programs:
                self._mat_fns.popitem(last=False)
        else:
            self._mat_fns.move_to_end((spans, capacity))
        state = fn([c.k_block for c in chain], [c.v_block for c in chain],
                   entry.score)
        return state, entry.logits

    # -- observability ---------------------------------------------------
    def stats(self) -> dict:
        total = self.hits + self.misses
        pool_blocks = (sum(len(e.blocks) for e in self._lru
                           if e.blocks is not None)
                       if self.pool is not None else 0)
        return {
            "entries": len(self._lru),
            "pool_blocks_pinned": pool_blocks,
            "materialize_programs": len(self._mat_fns),
            "bytes": self.bytes,
            "max_bytes": self.max_bytes,
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / total if total else 0.0,
            "inserts": self.inserts,
            "evictions": self.evictions,
            "tokens_hit": self.tokens_hit,
        }

    def bind_metrics(self, registry) -> None:
        """Mirror ``stats()`` as ``prefix_cache_*`` callback gauges on the
        engine's registry (collection-time reads, no hot-path writes)."""
        from repro.obs.metrics import bind_stat_gauges
        bind_stat_gauges(registry, "prefix_cache", self.stats)
