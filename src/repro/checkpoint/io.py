"""Flat-npz pytree checkpointing (orbax is not available offline).

Pytrees are flattened to ``path -> array`` with '/'-joined dict keys; dtypes
(including bfloat16, stored as uint16 views) and the tree structure round-trip
exactly.  Sharded arrays are gathered to host before saving (process-0
semantics on a real cluster; a no-op single-process here).
"""

from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> dict:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def save(path: str, tree: Any, *, metadata: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    arrays, dtypes = {}, {}
    for k, v in flat.items():
        a = np.asarray(jax.device_get(v))
        if a.dtype == jnp.bfloat16:
            dtypes[k] = "bfloat16"
            a = a.view(np.uint16)
        else:
            dtypes[k] = str(a.dtype)
        arrays[k.replace("/", "__")] = a
    arrays["__dtypes__"] = np.frombuffer(
        json.dumps(dtypes).encode(), dtype=np.uint8
    )
    if metadata:
        arrays["__meta__"] = np.frombuffer(
            json.dumps(metadata).encode(), dtype=np.uint8
        )
    np.savez(path, **arrays)


def load(path: str, like: Any | None = None) -> Any:
    """Restore.  With ``like`` given, unflatten into its structure (and
    validate shapes); otherwise return the flat {path: array} dict."""
    z = np.load(path)
    dtypes = json.loads(bytes(z["__dtypes__"]).decode())
    flat = {}
    for k in z.files:
        if k.startswith("__"):
            continue
        path_key = k.replace("__", "/")
        a = z[k]
        if dtypes[path_key] == "bfloat16":
            a = a.view(jnp.bfloat16)
        flat[path_key] = jnp.asarray(a)
    if like is None:
        return flat
    ref = _flatten(like)
    assert set(ref) == set(flat), (
        f"checkpoint/tree mismatch: {set(ref) ^ set(flat)}"
    )
    for k in ref:
        assert ref[k].shape == flat[k].shape, (k, ref[k].shape, flat[k].shape)
    leaves, treedef = jax.tree.flatten(like)
    ordered = [flat[k] for k in sorted(ref)]
    # tree.flatten of nested dicts is sorted-key order — same as _flatten
    return jax.tree.unflatten(treedef, ordered)


def metadata(path: str) -> dict:
    z = np.load(path)
    if "__meta__" in z.files:
        return json.loads(bytes(z["__meta__"]).decode())
    return {}
