"""Flat-npz pytree checkpointing (orbax is not available offline).

Pytrees are flattened to ``path -> array`` with '/'-joined dict keys; dtypes
(including bfloat16, stored as uint16 views) and the tree structure round-trip
exactly.  Sharded arrays are gathered to host before saving (process-0
semantics on a real cluster; a no-op single-process here).

On-disk layout: arrays are stored under opaque member names ``a0, a1, ...``
and the path keys ride a ``__keys__`` JSON manifest (aligned by index), so
path strings never collide with the ``__``-prefixed sentinels and keys
containing ``__`` or ``/`` survive verbatim.  Files written by the old
layout (path keys mangled with ``"/" -> "__"``) still load when their keys
are unambiguous.
"""

from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> dict:
    """Path-keyed leaves, in ``jax.tree.flatten`` leaf order: dicts iterate
    sorted (jax's dict registration), sequences numerically — so the dict's
    insertion order *is* the treedef leaf order."""
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def save(path: str, tree: Any, *, metadata: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    arrays, dtypes, keys = {}, {}, []
    for i, (k, v) in enumerate(flat.items()):
        a = np.asarray(jax.device_get(v))
        if a.dtype == jnp.bfloat16:
            dtypes[k] = "bfloat16"
            a = a.view(np.uint16)
        else:
            dtypes[k] = str(a.dtype)
        arrays[f"a{i}"] = a
        keys.append(k)
    arrays["__keys__"] = np.frombuffer(
        json.dumps(keys).encode(), dtype=np.uint8
    )
    arrays["__dtypes__"] = np.frombuffer(
        json.dumps(dtypes).encode(), dtype=np.uint8
    )
    if metadata:
        arrays["__meta__"] = np.frombuffer(
            json.dumps(metadata).encode(), dtype=np.uint8
        )
    np.savez(path, **arrays)


def _load_flat(path: str) -> dict:
    z = np.load(path)
    dtypes = json.loads(bytes(z["__dtypes__"]).decode())
    if "__keys__" in z.files:
        keys = json.loads(bytes(z["__keys__"]).decode())
        members = {k: f"a{i}" for i, k in enumerate(keys)}
    else:
        # legacy layout: path keys mangled "/" -> "__" (ambiguous for keys
        # that genuinely contain "__"; such files predate the manifest)
        members = {k.replace("__", "/"): k
                   for k in z.files if not k.startswith("__")}
    flat = {}
    for path_key, member in members.items():
        a = z[member]
        if dtypes[path_key] == "bfloat16":
            a = a.view(jnp.bfloat16)
        flat[path_key] = jnp.asarray(a)
    return flat


def unflatten(flat: dict, like: Any) -> Any:
    """Rebuild ``like``'s structure from a ``{path: array}`` dict, restoring
    leaves in treedef order (``_flatten`` emits keys in exactly that order —
    lexicographic sorting would scramble sequences of >= 10 entries, since
    "10" < "2" as strings)."""
    ref = _flatten(like)
    assert set(ref) == set(flat), (
        f"checkpoint/tree mismatch: {set(ref) ^ set(flat)}"
    )
    for k in ref:
        assert ref[k].shape == flat[k].shape, (k, ref[k].shape, flat[k].shape)
    _, treedef = jax.tree.flatten(like)
    return jax.tree.unflatten(treedef, [flat[k] for k in ref])


def load(path: str, like: Any | None = None) -> Any:
    """Restore.  With ``like`` given, unflatten into its structure (and
    validate shapes); otherwise return the flat {path: array} dict."""
    flat = _load_flat(path)
    if like is None:
        return flat
    return unflatten(flat, like)


def metadata(path: str) -> dict:
    z = np.load(path)
    if "__meta__" in z.files:
        return json.loads(bytes(z["__meta__"]).decode())
    return {}
