"""Logical→physical sharding rules (MaxText-style, but path-driven).

``param_specs(cfg, mesh)`` mirrors the parameter tree with PartitionSpecs:

* attention q/o projections shard the head dim on "model" when the head
  count divides the axis (GQA: k/v shard only when kv heads divide, else
  stay replicated — the standard Megatron GQA compromise);
* MLP shards d_ff column→row (no resharding between the two matmuls);
* MoE experts shard the expert dim on "model" (expert parallelism);
* embeddings shard vocab when divisible, else d_model, else replicate;
* ``cfg.fsdp`` additionally shards the d_model dim of big weights over
  "data" (ZeRO-3-ish storage; XLA all-gathers at use) — beyond-paper;
* Mamba-2 / LoRA / norms / scalars replicate (see DESIGN.md §4 — the SSM
  inner projection is deliberately replicated in the baseline; §Perf
  revisits it).

Every rule degrades to replication when divisibility fails, so every
(arch × mesh) combination lowers.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common.config import ModelConfig
from repro.common.pytree import tree_map_with_path


def _div(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


def param_specs(cfg: ModelConfig, mesh, *, embed_replicated: bool = False) -> Any:
    """PartitionSpec tree mirroring ``init_params(cfg)`` output.

    ``embed_replicated``: used by the dp_all §Perf variant (batch sharded
    over data *and* model — vocab sharding would then conflict with the
    batch-sharded hidden states at the unembed einsum)."""
    msize = mesh.shape["model"]
    dsize = mesh.shape["data"]
    a = cfg.attn
    fsdp = "data" if (cfg.fsdp and _div(cfg.d_model, dsize)) else None

    shard_q = a is not None and _div(a.num_heads, msize)
    shard_kv = a is not None and _div(a.num_kv_heads, msize)
    shard_ff = _div(cfg.d_ff, msize)
    shard_exp = cfg.moe is not None and _div(cfg.moe.num_experts, msize)
    shard_shared = (cfg.moe is not None and
                    _div(cfg.moe.num_shared_experts * cfg.moe.d_expert, msize))
    # padded vocab always divides the model axis (config.vocab_pad_multiple)
    if embed_replicated:
        embed_spec = P(None, None)
    elif _div(cfg.padded_vocab, msize):
        embed_spec = P("model", None)
    elif _div(cfg.d_model, msize):
        embed_spec = P(None, "model")
    else:
        embed_spec = P(None, None)

    def rule(path: str, leaf) -> P:
        parts = path.split("/")
        name = parts[-1]
        ndim = leaf.ndim
        stacked = "layers" in parts  # leading L axis
        pre = (None,) if stacked else ()

        if name == "embed":
            return embed_spec
        if name == "lm_head":
            return P(*embed_spec[::-1])
        if name == "pos_emb":
            return P(None, None)
        # --- attention (incl. whisper cross/encoder) ---
        if name == "wq":
            return P(*pre, fsdp, "model" if shard_q else None)
        if name in ("wk", "wv"):
            return P(*pre, fsdp, "model" if shard_kv else None)
        if name == "wo":
            return P(*pre, "model" if shard_q else None, fsdp)
        if name == "bq":
            return P(*pre, "model" if shard_q else None)
        if name in ("bk", "bv"):
            return P(*pre, "model" if shard_kv else None)
        # --- MoE ---
        if "experts" in parts:
            if name in ("w_gate", "w_up"):
                return P(*pre, "model" if shard_exp else None, fsdp, None)
            if name == "w_down":
                return P(*pre, "model" if shard_exp else None, None, fsdp)
        if name == "router":
            return P(*pre, fsdp, None)
        if "shared" in parts:
            if name in ("w_gate", "w_up"):
                return P(*pre, fsdp, "model" if shard_shared else None)
            if name == "w_down":
                return P(*pre, "model" if shard_shared else None, fsdp)
        # --- dense MLP ---
        if name in ("w_gate", "w_up"):
            return P(*pre, fsdp, "model" if shard_ff else None)
        if name == "w_down":
            return P(*pre, "model" if shard_ff else None, fsdp)
        # --- Mamba-2: replicated in the baseline (DESIGN.md §4) ---
        if name in ("in_proj", "out_proj", "conv_w", "conv_b", "A_log",
                    "D_skip", "dt_bias"):
            return P(*((None,) * ndim))
        # norms, scalars, anything unmatched: replicate
        return P(*((None,) * ndim))

    return tree_map_with_path(lambda p, l: rule(p, l), _as_shaped(cfg))


def _as_shaped(cfg: ModelConfig):
    """Abstract parameter tree (ShapeDtypeStructs) without allocation."""
    from repro.models import transformer as tf

    return jax.eval_shape(lambda: tf.init_params(jax.random.PRNGKey(0), cfg))


def lkv_specs(lkv_shapes: Any) -> Any:
    """Lookahead params replicate everywhere (tiny: <0.5% of model)."""
    return jax.tree.map(lambda x: P(*((None,) * x.ndim)), lkv_shapes)


def batch_axes(mesh) -> tuple:
    return tuple(n for n in mesh.axis_names if n != "model")


def cache_specs(cfg: ModelConfig, mesh, batch: int, capacity: int,
                hot_slots: int = 0) -> Any:
    """Sharding for the decode cache: batch over data axes; kv heads on
    "model" when divisible, else the *sequence* dim on "model" (sequence-
    parallel decode — XLA inserts the softmax partial collectives)."""
    dp = batch_axes(mesh)
    msize = mesh.shape["model"]
    dp_total = int(np.prod([mesh.shape[x] for x in dp]))
    bshard = dp if _div(batch, dp_total) else (
        ("data",) if _div(batch, mesh.shape["data"]) else ())
    bspec = bshard if bshard else None
    a = cfg.attn
    specs: dict = {}
    if a is not None:
        if _div(a.num_kv_heads, msize):
            kv_s, seq_s = "model", None
        elif _div(capacity, msize):
            kv_s, seq_s = None, "model"
        else:
            kv_s = seq_s = None
        if batch == 1 and seq_s is not None:
            # long-context decode: shard the cache sequence over everything
            seq_s = tuple(list(dp) + ["model"])
            bspec = None
        specs["attn"] = {
            "k": P(None, bspec, seq_s, kv_s, None),
            "v": P(None, bspec, seq_s, kv_s, None),
            "pos": P(None, bspec, seq_s, kv_s),
            "mask": P(None, bspec, seq_s, kv_s),
        }
        if hot_slots:
            # split-cache decode: the hot ring replicates over "model" so
            # per-step writes are shard-local (no cache resharding)
            specs["attn"].update({
                "hot_k": P(None, bspec, None, None, None),
                "hot_v": P(None, bspec, None, None, None),
                "hot_pos": P(None, bspec, None, None),
                "hot_mask": P(None, bspec, None, None),
            })
        specs["cursor"] = P()
    if cfg.uses_ssm:
        specs["ssm"] = {
            "conv": P(None, bspec, None, None),
            "state": P(None, bspec, None, None, None),
        }
    if cfg.is_encoder_decoder:
        specs["cross"] = {
            "k": P(None, bspec, None, None, None),
            "v": P(None, bspec, None, None, None),
        }
    specs["next_pos"] = P(bspec, None)
    return specs


def pool_specs(cfg: ModelConfig, mesh) -> Optional[dict]:
    """Sharding for the paged KV block pool (``serving/kv_pool.py``).

    The pool arrays are ``(L, num_blocks, block_size, KV, hd)`` K/V plus
    ``(L, num_blocks, block_size, KV)`` pos/mask; only the kv-head dim is
    sharded, on "model" — blocks are *whole* on every shard, so the host
    free-list allocator and the per-request block tables stay replicated
    and allocation logic is untouched.  Returns None when the mesh has no
    "model" axis or kv heads don't divide it (pool stays single-device /
    replicated)."""
    a = cfg.attn
    if a is None or mesh is None:
        return None
    if "model" not in getattr(mesh, "axis_names", ()):
        return None
    if not _div(a.num_kv_heads, mesh.shape["model"]):
        return None
    return {
        "k": P(None, None, None, "model", None),
        "v": P(None, None, None, "model", None),
        "pos": P(None, None, None, "model"),
        "mask": P(None, None, None, "model"),
    }


def mesh_signature(mesh) -> Optional[tuple]:
    """Hashable mesh identity for compile-cache keys: ``(("data", 4),
    ("model", 2))`` — None for no mesh or an all-1 (trivial) mesh, so
    meshless cache keys keep their historical shape."""
    if mesh is None:
        return None
    sig = tuple((n, int(mesh.shape[n])) for n in mesh.axis_names)
    if all(s == 1 for _, s in sig):
        return None
    return sig


def with_sharding(shapes: Any, specs: Any, mesh) -> Any:
    """Attach NamedShardings to a ShapeDtypeStruct tree."""
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)
        ),
        shapes, specs,
    )
