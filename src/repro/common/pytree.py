"""Small pytree utilities: parameter counting, dtype casting, tree maps with
path filters.  We hand-roll these because flax/optax are not available in the
offline container (DESIGN.md §2)."""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


def tree_size(tree: Any) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree: Any) -> int:
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree.leaves(tree)
    )


def tree_cast(tree: Any, dtype) -> Any:
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def tree_zeros_like(tree: Any, dtype=None) -> Any:
    return jax.tree.map(
        lambda x: jnp.zeros(x.shape, dtype or x.dtype), tree
    )


def tree_add(a: Any, b: Any) -> Any:
    return jax.tree.map(jnp.add, a, b)


def tree_scale(tree: Any, s) -> Any:
    return jax.tree.map(lambda x: x * s, tree)


def tree_map_with_path(fn: Callable, tree: Any) -> Any:
    """fn(path_str, leaf) -> new leaf.  path_str like 'layers/attn/wq'."""

    def _fn(path, leaf):
        keys = []
        for p in path:
            if hasattr(p, "key"):
                keys.append(str(p.key))
            elif hasattr(p, "idx"):
                keys.append(str(p.idx))
            else:
                keys.append(str(p))
        return fn("/".join(keys), leaf)

    return jax.tree_util.tree_map_with_path(_fn, tree)


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def check_finite(tree: Any) -> jnp.ndarray:
    """True iff every leaf is finite everywhere."""
    oks = [jnp.all(jnp.isfinite(x)) for x in jax.tree.leaves(tree)]
    return jnp.stack(oks).all() if oks else jnp.asarray(True)
