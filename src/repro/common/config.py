"""Unified model / technique / run configuration for the LookaheadKV framework.

Every assigned architecture is expressed as a single ``ModelConfig`` instance
(see ``repro.configs``).  The config is a frozen dataclass tree so it can be
hashed into jit static arguments and round-tripped to JSON for experiment
logging.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple


# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AttentionConfig:
    """Multi-head (grouped-query) attention settings."""

    num_heads: int
    num_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    # 0 => full attention.  >0 => sliding-window span (causal, local).
    sliding_window: int = 0
    # 0 => homogeneous layers.  n => every n-th layer (index % n == n-1) is a
    # *global* full-attention layer while the rest are sliding-window local
    # layers (gemma3's 5:1 pattern => global_every=6).
    global_every: int = 0
    # Explicit global-attention layer indices (hymba: first/middle/last);
    # overrides global_every when non-empty.
    global_layers: Tuple[int, ...] = ()
    # Multimodal rotary embedding (qwen2-vl): 3 position streams
    # (temporal, height, width) interleaved across the head dim.
    mrope: bool = False
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def group_size(self) -> int:
        return self.num_heads // self.num_kv_heads


@dataclass(frozen=True)
class MoEConfig:
    """Fine-grained mixture-of-experts FFN (DeepSeek-MoE / Phi-3.5-MoE)."""

    num_experts: int
    top_k: int
    d_expert: int  # per-expert hidden width
    num_shared_experts: int = 0
    router_noise: float = 0.0
    load_balance_coef: float = 0.01

    # Dry-run/serving: dense one-hot dispatch => fixed shapes, expert-parallel
    # friendly.  Capacity factor bounds per-expert tokens when using the
    # gather-based dispatch path.
    capacity_factor: float = 1.25
    # "dense": every expert runs on every token (paper-faithful baseline,
    # E/k x extra FLOPs).  "sparse": sort-based capacity dispatch (top-k
    # FLOPs only) — the §Perf beyond-paper optimization.
    dispatch: str = "dense"


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD (state-space duality) settings."""

    d_state: int
    expand: int = 2
    head_dim: int = 64
    conv_width: int = 4
    chunk_size: int = 128
    dt_min: float = 1e-3
    dt_max: float = 1e-1
    a_init_range: Tuple[float, float] = (1.0, 16.0)

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style bidirectional encoder (frontend stubbed: we consume
    precomputed frame embeddings of shape (B, num_frames, d_model))."""

    num_layers: int
    num_frames: int = 1500


@dataclass(frozen=True)
class LookaheadConfig:
    """The paper's technique: learnable lookahead tokens + selective LoRA."""

    n_lookahead: int = 32
    lora_rank: int = 8
    lora_alpha: float = 32.0
    # Which linear layers receive lookahead LoRA.  The paper's best config is
    # "all"; MoE archs restrict to attention projections (see DESIGN.md §5).
    lora_targets: Tuple[str, ...] = (
        "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
    )
    # Eviction-time score post-processing (paper defaults).
    pool_kernel: int = 7
    # Observation-window size used by the SnapKV/LAQ/SpecKV baselines.
    window_size: int = 32


@dataclass(frozen=True)
class ModelConfig:
    """A single architecture, assigned from the public pool."""

    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attn: Optional[AttentionConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None
    lookahead: Optional[LookaheadConfig] = field(default_factory=LookaheadConfig)

    # hybrid (hymba): run attention AND ssm in parallel inside each block.
    hybrid: bool = False
    # vlm (qwen2-vl): inputs arrive as patch/frame embeddings, not token ids.
    embeds_in: bool = False

    norm_eps: float = 1e-6
    act: str = "silu"
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    # Citation for the architecture definition (paper/model card).
    source: str = ""
    # Whether the paper's eviction technique applies (DESIGN.md §5).
    technique_applies: bool = True
    # FSDP-style extra sharding of frozen weights over the data axis for
    # large models (beyond-paper distribution feature).
    fsdp: bool = False
    # Embedding/lm-head rows are padded to this multiple so the vocab dim
    # always shards on "model" (§Perf: an unshardable vocab forces a full
    # (B,S,V) f32 logits all-reduce — 13 GB/device for mamba2 train_4k).
    vocab_pad_multiple: int = 256

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab_size // m) * m if m else self.vocab_size

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder is not None

    @property
    def uses_attention(self) -> bool:
        return self.attn is not None

    @property
    def uses_ssm(self) -> bool:
        return self.ssm is not None

    def num_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, L = self.d_model, self.num_layers
        total = self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d
        per_layer = 0
        if self.attn is not None:
            a = self.attn
            per_layer += d * a.q_dim + 2 * d * a.kv_dim + a.q_dim * d
            if a.qkv_bias:
                per_layer += a.q_dim + 2 * a.kv_dim
        if self.ssm is not None:
            s = self.ssm
            di = s.d_inner(d)
            nh = s.num_heads(d)
            # in_proj -> (z, x, B, C, dt), conv, A, D, norm, out_proj
            # (B/C are group-shared: ngroups=1, NOT per-head)
            per_layer += d * (2 * di + 2 * s.d_state + nh)
            per_layer += s.conv_width * di
            per_layer += 2 * nh + di  # A_log, D, gated-norm
            per_layer += di * d
        if self.moe is not None:
            m = self.moe
            per_layer += d * m.num_experts  # router
            per_layer += m.num_experts * 3 * d * m.d_expert
            per_layer += m.num_shared_experts * 3 * d * m.d_expert
        elif self.d_ff > 0:
            per_layer += 3 * d * self.d_ff
        per_layer += 2 * d  # norms
        total += L * per_layer
        if self.encoder is not None:
            a = self.attn
            enc_layer = d * a.q_dim + 2 * d * a.kv_dim + a.q_dim * d
            enc_layer += 3 * d * self.d_ff + 2 * d
            # decoder cross-attention
            total += self.encoder.num_layers * enc_layer
            total += L * (d * a.q_dim + 2 * d * a.kv_dim + a.q_dim * d + d)
        total += d  # final norm
        return total

    def active_params(self) -> int:
        """Activated parameters per token (MoE-aware), for MODEL_FLOPS."""
        if self.moe is None:
            return self.num_params()
        d, L, m = self.d_model, self.num_layers, self.moe
        routed_total = L * m.num_experts * 3 * d * m.d_expert
        routed_active = L * m.top_k * 3 * d * m.d_expert
        return self.num_params() - routed_total + routed_active

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2, default=str)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Run / eviction configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class EvictionConfig:
    policy: str = "lookaheadkv"
    budget: int = 128
    # StreamingLLM sink size.
    sink: int = 4
    # LAQ / SpecKV draft length (paper: equal to n_lookahead).
    draft_len: int = 32
    # PyramidKV: budgets decay linearly from first to last layer with this
    # total preserved (beta=20-ish funnel in the paper; linear here).
    pyramid_beta: float = 2.0
    # Encoder-decoder extension (beyond-paper): also evict the *cross*
    # attention KV (encoder frames) down to this budget, scored by the same
    # lookahead/observation queries.  0 = keep the full encoder cache.
    cross_budget: int = 0
    # "uniform": every kv head keeps ``budget`` slots.  "adaptive": Ada-KV
    # style — the global pool KV·budget redistributes toward heads whose
    # score mass concentrates (beyond-paper composable axis).
    head_alloc: str = "uniform"
    # Ada-KV ceiling multiplier: per-head capacity = ceil(budget · this).
    adaptive_ceiling: float = 2.0


@dataclass(frozen=True)
class TrainConfig:
    batch_size: int = 32
    n_in: int = 3_584
    n_out: int = 512
    steps: int = 200
    lr: float = 1e-3
    warmup_frac: float = 0.02
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    seed: int = 0
