"""LookaheadKV training objective (paper §3.2, Algorithm 1).

One training iteration:
  1. GT pass      — frozen model over [X; Y]; per-(layer, head) importance
                    scores of X's keys from Y's queries (stop-gradient).
  2. Lookahead pass — frozen model + lookahead tokens + selective LoRA over
                    [X; P]; the same scores from P's queries.
  3. Loss         — mean over L·H of KL(ŝ_GT ‖ ŝ_LKV) with L1-normalized
                    score vectors (≡ ListNet ranking loss with identity φ).

Only ``lkv_params`` receive gradients; the model tree is a closure constant.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.core.scoring import normalize_l1
from repro.kernels import ops
from repro.models import transformer as tf


def kl_divergence(p: jnp.ndarray, q: jnp.ndarray, eps: float = 1e-12) -> jnp.ndarray:
    """KL(p ‖ q) along the last axis; p, q L1-normalized score vectors.

    xlogy-style safe form: zero-mass ``p`` entries contribute exactly 0 and
    *both* logs see the same clamp, so ``KL(p ‖ p) == 0`` identically.  (The
    previous ``log(p + eps) - log(max(q, eps))`` asymmetry made the identity
    nonzero — and the divergence slightly negative — near convergence,
    biasing the distillation loss exactly where it matters.)"""
    p = jnp.maximum(p, 0.0)
    log_ratio = jnp.log(jnp.maximum(p, eps)) - jnp.log(jnp.maximum(q, eps))
    return jnp.sum(jnp.where(p > 0, p * log_ratio, 0.0), axis=-1)


def gt_scores(
    params: dict,
    cfg: ModelConfig,
    xy_tokens: jnp.ndarray,  # (B, n_in + n_out)
    n_in: int,
    *,
    encoder_embeds: Optional[jnp.ndarray] = None,
    mrope_positions: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Ground-truth per-head scores (L, B, H, n_in), f32, stop-gradient."""
    with ops.reference_mode():
        res = tf.prefill(
            params, cfg, xy_tokens, capture_scores=True, gt_boundary=n_in,
            want_logits="none", encoder_embeds=encoder_embeds,
            mrope_positions=mrope_positions,
        )
    return jax.lax.stop_gradient(res.scores)


def lookahead_scores(
    params: dict,
    cfg: ModelConfig,
    lkv_params: dict,
    x_tokens: jnp.ndarray,  # (B, n_in)
    *,
    encoder_embeds: Optional[jnp.ndarray] = None,
    mrope_positions: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Lookahead-estimated per-head scores (L, B, H, n_in), differentiable
    w.r.t. ``lkv_params``.

    Traced under ``ops.reference_mode()``: the Pallas kernels are
    forward-only, and this is the pass gradients flow through."""
    with ops.reference_mode():
        res = tf.prefill(
            params, cfg, x_tokens, lkv_params=lkv_params, capture_scores=True,
            want_logits="none", encoder_embeds=encoder_embeds,
            mrope_positions=mrope_positions,
        )
    return res.scores


class LossReport(NamedTuple):
    loss: jnp.ndarray
    kl_per_layer: jnp.ndarray  # (L,)


def lkv_loss(
    params: dict,
    cfg: ModelConfig,
    lkv_params: dict,
    x_tokens: jnp.ndarray,
    xy_tokens: jnp.ndarray,
    n_in: int,
    **kw,
) -> tuple[jnp.ndarray, LossReport]:
    s_gt = gt_scores(params, cfg, xy_tokens, n_in, **kw)  # (L,B,H,n)
    s_lkv = lookahead_scores(params, cfg, lkv_params, x_tokens, **kw)
    p = normalize_l1(s_gt)
    q = normalize_l1(s_lkv)
    kl = kl_divergence(p, q)  # (L, B, H)
    loss = kl.mean()
    return loss, LossReport(loss=loss, kl_per_layer=kl.mean(axis=(1, 2)))


def lkv_loss_from_targets(
    params: dict,
    cfg: ModelConfig,
    lkv_params: dict,
    x_tokens: jnp.ndarray,  # (B, n_in)
    s_gt: jnp.ndarray,  # (L, B, H, n_in) harvested gt_oracle scores
    **kw,
) -> tuple[jnp.ndarray, LossReport]:
    """Distillation against *precomputed* gt targets (harvested from serving
    traces, ``repro.data.harvest``): identical to ``lkv_loss`` with the GT
    pass replaced by stored score vectors — each step runs only the lookahead
    pass, so training is cheaper than online distillation and the expensive
    [X; Y] oracle pass is paid once at harvest time."""
    s_lkv = lookahead_scores(params, cfg, lkv_params, x_tokens, **kw)
    p = normalize_l1(jax.lax.stop_gradient(s_gt))
    q = normalize_l1(s_lkv)
    kl = kl_divergence(p, q)  # (L, B, H)
    loss = kl.mean()
    return loss, LossReport(loss=loss, kl_per_layer=kl.mean(axis=(1, 2)))


def lm_loss(
    params: dict,
    cfg: ModelConfig,
    tokens: jnp.ndarray,  # (B, S)
    *,
    encoder_embeds: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Plain next-token cross-entropy (pretraining loss for the SSM arch and
    the tiny end-to-end example)."""
    with ops.reference_mode():
        res = tf.prefill(params, cfg, tokens[:, :-1], want_logits="all",
                         encoder_embeds=encoder_embeds)
    logits = res.logits  # (B, S-1, V) f32
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return nll.mean() + res.aux
