"""LookaheadKV training objective (paper §3.2, Algorithm 1).

One training iteration:
  1. GT pass      — frozen model over [X; Y]; per-(layer, head) importance
                    scores of X's keys from Y's queries (stop-gradient).
  2. Lookahead pass — frozen model + lookahead tokens + selective LoRA over
                    [X; P]; the same scores from P's queries.
  3. Loss         — mean over L·H of KL(ŝ_GT ‖ ŝ_LKV) with L1-normalized
                    score vectors (≡ ListNet ranking loss with identity φ).

Only ``lkv_params`` receive gradients; the model tree is a closure constant.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.core.scoring import normalize_l1
from repro.models import transformer as tf


def kl_divergence(p: jnp.ndarray, q: jnp.ndarray, eps: float = 1e-9) -> jnp.ndarray:
    """KL(p ‖ q) along the last axis; p, q L1-normalized score vectors."""
    p = jnp.maximum(p, 0.0)
    q = jnp.maximum(q, eps)
    return jnp.sum(jnp.where(p > 0, p * (jnp.log(p + eps) - jnp.log(q)), 0.0),
                   axis=-1)


def gt_scores(
    params: dict,
    cfg: ModelConfig,
    xy_tokens: jnp.ndarray,  # (B, n_in + n_out)
    n_in: int,
    *,
    encoder_embeds: Optional[jnp.ndarray] = None,
    mrope_positions: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Ground-truth per-head scores (L, B, H, n_in), f32, stop-gradient."""
    res = tf.prefill(
        params, cfg, xy_tokens, capture_scores=True, gt_boundary=n_in,
        want_logits="none", encoder_embeds=encoder_embeds,
        mrope_positions=mrope_positions,
    )
    return jax.lax.stop_gradient(res.scores)


def lookahead_scores(
    params: dict,
    cfg: ModelConfig,
    lkv_params: dict,
    x_tokens: jnp.ndarray,  # (B, n_in)
    *,
    encoder_embeds: Optional[jnp.ndarray] = None,
    mrope_positions: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Lookahead-estimated per-head scores (L, B, H, n_in), differentiable
    w.r.t. ``lkv_params``."""
    res = tf.prefill(
        params, cfg, x_tokens, lkv_params=lkv_params, capture_scores=True,
        want_logits="none", encoder_embeds=encoder_embeds,
        mrope_positions=mrope_positions,
    )
    return res.scores


class LossReport(NamedTuple):
    loss: jnp.ndarray
    kl_per_layer: jnp.ndarray  # (L,)


def lkv_loss(
    params: dict,
    cfg: ModelConfig,
    lkv_params: dict,
    x_tokens: jnp.ndarray,
    xy_tokens: jnp.ndarray,
    n_in: int,
    **kw,
) -> tuple[jnp.ndarray, LossReport]:
    s_gt = gt_scores(params, cfg, xy_tokens, n_in, **kw)  # (L,B,H,n)
    s_lkv = lookahead_scores(params, cfg, lkv_params, x_tokens, **kw)
    p = normalize_l1(s_gt)
    q = normalize_l1(s_lkv)
    kl = kl_divergence(p, q)  # (L, B, H)
    loss = kl.mean()
    return loss, LossReport(loss=loss, kl_per_layer=kl.mean(axis=(1, 2)))


def lm_loss(
    params: dict,
    cfg: ModelConfig,
    tokens: jnp.ndarray,  # (B, S)
    *,
    encoder_embeds: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Plain next-token cross-entropy (pretraining loss for the SSM arch and
    the tiny end-to-end example)."""
    res = tf.prefill(params, cfg, tokens[:, :-1], want_logits="all",
                     encoder_embeds=encoder_embeds)
    logits = res.logits  # (B, S-1, V) f32
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return nll.mean() + res.aux
