"""Policy orchestration: single-pass policies call ``transformer.prefill``
directly; the draft-based baselines (LAQ, SpecKV) compose multiple passes.

* **LAQ** (Lookahead Q-Cache, Wang et al. 2025): SnapKV-evict → greedy-draft
  ``draft_len`` tokens with the compressed cache → re-evict the full prompt
  KV using the draft rows as observation queries.
* **SpecKV** (Galim et al. 2026): a smaller *draft model* generates the draft;
  the target model then scores the prompt with the draft as the observation
  window.

Both re-run a scoring prefill over [X; draft] (our TPU adaptation: recompute
beats parking the full uncompressed KV in HBM — the analytical TTFT model in
``benchmarks/bench_ttft.py`` accounts the paper's original memory-traffic
formulation).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.common.config import EvictionConfig, ModelConfig
from repro.models import transformer as tf

SINGLE_PASS = (
    "full", "random", "streaming_llm", "snapkv", "pyramidkv", "tova", "h2o",
    "lookaheadkv", "gt_oracle",
)
MULTI_PASS = ("laq", "speckv")
ALL_POLICIES = SINGLE_PASS + MULTI_PASS

_NEG_INF = -1e30


class EvictionResult(NamedTuple):
    logits: jnp.ndarray  # (B, V) next-token logits after the prompt
    cache: dict  # budgeted decode cache


class Sampling(NamedTuple):
    """Static sampling config for the fused decode epilogue.

    ``temperature <= 0`` is greedy argmax — the bit-exact default every
    differential trace test relies on; the filters are then ignored.
    ``top_k = 0`` and ``top_p = 1.0`` disable their filters."""

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0


def filter_logits(
    logits: jnp.ndarray,  # (..., V)
    *,
    top_k: int = 0,
    top_p: float = 1.0,
) -> jnp.ndarray:
    """Pure-jnp top-k / nucleus (top-p) filtering reference: logits outside
    the kept set drop to -inf, kept logits pass through *unchanged*.

    top-k keeps the k largest (ties at the k-th value are all kept);
    top-p keeps the smallest descending-probability prefix whose mass
    reaches ``top_p`` (always at least the argmax).  Both are identity
    when disabled, so the no-filter path stays bitwise what it was."""
    V = logits.shape[-1]
    if top_k and top_k < V:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, _NEG_INF, logits)
    if top_p < 1.0:
        srt = jnp.sort(logits, axis=-1)[..., ::-1]  # descending
        probs = jax.nn.softmax(srt, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        keep = (cum - probs) < top_p  # mass *before* each token < p
        thr = jnp.min(jnp.where(keep, srt, jnp.inf), axis=-1, keepdims=True)
        logits = jnp.where(logits < thr, _NEG_INF, logits)
    return logits


def fold_keys(seeds: jnp.ndarray, positions: jnp.ndarray) -> jax.Array:
    """Per-request, per-position PRNG keys: ``fold_in(PRNGKey(seed), pos)``
    for each (seed, position) pair.  Keyed on the *absolute* position of
    the sampled token, so a preempted request replaying the same positions
    resamples the same tokens — sampling stays replay-deterministic the
    way greedy decode is prefix-stable."""
    return jax.vmap(
        lambda s, p: jax.random.fold_in(jax.random.PRNGKey(s), p)
    )(seeds, positions)


def sample_logits(
    logits: jnp.ndarray,  # (B, V)
    keys: jax.Array,  # (B,) per-row PRNG keys (``fold_keys``)
    *,
    temperature: float = 1.0,
    top_k: int = 0,
    top_p: float = 1.0,
) -> jnp.ndarray:
    """Temperature / top-k / top-p categorical sampling, one independent
    key per row — the pure-jnp reference the fused decode epilogue jits
    and the host-sampling baseline calls eagerly.  Returns (B,) ids."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)
    x = logits.astype(jnp.float32) / temperature
    x = filter_logits(x, top_k=top_k, top_p=top_p)
    return jax.vmap(jax.random.categorical)(keys, x)


def decode_one(
    params: dict,
    cfg: ModelConfig,
    token: jnp.ndarray,  # (B, 1) current tokens
    cache: dict,
    *,
    active: Optional[jnp.ndarray] = None,  # (B,) live-slot mask
    paged_depth: Optional[int] = None,  # static depth of a paged cache
    sampling: Optional[Sampling] = None,  # None / temperature 0 = greedy
    seeds: Optional[jnp.ndarray] = None,  # (B,) per-request sampling seeds
    mesh=None,  # serving mesh: per-shard paged decode attention
) -> tuple[jnp.ndarray, dict]:
    """One decode step.  Returns (next_token (B, 1), new cache).

    With ``active`` (continuous batching), retired / empty slots don't
    advance: their cache is held fixed and their token freezes, so a slot
    can idle between retirement and the next admission without corrupting
    its neighbours' step count.  A *paged* cache (``"pool"`` key) gates
    its own advances in-step — the block pool is shared across slots, so
    there is no per-slot pytree to select back to.

    With ``sampling`` at temperature > 0 the next token comes from the
    fused sampling epilogue instead of argmax: the final-layer logits run
    through temperature / top-k / top-p and a per-request key folded on
    the sampled token's absolute position (``fold_keys``), all inside the
    same compiled program — the host never sees logits.
    """
    paged = "pool" in cache
    logits, new_cache = tf.decode_step(
        params, cfg, token, cache, mesh=mesh,
        active=active if paged else None, paged_depth=paged_depth)
    if sampling is not None and sampling.temperature > 0.0:
        assert seeds is not None, "sampling needs per-request seeds"
        # cache["next_pos"] is the *input* token's position; the token
        # sampled here sits one past it
        keys = fold_keys(seeds, cache["next_pos"][:, 0] + 1)
        nxt = sample_logits(
            logits, keys, temperature=sampling.temperature,
            top_k=sampling.top_k, top_p=sampling.top_p,
        )[:, None].astype(token.dtype)
    else:
        nxt = jnp.argmax(logits, -1)[:, None].astype(token.dtype)
    if active is not None:
        nxt = jnp.where(active[:, None], nxt, token)
        if not paged:
            new_cache = tf.select_cache_slots(active, new_cache, cache)
    return nxt, new_cache


def greedy_decode(
    params: dict,
    cfg: ModelConfig,
    first_token: jnp.ndarray,  # (B, 1)
    cache: dict,
    steps: int,
    *,
    active: Optional[jnp.ndarray] = None,  # (B,) live-slot mask
) -> tuple[jnp.ndarray, dict]:
    """Greedy continuation.  Returns (tokens (B, steps) incl. first, cache)."""

    def step(carry, _):
        tok, cache = carry
        nxt, cache = decode_one(params, cfg, tok, cache, active=active)
        return (nxt, cache), tok[:, 0]

    (last, cache), toks = jax.lax.scan(
        step, (first_token, cache), None, length=steps
    )
    return jnp.moveaxis(toks, 0, 1), cache  # (B, steps)


def decode_chunk(
    params: dict,
    cfg: ModelConfig,
    token: jnp.ndarray,  # (B, 1) last emitted tokens
    cache: dict,
    steps: int,
    *,
    active: Optional[jnp.ndarray] = None,
    paged_depth: Optional[int] = None,
    sampling: Optional[Sampling] = None,
    seeds: Optional[jnp.ndarray] = None,  # (B,) per-request sampling seeds
    mesh=None,  # serving mesh: per-shard paged decode attention
) -> tuple[jnp.ndarray, dict, jnp.ndarray]:
    """``steps`` decode steps *after* ``token``.  Returns (last (B, 1), cache,
    new tokens (B, steps)).  Unlike ``greedy_decode`` the emitted tokens
    exclude the input token — the serving loop emits the prefill's first
    token at admission and decodes the rest in chunks between admissions.
    With ``sampling`` set, every step samples through the fused epilogue
    (see ``decode_one``) — one device round-trip per chunk, not per-step
    logits transfers.

    Decode-time eviction needs no parameters here: when the serving
    engine arms it, its cumulative-score buffer rides the cache pytree —
    a ``"score"`` leaf inside the dense ``cache["attn"]`` or the paged
    ``cache["pool"]`` — and the scan simply carries it like every other
    cache leaf while the attention steps accumulate into it."""

    def step(carry, _):
        tok, cache = carry
        nxt, cache = decode_one(params, cfg, tok, cache, active=active,
                                paged_depth=paged_depth, sampling=sampling,
                                seeds=seeds, mesh=mesh)
        return (nxt, cache), nxt[:, 0]

    (last, cache), toks = jax.lax.scan(
        step, (token, cache), None, length=steps
    )
    return last, cache, jnp.moveaxis(toks, 0, 1)


def sample_decode(
    params: dict,
    cfg: ModelConfig,
    first_logits: jnp.ndarray,  # (B, V)
    cache: dict,
    steps: int,
    *,
    temperature: float = 0.0,
    top_k: int = 0,
    top_p: float = 1.0,
    key: Optional[jax.Array] = None,
) -> tuple[jnp.ndarray, dict]:
    """Temperature / top-k / top-p sampling (temperature 0 = greedy, the
    filters are then ignored).  Returns (tokens (B, steps), cache).
    ``filter_logits`` is the shared pure-jnp reference — disabled filters
    leave the temperature-only path bitwise unchanged."""
    if temperature <= 0.0:
        first = jnp.argmax(first_logits, -1)[:, None].astype(jnp.int32)
        return greedy_decode(params, cfg, first, cache, steps)
    assert key is not None
    keys = jax.random.split(key, steps)

    def pick(logits, k):
        x = filter_logits(logits / temperature, top_k=top_k, top_p=top_p)
        return jax.random.categorical(k, x)[:, None]

    def step(carry, k):
        tok, cache = carry
        logits, cache = tf.decode_step(params, cfg, tok, cache)
        nxt = pick(logits, k).astype(tok.dtype)
        return (nxt, cache), tok[:, 0]

    first = pick(first_logits, keys[0]).astype(jnp.int32)
    (last, cache), toks = jax.lax.scan(step, (first, cache), keys[1:])
    toks = jnp.concatenate([jnp.moveaxis(toks, 0, 1), last], axis=1)
    return toks, cache


def _draft_then_rescore(
    params: dict,
    cfg: ModelConfig,
    tokens: jnp.ndarray,  # (B, n_in)
    draft: jnp.ndarray,  # (B, draft_len)
    evict: EvictionConfig,
    extra_slots: int,
) -> EvictionResult:
    """Shared second half of LAQ/SpecKV: evict with draft rows as obs."""
    n_in = tokens.shape[1]
    xy = jnp.concatenate([tokens, draft.astype(tokens.dtype)], axis=1)
    # want_logits="last" with gt_boundary set returns row n_in-1's logits —
    # the target model's exact next-token distribution after X.
    return tf.prefill(
        params, cfg, xy, policy="gt_oracle", gt_boundary=n_in, evict=evict,
        extra_slots=extra_slots, want_logits="last",
    )


def run_eviction(
    policy: str,
    params: dict,
    cfg: ModelConfig,
    tokens: jnp.ndarray,  # (B, n_in) int tokens (or embeds for vlm)
    *,
    evict: EvictionConfig,
    lkv_params: Optional[dict] = None,
    draft_params: Optional[dict] = None,
    draft_cfg: Optional[ModelConfig] = None,
    extra_slots: int = 0,
    encoder_embeds: Optional[jnp.ndarray] = None,
    mrope_positions: Optional[jnp.ndarray] = None,
    prompt_lens: Optional[jnp.ndarray] = None,  # (B,) bucket-padded prefill
    seeds: Optional[jnp.ndarray] = None,  # (B,) per-request seeds (random)
) -> EvictionResult:
    """Prefill + evict under ``policy``; returns next-token logits and the
    budgeted decode cache."""
    kw = dict(encoder_embeds=encoder_embeds, mrope_positions=mrope_positions)
    if policy in SINGLE_PASS:
        res = tf.prefill(
            params, cfg, tokens, policy=policy, evict=evict,
            lkv_params=lkv_params if policy == "lookaheadkv" else None,
            extra_slots=extra_slots, prompt_lens=prompt_lens, seeds=seeds,
            **kw,
        )
        return EvictionResult(logits=res.logits, cache=res.cache)
    if prompt_lens is not None:
        raise ValueError(
            f"{policy} (multi-pass) cannot serve bucket-padded prompts; "
            "group its requests by exact length instead")

    if policy == "laq":
        # phase 1: cheap SnapKV eviction
        res1 = tf.prefill(params, cfg, tokens, policy="snapkv", evict=evict,
                          extra_slots=evict.draft_len + 1, **kw)
        # phase 2: draft with the compressed cache (the pseudo future)
        first = jnp.argmax(res1.logits, -1)[:, None].astype(jnp.int32)
        draft, _ = greedy_decode(params, cfg, first, res1.cache,
                                 evict.draft_len)
        # phase 3: re-evict with draft-row observation queries
        res3 = _draft_then_rescore(params, cfg, tokens, draft, evict,
                                   extra_slots)
        return EvictionResult(logits=res3.logits, cache=res3.cache)

    if policy == "speckv":
        assert draft_params is not None and draft_cfg is not None, \
            "speckv needs a draft model"
        dres = tf.prefill(draft_params, draft_cfg, tokens, policy="full",
                          extra_slots=evict.draft_len + 1, **kw)
        dfirst = jnp.argmax(dres.logits, -1)[:, None].astype(jnp.int32)
        draft, _ = greedy_decode(draft_params, draft_cfg, dfirst, dres.cache,
                                 evict.draft_len)
        res = _draft_then_rescore(params, cfg, tokens, draft, evict,
                                  extra_slots)
        return EvictionResult(logits=res.logits, cache=res.cache)

    raise ValueError(f"unknown policy {policy}; known: {ALL_POLICIES}")


def chunk_capacity_for(cfg: ModelConfig, policy: str, n_prompt: int,
                       chunk: int, *, n_obs: int = 0) -> int:
    """KV-buffer depth for a chunked prefill of ``n_prompt`` tokens: the
    prompt plus the policy's appended observation rows, rounded up to a
    whole number of chunks (the buffer is only ever written in chunk-sized
    or observation-sized blocks)."""
    if policy == "lookaheadkv":
        n_obs = cfg.lookahead.n_lookahead if cfg.lookahead else 0
    need = n_prompt + n_obs
    return -(-need // chunk) * chunk


def run_eviction_chunked(
    policy: str,
    params: dict,
    cfg: ModelConfig,
    tokens: jnp.ndarray,  # (B, n_in) int tokens (all rows same true length)
    *,
    chunk: int,
    evict: EvictionConfig,
    lkv_params: Optional[dict] = None,
    extra_slots: int = 0,
    gt_boundary: Optional[int] = None,  # gt_oracle: X|Y boundary in ``tokens``
    seeds: Optional[jnp.ndarray] = None,
    capacity: Optional[int] = None,
) -> EvictionResult:
    """Streamed prefill + evict: processes the prompt in fixed ``chunk``
    blocks with online score accumulation, then evicts once at prompt end —
    same kept cache and next-token logits as ``run_eviction`` for every
    single-pass policy (the serving engine drives the same two programs
    itself so it can interleave decode steps between chunks)."""
    assert policy in SINGLE_PASS, f"{policy} cannot stream (multi-pass)"
    n_tokens = tokens.shape[1]
    n = gt_boundary if gt_boundary is not None else n_tokens
    obs_tokens = tokens[:, n:] if gt_boundary is not None else None
    if capacity is None:
        capacity = chunk_capacity_for(cfg, policy, n, chunk,
                                      n_obs=n_tokens - n)
    state = tf.init_chunk_state(cfg, policy, tokens.shape[0], capacity)
    n_arr = jnp.asarray(n, jnp.int32)
    logits = None
    for s in range(0, n, chunk):
        blk = tokens[:, s:s + chunk]
        if blk.shape[1] < chunk:  # partial final chunk: pad rows are inert
            pad = chunk - blk.shape[1]
            blk = jnp.pad(blk, ((0, 0), (0, pad)))
        state, logits = tf.prefill_chunk(params, cfg, state, blk, n_arr,
                                         policy=policy)
    cache = tf.prefill_finalize(
        params, cfg, state, n_arr, policy=policy, evict=evict,
        lkv_params=lkv_params if policy == "lookaheadkv" else None,
        obs_tokens=obs_tokens, extra_slots=extra_slots, seeds=seeds,
    )
    return EvictionResult(logits=logits, cache=cache)
