"""Importance-score computation (paper §2, §3.1).

All scores flow through the same primitive — ``ops.lookahead_score`` — with
different observation queries:

    ground truth   : obs = the true response rows Y          (training target)
    lookaheadkv    : obs = the learned lookahead-token rows  (the paper)
    snapkv         : obs = the last ``window`` prompt rows
    tova           : obs = the last prompt row
    h2o            : obs = every prompt row (cumulative column mass)

Position-based policies (streaming_llm, random, full) don't need attention
at all and are handled in ``eviction.py``.

Score post-processing (paper's standard eviction configuration):
GQA mean-reduction over the query heads of each KV group, then 1-D max-pool
(kernel 7, same padding) along the key axis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops

# observation semantics per policy: how many trailing rows act as queries
OBS_POLICIES = ("lookaheadkv", "snapkv", "tova", "h2o", "gt")
POSITION_POLICIES = ("streaming_llm", "random", "full")


def observation_scores(
    q_obs: jnp.ndarray,  # (B, n_obs, H, hd)
    k_full: jnp.ndarray,  # (B, n_prompt + n_obs, KV, hd)
    n_prompt: int,
    *,
    window=None,
    kv_mask: jnp.ndarray | None = None,
    q_offset: int | None = None,
) -> jnp.ndarray:
    """Per-q-head scores (B, H, n_prompt), f32 — softmax rows include the
    observation keys (Algorithm 2 slices after the softmax)."""
    return ops.lookahead_score(
        q_obs, k_full, n_prompt, kv_mask=kv_mask, window=window,
        q_offset=q_offset,
    )


def gqa_reduce(scores: jnp.ndarray, num_kv_heads: int) -> jnp.ndarray:
    """(B, H, S) -> (B, KV, S): mean over each KV group's query heads
    (Ada-KV-style GQA compatibility, the paper's default)."""
    B, H, S = scores.shape
    group = H // num_kv_heads
    return scores.reshape(B, num_kv_heads, group, S).mean(axis=2)


def maxpool1d(scores: jnp.ndarray, kernel: int) -> jnp.ndarray:
    """Max-pool along the last axis with 'same' padding (paper kernel=7).

    Clustering effect: keeping a token pulls its neighbourhood along, which
    preserves local context around high-attention spikes.
    """
    if kernel <= 1:
        return scores
    pad = kernel // 2
    x = jnp.pad(scores, [(0, 0)] * (scores.ndim - 1) + [(pad, pad)],
                constant_values=-jnp.inf)
    windows = [x[..., i : i + scores.shape[-1]] for i in range(kernel)]
    return jnp.stack(windows, axis=0).max(axis=0)


def normalize_l1(scores: jnp.ndarray, eps: float = 1e-9) -> jnp.ndarray:
    """L1 normalization ŝ = s / ||s||₁ over the key axis (paper eq. (4))."""
    return scores / jnp.maximum(
        jnp.sum(jnp.abs(scores), axis=-1, keepdims=True), eps
    )


def postprocess(
    scores_per_qhead: jnp.ndarray,  # (B, H, S)
    num_kv_heads: int,
    pool_kernel: int,
) -> jnp.ndarray:
    """Eviction-time pipeline: GQA-reduce then max-pool.  (B, KV, S)."""
    s = gqa_reduce(scores_per_qhead, num_kv_heads)
    return maxpool1d(s, pool_kernel)
