"""Importance-score computation (paper §2, §3.1).

All scores flow through the same primitive — ``ops.lookahead_score`` — with
different observation queries:

    ground truth   : obs = the true response rows Y          (training target)
    lookaheadkv    : obs = the learned lookahead-token rows  (the paper)
    snapkv         : obs = the last ``window`` prompt rows
    tova           : obs = the last prompt row
    h2o            : obs = every prompt row (cumulative column mass)

Position-based policies (streaming_llm, random, full) don't need attention
at all and are handled in ``eviction.py``.

Score post-processing (paper's standard eviction configuration):
GQA mean-reduction over the query heads of each KV group, then 1-D max-pool
(kernel 7, same padding) along the key axis.

Streaming (chunked-prefill) scoring
-----------------------------------
``ScoreState`` reformulates every single-pass policy's importance score as
an *online* quantity over prompt chunks (KVpop-style predictive online
pruning), so prefill can stream fixed-size chunks and still evict exactly
like a monolithic pass:

* **cumulative** (h2o): each chunk adds its queries' softmax column masses
  into a running per-key accumulator — a commutative sum, so the final
  scores are chunk-split-invariant.  The per-chunk masses are a *fused
  second output* of the streaming attention pass
  (``ops.chunk_attention(..., score_masses=True)``): the kernel emits them
  tile-by-tile inside its online-softmax recurrence, so no dense (C, K)
  probability block ever materializes on the prefill hot path.
* **observation-window** (snapkv, pyramidkv, tova): only the last
  ``window`` prompt queries matter (1 for tova), so the state is a rolling
  buffer of the newest ``window`` rotary-position-encoded queries; scoring
  defers to the final chunk, where the masked streaming primitive
  ``ops.lookahead_score`` (traced observation base, sliding-window mask)
  scores them over the materialized buffer.
* **final-observation** (lookaheadkv, gt_oracle): the observation rows are
  appended *after* the prompt (learned lookahead rows / the GT response),
  so nothing accumulates during prompt chunks — the observation pass runs
  once at prompt end over the fully materialized key buffer, through the
  same streaming primitive.

The dense (C, K) reference for all of this lives in
``kernels/ref.py::chunk_column_masses`` (test oracle + small-shape direct
path of the ops dispatch).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.ref import NEG_INF

# observation semantics per policy: how many trailing rows act as queries
OBS_POLICIES = ("lookaheadkv", "snapkv", "tova", "h2o", "gt")
POSITION_POLICIES = ("streaming_llm", "random", "full")

# streaming-prefill classification (see module docstring)
STREAMING_CUMULATIVE = ("h2o",)
STREAMING_WINDOW = ("snapkv", "pyramidkv", "tova")
FINAL_OBS = ("lookaheadkv", "gt_oracle")


def observation_scores(
    q_obs: jnp.ndarray,  # (B, n_obs, H, hd)
    k_full: jnp.ndarray,  # (B, n_prompt + n_obs, KV, hd)
    n_prompt: int,
    *,
    window=None,
    kv_mask: jnp.ndarray | None = None,
    q_offset: int | None = None,
) -> jnp.ndarray:
    """Per-q-head scores (B, H, n_prompt), f32 — softmax rows include the
    observation keys (Algorithm 2 slices after the softmax)."""
    return ops.lookahead_score(
        q_obs, k_full, n_prompt, kv_mask=kv_mask, window=window,
        q_offset=q_offset,
    )


def gqa_reduce(scores: jnp.ndarray, num_kv_heads: int) -> jnp.ndarray:
    """(B, H, S) -> (B, KV, S): mean over each KV group's query heads
    (Ada-KV-style GQA compatibility, the paper's default)."""
    B, H, S = scores.shape
    group = H // num_kv_heads
    return scores.reshape(B, num_kv_heads, group, S).mean(axis=2)


def decode_mass_update(
    masses: jnp.ndarray,  # (B, H, D) decode token's normalized softmax masses
    num_kv_heads: int,
    active: Optional[jnp.ndarray] = None,  # (B,) live-slot mask
) -> jnp.ndarray:
    """One decode step's increment to the cumulative (H2O) decode-eviction
    scores: (B, D, KV) f32.

    The paged decode kernel's fused mass output is per *query* head; the
    dense decode-eviction reference (``decode_attention_step_evicting``)
    accumulates ``softmax(...).mean(axis=group)`` per kv head — so the
    increment is the GQA mean transposed into the cache's (row, kv-head)
    layout.  Masked rows arrive as exact zeros from every kernel tier, and
    inactive slots (a zombie decode between requests) are zeroed here so
    their scores stay untouched, mirroring the engine's cursor gating."""
    add = jnp.moveaxis(gqa_reduce(masses, num_kv_heads), 1, 2)  # (B, D, KV)
    if active is not None:
        add = jnp.where(active[:, None, None], add, 0.0)
    return add


def maxpool1d(scores: jnp.ndarray, kernel: int) -> jnp.ndarray:
    """Max-pool along the last axis with 'same' padding (paper kernel=7).

    Clustering effect: keeping a token pulls its neighbourhood along, which
    preserves local context around high-attention spikes.
    """
    if kernel <= 1:
        return scores
    pad = kernel // 2
    x = jnp.pad(scores, [(0, 0)] * (scores.ndim - 1) + [(pad, pad)],
                constant_values=-jnp.inf)
    windows = [x[..., i : i + scores.shape[-1]] for i in range(kernel)]
    return jnp.stack(windows, axis=0).max(axis=0)


def normalize_l1(scores: jnp.ndarray, eps: float = 1e-9) -> jnp.ndarray:
    """L1 normalization ŝ = s / ||s||₁ over the key axis (paper eq. (4))."""
    return scores / jnp.maximum(
        jnp.sum(jnp.abs(scores), axis=-1, keepdims=True), eps
    )


def postprocess(
    scores_per_qhead: jnp.ndarray,  # (B, H, S)
    num_kv_heads: int,
    pool_kernel: int,
) -> jnp.ndarray:
    """Eviction-time pipeline: GQA-reduce then max-pool.  (B, KV, S)."""
    s = gqa_reduce(scores_per_qhead, num_kv_heads)
    return maxpool1d(s, pool_kernel)


# ---------------------------------------------------------------------------
# Streaming scores for chunked prefill
# ---------------------------------------------------------------------------


class ScoreState(NamedTuple):
    """Per-policy streaming score accumulator, threaded across prefill chunks.

    Leaves carry a leading layer axis L (the transformer layer scan slices
    it per layer).  Fields are ``None`` for policies that don't need them —
    the pytree structure is static per compiled (chunk, policy) program.

    ``snapshot``/``restore`` make the state prefix-cacheable: at a chunk
    boundary ``n`` (with every streamed chunk full and the true prompt
    length >= ``n``) the state is a pure function of the first ``n`` prompt
    tokens — chunk updates never read the rows past the boundary, the
    cumulative accumulator is exactly zero there (masked softmax columns
    underflow to +0.0), and per-request randomness (``Request.seed``)
    enters only at finalize (``eviction.position_scores`` folds it in), so
    a snapshot is bit-identical across all requests sharing the prefix.
    """

    acc: Optional[jnp.ndarray] = None   # (L, B, H, K) f32 column-mass sums
    cnt: Optional[jnp.ndarray] = None   # ()  f32 scoring queries seen so far
    qbuf: Optional[jnp.ndarray] = None  # (L, B, W, H, hd) newest W rot. queries

    def snapshot(self, n: int) -> "ScoreState":
        """Capacity-independent snapshot at chunk boundary ``n``: the
        accumulator keeps only its first ``n`` key columns (columns past a
        boundary are exact +0.0 — no query has attended to them), the
        rolling query window and count are boundary state already."""
        if self.acc is None:
            return self
        return self._replace(acc=self.acc[..., :n])

    def restore(self, capacity: int) -> "ScoreState":
        """Re-inflate a snapshot for a ``capacity``-deep key buffer.  The
        zero right-pad reproduces the untouched tail of a freshly streamed
        accumulator bitwise (0.0 + 0.0 stays +0.0 under later adds)."""
        if self.acc is None:
            return self
        pad = capacity - self.acc.shape[-1]
        assert pad >= 0, \
            f"snapshot wider ({self.acc.shape[-1]}) than capacity {capacity}"
        width = [(0, 0)] * (self.acc.ndim - 1) + [(0, pad)]
        return self._replace(acc=jnp.pad(self.acc, width))


def stream_window(policy: str, window_size: int) -> int:
    """Observation-window width a streaming-window policy defers on."""
    return 1 if policy == "tova" else window_size


def init_score_state(
    policy: str,
    num_layers: int,
    batch: int,
    num_heads: int,
    head_dim: int,
    capacity: int,  # key-buffer depth K
    *,
    window_size: int = 32,
    dtype=jnp.float32,
) -> ScoreState:
    """Zero state sized for ``capacity`` buffered keys (policy-shaped)."""
    if policy in STREAMING_CUMULATIVE:
        return ScoreState(
            acc=jnp.zeros((num_layers, batch, num_heads, capacity),
                          jnp.float32),
            cnt=jnp.zeros((), jnp.float32),
        )
    if policy in STREAMING_WINDOW:
        w = stream_window(policy, window_size)
        return ScoreState(
            qbuf=jnp.zeros((num_layers, batch, w, num_heads, head_dim),
                           dtype),
        )
    return ScoreState()  # final-observation and position policies


def update_layer_scores(
    policy: str,
    acc_l: Optional[jnp.ndarray],   # (B, H, K) this layer's accumulator
    qbuf_l: Optional[jnp.ndarray],  # (B, W, H, hd) this layer's query window
    q_rot: jnp.ndarray,  # (B, C, H, hd) the chunk's rotary-encoded queries
    *,
    masses_l: Optional[jnp.ndarray] = None,  # (B, H, K) fused kernel partials
    q_offset: jnp.ndarray,  # scalar int32 chunk start
    n_total: jnp.ndarray,  # scalar int32 true prompt length
) -> tuple[Optional[jnp.ndarray], Optional[jnp.ndarray]]:
    """One chunk's streaming update for one layer; returns (acc', qbuf').

    Cumulative (h2o) policies consume ``masses_l`` — the summed softmax
    column masses of the chunk's valid rows, emitted by the attention
    kernel itself (``ops.chunk_attention(..., score_masses=True)``) — so
    the update is a plain accumulator add; no score matrix is recomputed
    or materialized here."""
    C = q_rot.shape[1]
    if policy in STREAMING_CUMULATIVE:
        assert masses_l is not None, \
            f"{policy} needs the attention kernel's fused mass output"
        return acc_l + masses_l, qbuf_l
    if policy in STREAMING_WINDOW:
        # roll the newest W *valid* rows in: global rows [total-W, total)
        # where total = min(n_total, chunk end).  Early chunks shorter than
        # W leave stale low slots that later chunks displace before any read.
        W = qbuf_l.shape[1]
        total = jnp.minimum(n_total, q_offset + C)
        joined = jnp.concatenate([qbuf_l, q_rot], axis=1)  # (B, W + C, H, hd)
        start = jnp.clip(total - q_offset, 0, C)  # joined idx of row total-W
        qbuf_l = jax.lax.dynamic_slice_in_dim(joined, start, W, axis=1)
        return acc_l, qbuf_l
    return acc_l, qbuf_l


def finalize_layer_scores(
    policy: str,
    k_buf: jnp.ndarray,  # (B, K, KV, hd)
    n_total: jnp.ndarray,  # scalar int32 true prompt length
    *,
    acc_l: Optional[jnp.ndarray] = None,
    cnt: Optional[jnp.ndarray] = None,
    qbuf_l: Optional[jnp.ndarray] = None,
    obs_masses_l: Optional[jnp.ndarray] = None,  # (B, H, K) mean obs masses
    num_kv_heads: int,
    pool_kernel: int,
    window_size: int = 32,
    window=None,
    smesh=None,  # model_shard_mesh-vetted mesh: per-shard head scoring
) -> jnp.ndarray:
    """Eviction-ready scores (B, KV, K) at prompt end, mirroring the
    monolithic pipeline exactly: GQA-reduce, max-pool over the *scored*
    region only (columns past the policy's boundary are -inf, matching the
    monolithic maxpool's edge padding), then the snapkv-family force-keep
    boost, then the valid-key mask.  Columns >= ``n_total`` rank last and
    are additionally masked out of the cache by ``evict_layer``'s
    ``key_mask``."""
    B, K, KV, _ = k_buf.shape
    col = jnp.arange(K)
    if policy in STREAMING_CUMULATIVE:
        s_qh = acc_l / jnp.maximum(cnt, 1.0)
        boundary = n_total
    elif policy in STREAMING_WINDOW:
        W = stream_window(policy, window_size)
        boundary = n_total - W
        # the masked streaming primitive scores the rolled window queries
        # over the whole buffer (traced observation base ``boundary``);
        # mean over the W rows == the monolithic sum / W
        from repro.models.attention import sharded_lookahead_score

        s_qh = sharded_lookahead_score(
            qbuf_l, k_buf, K, q_offset=boundary, window=window, smesh=smesh,
        )
    else:  # final-observation policies
        assert obs_masses_l is not None, f"{policy} needs an observation pass"
        s_qh = obs_masses_l
        boundary = n_total
    s_kv = gqa_reduce(s_qh, num_kv_heads)
    s_kv = jnp.where(col[None, None, :] < boundary, s_kv, -jnp.inf)
    s_kv = maxpool1d(s_kv, pool_kernel)
    if policy in STREAMING_WINDOW:
        # monolithic path: scores past the boundary are zero-padded, then the
        # observation window is force-kept — exactly 1e9 per window column
        in_window = (col[None, None, :] >= boundary) & \
            (col[None, None, :] < n_total)
        s_kv = jnp.where(in_window, 1e9, s_kv)
    return jnp.where(col[None, None, :] < n_total, s_kv, NEG_INF)
