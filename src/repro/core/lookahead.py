"""Lookahead parameters: learnable lookahead tokens + selective LoRA tree.

The LoRA tree *mirrors the model parameter tree*: for every stacked linear
weight ``(L, d_in, d_out)`` whose leaf name is in ``cfg.lookahead.lora_targets``
we create ``{"a": (L, d_in, r) f32, "b": (L, r, d_out) f32}``.  Mirroring
means the per-layer LoRA slice can ride the same ``lax.scan`` xs as the layer
params, and module code can look adapters up by the weight's own name.

Routed-expert weights are (L, E, d, f) — 4-D — and are therefore naturally
excluded (the paper only adapts dense linears; for MoE archs the config
restricts targets to attention + shared experts anyway).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models.layers import lora_init


def lora_scale(cfg: ModelConfig) -> float:
    lk = cfg.lookahead
    return lk.lora_alpha / lk.lora_rank


def init_lookahead_params(key, cfg: ModelConfig, layer_params: dict) -> dict:
    """Build {"emb": (n_lookahead, D), "lora": mirrored tree}.

    ``layer_params`` is the model's *stacked* per-layer tree (leaves have a
    leading L axis).
    """
    lk = cfg.lookahead
    k_emb, k_lora = jax.random.split(key)
    emb = jax.random.normal(
        k_emb, (lk.n_lookahead, cfg.d_model), jnp.float32
    ) * 0.02

    leaves_with_paths = jax.tree_util.tree_flatten_with_path(layer_params)[0]
    keys = jax.random.split(k_lora, max(len(leaves_with_paths), 1))

    def build(path, leaf, k):
        name = None
        for p in reversed(path):
            if hasattr(p, "key"):
                name = str(p.key)
                break
        if name in lk.lora_targets and leaf.ndim == 3:
            L, d_in, d_out = leaf.shape
            ks = jax.random.split(k, L)
            return jax.vmap(
                lambda kk: lora_init(kk, d_in, d_out, lk.lora_rank)
            )(ks)
        return None

    lora_tree: Any = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(layer_params),
        [build(p, l, k) for (p, l), k in zip(leaves_with_paths, keys)],
    )
    lora_tree = _prune_none(lora_tree)
    return {"emb": emb, "lora": lora_tree}


def _prune_none(tree):
    if isinstance(tree, dict):
        out = {k: _prune_none(v) for k, v in tree.items()}
        return {k: v for k, v in out.items() if v is not None} or None
    return tree


def lookahead_count(lkv_params: dict) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(lkv_params))


def load_lookahead_params(path: str, cfg: ModelConfig,
                          layer_params: dict) -> dict:
    """Load trained lookahead modules from a checkpoint.

    Accepts both layouts ``launch/train.py`` writes: a bare lkv tree
    (the final export) and the trainer-state layout
    ``{"lkv": tree, "opt": AdamState}`` (a periodic ``--ckpt-every``
    save), so serving can load either."""
    from repro.checkpoint import io as ckpt

    like = init_lookahead_params(jax.random.PRNGKey(0), cfg, layer_params)
    flat = ckpt.load(path)
    if any(k.startswith("lkv/") for k in flat):
        flat = {k[len("lkv/"):]: v
                for k, v in flat.items() if k.startswith("lkv/")}
    return ckpt.unflatten(flat, like)


def append_lookahead(
    h: jnp.ndarray,  # (B, S, D) embedded prompt
    lkv_params: dict,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Concat the learned lookahead rows; returns (h', lookahead_mask (B,S',1))."""
    B, S, D = h.shape
    emb = lkv_params["emb"].astype(h.dtype)  # (n, D)
    n = emb.shape[0]
    h2 = jnp.concatenate([h, jnp.broadcast_to(emb[None], (B, n, D))], axis=1)
    mask = jnp.concatenate(
        [jnp.zeros((B, S, 1), h.dtype), jnp.ones((B, n, 1), h.dtype)], axis=1
    )
    return h2, mask
