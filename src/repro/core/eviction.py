"""Eviction: Top-K selection + per-KV-head gather into a budgeted cache.

The per-layer entry point is ``evict_layer`` — called from inside the
prefill layer scan with that layer's (q, k, v) and the policy's scores.
Shapes are static: every layer emits a cache of ``capacity`` slots; a
validity mask implements per-layer budgets (PyramidKV) and padding.

Position-based policies (StreamingLLM sink+recent, random, full) are
expressed as synthetic score vectors so that one TopK path serves all
policies — this also makes the "budget is always respected" property test
uniform across policies.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class EvictedKV(NamedTuple):
    k: jnp.ndarray  # (B, capacity, KV, hd)
    v: jnp.ndarray  # (B, capacity, KV, hd)
    pos: jnp.ndarray  # (B, capacity, KV) original token positions, int32
    mask: jnp.ndarray  # (B, capacity, KV) slot validity


def position_scores(
    policy: str,
    n_prompt: int,
    batch: int,
    num_kv_heads: int,
    *,
    sink: int = 4,
    budget: int = 0,
    seed: int = 0,
    seeds: Optional[jnp.ndarray] = None,  # (B,) per-request seeds, int32
) -> jnp.ndarray:
    """Synthetic (B, KV, n_prompt) scores for attention-free policies.

    ``seeds`` decorrelates the ``random`` policy across requests: row ``b``
    draws from ``fold_in(PRNGKey(seed), seeds[b])``.  Every ``random`` draw
    is additionally folded per *position*, so the value at position p is
    independent of the score-vector length — chunked prefill (which scores
    over its full buffer depth) and monolithic prefill (which scores over
    exactly ``n_prompt`` columns) agree on every shared position, seeded or
    not.  Without ``seeds`` every row of every batch shares one
    ``PRNGKey(seed)`` stream — fine for single-request experiments, but a
    batch of requests would all evict the *same* "random" positions.
    """
    pos = jnp.arange(n_prompt, dtype=jnp.float32)
    if policy == "streaming_llm":
        recent = pos  # larger position => more recent => higher
        sink_boost = jnp.where(pos < sink, 1e9, 0.0)
        s = recent + sink_boost
    elif policy == "full":
        s = jnp.full((n_prompt,), 1.0)
    elif policy == "random":
        base = jax.random.PRNGKey(seed)

        def row(kr):
            return jax.vmap(
                lambda p: jax.random.uniform(jax.random.fold_in(kr, p))
            )(jnp.arange(n_prompt))

        if seeds is not None:
            sb = jax.vmap(
                lambda rs: row(jax.random.fold_in(base, rs))
            )(seeds.astype(jnp.uint32))  # (B, n_prompt)
            return jnp.broadcast_to(
                sb[:, None, :], (batch, num_kv_heads, n_prompt))
        s = row(base)
    else:
        raise ValueError(f"not a position policy: {policy}")
    return jnp.broadcast_to(s[None, None, :], (batch, num_kv_heads, n_prompt))


def keep_window(scores: jnp.ndarray, window: int) -> jnp.ndarray:
    """Force-keep the last ``window`` prompt tokens (SnapKV convention)."""
    n = scores.shape[-1]
    boost = jnp.where(jnp.arange(n) >= n - window, 1e9, 0.0)
    return scores + boost[None, None, :]


def select_topk(
    scores: jnp.ndarray,  # (B, KV, n_prompt) post-processed scores
    capacity: int,
    *,
    layer_budget: Optional[jnp.ndarray] = None,  # traced scalar <= capacity
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Top-``capacity`` indices per (batch, kv head), sorted by position.

    Returns (idx (B, KV, capacity) int32, mask (B, KV, capacity) bool).
    ``layer_budget`` (PyramidKV) invalidates slots beyond the layer's budget
    while keeping shapes static for the layer scan.
    """
    n = scores.shape[-1]
    cap = min(capacity, n)
    _, idx = jax.lax.top_k(scores, cap)  # (B, KV, cap) by score desc
    mask = jnp.ones(idx.shape, bool)
    if layer_budget is not None:
        mask &= jnp.arange(cap)[None, None, :] < layer_budget
    if cap < capacity:  # pad static shape
        pad = capacity - cap
        idx = jnp.pad(idx, ((0, 0), (0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, 0), (0, pad)))
    # restore temporal order (keeps positional structure of the cache)
    order = jnp.argsort(jnp.where(mask, idx, jnp.iinfo(jnp.int32).max), axis=-1)
    idx = jnp.take_along_axis(idx, order, axis=-1)
    mask = jnp.take_along_axis(mask, order, axis=-1)
    return idx.astype(jnp.int32), mask


def gather_kv(
    k: jnp.ndarray,  # (B, S, KV, hd)
    v: jnp.ndarray,
    idx: jnp.ndarray,  # (B, KV, capacity)
    mask: jnp.ndarray,  # (B, KV, capacity)
) -> EvictedKV:
    """Per-kv-head gather of the retained slots."""
    B, S, KV, hd = k.shape
    cap = idx.shape[-1]
    ik = jnp.swapaxes(idx, 1, 2)[..., None]  # (B, cap, KV, 1)
    kk = jnp.take_along_axis(k, jnp.broadcast_to(ik, (B, cap, KV, hd)), axis=1)
    vv = jnp.take_along_axis(v, jnp.broadcast_to(ik, (B, cap, KV, hd)), axis=1)
    pos = jnp.swapaxes(idx, 1, 2)  # (B, cap, KV)
    m = jnp.swapaxes(mask, 1, 2)
    kk = jnp.where(m[..., None], kk, 0)
    vv = jnp.where(m[..., None], vv, 0)
    return EvictedKV(k=kk, v=vv, pos=pos, mask=m)


def evict_layer(
    scores: jnp.ndarray,  # (B, KV, n_prompt)
    k: jnp.ndarray,  # (B, n_prompt, KV, hd) prompt keys only
    v: jnp.ndarray,
    capacity: int,
    *,
    layer_budget: Optional[jnp.ndarray] = None,
    head_budgets: Optional[jnp.ndarray] = None,  # (B, KV) Ada-KV allocation
    extra_slots: int = 0,
    key_mask: Optional[jnp.ndarray] = None,  # (B, n_prompt) valid prompt keys
) -> EvictedKV:
    """Evict one layer's prompt KV down to ``capacity`` kept slots, with
    ``extra_slots`` empty tail capacity for subsequent decode appends.

    ``key_mask`` marks which prompt keys are real (bucketed serving pads
    prompts to a common length): padded keys may still be *selected* when
    capacity exceeds the true prompt length, but their cache slots come out
    masked invalid, so decode never attends to them.
    """
    if head_budgets is not None:
        idx, mask = select_topk_per_head(scores, capacity, head_budgets)
    else:
        idx, mask = select_topk(scores, capacity, layer_budget=layer_budget)
    if key_mask is not None:
        B, KV, cap = idx.shape
        valid = jnp.broadcast_to(key_mask[:, None, :], (B, KV, key_mask.shape[-1]))
        mask &= jnp.take_along_axis(valid, idx, axis=-1)
    ev = gather_kv(k, v, idx, mask)
    if extra_slots:
        B, _, KV, hd = k.shape

        def padkv(x):
            return jnp.pad(x, ((0, 0), (0, extra_slots), (0, 0), (0, 0)))

        ev = EvictedKV(
            k=padkv(ev.k),
            v=padkv(ev.v),
            pos=jnp.pad(ev.pos, ((0, 0), (0, extra_slots), (0, 0))),
            mask=jnp.pad(ev.mask, ((0, 0), (0, extra_slots), (0, 0))),
        )
    return ev


def pyramid_budgets(num_layers: int, budget: int, beta: float) -> jnp.ndarray:
    """PyramidKV-style funnel: linearly decaying per-layer budgets whose mean
    equals ``budget``.  First layer gets ~2β/(β+1)× budget, last ~2/(β+1)×."""
    hi = 2.0 * beta / (beta + 1.0) * budget
    lo = 2.0 / (beta + 1.0) * budget
    b = jnp.linspace(hi, lo, num_layers)
    return jnp.maximum(b.astype(jnp.int32), 1)


def uniform_budgets(num_layers: int, budget: int) -> jnp.ndarray:
    return jnp.full((num_layers,), budget, jnp.int32)


def adaptive_head_budgets(
    scores: jnp.ndarray,  # (B, KV, n) post-processed scores
    total_budget: int,  # per-head budget × KV = the global pool
    capacity: int,  # static per-head slot count (>= any allocated budget)
    *,
    floor: int = 4,
) -> jnp.ndarray:
    """Ada-KV-style adaptive budget allocation (Feng et al. 2024 — cited by
    the paper as an orthogonal improvement; implemented here as a composable
    policy axis).

    Instead of giving every kv head the same budget, distribute the global
    pool ``KV · total_budget`` in proportion to each head's top-score mass —
    flat heads (mass spread thin) give slots to spiky heads (mass
    concentrated on few keys), subject to a per-head floor and the static
    ``capacity`` ceiling.  Returns int32 budgets (B, KV) summing to
    ≈ KV · total_budget.
    """
    B, KV, n = scores.shape
    pool = KV * total_budget
    k = min(total_budget, n)
    top_mass, _ = jax.lax.top_k(scores, k)  # (B, KV, k)
    mass = top_mass.sum(-1)
    frac = mass / jnp.maximum(mass.sum(axis=1, keepdims=True), 1e-9)
    raw = frac * pool
    b = jnp.clip(raw.astype(jnp.int32), floor, capacity)
    # water-filling: mass stranded by the floor/ceiling clips redistributes
    # equally among heads that still have room (3 rounds suffice for KV<=64)
    for _ in range(3):
        deficit = jnp.maximum(pool - b.sum(axis=1, keepdims=True), 0)
        room = capacity - b
        nroom = jnp.maximum((room > 0).sum(axis=1, keepdims=True), 1)
        give = jnp.minimum(room, deficit // nroom)
        b = b + give
    # final ±1 remainder onto the highest-mass heads with room
    leftover = jnp.maximum(pool - b.sum(axis=1, keepdims=True), 0)
    order = jnp.argsort(-jnp.where(b < capacity, raw, -jnp.inf), axis=1)
    bonus = (jnp.arange(KV)[None, :] < leftover).astype(jnp.int32)
    bonus = jnp.take_along_axis(bonus, jnp.argsort(order, axis=1), axis=1)
    return jnp.clip(b + bonus, floor, capacity)


def select_topk_per_head(
    scores: jnp.ndarray,  # (B, KV, n)
    capacity: int,
    head_budgets: jnp.ndarray,  # (B, KV) int32, <= capacity
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Top-``capacity`` slots with per-(batch, head) *budget* masks — the
    adaptive-allocation companion to ``select_topk`` (same static shapes)."""
    n = scores.shape[-1]
    cap = min(capacity, n)
    _, idx = jax.lax.top_k(scores, cap)
    mask = jnp.arange(cap)[None, None, :] < head_budgets[..., None]
    if cap < capacity:
        pad = capacity - cap
        idx = jnp.pad(idx, ((0, 0), (0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, 0), (0, pad)))
    order = jnp.argsort(jnp.where(mask, idx, jnp.iinfo(jnp.int32).max), axis=-1)
    idx = jnp.take_along_axis(idx, order, axis=-1)
    mask = jnp.take_along_axis(mask, order, axis=-1)
    return idx.astype(jnp.int32), mask
