import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# Sweep driver: every assigned (arch × shape) on one mesh kind.
#   PYTHONPATH=src python -m repro.launch.dryrun_all --mesh pod

import argparse
import json
import time
import traceback


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--archs", default="")
    ap.add_argument("--shapes", default="")
    args = ap.parse_args()

    from repro.common.config import INPUT_SHAPES
    from repro.configs import ARCH_IDS
    from repro.launch.dryrun import run_one

    archs = args.archs.split(",") if args.archs else list(ARCH_IDS)
    shapes = args.shapes.split(",") if args.shapes else list(INPUT_SHAPES)
    t0 = time.time()
    failures = []
    for arch in archs:
        for shape in shapes:
            try:
                run_one(arch, shape, args.mesh, args.out)
            except Exception as e:
                failures.append((arch, shape, repr(e)))
                traceback.print_exc(limit=4)
                res = {"arch": arch, "shape": shape, "mesh": args.mesh,
                       "status": "error", "error": repr(e)}
                os.makedirs(args.out, exist_ok=True)
                with open(os.path.join(
                        args.out, f"{arch}_{shape}_{args.mesh}.json"),
                        "w") as f:
                    json.dump(res, f, indent=2)
    print(f"[dryrun_all] {args.mesh}: done in {time.time()-t0:.0f}s; "
          f"{len(failures)} failures")
    for f in failures:
        print("  FAIL", f)


if __name__ == "__main__":
    main()
