"""Step functions the launcher jits: one per (arch kind × shape kind).

* ``train``   — the paper's module training (GT pass + lookahead pass + KL +
                Adam on lookahead params) for technique archs; plain LM loss +
                Adam on everything for the attention-free SSM arch.
* ``prefill`` — serving prefill with in-scan eviction (the technique's
                inference path); plain forward + state cache for SSM.
* ``decode``  — one token against a seq_len cache (``serve_step``).

Every builder returns (fn, abstract_inputs_fn) so the dry-run can lower the
exact callable with exact ShapeDtypeStructs.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.common.config import EvictionConfig, ModelConfig, ShapeConfig, TrainConfig
from repro.core import objective
from repro.models import transformer as tf
from repro.optim import adam

# Response length for training shapes (paper: max generation length 512).
TRAIN_N_OUT = 512
# Serving eviction budget for prefill shapes (paper evaluates 64..2048).
PREFILL_BUDGET = 2048
DECODE_MARGIN = 128


def train_batch_shapes(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    n_out = min(TRAIN_N_OUT, S // 8)
    n_in = S - n_out
    d = {"n_in": n_in, "n_out": n_out}
    if cfg.embeds_in:
        d["x"] = jax.ShapeDtypeStruct((B, n_in, cfg.d_model), jnp.bfloat16)
        d["y"] = jax.ShapeDtypeStruct((B, n_out), jnp.int32)
        d["mrope"] = jax.ShapeDtypeStruct((3, B, n_in), jnp.int32)
    else:
        d["x"] = jax.ShapeDtypeStruct((B, n_in), jnp.int32)
        d["xy"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.is_encoder_decoder:
        d["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder.num_frames, cfg.d_model), jnp.bfloat16)
    return d


def make_train_step(cfg: ModelConfig, tc: TrainConfig):
    """(params, lkv, opt_state, batch) -> (lkv', opt_state', loss)  — or the
    LM variant (params, opt_state, tokens) for the SSM arch."""
    if not cfg.technique_applies:

        def lm_step(params, opt_state, batch):
            def loss_fn(p):
                return objective.lm_loss(p, cfg, batch["xy"])

            loss, grads = jax.value_and_grad(loss_fn)(params)
            params, opt_state, metrics = adam.update(params, grads, opt_state, tc)
            return params, opt_state, loss

        return lm_step

    def lkv_step(params, lkv, opt_state, batch):
        kw = {}
        if cfg.is_encoder_decoder:
            kw["encoder_embeds"] = batch["frames"]

        if cfg.embeds_in:
            # VLM: X arrives as patch embeddings; Y as generated tokens.
            x = batch["x"]
            y_emb = jnp.take(params["embed"], batch["y"], axis=0)
            xy = jnp.concatenate([x.astype(y_emb.dtype), y_emb], axis=1)
            n_in = x.shape[1]
            kw_gt = dict(kw, mrope_positions=None)

            def loss_fn(lkv):
                s_gt = objective.gt_scores(params, cfg, xy, n_in, **kw_gt)
                s_lkv = objective.lookahead_scores(
                    params, cfg, lkv, x, mrope_positions=batch.get("mrope"),
                    **kw)
                from repro.core.scoring import normalize_l1

                kl = objective.kl_divergence(
                    normalize_l1(s_gt), normalize_l1(s_lkv))
                return kl.mean()

        else:

            def loss_fn(lkv):
                loss, _ = objective.lkv_loss(
                    params, cfg, lkv, batch["x"], batch["xy"],
                    batch["x"].shape[1], **kw)
                return loss

        loss, grads = jax.value_and_grad(loss_fn)(lkv)
        lkv, opt_state, metrics = adam.update(lkv, grads, opt_state, tc)
        return lkv, opt_state, loss

    return lkv_step


def make_distill_step(cfg: ModelConfig, tc: TrainConfig):
    """(params, lkv, opt_state, batch) -> (lkv', opt_state', loss) against
    *harvested* gt targets: ``batch = {"x": (B, n), "s_gt": (L, B, H, n)}``
    (``data/harvest.py``).  Each step runs only the lookahead pass — the
    oracle pass was paid once at harvest time."""
    assert cfg.technique_applies, \
        "distillation trains lookahead modules; the SSM arch has none"

    def distill_step(params, lkv, opt_state, batch):
        def loss_fn(lkv):
            loss, _ = objective.lkv_loss_from_targets(
                params, cfg, lkv, batch["x"], batch["s_gt"])
            return loss

        loss, grads = jax.value_and_grad(loss_fn)(lkv)
        lkv, opt_state, metrics = adam.update(lkv, grads, opt_state, tc)
        return lkv, opt_state, loss

    return distill_step


def make_prefill_step(cfg: ModelConfig, shape: ShapeConfig,
                      budget: int = PREFILL_BUDGET):
    evict = EvictionConfig(policy="lookaheadkv", budget=min(budget, shape.seq_len // 4))

    if not cfg.technique_applies:

        def ssm_prefill(params, batch):
            res = tf.prefill(params, cfg, batch["tokens"],
                             want_ssm_cache=True)
            return res.logits, res.cache

        return ssm_prefill

    def prefill_step(params, lkv, batch):
        res = tf.prefill(
            params, cfg, batch["tokens"], lkv_params=lkv,
            policy="lookaheadkv", evict=evict, extra_slots=DECODE_MARGIN,
            encoder_embeds=batch.get("frames"),
            mrope_positions=batch.get("mrope"),
        )
        return res.logits, res.cache

    return prefill_step


def prefill_batch_shapes(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    d: dict = {}
    if cfg.embeds_in:
        d["tokens"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
        d["mrope"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
    else:
        d["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.is_encoder_decoder:
        d["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder.num_frames, cfg.d_model), jnp.bfloat16)
    return d


def make_decode_step(cfg: ModelConfig, mesh=None):
    def decode(params, token, cache):
        return tf.decode_step(params, cfg, token, cache, mesh=mesh)

    return decode


def decode_batch_shapes(cfg: ModelConfig, shape: ShapeConfig,
                        hot_slots: int = 0):
    """(token struct, cache struct tree) for a cache holding seq_len tokens.

    ``hot_slots`` > 0 selects the split-cache decode layout (§Perf): the
    seq_len prompt cache is frozen/read-only and appends go to a replicated
    hot ring buffer."""
    B, S = shape.global_batch, shape.seq_len
    token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    capacity = S if cfg.uses_attention else 0
    cache = jax.eval_shape(
        functools.partial(tf.init_decode_cache, cfg, B, capacity,
                          fill_len=capacity, hot_slots=hot_slots)
        if hot_slots else
        functools.partial(tf.init_decode_cache, cfg, B, capacity,
                          fill_len=max(S - 1, 0))
    )
    return token, cache
