"""Production serving launcher: prefill+evict+decode under a mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
        --policy lookaheadkv --budget 16 --requests 4

Loads lookahead modules from --lkv-ckpt when given (else random init — fine
for plumbing checks; quality requires training, see launch/train.py).
"""

from __future__ import annotations

import argparse
import time
import warnings

import jax
import numpy as np

from repro.common.config import EvictionConfig
from repro.configs import get_config, get_smoke_config
from repro.core import policies
from repro.core.lookahead import (init_lookahead_params,
                                  load_lookahead_params)
from repro.models import transformer as tf
from repro.obs import TraceRecorder, phase_table
from repro.serving import (BucketedEngine, ChunkingConfig, ContinuousEngine,
                           DecodeEvictionConfig, KVBlockPool, PrefixCache,
                           Request, ServingConfig, ServingEngine)


def _print_phase_table(trace, done) -> None:
    """Per-request phase-latency breakdown from the span trace — where
    each request's TTFT actually went, instead of a flat stats dump."""
    rows = phase_table(trace, [r.uid for r in done])
    print(f"{'uid':>4s} {'pfx_skip':>8s} {'prefill_ms':>10s} "
          f"{'first_tok_ms':>12s} {'decode_ms':>9s} {'sweeps':>6s} "
          f"{'sweep_ms':>8s} {'replays':>7s} {'outcome':>9s}")
    for row in rows:
        if row["outcome"] == "missing":
            print(f"{row['uid']:4d} {'never admitted':>14s}")
            continue
        ft = (f"{row['first_token_ms']:12.1f}"
              if row["first_token_ms"] is not None else f"{'n/a':>12s}")
        print(f"{row['uid']:4d} {row['prefix_skip_tokens']:8d} "
              f"{row['prefill_ms']:10.1f} {ft} {row['decode_ms']:9.1f} "
              f"{row['sweeps']:6d} {row['sweep_ms']:8.1f} "
              f"{row['replays']:7d} {row['outcome']:>9s}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--policy", default="lookaheadkv")
    ap.add_argument("--budget", type=int, default=16)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--n-in", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--lkv-ckpt", default="")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--continuous", action="store_true",
                    help="serve mixed-length traffic through the "
                         "continuous-batching engine")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--chunk", type=int, default=32,
                    help="prefill chunk size (continuous engine)")
    ap.add_argument("--prefix-cache-mb", type=int, default=0,
                    help="radix-trie prompt-cache budget in MB (continuous "
                         "engine; 0 disables prefix reuse)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="tokens of a shared system-prompt prefix planted "
                         "in every request (rounded down to whole chunks)")
    ap.add_argument("--kv-pool-mb", type=float, default=0,
                    help="paged KV memory: decode caches live in a shared "
                         "block pool of this many MB, admission is gated "
                         "by free blocks, and eviction frees real device "
                         "memory (continuous engine; 0 = dense slot "
                         "caches, the old behavior)")
    ap.add_argument("--kv-block-size", type=int, default=16,
                    help="rows per KV pool block (with --kv-pool-mb)")
    ap.add_argument("--decode-evict", action="store_true",
                    help="decoding-stage eviction: with --kv-pool-mb the "
                         "cache grows block-by-block and periodic sweeps "
                         "re-evict it to the budget, freeing blocks "
                         "mid-generation; dense engines cap the cache at a "
                         "small fixed margin instead")
    ap.add_argument("--decode-evict-interval", type=int, default=64,
                    help="rows of decode growth between eviction sweeps "
                         "(paged pool; bounds a slot's footprint at "
                         "capacity + interval rows)")
    ap.add_argument("--mesh-model", type=int, default=1,
                    help="tensor-parallel shards: serve one sharded model "
                         "over a (data, model) device mesh (continuous "
                         "engine; 1 = single-device, the old behavior)")
    ap.add_argument("--metrics-json", default="",
                    help="write the engine's typed-metrics registry as a "
                         "JSON snapshot to this path after the run "
                         "(chunked continuous engine)")
    ap.add_argument("--prom-snapshot", default="",
                    help="write the registry in Prometheus text exposition "
                         "format to this path after the run")
    ap.add_argument("--trace-out", default="",
                    help="write the per-request span trace here: a .jsonl "
                         "path gets raw events, anything else Chrome "
                         "trace-event JSON (open in https://ui.perfetto.dev)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = tf.init_params(key, cfg)
    lkv = None
    if cfg.technique_applies and cfg.lookahead:
        lkv = init_lookahead_params(jax.random.PRNGKey(args.seed + 1), cfg,
                                    params["layers"])
        if args.lkv_ckpt:
            lkv = load_lookahead_params(args.lkv_ckpt, cfg, params["layers"])
            print(f"loaded lookahead modules from {args.lkv_ckpt}")

    rng = np.random.default_rng(args.seed)
    streamable = (args.continuous and args.policy not in policies.MULTI_PASS
                  and args.policy != "full")
    if args.prefix_cache_mb and not streamable:
        print("note: --prefix-cache-mb requires the chunked continuous "
              "engine (--continuous with a streamable policy); ignoring it")
    if args.shared_prefix and not args.continuous:
        print("note: --shared-prefix shapes --continuous traffic only; "
              "ignoring it")
    if args.kv_pool_mb and not streamable:
        print("note: --kv-pool-mb requires the chunked continuous engine "
              "(--continuous with a streamable policy); ignoring it")
        args.kv_pool_mb = 0
    mesh = None
    if args.mesh_model > 1:
        a = cfg.attn
        if not streamable:
            print("note: --mesh-model requires the chunked continuous "
                  "engine (--continuous with a streamable policy); "
                  "ignoring it")
        elif (a is None or a.num_kv_heads % args.mesh_model
              or a.num_heads % args.mesh_model):
            heads = None if a is None else (a.num_heads, a.num_kv_heads)
            print(f"note: --mesh-model {args.mesh_model} does not divide "
                  f"{args.arch}'s (q, kv) heads {heads}; serving "
                  "single-device")
        else:
            from repro.launch.mesh import make_host_mesh
            mesh = make_host_mesh(model=args.mesh_model)
            print(f"mesh: {dict(mesh.shape)} over "
                  f"{len(jax.devices())} devices")
    trace = None  # set on the chunked continuous path
    if args.continuous:
        if args.policy in policies.MULTI_PASS or args.policy == "full":
            # draft-based baselines and 'full' cannot stream prefill chunks;
            # fall back to the deprecated bucketed engine for them
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", DeprecationWarning)
                eng = BucketedEngine(
                    params, cfg, policy=args.policy,
                    evict=EvictionConfig(budget=args.budget, draft_len=8),
                    lkv_params=lkv, num_slots=args.slots,
                    max_new_tokens=args.max_new, eos_id=-1)
        else:
            kv_pool = None
            if args.kv_pool_mb:
                kv_pool = KVBlockPool(cfg, block_size=args.kv_block_size,
                                      pool_mb=args.kv_pool_mb, mesh=mesh)
            prefix_cache = None
            if args.prefix_cache_mb:
                # with a pool, cached prefixes pin pool blocks (one
                # physical copy shared with decode) instead of owning a
                # second device-resident copy
                prefix_cache = PrefixCache(
                    chunk=args.chunk,
                    max_bytes=args.prefix_cache_mb << 20, pool=kv_pool)
            decode_evict = args.decode_evict
            if decode_evict and kv_pool is not None and mesh is not None:
                print("note: decode-time eviction on the paged pool is "
                      "single-device; ignoring --decode-evict under "
                      "--mesh-model")
                decode_evict = False
            # span tracing is always on for the chunked engine: it is the
            # per-request latency attribution this launcher reports, and
            # the obs bench gates its overhead at < 3% of throughput
            trace = TraceRecorder()
            sc = ServingConfig(
                policy=args.policy,
                evict=EvictionConfig(budget=args.budget, draft_len=8),
                decode_evict=DecodeEvictionConfig(
                    enabled=decode_evict,
                    interval=args.decode_evict_interval),
                chunking=ChunkingConfig(
                    chunk=args.chunk,
                    max_context=max(args.n_in, args.chunk)),
                num_slots=args.slots, max_new_tokens=args.max_new,
                eos_id=-1, prefix_cache=prefix_cache, kv_pool=kv_pool,
                mesh=mesh, trace=trace)
            eng = ContinuousEngine(params, cfg, sc, lkv_params=lkv)
        shared = (args.shared_prefix // args.chunk) * args.chunk
        system = rng.integers(0, cfg.vocab_size, shared).astype(np.int32)
        lens = rng.integers(args.n_in // 2, args.n_in + 1, args.requests)
        reqs = [Request(uid=i,
                        prompt=np.concatenate([
                            system,
                            rng.integers(0, cfg.vocab_size,
                                         int(n)).astype(np.int32)]),
                        max_new_tokens=args.max_new)
                for i, n in enumerate(lens)]
        t0 = time.time()
        done = eng.run(reqs)
        wall = time.time() - t0
        if trace is not None:
            # where each request's latency went, phase by phase — the
            # span trace replaces the old flat stats dump
            _print_phase_table(trace, done)
        if getattr(eng, "prefix_cache", None) is not None:
            m = eng.metrics
            hits = int(m.value("serving_prefix_hits_total"))
            misses = int(m.value("serving_prefix_misses_total"))
            skipped = int(m.value("serving_prefix_tokens_skipped_total"))
            prompt_tokens = sum(len(r.prompt) for r in done)
            print(f"prefix cache: hit-rate "
                  f"{hits / max(hits + misses, 1):.2f}, "
                  f"{skipped}/{prompt_tokens} prompt tokens "
                  f"served from shared prefixes, "
                  f"{eng.prefix_cache.stats()['bytes'] / 1e6:.2f} MB resident")
        if getattr(eng, "pool", None) is not None:
            m = eng.metrics
            s = eng.pool.stats()
            print(f"kv pool: {s['blocks_total']} x {s['block_size']}-row "
                  f"blocks ({s['bytes_total'] / 1e6:.2f} MB), high water "
                  f"{s['high_water_blocks']} blocks, peak concurrency "
                  f"{int(m.value('serving_max_concurrency'))}, "
                  f"{int(m.value('serving_preemptions_total'))} preemptions, "
                  f"{s['blocks_pinned_prefix']} blocks pinned by the "
                  f"prefix cache")
            if sc.decode_evict.enabled:
                print(f"decode eviction: "
                      f"{int(m.value('serving_decode_evict_sweeps_total'))} "
                      f"sweeps reclaimed {s['blocks_reclaimed_decode']} "
                      f"blocks mid-generation")
    else:
        with warnings.catch_warnings():  # explicit lockstep-baseline request
            warnings.simplefilter("ignore", DeprecationWarning)
            eng = ServingEngine(
                params, cfg, policy=args.policy,
                evict=EvictionConfig(budget=args.budget, draft_len=8),
                lkv_params=lkv, max_new_tokens=args.max_new, eos_id=-1)
        reqs = [Request(uid=i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            args.n_in).astype(np.int32),
                        max_new_tokens=args.max_new)
                for i in range(args.requests)]
        t0 = time.time()
        done = eng.serve(reqs)
        wall = time.time() - t0
    cb = eng.cache_bytes(args.n_in)
    print(f"policy={args.policy} budget={args.budget} "
          f"requests={len(done)} ttft={done[0].ttft_s*1e3:.1f}ms "
          f"wall={wall:.2f}s cache_ratio={cb['ratio']:.1f}x "
          f"({cb['full']/1e3:.0f}KB -> {cb['evicted']/1e3:.0f}KB per req)")
    for r in done[:2]:
        print(f"  req {r.uid}: {len(r.out_tokens)} tokens "
              f"{r.out_tokens[:8]}...")
    # observability artifacts (chunked continuous engine only: the
    # deprecated engines predate the registry/tracer)
    metrics = getattr(eng, "metrics", None)
    if args.metrics_json:
        if metrics is None:
            print("note: --metrics-json needs the chunked continuous "
                  "engine; skipped")
        else:
            metrics.to_json(args.metrics_json)
            print(f"metrics snapshot -> {args.metrics_json}")
    if args.prom_snapshot:
        if metrics is None:
            print("note: --prom-snapshot needs the chunked continuous "
                  "engine; skipped")
        else:
            with open(args.prom_snapshot, "w") as f:
                f.write(metrics.prometheus_text())
            print(f"prometheus snapshot -> {args.prom_snapshot}")
    if args.trace_out:
        if trace is None:
            print("note: --trace-out needs the chunked continuous "
                  "engine; skipped")
        elif args.trace_out.endswith(".jsonl"):
            trace.to_jsonl(args.trace_out)
            print(f"span trace (jsonl) -> {args.trace_out}")
        else:
            trace.to_chrome(args.trace_out)
            print(f"span trace (perfetto) -> {args.trace_out}")


if __name__ == "__main__":
    main()
