"""Production training launcher: LookaheadKV module training under pjit on
whatever mesh is available (full production meshes on TPU; a host mesh on
CPU for verification).

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke \
        --steps 40 --batch 4

On a real v5e deployment this same entry point runs with
``--mesh pod|multipod`` (requires the matching device count).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import io as ckpt
from repro.common import sharding as sh
from repro.common.config import TrainConfig
from repro.configs import get_config, get_smoke_config
from repro.core import objective
from repro.core.lookahead import init_lookahead_params
from repro.data import synthetic
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import transformer as tf
from repro.optim import adam


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="host",
                    choices=["host", "pod", "multipod"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--n-in", type=int, default=64)
    ap.add_argument("--n-out", type=int, default=12)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="experiments/ckpt/train_lkv.npz")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not cfg.technique_applies:
        raise SystemExit(f"{args.arch}: technique inapplicable (DESIGN.md §5)"
                         " — use examples/train_e2e.py --lm for LM training")
    mesh = (make_host_mesh() if args.mesh == "host"
            else make_production_mesh(multi_pod=args.mesh == "multipod"))
    tc = TrainConfig(steps=args.steps, lr=args.lr, batch_size=args.batch,
                     n_in=args.n_in, n_out=args.n_out, seed=args.seed)

    key = jax.random.PRNGKey(args.seed)
    with mesh:
        params = tf.init_params(key, cfg)
        pspecs = sh.param_specs(cfg, mesh)
        params = jax.device_put(
            params, jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs))
        lkv = init_lookahead_params(jax.random.PRNGKey(args.seed + 1), cfg,
                                    params["layers"])
        lkv = jax.device_put(lkv, NamedSharding(mesh, P()))
        opt = adam.init(lkv)

        step_fn = jax.jit(steps_mod.make_train_step(cfg, tc))
        it = synthetic.MixtureIterator(cfg, args.batch, args.n_in, args.n_out,
                                       seed=args.seed)
        dp = sh.batch_axes(mesh)
        t0 = time.time()
        for i in range(args.steps):
            b = next(it)
            x = jnp.asarray(b.x)
            xy = jnp.concatenate([x, jnp.asarray(b.y)], axis=1)
            batch = {"x": x, "xy": xy}
            batch = jax.device_put(
                batch, NamedSharding(mesh, P(dp, None)))
            lkv, opt, loss = step_fn(params, lkv, opt, batch)
            if i % 10 == 0 or i == args.steps - 1:
                print(f"step {i:4d}  loss {float(loss):.4f}  "
                      f"({time.time()-t0:.0f}s)", flush=True)
    ckpt.save(args.ckpt, jax.device_get(lkv),
              metadata={"arch": cfg.name, "steps": args.steps})
    print(f"saved -> {args.ckpt}")


if __name__ == "__main__":
    main()
