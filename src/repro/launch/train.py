"""Production training launcher: LookaheadKV module training under pjit on
whatever mesh is available (full production meshes on TPU; a host mesh on
CPU for verification).

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke \
        --steps 40 --batch 4

Data sources:

* default — the synthetic mixture (``data/synthetic.MixtureIterator``),
  running the full two-pass objective (GT pass + lookahead pass) per step;
* ``--harvest <dir>`` — distillation against gt_oracle targets harvested
  from serving traces (``python -m repro.data.harvest``): each step runs
  only the lookahead pass against the stored score vectors.

Checkpointing: ``--ckpt-every N`` writes the full trainer state
``{"lkv", "opt"}`` (modules + AdamState) every N steps; ``--resume`` picks
up from the last save — step count, optimizer moments and the data stream
position all continue, so a killed run replays bit-identically.
``--verify`` turns the run into the CI train-smoke gate: the loss must
decrease and the final checkpoint must round-trip through
``ckpt.load(like=...)`` bit-exactly.

On a real v5e deployment this same entry point runs with
``--mesh pod|multipod`` (requires the matching device count).
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import io as ckpt
from repro.common import sharding as sh
from repro.common.config import TrainConfig
from repro.configs import get_config, get_smoke_config
from repro.core.lookahead import init_lookahead_params
from repro.data import harvest, synthetic
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import transformer as tf
from repro.optim import adam


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="host",
                    choices=["host", "pod", "multipod"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--n-in", type=int, default=64)
    ap.add_argument("--n-out", type=int, default=12)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="experiments/ckpt/train_lkv.npz")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--harvest", default="",
                    help="distill against a harvested gt_oracle dataset "
                         "directory instead of the synthetic mixture")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="write the trainer state (modules + AdamState) "
                         "every N steps (0: final save only)")
    ap.add_argument("--resume", action="store_true",
                    help="continue from --ckpt if it exists (step count, "
                         "optimizer moments and data position resume)")
    ap.add_argument("--verify", action="store_true",
                    help="CI train-smoke gate: assert the loss decreased "
                         "and the checkpoint round-trips bit-exactly")
    # kill simulation for the resume test: exit (no final save) after N
    # steps, as if the process died mid-run
    ap.add_argument("--stop-after", type=int, default=0,
                    help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not cfg.technique_applies:
        raise SystemExit(f"{args.arch}: technique inapplicable (DESIGN.md §5)"
                         " — use examples/train_e2e.py --lm for LM training")
    mesh = (make_host_mesh() if args.mesh == "host"
            else make_production_mesh(multi_pod=args.mesh == "multipod"))
    tc = TrainConfig(steps=args.steps, lr=args.lr, batch_size=args.batch,
                     n_in=args.n_in, n_out=args.n_out, seed=args.seed)

    key = jax.random.PRNGKey(args.seed)
    with mesh:
        params = tf.init_params(key, cfg)
        pspecs = sh.param_specs(cfg, mesh)
        params = jax.device_put(
            params, jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs))
        lkv = init_lookahead_params(jax.random.PRNGKey(args.seed + 1), cfg,
                                    params["layers"])
        lkv = jax.device_put(lkv, NamedSharding(mesh, P()))
        opt = adam.init(lkv)

        start = 0
        if args.resume and os.path.exists(args.ckpt):
            state = ckpt.load(args.ckpt, like={"lkv": lkv, "opt": opt})
            lkv = jax.device_put(state["lkv"], NamedSharding(mesh, P()))
            opt = jax.device_put(state["opt"], NamedSharding(mesh, P()))
            start = int(ckpt.metadata(args.ckpt).get("step", 0))
            print(f"resumed {args.ckpt} at step {start}", flush=True)

        if args.harvest:
            it = harvest.HarvestIterator(args.harvest, args.batch,
                                         seed=args.seed)
            step_fn = jax.jit(steps_mod.make_distill_step(cfg, tc))
        else:
            it = synthetic.MixtureIterator(cfg, args.batch, args.n_in,
                                           args.n_out, seed=args.seed)
            step_fn = jax.jit(steps_mod.make_train_step(cfg, tc))
        # both iterators are pure functions of (seed, draw index), so the
        # resumed data stream continues exactly where the killed run left it
        for _ in range(start):
            next(it)

        def save_state(step: int) -> None:
            ckpt.save(args.ckpt,
                      {"lkv": jax.device_get(lkv),
                       "opt": jax.device_get(opt)},
                      metadata={"arch": cfg.name, "step": step,
                                "steps": args.steps,
                                "source": args.harvest or "synthetic"})

        dp = sh.batch_axes(mesh)
        losses = []
        t0 = time.time()
        for i in range(start, args.steps):
            b = next(it)
            if args.harvest:
                batch = {
                    "x": jax.device_put(jnp.asarray(b["x"]),
                                        NamedSharding(mesh, P(dp, None))),
                    "s_gt": jax.device_put(
                        jnp.asarray(b["s_gt"]),
                        NamedSharding(mesh, P(None, dp))),
                }
            else:
                x = jnp.asarray(b.x)
                xy = jnp.concatenate([x, jnp.asarray(b.y)], axis=1)
                batch = jax.device_put({"x": x, "xy": xy},
                                       NamedSharding(mesh, P(dp, None)))
            lkv, opt, loss = step_fn(params, lkv, opt, batch)
            losses.append(float(loss))
            if i % 10 == 0 or i == args.steps - 1:
                print(f"step {i:4d}  loss {losses[-1]:.4f}  "
                      f"({time.time()-t0:.0f}s)", flush=True)
            if (args.ckpt_every and (i + 1) % args.ckpt_every == 0
                    and (i + 1) < args.steps):
                save_state(i + 1)
            if args.stop_after and (i + 1) >= args.stop_after:
                print(f"stopped after step {i + 1} (simulated kill)")
                return {"losses": losses, "ckpt": args.ckpt,
                        "step": i + 1}
    save_state(args.steps)
    print(f"saved -> {args.ckpt}")

    if args.verify:
        assert len(losses) >= 2 and min(losses[1:]) < losses[0], \
            f"train-smoke: loss did not decrease ({losses[0]:.4f} -> " \
            f"{min(losses[1:]):.4f})"
        back = ckpt.load(args.ckpt, like={"lkv": lkv, "opt": opt})
        for a, b in zip(jax.tree.leaves({"lkv": lkv, "opt": opt}),
                        jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(jax.device_get(a)),
                                          np.asarray(b))
        meta = ckpt.metadata(args.ckpt)
        assert meta["step"] == args.steps, meta
        print(f"train-smoke verdict: PASS (loss {losses[0]:.4f} -> "
              f"{losses[-1]:.4f}, checkpoint round-trips bit-exactly)")
    return {"losses": losses, "ckpt": args.ckpt, "step": args.steps}


if __name__ == "__main__":
    main()
