import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# The two lines above MUST run before any other import pulls jax in: jax
# locks the device count at first init.  This module (and only this module)
# sees 512 placeholder devices — smoke tests and benches see the real one.

# Multi-pod dry-run: prove every (arch × input-shape × mesh) combination
# lowers, compiles, and fits, and extract the roofline inputs.
#
#     PYTHONPATH=src python -m repro.launch.dryrun \
#         --arch smollm-135m --shape train_4k --mesh pod --out experiments/dryrun
#
# Per combo this emits JSON with: memory analysis (bytes/device), HLO FLOPs &
# bytes (cost analysis), per-collective byte totals parsed from the compiled
# module, and the three roofline terms (launch/analysis.py).
# (No ``from __future__`` here: the XLA_FLAGS lines must stay first.)

import argparse
import dataclasses
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common import sharding as sh
from repro.common.config import INPUT_SHAPES, TrainConfig
from repro.configs import get_config, shape_applicable
from repro.core.lookahead import init_lookahead_params
from repro.launch import analysis, steps
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as tf
from repro.optim import adam


def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def _shard_tree(mesh, shapes, specs):
    return sh.with_sharding(shapes, specs, mesh)


def _batch_specs(mesh, batch_shapes: dict, global_batch: int,
                 dp_all: bool = False, seq_shard: bool = False):
    """Input batch shardings: batch over the data axes when divisible."""
    dp = tuple(mesh.axis_names) if dp_all else sh.batch_axes(mesh)
    seq = "model" if seq_shard else None
    dp_total = int(np.prod([mesh.shape[x] for x in dp]))
    bspec = dp if global_batch % dp_total == 0 else (
        ("data",) if global_batch % mesh.shape["data"] == 0 else None)

    def spec_for(name, s):
        if name == "mrope":
            return P(None, bspec, seq, *([None] * (len(s.shape) - 3)))
        if name == "frames":  # whisper encoder frames: keep unsharded seq
            return P(bspec, *([None] * (len(s.shape) - 1)))
        return P(bspec, seq, *([None] * (len(s.shape) - 2)))

    return {k: spec_for(k, v) for k, v in batch_shapes.items()
            if hasattr(v, "shape")}


def abstract_params(cfg, mesh, *, embed_replicated: bool = False):
    shapes = jax.eval_shape(lambda: tf.init_params(jax.random.PRNGKey(0), cfg))
    specs = sh.param_specs(cfg, mesh, embed_replicated=embed_replicated)
    return _shard_tree(mesh, shapes, specs), specs


def abstract_lkv(cfg, mesh, param_shapes):
    lkv_shapes = jax.eval_shape(
        lambda: init_lookahead_params(
            jax.random.PRNGKey(0), cfg,
            jax.eval_shape(lambda: tf.init_params(jax.random.PRNGKey(0), cfg))
            ["layers"],
        )
    )
    specs = sh.lkv_specs(lkv_shapes)
    return _shard_tree(mesh, lkv_shapes, specs), specs


# --- §Perf variants: config transforms measured against the baselines -----

def _v_moe_sparse(cfg):
    assert cfg.moe is not None
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch="sparse"))


VARIANTS = {
    "": {},
    # sort-based top-k dispatch (phi/deepseek §Perf pair 1)
    "moe_sparse": {"cfg": _v_moe_sparse},
    # batch over (data, model) for TP-less archs (mamba2 §Perf pair 2):
    # the model axis otherwise idles while SSM compute replicates 16x.
    "dp_all": {"dp_all": True},
    # sequence parallelism for prefill (qwen2 §Perf pair 3): heads don't
    # divide the model axis, so shard the *sequence* over it — per-token ops
    # shard 16x further and XLA allgathers K/V per layer for attention.
    "seq_shard": {"seq_shard": True},
    # split-cache decode (§Perf decode iteration): frozen seq-sharded prompt
    # cache + replicated hot ring => no per-step cache resharding.
    "split_cache": {"hot_slots": 128},
}


def _variant_cfg(variant, cfg):
    fn = VARIANTS[variant].get("cfg")
    return fn(cfg) if fn else cfg


def build(arch: str, shape_name: str, mesh, variant: str = ""):
    """Returns (fn, args tuple of ShapeDtypeStructs, tokens_processed)."""
    cfg = _variant_cfg(variant, get_config(arch))
    shape = INPUT_SHAPES[shape_name]
    dp_all = VARIANTS[variant].get("dp_all", False)
    params_s, _ = abstract_params(cfg, mesh, embed_replicated=dp_all)

    if shape.kind == "train":
        tc = TrainConfig()
        fn = steps.make_train_step(cfg, tc)
        bs = steps.train_batch_shapes(cfg, shape)
        n_in, n_out = bs.pop("n_in"), bs.pop("n_out")
        bspecs = _batch_specs(mesh, bs, shape.global_batch, dp_all)
        batch_s = _shard_tree(mesh, bs, bspecs)
        tokens = shape.global_batch * shape.seq_len
        if cfg.technique_applies:
            lkv_s, _ = abstract_lkv(cfg, mesh, params_s)
            opt_s = jax.eval_shape(adam.init, lkv_s)
            opt_s = _shard_tree(
                mesh, opt_s,
                adam.AdamState(P(), sh.lkv_specs(lkv_s), sh.lkv_specs(lkv_s)),
            )
            return fn, (params_s, lkv_s, opt_s, batch_s), tokens
        opt_shapes = jax.eval_shape(adam.init, params_s)
        pspecs = sh.param_specs(cfg, mesh, embed_replicated=dp_all)
        opt_s = _shard_tree(mesh, opt_shapes,
                            adam.AdamState(P(), pspecs, pspecs))
        return fn, (params_s, opt_s, batch_s), tokens

    if shape.kind == "prefill":
        fn = steps.make_prefill_step(cfg, shape)
        bs = steps.prefill_batch_shapes(cfg, shape)
        bspecs = _batch_specs(mesh, bs, shape.global_batch, dp_all,
                              VARIANTS[variant].get("seq_shard", False))
        batch_s = _shard_tree(mesh, bs, bspecs)
        tokens = shape.global_batch * shape.seq_len
        if cfg.technique_applies:
            lkv_s, _ = abstract_lkv(cfg, mesh, params_s)
            return fn, (params_s, lkv_s, batch_s), tokens
        return fn, (params_s, batch_s), tokens

    # decode
    hot = VARIANTS[variant].get("hot_slots", 0)
    fn = steps.make_decode_step(cfg, mesh=mesh if hot else None)
    token_s, cache_shapes = steps.decode_batch_shapes(cfg, shape, hot)
    c_specs = sh.cache_specs(cfg, mesh, shape.global_batch,
                             shape.seq_len if cfg.uses_attention else 0,
                             hot_slots=hot)
    cache_s = _shard_tree(mesh, cache_shapes, c_specs)
    dp = sh.batch_axes(mesh)
    dp_total = int(np.prod([mesh.shape[x] for x in dp]))
    bspec = dp if shape.global_batch % dp_total == 0 else None
    token_s = jax.ShapeDtypeStruct(
        token_s.shape, token_s.dtype, sharding=_ns(mesh, P(bspec, None)))
    tokens = shape.global_batch  # one new token per sequence
    return fn, (params_s, token_s, cache_s), tokens


def run_one(arch: str, shape_name: str, mesh_kind: str, out_dir: str | None,
            variant: str = ""):
    applicable, reason = shape_applicable(arch, shape_name)
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "variant": variant}
    if not applicable:
        result["status"] = "skipped"
        result["reason"] = reason
        print(f"[dryrun] SKIP {arch} × {shape_name}: {reason}")
        _dump(result, out_dir)
        return result

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    chips = int(np.prod(list(mesh.shape.values())))
    cfg = _variant_cfg(variant, get_config(arch))
    shape = INPUT_SHAPES[shape_name]
    fn, args, tokens = build(arch, shape_name, mesh, variant)

    t0 = time.time()
    with mesh:
        lowered = jax.jit(fn).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = analysis.memory_analysis_dict(compiled)
    cost = analysis.cost_analysis_dict(compiled)  # reference only: XLA counts
    # while-loop bodies once (see analysis.py), so the roofline numerators
    # come from the scan-aware jaxpr counter + loop-multiplied collectives.
    jc = analysis.fn_cost(fn, *args)
    hlo = compiled.as_text()
    coll_raw = analysis.collective_bytes(hlo)
    coll = analysis.collective_bytes_with_loops(hlo, cfg.num_layers)

    mf = analysis.model_flops(cfg, shape.kind, tokens)
    eff_mesh = ({"data": chips, "model": 1}
                if VARIANTS[variant].get("dp_all") else dict(mesh.shape))
    comps = analysis.component_costs(
        cfg, shape.kind, shape.global_batch, shape.seq_len, eff_mesh,
        seq_sharded=VARIANTS[variant].get("seq_shard", False))
    pd = analysis.per_device_cost(comps, eff_mesh, shape.global_batch)
    # cross-check the component model against the exact jaxpr global flops
    comp_global = sum(c["flops"] for c in comps.values())
    jaxpr_check = comp_global / jc["flops"] if jc["flops"] else 0.0
    rl = analysis.roofline_terms(
        arch=arch, shape=shape_name, mesh=mesh_kind, chips=chips,
        hlo_flops_per_dev=pd["flops_per_dev"],
        hlo_bytes_per_dev=pd["bytes_per_dev"],
        coll_bytes_per_dev=float(coll["total"]),
        model_flops_global=mf,
        peak_bytes=mem.get("peak_memory_in_bytes"),
    )
    result.update({
        "status": "ok",
        "chips": chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem,
        "cost_xla": cost,
        "cost_jaxpr_global": jc,
        "components": {k: {kk: (vv if isinstance(vv, int) else float(vv))
                           for kk, vv in v.items()}
                       for k, v in comps.items()},
        "per_device": pd,
        "jaxpr_check_ratio": jaxpr_check,
        "collectives": coll,
        "collectives_raw": coll_raw,
        "roofline": rl.to_dict(),
        "params": cfg.num_params(),
        "active_params": cfg.active_params(),
    })
    print(f"[dryrun] OK {arch} × {shape_name} × {mesh_kind} "
          f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)")
    print(f"  memory/device: {mem}")
    print(f"  flops/device: {pd['flops_per_dev']:.3e}  "
          f"hbm bytes/device: {pd['bytes_per_dev']:.3e}  "
          f"collective bytes/device: {coll['total']:.3e}  "
          f"jaxpr_check: {jaxpr_check:.2f}")
    print(f"  roofline: compute {rl.compute_s*1e3:.2f}ms  "
          f"memory {rl.memory_s*1e3:.2f}ms  collective {rl.collective_s*1e3:.2f}ms "
          f"-> {rl.bottleneck}-bound; useful-flop ratio {rl.useful_flop_ratio:.3f}")
    _dump(result, out_dir)
    return result


def _dump(result, out_dir):
    if not out_dir:
        return
    os.makedirs(out_dir, exist_ok=True)
    v = f"_{result['variant']}" if result.get("variant") else ""
    name = f"{result['arch']}_{result['shape']}_{result['mesh']}{v}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(result, f, indent=2, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--variant", default="", choices=list(VARIANTS))
    args = ap.parse_args()
    res = run_one(args.arch, args.shape, args.mesh, args.out, args.variant)
    sys.exit(0 if res.get("status") in ("ok", "skipped") else 1)


if __name__ == "__main__":
    main()
