"""Compiled-artifact analysis: collective-byte accounting + roofline terms.

This is the §Roofline source (CPU container: we reason from the lowered /
compiled HLO, not wall-clock).  Hardware constants: TPU v5e.
"""

from __future__ import annotations

import re
from dataclasses import asdict, dataclass

import numpy as np

# --- TPU v5e ---------------------------------------------------------------
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (~ per-chip effective injection)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tuple": 0, "token": 0, "opaque": 0,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# `%x = bf16[8,128,4096]{2,1,0} all-gather(...)`
_LINE_RE = re.compile(
    r"=\s+(?:\()?([a-z0-9]+)\[([0-9,]*)\][^=]*?\b"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes of every collective op in (post-SPMD) HLO text.

    Shapes in the partitioned module are per-device, so the totals are
    per-device bytes moved — the right numerator for the per-chip roofline
    term.  ``-start``/``-done`` async pairs are counted once (on -start).
    """
    out = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        hit = None
        for c in _COLLECTIVES:
            if f" {c}(" in stripped or f"{c}-start(" in stripped:
                hit = c
                break
        if hit is None or "-done(" in stripped:
            continue
        # result shape = first dtype[dims] on the line (possibly a tuple)
        m = _SHAPE_RE.search(stripped.split("=", 1)[-1])
        if not m:
            continue
        out[hit] += _shape_bytes(m.group(1), m.group(2))
        counts[hit] += 1
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    out["counts"] = counts
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float  # per device
    hlo_bytes: float  # per device (HBM traffic)
    coll_bytes: float  # per device
    model_flops: float  # 6·N_active·D tokens, global
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    useful_flop_ratio: float
    peak_bytes_per_device: float | None = None

    def to_dict(self):
        return asdict(self)


def roofline_terms(
    *, arch: str, shape: str, mesh: str, chips: int,
    hlo_flops_per_dev: float, hlo_bytes_per_dev: float,
    coll_bytes_per_dev: float, model_flops_global: float,
    peak_bytes: float | None = None,
) -> Roofline:
    compute_s = hlo_flops_per_dev / PEAK_FLOPS_BF16
    memory_s = hlo_bytes_per_dev / HBM_BW
    collective_s = coll_bytes_per_dev / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    total_hlo = hlo_flops_per_dev * chips
    ratio = model_flops_global / total_hlo if total_hlo else 0.0
    return Roofline(
        arch=arch, shape=shape, mesh=mesh, chips=chips,
        hlo_flops=hlo_flops_per_dev, hlo_bytes=hlo_bytes_per_dev,
        coll_bytes=coll_bytes_per_dev, model_flops=model_flops_global,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck, useful_flop_ratio=ratio,
        peak_bytes_per_device=peak_bytes,
    )


def model_flops(cfg, shape_kind: str, tokens: int) -> float:
    """MODEL_FLOPS = 6·N_active·tokens (train ≈ fwd+bwd => 6; inference 2)."""
    n_active = cfg.active_params()
    mult = 6.0 if shape_kind == "train" else 2.0
    return mult * n_active * tokens


def memory_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "peak_memory_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if "peak_memory_in_bytes" not in out and out:
        # older jaxlib CompiledMemoryStats lacks the attribute: the standard
        # conservative bound is arguments + outputs + temps + code
        out["peak_memory_in_bytes"] = sum(
            out.get(k, 0) for k in ("argument_size_in_bytes",
                                    "output_size_in_bytes",
                                    "temp_size_in_bytes",
                                    "generated_code_size_in_bytes"))
    return out


def cost_analysis_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in dict(ca).items()
            if isinstance(v, (int, float)) and not k.startswith("utilization")}


# ---------------------------------------------------------------------------
# jaxpr-based cost model (scan-trip-count aware)
# ---------------------------------------------------------------------------
# Discovery (EXPERIMENTS.md §Dry-run): XLA's compiled.cost_analysis() counts
# a while-loop body ONCE, ignoring the trip count — with the whole depth under
# lax.scan this understates FLOPs by ~num_layers×.  We therefore walk the
# jaxpr, where scan lengths are explicit, and count:
#   flops: dot_general (2·M·N·K·batch) — the MXU work;
#   heavy_bytes: operand+result bytes of dot/gather/scatter/dyn-slice ops —
#     a fusion-aware-ish lower bound on HBM traffic (elementwise chains fuse).
# Shapes in the jaxpr are GLOBAL; divide by chip count for per-device terms.


def _aval_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


_HEAVY_BYTES_PRIMS = {
    "gather", "scatter", "scatter-add", "dynamic_slice",
    "dynamic_update_slice", "take", "conv_general_dilated",
}


def jaxpr_cost(jaxpr) -> dict:
    """{'flops': float, 'heavy_bytes': float} with scan multipliers."""
    flops = 0.0
    bytes_ = 0.0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            dnums = eqn.params["dimension_numbers"]
            (lc, rc), (lb, rb) = dnums
            a, b = eqn.invars[0].aval, eqn.invars[1].aval
            batch = int(np.prod([a.shape[i] for i in lb])) if lb else 1
            k = int(np.prod([a.shape[i] for i in lc])) if lc else 1
            m = int(np.prod([a.shape[i] for i in range(a.ndim)
                             if i not in lc and i not in lb]))
            n = int(np.prod([b.shape[i] for i in range(b.ndim)
                             if i not in rc and i not in rb]))
            flops += 2.0 * batch * m * n * k
            bytes_ += sum(_aval_bytes(v.aval) for v in eqn.invars)
            bytes_ += sum(_aval_bytes(v.aval) for v in eqn.outvars)
        elif prim in _HEAVY_BYTES_PRIMS:
            bytes_ += sum(_aval_bytes(v.aval) for v in eqn.invars)
            bytes_ += sum(_aval_bytes(v.aval) for v in eqn.outvars)
        elif prim == "scan":
            inner = jaxpr_cost(eqn.params["jaxpr"].jaxpr)
            length = eqn.params["length"]
            flops += length * inner["flops"]
            bytes_ += length * inner["heavy_bytes"]
        elif prim == "while":
            inner = jaxpr_cost(eqn.params["body_jaxpr"].jaxpr)
            flops += inner["flops"]  # trip count unknown; flagged by caller
            bytes_ += inner["heavy_bytes"]
        elif prim == "cond":
            branches = eqn.params["branches"]
            costs = [jaxpr_cost(br.jaxpr) for br in branches]
            flops += max(c["flops"] for c in costs)
            bytes_ += max(c["heavy_bytes"] for c in costs)
        elif prim in ("jit", "pjit", "closed_call", "core_call", "remat_call",
                      "custom_jvp_call", "custom_vjp_call",
                      "custom_vjp_call_jaxpr", "checkpoint", "remat"):
            sub = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
            if sub is not None:
                inner = jaxpr_cost(getattr(sub, "jaxpr", sub))
                flops += inner["flops"]
                bytes_ += inner["heavy_bytes"]
    return {"flops": flops, "heavy_bytes": bytes_}


def fn_cost(fn, *abstract_args) -> dict:
    """Global-shape cost of fn lowered at the given abstract args."""
    import jax

    jaxpr = jax.make_jaxpr(fn)(*abstract_args)
    return jaxpr_cost(jaxpr.jaxpr)


def collective_bytes_with_loops(hlo_text: str, loop_multiplier: int) -> dict:
    """Collective bytes with in-loop ops multiplied by ``loop_multiplier``
    (the layer-scan trip count — our only collective-bearing loop level).

    HLO text layout: each computation is printed as a block starting with
    ``%name (params) -> type {`` or ``name {``; while-loop bodies contain
    "while" in their computation name (XLA naming convention).
    """
    out = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    in_loop_body = False
    depth = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.endswith("{") and not s.startswith("ROOT"):
            header = s.split("(")[0]
            in_loop_body = ("while" in header or "body" in header
                            or "cond" in header)
            depth = 1
            continue
        if in_loop_body:
            depth += s.count("{") - s.count("}")
            if depth <= 0:
                in_loop_body = False
        hit = None
        for c in _COLLECTIVES:
            if f" {c}(" in s or f"{c}-start(" in s:
                hit = c
                break
        if hit is None or "-done(" in s:
            continue
        m = _SHAPE_RE.search(s.split("=", 1)[-1])
        if not m:
            continue
        b = _shape_bytes(m.group(1), m.group(2))
        mult = loop_multiplier if in_loop_body else 1
        out[hit] += b * mult
        counts[hit] += 1
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    out["counts"] = counts
    out["loop_multiplier"] = loop_multiplier
    return out


# ---------------------------------------------------------------------------
# Analytic HBM traffic model (TPU-target semantics)
# ---------------------------------------------------------------------------
# The jaxpr heavy-bytes counter over-counts the CPU fallback's attention
# tiles (on the TPU target those live in VMEM inside the Pallas kernels and
# never touch HBM).  The roofline memory term therefore uses this analytic
# model of *unavoidable* HBM traffic for our implementation:
#   · weights read once per pass (MoE dense-dispatch: once per seq chunk —
#     honestly charging the baseline's re-read, which §Perf attacks);
#   · activations written+read once per layer boundary (~8 stream tensors);
#   · flash attention reads K/V once per query block-row;
#   · decode reads the whole KV cache once per step;
#   · train charges 2 passes (GT + lookahead) + lookahead-row backward.
# Reported next to the jaxpr upper bound; both land in the JSON.


def analytic_hbm_bytes(cfg, shape_kind: str, batch: int, seq: int) -> float:
    bpe = 2.0  # bf16
    d = cfg.d_model
    L = cfg.num_layers
    p_bytes = cfg.num_params() * bpe

    if shape_kind == "decode":
        tokens = batch  # one token per sequence
        cache = 0.0
        if cfg.attn is not None:
            cache = L * batch * seq * cfg.attn.kv_dim * 2 * bpe
        if cfg.uses_ssm:
            s = cfg.ssm
            nh = s.num_heads(d)
            cache += L * batch * nh * s.head_dim * s.d_state * 4 * 2  # r+w f32
        act = L * tokens * d * bpe * 8
        return p_bytes + cache + act + tokens * cfg.vocab_size * 4

    tokens = batch * seq
    passes = 2.0 if shape_kind == "train" else 1.0
    act = passes * L * tokens * d * bpe * 8
    attn_io = 0.0
    if cfg.attn is not None:
        block_q = 512.0
        qblocks = max(seq / block_q, 1.0)
        kv_read = seq * cfg.attn.kv_dim * 2 * bpe
        attn_io = passes * L * batch * qblocks * kv_read
        if cfg.attn.sliding_window and not cfg.attn.global_every:
            attn_io *= min(cfg.attn.sliding_window / seq * qblocks, 1.0)
    moe_reread = 0.0
    if cfg.moe is not None:
        m = cfg.moe
        nchunks = max(seq / 256.0, 1.0)  # moe._CHUNK
        expert_bytes = L * m.num_experts * 3 * d * m.d_expert * bpe
        moe_reread = passes * (nchunks - 1) * expert_bytes
    weight_reads = passes * p_bytes
    if shape_kind == "train":
        weight_reads += p_bytes  # backward re-reads (remat-ish)
    return weight_reads + act + attn_io + moe_reread


# ---------------------------------------------------------------------------
# Sharding-aware per-component cost model (the roofline numerators)
# ---------------------------------------------------------------------------
# jaxpr totals are GLOBAL; dividing by chip count assumes every op shards
# over the whole mesh.  That hides replication waste: e.g. qwen2-1.5b has 12
# heads — not divisible by model=16 — so its attention runs replicated on
# every model rank.  Each component below carries its own effective shard
# count derived from the same divisibility rules as sharding.py; per-device
# cost = Σ_c flops_c / (dp_shards · model_shards_c).  The component
# breakdown is what §Perf iterates on.  Cross-checked against the jaxpr
# totals (reported as `jaxpr_check`).


def component_costs(cfg, shape_kind: str, batch: int, seq: int,
                    mesh_shape: dict, *, seq_sharded: bool = False) -> dict:
    """{component: {flops, bytes, model_shards}} — global flops/bytes and the
    model-axis parallelism each component actually achieves."""
    msize = mesh_shape.get("model", 1)
    a = cfg.attn
    d, L, V = cfg.d_model, cfg.num_layers, cfg.vocab_size
    bpe = 2.0
    tokens = batch * (1 if shape_kind == "decode" else seq)

    # forward-pass multiplier: GT + lookahead passes for train (+ backward
    # through the lookahead rows ~ small); plain LM train = fwd + 2×bwd.
    if shape_kind == "train":
        passes = 2.2 if cfg.technique_applies else 3.0
    else:
        passes = 1.0

    def div(n, s):
        return s > 0 and n % s == 0

    comps = {}

    if a is not None:
        shard_q = div(a.num_heads, msize)
        shard_kv = div(a.num_kv_heads, msize)
        proj_flops = 2.0 * tokens * d * (a.q_dim * 2 + a.kv_dim * 2) * L
        comps["attn_proj"] = {
            "flops": passes * proj_flops,
            "bytes": passes * L * (d * (a.q_dim * 2 + a.kv_dim * 2)) * bpe,
            "model_shards": msize if shard_q else 1,
        }
        if shape_kind == "decode":
            ctx = seq
            quad = 4.0 * batch * ctx * a.q_dim * L
            kv_bytes = L * batch * ctx * a.kv_dim * 2 * bpe
        else:  # data-like traffic: scales with the local batch shard
            causal_frac = 0.5
            window = a.sliding_window if (a.sliding_window and
                                          not a.global_every) else 0
            eff_ctx = min(window, seq) if window else seq * causal_frac
            if a.global_every:
                n_glob = L // a.global_every
                eff_ctx = (min(a.sliding_window, seq) * (L - n_glob)
                           + seq * causal_frac * n_glob) / L
            quad = 4.0 * batch * seq * eff_ctx * a.q_dim * L
            kv_bytes = passes * L * batch * (seq / 512.0) \
                * eff_ctx * a.kv_dim * 2 * bpe
        comps["attn_quadratic"] = {
            "flops": passes * quad,
            "bytes": 0.0,
            "data_bytes": kv_bytes,
            "model_shards": msize if shard_q else 1,
        }
    if cfg.moe is not None:
        m = cfg.moe
        shard_e = div(m.num_experts, msize)
        if m.dispatch == "sparse":
            # top-k + capacity slack; weights stream once (no chunk re-read)
            dense_e = m.top_k * m.capacity_factor
            nchunks = 1.0
        else:
            dense_e = m.num_experts  # dense dispatch computes every expert
            nchunks = max((1 if shape_kind == "decode" else seq) / 256.0, 1.0)
        expert_flops = 2.0 * tokens * 3 * d * m.d_expert * dense_e * L
        expert_bytes = L * m.num_experts * 3 * d * m.d_expert * bpe * nchunks
        comps["moe_experts"] = {
            "flops": passes * expert_flops,
            "bytes": passes * expert_bytes,
            "model_shards": msize if shard_e else 1,
        }
        if m.num_shared_experts:
            fs = m.num_shared_experts * m.d_expert
            comps["moe_shared"] = {
                "flops": passes * 2.0 * tokens * 3 * d * fs * L,
                "bytes": passes * L * 3 * d * fs * bpe,
                "model_shards": msize if div(fs, msize) else 1,
            }
        comps["moe_router"] = {
            "flops": passes * 2.0 * tokens * d * m.num_experts * L,
            "bytes": passes * L * d * m.num_experts * 4,
            "model_shards": 1,
        }
    elif cfg.d_ff > 0:
        comps["mlp"] = {
            "flops": passes * 2.0 * tokens * 3 * d * cfg.d_ff * L,
            "bytes": passes * L * 3 * d * cfg.d_ff * bpe,
            "model_shards": msize if div(cfg.d_ff, msize) else 1,
        }
    if cfg.ssm is not None:
        s = cfg.ssm
        di = s.d_inner(d)
        nh = s.num_heads(d)
        proj = 2.0 * tokens * d * (2 * di + 2 * s.d_state + nh) \
            + 2.0 * tokens * di * d
        # SSD: intra-chunk quadratic + state updates
        Q = s.chunk_size
        ssd = (2.0 * tokens * Q * nh * s.head_dim  # cb/w application
               + 2.0 * tokens * Q * s.d_state  # C·B
               + 4.0 * tokens * nh * s.head_dim * s.d_state)
        comps["ssm"] = {
            "flops": passes * (proj + ssd) * L,
            "bytes": passes * L * (d * (2 * di + 2 * s.d_state + nh)
                                   + di * d) * bpe,
            "model_shards": 1,  # baseline: replicated (DESIGN.md §4)
        }
    if cfg.is_encoder_decoder and shape_kind != "decode":
        F = cfg.encoder.num_frames
        enc_tokens = batch * F
        eL = cfg.encoder.num_layers
        enc = (2.0 * enc_tokens * d * (a.q_dim * 2 + a.kv_dim * 2)
               + 2.0 * enc_tokens * 3 * d * cfg.d_ff
               + 4.0 * enc_tokens * F * a.q_dim) * eL
        cross = (2.0 * tokens * d * a.q_dim * 2
                 + 4.0 * tokens * F * a.q_dim) * L
        comps["encoder_cross"] = {
            "flops": passes * (enc + cross),
            "bytes": passes * eL * (2 * d * (a.q_dim + a.kv_dim)
                                    + 3 * d * cfg.d_ff) * bpe,
            "model_shards": msize if div(cfg.d_ff, msize) else 1,
        }
    # logits / embeddings (padded vocab always shards — §Perf pair 2)
    Vp = getattr(cfg, "padded_vocab", V)
    if div(Vp, msize) or div(d, msize):
        lshard = msize
    else:
        lshard = 1
    logit_tokens = tokens if shape_kind != "train" else tokens  # 'all' logits
    if shape_kind == "train" and cfg.technique_applies:
        logit_tokens = 0  # KL objective needs no logits
    comps["logits"] = {
        "flops": 2.0 * logit_tokens * d * V,
        "bytes": V * d * bpe,
        "model_shards": lshard,
    }
    if seq_sharded:
        # sequence parallelism: every per-token component's *compute* shards
        # over the model axis too (weights are still read replicated — the
        # bytes keep their base shard counts).
        for c in comps.values():
            c["flops_shards"] = msize * max(c["model_shards"] // msize, 1) \
                if c["model_shards"] == msize else msize
    # decode cache traffic
    if shape_kind == "decode" and a is not None:
        kv_shards = msize if (div(a.num_kv_heads, msize) or
                              div(seq, msize)) else 1
        comps["kv_cache_io"] = {
            "flops": 0.0,
            "bytes": 0.0,
            "data_bytes": L * batch * seq * a.kv_dim * 2 * bpe,
            "model_shards": kv_shards,
        }
    return comps


def per_device_cost(comps: dict, mesh_shape: dict, global_batch: int) -> dict:
    """Fold components into per-device (flops, bytes) given batch sharding."""
    dp = 1
    for k, v in mesh_shape.items():
        if k != "model":
            dp *= v
    if global_batch % dp != 0:
        dp = mesh_shape.get("data", 1) if (
            global_batch % mesh_shape.get("data", 1) == 0) else 1
    flops = sum(c["flops"] / (dp * c.get("flops_shards", c["model_shards"]))
                for c in comps.values())
    # weight-like traffic: every device reads its weight shard each step;
    # data-like traffic (KV/cache streams) also divides by the batch shards.
    bytes_ = sum(c["bytes"] / c["model_shards"]
                 + c.get("data_bytes", 0.0) / (dp * c["model_shards"])
                 for c in comps.values())
    return {"flops_per_dev": flops, "bytes_per_dev": bytes_, "dp_shards": dp}
