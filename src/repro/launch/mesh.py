"""Production meshes (TPU v5e target).

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis extends data parallelism across the slow inter-pod links (DCN-ish);
only gradient/activation all-reduces cross it, never tensor-parallel
collectives.

Functions, not module constants: importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """The batch-sharding axes of a mesh: ("pod","data") or ("data",)."""
    names = mesh.axis_names
    return tuple(n for n in names if n != "model")


def axis_size(mesh, name) -> int:
    return mesh.shape[name]
