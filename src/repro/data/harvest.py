"""Trace-harvested gt_oracle distillation dataset (the data half of the
paper's learning loop).

The serving engine cannot run the ``gt_oracle`` policy online — it scores a
prompt's keys with the *future* response's queries.  But every retired
request carries exactly that future: the tokens the engine just generated.
``HarvestWriter`` therefore rides the engine's retirement hook
(``ServingConfig.harvest``): for each retired request it records
``(prompt X, generated continuation Y)`` and replays ``[X; Y]`` through the
frozen model's scoring pass (``objective.gt_scores``), yielding the
per-(layer, q-head) gt importance of X's keys under Y's real queries —
the distillation targets of paper §3.2, harvested from live traffic
instead of a synthetic mixture.

On-disk layout: ``<out_dir>/shard_NNNNN.npz`` with per-record members
``x{i}`` (n_in,) int32, ``y{i}`` (n_obs,) int32, ``s{i}`` (L, H, n_in)
f32 and a record count ``n``.  ``HarvestIterator`` groups records by
prompt length and yields fixed-shape batches for
``launch/train.py --harvest``.

CLI — replay a Zipf-prefix / Poisson-arrival trace through the continuous
engine with the hook installed::

    PYTHONPATH=src python -m repro.data.harvest --arch smollm-135m --smoke \
        --out experiments/harvest --requests 32
"""

from __future__ import annotations

import functools
import glob
import os
from collections import defaultdict
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig
from repro.core import objective


@dataclass(frozen=True)
class HarvestConfig:
    out_dir: str
    max_obs: int = 32  # observation rows kept per record (generated tokens)
    min_obs: int = 1  # skip requests that generated fewer tokens
    shard_records: int = 64  # records buffered per npz shard


class HarvestWriter:
    """Engine capture hook: buffers retired requests, computes gt_oracle
    targets one record at a time (one compile per distinct
    ``(n_in, n_obs)`` shape — trace lengths cluster, so this stays small),
    and writes npz shards.

    Call ``flush()`` after ``engine.run(...)`` to drain the tail buffer.
    """

    def __init__(self, params: dict, cfg: ModelConfig, hcfg: HarvestConfig):
        self.params, self.cfg, self.hcfg = params, cfg, hcfg
        self._pending: list[tuple[np.ndarray, np.ndarray]] = []
        self._gt_fns: dict = {}
        self._shard = 0
        self.records_written = 0
        os.makedirs(hcfg.out_dir, exist_ok=True)
        # never clobber an existing dataset: append after its last shard
        existing = sorted(glob.glob(os.path.join(hcfg.out_dir,
                                                 "shard_*.npz")))
        if existing:
            self._shard = int(os.path.basename(existing[-1])[6:11]) + 1

    # -- engine hook ---------------------------------------------------------
    def on_retire(self, req) -> None:
        y = np.asarray(req.out_tokens[: self.hcfg.max_obs], np.int32)
        if y.size < self.hcfg.min_obs:
            return
        self._pending.append((np.asarray(req.prompt, np.int32), y))
        if len(self._pending) >= self.hcfg.shard_records:
            self.flush()

    # -- gt scoring ----------------------------------------------------------
    def _gt_fn(self, n_in: int):
        fn = self._gt_fns.get(n_in)
        if fn is None:
            fn = jax.jit(functools.partial(
                objective.gt_scores, self.params, self.cfg, n_in=n_in))
            self._gt_fns[n_in] = fn
        return fn

    def gt_record(self, x: np.ndarray, y: np.ndarray) -> np.ndarray:
        """(L, H, n_in) f32 gt_oracle scores of ``x``'s keys under ``y``'s
        queries — the frozen-model oracle pass over ``[x; y]``."""
        xy = jnp.asarray(np.concatenate([x, y]))[None]
        s = self._gt_fn(len(x))(xy)  # (L, 1, H, n_in)
        return np.asarray(s[:, 0], np.float32)

    def flush(self) -> None:
        if not self._pending:
            return
        pending, self._pending = self._pending, []
        records = [(x, y, self.gt_record(x, y)) for x, y in pending]
        path = os.path.join(self.hcfg.out_dir,
                            f"shard_{self._shard:05d}.npz")
        arrays: dict = {"n": np.asarray(len(records), np.int64)}
        for i, (x, y, s) in enumerate(records):
            arrays[f"x{i}"], arrays[f"y{i}"], arrays[f"s{i}"] = x, y, s
        np.savez(path, **arrays)
        self._shard += 1
        self.records_written += len(records)


# -- dataset reading ---------------------------------------------------------


def load_records(path: str) -> list[dict]:
    """All harvested records under ``path`` as
    ``{"x": (n_in,), "y": (n_obs,), "s": (L, H, n_in)}`` dicts, in shard
    order (deterministic across runs)."""
    records = []
    for f in sorted(glob.glob(os.path.join(path, "shard_*.npz"))):
        z = np.load(f)
        for i in range(int(z["n"])):
            records.append({"x": z[f"x{i}"], "y": z[f"y{i}"],
                            "s": z[f"s{i}"]})
    return records


class HarvestIterator:
    """Deterministic fixed-shape batches from a harvested dataset.

    Records are grouped by prompt length; each ``next()`` round-robins the
    length groups and samples ``batch`` records from the current group
    (with replacement, so small groups still fill a batch).  Yields
    ``{"x": (B, n_in) int32, "s_gt": (L, B, H, n_in) f32}`` — the inputs
    of ``objective.lkv_loss_from_targets``.  Sampling is a pure function
    of (seed, call index), so resuming a killed trainer only needs the
    iterator fast-forwarded by the step count.
    """

    def __init__(self, path: str, batch: int, *, seed: int = 0):
        self.records = load_records(path)
        if not self.records:
            raise FileNotFoundError(
                f"no harvest shards under {path!r} — run "
                "`python -m repro.data.harvest` first")
        groups = defaultdict(list)
        for i, r in enumerate(self.records):
            groups[len(r["x"])].append(i)
        self._groups = {k: np.asarray(v) for k, v in sorted(groups.items())}
        self._keys = sorted(self._groups)
        self.batch = batch
        self._rng = np.random.default_rng(seed)
        self._t = 0

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        k = self._keys[self._t % len(self._keys)]
        self._t += 1
        idx = self._rng.choice(self._groups[k], size=self.batch,
                               replace=True)
        xs = np.stack([self.records[i]["x"] for i in idx])
        ss = np.stack([self.records[i]["s"] for i in idx], axis=1)
        return {"x": xs.astype(np.int32), "s_gt": ss.astype(np.float32)}


# -- CLI: serve a trace with the hook installed -------------------------------


def harvest_trace(params, cfg, *, out_dir: str, requests: int = 32,
                  policy: str = "h2o", budget: int = 96, chunk: int = 64,
                  max_new: int = 16, max_obs: int = 16, num_slots: int = 4,
                  seed: int = 0, lkv_params=None) -> HarvestWriter:
    """Serve a Zipf-prefix / Poisson-arrival trace through
    ``ContinuousEngine`` with the capture hook installed; returns the
    (flushed) writer.  The serving policy only shapes the generated
    continuations — the targets themselves always come from the frozen
    full-cache oracle pass."""
    from repro.common.config import EvictionConfig
    from repro.data import synthetic
    from repro.serving import (ChunkingConfig, ContinuousEngine, Request,
                               ServingConfig)

    writer = HarvestWriter(params, cfg,
                           HarvestConfig(out_dir=out_dir, max_obs=max_obs))
    trace = synthetic.make_prefix_trace(seed, requests, cfg.vocab_size,
                                        chunk=chunk)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=max_new, arrival_s=t)
            for i, (p, t) in enumerate(trace)]
    max_len = max(len(r.prompt) for r in reqs)
    sc = ServingConfig(
        policy=policy, evict=EvictionConfig(budget=budget, draft_len=8),
        chunking=ChunkingConfig(chunk=chunk, max_context=max(max_len, chunk)),
        num_slots=num_slots, max_new_tokens=max_new, eos_id=-1,
        harvest=writer)
    eng = ContinuousEngine(params, cfg, sc, lkv_params=lkv_params)
    eng.run(reqs)
    writer.flush()
    return writer


def main(argv=None):
    import argparse

    from repro.configs import get_config, get_smoke_config
    from repro.core.lookahead import init_lookahead_params
    from repro.models import transformer as tf

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="experiments/harvest")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--policy", default="h2o",
                    help="serving policy during harvest (shapes the "
                         "generated continuations, not the targets)")
    ap.add_argument("--budget", type=int, default=96)
    ap.add_argument("--chunk", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-obs", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = tf.init_params(jax.random.PRNGKey(args.seed), cfg)
    lkv = None
    if args.policy == "lookaheadkv" and cfg.technique_applies and cfg.lookahead:
        lkv = init_lookahead_params(jax.random.PRNGKey(args.seed + 1), cfg,
                                    params["layers"])
    w = harvest_trace(params, cfg, out_dir=args.out, requests=args.requests,
                      policy=args.policy, budget=args.budget,
                      chunk=args.chunk, max_new=args.max_new,
                      max_obs=args.max_obs, num_slots=args.slots,
                      seed=args.seed, lkv_params=lkv)
    print(f"harvested {w.records_written} records -> {args.out}")


if __name__ == "__main__":
    main()
