"""Pallas TPU flash-decode: one query token against a long (possibly evicted)
KV cache.

Decode is memory-bound: the roofline term is cache bytes / HBM bandwidth, so
the kernel's job is to stream K/V tiles exactly once at full bandwidth while
the (1 × block_k) score tile lives in registers/VMEM.  grid = (B, H, nk),
key axis innermost with (m, l, acc) scratch carry — the flash-attention
recurrence specialized to a single query row.

Oracle: ``ref.decode_attention``.  jnp fallback in ``ops.decode_attention``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, m_scr, l_scr, acc_scr, *,
            nk, scale):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0, :].astype(jnp.float32)  # (hd,)
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (block_k, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    ok = mask_ref[0, :]  # (block_k,)
    s = (k @ q) * scale  # (block_k,)
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[0]
    m_new = jnp.maximum(m_prev, s.max())
    p = jnp.where(ok, jnp.exp(s - m_new), 0.0)  # (block_k,)
    corr = jnp.exp(m_prev - m_new)
    l_scr[0] = l_scr[0] * corr + p.sum()
    acc_scr[...] = acc_scr[...] * corr + p @ v  # (hd,)
    m_scr[0] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[0], 1e-30)
        o_ref[0, 0, :] = (acc_scr[...] / l).astype(o_ref.dtype)


def decode_attention_pallas(
    q: jnp.ndarray,  # (B, H, hd)
    k: jnp.ndarray,  # (B, Sk, KV, hd)
    v: jnp.ndarray,
    *,
    kv_mask: jnp.ndarray | None = None,  # (B, Sk)
    block_k: int = 1024,
    interpret: bool = False,
) -> jnp.ndarray:
    B, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    group = H // KV
    block_k = min(block_k, Sk)
    pad = (-Sk) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    mask = jnp.ones((B, Sk), bool) if kv_mask is None else kv_mask
    if pad:
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nk = (Sk + pad) // block_k
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(_kernel, nk=nk, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nk),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, h, ik: (b, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, ik, g=group: (b, ik, h // g, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, ik, g=group: (b, ik, h // g, 0)),
            pl.BlockSpec((1, block_k), lambda b, h, ik: (b, ik)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda b, h, ik: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((hd,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, mask)
