"""Pallas TPU kernel for LookaheadKV importance scores — the paper's hot spot.

Computes, per (batch, q-head), the mean over observation rows of the softmax
probability mass each prompt key receives:

    scores[b, h, j] = 1/n_obs · Σ_i  softmax_row_i(q_obs · Kᵀ / √d)[j]

TPU adaptation (DESIGN.md §3): the observation block (n_obs ≤ 128 rows of
hd ≤ 256) stays resident in VMEM; keys stream HBM→VMEM in (block_k, hd)
tiles.  Per-key normalized mass needs the *final* row normalizers, so the
grid runs the key axis twice (phase trick): phase 0 accumulates the online
(m, l) statistics into scratch, phase 1 re-streams each key tile and emits
``exp(s − m)/l`` column means directly — the (n_obs × Sk) score matrix never
hits HBM, and output traffic is Sk floats per head instead of n_obs·Sk.

grid = (B, H, 2·nk); phase = ik // nk.

This is the one masked streaming scoring primitive every observation-style
policy rides (chunked *and* monolithic prefill):

* ``q_offset`` is a *scalar-prefetched* (traced) observation-row base
  position, so one compiled program serves the deferred observation-window
  scoring of the snapkv family at any (traced) prompt length;
* ``window`` applies the sliding-window visibility of local layers;
* ``row_valid`` zeroes invalid observation rows (bucket padding) — they
  contribute exact zeros to the mean, whose denominator stays ``n_obs``
  (callers wanting a sum over valid rows rescale by ``n_obs``).

Oracle: ``ref.lookahead_score``.  jnp fallback: ``ops._chunked_lookahead_score``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(offs_ref, q_ref, k_ref, mask_ref, rv_ref, o_ref, m_scr, l_scr, *,
            n_obs, block_k, nk, scale, window):
    j = pl.program_id(2)
    ik = jnp.where(j < nk, j, j - nk)
    phase1 = j >= nk
    q0 = offs_ref[0]  # absolute position of obs row 0 (traced)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    q = q_ref[0, :, 0, :].astype(jnp.float32)  # (n_obs, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (block_k, hd)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (n_obs, block_k)

    q_pos = q0 + jax.lax.broadcasted_iota(jnp.int32, (n_obs, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (n_obs, block_k), 1)
    ok = k_pos <= q_pos  # causal among obs rows; prompt keys all visible
    if window is not None:
        ok &= (q_pos - k_pos) < window
    ok &= mask_ref[0, :][None, :]  # key validity (padding / evicted)
    s = jnp.where(ok, s, NEG_INF)

    @pl.when(jnp.logical_not(phase1))
    def _pass1():
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.where(ok, jnp.exp(s - m_new[:, None]), 0.0)
        l_scr[...] = l_scr[...] * jnp.exp(m_prev - m_new) + p.sum(axis=-1)
        m_scr[...] = m_new

    @pl.when(phase1)
    def _pass2():
        m = m_scr[...]
        l = jnp.maximum(l_scr[...], 1e-30)
        p = jnp.where(ok, jnp.exp(s - m[:, None]), 0.0) / l[:, None]
        p = p * rv_ref[0, :][:, None].astype(jnp.float32)
        o_ref[0, 0, :] = (p.sum(axis=0) / n_obs).astype(o_ref.dtype)


def lookahead_score_pallas(
    q_obs: jnp.ndarray,  # (B, n_obs, H, hd)
    k: jnp.ndarray,  # (B, Sk, KV, hd) — prompt keys then obs keys
    n_prompt: int,
    *,
    kv_mask: jnp.ndarray | None = None,  # (B, n_prompt)
    window: int | None = None,
    q_offset=None,  # scalar int32 (may be traced); default n_prompt
    row_valid: jnp.ndarray | None = None,  # (B, n_obs) real-row mask
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    B, n_obs, H, hd = q_obs.shape
    Sk, KV = k.shape[1], k.shape[2]
    group = H // KV
    scale = 1.0 / (hd ** 0.5)
    if window == 0:
        window = None

    block_k = min(block_k, Sk)
    pad = (-Sk) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    full_mask = jnp.ones((B, Sk), bool)
    if kv_mask is not None:
        full_mask = full_mask.at[:, :n_prompt].set(kv_mask)
    if pad:
        full_mask = jnp.pad(full_mask, ((0, 0), (0, pad)))
    if row_valid is None:
        row_valid = jnp.ones((B, n_obs), bool)
    Skp = Sk + pad
    nk = Skp // block_k
    offs = jnp.reshape(
        jnp.asarray(n_prompt if q_offset is None else q_offset, jnp.int32),
        (1,))

    kernel = functools.partial(
        _kernel, n_obs=n_obs, block_k=block_k, nk=nk, scale=scale,
        window=window,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, 2 * nk),
        in_specs=[
            pl.BlockSpec((1, n_obs, 1, hd), lambda b, h, j, offs: (b, 0, h, 0)),
            pl.BlockSpec(
                (1, block_k, 1, hd),
                lambda b, h, j, offs, g=group, nk=nk: (
                    b, jnp.where(j < nk, j, j - nk), h // g, 0
                ),
            ),
            pl.BlockSpec(
                (1, block_k),
                lambda b, h, j, offs, nk=nk: (b, jnp.where(j < nk, j, j - nk)),
            ),
            pl.BlockSpec((1, n_obs), lambda b, h, j, offs: (b, 0)),
        ],
        # phase-0 iterations park on block 0 (never written by the kernel in
        # that phase; phase 1's first iteration overwrites it before any
        # write-back escapes), phase-1 iterations emit block ik.
        out_specs=pl.BlockSpec(
            (1, 1, block_k),
            lambda b, h, j, offs, nk=nk: (b, h, jnp.where(j < nk, 0, j - nk)),
        ),
        scratch_shapes=[
            pltpu.VMEM((n_obs,), jnp.float32),
            pltpu.VMEM((n_obs,), jnp.float32),
        ],
    )
    scores = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, Skp), jnp.float32),
        interpret=interpret,
    )(offs, q_obs, k, full_mask, row_valid)
    return scores[..., :n_prompt]
