"""Pallas TPU paged flash-decode: one query token against a block-table KV
cache (the paged-KV companion of ``decode_attention.py``).

The cache is not a contiguous (B, Sk, KV, hd) array but a shared block
pool — ``k_pool``/``v_pool`` of shape (num_blocks, block_size, KV, hd)
plus a per-sequence **block table** (B, nb) of physical block ids
(``serving/kv_pool.py``).  The table rides the grid as a *scalar-prefetch*
operand: Pallas reads it before the kernel body runs, so each grid step's
``BlockSpec`` index map can point the K/V/mask DMA at
``table[b, block_index]`` directly — key tiles are gathered from HBM by
the pipeline itself, and no dense per-sequence copy of the cache ever
materializes.  This is the serving hot path: the engine's decode step
attends straight out of the pool (``attention.decode_attention_step_paged``).

Ragged tails need no special casing: unallocated table entries hold the
pool's null block (id 0), whose validity mask is permanently all-False,
so a fully-masked tile contributes exact zeros to the online-softmax
recurrence (``m`` carries, ``corr = exp(0) = 1``).  The mask is per kv
head — eviction keeps different token positions per head — which the
dense Pallas decode kernel does not support; here the mask tile is
block-indexed like K/V, so per-head validity is free.

Sliding windows ride the same machinery: ``pos_pool`` tiles are
block-indexed exactly like the mask, and the query token's absolute
position (``new_pos``, per sequence) plus the window width are scalar-
prefetched next to the table, so the kernel applies
``new_pos - pos < window`` per key row with zero extra host logic — a
*traced* window (patterned local:global archs scan it through the layer
loop) takes this path too, it is just another prefetched scalar.

grid = (B, H, nb), key-block axis innermost with (m, l, acc) scratch
carry — the same flash-decode recurrence as ``decode_attention.py``, with
the key stream indirected through the table.

Oracle: ``ref.paged_decode_attention``.  jnp fallbacks in
``ops.paged_decode_attention`` (gather oracle at small depth, streaming
block scan beyond).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_tile(ib, nb, q_ref, k_ref, v_ref, ok, o_ref,
                m_scr, l_scr, acc_scr, scale):
    """One key-block step of the online-softmax recurrence.  ``ok`` is the
    (block_size,) attendability of this tile's rows for this kv head —
    validity mask, optionally pre-ANDed with the sliding-window predicate."""

    @pl.when(ib == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0, :].astype(jnp.float32)  # (hd,)
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (block_size, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = (k @ q) * scale
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[0]
    m_new = jnp.maximum(m_prev, s.max())
    p = jnp.where(ok, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_scr[0] = l_scr[0] * corr + p.sum()
    acc_scr[...] = acc_scr[...] * corr + p @ v
    m_scr[0] = m_new

    @pl.when(ib == nb - 1)
    def _finish():
        l = jnp.maximum(l_scr[0], 1e-30)
        o_ref[0, 0, :] = (acc_scr[...] / l).astype(o_ref.dtype)


def _kernel(tbl_ref, q_ref, k_ref, v_ref, mask_ref, o_ref,
            m_scr, l_scr, acc_scr, *, nb, scale):
    ok = mask_ref[0, :, 0]  # (block_size,) — this kv head's validity
    _flash_tile(pl.program_id(2), nb, q_ref, k_ref, v_ref, ok, o_ref,
                m_scr, l_scr, acc_scr, scale)


def _kernel_windowed(tbl_ref, npos_ref, win_ref, q_ref, k_ref, v_ref,
                     mask_ref, pos_ref, o_ref, m_scr, l_scr, acc_scr,
                     *, nb, scale):
    b = pl.program_id(0)
    pos = pos_ref[0, :, 0]  # (block_size,) int32 absolute positions
    ok = mask_ref[0, :, 0] & ((npos_ref[b] - pos) < win_ref[0])
    _flash_tile(pl.program_id(2), nb, q_ref, k_ref, v_ref, ok, o_ref,
                m_scr, l_scr, acc_scr, scale)


def paged_decode_attention_pallas(
    q: jnp.ndarray,  # (B, H, hd)
    k_pool: jnp.ndarray,  # (N, block_size, KV, hd) shared block pool
    v_pool: jnp.ndarray,
    mask_pool: jnp.ndarray,  # (N, block_size, KV) per-head slot validity
    table: jnp.ndarray,  # (B, nb) int32 physical block ids (0 = null)
    *,
    pos_pool: jnp.ndarray | None = None,  # (N, block_size, KV) int32
    new_pos: jnp.ndarray | None = None,  # (B,) query-token positions
    window=None,  # None | python int | traced int32 scalar
    interpret: bool = False,
) -> jnp.ndarray:
    """Flash decode over a paged cache.  Rows the caller considers dead
    (beyond the logical depth, or holding a stale previous owner's data)
    must be masked False in ``mask_pool`` — the mask is the single source
    of validity, exactly as in the dense cache layout.  With ``window``
    set, rows also need ``new_pos - pos < window`` to be attended
    (``pos_pool``/``new_pos`` become required); a sequence/head left with
    no attendable row returns exact zeros."""
    B, H, hd = q.shape
    N, bs, KV, _ = k_pool.shape
    nb = table.shape[1]
    group = H // KV
    scale = 1.0 / (hd ** 0.5)

    scratch_shapes = [
        pltpu.VMEM((1,), jnp.float32),
        pltpu.VMEM((1,), jnp.float32),
        pltpu.VMEM((hd,), jnp.float32),
    ]
    if window is None:
        kernel = functools.partial(_kernel, nb=nb, scale=scale)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, H, nb),
            in_specs=[
                pl.BlockSpec((1, 1, hd), lambda b, h, ib, tbl: (b, h, 0)),
                pl.BlockSpec((1, bs, 1, hd),
                             lambda b, h, ib, tbl, g=group: (tbl[b, ib], 0,
                                                             h // g, 0)),
                pl.BlockSpec((1, bs, 1, hd),
                             lambda b, h, ib, tbl, g=group: (tbl[b, ib], 0,
                                                             h // g, 0)),
                pl.BlockSpec((1, bs, 1),
                             lambda b, h, ib, tbl, g=group: (tbl[b, ib], 0,
                                                             h // g)),
            ],
            out_specs=pl.BlockSpec((1, 1, hd),
                                   lambda b, h, ib, tbl: (b, h, 0)),
            scratch_shapes=scratch_shapes,
        )
        return pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
            interpret=interpret,
        )(table.astype(jnp.int32), q, k_pool, v_pool, mask_pool)

    assert pos_pool is not None and new_pos is not None, \
        "sliding-window masking needs pos_pool and new_pos"
    kernel = functools.partial(_kernel_windowed, nb=nb, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,  # table, new_pos, window
        grid=(B, H, nb),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, h, ib, t, n, w: (b, h, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda b, h, ib, t, n, w, g=group: (t[b, ib], 0,
                                                             h // g, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda b, h, ib, t, n, w, g=group: (t[b, ib], 0,
                                                             h // g, 0)),
            pl.BlockSpec((1, bs, 1),
                         lambda b, h, ib, t, n, w, g=group: (t[b, ib], 0,
                                                             h // g)),
            pl.BlockSpec((1, bs, 1),
                         lambda b, h, ib, t, n, w, g=group: (t[b, ib], 0,
                                                             h // g)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd),
                               lambda b, h, ib, t, n, w: (b, h, 0)),
        scratch_shapes=scratch_shapes,
    )
    win = jnp.asarray(window, jnp.int32).reshape(1)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=interpret,
    )(table.astype(jnp.int32), new_pos.astype(jnp.int32), win,
      q, k_pool, v_pool, mask_pool, pos_pool)


# ---------------------------------------------------------------------------
# fused masses: decode attention + per-key softmax masses in one pass
# ---------------------------------------------------------------------------
#
# The decode-eviction scorer needs the probability mass the query token put
# on every cached row — the single-token analogue of the fused chunk-score
# kernel in ``chunk_attention.py``, and it reuses that kernel's two-phase
# trick: the grid's innermost axis runs 2*nb steps.  Phase 0 (j < nb) is the
# unmodified flash recurrence; once it ends, the scratch holds the *final*
# (m, l) statistics, so phase 1 (j >= nb) revisits each key tile, recomputes
# the scaled logits (cheap: one (block_size, hd) matmul), and emits the
# normalized masses ``exp(s - m) / l`` per row.  V tiles park on the null
# block during phase 1 (they are not read), and the mass output block parks
# on tile 0 during phase 0 — safe because phase 1's first step overwrites it
# before the pipeline's write-back moves on.  The attention output is
# *bitwise* the plain kernel's: phase 0 is the same instruction sequence.


def _masses_tile(j, nb, q_ref, k_ref, v_ref, ok, o_ref, mass_ref,
                 m_scr, l_scr, acc_scr, scale):
    phase0 = j < nb

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0, :].astype(jnp.float32)  # (hd,)
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (block_size, hd)
    s = (k @ q) * scale
    s = jnp.where(ok, s, NEG_INF)

    @pl.when(phase0)
    def _flash():
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        m_prev = m_scr[0]
        m_new = jnp.maximum(m_prev, s.max())
        p = jnp.where(ok, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[0] = l_scr[0] * corr + p.sum()
        acc_scr[...] = acc_scr[...] * corr + p @ v
        m_scr[0] = m_new

    @pl.when(j == nb - 1)
    def _finish():
        l = jnp.maximum(l_scr[0], 1e-30)
        o_ref[0, 0, :] = (acc_scr[...] / l).astype(o_ref.dtype)

    @pl.when(jnp.logical_not(phase0))
    def _masses():
        l = jnp.maximum(l_scr[0], 1e-30)
        mass_ref[0, 0, :] = jnp.where(ok, jnp.exp(s - m_scr[0]), 0.0) / l


def _masses_kernel(tbl_ref, q_ref, k_ref, v_ref, mask_ref, o_ref, mass_ref,
                   m_scr, l_scr, acc_scr, *, nb, scale):
    ok = mask_ref[0, :, 0]
    _masses_tile(pl.program_id(2), nb, q_ref, k_ref, v_ref, ok, o_ref,
                 mass_ref, m_scr, l_scr, acc_scr, scale)


def _masses_kernel_windowed(tbl_ref, npos_ref, win_ref, q_ref, k_ref, v_ref,
                            mask_ref, pos_ref, o_ref, mass_ref,
                            m_scr, l_scr, acc_scr, *, nb, scale):
    b = pl.program_id(0)
    pos = pos_ref[0, :, 0]
    ok = mask_ref[0, :, 0] & ((npos_ref[b] - pos) < win_ref[0])
    _masses_tile(pl.program_id(2), nb, q_ref, k_ref, v_ref, ok, o_ref,
                 mass_ref, m_scr, l_scr, acc_scr, scale)


def paged_decode_masses_pallas(
    q: jnp.ndarray,  # (B, H, hd)
    k_pool: jnp.ndarray,  # (N, block_size, KV, hd)
    v_pool: jnp.ndarray,
    mask_pool: jnp.ndarray,  # (N, block_size, KV)
    table: jnp.ndarray,  # (B, nb) int32
    *,
    pos_pool: jnp.ndarray | None = None,
    new_pos: jnp.ndarray | None = None,
    window=None,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Paged flash decode that also returns the query's normalized softmax
    masses over every table row: (out (B, H, hd), masses (B, H, nb*bs) f32).
    ``out`` is bitwise ``paged_decode_attention_pallas``; masked rows carry
    exact-zero mass.  Oracle: ``ref.paged_decode_masses``."""
    B, H, hd = q.shape
    N, bs, KV, _ = k_pool.shape
    nb = table.shape[1]
    group = H // KV
    scale = 1.0 / (hd ** 0.5)

    scratch_shapes = [
        pltpu.VMEM((1,), jnp.float32),
        pltpu.VMEM((1,), jnp.float32),
        pltpu.VMEM((hd,), jnp.float32),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        jax.ShapeDtypeStruct((B, H, nb * bs), jnp.float32),
    ]

    def _ib(j):  # key-block index: phase 0 walks 0..nb-1, phase 1 repeats it
        return jnp.where(j < nb, j, j - nb)

    if window is None:
        kernel = functools.partial(_masses_kernel, nb=nb, scale=scale)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, H, 2 * nb),
            in_specs=[
                pl.BlockSpec((1, 1, hd), lambda b, h, j, tbl: (b, h, 0)),
                pl.BlockSpec((1, bs, 1, hd),
                             lambda b, h, j, tbl, g=group:
                             (tbl[b, _ib(j)], 0, h // g, 0)),
                pl.BlockSpec((1, bs, 1, hd),  # v: park on null block in ph. 1
                             lambda b, h, j, tbl, g=group:
                             (jnp.where(j < nb, tbl[b, _ib(j)], 0), 0,
                              h // g, 0)),
                pl.BlockSpec((1, bs, 1),
                             lambda b, h, j, tbl, g=group:
                             (tbl[b, _ib(j)], 0, h // g)),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, hd), lambda b, h, j, tbl: (b, h, 0)),
                pl.BlockSpec((1, 1, bs),
                             lambda b, h, j, tbl:
                             (b, h, jnp.where(j < nb, 0, j - nb))),
            ],
            scratch_shapes=scratch_shapes,
        )
        return pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(table.astype(jnp.int32), q, k_pool, v_pool, mask_pool)

    assert pos_pool is not None and new_pos is not None, \
        "sliding-window masking needs pos_pool and new_pos"
    kernel = functools.partial(_masses_kernel_windowed, nb=nb, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,  # table, new_pos, window
        grid=(B, H, 2 * nb),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, h, j, t, n, w: (b, h, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda b, h, j, t, n, w, g=group:
                         (t[b, _ib(j)], 0, h // g, 0)),
            pl.BlockSpec((1, bs, 1, hd),  # v: park on null block in phase 1
                         lambda b, h, j, t, n, w, g=group:
                         (jnp.where(j < nb, t[b, _ib(j)], 0), 0, h // g, 0)),
            pl.BlockSpec((1, bs, 1),
                         lambda b, h, j, t, n, w, g=group:
                         (t[b, _ib(j)], 0, h // g)),
            pl.BlockSpec((1, bs, 1),
                         lambda b, h, j, t, n, w, g=group:
                         (t[b, _ib(j)], 0, h // g)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, h, j, t, n, w: (b, h, 0)),
            pl.BlockSpec((1, 1, bs),
                         lambda b, h, j, t, n, w:
                         (b, h, jnp.where(j < nb, 0, j - nb))),
        ],
        scratch_shapes=scratch_shapes,
    )
    win = jnp.asarray(window, jnp.int32).reshape(1)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(table.astype(jnp.int32), new_pos.astype(jnp.int32), win,
      q, k_pool, v_pool, mask_pool, pos_pool)
