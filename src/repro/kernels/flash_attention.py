"""Pallas TPU flash attention (prefill, causal / sliding-window, GQA).

Tiling: grid = (B, H, nq, nk) with the key axis innermost (sequential).
Per-step working set in VMEM: a (block_q, hd) query tile, (block_k, hd)
key/value tiles, and f32 accumulators (m, l, acc) in scratch — the (Sq, Sk)
score matrix never exists.  MXU alignment: block_q/block_k default to
128-multiples; hd is 32–256 in our configs.

GQA is handled in the index map: the key/value block for query head ``h``
reads kv head ``h // group`` — no materialized repeat.

Oracle: ``ref.attention``.  jnp fallback with identical math:
``ops._chunked_attention``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            causal, window, block_q, block_k, nk, scale):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :].astype(jnp.float32)  # (bq, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bk, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bk)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    ok = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        ok &= k_pos <= q_pos
    if window is not None:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.where(ok, jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Sk, KV, hd)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    group = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (
        "pad sequences to block multiples before calling the kernel"
    )
    nq, nk = Sq // block_q, Sk // block_k
    scale = 1.0 / (hd ** 0.5)
    if window == 0:
        window = None

    kernel = functools.partial(
        _kernel, causal=causal, window=window,
        block_q=block_q, block_k=block_k, nk=nk, scale=scale,
    )
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, iq, ik, g=group: (b, ik, h // g, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, iq, ik, g=group: (b, ik, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd),
                               lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
