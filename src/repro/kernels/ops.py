"""Public, jit-friendly wrappers for every kernel.

Dispatch policy
---------------
* On TPU (``jax.default_backend() == "tpu"``) or when ``REPRO_FORCE_PALLAS=1``
  (used by the interpret-mode kernel tests), the Pallas kernels in this
  package are used.
* Otherwise a memory-bounded, pure-jnp *chunked* implementation runs.  These
  fallbacks implement the same streaming algorithms as the kernels (online
  softmax, chunked SSD) so the CPU dry-run lowers with bounded temporaries —
  which is what the roofline reads.
* ``reference_mode()`` overrides both: the Pallas kernels are forward-only
  (no custom VJP), so any code that must trace under ``jax.grad`` — the
  distillation objective — wraps its forward pass in this context and gets
  the differentiable jnp path regardless of backend or env.

Every wrapper has a matching naive oracle in ``ref.py``.
"""

from __future__ import annotations

import contextlib
import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.ref import NEG_INF, _expand_gqa

# Sequence lengths at or below this threshold just call the naive path: the
# full score block is small enough that chunking only adds overhead.
_DIRECT_SEQ = 2048

# Trace-time override: when truthy, use_pallas() is False no matter what.
# Only mutated by reference_mode(); read at trace time, so a jitted function
# traced inside the context bakes in the jnp path.
_REFERENCE_ONLY = False


@contextlib.contextmanager
def reference_mode():
    """Force the differentiable jnp dispatch path while tracing.

    The Pallas kernels have no custom VJP — differentiating through
    ``pallas_call`` raises.  Training code (``core/objective``) traces its
    forward pass inside this context so gradients flow through the jnp
    implementations on every backend, including TPU and REPRO_FORCE_PALLAS=1.
    """
    global _REFERENCE_ONLY
    prev = _REFERENCE_ONLY
    _REFERENCE_ONLY = True
    try:
        yield
    finally:
        _REFERENCE_ONLY = prev


def use_pallas() -> bool:
    if _REFERENCE_ONLY:
        return False
    if os.environ.get("REPRO_FORCE_PALLAS") == "1":
        return True
    return jax.default_backend() == "tpu"


def _pallas_interpret() -> bool:
    """interpret=True whenever we are not actually on a TPU."""
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# flash attention (prefill)
# ---------------------------------------------------------------------------


def flash_attention(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Sk, KV, hd)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window=None,  # None = unbounded; python int (static) or traced int32 scalar
    q_offset: int = 0,
    kv_mask: jnp.ndarray | None = None,
    block_q: int = 512,
    block_k: int = 1024,
) -> jnp.ndarray:
    """Masked (GQA) attention with bounded temporaries.

    ``q_offset`` is the absolute position of q row 0 relative to k row 0
    (prefill: Sk - Sq when queries are the tail of the key sequence).
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    static_window = window is None or isinstance(window, int)
    if (use_pallas() and kv_mask is None and Sq == Sk and q_offset == 0
            and static_window):
        from repro.kernels import flash_attention as fk

        return fk.flash_attention_pallas(
            q, k, v, causal=causal, window=window,
            block_q=min(block_q, Sq), block_k=min(block_k, Sk),
            interpret=_pallas_interpret(),
        )
    if Sk <= _DIRECT_SEQ:
        from repro.kernels import ref

        q_pos = jnp.broadcast_to(q_offset + jnp.arange(Sq), (B, Sq))
        return ref.attention(
            q, k, v, causal=causal, window=window,
            q_pos=q_pos, kv_mask=kv_mask,
        )
    return _chunked_attention(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        kv_mask=kv_mask, block_q=block_q, block_k=block_k,
    )


def _chunked_attention(q, k, v, *, causal, window, q_offset, kv_mask,
                       block_q, block_k):
    """Online-softmax attention: scan over q blocks × k blocks (jnp flash)."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    group = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    pad_q = (-Sq) % block_q
    pad_k = (-Sk) % block_k
    qf = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kf = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vf = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    kvm = jnp.ones((B, Sk), bool) if kv_mask is None else kv_mask
    kvm = jnp.pad(kvm, ((0, 0), (0, pad_k)))
    nq, nk = qf.shape[1] // block_q, kf.shape[1] // block_k
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    kf = kf.reshape(B, nk, block_k, KV, hd)
    vf = vf.reshape(B, nk, block_k, KV, hd)
    kvm = kvm.reshape(B, nk, block_k)
    qf = qf.reshape(B, nq, block_q, H, hd)

    def q_block(iq, qb):
        # qb: (B, block_q, H, hd)
        q_pos = q_offset + iq * block_q + jnp.arange(block_q)

        def k_block(carry, inputs):
            m, l, acc = carry  # (B,H,bq), (B,H,bq), (B,H,bq,hd)
            ik, kb, vb, mb = inputs
            k_pos = ik * block_k + jnp.arange(block_k)
            kbf = _expand_gqa(kb, group)
            vbf = _expand_gqa(vb, group)
            s = jnp.einsum(
                "bqhd,bkhd->bhqk", qb.astype(jnp.float32),
                kbf.astype(jnp.float32),
            ) * scale
            ok = jnp.ones((block_q, block_k), bool)
            if causal:
                ok &= k_pos[None, :] <= q_pos[:, None]
            if window is not None:
                ok &= (q_pos[:, None] - k_pos[None, :]) < window
            ok = ok[None, :, :] & mb[:, None, :]
            s = jnp.where(ok[:, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vbf.astype(jnp.float32))
            return (m_new, l, acc), None

        init = (
            jnp.full((B, H, block_q), NEG_INF, jnp.float32),
            jnp.zeros((B, H, block_q), jnp.float32),
            jnp.zeros((B, H, block_q, hd), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(
            k_block, init,
            (jnp.arange(nk), jnp.moveaxis(kf, 1, 0), jnp.moveaxis(vf, 1, 0),
             jnp.moveaxis(kvm, 1, 0)),
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.moveaxis(out, 1, 2)  # (B, block_q, H, hd)

    outs = jax.lax.map(lambda args: q_block(*args),
                       (jnp.arange(nq), jnp.moveaxis(qf, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * block_q, H, hd)
    return out[:, :Sq].astype(q.dtype)


# ---------------------------------------------------------------------------
# cross-chunk attention (streaming / chunked prefill)
# ---------------------------------------------------------------------------


def chunk_attention(
    q: jnp.ndarray,  # (B, C, H, hd) rotary-encoded chunk queries
    k: jnp.ndarray,  # (B, K, KV, hd) materialized key buffer (col j = pos j)
    v: jnp.ndarray,
    *,
    q_offset,  # scalar int32 (usually traced) — position of q row 0
    window=None,  # None | python int | traced int32 scalar
    score_masses: bool = False,  # also emit summed softmax column masses
    n_total=None,  # scalar int32 — rows at/past it contribute zero mass
    block_q: int = 256,
    block_k: int = 1024,
):
    """Attention of one prefill chunk over the prompt-so-far buffer.

    Prior keys (columns < ``q_offset``) are fully visible, the chunk is
    causal within itself, and columns at or beyond the chunk end are
    causally invisible — so the buffer may be deeper than the tokens
    streamed so far without any explicit validity mask.  ``q_offset`` is
    traced: one compiled program serves every chunk position.

    With ``score_masses=True`` the return value is ``(out, masses)`` where
    ``masses[b, h, j] = Σ_i softmax_row_i[j]`` over the chunk's *valid*
    rows (``q_offset + i < n_total``; all rows when ``n_total`` is None) —
    the cumulative (h2o) eviction-score partial, fused into the streaming
    pass so the (C, K) probability block never materializes on the Pallas
    or large-buffer paths.  The small-buffer jnp path scores through the
    dense ``ref.chunk_column_masses`` oracle (chunking only adds overhead
    there, and the dense sum preserves bit-exact chunked-vs-monolithic
    eviction parity on CPU).
    """
    B, C, H, hd = q.shape
    K = k.shape[1]
    static_window = window is None or isinstance(window, int)
    if use_pallas() and static_window:
        from repro.kernels import chunk_attention as ck

        if score_masses:
            nt = q_offset + C if n_total is None else n_total
            return ck.chunk_attention_masses_pallas(
                q, k, v, q_offset, nt, window=window,
                block_k=min(block_k, K), interpret=_pallas_interpret(),
            )
        return ck.chunk_attention_pallas(
            q, k, v, q_offset, window=window, block_k=min(block_k, K),
            interpret=_pallas_interpret(),
        )
    row_valid = None
    if score_masses and n_total is not None:
        row_valid = jnp.broadcast_to(
            (jnp.asarray(q_offset, jnp.int32) + jnp.arange(C))[None]
            < n_total, (B, C))
    if K <= _DIRECT_SEQ:
        from repro.kernels import ref

        q_pos = jnp.broadcast_to(
            jnp.asarray(q_offset, jnp.int32) + jnp.arange(C), (B, C))
        out = ref.attention(q, k, v, causal=True, window=window, q_pos=q_pos)
        if score_masses:
            masses = ref.chunk_column_masses(
                q, k, q_offset=q_offset, window=window, row_valid=row_valid)
            return out, masses
        return out
    out = _chunked_attention(
        q, k, v, causal=True, window=window, q_offset=q_offset,
        kv_mask=None, block_q=block_q, block_k=block_k,
    )
    if score_masses:
        masses = _chunked_lookahead_score(
            q, k, K, kv_mask=None, window=window, q_offset=q_offset,
            row_valid=row_valid, reduce="sum", block_k=block_k,
        )
        return out, masses
    return out


# ---------------------------------------------------------------------------
# decode attention (single new token vs long cache)
# ---------------------------------------------------------------------------


def decode_attention(
    q: jnp.ndarray,  # (B, H, hd)
    k: jnp.ndarray,  # (B, Sk, KV, hd)
    v: jnp.ndarray,
    *,
    kv_mask: jnp.ndarray | None = None,  # (B, Sk) or (B, Sk, KV) per-head
    block_k: int = 2048,
) -> jnp.ndarray:
    B, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    if use_pallas() and (kv_mask is None or kv_mask.ndim == 2):
        from repro.kernels import decode_attention as dk

        return dk.decode_attention_pallas(
            q, k, v, kv_mask=kv_mask, block_k=min(block_k, Sk),
            interpret=_pallas_interpret(),
        )
    # Single-query decode: always take the direct einsum on the jnp path.
    # The (B, H, Sk) logits are small (one row per sequence), and — crucially
    # for SPMD — the direct form lets XLA keep a sequence-sharded cache
    # sharded (partial softmax + tiny all-reduces).  The chunked fallback
    # below scans over key blocks, which *gathers* a seq-sharded cache every
    # block (§Perf decode iteration 1, refuted-then-fixed hypothesis).
    if kv_mask is None or kv_mask.ndim in (2, 3):
        from repro.kernels import ref

        return ref.decode_attention(q, k, v, kv_mask=kv_mask)
    group = H // KV
    pad = (-Sk) % block_k
    kf = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vf = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    if kv_mask is None:
        kvm = jnp.ones((B, Sk, KV), bool)
    elif kv_mask.ndim == 2:
        kvm = jnp.broadcast_to(kv_mask[..., None], (B, Sk, KV))
    else:
        kvm = kv_mask
    kvm = jnp.pad(kvm, ((0, 0), (0, pad), (0, 0)))
    nk = kf.shape[1] // block_k
    kf = jnp.moveaxis(kf.reshape(B, nk, block_k, KV, hd), 1, 0)
    vf = jnp.moveaxis(vf.reshape(B, nk, block_k, KV, hd), 1, 0)
    kvm = jnp.moveaxis(kvm.reshape(B, nk, block_k, KV), 1, 0)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    def body(carry, inputs):
        m, l, acc = carry
        kb, vb, mb = inputs
        kbf = _expand_gqa(kb, group).astype(jnp.float32)
        vbf = _expand_gqa(vb, group).astype(jnp.float32)
        s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32), kbf) * scale
        # mb: (B, block_k, KV) -> (B, H, block_k)
        mh = jnp.repeat(jnp.moveaxis(mb, 2, 1), group, axis=1)
        s = jnp.where(mh, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bhk,bkhd->bhd", p, vbf)
        return (m_new, l, acc), None

    init = (
        jnp.full((B, H), NEG_INF, jnp.float32),
        jnp.zeros((B, H), jnp.float32),
        jnp.zeros((B, H, hd), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(body, init, (kf, vf, kvm))
    return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)


# ---------------------------------------------------------------------------
# paged decode attention (block-table KV pool)
# ---------------------------------------------------------------------------


def paged_decode_attention(
    q: jnp.ndarray,  # (B, H, hd) single query token
    k_pool: jnp.ndarray,  # (N, block_size, KV, hd) shared block pool
    v_pool: jnp.ndarray,
    mask_pool: jnp.ndarray,  # (N, block_size, KV) per-head slot validity
    table: jnp.ndarray,  # (B, nb) int32 physical block ids (0 = null)
    *,
    pos_pool: jnp.ndarray | None = None,  # (N, block_size, KV) int32
    new_pos: jnp.ndarray | None = None,  # (B,) query-token positions
    window=None,  # None | python int | traced int32 scalar
    depth: int | None = None,  # static logical cache depth (jnp gather path)
    score_masses: bool = False,  # also emit normalized per-row softmax masses
):
    """Decode attention over a paged KV cache (``serving/kv_pool.py``) —
    the serving hot path of ``attention.decode_attention_step_paged``.

    Three dispatch tiers (``paged_decode_path`` names the active one):

    * **kernel** — the Pallas kernel scalar-prefetches the block table
      (plus ``new_pos`` and the window width, which may be *traced*) and
      streams K/V/mask/pos tiles straight from the pool: no dense
      per-sequence copy of the cache exists anywhere on this path.
    * **gather** — jnp dispatch at small depth: gathers the block-table
      view (``ref.gather_paged``, an exact bitwise copy of the pooled
      rows), slices it to ``depth``, and runs the same direct decode
      attention as the dense path — which is what makes paged serving
      bit-identical to dense serving on the jnp dispatch.  This is also
      the test oracle the kernel is checked against.
    * **fallback** — jnp dispatch beyond ``_DIRECT_SEQ`` rows: a
      streaming block scan with the kernel's online-softmax recurrence
      and bounded (B, block_size) temporaries — the memory-traffic shape
      the roofline budget reads (``benchmarks/bench_kernels.py``).

    Dead rows — null blocks behind ragged tables, tails beyond a slot's
    cursor, stale rows of a reallocated block — must be masked False in
    ``mask_pool``; the mask is the single source of validity.  With
    ``window``, rows additionally need ``new_pos - pos < window``.

    With ``score_masses=True`` the return value is ``(out, masses)`` where
    ``masses[b, h, j]`` is the query's normalized softmax probability on
    logical row ``j`` — the decode-time analogue of ``chunk_attention``'s
    fused column masses, streamed into cumulative H2O scores by the
    serving engine's decode-eviction sweep.  ``out`` stays bitwise the
    ``score_masses=False`` result on every tier (the Pallas two-phase
    kernel reruns the identical flash recurrence; the jnp tiers reuse the
    unmodified attention), masked rows carry exact-zero mass, and
    ``masses`` has ``depth`` columns when ``depth`` is given (else
    ``nb * block_size``).
    """
    if use_pallas():
        from repro.kernels import paged_attention as pk

        if score_masses:
            out, masses = pk.paged_decode_masses_pallas(
                q, k_pool, v_pool, mask_pool, table, pos_pool=pos_pool,
                new_pos=new_pos, window=window,
                interpret=_pallas_interpret(),
            )
            return out, (masses if depth is None else masses[..., :depth])
        return pk.paged_decode_attention_pallas(
            q, k_pool, v_pool, mask_pool, table, pos_pool=pos_pool,
            new_pos=new_pos, window=window, interpret=_pallas_interpret(),
        )
    span = table.shape[1] * k_pool.shape[1]
    if depth is not None:
        span = min(span, depth)
    if span <= _DIRECT_SEQ:
        from repro.kernels import ref

        out = ref.paged_decode_attention(
            q, k_pool, v_pool, mask_pool, table, pos_pool=pos_pool,
            new_pos=new_pos, window=window, depth=depth)
        if score_masses:
            masses = ref.paged_decode_masses(
                q, k_pool, mask_pool, table, pos_pool=pos_pool,
                new_pos=new_pos, window=window, depth=depth)
            return out, masses
        return out
    # beyond the direct threshold the dense gather is the O(depth) HBM
    # copy the paged layout exists to avoid; rows past ``depth`` are
    # masked False by construction (appends clamp at depth), so the
    # streaming scan needs no slice
    res = _paged_decode_streaming(
        q, k_pool, v_pool, mask_pool, table, pos_pool=pos_pool,
        new_pos=new_pos, window=window, score_masses=score_masses)
    if score_masses:
        out, masses = res
        return out, (masses if depth is None else masses[..., :depth])
    return res


def paged_decode_path(span: int) -> str:
    """Which ``paged_decode_attention`` tier serves a logical cache of
    ``span`` rows in the current environment: ``"kernel"`` (Pallas),
    ``"gather"`` (jnp direct, the bit-exact oracle) or ``"fallback"``
    (jnp streaming block scan)."""
    if use_pallas():
        return "kernel"
    return "gather" if span <= _DIRECT_SEQ else "fallback"


def _paged_decode_streaming(q, k_pool, v_pool, mask_pool, table, *,
                            pos_pool=None, new_pos=None, window=None,
                            score_masses=False):
    """Gather-free jnp fallback: scan over block-table columns with the
    kernel's online-softmax recurrence — one (B, block_size) K/V tile in
    flight per step, never a dense (B, depth, ...) copy.  With
    ``score_masses`` a second scan revisits each tile with the final
    (m, l) statistics and emits its normalized masses — the streaming
    analogue of the Pallas two-phase kernel, with the same bounded
    temporaries."""
    B, H, hd = q.shape
    bs, KV = k_pool.shape[1], k_pool.shape[2]
    group = H // KV
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qf = q.astype(jnp.float32)
    cols = jnp.moveaxis(table.astype(jnp.int32), 1, 0)  # (nb, B)

    def tile_logits(tb):
        kb = _expand_gqa(k_pool[tb], group).astype(jnp.float32)
        mb = mask_pool[tb]  # (B, bs, KV)
        if window is not None:
            mb = mb & ((new_pos[:, None, None] - pos_pool[tb]) < window)
        s = jnp.einsum("bhd,bkhd->bhk", qf, kb) * scale
        mh = jnp.repeat(jnp.moveaxis(mb, 2, 1), group, axis=1)  # (B, H, bs)
        return jnp.where(mh, s, NEG_INF), mh

    def body(carry, tb):
        m, l, acc = carry
        vb = _expand_gqa(v_pool[tb], group).astype(jnp.float32)
        s, mh = tile_logits(tb)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # the explicit where keeps fully-dead rows at l == 0 (m stays
        # NEG_INF, so exp(s - m) would be exp(0) = 1, not 0)
        p = jnp.where(mh, jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bhk,bkhd->bhd", p, vb)
        return (m_new, l, acc), None

    init = (
        jnp.full((B, H), NEG_INF, jnp.float32),
        jnp.zeros((B, H), jnp.float32),
        jnp.zeros((B, H, hd), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(body, init, cols)
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
    if not score_masses:
        return out
    lsafe = jnp.maximum(l, 1e-30)

    def mass_tile(_, tb):
        s, mh = tile_logits(tb)
        p = jnp.where(mh, jnp.exp(s - m[..., None]), 0.0) / lsafe[..., None]
        return None, p  # (B, H, bs)

    _, tiles = jax.lax.scan(mass_tile, None, cols)  # (nb, B, H, bs)
    nb = cols.shape[0]
    masses = jnp.moveaxis(tiles, 0, 2).reshape(B, H, nb * bs)
    return out, masses


# ---------------------------------------------------------------------------
# lookahead importance scores (the paper's hot spot)
# ---------------------------------------------------------------------------


def lookahead_score(
    q_obs: jnp.ndarray,  # (B, n_obs, H, hd)
    k: jnp.ndarray,  # (B, n_prompt + n_obs, KV, hd)
    n_prompt: int,
    *,
    kv_mask: jnp.ndarray | None = None,
    window=None,  # None | python int | traced int32 scalar
    q_offset=None,  # None | python int | traced int32 scalar
    row_valid: jnp.ndarray | None = None,  # (B, n_obs) real-row mask
    block_k: int = 2048,
) -> jnp.ndarray:
    """Per-q-head importance scores of prompt keys: (B, H, n_prompt), f32.

    Two-pass streaming softmax over the key axis: pass 1 computes per-row max
    and normalizer, pass 2 accumulates normalized probability mass per prompt
    key.  The (n_obs × Sk) score matrix is never materialized in full — only
    (n_obs × block_k) tiles.

    The one masked streaming scoring primitive shared by monolithic and
    chunked prefill: ``q_offset`` may be a *traced* scalar (the Pallas
    kernel prefetches it, so one compiled program serves the deferred
    observation-window scoring at any prompt length), ``window`` restricts
    local layers (static int on the Pallas path; a traced window falls back
    to jnp), and ``row_valid`` zeroes invalid observation rows — they
    contribute exact zeros to the mean, whose denominator stays ``n_obs``.
    """
    B, n_obs, H, hd = q_obs.shape
    Sk = k.shape[1]
    static_window = window is None or isinstance(window, int)
    if use_pallas() and static_window:
        from repro.kernels import lookahead_score as lk

        return lk.lookahead_score_pallas(
            q_obs, k, n_prompt, kv_mask=kv_mask, window=window,
            q_offset=q_offset, row_valid=row_valid,
            block_k=min(block_k, Sk), interpret=_pallas_interpret(),
        )
    if Sk <= _DIRECT_SEQ:
        from repro.kernels import ref

        return ref.lookahead_score(q_obs, k, n_prompt, kv_mask=kv_mask,
                                   window=window, q_offset=q_offset,
                                   row_valid=row_valid)
    return _chunked_lookahead_score(
        q_obs, k, n_prompt, kv_mask=kv_mask, window=window,
        q_offset=q_offset, row_valid=row_valid, block_k=block_k,
    )


def _chunked_lookahead_score(q_obs, k, n_prompt, *, kv_mask, window, q_offset,
                             block_k, row_valid=None, reduce="mean"):
    """Streaming jnp scoring fallback.  ``reduce='mean'`` divides the summed
    per-key mass by n_obs (``lookahead_score`` semantics); ``'sum'`` leaves
    the raw sum over valid rows (``chunk_attention``'s fused-mass
    semantics)."""
    B, n_obs, H, hd = q_obs.shape
    Sk, KV = k.shape[1], k.shape[2]
    group = H // KV
    pad = (-Sk) % block_k
    kf = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    valid = jnp.ones((B, n_prompt), bool) if kv_mask is None else kv_mask
    # full-key validity: prompt mask ++ obs keys valid ++ padding invalid
    full_mask = jnp.concatenate(
        [valid, jnp.ones((B, Sk - n_prompt), bool),
         jnp.zeros((B, pad), bool)], axis=1)
    nk = kf.shape[1] // block_k
    kf = jnp.moveaxis(kf.reshape(B, nk, block_k, KV, hd), 1, 0)
    fm = jnp.moveaxis(full_mask.reshape(B, nk, block_k), 1, 0)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    q32 = q_obs.astype(jnp.float32)
    q_pos = (n_prompt if q_offset is None else q_offset) + jnp.arange(n_obs)

    def tile_logits(ik, kb, mb):
        kbf = _expand_gqa(kb, group).astype(jnp.float32)
        s = jnp.einsum("bqhd,bkhd->bhqk", q32, kbf) * scale
        k_pos = ik * block_k + jnp.arange(block_k)
        ok = k_pos[None, :] <= q_pos[:, None]  # (n_obs, block_k) causal-on-obs
        if window is not None:
            ok &= (q_pos[:, None] - k_pos[None, :]) < window
        ok = ok[None] & mb[:, None, :]
        return jnp.where(ok[:, None], s, NEG_INF)

    # pass 1: row max + normalizer
    def p1(carry, inputs):
        m, l = carry
        ik, kb, mb = inputs
        s = tile_logits(ik, kb, mb)
        m_new = jnp.maximum(m, s.max(axis=-1))
        l = l * jnp.exp(m - m_new) + jnp.exp(s - m_new[..., None]).sum(-1)
        return (m_new, l), None

    init = (jnp.full((B, H, n_obs), NEG_INF, jnp.float32),
            jnp.zeros((B, H, n_obs), jnp.float32))
    (m, l), _ = jax.lax.scan(p1, init, (jnp.arange(nk), kf, fm))
    l = jnp.maximum(l, 1e-30)
    rv = None
    if row_valid is not None:
        rv = row_valid[:, None, :, None].astype(jnp.float32)  # (B,1,n_obs,1)

    # pass 2: per-key normalized mass, reduced over obs rows
    def p2(_, inputs):
        ik, kb, mb = inputs
        s = tile_logits(ik, kb, mb)
        p = jnp.exp(s - m[..., None]) / l[..., None]
        if rv is not None:
            p = p * rv
        red = p.mean(axis=2) if reduce == "mean" else p.sum(axis=2)
        return None, red  # (B, H, block_k)

    _, tiles = jax.lax.scan(p2, None, (jnp.arange(nk), kf, fm))
    scores = jnp.moveaxis(tiles, 0, 2).reshape(B, H, nk * block_k)
    return scores[..., :n_prompt]


# ---------------------------------------------------------------------------
# Mamba-2 SSD chunked scan
# ---------------------------------------------------------------------------


def ssd_scan(
    x: jnp.ndarray,  # (B, S, nh, hd)
    dt: jnp.ndarray,  # (B, S, nh)
    A: jnp.ndarray,  # (nh,) negative rates
    Bm: jnp.ndarray,  # (B, S, G, ds)
    Cm: jnp.ndarray,  # (B, S, G, ds)
    *,
    chunk: int = 128,
    initial_state: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked state-space-duality scan.  Returns (y, final_state) in f32.

    Within-chunk term is a masked quadratic ("attention-like") form; chunks
    are linked by a sequential state recurrence — O(S·Q) instead of O(S²).
    """
    B, S, nh, hd = x.shape
    if use_pallas() and S % chunk == 0:
        from repro.kernels import ssd_scan as sk

        return sk.ssd_scan_pallas(
            x, dt, A, Bm, Cm, chunk=chunk, initial_state=initial_state,
            interpret=_pallas_interpret(),
        )
    return ssd_scan_chunked_jnp(
        x, dt, A, Bm, Cm, chunk=chunk, initial_state=initial_state
    )


def ssd_scan_chunked_jnp(x, dt, A, Bm, Cm, *, chunk, initial_state=None):
    B, S, nh, hd = x.shape
    G, ds = Bm.shape[2], Bm.shape[3]
    hpg = nh // G
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = x.shape[1]
    nc = Sp // chunk
    x = x.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    A = A.astype(jnp.float32)
    Bf = jnp.repeat(Bm, hpg, axis=2).astype(jnp.float32)  # (B,Sp,nh,ds)
    Cf = jnp.repeat(Cm, hpg, axis=2).astype(jnp.float32)

    # per-step log decay a_t = A * dt_t  (<= 0)
    a = A[None, None, :] * dt  # (B, Sp, nh)
    xr = jnp.moveaxis(x.reshape(B, nc, chunk, nh, hd), 1, 0)
    dtr = jnp.moveaxis(dt.reshape(B, nc, chunk, nh), 1, 0)
    ar = jnp.moveaxis(a.reshape(B, nc, chunk, nh), 1, 0)
    Br = jnp.moveaxis(Bf.reshape(B, nc, chunk, nh, ds), 1, 0)
    Cr = jnp.moveaxis(Cf.reshape(B, nc, chunk, nh, ds), 1, 0)

    if initial_state is None:
        initial_state = jnp.zeros((B, nh, hd, ds), jnp.float32)
    else:
        initial_state = initial_state.astype(jnp.float32)

    idx = jnp.arange(chunk)
    causal = idx[:, None] >= idx[None, :]  # (t, s): s <= t

    def chunk_step(h, inputs):
        xc, dtc, ac, bc, cc = inputs
        # cumulative decays within the chunk
        L = jnp.cumsum(ac, axis=1)  # (B, Q, nh) — sum_{s<=t} a_s
        # intra-chunk quadratic term:
        #   y_t = sum_{s<=t} (C_t·B_s) exp(L_t - L_s) dt_s x_s
        cb = jnp.einsum("btnd,bsnd->bnts", cc, bc)  # (B, nh, Q, Q)
        decay = jnp.exp(
            jnp.clip(L[:, :, None, :] - L[:, None, :, :], -60.0, 0.0)
        )  # (B, t, s, nh)
        w = cb * jnp.moveaxis(decay, 3, 1) * jnp.where(causal, 1.0, 0.0)[None, None]
        y_intra = jnp.einsum("bnts,bsn,bsnh->btnh", w, dtc, xc)
        # inter-chunk: contribution of the carried state
        y_inter = jnp.einsum(
            "btnd,bnhd,btn->btnh", cc, h, jnp.exp(jnp.clip(L, -60.0, 0.0))
        )
        # state update: h' = exp(L_Q) h + sum_s exp(L_Q - L_s) dt_s x_s ⊗ B_s
        Lq = L[:, -1]  # (B, nh)
        rem = jnp.exp(jnp.clip(Lq[:, None, :] - L, -60.0, 0.0))  # (B, Q, nh)
        dstate = jnp.einsum("bsn,bsn,bsnh,bsnd->bnhd", rem, dtc, xc, bc)
        h = h * jnp.exp(jnp.clip(Lq, -60.0, 0.0))[..., None, None] + dstate
        return h, y_intra + y_inter

    final, ys = jax.lax.scan(chunk_step, initial_state, (xr, dtr, ar, Br, Cr))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, Sp, nh, hd)[:, :S]
    return y, final


def ssd_step(
    x_t: jnp.ndarray,  # (B, nh, hd)
    dt_t: jnp.ndarray,  # (B, nh)
    A: jnp.ndarray,  # (nh,)
    B_t: jnp.ndarray,  # (B, G, ds)
    C_t: jnp.ndarray,  # (B, G, ds)
    state: jnp.ndarray,  # (B, nh, hd, ds)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token SSD recurrence for decode.  Returns (y_t, new_state)."""
    B, nh, hd = x_t.shape
    G = B_t.shape[1]
    hpg = nh // G
    Bf = jnp.repeat(B_t, hpg, axis=1).astype(jnp.float32)
    Cf = jnp.repeat(C_t, hpg, axis=1).astype(jnp.float32)
    x32, dt32 = x_t.astype(jnp.float32), dt_t.astype(jnp.float32)
    decay = jnp.exp(A.astype(jnp.float32)[None] * dt32)  # (B, nh)
    state = state * decay[..., None, None] + (
        (dt32[..., None] * x32)[..., None] * Bf[..., None, :]
    )
    y = jnp.einsum("bnhs,bns->bnh", state, Cf)
    return y.astype(x_t.dtype), state


# ---------------------------------------------------------------------------
# decode attention with exposed online-softmax stats (split-cache decode)
# ---------------------------------------------------------------------------


def decode_attention_stats(
    q: jnp.ndarray,  # (B, H, hd)
    k: jnp.ndarray,  # (B, Sk, KV, hd)
    v: jnp.ndarray,
    *,
    kv_mask: jnp.ndarray | None = None,  # (B, Sk) or (B, Sk, KV)
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Unnormalized flash-decode partials: (m (B,H), l (B,H), acc (B,H,hd)).

    Lets callers attend over *disjoint cache segments with different
    shardings* (frozen seq-sharded prompt cache + replicated hot buffer) and
    merge exactly — the split-cache decode of §Perf (writing into a
    seq-sharded cache otherwise makes XLA all-gather the cache every layer).
    """
    B, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    group = H // KV
    kf = _expand_gqa(k, group).astype(jnp.float32)
    vf = _expand_gqa(v, group).astype(jnp.float32)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32), kf) \
        / jnp.sqrt(jnp.float32(hd))
    if kv_mask is not None:
        if kv_mask.ndim == 2:
            ok = kv_mask[:, None, :]
        else:
            ok = jnp.repeat(jnp.moveaxis(kv_mask, 2, 1), group, axis=1)
        s = jnp.where(ok, s, NEG_INF)
        pmask = ok
    else:
        pmask = jnp.ones_like(s, bool)
    m = s.max(axis=-1)
    p = jnp.where(pmask, jnp.exp(s - m[..., None]), 0.0)
    l = p.sum(axis=-1)
    acc = jnp.einsum("bhk,bkhd->bhd", p, vf)
    return m, l, acc


def merge_attention_stats(parts) -> jnp.ndarray:
    """Combine [(m, l, acc), ...] partials into normalized attention out."""
    m = parts[0][0]
    for mp, _, _ in parts[1:]:
        m = jnp.maximum(m, mp)
    l = 0.0
    acc = 0.0
    for mp, lp, ap in parts:
        corr = jnp.exp(mp - m)
        l = l + lp * corr
        acc = acc + ap * corr[..., None]
    return acc / jnp.maximum(l, 1e-30)[..., None]
