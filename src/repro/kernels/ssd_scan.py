"""Pallas TPU kernel for the Mamba-2 SSD chunked scan (arXiv:2405.21060).

The state-space-duality decomposition: within a chunk of Q tokens the output
is a masked quadratic ("attention-like") form that maps onto the MXU; chunks
are linked by a rank-preserving state recurrence.  grid = (B, nh_blocks, nc)
with the chunk axis innermost (sequential); the (bh, hd, ds) f32 running
state lives in VMEM scratch and never round-trips HBM between chunks —
that is the TPU adaptation of the paper's kernel (the CUDA version re-reads
chunk states from HBM between its three sub-kernels).

Assumes ngroups == 1 (our configs): B/C tiles are shared across heads.

Oracle: ``ref.ssd_scan`` (sequential recurrence).
jnp fallback: ``ops.ssd_scan_chunked_jnp`` (same chunked math).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, A_ref, b_ref, c_ref, h0_ref, y_ref, hout_ref,
            state_scr, *, nc, chunk):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = h0_ref[0].astype(jnp.float32)  # (bh, hd, ds)

    x = x_ref[0].astype(jnp.float32)  # (Q, bh, hd)
    dt = dt_ref[0].astype(jnp.float32)  # (Q, bh)
    A = A_ref[...].astype(jnp.float32)  # (bh,)
    bm = b_ref[0, :, 0, :].astype(jnp.float32)  # (Q, ds) — group-shared
    cm = c_ref[0, :, 0, :].astype(jnp.float32)  # (Q, ds)

    a = A[None, :] * dt  # (Q, bh) log-decays, <= 0
    L = jnp.cumsum(a, axis=0)  # (Q, bh)

    # intra-chunk quadratic: y_t += Σ_{s<=t} (C_t·B_s) exp(L_t − L_s) dt_s x_s
    cb = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, Q) = C_t · B_s
    decay = jnp.exp(jnp.clip(L[:, None, :] - L[None, :, :], -60.0, 0.0))
    causal = (
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    )
    w = cb[:, :, None] * decay * jnp.where(causal, 1.0, 0.0)[:, :, None]
    # (t, s, bh) weights; y_intra[t, n, h] = Σ_s w[t,s,n]·dt[s,n]·x[s,n,h]
    y_intra = jnp.einsum("tsn,sn,snh->tnh", w, dt, x)

    # inter-chunk: carried state h contributes C_t·h·exp(L_t)
    h = state_scr[...]  # (bh, hd, ds)
    eL = jnp.exp(jnp.clip(L, -60.0, 0.0))  # (Q, bh)
    y_inter = jnp.einsum("td,nhd,tn->tnh", cm, h, eL)

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h' = exp(L_Q) h + Σ_s exp(L_Q − L_s) dt_s x_s ⊗ B_s
    Lq = L[-1]  # (bh,)
    rem = jnp.exp(jnp.clip(Lq[None, :] - L, -60.0, 0.0))  # (Q, bh)
    dstate = jnp.einsum("sn,sn,snh,sd->nhd", rem, dt, x, bm)
    state_scr[...] = h * jnp.exp(jnp.clip(Lq, -60.0, 0.0))[:, None, None] + dstate

    @pl.when(ic == nc - 1)
    def _emit_state():
        hout_ref[0] = state_scr[...].astype(hout_ref.dtype)


def ssd_scan_pallas(
    x: jnp.ndarray,  # (B, S, nh, hd)
    dt: jnp.ndarray,  # (B, S, nh)
    A: jnp.ndarray,  # (nh,)
    Bm: jnp.ndarray,  # (B, S, 1, ds)
    Cm: jnp.ndarray,  # (B, S, 1, ds)
    *,
    chunk: int = 128,
    block_nh: int = 8,
    initial_state: jnp.ndarray | None = None,  # (B, nh, hd, ds)
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    B, S, nh, hd = x.shape
    G, ds = Bm.shape[2], Bm.shape[3]
    assert G == 1, "kernel assumes ngroups == 1 (shared B/C across heads)"
    assert S % chunk == 0, "pad sequence to chunk multiple before the kernel"
    nc = S // chunk
    block_nh = min(block_nh, nh)
    assert nh % block_nh == 0
    nhb = nh // block_nh
    if initial_state is None:
        initial_state = jnp.zeros((B, nh, hd, ds), jnp.float32)

    kernel = functools.partial(_kernel, nc=nc, chunk=chunk)
    y, hout = pl.pallas_call(
        kernel,
        grid=(B, nhb, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_nh, hd),
                         lambda b, n, ic: (b, ic, n, 0)),
            pl.BlockSpec((1, chunk, block_nh), lambda b, n, ic: (b, ic, n)),
            pl.BlockSpec((block_nh,), lambda b, n, ic: (n,)),
            pl.BlockSpec((1, chunk, 1, ds), lambda b, n, ic: (b, ic, 0, 0)),
            pl.BlockSpec((1, chunk, 1, ds), lambda b, n, ic: (b, ic, 0, 0)),
            pl.BlockSpec((1, block_nh, hd, ds), lambda b, n, ic: (b, n, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_nh, hd),
                         lambda b, n, ic: (b, ic, n, 0)),
            pl.BlockSpec((1, block_nh, hd, ds), lambda b, n, ic: (b, n, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, nh, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, nh, hd, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_nh, hd, ds), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm, initial_state)
    return y, hout
