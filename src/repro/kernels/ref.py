"""Pure-jnp oracles for every Pallas kernel in this package.

These are deliberately naive (materialize full score matrices, sequential
scans) — they define numerical ground truth for the kernel allclose sweeps in
``tests/test_kernels_*.py`` and for small-scale CPU execution.

Shared conventions
------------------
q:  (B, Sq, H, hd)       queries
k:  (B, Sk, KV, hd)      keys   (GQA: H = KV * G)
v:  (B, Sk, KV, hd)      values
kv_mask: (B, Sk) bool    validity of each cache slot (True = attend)
positions are absolute; causal masking compares absolute positions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _expand_gqa(x: jnp.ndarray, group: int) -> jnp.ndarray:
    """(B, S, KV, hd) -> (B, S, KV*G, hd) by repeating each kv head."""
    return jnp.repeat(x, group, axis=2)


def _logits_mask(
    q_pos: jnp.ndarray,  # (B, Sq)
    k_pos: jnp.ndarray,  # (B, Sk)
    *,
    causal: bool,
    window,  # None = unbounded; python int or traced int32 scalar otherwise
    kv_mask: jnp.ndarray | None,
) -> jnp.ndarray:
    """(B, Sq, Sk) bool: True where attention is allowed."""
    ok = jnp.ones((q_pos.shape[0], q_pos.shape[1], k_pos.shape[1]), bool)
    if causal:
        ok &= k_pos[:, None, :] <= q_pos[:, :, None]
    if window is not None:
        ok &= (q_pos[:, :, None] - k_pos[:, None, :]) < window
    if kv_mask is not None:
        ok &= kv_mask[:, None, :]
    return ok


def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window=None,
    q_pos: jnp.ndarray | None = None,
    k_pos: jnp.ndarray | None = None,
    kv_mask: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Naive masked softmax attention.  Returns (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    group = H // KV
    if q_pos is None:
        q_pos = jnp.broadcast_to(jnp.arange(Sk - Sq, Sk), (B, Sq))
    if k_pos is None:
        k_pos = jnp.broadcast_to(jnp.arange(Sk), (B, Sk))
    kf = _expand_gqa(k, group)
    vf = _expand_gqa(v, group)
    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), kf.astype(jnp.float32)
    ) / jnp.sqrt(hd).astype(jnp.float32)
    ok = _logits_mask(q_pos, k_pos, causal=causal, window=window, kv_mask=kv_mask)
    logits = jnp.where(ok[:, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vf.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,  # (B, H, hd) single query token
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    kv_mask: jnp.ndarray | None = None,  # (B, Sk) or (B, Sk, KV) per-head
) -> jnp.ndarray:
    B, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    group = H // KV
    kf = _expand_gqa(k, group)
    vf = _expand_gqa(v, group)
    logits = jnp.einsum(
        "bhd,bkhd->bhk", q.astype(jnp.float32), kf.astype(jnp.float32)
    ) / jnp.sqrt(hd).astype(jnp.float32)
    if kv_mask is not None:
        if kv_mask.ndim == 2:
            ok = kv_mask[:, None, :]  # (B, 1, Sk)
        else:  # (B, Sk, KV) -> (B, H, Sk)
            ok = jnp.repeat(jnp.moveaxis(kv_mask, 2, 1), group, axis=1)
        logits = jnp.where(ok, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhk,bkhd->bhd", probs, vf.astype(jnp.float32))
    return out.astype(q.dtype)


def lookahead_score(
    q_obs: jnp.ndarray,  # (B, n_obs, H, hd) — queries of the observation rows
    k: jnp.ndarray,  # (B, n_prompt + n_obs, KV, hd) — prompt keys then obs keys
    n_prompt: int,
    *,
    kv_mask: jnp.ndarray | None = None,  # (B, n_prompt) prompt-key validity
    window=None,  # sliding-window span for local layers (None = full)
    q_offset: int | None = None,  # absolute position of obs row 0 (default n_prompt)
    row_valid: jnp.ndarray | None = None,  # (B, n_obs) real-row mask
) -> jnp.ndarray:
    """Ground-truth importance scores (paper eq. (1)/(3)).

    The observation rows sit causally *after* the prompt: obs row i attends to
    all prompt keys plus obs keys j <= i.  The softmax normalizer therefore
    includes the obs-to-obs mass (Algorithm 2 slices A[n_in:, :n_in] *after*
    the softmax).  Returns per-q-head scores, mean over obs rows:
    (B, H, n_prompt), f32.

    ``row_valid`` marks real observation rows: invalid (padded / beyond the
    true prompt length) rows contribute exact zeros to the mean, whose
    denominator stays ``n_obs`` — callers that want a sum over valid rows
    rescale by ``n_obs``.
    """
    B, n_obs, H, hd = q_obs.shape
    Sk = k.shape[1]
    KV = k.shape[2]
    group = H // KV
    kf = _expand_gqa(k, group)
    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q_obs.astype(jnp.float32), kf.astype(jnp.float32)
    ) / jnp.sqrt(hd).astype(jnp.float32)
    # causal among obs rows; all prompt keys visible.
    q_pos = (n_prompt if q_offset is None else q_offset) + jnp.arange(n_obs)
    k_pos = jnp.arange(Sk)
    ok = k_pos[None, :] <= q_pos[:, None]  # (n_obs, Sk)
    if window is not None:
        ok &= (q_pos[:, None] - k_pos[None, :]) < window
    ok = jnp.broadcast_to(ok[None], (B, n_obs, Sk))
    if kv_mask is not None:
        full_mask = jnp.concatenate(
            [kv_mask, jnp.ones((B, Sk - n_prompt), bool)], axis=1
        )
        ok &= full_mask[:, None, :]
    logits = jnp.where(ok[:, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)  # (B, H, n_obs, Sk)
    if row_valid is not None:
        probs = probs * row_valid[:, None, :, None].astype(jnp.float32)
    scores = probs[..., :n_prompt].mean(axis=2)  # (B, H, n_prompt)
    return scores


def chunk_column_masses(
    q: jnp.ndarray,  # (B, C, H, hd) rotary-encoded chunk queries
    k: jnp.ndarray,  # (B, K, KV, hd) key buffer; col j holds position j
    *,
    q_offset,  # scalar int32 (may be traced) — absolute position of q row 0
    window=None,
    row_valid: jnp.ndarray | None = None,  # (B, C) real-row mask
) -> jnp.ndarray:
    """Summed softmax column masses of the chunk's queries: (B, H, K) f32.

    The dense oracle for the fused score output of
    ``chunk_attention.chunk_attention_masses_pallas`` and the streaming jnp
    fallback in ``ops.chunk_attention`` — it materializes the full
    (B, H, C, K) probability block, so it is test-/small-shape-only.  The
    per-row softmax is the same computation as ``lookahead_score`` (causal
    on absolute positions, NEG_INF masking, f32) — buffer columns a row
    cannot see contribute *exact zeros*, so streaming accumulation over
    chunks reproduces the monolithic scores up to summation order.  Rows
    beyond the true prompt length are zeroed via ``row_valid`` before the
    sum.
    """
    B, C, H, hd = q.shape
    K, KV = k.shape[1], k.shape[2]
    kf = _expand_gqa(k, H // KV)
    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), kf.astype(jnp.float32)
    ) / jnp.sqrt(hd).astype(jnp.float32)
    q_pos = q_offset + jnp.arange(C)
    k_pos = jnp.arange(K)
    ok = k_pos[None, :] <= q_pos[:, None]  # (C, K)
    if window is not None:
        ok &= (q_pos[:, None] - k_pos[None, :]) < window
    logits = jnp.where(ok[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)  # (B, H, C, K)
    if row_valid is not None:
        probs = probs * row_valid[:, None, :, None].astype(jnp.float32)
    return probs.sum(axis=2)


def ssd_scan(
    x: jnp.ndarray,  # (B, S, nh, hd) — pre-discretization inputs
    dt: jnp.ndarray,  # (B, S, nh)    — softplus'd timestep
    A: jnp.ndarray,  # (nh,)          — negative decay rates (A = -exp(A_log))
    Bm: jnp.ndarray,  # (B, S, G, ds)
    Cm: jnp.ndarray,  # (B, S, G, ds)
    *,
    initial_state: jnp.ndarray | None = None,  # (B, nh, hd, ds)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential Mamba-2 SSD recurrence oracle.

    h_t = exp(A * dt_t) * h_{t-1} + dt_t * x_t ⊗ B_t
    y_t = h_t · C_t

    Returns (y: (B, S, nh, hd), final_state: (B, nh, hd, ds)), f32.
    """
    B, S, nh, hd = x.shape
    G, ds = Bm.shape[2], Bm.shape[3]
    heads_per_group = nh // G
    Bm = jnp.repeat(Bm, heads_per_group, axis=2)  # (B,S,nh,ds)
    Cm = jnp.repeat(Cm, heads_per_group, axis=2)
    x, dt = x.astype(jnp.float32), dt.astype(jnp.float32)
    Bm, Cm = Bm.astype(jnp.float32), Cm.astype(jnp.float32)
    A = A.astype(jnp.float32)
    if initial_state is None:
        initial_state = jnp.zeros((B, nh, hd, ds), jnp.float32)

    def step(h, inputs):
        xt, dtt, bt, ct = inputs  # (B,nh,hd), (B,nh), (B,nh,ds), (B,nh,ds)
        decay = jnp.exp(A[None] * dtt)  # (B, nh)
        h = h * decay[..., None, None] + (
            (dtt[..., None] * xt)[..., None] * bt[..., None, :]
        )
        y = jnp.einsum("bnhs,bns->bnh", h, ct)
        return h, y

    xs = (
        jnp.moveaxis(x, 1, 0),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(Bm, 1, 0),
        jnp.moveaxis(Cm, 1, 0),
    )
    final, ys = jax.lax.scan(step, initial_state, xs)
    return jnp.moveaxis(ys, 0, 1), final


def gather_paged(pool: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """Flatten a block-table view of a paged pool into the dense cache
    layout: pool (N, bs, ...) + table (B, nb) -> (B, nb*bs, ...), where
    logical row ``c`` of sequence ``b`` is ``pool[table[b, c // bs],
    c % bs]``.  Gathers are exact — the dense view is a bitwise copy of
    the pooled rows (the paged-vs-dense equivalence lemma)."""
    B, nb = table.shape
    g = pool[table]  # (B, nb, bs, ...)
    return g.reshape((B, nb * pool.shape[1]) + pool.shape[2:])


def paged_decode_attention(
    q: jnp.ndarray,  # (B, H, hd) single query token
    k_pool: jnp.ndarray,  # (N, block_size, KV, hd) shared block pool
    v_pool: jnp.ndarray,
    mask_pool: jnp.ndarray,  # (N, block_size, KV) per-head validity
    table: jnp.ndarray,  # (B, nb) int32 physical block ids (0 = null)
    *,
    pos_pool: jnp.ndarray | None = None,  # (N, block_size, KV) int32 positions
    new_pos: jnp.ndarray | None = None,  # (B,) query-token absolute positions
    window=None,  # None | python int | traced int32 scalar
    depth: int | None = None,  # static logical depth: slice the gathered view
) -> jnp.ndarray:
    """Dense oracle for the paged decode kernel: materialize the
    block-table gather and run the naive masked decode attention over it.
    Dead rows (null blocks, ragged tails, stale previous owners) must be
    masked False in ``mask_pool`` — the mask is the sole validity source,
    as in the dense cache layout.

    ``window`` applies the dense path's sliding-window predicate
    ``new_pos - pos < window`` on the gathered ``pos_pool`` rows; with
    ``depth`` the gathered view is sliced to the dense engine's logical
    cache depth before attending, which makes this oracle *bitwise* the
    old gather-hop serving step (same reduction order as the dense cache).

    A sequence/head with *no* attendable key anywhere (an all-null table
    — a slot between requests — or every in-window row masked) is defined
    to be exact zeros, matching the flash kernels' ``l -> max(l, eps)``
    convention rather than the naive softmax's uniform-over-garbage
    limit."""
    mask = gather_paged(mask_pool, table)  # (B, S, KV)
    k = gather_paged(k_pool, table)
    v = gather_paged(v_pool, table)
    if depth is not None:
        k, v, mask = k[:, :depth], v[:, :depth], mask[:, :depth]
    if window is not None:
        assert pos_pool is not None and new_pos is not None, \
            "sliding-window masking needs pos_pool and new_pos"
        pos = gather_paged(pos_pool, table)  # (B, S, KV)
        if depth is not None:
            pos = pos[:, :depth]
        mask = mask & ((new_pos[:, None, None] - pos) < window)
    out = decode_attention(q, k, v, kv_mask=mask)
    B, H, _ = q.shape
    KV = mask_pool.shape[2]
    alive = jnp.repeat(mask.any(axis=1), H // KV, axis=1)  # (B, H)
    return jnp.where(alive[..., None], out, 0.0).astype(out.dtype)


def paged_decode_masses(
    q: jnp.ndarray,  # (B, H, hd) single query token
    k_pool: jnp.ndarray,  # (N, block_size, KV, hd) shared block pool
    mask_pool: jnp.ndarray,  # (N, block_size, KV) per-head validity
    table: jnp.ndarray,  # (B, nb) int32 physical block ids (0 = null)
    *,
    pos_pool: jnp.ndarray | None = None,
    new_pos: jnp.ndarray | None = None,
    window=None,
    depth: int | None = None,
) -> jnp.ndarray:
    """Dense oracle for the decode token's per-key softmax masses over a
    paged cache: (B, H, S) f32, S = nb*block_size (or ``depth``).

    Row j holds the normalized probability the query puts on logical cache
    row j — the decode-time analogue of ``chunk_column_masses``, streamed
    into cumulative H2O scores by the serving engine's decode-eviction
    sweep.  Masked rows contribute *exact zeros* and a sequence/head with
    no attendable row is all-zero (``l -> max(l, eps)``), matching the
    flash kernels — so accumulating masses over steps reproduces the dense
    ``decode_attention_step_evicting`` score recurrence, which adds
    ``where(mask, probs, 0)`` each step."""
    mask = gather_paged(mask_pool, table)  # (B, S, KV)
    k = gather_paged(k_pool, table)
    if depth is not None:
        k, mask = k[:, :depth], mask[:, :depth]
    if window is not None:
        assert pos_pool is not None and new_pos is not None, \
            "sliding-window masking needs pos_pool and new_pos"
        pos = gather_paged(pos_pool, table)
        if depth is not None:
            pos = pos[:, :depth]
        mask = mask & ((new_pos[:, None, None] - pos) < window)
    B, H, hd = q.shape
    KV = k.shape[2]
    group = H // KV
    kf = _expand_gqa(k, group)
    logits = jnp.einsum(
        "bhd,bkhd->bhk", q.astype(jnp.float32), kf.astype(jnp.float32)
    ) / jnp.sqrt(hd).astype(jnp.float32)
    ok = jnp.repeat(jnp.moveaxis(mask, 2, 1), group, axis=1)  # (B, H, S)
    logits = jnp.where(ok, logits, NEG_INF)
    m = logits.max(axis=-1, keepdims=True)
    p = jnp.where(ok, jnp.exp(logits - m), 0.0)
    l = jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    return p / l
