"""Pallas TPU cross-chunk flash attention (streaming / chunked prefill).

One query chunk of ``C`` rows starting at absolute position ``q_offset``
attends over the materialized key/value buffer: prior-chunk keys are fully
visible, the chunk attends itself causally, and buffer columns at or past
the chunk end are causally invisible (buffer column ``j`` holds the token
at absolute position ``j``).  ``q_offset`` is a *traced* scalar — one
compiled program serves every chunk index of every prompt length, which is
what lets the serving compile cache drop the prompt-length bucket ladder.

Tiling: grid = (B, H, nk) with the key axis innermost (sequential).  The
whole chunk rides in VMEM as a single (C, hd) query tile; key blocks whose
first column lies beyond the chunk's last visible position are skipped
(the usual causal block pruning — for an early chunk of a long buffer
almost every key block short-circuits).

GQA is handled in the index map (query head ``h`` reads kv head
``h // group``).  Oracle: ``ref.attention`` with explicit ``q_pos``.
jnp fallback with identical math: ``ops.chunk_attention``'s direct path.

Fused score accumulation
------------------------
``chunk_attention_masses_pallas`` additionally emits the chunk's summed
softmax *column masses* per key — the streaming eviction-score partial the
cumulative (h2o) policy accumulates across chunks — without ever
materializing the (C, K) probability block.  Per-key normalized mass needs
the *final* per-row softmax statistics, so the fused kernel runs the key
axis twice (the same phase trick as ``lookahead_score``): phase 0 is the
unmodified online-softmax attention pass (the attention output is
bit-identical to the unfused kernel); phase 1 re-streams each key tile and
emits ``Σ_rows exp(s − m)/l`` column sums, zeroing rows at or past the true
prompt length (``n_total``) so padded chunk rows contribute nothing.
Output traffic for the scores is K floats per (batch, head) instead of
C·K.  Oracle: ``ref.chunk_column_masses``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(offs_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            window, block_k, nk, C, scale):
    ik = pl.program_id(2)
    s0 = offs_ref[0]  # absolute position of q row 0

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal block pruning: the chunk's last row sits at s0 + C - 1; key
    # blocks starting past it contain no visible column for any row.
    @pl.when(ik * block_k <= s0 + C - 1)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)  # (C, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (C, bk)

        q_pos = s0 + jax.lax.broadcasted_iota(jnp.int32, (C, block_k), 0)
        k_pos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (C, block_k), 1)
        ok = k_pos <= q_pos
        if window is not None:
            ok &= (q_pos - k_pos) < window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.where(ok, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def chunk_attention_pallas(
    q: jnp.ndarray,  # (B, C, H, hd) rotary-encoded chunk queries
    k: jnp.ndarray,  # (B, K, KV, hd) key buffer (col j = position j)
    v: jnp.ndarray,
    q_offset,  # scalar int32 (may be traced) — position of q row 0
    *,
    window: int | None = None,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    B, C, H, hd = q.shape
    K, KV = k.shape[1], k.shape[2]
    group = H // KV
    block_k = min(block_k, K)
    while K % block_k:
        block_k //= 2
    nk = K // block_k
    scale = 1.0 / (hd ** 0.5)
    if window == 0:
        window = None
    offs = jnp.reshape(jnp.asarray(q_offset, jnp.int32), (1,))

    kernel = functools.partial(
        _kernel, window=window, block_k=block_k, nk=nk, C=C, scale=scale,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, nk),
        in_specs=[
            pl.BlockSpec((1, C, 1, hd), lambda b, h, ik, offs: (b, 0, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, ik, offs, g=group: (b, ik, h // g, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, ik, offs, g=group: (b, ik, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, C, 1, hd),
                               lambda b, h, ik, offs: (b, 0, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((C,), jnp.float32),
            pltpu.VMEM((C,), jnp.float32),
            pltpu.VMEM((C, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, C, H, hd), q.dtype),
        interpret=interpret,
    )(offs, q, k, v)


def _fused_kernel(offs_ref, q_ref, k_ref, v_ref, o_ref, mass_ref,
                  m_scr, l_scr, acc_scr, *, window, block_k, nk, C, scale):
    j = pl.program_id(2)
    ik = jnp.where(j < nk, j, j - nk)
    phase1 = j >= nk
    s0 = offs_ref[0]  # absolute position of q row 0
    n_total = offs_ref[1]  # true prompt length (rows >= it score zero)
    # causal block pruning (see the single-pass kernel): key blocks starting
    # past the chunk's last row are invisible to every query row
    live = ik * block_k <= s0 + C - 1

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _tile():
        """(s, ok) logits + visibility of this key tile — shared by phases."""
        q = q_ref[0, :, 0, :].astype(jnp.float32)  # (C, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (C, bk)
        q_pos = s0 + jax.lax.broadcasted_iota(jnp.int32, (C, block_k), 0)
        k_pos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (C, block_k), 1)
        ok = k_pos <= q_pos
        if window is not None:
            ok &= (q_pos - k_pos) < window
        return jnp.where(ok, s, NEG_INF), ok

    # phase 0: the unmodified online-softmax attention recurrence — the
    # attention output is bit-identical to the single-pass kernel's.
    @pl.when(jnp.logical_not(phase1) & live)
    def _pass1():
        s, ok = _tile()
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.where(ok, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(j == nk - 1)
    def _finish_o():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)

    # phase 1: the (m, l) statistics are final — re-stream the key tile and
    # emit its normalized column masses, zeroing invalid (padded) rows.
    @pl.when(phase1 & live)
    def _pass2():
        s, ok = _tile()
        m = m_scr[...]
        l = jnp.maximum(l_scr[...], 1e-30)
        p = jnp.where(ok, jnp.exp(s - m[:, None]), 0.0) / l[:, None]
        row = s0 + jax.lax.broadcasted_iota(jnp.int32, (C, block_k), 0)
        p = jnp.where(row < n_total, p, 0.0)
        mass_ref[0, 0, :] = p.sum(axis=0)

    @pl.when(phase1 & jnp.logical_not(live))
    def _pass2_pruned():  # causally invisible tile: exact zero mass
        mass_ref[0, 0, :] = jnp.zeros((block_k,), jnp.float32)


def chunk_attention_masses_pallas(
    q: jnp.ndarray,  # (B, C, H, hd) rotary-encoded chunk queries
    k: jnp.ndarray,  # (B, K, KV, hd) key buffer (col j = position j)
    v: jnp.ndarray,
    q_offset,  # scalar int32 (may be traced) — position of q row 0
    n_total,  # scalar int32 (may be traced) — true prompt length
    *,
    window: int | None = None,
    block_k: int = 512,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused attention + streaming score partials.

    Returns (out (B, C, H, hd), masses (B, H, K) f32) where
    ``masses[b, h, j] = Σ_{i: q_offset+i < n_total} softmax_row_i[j]`` —
    the h2o column-mass contribution of this chunk, computed tile-by-tile
    without materializing the probability block.  ``out`` is bit-identical
    to ``chunk_attention_pallas``.
    """
    B, C, H, hd = q.shape
    K, KV = k.shape[1], k.shape[2]
    group = H // KV
    block_k = min(block_k, K)
    while K % block_k:
        block_k //= 2
    nk = K // block_k
    scale = 1.0 / (hd ** 0.5)
    if window == 0:
        window = None
    offs = jnp.stack([jnp.asarray(q_offset, jnp.int32).reshape(()),
                      jnp.asarray(n_total, jnp.int32).reshape(())])

    kernel = functools.partial(
        _fused_kernel, window=window, block_k=block_k, nk=nk, C=C,
        scale=scale,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, 2 * nk),
        in_specs=[
            pl.BlockSpec((1, C, 1, hd), lambda b, h, j, offs: (b, 0, h, 0)),
            pl.BlockSpec(
                (1, block_k, 1, hd),
                lambda b, h, j, offs, g=group, nk=nk: (
                    b, jnp.where(j < nk, j, j - nk), h // g, 0
                ),
            ),
            # v is only read in phase 0; phase-1 iterations park on block 0
            # so the mass sweep doesn't re-stream the whole v buffer
            pl.BlockSpec(
                (1, block_k, 1, hd),
                lambda b, h, j, offs, g=group, nk=nk: (
                    b, jnp.where(j < nk, j, 0), h // g, 0
                ),
            ),
        ],
        out_specs=[
            pl.BlockSpec((1, C, 1, hd), lambda b, h, j, offs: (b, 0, h, 0)),
            # phase-0 iterations park on mass block 0 (key block 0 is never
            # causally pruned, so phase 1's first iteration overwrites it
            # before any write-back escapes); phase 1 emits block ik
            pl.BlockSpec(
                (1, 1, block_k),
                lambda b, h, j, offs, nk=nk: (b, h, jnp.where(j < nk, 0,
                                                              j - nk)),
            ),
        ],
        scratch_shapes=[
            pltpu.VMEM((C,), jnp.float32),
            pltpu.VMEM((C,), jnp.float32),
            pltpu.VMEM((C, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, C, H, hd), q.dtype),
            jax.ShapeDtypeStruct((B, H, K), jnp.float32),
        ],
        interpret=interpret,
    )(offs, q, k, v)
