"""Pallas TPU cross-chunk flash attention (streaming / chunked prefill).

One query chunk of ``C`` rows starting at absolute position ``q_offset``
attends over the materialized key/value buffer: prior-chunk keys are fully
visible, the chunk attends itself causally, and buffer columns at or past
the chunk end are causally invisible (buffer column ``j`` holds the token
at absolute position ``j``).  ``q_offset`` is a *traced* scalar — one
compiled program serves every chunk index of every prompt length, which is
what lets the serving compile cache drop the prompt-length bucket ladder.

Tiling: grid = (B, H, nk) with the key axis innermost (sequential).  The
whole chunk rides in VMEM as a single (C, hd) query tile; key blocks whose
first column lies beyond the chunk's last visible position are skipped
(the usual causal block pruning — for an early chunk of a long buffer
almost every key block short-circuits).

GQA is handled in the index map (query head ``h`` reads kv head
``h // group``).  Oracle: ``ref.attention`` with explicit ``q_pos``.
jnp fallback with identical math: ``ops.chunk_attention``'s direct path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(offs_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            window, block_k, nk, C, scale):
    ik = pl.program_id(2)
    s0 = offs_ref[0]  # absolute position of q row 0

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal block pruning: the chunk's last row sits at s0 + C - 1; key
    # blocks starting past it contain no visible column for any row.
    @pl.when(ik * block_k <= s0 + C - 1)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)  # (C, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (C, bk)

        q_pos = s0 + jax.lax.broadcasted_iota(jnp.int32, (C, block_k), 0)
        k_pos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (C, block_k), 1)
        ok = k_pos <= q_pos
        if window is not None:
            ok &= (q_pos - k_pos) < window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.where(ok, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def chunk_attention_pallas(
    q: jnp.ndarray,  # (B, C, H, hd) rotary-encoded chunk queries
    k: jnp.ndarray,  # (B, K, KV, hd) key buffer (col j = position j)
    v: jnp.ndarray,
    q_offset,  # scalar int32 (may be traced) — position of q row 0
    *,
    window: int | None = None,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    B, C, H, hd = q.shape
    K, KV = k.shape[1], k.shape[2]
    group = H // KV
    block_k = min(block_k, K)
    while K % block_k:
        block_k //= 2
    nk = K // block_k
    scale = 1.0 / (hd ** 0.5)
    if window == 0:
        window = None
    offs = jnp.reshape(jnp.asarray(q_offset, jnp.int32), (1,))

    kernel = functools.partial(
        _kernel, window=window, block_k=block_k, nk=nk, C=C, scale=scale,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, nk),
        in_specs=[
            pl.BlockSpec((1, C, 1, hd), lambda b, h, ik, offs: (b, 0, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, ik, offs, g=group: (b, ik, h // g, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, ik, offs, g=group: (b, ik, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, C, 1, hd),
                               lambda b, h, ik, offs: (b, 0, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((C,), jnp.float32),
            pltpu.VMEM((C,), jnp.float32),
            pltpu.VMEM((C, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, C, H, hd), q.dtype),
        interpret=interpret,
    )(offs, q, k, v)
