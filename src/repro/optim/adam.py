"""Hand-rolled Adam + cosine schedule + global-norm clipping (no optax in the
offline container).  State and updates are pytree-shaped like the trainable
parameters; master weights and moments are f32 regardless of param dtype.

Matches the paper's recipe (Table 16): Adam β=(0.9, 0.95), cosine to 0,
2% warmup, grad-clip 1.0.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.common.config import TrainConfig


class AdamState(NamedTuple):
    step: jnp.ndarray  # scalar int32
    mu: Any  # first moment (f32 pytree)
    nu: Any  # second moment (f32 pytree)


def init(params: Any) -> AdamState:
    f32 = lambda t: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), t
    )
    return AdamState(step=jnp.zeros((), jnp.int32), mu=f32(params), nu=f32(params))


def cosine_lr(step, tc: TrainConfig) -> jnp.ndarray:
    warmup = max(int(tc.warmup_frac * tc.steps), 1)
    warm = tc.lr * (step + 1) / warmup
    prog = jnp.clip((step - warmup) / max(tc.steps - warmup, 1), 0.0, 1.0)
    cos = 0.5 * tc.lr * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, cos)


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jnp.ndarray]:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads)]
    gnorm = jnp.sqrt(sum(leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gnorm


def update(
    params: Any,
    grads: Any,
    state: AdamState,
    tc: TrainConfig,
) -> tuple[Any, AdamState, dict]:
    """One Adam step.  Returns (new params, new state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
    step = state.step
    lr = cosine_lr(step, tc)
    b1, b2, eps = tc.beta1, tc.beta2, 1e-8

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / (1 - b1 ** (step + 1))
        vhat = v / (1 - b2 ** (step + 1))
        new_p = p.astype(jnp.float32) - lr * mhat / (jnp.sqrt(vhat) + eps)
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, AdamState(step + 1, new_mu, new_nu), {
        "lr": lr, "grad_norm": gnorm,
    }
