"""llama3.1-8b — the paper's primary subject model [arXiv:2407.21783].

Included beyond the assigned pool so the benchmarks mirror the paper's own
tables (at reduced scale on CPU via ``smoke``).
"""

from repro.common.config import AttentionConfig, LookaheadConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    arch_type="dense",
    num_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=128256,
    attn=AttentionConfig(num_heads=32, num_kv_heads=8, head_dim=128,
                         rope_theta=5e5),
    tie_embeddings=False,
    fsdp=True,
    source="arXiv:2407.21783 (Llama 3 herd)",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama3-smoke", arch_type="dense", num_layers=2, d_model=128,
        d_ff=384, vocab_size=512,
        attn=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=32),
        lookahead=LookaheadConfig(n_lookahead=8, lora_rank=4, window_size=8,
                                  pool_kernel=3),
        tie_embeddings=False,
    )
