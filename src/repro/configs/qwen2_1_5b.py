"""qwen2-1.5b — GQA with QKV bias [arXiv:2407.10671].

28L, d_model=1536, 12 heads (GQA kv=2), d_ff=8960, vocab=151936.
"""

from repro.common.config import AttentionConfig, LookaheadConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b",
    arch_type="dense",
    num_layers=28,
    d_model=1536,
    d_ff=8960,
    vocab_size=151936,
    attn=AttentionConfig(num_heads=12, num_kv_heads=2, head_dim=128,
                         qkv_bias=True, rope_theta=1e6),
    source="arXiv:2407.10671 (Qwen2)",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-smoke", arch_type="dense", num_layers=2, d_model=128,
        d_ff=256, vocab_size=512,
        attn=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=32,
                             qkv_bias=True),
        lookahead=LookaheadConfig(n_lookahead=8, lora_rank=4, window_size=8,
                                  pool_kernel=3),
    )
