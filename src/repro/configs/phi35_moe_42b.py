"""phi3.5-moe-42b-a6.6b — 16 experts, top-2 routing
[hf:microsoft/Phi-3.5-MoE-instruct].

32L, d_model=4096, 32 heads (GQA kv=8), per-expert d_ff=6400, vocab=32064.
"""

from repro.common.config import (AttentionConfig, LookaheadConfig, ModelConfig,
                                 MoEConfig)

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    arch_type="moe",
    num_layers=32,
    d_model=4096,
    d_ff=6400,
    vocab_size=32064,
    attn=AttentionConfig(num_heads=32, num_kv_heads=8, head_dim=128),
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=6400),
    lookahead=LookaheadConfig(lora_targets=("wq", "wk", "wv", "wo")),
    tie_embeddings=False,
    fsdp=True,
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="phi35-moe-smoke", arch_type="moe", num_layers=2, d_model=128,
        d_ff=128, vocab_size=512,
        attn=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=32),
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=128),
        lookahead=LookaheadConfig(n_lookahead=8, lora_rank=4, window_size=8,
                                  pool_kernel=3,
                                  lora_targets=("wq", "wk", "wv", "wo")),
        tie_embeddings=False,
    )
