"""tiny-llama — ~100M llama-family model for the end-to-end training example
(examples/train_e2e.py): trains LookaheadKV modules for a few hundred steps
on CPU."""

from repro.common.config import AttentionConfig, LookaheadConfig, ModelConfig

CONFIG = ModelConfig(
    name="tiny-llama",
    arch_type="dense",
    num_layers=12,
    d_model=768,
    d_ff=2048,
    vocab_size=32000,
    attn=AttentionConfig(num_heads=12, num_kv_heads=4, head_dim=64),
    lookahead=LookaheadConfig(n_lookahead=32, lora_rank=8),
    source="llama-family ~100M (this repo)",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="tiny-llama-smoke", arch_type="dense", num_layers=2, d_model=128,
        d_ff=256, vocab_size=512,
        attn=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=32),
        lookahead=LookaheadConfig(n_lookahead=8, lora_rank=4, window_size=8,
                                  pool_kernel=3),
    )
