"""qwen2-vl-72b — VLM language backbone with M-RoPE, dynamic resolution
[arXiv:2409.12191].

80L, d_model=8192, 64 heads (GQA kv=8), d_ff=29568, vocab=152064.  The vision
encoder (ViT) is stubbed per the assignment carve-out: ``input_specs``
provides pre-projected patch embeddings (B, S, D); M-RoPE positions arrive as
a (3, B, S) stream (temporal/height/width).
"""

from repro.common.config import AttentionConfig, LookaheadConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    arch_type="vlm",
    num_layers=80,
    d_model=8192,
    d_ff=29568,
    vocab_size=152064,
    attn=AttentionConfig(num_heads=64, num_kv_heads=8, head_dim=128,
                         qkv_bias=True, rope_theta=1e6, mrope=True,
                         mrope_sections=(16, 24, 24)),
    embeds_in=True,
    tie_embeddings=False,
    fsdp=True,
    source="arXiv:2409.12191 (Qwen2-VL)",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-smoke", arch_type="vlm", num_layers=2, d_model=128,
        d_ff=256, vocab_size=512,
        attn=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=32,
                             qkv_bias=True, mrope=True,
                             mrope_sections=(4, 6, 6)),
        embeds_in=True,
        lookahead=LookaheadConfig(n_lookahead=8, lora_rank=4, window_size=8,
                                  pool_kernel=3),
        tie_embeddings=False,
    )
