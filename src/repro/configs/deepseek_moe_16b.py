"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed experts, top-6
[arXiv:2401.06066].

28L, d_model=2048, 16 heads (kv=16, i.e. MHA), per-expert d_ff=1408,
vocab=102400.  (Deviation noted in DESIGN.md: the real model's layer 0 is a
dense FFN; we keep all layers MoE for scan-uniform depth.)  Lookahead LoRA
restricted to attention + shared experts (routed experts stay untouched).
"""

from repro.common.config import (AttentionConfig, LookaheadConfig, ModelConfig,
                                 MoEConfig)

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    arch_type="moe",
    num_layers=28,
    d_model=2048,
    d_ff=1408,
    vocab_size=102400,
    attn=AttentionConfig(num_heads=16, num_kv_heads=16, head_dim=128),
    moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408,
                  num_shared_experts=2),
    lookahead=LookaheadConfig(
        lora_targets=("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down")),
    tie_embeddings=False,
    fsdp=True,
    source="arXiv:2401.06066 (DeepSeekMoE)",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-smoke", arch_type="moe", num_layers=2, d_model=128,
        d_ff=64, vocab_size=512,
        attn=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=32),
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=64,
                      num_shared_experts=1),
        lookahead=LookaheadConfig(n_lookahead=8, lora_rank=4, window_size=8,
                                  pool_kernel=3),
        tie_embeddings=False,
    )
