"""minitron-8b — width-pruned Nemotron-4 [arXiv:2407.14679].

32L, d_model=4096, 32 heads (GQA kv=8), d_ff=16384, vocab=256000.
"""

from repro.common.config import AttentionConfig, LookaheadConfig, ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    arch_type="dense",
    num_layers=32,
    d_model=4096,
    d_ff=16384,
    vocab_size=256000,
    attn=AttentionConfig(num_heads=32, num_kv_heads=8, head_dim=128),
    tie_embeddings=False,
    fsdp=True,
    source="arXiv:2407.14679 (Minitron)",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="minitron-smoke", arch_type="dense", num_layers=2, d_model=128,
        d_ff=512, vocab_size=512,
        attn=AttentionConfig(num_heads=4, num_kv_heads=1, head_dim=32),
        lookahead=LookaheadConfig(n_lookahead=8, lora_rank=4, window_size=8,
                                  pool_kernel=3),
        tie_embeddings=False,
    )
