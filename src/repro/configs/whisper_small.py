"""whisper-small — encoder-decoder, conv/mel frontend stubbed
[arXiv:2212.04356].

12 encoder + 12 decoder layers, d_model=768, 12 heads (kv=12), d_ff=3072,
vocab=51865.  ``input_specs`` provides precomputed 1500-frame embeddings
(B, 1500, 768) in place of the mel-spectrogram + conv feature extractor
(assignment carve-out).  Decoder self-attention uses RoPE instead of learned
absolute positions so the 32k decode shapes are well-posed (deviation noted
in DESIGN.md §8).  The eviction technique applies to the decoder self-attn
cache.
"""

from repro.common.config import (AttentionConfig, EncoderConfig,
                                 LookaheadConfig, ModelConfig)

CONFIG = ModelConfig(
    name="whisper-small",
    arch_type="audio",
    num_layers=12,
    d_model=768,
    d_ff=3072,
    vocab_size=51865,
    attn=AttentionConfig(num_heads=12, num_kv_heads=12, head_dim=64),
    encoder=EncoderConfig(num_layers=12, num_frames=1500),
    act="gelu",
    source="arXiv:2212.04356 (Whisper)",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", arch_type="audio", num_layers=2, d_model=128,
        d_ff=256, vocab_size=512,
        attn=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=32),
        encoder=EncoderConfig(num_layers=2, num_frames=16),
        act="gelu",
        lookahead=LookaheadConfig(n_lookahead=8, lora_rank=4, window_size=8,
                                  pool_kernel=3),
    )
