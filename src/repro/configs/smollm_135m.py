"""smollm-135m — llama-architecture small dense model
[hf:HuggingFaceTB/SmolLM-135M].

30L, d_model=576, 9 heads (GQA kv=3), d_ff=1536, vocab=49152.
"""

from repro.common.config import AttentionConfig, LookaheadConfig, ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    arch_type="dense",
    num_layers=30,
    d_model=576,
    d_ff=1536,
    vocab_size=49152,
    attn=AttentionConfig(num_heads=9, num_kv_heads=3, head_dim=64),
    source="hf:HuggingFaceTB/SmolLM-135M",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="smollm-smoke", arch_type="dense", num_layers=2, d_model=96,
        d_ff=256, vocab_size=512,
        attn=AttentionConfig(num_heads=3, num_kv_heads=1, head_dim=32),
        lookahead=LookaheadConfig(n_lookahead=8, lora_rank=4, window_size=8,
                                  pool_kernel=3),
    )
