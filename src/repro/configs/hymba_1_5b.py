"""hymba-1.5b — hybrid: parallel attention + Mamba heads per block
[arXiv:2411.13676].

32L, d_model=1600, 25 heads (GQA kv=5), d_ff=5504, ssm_state=16.  Sliding
window (1024) in all but the first/middle/last layers (global), per the
paper.  Block output = ½(attn(u) + ssd(u)).  vocab=32001.

Eviction applies to the attention-head KV (partial applicability: the SSM
state is constant-size, DESIGN.md §5).
"""

from repro.common.config import (AttentionConfig, LookaheadConfig, ModelConfig,
                                 SSMConfig)

CONFIG = ModelConfig(
    name="hymba-1.5b",
    arch_type="hybrid",
    num_layers=32,
    d_model=1600,
    d_ff=5504,
    vocab_size=32001,
    attn=AttentionConfig(num_heads=25, num_kv_heads=5, head_dim=64,
                         sliding_window=1024, global_layers=(0, 15, 31)),
    ssm=SSMConfig(d_state=16, expand=2, head_dim=64, chunk_size=128),
    hybrid=True,
    source="arXiv:2411.13676 (Hymba)",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="hymba-smoke", arch_type="hybrid", num_layers=2, d_model=128,
        d_ff=256, vocab_size=512,
        attn=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=32,
                             sliding_window=16, global_layers=(0,)),
        ssm=SSMConfig(d_state=8, expand=2, head_dim=32, chunk_size=32),
        hybrid=True,
        lookahead=LookaheadConfig(n_lookahead=8, lora_rank=4, window_size=8,
                                  pool_kernel=3),
    )
