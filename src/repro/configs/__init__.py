"""Architecture registry: ``get_config(arch_id)`` / ``get_smoke_config(arch_id)``.

One module per assigned architecture; each exports ``CONFIG`` (the exact
assigned full-size config, citation in ``source``) and ``smoke()`` (a reduced
same-family variant: ≤2 layers, d_model ≤ 512, ≤4 experts — runs a forward /
train step on CPU in the per-arch smoke tests).
"""

from __future__ import annotations

import importlib

from repro.common.config import INPUT_SHAPES, ModelConfig, ShapeConfig

ARCH_IDS = (
    "mamba2-130m",
    "smollm-135m",
    "deepseek-moe-16b",
    "phi3.5-moe-42b-a6.6b",
    "minitron-8b",
    "qwen2-vl-72b",
    "gemma3-1b",
    "qwen2-1.5b",
    "whisper-small",
    "hymba-1.5b",
)

_MODULES = {
    "mamba2-130m": "mamba2_130m",
    "smollm-135m": "smollm_135m",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b",
    "minitron-8b": "minitron_8b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "gemma3-1b": "gemma3_1b",
    "qwen2-1.5b": "qwen2_1_5b",
    "whisper-small": "whisper_small",
    "hymba-1.5b": "hymba_1_5b",
    # paper's own subject models (reduced-scale stand-ins train end-to-end)
    "llama3-8b": "llama3_8b",
    "tiny-llama": "tiny_llama",
}


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(
            f"unknown arch '{arch_id}'; known: {sorted(_MODULES)}"
        )
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).smoke()


def get_shape(name: str) -> ShapeConfig:
    return INPUT_SHAPES[name]


def shape_applicable(arch_id: str, shape_name: str) -> tuple[bool, str]:
    """(runs?, reason) — DESIGN.md §5 skip matrix for long_500k etc."""
    cfg = get_config(arch_id)
    if shape_name != "long_500k":
        return True, ""
    if cfg.uses_ssm:  # mamba2, hymba
        return True, "ssm/hybrid: constant state + windowed attention"
    if cfg.attn is not None and (cfg.attn.sliding_window > 0):
        return True, "sliding-window attention bounds per-layer cache"
    return False, (
        "pure full-attention arch: 524k decode cache is quadratic-history; "
        "skipped per spec (DESIGN.md §5)"
    )
