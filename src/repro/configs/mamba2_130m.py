"""mamba2-130m — SSD (state-space duality), attention-free [arXiv:2405.21060].

24L, d_model=768, d_ff=0 (no MLP block: Mamba-2 blocks only), vocab=50280,
ssm_state=128.  The paper's KV-eviction technique is inapplicable (no KV
cache; constant-size recurrent state) — built without it per DESIGN.md §5.
"""

from repro.common.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    arch_type="ssm",
    num_layers=24,
    d_model=768,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64, chunk_size=128),
    lookahead=None,
    technique_applies=False,
    source="arXiv:2405.21060 (Mamba-2 / SSD)",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", arch_type="ssm", num_layers=2, d_model=128,
        d_ff=0, vocab_size=512,
        ssm=SSMConfig(d_state=16, expand=2, head_dim=32, chunk_size=32),
        lookahead=None, technique_applies=False,
    )
