"""gemma3-1b — 5:1 local:global attention, 128k context [hf:google/gemma-3-1b-pt].

26L, d_model=1152, 4 heads (GQA kv=1), d_ff=6912, vocab=262144.  Every 6th
layer is global full attention; the rest use a 512-token sliding window.
head_dim=256 (explicit in the model card, != d_model/num_heads).

long_500k applicability: local layers bound their cache by the window; the
global layers decode against the *evicted budget* cache — i.e. the paper's
own technique is what makes a 524k-token decode feasible for this dense arch
(DESIGN.md §5).
"""

from repro.common.config import AttentionConfig, LookaheadConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    arch_type="dense",
    num_layers=26,
    d_model=1152,
    d_ff=6912,
    vocab_size=262144,
    attn=AttentionConfig(num_heads=4, num_kv_heads=1, head_dim=256,
                         sliding_window=512, global_every=6, rope_theta=1e6),
    source="hf:google/gemma-3-1b-pt",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma3-smoke", arch_type="dense", num_layers=2, d_model=128,
        d_ff=256, vocab_size=512,
        attn=AttentionConfig(num_heads=4, num_kv_heads=1, head_dim=32,
                             sliding_window=16, global_every=2),
        lookahead=LookaheadConfig(n_lookahead=8, lora_rank=4, window_size=8,
                                  pool_kernel=3),
    )
