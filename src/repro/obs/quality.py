"""Streaming lookahead drift monitor + the shared kept-set machinery.

The serving predictor (trained lookahead modules) is distilled offline;
its quality on *live* traffic can drift as the workload shifts — the
failure mode learned-importance baselines document and the blocker for
the ROADMAP's online adapter refresh.  ``DriftMonitor`` turns the
engine's retirement hook into a streaming quality signal:

1. retired requests are sampled into a small held-out ring — each
   carries its prompt ``x`` and the *generated continuation* ``y``, the
   very future the gt_oracle needs (the ``data/harvest.py`` insight);
2. every ``eval_every`` sampled retirements the ring is re-scored: the
   frozen model's oracle pass over ``[x; y]`` (``objective.gt_scores``,
   one jit per prompt length — the ``HarvestWriter`` pattern) against
   the serving predictor's ``objective.lookahead_scores``;
3. the mean per-(layer, head) top-``budget`` kept-set overlap lands in
   the ``lookahead_drift_overlap`` gauge.

``head_kept_sets`` / ``kept_overlaps`` are the same machinery
``benchmarks/bench_lookahead_quality.py`` gates the learning loop with
(it imports them from here), so the streaming gauge and the offline
benchmark computation agree to float tolerance on identical records —
the property ``benchmarks/bench_obs.py`` asserts.
"""

from __future__ import annotations

import functools
from typing import Optional

import numpy as np

__all__ = ["head_kept_sets", "kept_overlaps", "DriftMonitor"]


def head_kept_sets(scores, budget: int) -> dict:
    """Per-(layer, head) top-``budget`` kept set of a raw score tensor
    (L, H, n) — the predictor's selection before GQA pooling, the
    quantity the distillation objective actually trains."""
    return {(l, h): set(np.argsort(-scores[l, h])[:budget].tolist())
            for l in range(scores.shape[0])
            for h in range(scores.shape[1])}


def kept_overlaps(pred_scores, gt_scores, budget: int) -> list[float]:
    """Per-(layer, head) kept-set overlap fractions between a predictor's
    raw scores and the oracle's, both (L, H, n)."""
    gt_sets = head_kept_sets(gt_scores, budget)
    sets = head_kept_sets(pred_scores, budget)
    return [len(sets[key] & g) / budget for key, g in gt_sets.items()]


class DriftMonitor:
    """Streaming predictor-quality monitor riding the retirement hook.

    Construct with the frozen model and the *serving* predictor tree,
    hand it to ``ServingConfig.drift``; the engine calls ``on_retire``
    per retired request and ``bind`` at init to attach its metrics
    registry / tracer.  ``evaluate()`` can also be called directly (the
    benches do) and returns the overlap, or None with an empty ring.

    Scoring is jitted once per distinct prompt length (trace lengths
    cluster, so the cache stays small) and runs on the engine thread —
    size ``ring_size``/``eval_every`` to the overhead budget.  Requests
    whose prompt is within ``budget`` tokens are skipped: their kept set
    is the whole prompt and the overlap would be vacuously 1.
    """

    def __init__(self, params: dict, cfg, lkv_params: dict, *,
                 budget: int, ring_size: int = 16, sample_every: int = 1,
                 eval_every: int = 8, max_obs: int = 16, min_obs: int = 1):
        assert ring_size >= 1 and sample_every >= 1 and eval_every >= 1
        self.params, self.cfg, self.lkv_params = params, cfg, lkv_params
        self.budget = budget
        self.ring_size = ring_size
        self.sample_every = sample_every
        self.eval_every = eval_every
        self.max_obs = max_obs
        self.min_obs = min_obs
        self._ring: list[tuple[np.ndarray, np.ndarray]] = []
        self._ring_pos = 0
        self._retired = 0
        self._sampled_since_eval = 0
        self._gt_fns: dict = {}
        self._pred_fns: dict = {}
        self.last_overlap: Optional[float] = None
        self.evals = 0
        self.samples = 0
        self._metrics = None
        self._trace = None

    # -- engine wiring -------------------------------------------------------
    def bind(self, metrics=None, trace=None) -> None:
        """Attach the engine's registry (gauge + counters) and tracer
        (an ``drift_eval`` span per evaluation on the engine track)."""
        self._trace = trace
        if metrics is not None:
            self._metrics = metrics
            g = metrics.gauge(
                "lookahead_drift_overlap",
                "Mean per-(layer, head) oracle kept-set overlap of the "
                "serving predictor over the held-out ring of sampled "
                "retired requests (1.0 = predictor keeps exactly the "
                "oracle set; falling values signal drift).")
            g.set_fn(lambda: (self.last_overlap
                              if self.last_overlap is not None else -1.0))
            metrics.gauge(
                "lookahead_drift_ring",
                "Retired requests currently held in the drift ring."
            ).set_fn(lambda: len(self._ring))
            metrics.counter(
                "lookahead_drift_samples",
                "Retired requests sampled into the drift ring.")
            metrics.counter(
                "lookahead_drift_evals",
                "Drift evaluations performed (ring re-scorings).")

    def on_retire(self, req) -> None:
        """Engine retirement hook: sample, then periodically evaluate."""
        self._retired += 1
        if (self._retired - 1) % self.sample_every:
            return
        y = np.asarray(req.out_tokens[: self.max_obs], np.int32)
        x = np.asarray(req.prompt, np.int32)
        if y.size < self.min_obs or len(x) <= self.budget:
            return
        self.observe(x, y)
        if self._sampled_since_eval >= self.eval_every:
            self.evaluate()

    def observe(self, x: np.ndarray, y: np.ndarray) -> None:
        """Insert one (prompt, generated-future) record into the ring."""
        rec = (np.asarray(x, np.int32), np.asarray(y, np.int32))
        if len(self._ring) < self.ring_size:
            self._ring.append(rec)
        else:
            self._ring[self._ring_pos] = rec
            self._ring_pos = (self._ring_pos + 1) % self.ring_size
        self.samples += 1
        self._sampled_since_eval += 1
        if self._metrics is not None:
            self._metrics.counter("lookahead_drift_samples").inc()

    # -- scoring (one jit per prompt length, the HarvestWriter pattern) ------
    def _gt_fn(self, n_in: int):
        import jax

        from repro.core import objective

        fn = self._gt_fns.get(n_in)
        if fn is None:
            fn = jax.jit(functools.partial(
                objective.gt_scores, self.params, self.cfg, n_in=n_in))
            self._gt_fns[n_in] = fn
        return fn

    def _pred_fn(self, n_in: int):
        import jax

        from repro.core import objective

        fn = self._pred_fns.get(n_in)
        if fn is None:
            fn = jax.jit(functools.partial(
                objective.lookahead_scores, self.params, self.cfg))
            self._pred_fns[n_in] = fn
        return fn

    def gt_head_scores(self, x: np.ndarray, y: np.ndarray) -> np.ndarray:
        """(L, H, n_in) f32 oracle scores of ``x``'s keys under ``y``'s
        real queries — bit-identical to ``HarvestWriter.gt_record`` (same
        jitted program, same shapes)."""
        import jax.numpy as jnp

        xy = jnp.asarray(np.concatenate([x, y]))[None]
        s = self._gt_fn(len(x))(xy)  # (L, 1, H, n_in)
        return np.asarray(s[:, 0], np.float32)

    def pred_head_scores(self, x: np.ndarray) -> np.ndarray:
        """(L, H, n_in) f32 serving-predictor scores of ``x``'s keys."""
        import jax.numpy as jnp

        s = self._pred_fn(len(x))(self.lkv_params, jnp.asarray(x)[None])
        return np.asarray(s[:, 0], np.float32)

    # -- evaluation ----------------------------------------------------------
    def evaluate(self) -> Optional[float]:
        """Re-score the ring; returns (and gauges) the mean overlap."""
        self._sampled_since_eval = 0
        if not self._ring:
            return None
        tr = self._trace
        if tr is not None:
            tr.begin("drift_eval", tr.ENGINE, records=len(self._ring))
        ovs: list[float] = []
        for x, y in self._ring:
            gt = self.gt_head_scores(x, y)
            pred = self.pred_head_scores(x)
            ovs.extend(kept_overlaps(pred, gt, self.budget))
        self.last_overlap = float(np.mean(ovs))
        self.evals += 1
        if self._metrics is not None:
            self._metrics.counter("lookahead_drift_evals").inc()
        if tr is not None:
            tr.end("drift_eval", tr.ENGINE,
                   overlap=self.last_overlap)
        return self.last_overlap
