"""Typed metrics registry: Counter / Gauge / Histogram / Info with labels.

The registry is the serving stack's single metrics surface — engines and
components register named, typed, documented metrics instead of growing
ad-hoc ``stats`` dicts.  Design points:

* **Typed kinds.**  A ``Counter`` only goes up (within a run), a
  ``Gauge`` holds a last-write value, a ``Histogram`` accumulates a
  bucketed distribution, and an ``Info`` carries a small string->string
  payload (dispatch path, mesh shape) that has no numeric value.
* **Labels.**  A metric may declare label names; each distinct label
  tuple gets its own child series (Prometheus semantics).
* **Callback gauges.**  ``Gauge.set_fn`` binds a zero-argument callable
  evaluated at *collection* time — components (KV pool, prefix cache,
  compile cache, scheduler) mirror their state without a single hot-path
  write.
* **Per-run semantics.**  Engine counters reset at ``run()`` start
  (``MetricsRegistry.reset``), matching the historical per-run ``stats``
  dict the benches rely on (warmup run, then a timed run on the same
  engine).  Callback gauges are left alone by ``reset`` — they mirror
  live component state, which has its own lifetime.
* **Timing semantics are part of the metric.**  Every timer's help
  string states whether it measures *dispatch* or *synced execution*
  under JAX async dispatch (see ``ContinuousEngine``'s ``sync_timers``),
  so a dashboard reader does not have to reverse-engineer the engine.

Export: ``snapshot()`` (JSON-able dict), ``prometheus_text()`` (text
exposition format, histogram ``_bucket``/``_sum``/``_count`` series
included), ``value(name, **labels)`` for point reads.
"""

from __future__ import annotations

import json
from typing import Callable, Optional, Sequence

__all__ = ["Counter", "Gauge", "Histogram", "Info", "MetricsRegistry",
           "DEFAULT_BUCKETS", "bind_stat_gauges"]

#: default histogram buckets (seconds): serving latencies from 0.5 ms to 10 s
DEFAULT_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                   0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


def _label_key(labelnames, labels: dict) -> tuple:
    if set(labels) != set(labelnames):
        raise ValueError(
            f"expected labels {tuple(labelnames)}, got {tuple(labels)}")
    return tuple(str(labels[k]) for k in labelnames)


def _series_name(name: str, labelnames, key: tuple) -> str:
    if not labelnames:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in zip(labelnames, key))
    return f"{name}{{{inner}}}"


class Metric:
    """Base: a named, typed, documented metric with optional labels."""

    kind = "untyped"

    def __init__(self, name: str, help: str, labelnames: Sequence[str] = ()):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._series: dict = {}  # label-value tuple -> series state

    # -- series plumbing -----------------------------------------------------
    def _get(self, labels: dict):
        key = _label_key(self.labelnames, labels)
        s = self._series.get(key)
        if s is None:
            s = self._series[key] = self._new_series()
        return s

    def _new_series(self):
        raise NotImplementedError

    def reset(self) -> None:
        self._series.clear()

    # -- export --------------------------------------------------------------
    def _series_value(self, s):
        raise NotImplementedError

    def collect(self) -> dict:
        """{"kind", "help", "labels", "values": {series_name: value}}."""
        return {
            "kind": self.kind,
            "help": self.help,
            "labels": list(self.labelnames),
            "values": {
                _series_name(self.name, self.labelnames, key):
                    self._series_value(s)
                for key, s in sorted(self._series.items())
            },
        }


class Counter(Metric):
    """Monotonically increasing count (within one ``reset`` epoch)."""

    kind = "counter"

    def _new_series(self):
        return [0.0]

    def inc(self, amount: float = 1, **labels) -> None:
        if amount < 0:
            raise ValueError("counters only go up; use a Gauge")
        self._get(labels)[0] += amount

    def value(self, **labels) -> float:
        return self._get(labels)[0]

    def _series_value(self, s):
        v = s[0]
        return int(v) if float(v).is_integer() else v


class Gauge(Metric):
    """Last-written value, or a collection-time callback (``set_fn``)."""

    kind = "gauge"

    def _new_series(self):
        return {"value": 0.0, "fn": None}

    def set(self, value: float, **labels) -> None:
        s = self._get(labels)
        s["fn"], s["value"] = None, value

    def inc(self, amount: float = 1, **labels) -> None:
        self._get(labels)["value"] += amount

    def max(self, value: float, **labels) -> None:
        """Keep the running maximum (high-water observability)."""
        s = self._get(labels)
        s["value"] = max(s["value"], value)

    def set_fn(self, fn: Callable[[], float], **labels) -> None:
        """Bind a collection-time callback; re-binding replaces the old
        callback (a fresh component instance takes over the series)."""
        self._get(labels)["fn"] = fn

    def value(self, **labels) -> float:
        s = self._get(labels)
        return s["fn"]() if s["fn"] is not None else s["value"]

    def reset(self) -> None:
        # callback-backed series mirror live component state and survive;
        # set-value series restart at zero with the run
        for s in self._series.values():
            if s["fn"] is None:
                s["value"] = 0.0

    def _series_value(self, s):
        v = s["fn"]() if s["fn"] is not None else s["value"]
        return int(v) if float(v).is_integer() else v


class Histogram(Metric):
    """Cumulative bucketed distribution plus sum and count."""

    kind = "histogram"

    def __init__(self, name, help, labelnames=(), buckets=DEFAULT_BUCKETS):
        super().__init__(name, help, labelnames)
        self.buckets = tuple(sorted(buckets))
        assert self.buckets, "a histogram needs at least one bucket bound"

    def _new_series(self):
        return {"counts": [0] * (len(self.buckets) + 1),  # +inf tail
                "sum": 0.0, "count": 0}

    def observe(self, value: float, **labels) -> None:
        s = self._get(labels)
        i = len(self.buckets)
        for j, b in enumerate(self.buckets):
            if value <= b:
                i = j
                break
        s["counts"][i] += 1
        s["sum"] += value
        s["count"] += 1

    def count(self, **labels) -> int:
        return self._get(labels)["count"]

    def sum(self, **labels) -> float:
        return self._get(labels)["sum"]

    def _series_value(self, s):
        cum, out = 0, {}
        for b, c in zip(self.buckets, s["counts"]):
            cum += c
            out[str(b)] = cum
        out["+Inf"] = cum + s["counts"][-1]
        return {"buckets": out, "sum": s["sum"], "count": s["count"]}


class Info(Metric):
    """A small string->string payload (dispatch path, mesh shape, …) —
    exported as a constant-1 series with the payload as labels, the
    Prometheus ``_info`` convention."""

    kind = "info"

    def _new_series(self):
        return {}

    def set(self, **payload) -> None:
        s = self._get({})
        s.clear()
        s.update({k: str(v) for k, v in payload.items()})

    def value(self) -> dict:
        return dict(self._get({}))

    def _series_value(self, s):
        return dict(s)


def bind_stat_gauges(registry: "MetricsRegistry", prefix: str, stats_fn,
                     keys: Optional[Sequence[str]] = None) -> list[str]:
    """Mirror a component's ``stats()`` dict as callback gauges.

    Each numeric key ``k`` becomes the gauge ``<prefix>_<k>`` whose value
    is ``stats_fn()[k]`` at collection time — zero hot-path writes, and a
    re-bound component (fresh instance, same registry) simply takes the
    series over.  ``keys=None`` samples ``stats_fn()`` once and binds
    every numeric entry (bools and non-numerics are skipped).  Returns
    the bound key list.
    """
    if keys is None:
        keys = [k for k, v in stats_fn().items()
                if isinstance(v, (int, float)) and not isinstance(v, bool)]
    for k in keys:
        registry.gauge(
            f"{prefix}_{k}",
            f"Live mirror of the component's stats()[{k!r}] "
            "(callback gauge, evaluated at collection time).",
        ).set_fn(lambda k=k: float(stats_fn()[k]))
    return list(keys)


class MetricsRegistry:
    """Named registry of typed metrics; the serving stack's one surface.

    ``counter/gauge/histogram/info`` are get-or-create: re-registering a
    name returns the existing metric (components re-bound across engine
    runs share series), and a kind mismatch fails loudly.
    """

    def __init__(self):
        self._metrics: dict[str, Metric] = {}

    def _register(self, cls, name, help, labelnames=(), **kw) -> Metric:
        m = self._metrics.get(name)
        if m is not None:
            if not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {m.kind}")
            return m
        m = self._metrics[name] = cls(name, help, labelnames, **kw)
        return m

    def counter(self, name, help="", labelnames=()) -> Counter:
        return self._register(Counter, name, help, labelnames)

    def gauge(self, name, help="", labelnames=()) -> Gauge:
        return self._register(Gauge, name, help, labelnames)

    def histogram(self, name, help="", labelnames=(),
                  buckets=DEFAULT_BUCKETS) -> Histogram:
        return self._register(Histogram, name, help, labelnames,
                              buckets=buckets)

    def info(self, name, help="") -> Info:
        return self._register(Info, name, help)

    def get(self, name: str) -> Optional[Metric]:
        return self._metrics.get(name)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def value(self, name: str, default=0, **labels):
        """Point read of one series (0/default when never touched)."""
        m = self._metrics.get(name)
        if m is None:
            return default
        if isinstance(m, Info):
            return m.value()
        return m.value(**labels)

    def reset(self) -> None:
        """Start a fresh collection epoch: counters, histograms and
        set-value gauges restart at zero; callback gauges (live component
        mirrors) and Info payloads are untouched."""
        for m in self._metrics.values():
            if not isinstance(m, Info):
                m.reset()

    # -- export --------------------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-able snapshot of every metric (callbacks evaluated now)."""
        return {name: m.collect()
                for name, m in sorted(self._metrics.items())}

    def to_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=2, sort_keys=True)

    def prometheus_text(self) -> str:  # noqa: C901 - one format, one place
        """Prometheus text exposition format (0.0.4)."""
        lines = []
        for name, m in sorted(self._metrics.items()):
            col = m.collect()
            if m.help:
                lines.append(f"# HELP {name} {' '.join(m.help.split())}")
            lines.append(f"# TYPE {name} "
                         f"{'gauge' if m.kind == 'info' else m.kind}")
            for series, val in col["values"].items():
                if m.kind == "histogram":
                    base, _, rest = series.partition("{")
                    inner = rest[:-1] if rest else ""
                    for le, c in val["buckets"].items():
                        lbl = f"{inner},le=\"{le}\"" if inner \
                            else f"le=\"{le}\""
                        lines.append(f"{base}_bucket{{{lbl}}} {c}")
                    suffix = f"{{{inner}}}" if inner else ""
                    lines.append(f"{base}_sum{suffix} {val['sum']}")
                    lines.append(f"{base}_count{suffix} {val['count']}")
                elif m.kind == "info":
                    inner = ",".join(f'{k}="{v}"'
                                     for k, v in sorted(val.items()))
                    lines.append(f"{name}_info{{{inner}}} 1")
                else:
                    lines.append(f"{series} {val}")
        return "\n".join(lines) + "\n"
