"""First-class observability for the serving stack.

Three cooperating pieces (see the README's "Observability" section):

* ``obs.metrics``  — a typed metrics registry (Counter / Gauge /
  Histogram / Info, optional labels) that replaces the engines' ad-hoc
  ``stats`` dicts, with JSON-snapshot and Prometheus text-exposition
  export.  Component state (pool, prefix cache, compile cache,
  scheduler) is mirrored through *callback-backed* gauges evaluated at
  collection time, so binding a component costs nothing on the hot path.
* ``obs.trace``    — per-request span tracing (admission → prefix-cache
  probe → prefill chunks → decode → sweeps → preemption/replay →
  retirement/harvest, plus compile events), exported as JSONL and as
  Chrome trace-event JSON viewable in Perfetto.
* ``obs.quality``  — the streaming lookahead drift monitor: retired
  requests are sampled into a held-out ring and periodically re-scored
  against the frozen-model oracle, exposing per-(layer, head) kept-set
  overlap as a gauge — the drift gate the ROADMAP's online adapter
  refresh needs.
"""

from repro.obs.metrics import (Counter, Gauge, Histogram, Info,
                               MetricsRegistry)
from repro.obs.quality import DriftMonitor, head_kept_sets, kept_overlaps
from repro.obs.trace import (TraceRecorder, phase_table, request_span_trees,
                             validate_trace)

__all__ = [
    "MetricsRegistry", "Counter", "Gauge", "Histogram", "Info",
    "TraceRecorder", "validate_trace", "request_span_trees", "phase_table",
    "DriftMonitor", "head_kept_sets", "kept_overlaps",
]
