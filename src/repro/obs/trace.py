"""Per-request span tracing for the serving engine, Perfetto-viewable.

``TraceRecorder`` collects begin/end/instant events on named *tracks*:
one track per request (``req:<uid>``) plus one engine track for work
that spans slots (decode chunks, jit compiles, drift evaluations).  The
per-request span tree is::

    request {admission_seq, replay_of?}          ── track req:<uid>
      prefix_probe {hit, depth}                  (prefix cache enabled)
      prefill_chunk {s} × ceil(n/chunk)
      finalize
      i first_token
      decode {…}
        paged_sweep {blocks_freed} × k           (decode-time eviction)
      harvest                                    (capture hook installed)
      i retire | i preempt
    [end] request {outcome: done|preempted|admission_blocked}

A preempted request's spans are *closed* at preemption (outcome
``preempted``); its re-serve opens a fresh ``request`` span whose
``replay_of`` arg carries the original admission's ``admission_seq`` —
the replay ↔ original link the span-invariant tests assert.

**Device-time attribution.**  Span end timestamps are host stamps; under
JAX async dispatch a bare stamp measures *dispatch*.  The engine
therefore blocks on the spanned computation's output arrays before
closing timing-sensitive spans whenever tracing is enabled
(``ContinuousEngine`` ``sync_timers``), so spans measure synced
execution at chunk granularity.  ``TraceRecorder.sync`` records which
semantics a given trace was captured under.

Export: ``to_jsonl`` (one raw event per line) and ``to_chrome`` /
``chrome_trace`` (Chrome trace-event JSON — load the file in Perfetto's
https://ui.perfetto.dev or ``chrome://tracing``).
"""

from __future__ import annotations

import json
import time
from contextlib import contextmanager
from typing import Optional

__all__ = ["TraceRecorder", "validate_trace", "request_span_trees",
           "phase_table"]

ENGINE_TRACK = "engine"


def request_track(uid: int) -> str:
    return f"req:{uid}"


class TraceRecorder:
    """Append-only event recorder with one ``perf_counter`` epoch.

    Events are plain dicts ``{"name", "ph", "ts", "tid", "args"}`` with
    ``ph`` in B (begin), E (end), i (instant) and ``ts`` in microseconds
    since the recorder's epoch.  Per-track event order is append order,
    so timestamps are monotone per track by construction.
    """

    ENGINE = ENGINE_TRACK

    def __init__(self, *, sync: bool = True):
        #: whether span ends were device-synced (see module docstring)
        self.sync = sync
        self.events: list[dict] = []
        self._t0 = time.perf_counter()

    # -- recording -----------------------------------------------------------
    def now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def _emit(self, ph: str, name: str, tid: str, args: Optional[dict]):
        self.events.append({"name": name, "ph": ph, "ts": self.now_us(),
                            "tid": tid, "args": args or {}})

    def begin(self, name: str, tid: str, **args) -> None:
        self._emit("B", name, tid, args)

    def end(self, name: str, tid: str, **args) -> None:
        self._emit("E", name, tid, args)

    def instant(self, name: str, tid: str, **args) -> None:
        self._emit("i", name, tid, args)

    @contextmanager
    def span(self, name: str, tid: str, sync_on=None, **args):
        """Timed span; blocks on ``sync_on`` (any jax pytree) before the
        end stamp when the recorder is sync-mode — the device-time
        attribution fix for async dispatch."""
        self.begin(name, tid, **args)
        try:
            yield
        finally:
            if sync_on is not None and self.sync:
                import jax
                jax.block_until_ready(sync_on)
            self.end(name, tid)

    # -- export --------------------------------------------------------------
    def to_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for e in self.events:
                f.write(json.dumps(e) + "\n")

    def chrome_trace(self) -> dict:
        """Chrome trace-event JSON dict (Perfetto-loadable): tracks map to
        tids under one pid, named via ``thread_name`` metadata."""
        tids: dict[str, int] = {ENGINE_TRACK: 0}
        out = []
        for e in self.events:
            tid = tids.setdefault(e["tid"], len(tids))
            out.append({"name": e["name"], "ph": e["ph"], "ts": e["ts"],
                        "pid": 0, "tid": tid, "args": e["args"],
                        **({"s": "t"} if e["ph"] == "i" else {})})
        meta = [{"name": "thread_name", "ph": "M", "pid": 0, "tid": i,
                 "args": {"name": track}} for track, i in tids.items()]
        meta += [{"name": "thread_sort_index", "ph": "M", "pid": 0,
                  "tid": i, "args": {"sort_index": i}}
                 for i in tids.values()]
        return {"traceEvents": meta + out,
                "otherData": {"sync_timers": self.sync}}

    def to_chrome(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)


def _events_of(trace) -> list[dict]:
    return trace.events if isinstance(trace, TraceRecorder) else list(trace)


def validate_trace(trace) -> dict:
    """Assert the structural span invariants over a whole trace:

    * per track, B/E events are properly nested and name-matched;
    * every opened span is closed (no dangling B at end-of-trace);
    * timestamps are monotone non-decreasing per track.

    Returns summary counts ``{"tracks", "spans", "events"}``; raises
    ``AssertionError`` with the offending track/event on violation.
    """
    events = _events_of(trace)
    stacks: dict[str, list] = {}
    last_ts: dict[str, float] = {}
    spans = 0
    for e in events:
        tid = e["tid"]
        assert e["ts"] >= last_ts.get(tid, 0.0), \
            f"track {tid}: timestamp moved backwards at {e['name']!r}"
        last_ts[tid] = e["ts"]
        stack = stacks.setdefault(tid, [])
        if e["ph"] == "B":
            stack.append(e["name"])
        elif e["ph"] == "E":
            assert stack, f"track {tid}: end {e['name']!r} with no open span"
            top = stack.pop()
            assert top == e["name"], \
                f"track {tid}: end {e['name']!r} crosses open {top!r}"
            spans += 1
    for tid, stack in stacks.items():
        assert not stack, f"track {tid}: unclosed spans {stack}"
    return {"tracks": len(stacks), "spans": spans, "events": len(events)}


def request_span_trees(trace, uid: int) -> list[dict]:
    """The request's span forest, one tree per serve attempt (original +
    replays), each node ``{"name", "ts", "dur_us", "args", "end_args",
    "children", "instants"}``."""
    tid = request_track(uid)
    roots: list[dict] = []
    stack: list[dict] = []
    for e in _events_of(trace):
        if e["tid"] != tid:
            continue
        if e["ph"] == "B":
            node = {"name": e["name"], "ts": e["ts"], "dur_us": 0.0,
                    "args": e["args"], "end_args": {}, "children": [],
                    "instants": []}
            (stack[-1]["children"] if stack else roots).append(node)
            stack.append(node)
        elif e["ph"] == "E":
            node = stack.pop()
            node["dur_us"] = e["ts"] - node["ts"]
            node["end_args"] = e["args"]
        else:  # instant
            if stack:
                stack[-1]["instants"].append(
                    {"name": e["name"], "ts": e["ts"], "args": e["args"]})
    return roots


def _walk(node):
    yield node
    for c in node["children"]:
        yield from _walk(c)


def phase_table(trace, uids) -> list[dict]:
    """Per-request phase-latency breakdown from the span trees — the
    table ``launch/serve.py`` prints in place of the old flat stats dump.

    One row per uid: prefix-skipped tokens, total prefill time (chunk
    spans + finalize), time from first serve attempt to the first-token
    instant, decode-span time, sweep count/time, replay count, and the
    final outcome.  Times in milliseconds; a request with no closed tree
    (never admitted) yields a row with ``outcome="missing"``.
    """
    rows = []
    for uid in sorted(uids):
        trees = request_span_trees(trace, uid)
        if not trees:
            rows.append({"uid": uid, "outcome": "missing"})
            continue
        row = {"uid": uid, "prefix_skip_tokens": 0, "prefill_ms": 0.0,
               "first_token_ms": None, "decode_ms": 0.0, "sweeps": 0,
               "sweep_ms": 0.0, "replays": len(trees) - 1,
               "outcome": trees[-1]["end_args"].get("outcome", "open")}
        t_start = trees[0]["ts"]
        for tree in trees:
            for node in _walk(tree):
                if node["name"] in ("prefill_chunk", "finalize"):
                    row["prefill_ms"] += node["dur_us"] / 1e3
                elif node["name"] == "decode":
                    row["decode_ms"] += node["dur_us"] / 1e3
                elif node["name"] == "paged_sweep":
                    row["sweeps"] += 1
                    row["sweep_ms"] += node["dur_us"] / 1e3
                elif node["name"] == "prefix_probe":
                    row["prefix_skip_tokens"] = max(
                        row["prefix_skip_tokens"],
                        int(node["end_args"].get("depth", 0)))
                for i in node["instants"]:
                    if (i["name"] == "first_token"
                            and row["first_token_ms"] is None):
                        row["first_token_ms"] = (i["ts"] - t_start) / 1e3
        rows.append(row)
    return rows
