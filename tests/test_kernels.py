"""Per-kernel allclose sweeps: Pallas (interpret=True) and the jnp chunked
fallbacks, both against the pure-jnp oracles in ``repro.kernels.ref``.

Each sweep randomizes (batch, seq, heads, kv heads, head_dim, block sizes,
dtype) — the no-hypothesis property harness (see conftest)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import sweep_cases
from repro.kernels import ops, ref
from repro.kernels.chunk_attention import chunk_attention_pallas
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.lookahead_score import lookahead_score_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas


def _attn_case(rng):
    hd = int(rng.choice([16, 32, 64]))
    kv = int(rng.choice([1, 2, 4]))
    group = int(rng.choice([1, 2, 3]))
    bq = int(rng.choice([32, 64]))
    nq = int(rng.integers(1, 5))
    dtype = rng.choice(["float32", "bfloat16"])
    return dict(B=int(rng.integers(1, 3)), S=bq * nq, H=kv * group, KV=kv,
                hd=hd, bq=bq, bk=bq, window=int(rng.choice([0, 48])),
                dtype=dtype, seed=int(rng.integers(1 << 30)))


@pytest.mark.parametrize("case", sweep_cases(0, 8, _attn_case))
def test_flash_attention_matches_oracle(case):
    key = jax.random.PRNGKey(case["seed"])
    ks = jax.random.split(key, 3)
    dt = jnp.dtype(case["dtype"])
    q = jax.random.normal(ks[0], (case["B"], case["S"], case["H"], case["hd"])).astype(dt)
    k = jax.random.normal(ks[1], (case["B"], case["S"], case["KV"], case["hd"])).astype(dt)
    v = jax.random.normal(ks[2], (case["B"], case["S"], case["KV"], case["hd"])).astype(dt)
    w = case["window"] or None
    got = flash_attention_pallas(q, k, v, causal=True, window=w,
                                 block_q=case["bq"], block_k=case["bk"],
                                 interpret=True)
    want = ref.attention(q, k, v, causal=True, window=w)
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("case", sweep_cases(1, 6, _attn_case))
def test_chunked_attention_fallback_matches_oracle(case):
    key = jax.random.PRNGKey(case["seed"])
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (case["B"], case["S"], case["H"], case["hd"]))
    k = jax.random.normal(ks[1], (case["B"], case["S"], case["KV"], case["hd"]))
    v = jax.random.normal(ks[2], (case["B"], case["S"], case["KV"], case["hd"]))
    w = case["window"] or None
    got = ops._chunked_attention(q, k, v, causal=True, window=w, q_offset=0,
                                 kv_mask=None, block_q=case["bq"],
                                 block_k=case["bk"])
    want = ref.attention(q, k, v, causal=True, window=w)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def _chunk_attn_case(rng):
    hd = int(rng.choice([16, 32, 64]))
    kv = int(rng.choice([1, 2, 4]))
    group = int(rng.choice([1, 2, 3]))
    C = int(rng.choice([16, 32, 64]))
    K = C * int(rng.integers(2, 6))
    # chunk offsets: start, interior (possibly unaligned), last chunk
    off = int(rng.choice([0, K // 3, K - C]))
    return dict(B=int(rng.integers(1, 3)), C=C, K=K, H=kv * group, KV=kv,
                hd=hd, off=off, bk=int(rng.choice([32, 64])),
                window=int(rng.choice([0, 48])),
                dtype=rng.choice(["float32", "bfloat16"]),
                seed=int(rng.integers(1 << 30)))


@pytest.mark.parametrize("case", sweep_cases(9, 8, _chunk_attn_case))
def test_chunk_attention_matches_oracle(case):
    """Cross-chunk prefill attention: a C-row query chunk at a (traced)
    offset over a deeper key buffer — prior keys visible, causal within the
    chunk, columns past the chunk end invisible."""
    key = jax.random.PRNGKey(case["seed"])
    ks = jax.random.split(key, 3)
    dt = jnp.dtype(case["dtype"])
    B, C, K, H, KV, hd = (case["B"], case["C"], case["K"], case["H"],
                          case["KV"], case["hd"])
    q = jax.random.normal(ks[0], (B, C, H, hd)).astype(dt)
    k = jax.random.normal(ks[1], (B, K, KV, hd)).astype(dt)
    v = jax.random.normal(ks[2], (B, K, KV, hd)).astype(dt)
    w = case["window"] or None
    off = jnp.asarray(case["off"], jnp.int32)  # traced offset path
    got = jax.jit(
        lambda q, k, v, o: chunk_attention_pallas(
            q, k, v, o, window=w, block_k=case["bk"], interpret=True)
    )(q, k, v, off)
    q_pos = jnp.broadcast_to(case["off"] + jnp.arange(C), (B, C))
    want = ref.attention(q, k, v, causal=True, window=w, q_pos=q_pos,
                         kv_mask=None)
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), atol=tol, rtol=tol)
    # the public wrapper (jnp fallback off-TPU) agrees as well
    got2 = ops.chunk_attention(q, k, v, q_offset=off, window=w)
    np.testing.assert_allclose(
        got2.astype(jnp.float32), want.astype(jnp.float32), atol=tol,
        rtol=tol)


@pytest.mark.parametrize("case", sweep_cases(2, 6, _attn_case))
def test_decode_attention_matches_oracle(case):
    key = jax.random.PRNGKey(case["seed"])
    ks = jax.random.split(key, 4)
    B, S, H, KV, hd = case["B"], case["S"], case["H"], case["KV"], case["hd"]
    q = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    mask = jax.random.bernoulli(ks[3], 0.8, (B, S))
    mask = mask.at[:, 0].set(True)  # never fully-masked
    got = decode_attention_pallas(q, k, v, kv_mask=mask,
                                  block_k=case["bk"], interpret=True)
    want = ref.decode_attention(q, k, v, kv_mask=mask)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_decode_attention_perhead_mask_fallback():
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 4)
    B, S, KV, G, hd = 2, 4096, 2, 3, 32
    q = jax.random.normal(ks[0], (B, KV * G, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    mask = jax.random.bernoulli(ks[3], 0.7, (B, S, KV)).at[:, 0].set(True)
    got = ops.decode_attention(q, k, v, kv_mask=mask, block_k=512)
    want = ref.decode_attention(q, k, v, kv_mask=mask)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("case", sweep_cases(4, 8, _attn_case))
def test_lookahead_score_matches_oracle(case):
    key = jax.random.PRNGKey(case["seed"])
    ks = jax.random.split(key, 2)
    B, S, H, KV, hd = case["B"], case["S"], case["H"], case["KV"], case["hd"]
    n_obs = min(16, S // 2)
    n_prompt = S - n_obs
    qo = jax.random.normal(ks[0], (B, n_obs, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    got = lookahead_score_pallas(qo, k, n_prompt, block_k=case["bk"],
                                 interpret=True)
    want = ref.lookahead_score(qo, k, n_prompt)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-3)
    # chunked jnp fallback too
    got2 = ops._chunked_lookahead_score(qo, k, n_prompt, kv_mask=None,
                                        window=None, q_offset=None,
                                        block_k=case["bk"])
    np.testing.assert_allclose(got2, want, atol=1e-5, rtol=1e-3)


def test_decode_attention_fully_masked_rows_finite():
    """A retired serving slot carries an all-False cache mask; the kernel
    must return finite output for such rows (the slot's result is discarded
    but NaNs would poison the whole batched step)."""
    key = jax.random.PRNGKey(11)
    ks = jax.random.split(key, 3)
    B, S, KV, G, hd = 2, 96, 2, 2, 16
    q = jax.random.normal(ks[0], (B, KV * G, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    mask = jnp.ones((B, S), bool).at[0].set(False)  # row 0 fully masked
    got = decode_attention_pallas(q, k, v, kv_mask=mask, block_k=32,
                                  interpret=True)
    assert np.isfinite(np.asarray(got)).all()
    np.testing.assert_array_equal(np.asarray(got[0]), 0.0)
    # the live row is unaffected by its dead neighbour
    want = ref.decode_attention(q[1:], k[1:], v[1:], kv_mask=mask[1:])
    np.testing.assert_allclose(got[1:], want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("sk,bk", [(100, 32), (7, 16), (130, 64), (33, 32)])
def test_decode_attention_unaligned_seq_parity(sk, bk):
    """Sk % block_k != 0: the kernel's tail padding must not leak into the
    output (serving caches are budget+margin long — rarely block-aligned)."""
    key = jax.random.PRNGKey(12)
    ks = jax.random.split(key, 4)
    B, KV, G, hd = 2, 2, 3, 16
    q = jax.random.normal(ks[0], (B, KV * G, hd))
    k = jax.random.normal(ks[1], (B, sk, KV, hd))
    v = jax.random.normal(ks[2], (B, sk, KV, hd))
    mask = jax.random.bernoulli(ks[3], 0.7, (B, sk)).at[:, 0].set(True)
    got = decode_attention_pallas(q, k, v, kv_mask=mask,
                                  block_k=min(bk, sk), interpret=True)
    want = ref.decode_attention(q, k, v, kv_mask=mask)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_lookahead_score_rows_sum_below_one():
    """Each obs row's prompt mass is < 1 (softmax includes obs keys)."""
    key = jax.random.PRNGKey(5)
    B, S, H, KV, hd = 2, 96, 4, 2, 16
    n_obs, n_prompt = 8, 88
    qo = jax.random.normal(key, (B, n_obs, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(6), (B, S, KV, hd))
    s = ref.lookahead_score(qo, k, n_prompt)
    assert (s.sum(-1) <= 1.0 + 1e-5).all()
    assert (s >= 0).all()


def _ssd_case(rng):
    hd = int(rng.choice([16, 32]))
    nh = int(rng.choice([2, 4, 8]))
    ds = int(rng.choice([8, 16]))
    chunk = int(rng.choice([16, 32]))
    nc = int(rng.integers(1, 5))
    return dict(B=int(rng.integers(1, 3)), S=chunk * nc, nh=nh, hd=hd, ds=ds,
                chunk=chunk, seed=int(rng.integers(1 << 30)))


@pytest.mark.parametrize("case", sweep_cases(7, 8, _ssd_case))
def test_ssd_scan_matches_sequential_oracle(case):
    key = jax.random.PRNGKey(case["seed"])
    ks = jax.random.split(key, 6)
    B, S, nh, hd, ds = case["B"], case["S"], case["nh"], case["hd"], case["ds"]
    x = jax.random.normal(ks[0], (B, S, nh, hd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, 1, ds))
    Cm = jax.random.normal(ks[4], (B, S, 1, ds))
    h0 = jax.random.normal(ks[5], (B, nh, hd, ds))
    want_y, want_h = ref.ssd_scan(x, dt, A, Bm, Cm, initial_state=h0)
    got_y, got_h = ssd_scan_pallas(x, dt, A, Bm, Cm, chunk=case["chunk"],
                                   block_nh=min(2, nh), initial_state=h0,
                                   interpret=True)
    np.testing.assert_allclose(got_y, want_y, atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(got_h, want_h, atol=2e-3, rtol=2e-3)
    got_y2, got_h2 = ops.ssd_scan_chunked_jnp(x, dt, A, Bm, Cm,
                                              chunk=case["chunk"],
                                              initial_state=h0)
    np.testing.assert_allclose(got_y2, want_y, atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(got_h2, want_h, atol=2e-3, rtol=2e-3)


def test_ssd_step_matches_scan():
    """Decode recurrence == one-step slice of the full scan."""
    key = jax.random.PRNGKey(11)
    ks = jax.random.split(key, 5)
    B, S, nh, hd, ds = 2, 8, 4, 16, 8
    x = jax.random.normal(ks[0], (B, S, nh, hd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, 1, ds))
    Cm = jax.random.normal(ks[4], (B, S, 1, ds))
    y_full, h_full = ref.ssd_scan(x, dt, A, Bm, Cm)
    h = jnp.zeros((B, nh, hd, ds))
    for t in range(S):
        y_t, h = ops.ssd_step(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], h)
        np.testing.assert_allclose(y_t, y_full[:, t], atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(h, h_full, atol=2e-4, rtol=2e-4)
