"""Continuous-batching serving: slot scheduler, chunked prefill, and the
slot-batched decode loop.

The load-bearing property is *exactness*: a request served through the
continuous engine — streamed chunk by chunk with online score
accumulation, scattered into a previously used decode slot, and decoded
in chunks next to unrelated neighbours — must produce the same tokens as
serving it alone through the lockstep engine.  Post-eviction caches being
shape-uniform is what makes the machinery possible; these tests are what
make it trustworthy.  The deprecated bucketed path keeps its own smoke
coverage at the bottom.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import EvictionConfig
from repro.configs import get_smoke_config
from repro.core import policies
from repro.core.lookahead import init_lookahead_params
from repro.models import transformer as tf
from repro.serving import (BucketedEngine, ContinuousEngine,
                           PrefillCompileCache, Request, ServingEngine,
                           SlotScheduler, batch_bucket, bucket_for,
                           pad_to_bucket, plan_step)

BUDGET = 8
MAX_NEW = 6
BUCKETS = (16, 32)
CHUNK = 16

# the bucketed path is deprecated-but-kept; its own tests stay authoritative.
# Only the *expected* deprecations are silenced (message-scoped), so any
# real DeprecationWarning from jax/numpy/our code still surfaces in CI logs.
pytestmark = [
    pytest.mark.filterwarnings(
        r"ignore:ServingEngine \(lockstep\) is deprecated"
        ":DeprecationWarning"),
    pytest.mark.filterwarnings(
        r"ignore:BucketedEngine \(pad-to-bucket prefill\) is deprecated"
        ":DeprecationWarning"),
    pytest.mark.filterwarnings(
        r"ignore:(bucket_for|batch_bucket|pad_to_bucket|PrefillCompileCache)"
        r" is deprecated"
        ":DeprecationWarning"),
]


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    lkv = init_lookahead_params(jax.random.PRNGKey(1), cfg, params["layers"])
    return cfg, params, lkv


def _requests(cfg, lens, seed=0, max_new=MAX_NEW):
    rng = np.random.default_rng(seed)
    return [Request(uid=i, prompt=rng.integers(
        0, cfg.vocab_size, n).astype(np.int32), max_new_tokens=max_new)
        for i, n in enumerate(lens)]


def _isolated(cfg, params, lkv, req, policy="lookaheadkv"):
    eng = ServingEngine(params, cfg, policy=policy,
                        evict=EvictionConfig(budget=BUDGET), lkv_params=lkv,
                        max_new_tokens=req.max_new_tokens, eos_id=-1)
    iso = Request(uid=req.uid, prompt=req.prompt,
                  max_new_tokens=req.max_new_tokens)
    eng.serve([iso])
    return iso.out_tokens


# ---------------------------------------------------------------------------
# host-side scheduling (no model)
# ---------------------------------------------------------------------------


def test_plan_step_budget_split():
    # decode is first-class: live slots always get their chunk; the rest of
    # the budget buys prefill chunks (at least one when a prefill pends)
    assert plan_step(token_budget=32, chunk=16, n_active=2, decode_steps=8,
                     prefill_pending=True) == (8, 1)
    assert plan_step(token_budget=48, chunk=16, n_active=0, decode_steps=8,
                     prefill_pending=True) == (0, 3)
    assert plan_step(token_budget=16, chunk=16, n_active=4, decode_steps=4,
                     prefill_pending=True) == (4, 1)  # progress guarantee
    assert plan_step(token_budget=32, chunk=16, n_active=2, decode_steps=8,
                     prefill_pending=False) == (8, 0)


def test_slot_scheduler_next_request_gated_by_free_slots():
    sched = SlotScheduler(1, bucket_for=lambda n: CHUNK)
    reqs = _requests(get_smoke_config("smollm-135m"), [8, 8], seed=1)
    for r in reqs:
        sched.submit(r)
    head = sched.next_request(now=0.0)
    assert head.uid == 0
    sched.place(head)
    assert sched.next_request(now=0.0) is None  # no free slot
    sched.retire(head, now=1.0)
    assert sched.next_request(now=1.0).uid == 1


def test_slot_scheduler_bookkeeping():
    sched = SlotScheduler(2, bucket_for=lambda n: bucket_for(n, BUCKETS))
    reqs = [Request(uid=i, prompt=np.zeros(n, np.int32), max_new_tokens=4)
            for i, n in enumerate([12, 30, 14])]
    for r in reqs:
        sched.submit(r)
    # head (len 12 -> bucket 16) groups with the other bucket-16 request,
    # skipping the bucket-32 one in between
    group = sched.next_prefill_group(now=0.0)
    assert [r.uid for r in group] == [0, 2]
    slots = [sched.place(r) for r in group]
    assert sched.free_slots() == 0
    assert sched.next_prefill_group(now=0.0) is None  # no free slot
    freed = sched.retire(group[0], now=1.0)
    assert freed == slots[0] and group[0].done
    group2 = sched.next_prefill_group(now=0.0)
    assert [r.uid for r in group2] == [1]
    assert sched.place(group2[0]) == freed  # retired slot is reused
    for r in (group[1], group2[0]):
        sched.retire(r, now=2.0)
    assert not sched.has_work()


def test_slot_scheduler_arrivals_gate_admission():
    sched = SlotScheduler(1, bucket_for=lambda n: 16)
    r = Request(uid=0, prompt=np.zeros(8, np.int32), max_new_tokens=2,
                arrival_s=5.0)
    sched.submit(r)
    assert sched.next_prefill_group(now=1.0) is None
    assert sched.next_arrival() == 5.0
    assert [q.uid for q in sched.next_prefill_group(now=5.0)] == [0]


def test_bucketing_helpers():
    assert bucket_for(12, BUCKETS) == 16
    assert bucket_for(17, BUCKETS) == 32
    assert bucket_for(33, BUCKETS) == 64  # auto-extends past the table
    assert batch_bucket(3, 8) == 4
    assert batch_bucket(5, 4) == 4  # capped
    toks, lens = pad_to_bucket([np.arange(3), np.arange(5)], 8, 4)
    assert toks.shape == (4, 8) and lens.tolist() == [3, 5, 8, 8]
    assert toks[0, :3].tolist() == [0, 1, 2] and toks[0, 3:].sum() == 0


def test_prefill_compile_cache_counts():
    built = []

    def build(policy, padded):
        built.append((policy, padded))
        return lambda a: a

    cache = PrefillCompileCache(build)
    cache.get(16, 2, "lookaheadkv", True)
    cache.get(16, 2, "lookaheadkv", True)
    cache.get(32, 2, "lookaheadkv", False)
    assert cache.stats() == {"entries": 2, "hits": 1, "misses": 2}
    assert built == [("lookaheadkv", True), ("lookaheadkv", False)]
    cache.warm([(16, 4, "lookaheadkv", True)])
    assert cache.stats()["entries"] == 3


# ---------------------------------------------------------------------------
# cache surgery + active-mask decode
# ---------------------------------------------------------------------------


def test_insert_extract_roundtrip_pads_capacity(model):
    cfg, params, lkv = model
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 12)))
    res = tf.prefill(params, cfg, toks, policy="lookaheadkv",
                     evict=EvictionConfig(budget=BUDGET), lkv_params=lkv,
                     extra_slots=3)
    cap_req = res.cache["attn"]["k"].shape[2]
    live = tf.init_decode_cache(cfg, 3, cap_req + 5, per_slot_cursor=True)
    live = tf.insert_request_cache(live, res.cache, 2)
    ext = tf.extract_request_cache(live, 2)
    np.testing.assert_array_equal(
        np.asarray(ext["attn"]["k"][:, :, :cap_req]),
        np.asarray(res.cache["attn"]["k"]))
    assert not np.asarray(ext["attn"]["mask"][:, :, cap_req:]).any()
    assert int(ext["cursor"][0]) == int(res.cache["cursor"])
    np.testing.assert_array_equal(np.asarray(ext["next_pos"]),
                                  np.asarray(res.cache["next_pos"]))


def test_inactive_slots_do_not_advance(model):
    cfg, params, lkv = model
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 12)))
    res = tf.prefill(params, cfg, toks, policy="lookaheadkv",
                     evict=EvictionConfig(budget=BUDGET), lkv_params=lkv,
                     extra_slots=4)
    cap = res.cache["attn"]["k"].shape[2]
    live = tf.init_decode_cache(cfg, 2, cap, per_slot_cursor=True)
    live = tf.insert_request_cache(live, res.cache, 0)
    tok = jnp.zeros((2, 1), jnp.int32)
    active = jnp.asarray([False, True])  # slot 0 is retired/idle
    nxt, new = policies.decode_one(params, cfg, tok, live, active=active)
    assert int(nxt[0, 0]) == 0  # frozen token
    for a, b in zip(jax.tree.leaves(tf.extract_request_cache(new, 0)),
                    jax.tree.leaves(tf.extract_request_cache(live, 0))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the active slot did advance
    assert int(new["cursor"][1]) == int(live["cursor"][1]) + 1


# ---------------------------------------------------------------------------
# end-to-end exactness (the acceptance property)
# ---------------------------------------------------------------------------


def test_retired_slot_refill_matches_isolated(model):
    """One slot, three queued requests: each admission scatters into the
    slot the previous request retired from, and every request's tokens
    match serving it alone through the lockstep engine."""
    cfg, params, lkv = model
    reqs = _requests(cfg, [12, 16, 26], seed=4)
    eng = ContinuousEngine(params, cfg, policy="lookaheadkv",
                           evict=EvictionConfig(budget=BUDGET),
                           lkv_params=lkv, num_slots=1, chunk=CHUNK,
                           max_context=32, max_new_tokens=MAX_NEW, eos_id=-1)
    done = eng.run(reqs)
    assert len(done) == 3 and all(r.done for r in done)
    assert all(r.slot == 0 for r in done)  # same slot, reused twice
    for r in done:
        assert r.out_tokens == _isolated(cfg, params, lkv, r), r.uid
        assert r.ttft_s > 0 and r.first_token_s is not None
    # later admissions waited on the busy slot
    by_uid = sorted(done, key=lambda r: r.uid)
    assert by_uid[2].ttft_s > by_uid[0].ttft_s


def test_mixed_length_slots_match_isolated(model):
    """Two slots, mixed prompt lengths (divisible and not by the chunk)
    decoding side by side — one compiled chunk shape serves them all."""
    cfg, params, lkv = model
    reqs = _requests(cfg, [12, 26, 32, 9], seed=5)
    eng = ContinuousEngine(params, cfg, policy="lookaheadkv",
                           evict=EvictionConfig(budget=BUDGET),
                           lkv_params=lkv, num_slots=2, chunk=CHUNK,
                           max_context=48, max_new_tokens=MAX_NEW, eos_id=-1)
    done = eng.run(reqs)
    assert len(done) == 4
    for r in done:
        assert len(r.out_tokens) == MAX_NEW
        assert r.out_tokens == _isolated(cfg, params, lkv, r), r.uid
    # one chunk-step program + one finalize program, regardless of the
    # four distinct prompt lengths
    assert eng.chunk_cache.stats()["entries"] == 2


def test_position_policy_exact_chunked(model):
    """streaming_llm is attention-free; chunked streaming must not perturb
    its position scores (or the decode tokens)."""
    cfg, params, _ = model
    reqs = _requests(cfg, [11, 16], seed=6)
    eng = ContinuousEngine(params, cfg, policy="streaming_llm",
                           evict=EvictionConfig(budget=BUDGET),
                           num_slots=2, chunk=CHUNK, max_context=32,
                           max_new_tokens=MAX_NEW, eos_id=-1)
    done = eng.run(reqs)
    for r in done:
        assert r.out_tokens == _isolated(cfg, params, None, r,
                                         policy="streaming_llm"), r.uid


def test_single_token_request_retires_at_admission(model):
    cfg, params, lkv = model
    reqs = _requests(cfg, [12, 14], seed=7, max_new=1)
    eng = ContinuousEngine(params, cfg, policy="lookaheadkv",
                           evict=EvictionConfig(budget=BUDGET),
                           lkv_params=lkv, num_slots=1, chunk=CHUNK,
                           max_context=32, max_new_tokens=MAX_NEW, eos_id=-1)
    done = eng.run(reqs)
    assert [len(r.out_tokens) for r in done] == [1, 1]
    assert all(r.done and r.tpot_s == 0.0 for r in done)


def test_random_policy_decorrelated_across_requests(model):
    """The per-request fold_in seed: two different requests with identical
    prompts must not evict the same 'random' positions (the old fixed
    PRNGKey(seed) gave every request in every batch one shared pattern),
    while the same request replayed stays deterministic."""
    cfg, params, _ = model
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab_size, 24).astype(np.int32)
    ev = EvictionConfig(budget=BUDGET)
    toks = jnp.asarray(np.stack([prompt, prompt]))
    res = policies.run_eviction("random", params, cfg, toks, evict=ev,
                                seeds=jnp.asarray([0, 1], jnp.int32))
    pos = np.asarray(res.cache["attn"]["pos"])
    mask = np.asarray(res.cache["attn"]["mask"])
    kept0 = set(pos[0, 0, mask[0, 0, :, 0], 0].tolist())
    kept1 = set(pos[0, 1, mask[0, 1, :, 0], 0].tolist())
    assert kept0 != kept1  # decorrelated rows
    res2 = policies.run_eviction("random", params, cfg, toks, evict=ev,
                                 seeds=jnp.asarray([0, 1], jnp.int32))
    np.testing.assert_array_equal(pos, np.asarray(res2.cache["attn"]["pos"]))


# ---------------------------------------------------------------------------
# deprecated engines: importable, warn on construction, still serve
# ---------------------------------------------------------------------------


def test_deprecated_engines_warn_and_still_serve(model):
    """Deprecate-but-keep: ServingEngine (lockstep) and BucketedEngine emit
    a DeprecationWarning yet still produce the exact tokens the chunked
    engine serves — the benchmark baseline contract."""
    cfg, params, lkv = model
    kw = dict(policy="lookaheadkv", evict=EvictionConfig(budget=BUDGET),
              lkv_params=lkv, max_new_tokens=MAX_NEW, eos_id=-1)
    with pytest.warns(DeprecationWarning):
        lock = ServingEngine(params, cfg, **kw)
    with pytest.warns(DeprecationWarning):
        bucketed = BucketedEngine(params, cfg, num_slots=1, buckets=BUCKETS,
                                  **kw)
    with pytest.warns(DeprecationWarning):
        bucket_for(12, BUCKETS)
    reqs = _requests(cfg, [12], seed=8)
    chunked = ContinuousEngine(params, cfg, num_slots=1, chunk=CHUNK,
                               max_context=32, **kw)
    got = chunked.run(reqs)[0].out_tokens
    lock_req = _requests(cfg, [12], seed=8)
    lock.serve(lock_req)
    assert lock_req[0].out_tokens == got
    bucket_req = _requests(cfg, [12], seed=8)
    assert bucketed.run(bucket_req)[0].out_tokens == got


def test_padded_prefill_parity(model):
    """Bucket-padded lookaheadkv prefill is exact: same next-token logits
    and the same kept (layer, head, position) sets as unpadded prefill."""
    cfg, params, lkv = model
    rng = np.random.default_rng(8)
    lens = [10, 16]
    bucket = 16
    toks = np.zeros((2, bucket), np.int32)
    for i, n in enumerate(lens):
        toks[i, :n] = rng.integers(0, cfg.vocab_size, n)
    ev = EvictionConfig(budget=BUDGET)
    pad = tf.prefill(params, cfg, jnp.asarray(toks), policy="lookaheadkv",
                     evict=ev, lkv_params=lkv, extra_slots=2,
                     prompt_lens=jnp.asarray(lens))
    for i, n in enumerate(lens):
        exact = tf.prefill(params, cfg, jnp.asarray(toks[i:i + 1, :n]),
                           policy="lookaheadkv", evict=ev, lkv_params=lkv,
                           extra_slots=2)
        np.testing.assert_array_equal(np.asarray(pad.logits[i]),
                                      np.asarray(exact.logits[0]))
        mp = np.asarray(pad.cache["attn"]["mask"][:, i])
        pp = np.asarray(pad.cache["attn"]["pos"][:, i])
        me = np.asarray(exact.cache["attn"]["mask"][:, 0])
        pe = np.asarray(exact.cache["attn"]["pos"][:, 0])
        L, _, KV = mp.shape
        for layer in range(L):
            for h in range(KV):
                kept_pad = set(pp[layer, mp[layer, :, h], h].tolist())
                kept_exact = set(pe[layer, me[layer, :, h], h].tolist())
                assert kept_pad == kept_exact, (i, layer, h)
        assert int(pad.cache["next_pos"][i, 0]) == n
