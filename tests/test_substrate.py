"""Substrate tests: optimizer, checkpoint round-trip, data pipeline."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import io as ckpt
from repro.common.config import TrainConfig
from repro.data import synthetic
from repro.optim import adam


def test_adam_minimizes_quadratic():
    tc = TrainConfig(steps=200, lr=0.1, warmup_frac=0.0, grad_clip=10.0)
    params = {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray(5.0)}
    opt = adam.init(params)

    def loss_fn(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    for _ in range(200):
        grads = jax.grad(loss_fn)(params)
        params, opt, m = adam.update(params, grads, opt, tc)
    assert float(loss_fn(params)) < 1e-3


def test_cosine_schedule_shape():
    tc = TrainConfig(steps=100, lr=1.0, warmup_frac=0.1)
    lrs = [float(adam.cosine_lr(s, tc)) for s in range(100)]
    assert lrs[0] < lrs[9]  # warmup rises
    assert abs(max(lrs) - 1.0) < 0.06
    assert lrs[-1] < 0.01  # cosine decays to ~0
    assert all(b <= a + 1e-9 for a, b in zip(lrs[10:], lrs[11:]))


def test_grad_clipping():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = adam.clip_by_global_norm(g, 1.0)
    assert float(norm) > 100
    n2 = float(jnp.linalg.norm(clipped["a"]))
    assert abs(n2 - 1.0) < 1e-4


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
              "b": jnp.ones((4,), jnp.bfloat16) * 1.5},
        "c": jnp.asarray([1, 2, 3], jnp.int32),
    }
    path = os.path.join(tmp_path, "ck.npz")
    ckpt.save(path, tree, metadata={"step": 7})
    back = ckpt.load(path, like=tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert ckpt.metadata(path)["step"] == 7


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    tree = {"w": jnp.zeros((2, 2))}
    path = os.path.join(tmp_path, "ck.npz")
    ckpt.save(path, tree)
    with pytest.raises(AssertionError):
        ckpt.load(path, like={"w": jnp.zeros((3, 2))})


def test_needle_batch_structure():
    rng = np.random.default_rng(0)
    b = synthetic.make_needle_batch(rng, 4, 128, 1000)
    assert b.x.shape == (4, 128) and b.y.shape == (4, 8)
    for i in range(4):
        # the value sits at answer_pos and the key is repeated at the end
        np.testing.assert_array_equal(b.x[i, b.answer_pos[i]], b.y[i])
        key_start = b.answer_pos[i][0] - 4
        np.testing.assert_array_equal(b.x[i, key_start:key_start + 4],
                                      b.x[i, -4:])
    assert (b.x >= 0).all() and (b.x < 1000).all()


def test_copy_batch_structure():
    rng = np.random.default_rng(1)
    b = synthetic.make_copy_batch(rng, 2, 96, 500)
    for i in range(2):
        np.testing.assert_array_equal(b.x[i, b.answer_pos[i]], b.y[i])


def test_mixture_iterator_deterministic():
    from repro.configs import get_smoke_config

    cfg = get_smoke_config("smollm-135m")
    it1 = synthetic.MixtureIterator(cfg, 2, 64, 8, seed=3)
    it2 = synthetic.MixtureIterator(cfg, 2, 64, 8, seed=3)
    for _ in range(3):
        b1, b2 = next(it1), next(it2)
        np.testing.assert_array_equal(b1.x, b2.x)
        np.testing.assert_array_equal(b1.y, b2.y)
    assert b1.x.shape == (2, 64) and b1.y.shape == (2, 8)


def test_mixture_with_model_generation():
    from repro.configs import get_smoke_config
    from repro.models import transformer as tf

    cfg = get_smoke_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    it = synthetic.MixtureIterator(cfg, 2, 32, 6, seed=0, gen_params=params)
    b = next(it)
    assert b.y.shape == (2, 6)
    assert (b.y >= 0).all() and (b.y < cfg.vocab_size).all()
