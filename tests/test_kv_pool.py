"""Paged KV memory: allocator invariants, paged-vs-dense serving
bit-identity, preempt-to-queue, pool-backed prefix sharing, and
kernel-vs-oracle parity.

Four layers of proof, least to most end-to-end:

1. **Allocator invariants** (no model): the pool is conserved under
   adversarial alloc/free/incref/reserve interleavings, double-frees and
   null-block frees fail loudly, reservations fence ordinary allocations,
   and ``check()`` holds after every step.
2. **Kernel parity** (no engine): the Pallas block-table decode kernel
   matches the dense-gather oracle over randomized GQA shapes, ragged
   tables (null entries, null tails), per-head masks, and fully-masked
   tail blocks.
3. **Differential traces** (the headline): serving a seeded randomized
   trace through ``ContinuousEngine`` with a ``KVBlockPool`` emits
   *bit-identical tokens and kept (layer, head, position) sets* as dense
   serving — every servable single-pass policy, chunk sizes 128 and 256,
   prompts not divisible by the chunk, on both the jnp and forced-Pallas
   dispatch paths (the CI matrix runs this file under both).
4. **Memory pressure**: a deliberately tiny pool under burst arrivals
   (optimistic admission) preempts running requests to the queue — and
   the re-served tokens are still bit-identical, with the pool conserved
   and fully drained afterwards.  Pool-backed prefix-cache entries share
   the same pool without perturbing any of it.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import sweep_cases
from repro.configs import get_smoke_config
from repro.core import policies
from repro.core.lookahead import init_lookahead_params
from repro.kernels import ops, ref
from repro.kernels.paged_attention import (paged_decode_attention_pallas,
                                           paged_decode_masses_pallas)
from repro.models import transformer as tf
from repro.serving import DecodeEvictionConfig, KVBlockPool, PrefixCache
from repro.serving.engine import paged_sweep
from trace_utils import kept_sets, make_trace_requests, run_trace

ENGINE_POLICIES = [p for p in policies.SINGLE_PASS
                   if p not in ("gt_oracle", "full")]


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    lkv = init_lookahead_params(jax.random.PRNGKey(1), cfg, params["layers"])
    return cfg, params, lkv


def _pool(cfg, **kw):
    kw.setdefault("block_size", 16)
    kw.setdefault("num_blocks", 128)
    return KVBlockPool(cfg, **kw)


# ---------------------------------------------------------------------------
# 1. allocator invariants (no model forward passes)
# ---------------------------------------------------------------------------


def test_allocator_basics_and_double_free():
    cfg = get_smoke_config("smollm-135m")
    pool = _pool(cfg, num_blocks=8)
    assert pool.usable_blocks == 8 and pool.free_blocks() == 8
    assert pool.blocks_for(0) == 0
    assert pool.blocks_for(1) == 1
    assert pool.blocks_for(16) == 1
    assert pool.blocks_for(17) == 2
    a = pool.alloc(3)
    assert a is not None and len(a) == 3 and 0 not in a
    assert pool.used_blocks() == 3 and pool.high_water == 3
    assert pool.alloc(6) is None, "over-allocation must refuse, not split"
    pool.incref(a[:1])  # shared block survives one free
    pool.free(a)
    assert pool.used_blocks() == 1
    pool.free(a[:1])
    assert pool.used_blocks() == 0
    with pytest.raises(AssertionError):
        pool.free(a[:1])  # double-free
    with pytest.raises(AssertionError):
        pool.free([0])  # the null block is never allocatable
    pool.check()


def test_reservations_fence_ordinary_allocations():
    cfg = get_smoke_config("smollm-135m")
    pool = _pool(cfg, num_blocks=8)
    assert pool.reserve(5)
    assert pool.available_blocks() == 3
    assert pool.alloc(4) is None, "ordinary alloc dipped into a reservation"
    assert pool.alloc(3) is not None
    assert not pool.reserve(1), "over-promise accepted"
    got = pool.alloc(2, from_reserved=True)
    assert got is not None and pool.reserved == 3
    pool.unreserve(3)
    assert pool.reserved == 0
    pool.check()


def test_allocator_invariants_under_adversarial_interleavings():
    cfg = get_smoke_config("smollm-135m")
    for case in sweep_cases(7, 5, lambda r: {"seed": int(r.integers(1e6))}):
        rng = np.random.default_rng(case["seed"])
        pool = _pool(cfg, num_blocks=int(rng.integers(8, 32)))
        held: list[np.ndarray] = []   # refcount-1 runs
        shared: list[np.ndarray] = []  # runs holding an extra ref
        for _ in range(200):
            op = rng.integers(5)
            if op == 0:
                ids = pool.alloc(int(rng.integers(1, 4)))
                if ids is not None:
                    held.append(ids)
            elif op == 1 and held:
                pool.free(held.pop(int(rng.integers(len(held)))))
            elif op == 2 and held:
                ids = held[int(rng.integers(len(held)))]
                pool.incref(ids)
                shared.append(ids)
            elif op == 3 and shared:
                pool.free(shared.pop(int(rng.integers(len(shared)))))
            elif op == 4:
                if rng.random() < 0.5:
                    pool.reserve(int(rng.integers(0, 3)))
                elif pool.reserved:
                    pool.unreserve(1)
            pool.check()
        pool.unreserve(pool.reserved)
        for ids in shared:
            pool.free(ids)
        for ids in held:
            pool.free(ids)
        pool.check()
        assert pool.used_blocks() == 0, "pool not conserved after drain"


# ---------------------------------------------------------------------------
# 2. kernel-vs-oracle parity (ragged tables, masked tails, per-head masks)
# ---------------------------------------------------------------------------


def _paged_case(rng):
    kv = int(rng.choice([1, 2]))
    return {
        "B": int(rng.integers(1, 4)),
        "KV": kv,
        "G": int(rng.choice([1, 3])),
        "hd": int(rng.choice([16, 32])),
        "bs": int(rng.choice([4, 8, 16])),
        "N": int(rng.integers(4, 12)),
        "nb": int(rng.integers(1, 6)),
        "seed": int(rng.integers(1e6)),
    }


@pytest.mark.parametrize("case", sweep_cases(11, 8, _paged_case))
def test_paged_kernel_matches_oracle(case):
    rng = np.random.default_rng(case["seed"])
    B, KV, hd, bs = case["B"], case["KV"], case["hd"], case["bs"]
    N, nb, H = case["N"], case["nb"], case["KV"] * case["G"]
    q = jnp.asarray(rng.normal(size=(B, H, hd)), jnp.float32)
    pk = jnp.asarray(rng.normal(size=(N, bs, KV, hd)), jnp.float32)
    pv = jnp.asarray(rng.normal(size=(N, bs, KV, hd)), jnp.float32)
    pm = jnp.asarray(rng.random((N, bs, KV)) > 0.3)
    pm = pm.at[0].set(False)  # the null block is permanently invalid
    # ragged tables: null tails and interleaved null entries
    tbl = np.zeros((B, nb), np.int32)
    for b in range(B):
        n_live = int(rng.integers(0, nb + 1))
        tbl[b, :n_live] = rng.choice(np.arange(1, N), n_live, replace=False)
        rng.shuffle(tbl[b])
    tbl = jnp.asarray(tbl)
    want = ref.paged_decode_attention(q, pk, pv, pm, tbl)
    got = paged_decode_attention_pallas(q, pk, pv, pm, tbl, interpret=True)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_paged_kernel_fully_masked_tail_blocks():
    """A table whose live blocks are followed by all-null (or fully masked)
    tail blocks must match the oracle — and an entirely dead sequence must
    come out exact-zero, not NaN."""
    rng = np.random.default_rng(0)
    B, H, KV, hd, bs, N, nb = 2, 4, 2, 32, 8, 6, 4
    q = jnp.asarray(rng.normal(size=(B, H, hd)), jnp.float32)
    pk = jnp.asarray(rng.normal(size=(N, bs, KV, hd)), jnp.float32)
    pv = jnp.asarray(rng.normal(size=(N, bs, KV, hd)), jnp.float32)
    pm = jnp.ones((N, bs, KV), bool).at[0].set(False)
    pm = pm.at[5].set(False)  # an allocated-but-fully-masked block
    tbl = jnp.asarray([[1, 2, 5, 0], [0, 0, 0, 0]], jnp.int32)
    want = ref.paged_decode_attention(q, pk, pv, pm, tbl)
    got = paged_decode_attention_pallas(q, pk, pv, pm, tbl, interpret=True)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)
    assert np.all(np.asarray(got[1]) == 0.0), "dead sequence must be zeros"


def test_ops_paged_dispatch_matches_oracle():
    """The public wrapper agrees with the oracle on whichever path the
    environment dispatches (jnp gather here, the kernel under
    REPRO_FORCE_PALLAS=1 in the CI matrix)."""
    rng = np.random.default_rng(1)
    B, H, KV, hd, bs, N, nb = 2, 6, 2, 16, 4, 8, 5
    q = jnp.asarray(rng.normal(size=(B, H, hd)), jnp.float32)
    pk = jnp.asarray(rng.normal(size=(N, bs, KV, hd)), jnp.float32)
    pv = jnp.asarray(rng.normal(size=(N, bs, KV, hd)), jnp.float32)
    pm = jnp.asarray(rng.random((N, bs, KV)) > 0.2).at[0].set(False)
    tbl = jnp.asarray(rng.integers(0, N, (B, nb)), jnp.int32)
    want = ref.paged_decode_attention(q, pk, pv, pm, tbl)
    got = ops.paged_decode_attention(q, pk, pv, pm, tbl)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# 3. differential traces: paged serving is bit-identical to dense
# ---------------------------------------------------------------------------


def _assert_paged_differential(cfg, params, lkv, *, policy, requests, chunk,
                               pool, **kw):
    base, _ = run_trace(cfg, params, lkv, policy=policy, requests=requests,
                        chunk=chunk, **kw)
    got, eng = run_trace(cfg, params, lkv, policy=policy, requests=requests,
                         chunk=chunk, kv_pool=pool, **kw)
    for uid, want in base.items():
        r = got[uid]
        assert r.out_tokens == want.out_tokens, \
            f"policy={policy} chunk={chunk} uid={uid}: tokens diverged"
        assert kept_sets(r.admission_cache) == kept_sets(
            want.admission_cache), \
            f"policy={policy} chunk={chunk} uid={uid}: kept sets diverged"
    return eng


@pytest.mark.parametrize("chunk", [128, 256])
@pytest.mark.parametrize("policy", ENGINE_POLICIES)
def test_paged_vs_dense_differential(model, policy, chunk):
    """Tokens and kept sets are bit-equal paged vs dense for every
    servable single-pass policy and both chunk sizes (mixed non-divisible
    prompt lengths)."""
    cfg, params, lkv = model
    reqs = make_trace_requests(cfg, chunk=chunk, seed=0, n_requests=4,
                               max_new=3)
    pool = _pool(cfg)
    eng = _assert_paged_differential(cfg, params, lkv, policy=policy,
                                     requests=reqs, chunk=chunk, pool=pool,
                                     decode_chunk=2)
    pool.check()
    assert pool.used_blocks() == 0, "retired requests must drain the pool"
    assert eng.stats["kv_pool"]["high_water_blocks"] > 0


def test_paged_differential_burst_concurrency(model):
    """Simultaneous arrivals exercise concurrent slots sharing the pool —
    zombie slots must never corrupt a neighbour's blocks."""
    cfg, params, lkv = model
    reqs = make_trace_requests(cfg, chunk=128, seed=2, n_requests=6,
                               max_new=5)
    for r in reqs:
        r.arrival_s = 0.0
    pool = _pool(cfg)
    eng = _assert_paged_differential(cfg, params, lkv, policy="h2o",
                                     requests=reqs, chunk=128, pool=pool,
                                     num_slots=4, decode_chunk=2)
    pool.check()
    assert pool.used_blocks() == 0
    assert eng.stats["max_concurrency"] >= 1


# ---------------------------------------------------------------------------
# 4. memory pressure: preemption, gated admission, prefix sharing
# ---------------------------------------------------------------------------


def test_preemption_under_tiny_pool_is_exact(model):
    """Optimistic admission over a pool that cannot hold every admitted
    request's growth: the engine must preempt to the queue, re-serve
    bit-identically, and leave the pool conserved."""
    cfg, params, lkv = model
    chunk = 128
    reqs = make_trace_requests(cfg, chunk=chunk, seed=5, n_requests=6,
                               max_new=8, suffix_lens=(0, 1, 77))
    for r in reqs:
        r.arrival_s = 0.0
    base, _ = run_trace(cfg, params, lkv, policy="streaming_llm",
                        requests=reqs, chunk=chunk, num_slots=3,
                        decode_chunk=1)
    # depth = budget(8) + margin(9) = 17 rows; 5 blocks of 4 rows per
    # request worst-case — 7 usable blocks admit two but can't grow both
    pool = _pool(cfg, block_size=4, num_blocks=7)
    got, eng = run_trace(cfg, params, lkv, policy="streaming_llm",
                         requests=reqs, chunk=chunk, num_slots=3,
                         decode_chunk=1, kv_pool=pool,
                         reserve_appends=False)
    for uid, want in base.items():
        assert got[uid].out_tokens == want.out_tokens, uid
        assert kept_sets(got[uid].admission_cache) == kept_sets(
            want.admission_cache), uid
    assert eng.stats["preemptions"] > 0, \
        "tiny pool under burst must exercise preempt-to-queue"
    pool.check()
    assert pool.used_blocks() == 0
    assert pool.reserved == 0


def test_pool_backed_prefix_cache_shares_and_reclaims(model):
    """Prefix-cache entries pinned as block runs in the serving pool:
    differential exactness holds, pins are accounted, and eviction
    returns every block."""
    cfg, params, lkv = model
    chunk = 128
    reqs = make_trace_requests(cfg, chunk=chunk, seed=3, n_requests=5,
                               max_new=3)
    base, _ = run_trace(cfg, params, lkv, policy="lookaheadkv",
                        requests=reqs, chunk=chunk, decode_chunk=2)
    pool = _pool(cfg, block_size=16, num_blocks=256)
    cache = PrefixCache(chunk=chunk, max_bytes=1 << 30, pool=pool)
    got, eng = run_trace(cfg, params, lkv, policy="lookaheadkv",
                         requests=reqs, chunk=chunk, kv_pool=pool,
                         prefix_cache=cache, decode_chunk=2)
    for uid, want in base.items():
        assert got[uid].out_tokens == want.out_tokens, uid
        assert kept_sets(got[uid].admission_cache) == kept_sets(
            want.admission_cache), uid
    s = cache.stats()
    assert s["hits"] > 0 and s["pool_blocks_pinned"] > 0
    assert pool.pinned_blocks == s["pool_blocks_pinned"]
    assert pool.used_blocks() == s["pool_blocks_pinned"], \
        "only prefix pins may outlive the trace"
    # live traffic reclaims cached prefixes on demand
    assert cache.evict_pool_blocks(s["pool_blocks_pinned"])
    pool.check()
    assert pool.used_blocks() == 0 and pool.pinned_blocks == 0


def test_reserve_failure_reclaims_prefix_blocks_no_livelock(model):
    """Regression: a pool whose free space is almost entirely prefix-cache
    pins must still admit under ``reserve_appends`` — the reserve-failure
    path reclaims cached prefixes instead of re-queueing the head forever
    (the admission gate counts evictable blocks as free, so giving up
    without evicting restores the exact pre-attempt state: a livelock)."""
    cfg, params, lkv = model
    chunk = 128
    reqs = make_trace_requests(cfg, chunk=chunk, seed=8, n_requests=3,
                               max_new=4, suffix_lens=(0, 1))
    base, _ = run_trace(cfg, params, lkv, policy="streaming_llm",
                        requests=reqs, chunk=chunk, decode_chunk=2)
    # depth = budget(8)+margin(5) = 13 rows -> 2 data + 2 append blocks of
    # 4 rows; the first admission's prefix inserts pin most of the pool,
    # so later admissions must evict cached spans to keep their promises
    pool = _pool(cfg, block_size=4, num_blocks=36)
    cache = PrefixCache(chunk=chunk, max_bytes=1 << 30, pool=pool)
    got, eng = run_trace(cfg, params, lkv, policy="streaming_llm",
                         requests=reqs, chunk=chunk, decode_chunk=2,
                         kv_pool=pool, prefix_cache=cache)
    for uid, want in base.items():
        assert got[uid].out_tokens == want.out_tokens, uid
    pool.check()
    assert pool.reserved == 0


def test_prefix_insert_skipped_when_pool_is_consumed(model):
    """A pool with no room for prefix spans must not break serving — the
    insert is skipped, traffic still serves exactly."""
    cfg, params, lkv = model
    chunk = 128
    reqs = make_trace_requests(cfg, chunk=chunk, seed=4, n_requests=3,
                               max_new=3)
    base, _ = run_trace(cfg, params, lkv, policy="h2o", requests=reqs,
                        chunk=chunk, decode_chunk=2)
    pool = _pool(cfg, block_size=16, num_blocks=6)  # decode fits, spans don't
    cache = PrefixCache(chunk=chunk, max_bytes=1 << 30, pool=pool)
    got, _ = run_trace(cfg, params, lkv, policy="h2o", requests=reqs,
                       chunk=chunk, kv_pool=pool, prefix_cache=cache,
                       decode_chunk=2)
    for uid, want in base.items():
        assert got[uid].out_tokens == want.out_tokens, uid
    pool.check()


# ---------------------------------------------------------------------------
# 5. observability
# ---------------------------------------------------------------------------


def test_pool_stats_and_engine_reporting(model):
    cfg, params, lkv = model
    reqs = make_trace_requests(cfg, chunk=128, seed=6, n_requests=3,
                               max_new=3)
    pool = _pool(cfg)
    got, eng = run_trace(cfg, params, lkv, policy="snapkv", requests=reqs,
                         chunk=128, kv_pool=pool, decode_chunk=2)
    s = eng.stats["kv_pool"]
    for key in ("blocks_total", "blocks_used", "blocks_free",
                "blocks_reserved", "blocks_pinned_prefix",
                "high_water_blocks", "bytes_total", "bytes_high_water",
                "queued", "preemptions"):
        assert key in s, key
    assert s["high_water_blocks"] > 0
    assert 0 < eng.stats["max_concurrency"] <= eng.num_slots
    cb = eng.cache_bytes(128)
    assert "pool" in cb and cb["evicted"] > 0
    assert eng.kv_device_bytes() == s["bytes_total"]


# ---------------------------------------------------------------------------
# 6. async-dispatch mirror snapshots
# ---------------------------------------------------------------------------


def test_mirror_snapshots_are_frozen_copies(model):
    """The paged dispatch hands jax *snapshots* of the host mirrors: jax
    stages host->device transfers lazily, so mutating a mirror in place
    after the call (cursor advance, retirement bookkeeping) must never
    change what an in-flight dispatch reads.  ``_snapshot`` hands jax a
    private read-only copy; the original mirror stays writable."""
    from repro.serving import engine as engine_mod

    a = np.arange(6, dtype=np.int32)
    snap = engine_mod._snapshot(a)
    a[:] = -1  # post-dispatch mirror mutation, as the engine does in place
    assert np.asarray(snap).tolist() == [0, 1, 2, 3, 4, 5]
    assert a.flags.writeable  # only the handed-off copy is frozen

    cfg, params, lkv = model
    reqs = make_trace_requests(cfg, chunk=128, seed=9, n_requests=2,
                               max_new=3)
    pool = _pool(cfg)
    _, eng = run_trace(cfg, params, lkv, policy="h2o", requests=reqs,
                       chunk=128, kv_pool=pool, decode_chunk=2)
    before = np.asarray(eng._table_dev).copy()
    eng._table_h[:] = 7  # the device snapshot must not alias the mirror
    assert np.array_equal(np.asarray(eng._table_dev), before)


# ---------------------------------------------------------------------------
# 7. decode-time streaming eviction on the paged pool
# ---------------------------------------------------------------------------


def test_paged_sweep_matches_numpy_topk():
    """The jitted evict-and-compact sweep against a from-scratch numpy
    reference: per (layer, kv head) keep the ``capacity`` highest-scoring
    valid rows in temporal order, compact them into the head blocks,
    zero the pad, carry kept score tallies, and touch *nothing* else —
    not other slots' score lanes, not blocks outside the keep run."""
    rng = np.random.default_rng(0)
    L, KV, hd, bs = 2, 2, 8, 4
    capacity, depth = 6, 16  # nb=4 blocks, nb_keep=2, pad rows 6..8 dead
    nb, nb_keep = 4, 2
    num_slots, N = 3, 12
    k = rng.normal(size=(L, N, bs, KV, hd)).astype(np.float32)
    v = rng.normal(size=(L, N, bs, KV, hd)).astype(np.float32)
    pos = rng.integers(0, 500, size=(L, N, bs, KV)).astype(np.int32)
    mask = rng.random((L, N, bs, KV)) < 0.8
    mask[:, 0] = False  # the null block
    score = rng.random((L, num_slots, depth, KV)).astype(np.float32)
    slot = 1
    table = np.zeros((num_slots, nb), np.int32)
    table[slot] = rng.choice(np.arange(1, N), nb, replace=False)
    newpool, newscore = paged_sweep(
        {"k": jnp.asarray(k), "v": jnp.asarray(v),
         "pos": jnp.asarray(pos), "mask": jnp.asarray(mask)},
        jnp.asarray(score), jnp.asarray(table),
        jnp.asarray(slot, jnp.int32), capacity=capacity, depth=depth,
        block_size=bs, nb_keep=nb_keep)
    newpool = {n: np.asarray(x) for n, x in newpool.items()}
    newscore = np.asarray(newscore)

    row = table[slot]
    keep_ids = row[:nb_keep]

    def dense(x, ids, rows):
        g = x[:, ids]
        return g.reshape((L, len(ids) * bs) + x.shape[3:])[:, :rows]

    kd, vd = dense(k, row, depth), dense(v, row, depth)
    pd, md = dense(pos, row, depth), dense(mask, row, depth)
    kn = dense(newpool["k"], keep_ids, nb_keep * bs)
    vn = dense(newpool["v"], keep_ids, nb_keep * bs)
    pn = dense(newpool["pos"], keep_ids, nb_keep * bs)
    mn = dense(newpool["mask"], keep_ids, nb_keep * bs)
    for lyr in range(L):
        for h in range(KV):
            s = np.where(md[lyr, :, h], score[lyr, slot, :, h], -np.inf)
            keep = np.sort(np.argsort(-s, kind="stable")[:capacity])
            kept = md[lyr, keep, h]
            assert np.array_equal(mn[lyr, :capacity, h], kept), (lyr, h)
            assert not mn[lyr, capacity:, h].any(), "pad rows must be dead"
            j = np.nonzero(kept)[0]
            src = keep[kept]
            assert np.array_equal(kn[lyr, j, h], kd[lyr, src, h])
            assert np.array_equal(vn[lyr, j, h], vd[lyr, src, h])
            assert np.array_equal(pn[lyr, j, h], pd[lyr, src, h])
            dead = np.setdiff1d(np.arange(nb_keep * bs), j)
            assert np.all(kn[lyr, dead, h] == 0.0), "evicted rows leak K"
            want_sc = np.zeros(depth, np.float32)
            want_sc[j] = score[lyr, slot, src, h]
            assert np.array_equal(newscore[lyr, slot, :, h], want_sc)
    others = [s for s in range(num_slots) if s != slot]
    assert np.array_equal(newscore[:, others], score[:, others]), \
        "sweep must not touch other slots' score lanes"
    untouched = np.setdiff1d(np.arange(N), keep_ids)
    for name, old in (("k", k), ("v", v), ("pos", pos), ("mask", mask)):
        assert np.array_equal(newpool[name][:, untouched], old[:, untouched]), \
            f"sweep rewrote {name} blocks outside the keep run"


def _masses_case(rng):
    case = _paged_case(rng)
    case["window"] = int(rng.integers(3, 30)) if rng.random() < 0.5 else 0
    return case


@pytest.mark.parametrize("case", sweep_cases(17, 8, _masses_case))
def test_paged_masses_kernel_matches_oracle(case):
    """The two-phase Pallas masses kernel: ``out`` bitwise-identical to
    the plain decode kernel (phase 0 is the unmodified flash recurrence),
    masses match the dense-gather oracle, masked rows carry exact zeros —
    over ragged tables, per-head masks, GQA shapes, and sliding windows."""
    rng = np.random.default_rng(case["seed"])
    B, KV, hd, bs = case["B"], case["KV"], case["hd"], case["bs"]
    N, nb, H = case["N"], case["nb"], case["KV"] * case["G"]
    q = jnp.asarray(rng.normal(size=(B, H, hd)), jnp.float32)
    pk = jnp.asarray(rng.normal(size=(N, bs, KV, hd)), jnp.float32)
    pv = jnp.asarray(rng.normal(size=(N, bs, KV, hd)), jnp.float32)
    pm = jnp.asarray(rng.random((N, bs, KV)) > 0.3).at[0].set(False)
    tbl = np.zeros((B, nb), np.int32)
    for b in range(B):
        n_live = int(rng.integers(0, min(nb, N - 1) + 1))
        tbl[b, :n_live] = rng.choice(np.arange(1, N), n_live, replace=False)
        rng.shuffle(tbl[b])
    tbl = jnp.asarray(tbl)
    kw = {}
    if case["window"]:
        kw = {"pos_pool": jnp.asarray(rng.integers(0, 50, (N, bs, KV)),
                                      jnp.int32),
              "new_pos": jnp.asarray(rng.integers(20, 70, (B,)), jnp.int32),
              "window": case["window"]}
    plain = paged_decode_attention_pallas(q, pk, pv, pm, tbl,
                                          interpret=True, **kw)
    got_out, got_m = paged_decode_masses_pallas(q, pk, pv, pm, tbl,
                                                interpret=True, **kw)
    assert np.array_equal(np.asarray(got_out), np.asarray(plain)), \
        "score_masses must not perturb the attention output"
    want_m = ref.paged_decode_masses(q, pk, pm, tbl, **kw)
    np.testing.assert_allclose(got_m, want_m, atol=2e-5, rtol=2e-5)
    # masked rows contribute exact zeros, alive heads sum to ~1
    dead = ~np.repeat(np.moveaxis(np.asarray(
        ref.gather_paged(pm, tbl)), 2, 1), H // KV, axis=1)
    if case["window"]:
        pos = np.asarray(ref.gather_paged(kw["pos_pool"], tbl))
        oow = (np.asarray(kw["new_pos"])[:, None, None] - pos) >= \
            case["window"]
        dead |= np.repeat(np.moveaxis(oow, 2, 1), H // KV, axis=1)
    got_m = np.asarray(got_m)
    assert np.all(got_m[dead] == 0.0)
    sums = got_m.sum(axis=-1)
    alive = ~dead.all(axis=-1)
    np.testing.assert_allclose(sums[alive], 1.0, atol=1e-4)
    assert np.all(sums[~alive] == 0.0)


def test_paged_masses_streaming_and_dispatch():
    """The jnp streaming tier's second-pass masses and the public
    ``ops.paged_decode_attention(score_masses=True)`` dispatch: same
    oracle, ``out`` bitwise-unchanged, ``depth`` slices the mass width."""
    rng = np.random.default_rng(3)
    B, H, KV, hd, bs, N, nb = 2, 6, 2, 16, 4, 11, 5
    depth = 18  # non-multiple of bs: the engine's capacity+interval shape
    q = jnp.asarray(rng.normal(size=(B, H, hd)), jnp.float32)
    pk = jnp.asarray(rng.normal(size=(N, bs, KV, hd)), jnp.float32)
    pv = jnp.asarray(rng.normal(size=(N, bs, KV, hd)), jnp.float32)
    pm = np.asarray(rng.random((N, bs, KV)) > 0.2)
    pm[0] = False
    # one private block run per sequence, with the engine's depth
    # invariant: rows past ``depth`` are masked False by construction
    # (appends clamp at depth) — the kernel tier's depth slice assumes it
    tbl = 1 + np.arange(B * nb, dtype=np.int32).reshape(B, nb)
    pm[tbl[:, -1], depth - (nb - 1) * bs:] = False
    pm, tbl = jnp.asarray(pm), jnp.asarray(tbl)
    want_m = ref.paged_decode_masses(q, pk, pm, tbl)
    out1 = ops._paged_decode_streaming(q, pk, pv, pm, tbl)
    out2, m2 = ops._paged_decode_streaming(q, pk, pv, pm, tbl,
                                           score_masses=True)
    assert np.array_equal(np.asarray(out2), np.asarray(out1))
    np.testing.assert_allclose(m2, want_m, atol=2e-5, rtol=2e-5)
    # the public wrapper, on whichever tier the environment dispatches
    out3 = ops.paged_decode_attention(q, pk, pv, pm, tbl, depth=depth)
    out4, m4 = ops.paged_decode_attention(q, pk, pv, pm, tbl, depth=depth,
                                          score_masses=True)
    assert np.array_equal(np.asarray(out4), np.asarray(out3)), \
        "score_masses must not change the dispatched output"
    assert m4.shape == (B, H, depth)
    # with ``depth`` the attention (and so the normalizer) runs over the
    # first ``depth`` rows only — compare against the depth-sliced oracle,
    # not a post-hoc slice of the full-width masses
    want_d = ref.paged_decode_masses(q, pk, pm, tbl, depth=depth)
    np.testing.assert_allclose(m4, want_d, atol=2e-5, rtol=2e-5)


def _retire_kept(req):
    """Kept (layer, head, position) sets at retirement, from the decode
    cache snapshot ``_on_retire`` captures (already clipped at the
    emitted-token horizon, so runs with different cache depths compare)."""
    rc = req.retirement_cache
    assert rc is not None, "capture_admission must stash retirement_cache"
    return kept_sets({"mask": rc["mask"][:, None], "pos": rc["pos"][:, None]})


def test_decode_evict_interval_inf_is_bitwise_noop(model):
    """The API contract: decode eviction enabled with an interval no
    generation reaches emits bitwise-identical tokens AND kept sets (at
    retirement, per request) as the eviction-disabled paged path — the
    score plumbing, the grown cache depth, and the sweep gate change
    nothing until a sweep actually fires."""
    cfg, params, lkv = model
    chunk, max_new = 64, 24
    reqs = make_trace_requests(cfg, chunk=chunk, seed=11, n_requests=3,
                               max_new=max_new)
    pool_a = _pool(cfg, block_size=4, num_blocks=256)
    base, _ = run_trace(cfg, params, lkv, policy="lookaheadkv",
                        requests=reqs, chunk=chunk, kv_pool=pool_a)
    pool_b = _pool(cfg, block_size=4, num_blocks=256)
    # chunk dispatch can overshoot a finishing request by up to the max
    # decode chunk (16) rows, so "infinite" must cover max_new + 16
    got, eng = run_trace(cfg, params, lkv, policy="lookaheadkv",
                         requests=reqs, chunk=chunk, kv_pool=pool_b,
                         decode_evict=DecodeEvictionConfig(
                             enabled=True, interval=max_new + 16))
    assert eng.stats["decode_evict_sweeps"] == 0, \
        "interval > generation length must never sweep"
    for uid, want in base.items():
        assert got[uid].out_tokens == want.out_tokens, uid
        assert _retire_kept(got[uid]) == _retire_kept(want), uid
    for p in (pool_a, pool_b):
        p.check()
        assert p.used_blocks() == 0
    assert pool_b.blocks_reclaimed_decode == 0


def test_decode_evict_sweeps_reclaim_mid_generation(model):
    """Active decode eviction: sweeps fire, whole blocks return to the
    pool mid-generation, every request still completes at full length,
    the per-slot footprint is bounded at capacity + interval rows, and
    the pool drains conserved afterwards."""
    cfg, params, lkv = model
    chunk, max_new = 64, 24
    reqs = make_trace_requests(cfg, chunk=chunk, seed=12, n_requests=4,
                               max_new=max_new)
    for r in reqs:
        r.arrival_s = 0.0
    pool = _pool(cfg, block_size=4, num_blocks=256)
    got, eng = run_trace(cfg, params, lkv, policy="lookaheadkv",
                         requests=reqs, chunk=chunk, kv_pool=pool,
                         num_slots=2,
                         decode_evict=DecodeEvictionConfig(enabled=True,
                                                           interval=8))
    assert eng._paged_depth == 8 + 8  # budget + interval bounds the slot
    assert eng.stats["decode_evict_sweeps"] > 0
    assert pool.blocks_reclaimed_decode > 0, \
        "interval spanning whole blocks must free real blocks"
    assert eng.stats["kv_pool"]["blocks_reclaimed_decode"] == \
        pool.blocks_reclaimed_decode
    for r in got.values():
        assert len(r.out_tokens) == max_new  # eos_id=-1: full generations
    pool.check()
    assert pool.used_blocks() == 0 and pool.reserved == 0


def test_decode_evict_contended_matches_isolated(model):
    """Slot isolation under eviction: a request served in a contended
    multi-slot engine emits the same tokens and retires with the same
    kept sets as the same request served alone — sweeps fire at fixed
    per-slot growth marks, so neighbours cannot perturb the cache."""
    cfg, params, lkv = model
    chunk, max_new = 64, 20
    de = DecodeEvictionConfig(enabled=True, interval=8)
    reqs = make_trace_requests(cfg, chunk=chunk, seed=13, n_requests=3,
                               max_new=max_new)
    for r in reqs:
        r.arrival_s = 0.0
    max_ctx = max(len(r.prompt) for r in reqs)
    got, eng = run_trace(cfg, params, lkv, policy="lookaheadkv",
                         requests=reqs, chunk=chunk, num_slots=2,
                         max_context=max_ctx, decode_evict=de,
                         kv_pool=_pool(cfg, block_size=4, num_blocks=256))
    assert eng.stats["decode_evict_sweeps"] > 0
    for r in reqs:
        solo, _ = run_trace(cfg, params, lkv, policy="lookaheadkv",
                            requests=[r], chunk=chunk, num_slots=1,
                            max_context=max_ctx, decode_evict=de,
                            kv_pool=_pool(cfg, block_size=4,
                                          num_blocks=256))
        assert got[r.uid].out_tokens == solo[r.uid].out_tokens, r.uid
        assert _retire_kept(got[r.uid]) == _retire_kept(solo[r.uid]), r.uid
