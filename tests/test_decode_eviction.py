"""Decoding-stage eviction (beyond-paper; the paper's stated future work):
the cache stays within capacity during generation, victims are the lowest
cumulative-attention slots, and while capacity remains the step is exactly
the plain decode step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import EvictionConfig
from repro.configs import get_smoke_config
from repro.models import transformer as tf


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("qwen2-1.5b")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 48), 0,
                                cfg.vocab_size)
    return cfg, params, tokens


def test_capacity_never_exceeded(setup):
    cfg, params, tokens = setup
    res = tf.prefill(params, cfg, tokens, policy="snapkv",
                     evict=EvictionConfig(budget=12), extra_slots=4)
    cache = tf.add_decode_eviction_scores(res.cache)
    cap = cache["attn"]["k"].shape[2]
    tok = jnp.argmax(res.logits, -1)[:, None]
    for i in range(cap + 6):  # go well past capacity
        lg, cache = tf.decode_step(params, cfg, tok, cache)
        tok = jnp.argmax(lg, -1)[:, None]
        assert bool(jnp.isfinite(lg).all())
        m = np.asarray(cache["attn"]["mask"])
        assert m.shape[2] == cap
    assert int(cache["cursor"]) == cap  # saturates
    # positions keep advancing even though the cache doesn't grow
    assert int(cache["next_pos"][0, 0]) == 48 + cap + 6


def test_matches_plain_step_below_capacity(setup):
    cfg, params, tokens = setup
    res = tf.prefill(params, cfg, tokens, policy="snapkv",
                     evict=EvictionConfig(budget=12), extra_slots=8)
    plain = res.cache
    armed = tf.add_decode_eviction_scores(res.cache)
    tok = jnp.argmax(res.logits, -1)[:, None]
    for _ in range(4):  # still below capacity: identical logits
        lg_p, plain = tf.decode_step(params, cfg, tok, plain)
        lg_e, armed = tf.decode_step(params, cfg, tok, armed)
        np.testing.assert_allclose(lg_p, lg_e, atol=1e-4, rtol=1e-4)
        tok = jnp.argmax(lg_p, -1)[:, None]


def test_victims_are_lowest_scores(setup):
    cfg, params, tokens = setup
    res = tf.prefill(params, cfg, tokens, policy="snapkv",
                     evict=EvictionConfig(budget=12), extra_slots=0)
    cache = tf.add_decode_eviction_scores(res.cache)
    tok = jnp.argmax(res.logits, -1)[:, None]
    before = np.asarray(cache["attn"]["score"])
    lg, cache2 = tf.decode_step(params, cfg, tok, cache)
    pos_before = np.asarray(cache["attn"]["pos"])
    pos_after = np.asarray(cache2["attn"]["pos"])
    changed = pos_before != pos_after  # (L, B, C, KV)
    assert changed.any()  # cache was full: someone was evicted
    # exactly one victim per (layer, batch, kv head)
    assert (changed.sum(axis=2) == 1).all()


# ---------------------------------------------------------------------------
# cross-KV eviction (whisper; beyond-paper)
# ---------------------------------------------------------------------------


def test_cross_kv_eviction_whisper():
    """Encoder KV evicted by the decoder's lookahead queries; decode runs
    over the per-head evicted cross cache."""
    from repro.core.lookahead import init_lookahead_params

    cfg = get_smoke_config("whisper-small")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    lkv = init_lookahead_params(jax.random.PRNGKey(1), cfg, params["layers"])
    B, S = 2, 40
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                cfg.vocab_size)
    frames = jax.random.normal(
        jax.random.PRNGKey(3), (B, cfg.encoder.num_frames, cfg.d_model))
    res = tf.prefill(params, cfg, tokens, lkv_params=lkv,
                     policy="lookaheadkv",
                     evict=EvictionConfig(budget=12, cross_budget=8),
                     extra_slots=4, encoder_embeds=frames)
    ck = res.cache["cross"]
    L = cfg.num_layers
    assert ck["k"].shape == (L, B, 8, cfg.attn.num_kv_heads,
                             cfg.attn.head_dim)
    assert bool(jnp.asarray(ck["mask"]).all())
    pos = np.asarray(ck["pos"])
    assert (pos < cfg.encoder.num_frames).all()
    # kept frame sets are unique per head and temporally sorted
    for l in range(L):
        for h in range(cfg.attn.num_kv_heads):
            sel = pos[l, 0, :, h]
            assert len(set(sel.tolist())) == len(sel)
    tok = jnp.argmax(res.logits, -1)[:, None]
    lg, c2 = tf.decode_step(params, cfg, tok, res.cache)
    lg2, _ = tf.decode_step(params, cfg, jnp.argmax(lg, -1)[:, None], c2)
    assert bool(jnp.isfinite(lg2).all())


def test_cross_kv_full_budget_noop():
    """cross_budget >= num_frames keeps every frame (mask all-true, decode
    logits match the unevicted path)."""
    from repro.core.lookahead import init_lookahead_params

    cfg = get_smoke_config("whisper-small")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    lkv = init_lookahead_params(jax.random.PRNGKey(1), cfg, params["layers"])
    B, S = 1, 32
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                cfg.vocab_size)
    frames = jax.random.normal(
        jax.random.PRNGKey(3), (B, cfg.encoder.num_frames, cfg.d_model))
    full = tf.prefill(params, cfg, tokens, lkv_params=lkv,
                      policy="lookaheadkv", evict=EvictionConfig(budget=12),
                      extra_slots=4, encoder_embeds=frames)
    ev = tf.prefill(params, cfg, tokens, lkv_params=lkv,
                    policy="lookaheadkv",
                    evict=EvictionConfig(budget=12,
                                         cross_budget=cfg.encoder.num_frames),
                    extra_slots=4, encoder_embeds=frames)
    tok = jnp.argmax(full.logits, -1)[:, None]
    lg_full, _ = tf.decode_step(params, cfg, tok, full.cache)
    lg_ev, _ = tf.decode_step(params, cfg, tok, ev.cache)
    np.testing.assert_allclose(lg_full, lg_ev, atol=2e-2, rtol=2e-2)
