"""Multi-pod dry-run integration: spawn ``repro.launch.dryrun`` in a
subprocess (it forces 512 host devices via XLA_FLAGS before jax init —
isolation keeps this pytest process on 1 device) and validate the JSON
artifact end to end.  Marked slow: one real 512-way SPMD compile each."""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(arch, shape, mesh, tmp_path, variant=""):
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh, "--out", str(tmp_path)]
    if variant:
        cmd += ["--variant", variant]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       timeout=540)
    assert r.returncode == 0, r.stderr[-2000:]
    v = f"_{variant}" if variant else ""
    with open(os.path.join(tmp_path, f"{arch}_{shape}_{mesh}{v}.json")) as f:
        return json.load(f)


@pytest.mark.slow
def test_dryrun_pod_decode(tmp_path):
    res = _run("smollm-135m", "decode_32k", "pod", tmp_path)
    assert res["status"] == "ok"
    assert res["chips"] == 256
    rl = res["roofline"]
    assert rl["bottleneck"] in ("compute", "memory", "collective")
    assert res["memory"]["peak_memory_in_bytes"] < 16e9  # fits v5e HBM
    assert res["collectives"]["total"] > 0


@pytest.mark.slow
def test_dryrun_multipod_train(tmp_path):
    res = _run("smollm-135m", "train_4k", "multipod", tmp_path)
    assert res["status"] == "ok"
    assert res["chips"] == 512
    assert res["cost_jaxpr_global"]["flops"] > 1e14


@pytest.mark.slow
def test_dryrun_skip_matrix(tmp_path):
    res = _run("smollm-135m", "long_500k", "pod", tmp_path)
    assert res["status"] == "skipped"
    assert "full-attention" in res["reason"]
