"""The unified ``ServingConfig`` API and its deprecation shim.

Three layers:

1. **Schema**: defaults, ``DecodeEvictionConfig.coerce`` (bool / None /
   instance), the shared ``margin_rows`` rule, and validation.
2. **Round-trip**: ``from_legacy(**sc.legacy_kwargs()) == sc`` for a
   fully non-default config; unknown kwargs raise ``TypeError`` exactly
   like the old ``__init__`` signature would.
3. **Shim equivalence**: ``ContinuousEngine(params, cfg, **old_kwargs)``
   warns ``DeprecationWarning`` and serves bit-identically to the same
   engine built from the equivalent ``ServingConfig`` (which must stay
   silent); mixing both spellings fails loudly.
"""

import warnings

import jax
import numpy as np
import pytest

from repro.common.config import EvictionConfig
from repro.configs import get_smoke_config
from repro.core.lookahead import init_lookahead_params
from repro.models import transformer as tf
from repro.serving import (ChunkingConfig, ContinuousEngine,
                           DecodeEvictionConfig, Request, ServingConfig)


# ---------------------------------------------------------------------------
# 1. schema
# ---------------------------------------------------------------------------


def test_defaults_and_decode_evict_coercion():
    sc = ServingConfig()
    assert sc.decode_evict == DecodeEvictionConfig()
    assert not sc.decode_evict.enabled
    assert sc.chunking == ChunkingConfig()
    assert sc.evict is not None  # None coerces to the default budget

    assert DecodeEvictionConfig.coerce(True).enabled
    assert not DecodeEvictionConfig.coerce(False).enabled
    assert DecodeEvictionConfig.coerce(None) == DecodeEvictionConfig()
    d = DecodeEvictionConfig(enabled=True, interval=32)
    assert DecodeEvictionConfig.coerce(d) is d
    with pytest.raises(AssertionError):
        DecodeEvictionConfig.coerce(3)
    # the legacy bool spelling rides ServingConfig too
    assert ServingConfig(decode_evict=True).decode_evict.enabled
    assert ServingConfig(evict=None).evict == EvictionConfig()


def test_margin_rows_rule():
    """The thrice-copied ``8 if decode_evict else max_new + 1`` rule all
    three engines used to inline, now in one place."""
    assert DecodeEvictionConfig().margin_rows(64) == 65
    assert DecodeEvictionConfig(enabled=True).margin_rows(64) == 8
    assert DecodeEvictionConfig(enabled=True, margin=4).margin_rows(64) == 4


def test_validation():
    with pytest.raises(AssertionError):
        DecodeEvictionConfig(interval=0)
    with pytest.raises(AssertionError):
        DecodeEvictionConfig(margin=0)
    with pytest.raises(AssertionError):
        ChunkingConfig(chunk=0)


# ---------------------------------------------------------------------------
# 2. legacy round-trip
# ---------------------------------------------------------------------------


def test_from_legacy_round_trip():
    sc = ServingConfig(
        policy="h2o", evict=EvictionConfig(budget=32),
        decode_evict=DecodeEvictionConfig(enabled=True, interval=16),
        chunking=ChunkingConfig(chunk=64, max_context=512, token_budget=96,
                                decode_chunk=4),
        num_slots=3, max_new_tokens=12, eos_id=7, reserve_appends=False,
        capture_admission=True)
    kw = sc.legacy_kwargs()
    assert kw["chunk"] == 64 and kw["decode_chunk"] == 4
    assert kw["decode_evict"].interval == 16
    assert ServingConfig.from_legacy(**kw) == sc
    assert sc.replace(num_slots=5).num_slots == 5
    assert sc.num_slots == 3  # replace is non-destructive


def test_from_legacy_rejects_unknown_kwargs():
    with pytest.raises(TypeError, match="bogus_kwarg"):
        ServingConfig.from_legacy(bogus_kwarg=1)


# ---------------------------------------------------------------------------
# 3. deprecation-shim equivalence on a live engine
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    lkv = init_lookahead_params(jax.random.PRNGKey(1), cfg, params["layers"])
    return cfg, params, lkv


def _requests(cfg, n=2, n_in=80, max_new=4):
    rng = np.random.default_rng(0)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        n_in).astype(np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


def test_legacy_kwargs_shim_serves_identically(model):
    cfg, params, lkv = model
    reqs = _requests(cfg)
    kw = dict(policy="lookaheadkv", evict=EvictionConfig(budget=8),
              num_slots=2, chunk=64, max_context=128, max_new_tokens=4,
              eos_id=-1)
    with pytest.warns(DeprecationWarning, match="ServingConfig"):
        old = ContinuousEngine(params, cfg, lkv_params=lkv, **kw)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        new = ContinuousEngine(params, cfg, ServingConfig.from_legacy(**kw),
                               lkv_params=lkv)
    assert not any(issubclass(w.category, DeprecationWarning)
                   for w in caught), "the supported spelling must not warn"
    assert old.config == new.config
    done_old = old.run([r.clone() for r in reqs])
    done_new = new.run([r.clone() for r in reqs])
    want = {r.uid: r.out_tokens for r in done_old}
    for r in done_new:
        assert r.out_tokens == want[r.uid]


def test_mixing_config_and_kwargs_fails_loudly(model):
    cfg, params, lkv = model
    with pytest.raises(AssertionError):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            ContinuousEngine(params, cfg, ServingConfig(), lkv_params=lkv,
                             num_slots=2)
