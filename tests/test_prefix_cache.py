"""Prefix-aware KV reuse: radix-trie invariants, ScoreState snapshot
round-trips, and the differential trace suite.

Three layers of proof, least to most end-to-end:

1. **Trie invariants** (no model): refcounts never go negative, LRU never
   evicts a pinned (in-flight) or parented entry, the byte budget is
   respected after *every* insert/evict under adversarial interleavings,
   and partial-chunk prefixes never match.
2. **Snapshot properties** (model, no engine): ``ScoreState.snapshot /
   restore`` (via ``transformer.snapshot_chunk_state / resume_chunk_
   state``) round-trips bit-exact for every servable policy — including
   the deferred-window query buffer — on both the jnp and forced-Pallas
   dispatch paths, and a restored prefill finishes with the same kept sets
   and logits as the uninterrupted one.
3. **Differential traces** (the headline): serving a seeded randomized
   Zipf-prefix trace through ``ContinuousEngine`` with the prefix cache on
   emits bit-identical tokens and kept (layer, head, position) sets as
   with it off — every servable single-pass policy, chunk sizes 128 and
   256, prompts not divisible by the chunk.  Plus compile-count pinning:
   a cache hit must not add a compile key or a compiled shape.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import EvictionConfig
from repro.configs import get_smoke_config
from repro.core import policies, scoring
from repro.core.lookahead import init_lookahead_params
from repro.models import transformer as tf
from repro.serving import ChunkCompileCache, PrefixCache
from trace_utils import kept_sets, make_trace_requests, run_trace
from trace_utils import assert_differential

# every policy the chunked continuous engine serves
ENGINE_POLICIES = [p for p in policies.SINGLE_PASS
                   if p not in ("gt_oracle", "full")]


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    lkv = init_lookahead_params(jax.random.PRNGKey(1), cfg, params["layers"])
    return cfg, params, lkv


# ---------------------------------------------------------------------------
# 1. radix-trie invariants (no model)
# ---------------------------------------------------------------------------

CHUNK = 4


def _state(depth: int, fill: float, capacity: int = 16) -> tf.ChunkState:
    """Tiny fake streaming state: column j of k/v carries ``fill + j`` so
    materialized chains are checkable value-by-value."""
    col = jnp.arange(capacity, dtype=jnp.float32) + fill
    k = jnp.broadcast_to(col[None, None, :, None, None], (1, 1, capacity, 1, 2))
    return tf.ChunkState(k=k, v=k + 0.5, score=scoring.ScoreState(),
                         pos=jnp.asarray(depth, jnp.int32))


def _logits(tag: float) -> jnp.ndarray:
    return jnp.full((1, 4), tag, jnp.float32)


def _tokens(seed: int, n: int) -> np.ndarray:
    return np.random.default_rng(seed).integers(0, 50, n).astype(np.int32)


def _check_invariants(cache: PrefixCache):
    entries = list(cache._lru)
    assert cache.bytes == sum(e.nbytes for e in entries)
    assert cache.bytes <= cache.max_bytes
    children = {id(e): 0 for e in entries}
    for e in entries:
        assert e.refs >= 0
        if e.parent is not None:
            assert e.parent in cache._lru, "child outlived its parent"
            children[id(e.parent)] += 1
    return {id(e): n for e, n in zip(entries, children.values())}


def test_trie_lookup_and_partial_chunk_prefixes_never_match():
    cache = PrefixCache(chunk=CHUNK, max_bytes=1 << 20)
    t = _tokens(0, 8)
    e4 = cache.insert(t[:4], state=_state(4, 0.0), logits=_logits(1))
    e8 = cache.insert(t[:8], state=_state(8, 0.0), logits=_logits(2),
                      parent=e4)
    assert (e4.depth, e8.depth) == (4, 8) and e8.parent is e4
    # exact and deeper lookups
    assert cache.lookup(t[:8]) is e8
    assert cache.lookup(np.concatenate([t, _tokens(1, 5)])) is e8
    assert cache.lookup(t[:4]) is e4
    # sharing 6 of 8 tokens matches only the aligned 4-deep entry — a
    # partial-chunk prefix (6) never matches even though the trie edge does
    probe = np.concatenate([t[:6], _tokens(2, 10)])
    assert cache.lookup(probe) is e4
    # sharing fewer tokens than one chunk matches nothing
    assert cache.lookup(np.concatenate([t[:3], _tokens(3, 9)])) is None
    assert cache.lookup(t[:3]) is None  # prompt shorter than a chunk
    with pytest.raises(AssertionError):
        cache.insert(t[:6], state=_state(6, 0.0), logits=_logits(9))
    _check_invariants(cache)


def test_lru_never_evicts_pinned_or_parented_entries():
    one = PrefixCache(chunk=CHUNK, max_bytes=1 << 20)
    per = one.insert(_tokens(0, 4), state=_state(4, 0.0),
                     logits=_logits(0)).nbytes
    cache = PrefixCache(chunk=CHUNK, max_bytes=2 * per)
    t = _tokens(1, 8)
    a = cache.insert(t[:4], state=_state(4, 1.0), logits=_logits(1))
    b = cache.insert(t[:8], state=_state(8, 1.0), logits=_logits(2), parent=a)
    cache.acquire(b)  # in-flight pin
    # budget is full; a is parented, b is pinned -> nothing evictable, and
    # the doomed insert must refuse *without* churning existing entries
    assert cache.insert(_tokens(2, 4), state=_state(4, 2.0),
                        logits=_logits(3)) is None
    assert cache.evictions == 0
    assert cache.lookup(t[:8]) is b and cache.bytes <= cache.max_bytes
    cache.release(b)
    # now b (LRU-evictable leaf) goes first, then a — never the reverse
    c = cache.insert(_tokens(2, 4), state=_state(4, 2.0), logits=_logits(3))
    assert c is not None
    assert cache.lookup(t[:8]) is not b
    _check_invariants(cache)
    with pytest.raises(AssertionError):
        cache.release(c)  # refcount underflow is loud, never negative


def test_lru_recency_orders_eviction():
    one = PrefixCache(chunk=CHUNK, max_bytes=1 << 20)
    per = one.insert(_tokens(0, 4), state=_state(4, 0.0),
                     logits=_logits(0)).nbytes
    cache = PrefixCache(chunk=CHUNK, max_bytes=2 * per)
    ta, tb, tc = _tokens(1, 4), _tokens(2, 4), _tokens(3, 4)
    cache.insert(ta, state=_state(4, 1.0), logits=_logits(1))
    cache.insert(tb, state=_state(4, 2.0), logits=_logits(2))
    assert cache.lookup(ta) is not None  # touch a: b becomes LRU
    cache.insert(tc, state=_state(4, 3.0), logits=_logits(3))
    assert cache.lookup(tb) is None  # b evicted
    assert cache.lookup(ta) is not None and cache.lookup(tc) is not None
    _check_invariants(cache)


def test_materialize_rebuilds_the_chain():
    cache = PrefixCache(chunk=CHUNK, max_bytes=1 << 20)
    t = _tokens(4, 8)
    donor = _state(8, 7.0)
    a = cache.insert(t[:4], state=donor, logits=_logits(1))
    b = cache.insert(t[:8], state=donor, logits=_logits(2), parent=a)
    state, logits = cache.materialize(b, capacity=12)
    assert state.k.shape[2] == 12 and int(state.pos) == 8
    np.testing.assert_array_equal(np.asarray(state.k[:, :, :8]),
                                  np.asarray(donor.k[:, :, :8]))
    assert not np.asarray(state.k[:, :, 8:]).any()  # zero tail
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(_logits(2)))


def test_byte_budget_respected_under_adversarial_interleavings():
    """Simulated engine protocol (lookup+pin, stream+insert, release) over
    interleaved requests with a tight budget: after every operation the
    byte budget holds, refcounts stay non-negative, and pinned tips are
    never evicted mid-flight."""
    rng = np.random.default_rng(7)
    probe = PrefixCache(chunk=CHUNK, max_bytes=1 << 20)
    per = probe.insert(_tokens(0, 4), state=_state(4, 0.0),
                       logits=_logits(0)).nbytes
    cache = PrefixCache(chunk=CHUNK, max_bytes=5 * per)
    bases = [_tokens(s, 16) for s in range(3)]  # shared-prefix pool

    class Sim:
        def __init__(self, uid):
            base = bases[int(rng.integers(3))]
            depth = int(rng.integers(1, 5)) * CHUNK
            self.prompt = base[:depth].copy()
            if rng.random() < 0.5:  # diverge mid-pool: trie splits
                self.prompt[-1] = 99 + uid
            self.state = _state(0, float(uid), capacity=16)
            self.s, self.tip = 0, None
            hit = cache.lookup(self.prompt)
            if hit is not None:
                cache.acquire(hit)
                self.tip, self.s = hit, hit.depth

        def step(self):
            self.s += CHUNK
            self.state = self.state._replace(
                pos=jnp.asarray(self.s, jnp.int32))
            e = cache.insert(self.prompt[:self.s], state=self.state,
                             logits=_logits(self.s), parent=self.tip)
            if e is not None:
                cache.acquire(e)
                if self.tip is not None:
                    cache.release(self.tip)
                self.tip = e

        def finish(self):
            if self.tip is not None:
                cache.release(self.tip)
                self.tip = None

    live, uid = [], 0
    for _ in range(200):
        op = rng.random()
        if (op < 0.25 and len(live) < 6) or not live:
            live.append(Sim(uid))
            uid += 1
        elif op < 0.85:
            sim = live[int(rng.integers(len(live)))]
            if sim.s < len(sim.prompt):
                sim.step()
        else:
            sim = live.pop(int(rng.integers(len(live))))
            sim.finish()
        _check_invariants(cache)
        for sim in live:  # a pinned in-flight tip is never evicted
            if sim.tip is not None:
                assert sim.tip in cache._lru
    for sim in live:
        sim.finish()
    # all pins released: every refcount is exactly its child-entry count
    for e in list(cache._lru):
        assert e.refs == sum(1 for x in cache._lru if x.parent is e)
    _check_invariants(cache)
    assert cache.evictions > 0, "budget pressure never exercised eviction"


# ---------------------------------------------------------------------------
# 2. ScoreState / ChunkState snapshot properties
# ---------------------------------------------------------------------------

SNAP_CHUNK, SNAP_N, SNAP_BOUNDARY = 16, 40, 32


def _stream(cfg, params, state, toks, n, policy, start=0):
    n_arr = jnp.asarray(n, jnp.int32)
    logits = None
    for s in range(start, n, SNAP_CHUNK):
        blk = np.zeros((1, SNAP_CHUNK), np.int32)
        seg = toks[0, s:s + SNAP_CHUNK]
        blk[0, :len(seg)] = seg
        state, logits = tf.prefill_chunk(params, cfg, state,
                                         jnp.asarray(blk), n_arr,
                                         policy=policy)
    return state, logits


@pytest.mark.parametrize("backend", ["jnp", "forced-pallas"])
@pytest.mark.parametrize("policy", ENGINE_POLICIES)
def test_snapshot_restore_bit_exact(model, policy, backend, monkeypatch):
    """snapshot -> restore at a chunk boundary reproduces every state leaf
    bitwise (including the deferred-window query buffer), and finishing
    the prefill from the restored state yields the same kept sets and
    bitwise logits, on both dispatch paths."""
    if backend == "forced-pallas":
        monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    else:
        monkeypatch.delenv("REPRO_FORCE_PALLAS", raising=False)
    cfg, params, lkv = model
    rng = np.random.default_rng(11)
    toks = rng.integers(0, cfg.vocab_size, (1, SNAP_N)).astype(np.int32)
    cap = policies.chunk_capacity_for(cfg, policy, SNAP_N, SNAP_CHUNK)
    state0 = tf.init_chunk_state(cfg, policy, 1, cap)
    mid, _ = _stream(cfg, params, state0, toks, SNAP_BOUNDARY, policy)
    restored = tf.resume_chunk_state(
        tf.snapshot_chunk_state(mid, SNAP_BOUNDARY), cap)
    for a, b in zip(jax.tree.leaves(mid), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # continuation equivalence: stream the tail from both states
    kw = dict(policy=policy,
              lkv_params=lkv if policy == "lookaheadkv" else None,
              seeds=jnp.asarray([3], jnp.int32))
    ends = []
    for st in (mid, restored):
        end, logits = _stream(cfg, params, st, toks, SNAP_N, policy,
                              start=SNAP_BOUNDARY)
        cache = tf.prefill_finalize(
            params, cfg, end, jnp.asarray(SNAP_N, jnp.int32),
            evict=EvictionConfig(budget=8), **kw)
        ends.append((cache, logits))
    (c_mid, l_mid), (c_res, l_res) = ends
    np.testing.assert_array_equal(np.asarray(l_mid), np.asarray(l_res))
    for a, b in zip(jax.tree.leaves(c_mid), jax.tree.leaves(c_res)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_into_deeper_buffer_keeps_same_evictions(model):
    """Cross-rung resume (snapshot from one buffer depth restored into a
    deeper one) preserves the kept sets — masked softmax columns beyond
    the frontier contribute exact zeros."""
    cfg, params, _ = model
    policy = "h2o"
    rng = np.random.default_rng(12)
    toks = rng.integers(0, cfg.vocab_size, (1, SNAP_N)).astype(np.int32)
    cap = policies.chunk_capacity_for(cfg, policy, SNAP_N, SNAP_CHUNK)
    base = tf.init_chunk_state(cfg, policy, 1, cap)
    mid, _ = _stream(cfg, params, base, toks, SNAP_BOUNDARY, policy)
    snap = tf.snapshot_chunk_state(mid, SNAP_BOUNDARY)
    caches = []
    for depth in (cap, 2 * cap):
        st = tf.resume_chunk_state(snap, depth)
        end, _ = _stream(cfg, params, st, toks, SNAP_N, policy,
                         start=SNAP_BOUNDARY)
        caches.append(tf.prefill_finalize(
            params, cfg, end, jnp.asarray(SNAP_N, jnp.int32), policy=policy,
            evict=EvictionConfig(budget=8)))
    kept = [kept_sets({"mask": np.asarray(c["attn"]["mask"]),
                       "pos": np.asarray(c["attn"]["pos"])})
            for c in caches]
    assert kept[0] == kept[1]


# ---------------------------------------------------------------------------
# 3. differential trace suite (the acceptance property)
# ---------------------------------------------------------------------------


def _trace(cfg, chunk, seed=3):
    # prompts stay within one KV-buffer rung; suffix 77 exercises prompts
    # not divisible by either chunk size, suffix 0 yields exact duplicates
    return make_trace_requests(
        cfg, chunk=chunk, seed=seed, n_requests=5, max_new=3,
        n_prefixes=3, prefix_chunks=(1, 2) if chunk <= 128 else (1,),
        suffix_lens=(0, 1, 77))


@pytest.mark.parametrize("chunk", [128, 256])
@pytest.mark.parametrize("policy", ENGINE_POLICIES)
def test_differential_trace(model, policy, chunk):
    """Tokens and kept sets are bit-equal with the prefix cache on vs. off
    for every servable single-pass policy and both chunk sizes."""
    cfg, params, lkv = model
    reqs = _trace(cfg, chunk)
    eng, cache = assert_differential(cfg, params, lkv, policy=policy,
                                     requests=reqs, chunk=chunk,
                                     decode_chunk=2)
    # the property must not hold vacuously
    assert eng.stats["prefix_hits"] > 0
    assert eng.stats["prefix_tokens_skipped"] > 0
    assert cache.stats()["bytes"] > 0


def test_differential_trace_with_tight_budget(model):
    """Eviction pressure mid-trace (budget ~ two entries) must not perturb
    served tokens either — a miss-after-evict just streams normally."""
    cfg, params, lkv = model
    reqs = _trace(cfg, 128, seed=9)
    probe = PrefixCache(chunk=128, max_bytes=1 << 30)
    _, eng_probe = run_trace(cfg, params, lkv, policy="h2o", requests=reqs,
                             chunk=128, prefix_cache=probe, decode_chunk=2)
    per = probe.bytes // max(probe.stats()["entries"], 1)
    eng, cache = assert_differential(cfg, params, lkv, policy="h2o",
                                     requests=reqs, chunk=128,
                                     cache_bytes=2 * per, decode_chunk=2)
    assert cache.stats()["bytes"] <= 2 * per


def test_differential_trace_mixed_rungs(model):
    """Requests on different KV-buffer rungs (a long prompt escalates past
    ``max_context``): snapshots only serve same-rung hits — chains are
    capacity-homogeneous and cross-rung lookups miss — so tokens and kept
    sets stay bit-equal even under mixed buffer shapes."""
    cfg, params, _ = model
    from repro.serving import Request
    rng = np.random.default_rng(6)
    shared = rng.integers(0, cfg.vocab_size, 64).astype(np.int32)
    tail = rng.integers(0, cfg.vocab_size, 96).astype(np.int32)
    long_p = np.concatenate([shared, tail])  # rung above max_context=64
    reqs = [
        Request(uid=0, prompt=long_p, max_new_tokens=2, arrival_s=0.00),
        Request(uid=1, prompt=shared.copy(), max_new_tokens=2,
                arrival_s=0.02),  # base rung; shares tokens, not the rung
        Request(uid=2, prompt=long_p.copy(), max_new_tokens=2,
                arrival_s=0.04),  # same-rung duplicate: the legal full hit
        Request(uid=3, prompt=shared.copy(), max_new_tokens=2,
                arrival_s=0.06),  # base-rung duplicate of uid 1
    ]
    base, _ = run_trace(cfg, params, None, policy="h2o", requests=reqs,
                        chunk=32, max_context=64, decode_chunk=2)
    cache = PrefixCache(chunk=32, max_bytes=1 << 30)
    got, eng = run_trace(cfg, params, None, policy="h2o", requests=reqs,
                         chunk=32, max_context=64, decode_chunk=2,
                         prefix_cache=cache)
    for uid, ref in base.items():
        assert got[uid].out_tokens == ref.out_tokens, uid
        assert kept_sets(got[uid].admission_cache) == kept_sets(
            ref.admission_cache), uid
    # uid 2 hit its same-rung snapshot in full; the base-rung requests
    # never consumed the long prompt's cross-rung entries
    assert got[2].cached_prefix_tokens == len(long_p)
    assert got[1].cached_prefix_tokens == 0
    assert got[3].cached_prefix_tokens == 0
    assert eng.stats["prefix_hits"] == 1


def test_random_policy_seed_stays_out_of_cached_state(model):
    """Two requests with identical prompts but different uids share the
    cached prefix, yet still draw decorrelated random evictions — the
    per-request fold_in happens at finalize, not in the snapshot."""
    cfg, params, _ = model
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, 128).astype(np.int32)
    from repro.serving import Request
    reqs = [Request(uid=i, prompt=prompt, max_new_tokens=2,
                    arrival_s=0.01 * i) for i in range(2)]
    cache = PrefixCache(chunk=128, max_bytes=1 << 30)
    got, eng = run_trace(cfg, params, None, policy="random", requests=reqs,
                         chunk=128, prefix_cache=cache, decode_chunk=2)
    assert eng.stats["prefix_hits"] == 1  # second request fully cached
    assert got[1].cached_prefix_tokens == 128
    assert kept_sets(got[0].admission_cache) != kept_sets(
        got[1].admission_cache)


# ---------------------------------------------------------------------------
# compile-count pinning + stats (a hit must not compile anything new)
# ---------------------------------------------------------------------------


def test_chunk_compile_cache_stats_direct():
    built = []

    def build(kind, policy):
        built.append((kind, policy))
        return lambda x: x

    cc = ChunkCompileCache(build)
    f = cc.get("chunk", 16, 1, "h2o")
    cc.get("chunk", 16, 1, "h2o")
    cc.get("finalize", 16, 1, "h2o")
    s = cc.stats()
    assert s["entries"] == 2 and s["hits"] == 1 and s["misses"] == 2
    assert s["keys"] == [("chunk", 16, 1, "h2o"), ("finalize", 16, 1, "h2o")]
    assert s["compiles"] == 0  # nothing invoked yet
    f(jnp.zeros(2))
    assert cc.stats()["compiles"] == 1
    assert built == [("chunk", "h2o"), ("finalize", "h2o")]


def test_prefix_hits_pin_compile_counts_and_report_stats(model):
    """Replaying a warmed trace serves every admission from the trie: the
    compile cache gains no key and no compiled shape signature, and the
    engine/scheduler stats report hit-rate, skipped tokens, and bytes."""
    cfg, params, lkv = model
    # seed 5's trace contains chunk-aligned prompts — full-hit candidates
    # on the replay (a warmed trie covers their entire length)
    reqs = _trace(cfg, 128, seed=5)
    cache = PrefixCache(chunk=128, max_bytes=1 << 30)
    max_new = max(r.max_new_tokens for r in reqs)
    max_len = max(len(r.prompt) for r in reqs)
    from repro.serving import ContinuousEngine
    eng = ContinuousEngine(
        params, cfg, policy="h2o", evict=EvictionConfig(budget=8),
        num_slots=2, chunk=128, max_context=max_len,
        max_new_tokens=max_new, eos_id=-1, prefix_cache=cache,
        decode_chunk=2)

    def clone(rs):
        return [r.clone() for r in rs]

    eng.run(clone(reqs))
    warm = eng.chunk_cache.stats()
    assert warm["entries"] == 2  # one chunk + one finalize program
    done = eng.run(clone(reqs))
    after = eng.chunk_cache.stats()
    assert after["keys"] == warm["keys"]
    assert after["entries"] == 2
    assert after["compiles"] == warm["compiles"], \
        "a prefix-cache hit triggered a fresh compile"
    # second replay: every request hits, duplicates hit fully
    assert eng.stats["prefix_hits"] == len(reqs)
    assert eng.stats["prefix_misses"] == 0
    assert eng.stats["prefix_tokens_skipped"] >= sum(
        (len(r.prompt) // 128) * 128 for r in reqs)
    sps = eng.stats["prefix"]
    assert sps["prefix_hits"] == len(reqs) and sps["hit_rate"] == 1.0
    assert 0 < eng.stats["prefix_cache"]["bytes"] <= cache.max_bytes
    assert any(r.cached_prefix_tokens == len(r.prompt) for r in done), \
        "no fully-cached admission in the replay"
