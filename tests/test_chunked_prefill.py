"""Chunked-prefill parity: streaming score accumulation must reproduce
monolithic prefill's eviction *exactly*.

The acceptance property of the chunked serving path: for every single-pass
policy, prefilling a prompt chunk by chunk (``policies.run_eviction_
chunked``) yields

* the same kept (layer, head, position) sets as monolithic
  ``policies.run_eviction`` — bit-exact, because the final ``evict_layer``
  consumes scores that match the monolithic pipeline (cumulative sums for
  h2o, deferred observation-window scoring for the snapkv family and
  lookaheadkv/gt_oracle, position scores otherwise);
* next-token logits within 1e-4 (bitwise on the CPU reference path, since
  causally-masked extra buffer columns contribute exact zeros to every
  softmax).

Plus the streaming-state property: cumulative (h2o) accumulation is
chunk-split- and chunk-order-invariant.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import EvictionConfig
from repro.configs import get_smoke_config
from repro.core import policies
from repro.core.lookahead import init_lookahead_params
from repro.kernels import ops
from repro.models import transformer as tf
from repro.serving import ContinuousEngine, Request, ServingEngine

# silence only the *expected* engine deprecations (the lockstep baseline is
# exercised on purpose) so any real DeprecationWarning still surfaces in CI
pytestmark = [
    pytest.mark.filterwarnings(
        r"ignore:ServingEngine \(lockstep\) is deprecated"
        ":DeprecationWarning"),
    pytest.mark.filterwarnings(
        r"ignore:BucketedEngine \(pad-to-bucket prefill\) is deprecated"
        ":DeprecationWarning"),
]

BUDGET = 16
N_PROMPT = 300  # not divisible by either tested chunk size

# On the jnp reference path the chunked computation is exact (extra buffer
# columns contribute exact zeros), so logits agree to 1e-4 and usually
# bitwise.  Under REPRO_FORCE_PALLAS the monolithic and chunked paths run
# *different* kernels (flash_attention vs chunk_attention), so bf16 hidden
# states only agree to bf16 rounding — kept sets must still match exactly.
LOGITS_ATOL = 2e-2 if ops.use_pallas() else 1e-4


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    lkv = init_lookahead_params(jax.random.PRNGKey(1), cfg, params["layers"])
    rng = np.random.default_rng(0)
    toks = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (1, N_PROMPT)).astype(np.int32))
    return cfg, params, lkv, toks


def kept_sets(cache):
    """The evicted cache as {(layer, batch, head): frozenset(positions)}."""
    m = np.asarray(cache["attn"]["mask"])
    p = np.asarray(cache["attn"]["pos"])
    L, B, _, KV = m.shape
    return {
        (lyr, b, h): frozenset(p[lyr, b, m[lyr, b, :, h], h].tolist())
        for lyr in range(L) for b in range(B) for h in range(KV)
    }


def assert_parity(mono, chunked):
    assert kept_sets(mono.cache) == kept_sets(chunked.cache)
    np.testing.assert_allclose(np.asarray(mono.logits),
                               np.asarray(chunked.logits), atol=LOGITS_ATOL,
                               rtol=0)
    # the decode hand-off state matches too
    np.testing.assert_array_equal(np.asarray(mono.cache["next_pos"]),
                                  np.asarray(chunked.cache["next_pos"]))


@pytest.mark.parametrize("chunk", [128, 256])
@pytest.mark.parametrize("policy", [p for p in policies.SINGLE_PASS
                                    if p != "gt_oracle"])
def test_chunked_matches_monolithic(model, policy, chunk):
    """Every single-pass policy, chunk sizes 128 and 256, prompt length not
    divisible by either (the partial final chunk is the hard case)."""
    cfg, params, lkv, toks = model
    ev = EvictionConfig(budget=BUDGET)
    seeds = jnp.asarray([5], jnp.int32)
    mono = policies.run_eviction(
        policy, params, cfg, toks, evict=ev,
        lkv_params=lkv if policy == "lookaheadkv" else None,
        extra_slots=2, seeds=seeds)
    chunked = policies.run_eviction_chunked(
        policy, params, cfg, toks, chunk=chunk, evict=ev,
        lkv_params=lkv if policy == "lookaheadkv" else None,
        extra_slots=2, seeds=seeds)
    assert_parity(mono, chunked)


def test_chunked_random_unseeded_parity(model):
    """Without per-request seeds the random policy must still be
    length-invariant: chunked prefill scores over its buffer depth,
    monolithic over the exact prompt length, and the kept sets must agree
    (the draw is folded per position, not drawn as one length-shaped
    vector)."""
    cfg, params, _, toks = model
    ev = EvictionConfig(budget=BUDGET)
    mono = policies.run_eviction("random", params, cfg, toks, evict=ev,
                                 extra_slots=2)
    chunked = policies.run_eviction_chunked("random", params, cfg, toks,
                                            chunk=128, evict=ev,
                                            extra_slots=2)
    assert_parity(mono, chunked)


def test_chunked_matches_monolithic_divisible(model):
    """Prompt length an exact chunk multiple (no partial final chunk)."""
    cfg, params, lkv, toks = model
    toks = toks[:, :256]
    ev = EvictionConfig(budget=BUDGET)
    for policy in ("lookaheadkv", "h2o"):
        mono = policies.run_eviction(
            policy, params, cfg, toks, evict=ev,
            lkv_params=lkv if policy == "lookaheadkv" else None,
            extra_slots=2)
        chunked = policies.run_eviction_chunked(
            policy, params, cfg, toks, chunk=128, evict=ev,
            lkv_params=lkv if policy == "lookaheadkv" else None,
            extra_slots=2)
        assert_parity(mono, chunked)


def test_chunked_gt_oracle_matches_monolithic(model):
    """gt_oracle streams X in chunks and scores with the real Y suffix as
    the final observation pass."""
    cfg, params, _, toks = model
    boundary = 280  # Y = 20 rows
    ev = EvictionConfig(budget=BUDGET)
    mono = tf.prefill(params, cfg, toks, policy="gt_oracle",
                      gt_boundary=boundary, evict=ev, extra_slots=2)
    chunked = policies.run_eviction_chunked(
        "gt_oracle", params, cfg, toks, chunk=128, evict=ev,
        gt_boundary=boundary, extra_slots=2)
    assert kept_sets(mono.cache) == kept_sets(chunked.cache)
    np.testing.assert_allclose(np.asarray(mono.logits),
                               np.asarray(chunked.logits), atol=LOGITS_ATOL,
                               rtol=0)


def test_chunked_adaptive_head_alloc_parity(model):
    """Ada-KV adaptive budgets consume the same streamed scores."""
    cfg, params, _, toks = model
    ev = EvictionConfig(budget=BUDGET, head_alloc="adaptive")
    mono = policies.run_eviction("h2o", params, cfg, toks, evict=ev,
                                 extra_slots=2)
    chunked = policies.run_eviction_chunked("h2o", params, cfg, toks,
                                            chunk=128, evict=ev,
                                            extra_slots=2)
    assert_parity(mono, chunked)


# ---------------------------------------------------------------------------
# streaming-state properties
# ---------------------------------------------------------------------------


def test_cumulative_scores_chunk_order_invariant():
    """h2o's ScoreState is a commutative sum: per-chunk column-mass
    contributions added in any order — and under any chunk split — give the
    same final accumulator.  Contributions come from the fused second
    output of ``ops.chunk_attention`` (the path prefill actually runs)."""
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 3)
    B, H, KV, hd, K = 2, 4, 2, 16, 96
    q = jax.random.normal(ks[0], (B, K, H, hd))
    kbuf = jax.random.normal(ks[1], (B, K, KV, hd))
    vbuf = jax.random.normal(ks[2], (B, K, KV, hd))
    n = jnp.asarray(K, jnp.int32)

    def contrib(s, c):
        _, masses = ops.chunk_attention(
            q[:, s:s + c], kbuf, vbuf, q_offset=jnp.asarray(s, jnp.int32),
            score_masses=True, n_total=n)
        return masses

    chunks3 = [contrib(0, 32), contrib(32, 32), contrib(64, 32)]
    fwd = chunks3[0] + chunks3[1] + chunks3[2]
    # two-term fp addition commutes exactly; 3+-term reorderings and
    # different splits only reassociate, so they agree to addition ulps
    np.testing.assert_array_equal(np.asarray(chunks3[0] + chunks3[1]),
                                  np.asarray(chunks3[1] + chunks3[0]))
    rev = chunks3[2] + chunks3[1] + chunks3[0]
    np.testing.assert_allclose(np.asarray(fwd), np.asarray(rev),
                               atol=1e-6, rtol=1e-6)
    perm = chunks3[1] + chunks3[2] + chunks3[0]
    np.testing.assert_allclose(np.asarray(fwd), np.asarray(perm),
                               atol=1e-6, rtol=1e-6)
    split2 = contrib(0, 48) + contrib(48, 48)
    np.testing.assert_allclose(np.asarray(fwd), np.asarray(split2),
                               atol=1e-6, rtol=1e-6)


def test_partial_chunk_pad_rows_are_inert():
    """Rows past the true prompt length in a padded final chunk contribute
    zero column mass and never shift the observation-window buffer."""
    cfg = get_smoke_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    toks = rng.integers(0, cfg.vocab_size, (1, 40)).astype(np.int32)
    ev = EvictionConfig(budget=8)
    base = policies.run_eviction_chunked(
        "h2o", params, cfg, jnp.asarray(toks), chunk=16, evict=ev)
    # same prompt, garbage in the pad region of the final chunk: the caller
    # zero-pads, but even adversarial pad tokens must not perturb scores
    dirty = np.concatenate(
        [toks, rng.integers(0, cfg.vocab_size, (1, 8)).astype(np.int32)],
        axis=1)
    cap = policies.chunk_capacity_for(cfg, "h2o", 40, 16)
    state = tf.init_chunk_state(cfg, "h2o", 1, cap)
    n = jnp.asarray(40, jnp.int32)
    for s in range(0, 40, 16):
        blk = jnp.asarray(dirty[:, s:s + 16])
        state, logits = tf.prefill_chunk(params, cfg, state, blk, n,
                                         policy="h2o")
    cache = tf.prefill_finalize(params, cfg, state, n, policy="h2o",
                                evict=ev)
    assert kept_sets(base.cache) == kept_sets(cache)
    np.testing.assert_allclose(np.asarray(base.logits), np.asarray(logits),
                               atol=1e-5, rtol=0)


# ---------------------------------------------------------------------------
# engine-level: unbounded prompt length + bounded decode stalls
# ---------------------------------------------------------------------------


def test_engine_serves_prompt_beyond_legacy_buckets(model):
    """A prompt longer than the largest legacy bucket (1024) streams through
    the one compiled chunk shape; tokens still match isolated lockstep."""
    cfg, params, lkv, _ = model
    rng = np.random.default_rng(9)
    long_req = Request(uid=0, prompt=rng.integers(
        0, cfg.vocab_size, 1100).astype(np.int32), max_new_tokens=4)
    eng = ContinuousEngine(params, cfg, policy="lookaheadkv",
                           evict=EvictionConfig(budget=BUDGET),
                           lkv_params=lkv, num_slots=1, chunk=128,
                           max_context=256, max_new_tokens=4, eos_id=-1)
    done = eng.run([long_req])
    assert done[0].done and len(done[0].out_tokens) == 4
    # the compile cache never grew a bucket ladder: two entries total
    assert eng.chunk_cache.stats()["entries"] == 2
    if ops.use_pallas():
        # the *monolithic* pallas flash kernel needs block-aligned prompt
        # lengths, so the lockstep baseline cannot serve 1100 tokens under
        # REPRO_FORCE_PALLAS — chunked serving is exactly the path that
        # removes that constraint
        return
    iso_eng = ServingEngine(params, cfg, policy="lookaheadkv",
                            evict=EvictionConfig(budget=BUDGET),
                            lkv_params=lkv, max_new_tokens=4, eos_id=-1)
    iso = Request(uid=0, prompt=long_req.prompt, max_new_tokens=4)
    iso_eng.serve([iso])
    assert done[0].out_tokens == iso.out_tokens


def test_engine_decode_never_stalls_behind_long_prompt(model):
    """Mixed step: while a long prompt prefills, live decode slots advance
    every token-budget step — the gap between decode chunks never exceeds
    the planned prefill allotment."""
    cfg, params, lkv, _ = model
    rng = np.random.default_rng(10)
    reqs = [
        Request(uid=0, prompt=rng.integers(0, cfg.vocab_size, 24)
                .astype(np.int32), max_new_tokens=24),
        Request(uid=1, prompt=rng.integers(0, cfg.vocab_size, 640)
                .astype(np.int32), max_new_tokens=4, arrival_s=0.0),
    ]
    eng = ContinuousEngine(params, cfg, policy="lookaheadkv",
                           evict=EvictionConfig(budget=BUDGET),
                           lkv_params=lkv, num_slots=2, chunk=64,
                           max_context=128, max_new_tokens=24, eos_id=-1,
                           decode_chunk=4)
    done = eng.run(reqs)
    assert len(done) == 2
    budgeted_chunks = max(eng.token_budget // eng.chunk, 1)
    assert eng.stats["max_prefill_between_decode"] <= budgeted_chunks
    assert eng.stats["decode_chunks"] > 0
    if ops.use_pallas():
        return  # the lockstep baseline needs block-aligned prompt lengths
    for r in done:
        assert r.out_tokens == _isolated_tokens(cfg, params, lkv, r)


def _isolated_tokens(cfg, params, lkv, req):
    eng = ServingEngine(params, cfg, policy="lookaheadkv",
                        evict=EvictionConfig(budget=BUDGET), lkv_params=lkv,
                        max_new_tokens=req.max_new_tokens, eos_id=-1)
    iso = Request(uid=req.uid, prompt=req.prompt,
                  max_new_tokens=req.max_new_tokens)
    eng.serve([iso])
    return iso.out_tokens
