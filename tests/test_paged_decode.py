"""Gather-free paged flash-decode + fused sampling epilogue.

Four proof layers, least to most end-to-end:

1. **Windowed kernel parity**: the extended Pallas block-table decode
   kernel (sliding-window masking via scalar-prefetched ``new_pos`` /
   ``window`` and block-indexed ``pos`` tiles) matches the dense-gather
   oracle over randomized GQA shapes, per-head masks, ragged tables with
   null entries/tails, block sizes {16, 64, 128}, and traced (jitted)
   window operands; fully windowed-out sequences come out exact-zero.
2. **Dispatch-tier exactness**: the streaming jnp fallback reproduces the
   oracle, and the jnp gather tier of ``ops.paged_decode_attention`` —
   including the unaligned ``depth`` slice — is *bitwise* equal to the
   dense decode reduction it must replay (the paged-vs-dense serving
   contract of ``tests/test_kv_pool.py``).
3. **Sampling reference**: ``filter_logits`` top-k / top-p unit tests
   against hand-computed kept sets, identity when disabled, and the
   replay-determinism of ``fold_keys``.
4. **Fused-vs-host determinism**: ``decode_chunk`` with the fused
   sampling epilogue emits the same tokens as an eager host loop that
   pulls per-step logits and samples with the same folded keys; the
   serving engine reports which dispatch tier decoded.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import sweep_cases
from repro.configs import get_smoke_config
from repro.core import policies
from repro.core.lookahead import init_lookahead_params
from repro.kernels import ops, ref
from repro.kernels.paged_attention import paged_decode_attention_pallas
from repro.models import transformer as tf
from repro.serving import KVBlockPool
from trace_utils import make_trace_requests, run_trace

_HUGE = 1 << 30


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    lkv = init_lookahead_params(jax.random.PRNGKey(1), cfg, params["layers"])
    return cfg, params, lkv


def _paged_inputs(rng, *, B, KV, G, hd, bs, nb, p_valid=0.7, ragged=True):
    """Randomized pool-layout decode inputs: per-head masks, positions,
    and a table with interleaved null entries plus null tails."""
    H, N = KV * G, 2 + B * nb
    q = jnp.asarray(rng.normal(size=(B, H, hd)), jnp.float32)
    pk = jnp.asarray(rng.normal(size=(N, bs, KV, hd)), jnp.float32)
    pv = jnp.asarray(rng.normal(size=(N, bs, KV, hd)), jnp.float32)
    pm = jnp.asarray(rng.random((N, bs, KV)) < p_valid).at[0].set(False)
    pos = jnp.asarray(rng.integers(0, nb * bs, (N, bs, KV)), jnp.int32)
    tbl = np.zeros((B, nb), np.int32)
    for b in range(B):
        n_live = int(rng.integers(0, nb + 1)) if ragged else nb
        tbl[b, :n_live] = rng.choice(np.arange(1, N), n_live, replace=False)
        if ragged:
            rng.shuffle(tbl[b])
    new_pos = jnp.asarray(rng.integers(1, nb * bs + 1, B), jnp.int32)
    return q, pk, pv, pm, pos, jnp.asarray(tbl), new_pos


# ---------------------------------------------------------------------------
# 1. windowed kernel parity
# ---------------------------------------------------------------------------


def _win_case(rng):
    kv = int(rng.choice([1, 2]))
    return {
        "B": int(rng.integers(1, 4)),
        "KV": kv,
        "G": int(rng.choice([1, 3])),
        "hd": int(rng.choice([16, 32])),
        "nb": int(rng.integers(1, 6)),
        "window": int(rng.choice([0, 3, 17, _HUGE])),  # 0 encodes None
        "seed": int(rng.integers(1e6)),
    }


@pytest.mark.parametrize("bs", [16, 64, 128])
@pytest.mark.parametrize("case", sweep_cases(23, 6, _win_case))
def test_windowed_kernel_matches_oracle(case, bs):
    rng = np.random.default_rng(case["seed"])
    q, pk, pv, pm, pos, tbl, npos = _paged_inputs(
        rng, B=case["B"], KV=case["KV"], G=case["G"], hd=case["hd"],
        bs=bs, nb=case["nb"])
    win = case["window"] or None
    kw = ({} if win is None
          else dict(pos_pool=pos, new_pos=npos, window=win))
    want = ref.paged_decode_attention(q, pk, pv, pm, tbl, **kw)
    got = paged_decode_attention_pallas(q, pk, pv, pm, tbl,
                                        interpret=True, **kw)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_windowed_kernel_traced_window():
    """The window arrives as a *traced* scalar (patterned local:global
    archs pass ``layer_window`` through jit) — the kernel must accept it
    without retracing per value and still match the oracle."""
    rng = np.random.default_rng(7)
    q, pk, pv, pm, pos, tbl, npos = _paged_inputs(
        rng, B=2, KV=2, G=2, hd=32, bs=16, nb=3)

    @jax.jit
    def f(w):
        return paged_decode_attention_pallas(
            q, pk, pv, pm, tbl, pos_pool=pos, new_pos=npos, window=w,
            interpret=True)

    for w in (5, 16, _HUGE):
        want = ref.paged_decode_attention(
            q, pk, pv, pm, tbl, pos_pool=pos, new_pos=npos, window=w)
        np.testing.assert_allclose(f(jnp.int32(w)), want,
                                   atol=2e-5, rtol=2e-5)


def test_windowed_out_sequence_is_exact_zero():
    """Every key older than the window (and the all-null second row) must
    produce exact zeros — not NaN from an empty softmax."""
    rng = np.random.default_rng(3)
    q, pk, pv, pm, pos, tbl, _ = _paged_inputs(
        rng, B=2, KV=1, G=4, hd=16, bs=16, nb=2, p_valid=1.0, ragged=False)
    tbl = tbl.at[1].set(0)
    npos = jnp.asarray([1000, 1000], jnp.int32)  # window excludes all pos
    for fn in (ref.paged_decode_attention,
               lambda *a, **k: paged_decode_attention_pallas(
                   *a, interpret=True, **k)):
        out = fn(q, pk, pv, pm, tbl, pos_pool=pos, new_pos=npos, window=4)
        assert np.all(np.asarray(out) == 0.0)


# ---------------------------------------------------------------------------
# 2. dispatch-tier exactness (streaming fallback, gather oracle, depth)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("window", [None, 9, _HUGE])
def test_streaming_fallback_matches_oracle(window):
    """The jnp streaming block scan (the beyond-2k tier) reproduces the
    gather oracle under per-head masks, raggedness, and windows."""
    rng = np.random.default_rng(11)
    q, pk, pv, pm, pos, tbl, npos = _paged_inputs(
        rng, B=3, KV=2, G=3, hd=32, bs=16, nb=4)
    kw = ({} if window is None
          else dict(pos_pool=pos, new_pos=npos, window=window))
    want = ref.paged_decode_attention(q, pk, pv, pm, tbl, **kw)
    got = ops._paged_decode_streaming(q, pk, pv, pm, tbl, **kw)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@pytest.mark.skipif(ops.use_pallas(), reason="bitwise dense equality is "
                    "the jnp gather tier's contract; the kernel tier is "
                    "covered by allclose parity + the differential traces")
@pytest.mark.parametrize("depth_off", [0, 1, 7])
def test_gather_tier_bitwise_equals_dense_reduction(depth_off):
    """``ops.paged_decode_attention`` on the jnp path — including an
    unaligned ``depth`` slice — must be *bit-identical* to gathering a
    dense view and running the dense decode reduction, because that is
    what keeps paged serving token-exact vs the dense engine."""
    rng = np.random.default_rng(13)
    B, KV, G, hd, bs, nb = 2, 2, 2, 32, 16, 4
    q, pk, pv, pm, pos, tbl, npos = _paged_inputs(
        rng, B=B, KV=KV, G=G, hd=hd, bs=bs, nb=nb, p_valid=0.95,
        ragged=False)
    depth = nb * bs - depth_off
    window = 24
    got = ops.paged_decode_attention(
        q, pk, pv, pm, tbl, pos_pool=pos, new_pos=npos, window=window,
        depth=depth)
    # the dense replay: gather, slice to depth, window on gathered pos
    shp = (B, nb * bs)
    k = pk[tbl].reshape(shp + pk.shape[2:])[:, :depth]
    v = pv[tbl].reshape(shp + pv.shape[2:])[:, :depth]
    m = pm[tbl].reshape(shp + pm.shape[2:])[:, :depth]
    p = pos[tbl].reshape(shp + pos.shape[2:])[:, :depth]
    m = m & ((npos[:, None, None] - p) < window)
    want = ops.decode_attention(q, k, v, kv_mask=m)
    assert np.array_equal(np.asarray(got), np.asarray(want)), \
        "jnp gather tier drifted from the dense reduction"


def test_paged_decode_path_tiers():
    small, big = 1024, ops._DIRECT_SEQ + 1
    if ops.use_pallas():
        assert ops.paged_decode_path(small) == "kernel"
        assert ops.paged_decode_path(big) == "kernel"
    else:
        assert ops.paged_decode_path(small) == "gather"
        assert ops.paged_decode_path(big) == "fallback"


# ---------------------------------------------------------------------------
# 3. sampling reference: filter_logits / fold_keys / sample_logits
# ---------------------------------------------------------------------------


def test_filter_logits_top_k():
    logits = jnp.asarray([[5.0, 1.0, 4.0, 3.0, 2.0]])
    out = np.asarray(policies.filter_logits(logits, top_k=2))
    assert out[0, 0] == 5.0 and out[0, 2] == 4.0
    assert (out[0, [1, 3, 4]] <= -1e29).all()
    # ties at the k-th value are all kept (the filter never breaks ties
    # arbitrarily, so results don't depend on sort stability)
    tied = jnp.asarray([[3.0, 3.0, 3.0, 1.0]])
    out = np.asarray(policies.filter_logits(tied, top_k=2))
    assert (out[0, :3] == 3.0).all() and out[0, 3] <= -1e29


def test_filter_logits_top_p():
    probs = np.array([0.5, 0.3, 0.15, 0.05])
    logits = jnp.log(jnp.asarray(probs, jnp.float32))[None]
    kept = np.asarray(logits)[0]  # kept entries pass through unchanged
    # mass before: 0, .5, .8, .95 -> top_p=0.7 keeps the first two
    out = np.asarray(policies.filter_logits(logits, top_p=0.7))
    assert np.isfinite(out[0, :2]).all() and (out[0, 2:] <= -1e29).all()
    np.testing.assert_array_equal(out[0, :2], kept[:2])
    # a tiny top_p still keeps the argmax
    out = np.asarray(policies.filter_logits(logits, top_p=1e-6))
    assert out[0, 0] == kept[0] and (out[0, 1:] <= -1e29).all()


def test_filter_logits_disabled_is_identity():
    logits = jax.random.normal(jax.random.PRNGKey(0), (3, 17))
    out = policies.filter_logits(logits, top_k=0, top_p=1.0)
    assert out is logits, "disabled filters must be a python-level no-op"
    # top_k >= V is likewise identity (cheap common case)
    out = np.asarray(policies.filter_logits(logits, top_k=17))
    np.testing.assert_array_equal(out, np.asarray(logits))


def test_fold_keys_replay_determinism():
    seeds = jnp.asarray([3, 3, 9], jnp.int32)
    pos = jnp.asarray([10, 11, 10], jnp.int32)
    k1, k2 = policies.fold_keys(seeds, pos), policies.fold_keys(seeds, pos)
    np.testing.assert_array_equal(np.asarray(k1), np.asarray(k2))
    # distinct (seed, position) pairs give distinct keys
    ks = np.asarray(k1).reshape(3, -1)
    assert len({tuple(r) for r in ks}) == 3


def test_sample_logits_greedy_is_argmax():
    logits = jax.random.normal(jax.random.PRNGKey(1), (4, 33))
    keys = policies.fold_keys(jnp.arange(4, dtype=jnp.int32),
                              jnp.zeros(4, jnp.int32))
    out = policies.sample_logits(logits, keys, temperature=0.0)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(jnp.argmax(logits, -1)))


def test_sample_logits_respects_filters():
    """At temperature > 0 with a tight top-k, samples stay inside the
    kept set for every key."""
    logits = jnp.asarray(np.random.default_rng(5).normal(size=(64, 50)),
                         jnp.float32)
    keys = policies.fold_keys(jnp.arange(64, dtype=jnp.int32),
                              jnp.full(64, 7, jnp.int32))
    ids = np.asarray(policies.sample_logits(
        logits, keys, temperature=1.3, top_k=3))
    top3 = np.asarray(jax.lax.top_k(logits, 3)[1])
    assert all(ids[i] in top3[i] for i in range(64))


# ---------------------------------------------------------------------------
# 4. fused-vs-host sampling determinism + engine dispatch stats
# ---------------------------------------------------------------------------


def test_fused_sampling_matches_host_loop(model):
    """The fused epilogue inside jitted ``decode_chunk`` and an eager host
    loop (per-step logits transfers + the same folded keys) must emit the
    same token sequences — the epilogue changes where sampling runs, not
    what it samples."""
    cfg, params, lkv = model
    rng = np.random.default_rng(17)
    B, S, steps = 2, 24, 8
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    seeds = jnp.asarray([101, 202], jnp.int32)
    sampling = policies.Sampling(temperature=0.8, top_k=20, top_p=0.95)

    def fresh_state():
        pf = tf.prefill(params, cfg, prompts, policy="lookaheadkv",
                        lkv_params=lkv, extra_slots=steps + 1)
        keys = policies.fold_keys(seeds, jnp.full((B,), S, jnp.int32))
        first = policies.sample_logits(
            pf.logits, keys, temperature=sampling.temperature,
            top_k=sampling.top_k, top_p=sampling.top_p)[:, None]
        return first.astype(jnp.int32), pf.cache

    tok, cache = fresh_state()
    fused = jax.jit(lambda t, c, s: policies.decode_chunk(
        params, cfg, t, c, steps, sampling=sampling, seeds=s))
    _, _, toks_fused = fused(tok, cache, seeds)

    tok, cache = fresh_state()
    host = []
    for _ in range(steps):
        nxt_pos = cache["next_pos"][:, 0] + 1
        logits, cache = tf.decode_step(params, cfg, tok, cache)
        keys = policies.fold_keys(seeds, nxt_pos)
        tok = policies.sample_logits(
            logits, keys, temperature=sampling.temperature,
            top_k=sampling.top_k, top_p=sampling.top_p
        )[:, None].astype(jnp.int32)
        host.append(np.asarray(tok[:, 0]))
    np.testing.assert_array_equal(np.asarray(toks_fused),
                                  np.stack(host, axis=1))


def test_fused_sampling_same_seed_same_tokens(model):
    """Two runs, same seeds -> identical tokens; a different seed moves at
    least one of them (sanity that sampling is actually stochastic)."""
    cfg, params, lkv = model
    rng = np.random.default_rng(19)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 16)),
                          jnp.int32)
    sampling = policies.Sampling(temperature=1.0, top_k=0, top_p=1.0)
    fused = jax.jit(lambda t, c, s: policies.decode_chunk(
        params, cfg, t, c, 12, sampling=sampling, seeds=s)[2])

    def run(seed):
        pf = tf.prefill(params, cfg, prompts, policy="lookaheadkv",
                        lkv_params=lkv, extra_slots=13)
        tok = jnp.argmax(pf.logits, -1)[:, None].astype(jnp.int32)
        return np.asarray(fused(tok, pf.cache,
                                jnp.asarray([seed], jnp.int32)))

    a, b, c = run(42), run(42), run(43)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c), "seed must matter at temperature 1"


def test_engine_reports_decode_path_and_step_time(model):
    """The serving engine's stats must name the active dispatch tier and
    account decode wall time per step — paged and dense."""
    cfg, params, lkv = model
    chunk = 128
    reqs = make_trace_requests(cfg, chunk=chunk, seed=2, n_requests=3,
                               max_new=4)
    _, dense = run_trace(cfg, params, lkv, policy="lookaheadkv",
                         requests=reqs, chunk=chunk, decode_chunk=2)
    assert dense.stats["decode_path"] == "dense"
    pool = KVBlockPool(cfg, block_size=16, num_blocks=128)
    _, paged = run_trace(cfg, params, lkv, policy="lookaheadkv",
                         requests=reqs, chunk=chunk, decode_chunk=2,
                         kv_pool=pool)
    assert paged.stats["decode_path"] == ops.paged_decode_path(
        paged._paged_depth)
    for eng in (dense, paged):
        assert eng.stats["decode_steps"] > 0
        assert eng.stats["decode_time_s"] > 0.0
