"""The paper's core invariants:

  I1  selective LoRA + lookahead rows leave *normal-token* computation
      bit-identical to the frozen model (§3.1 "original model behavior is
      preserved") — checked on logits and on per-layer prompt keys;
  I2  the lookahead importance estimate matches the oracle scoring math;
  I3  training the modules reduces the KL to the GT scores (loss decreases);
  I4  the GT-oracle policy's kept-set recovers the needle positions better
      than random (sanity of the whole scoring path);
  I5  lookahead params are <0.5% of model params (paper Table 1 property).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import EvictionConfig, TrainConfig
from repro.configs import get_smoke_config
from repro.core import objective
from repro.core.lookahead import init_lookahead_params, lookahead_count
from repro.data import synthetic
from repro.models import transformer as tf
from repro.optim import adam


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("smollm-135m")
    key = jax.random.PRNGKey(0)
    params = tf.init_params(key, cfg)
    lkv = init_lookahead_params(jax.random.PRNGKey(1), cfg, params["layers"])
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 64), 0,
                                cfg.vocab_size)
    return cfg, params, lkv, tokens


def _f32(cfg):
    import dataclasses

    return dataclasses.replace(cfg, dtype="float32")


def test_lora_preserves_normal_tokens(setup):
    """I1: logits from the last *real* row with lookahead modules active must
    equal the frozen model's (LoRA masked off real rows; lookahead rows are
    causally after them).  f32 model: the only residual difference is float
    sum-order noise from the longer (padded) sequence."""
    cfg, params, lkv, tokens = setup
    cfg = _f32(cfg)
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    base = tf.prefill(params, cfg, tokens, want_logits="last")
    with_lkv = tf.prefill(params, cfg, tokens, lkv_params=lkv,
                          policy="lookaheadkv",
                          evict=EvictionConfig(budget=16))
    np.testing.assert_allclose(base.logits, with_lkv.logits,
                               atol=1e-4, rtol=1e-4)


def test_lora_nonzero_b_still_preserves(setup):
    """I1 with non-trivial LoRA B (post-training state)."""
    cfg, params, lkv, tokens = setup
    cfg = _f32(cfg)
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    lkv2 = jax.tree.map(lambda x: x + 0.37, lkv)  # perturb emb + a + b
    base = tf.prefill(params, cfg, tokens, want_logits="last")
    got = tf.prefill(params, cfg, tokens, lkv_params=lkv2,
                     policy="lookaheadkv", evict=EvictionConfig(budget=16))
    np.testing.assert_allclose(base.logits, got.logits, atol=1e-4, rtol=1e-4)


def test_selective_linear_exact_zero_delta():
    """I1 at the op level: a masked row's LoRA delta is exactly zero (bit
    identity — no tolerance)."""
    from repro.models.layers import linear, lora_init

    key = jax.random.PRNGKey(9)
    x = jax.random.normal(key, (2, 6, 16), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(10), (16, 24), jnp.bfloat16)
    lora = lora_init(jax.random.PRNGKey(11), 16, 24, 4)
    lora = jax.tree.map(lambda v: v + 0.5, lora)  # nonzero b
    mask = jnp.zeros((2, 6, 1), jnp.bfloat16).at[:, -2:].set(1.0)
    base = linear(x, w)
    got = linear(x, w, lora=lora, lora_mask=mask, lora_scale=4.0)
    assert (np.asarray(base[:, :4]) == np.asarray(got[:, :4])).all()
    assert not (np.asarray(base[:, 4:]) == np.asarray(got[:, 4:])).all()


def test_scores_shapes_and_range(setup):
    cfg, params, lkv, tokens = setup
    s = objective.lookahead_scores(params, cfg, lkv, tokens)
    L, B, H, n = s.shape
    assert (L, B, n) == (cfg.num_layers, tokens.shape[0], tokens.shape[1])
    assert H == cfg.attn.num_heads
    assert bool((s >= 0).all()) and bool((s.sum(-1) <= 1 + 1e-5).all())


def test_gt_scores_stop_gradient(setup):
    cfg, params, lkv, tokens = setup
    xy = jnp.concatenate(
        [tokens, jax.random.randint(jax.random.PRNGKey(3), (2, 16), 0,
                                    cfg.vocab_size)], axis=1)

    def loss(p):
        return objective.gt_scores(p, cfg, xy, tokens.shape[1]).sum()

    g = jax.grad(lambda p: loss(p))(params)
    assert all(float(jnp.abs(x).max()) == 0.0 for x in jax.tree.leaves(g))


def test_training_reduces_kl(setup):
    """I3: a few Adam steps on a fixed batch reduce the objective."""
    cfg, params, lkv, _ = setup
    tc = TrainConfig(steps=40, lr=1e-3, warmup_frac=0.1)  # paper's lr
    x = jax.random.randint(jax.random.PRNGKey(5), (4, 48), 0, cfg.vocab_size)
    xy = jnp.concatenate(
        [x, jax.random.randint(jax.random.PRNGKey(6), (4, 16), 0,
                               cfg.vocab_size)], axis=1)

    @jax.jit
    def step(lkv, opt):
        def loss_fn(l):
            return objective.lkv_loss(params, cfg, l, x, xy, x.shape[1])[0]

        loss, grads = jax.value_and_grad(loss_fn)(lkv)
        lkv, opt, _ = adam.update(lkv, grads, opt, tc)
        return lkv, opt, loss

    opt = adam.init(lkv)
    first = None
    cur = lkv
    for i in range(40):
        cur, opt, loss = step(cur, opt)
        if first is None:
            first = float(loss)
    assert float(loss) < first * 0.5, (first, float(loss))


def test_gt_oracle_recovers_needle(setup):
    """I4: with GT scores, the kept set contains needle positions far above
    the random-keep rate."""
    cfg, params, _, _ = setup
    rng = np.random.default_rng(0)
    batch = synthetic.make_needle_batch(rng, 4, 96, cfg.vocab_size)
    x = jnp.asarray(batch.x)
    # teacher-forced "response" = the true needle values
    xy = jnp.concatenate([x, jnp.asarray(batch.y)], axis=1)
    budget = 24
    res = tf.prefill(params, cfg, xy, policy="gt_oracle",
                     gt_boundary=x.shape[1],
                     evict=EvictionConfig(budget=budget))
    pos = np.asarray(res.cache["attn"]["pos"])  # (L, B, cap, KV)
    hit = 0
    tot = 0
    for b in range(x.shape[0]):
        want = set(batch.answer_pos[b].tolist())
        kept = set(pos[:, b].reshape(-1).tolist())
        hit += len(want & kept)
        tot += len(want)
    recall = hit / tot
    # random keep-rate would be ~ budget/n = 0.25
    assert recall > 0.5, recall


def test_param_budget(setup):
    """I5: lookahead params < 0.5% of the model (paper Table 1)."""
    cfg, params, lkv, _ = setup
    from repro.common.pytree import tree_size

    frac = lookahead_count(lkv) / tree_size(params)
    assert frac < 0.10  # smoke models are tiny; full configs sit <=0.5%


def test_full_config_param_budget():
    """Paper Table 1 at assigned-architecture scale (analytic count): the
    paper's <0.5% holds for its 1B–8B subjects; the fraction shrinks with
    model size (LoRA is O(d·L) vs params O(d²·L))."""
    from repro.configs import get_config

    def frac(arch):
        cfg = get_config(arch)
        lk = cfg.lookahead
        d, a, r = cfg.d_model, cfg.attn, cfg.lookahead.lora_rank
        per_layer = r * (2 * d + a.q_dim + 2 * a.kv_dim + (a.q_dim + d))
        if cfg.d_ff:
            per_layer += r * (2 * (d + cfg.d_ff) + (cfg.d_ff + d))
        lkv_total = lk.n_lookahead * d + cfg.num_layers * per_layer
        return lkv_total / cfg.num_params()

    for arch in ("minitron-8b", "qwen2-vl-72b", "llama3-8b"):
        assert frac(arch) < 0.005, arch  # paper Table 1 regime
    assert frac("qwen2-1.5b") < 0.007
    # monotone: bigger model => smaller trainable fraction
    assert frac("qwen2-vl-72b") < frac("minitron-8b") < frac("qwen2-1.5b") \
        < frac("smollm-135m")
