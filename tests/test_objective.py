"""Distillation-objective regression suite (core/objective.py).

Pins the ``kl_divergence`` eps-asymmetry bug: the old form computed
``log(p + eps) - log(max(q, eps))`` so ``KL(p ‖ p)`` was nonzero (and the
divergence could go negative), biasing the loss near convergence.  Also
checks the harvested-target distillation loss agrees with the online
two-pass objective when the targets come from the same gt pass.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import TrainConfig
from repro.configs import get_smoke_config
from repro.core import objective
from repro.core.lookahead import init_lookahead_params
from repro.core.scoring import normalize_l1
from repro.models import transformer as tf


def _random_dist(rng, shape, zeros=0.0):
    """L1-normalized nonnegative vectors along the last axis; ``zeros`` is
    the fraction of entries forced to exactly 0."""
    x = rng.random(shape).astype(np.float32)
    if zeros:
        x = np.where(rng.random(shape) < zeros, 0.0, x)
        x[..., 0] = np.maximum(x[..., 0], 0.1)  # keep mass positive
    return np.asarray(normalize_l1(jnp.asarray(x)))


def test_kl_identity_is_exactly_zero():
    rng = np.random.default_rng(0)
    for zeros in (0.0, 0.3):
        p = jnp.asarray(_random_dist(rng, (4, 6, 32), zeros=zeros))
        kl = objective.kl_divergence(p, p)
        assert kl.shape == (4, 6)
        np.testing.assert_array_equal(np.asarray(kl), 0.0)


def test_kl_nonnegative():
    rng = np.random.default_rng(1)
    p = jnp.asarray(_random_dist(rng, (8, 48), zeros=0.2))
    q = jnp.asarray(_random_dist(rng, (8, 48)))
    kl = np.asarray(objective.kl_divergence(p, q))
    # mathematically >= 0 for normalized p, q; the tolerance covers f32
    # summation rounding only
    assert (kl >= -1e-6).all()
    # distinct distributions must register as genuinely divergent
    assert kl.mean() > 1e-3


def test_kl_zero_q_mass_is_finite_and_penalized():
    p = jnp.asarray([[0.5, 0.5, 0.0]], jnp.float32)
    q = jnp.asarray([[1.0, 0.0, 0.0]], jnp.float32)
    kl = np.asarray(objective.kl_divergence(p, q))
    assert np.isfinite(kl).all()
    assert kl[0] > 1.0  # missing mass costs ~0.5 * log(0.5/eps)


def test_kl_gradient_finite_at_convergence():
    """d/dq KL at p == q must be finite (the asymmetric form's bias lived
    exactly here)."""
    p = jnp.asarray([0.6, 0.4, 0.0], jnp.float32)

    g = jax.grad(lambda q: objective.kl_divergence(p, q).sum())(p)
    assert np.isfinite(np.asarray(g)).all()


def test_targets_loss_matches_online_loss():
    """lkv_loss_from_targets(x, gt_scores(xy)) == lkv_loss(x, xy): the
    harvested-target path is the same objective with the GT pass hoisted."""
    cfg = get_smoke_config("smollm-135m")
    key = jax.random.PRNGKey(0)
    params = tf.init_params(key, cfg)
    lkv = init_lookahead_params(jax.random.PRNGKey(1), cfg, params["layers"])
    rng = np.random.default_rng(2)
    B, n_in, n_out = 2, 24, 8
    x = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, n_in)), jnp.int32)
    y = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, n_out)), jnp.int32)
    xy = jnp.concatenate([x, y], axis=1)

    loss_online, rep_online = objective.lkv_loss(params, cfg, lkv, x, xy, n_in)
    s_gt = objective.gt_scores(params, cfg, xy, n_in)
    loss_t, rep_t = objective.lkv_loss_from_targets(params, cfg, lkv, x, s_gt)
    assert float(loss_t) == pytest.approx(float(loss_online), rel=1e-5)
    np.testing.assert_allclose(np.asarray(rep_t.kl_per_layer),
                               np.asarray(rep_online.kl_per_layer), rtol=1e-5)


def test_targets_loss_trains():
    """A few Adam steps on the harvested-target objective must reduce it —
    the gradient path through the lookahead pass is intact."""
    from repro.optim import adam

    cfg = get_smoke_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    lkv = init_lookahead_params(jax.random.PRNGKey(1), cfg, params["layers"])
    rng = np.random.default_rng(3)
    B, n_in, n_out = 2, 24, 8
    x = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, n_in)), jnp.int32)
    y = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, n_out)), jnp.int32)
    s_gt = objective.gt_scores(params, cfg, jnp.concatenate([x, y], 1), n_in)

    tc = TrainConfig(steps=8, lr=3e-3, warmup_frac=0.0)
    opt = adam.init(lkv)

    @jax.jit
    def step(lkv, opt):
        def loss_fn(lkv):
            loss, _ = objective.lkv_loss_from_targets(
                params, cfg, lkv, x, s_gt)
            return loss

        loss, grads = jax.value_and_grad(loss_fn)(lkv)
        lkv, opt, _ = adam.update(lkv, grads, opt, tc)
        return lkv, opt, loss

    losses = []
    for _ in range(8):
        lkv, opt, loss = step(lkv, opt)
        losses.append(float(loss))
    assert losses[-1] < losses[0]
