"""Eviction invariants (property tests, seeded sweeps):

  P1  budget is always respected — exactly ``capacity`` slots, validity mask
      bounds the per-layer budget;
  P2  retained indices are unique per (batch, kv head) and temporally sorted;
  P3  eviction at full budget is a no-op: decode attention over the evicted
      cache equals attention over the raw KV;
  P4  StreamingLLM keeps sink + most-recent tokens;
  P5  SnapKV-style window force-keep retains the observation suffix;
  P6  PyramidKV budgets: monotone decreasing, mean == budget;
  P7  maxpool is monotone, idempotent on constants, and dominates identity;
  P8  L1 normalization: sums to 1, scale-invariant;
  P9  KL ≥ 0 and == 0 iff identical distributions.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import sweep_cases
from repro.core import eviction as ev
from repro.core import scoring
from repro.core.objective import kl_divergence
from repro.kernels import ref


def _case(rng):
    return dict(B=int(rng.integers(1, 4)), KV=int(rng.integers(1, 4)),
                n=int(rng.integers(16, 100)),
                budget=int(rng.integers(2, 14)),
                seed=int(rng.integers(1 << 30)))


@pytest.mark.parametrize("case", sweep_cases(21, 10, _case))
def test_budget_respected_and_indices_unique(case):
    key = jax.random.PRNGKey(case["seed"])
    scores = jax.random.uniform(key, (case["B"], case["KV"], case["n"]))
    idx, mask = ev.select_topk(scores, case["budget"])
    assert idx.shape == (case["B"], case["KV"], case["budget"])
    assert bool(mask.all())  # uniform budgets: every slot valid
    for b in range(case["B"]):
        for h in range(case["KV"]):
            sel = np.asarray(idx[b, h])
            assert len(set(sel.tolist())) == len(sel)  # P2 unique
            assert (np.diff(sel) > 0).all()  # P2 sorted by position
    # P1 with a traced layer budget
    lb = jnp.asarray(max(case["budget"] - 1, 1))
    idx2, mask2 = ev.select_topk(scores, case["budget"], layer_budget=lb)
    assert int(mask2.sum()) == case["B"] * case["KV"] * int(lb)


@pytest.mark.parametrize("case", sweep_cases(22, 6, _case))
def test_full_budget_eviction_is_noop(case):
    """P3: evict with capacity >= n, then decode-attend: identical output."""
    key = jax.random.PRNGKey(case["seed"])
    ks = jax.random.split(key, 4)
    B, KV, n = case["B"], case["KV"], case["n"]
    hd, G = 16, 2
    k = jax.random.normal(ks[0], (B, n, KV, hd))
    v = jax.random.normal(ks[1], (B, n, KV, hd))
    q = jax.random.normal(ks[2], (B, KV * G, hd))
    scores = jax.random.uniform(ks[3], (B, KV, n))
    cache = ev.evict_layer(scores, k, v, capacity=n)
    # same *set* of (k, v) rows per head => same attention output
    out_full = ref.decode_attention(q, k, v)
    out_ev = ref.decode_attention(q, cache.k, cache.v,
                                  kv_mask=cache.mask)
    np.testing.assert_allclose(out_ev, out_full, atol=1e-5, rtol=1e-5)


def test_streaming_llm_keeps_sink_and_recent():
    B, KV, n, budget, sink = 2, 3, 64, 10, 4
    s = ev.position_scores("streaming_llm", n, B, KV, sink=sink)
    idx, mask = ev.select_topk(s, budget)
    want = set(range(sink)) | set(range(n - (budget - sink), n))
    for b in range(B):
        for h in range(KV):
            assert set(np.asarray(idx[b, h]).tolist()) == want


def test_window_force_keep():
    B, KV, n, budget, window = 1, 2, 64, 12, 8
    key = jax.random.PRNGKey(0)
    s = jax.random.uniform(key, (B, KV, n))
    s = ev.keep_window(s, window)
    idx, _ = ev.select_topk(s, budget)
    kept = set(np.asarray(idx[0, 0]).tolist())
    assert set(range(n - window, n)) <= kept


def test_pyramid_budgets():
    L, budget = 28, 128
    b = np.asarray(ev.pyramid_budgets(L, budget, beta=2.0))
    assert (np.diff(b) <= 0).all()
    assert abs(b.mean() - budget) / budget < 0.02
    assert b[0] > budget > b[-1]


@pytest.mark.parametrize("case", sweep_cases(23, 6, _case))
def test_maxpool_properties(case):
    key = jax.random.PRNGKey(case["seed"])
    s = jax.random.uniform(key, (case["B"], case["KV"], case["n"]))
    p = scoring.maxpool1d(s, 7)
    assert p.shape == s.shape
    assert bool((p >= s - 1e-7).all())  # dominates identity
    const = jnp.ones_like(s) * 0.3
    np.testing.assert_allclose(scoring.maxpool1d(const, 7), const)
    assert np.allclose(scoring.maxpool1d(s, 1), s)


@pytest.mark.parametrize("case", sweep_cases(24, 6, _case))
def test_normalize_and_kl(case):
    key = jax.random.PRNGKey(case["seed"])
    k1, k2 = jax.random.split(key)
    s = jax.random.uniform(k1, (case["B"], case["KV"], case["n"])) + 1e-3
    ns = scoring.normalize_l1(s)
    np.testing.assert_allclose(ns.sum(-1), 1.0, atol=1e-5)
    np.testing.assert_allclose(scoring.normalize_l1(s * 7.3), ns, atol=1e-5)
    t = jax.random.uniform(k2, s.shape) + 1e-3
    nt = scoring.normalize_l1(t)
    assert bool((kl_divergence(ns, nt) >= -1e-6).all())  # P9 nonneg
    np.testing.assert_allclose(kl_divergence(ns, ns), 0.0, atol=1e-5)


def test_gqa_reduce():
    B, KV, G, n = 2, 3, 4, 10
    s = jnp.arange(B * KV * G * n, dtype=jnp.float32).reshape(B, KV * G, n)
    r = scoring.gqa_reduce(s, KV)
    assert r.shape == (B, KV, n)
    np.testing.assert_allclose(
        r[0, 0], s[0, 0:G].mean(0), atol=1e-5)


def test_gather_kv_zeroes_invalid():
    key = jax.random.PRNGKey(0)
    B, n, KV, hd = 1, 16, 1, 4
    k = jax.random.normal(key, (B, n, KV, hd))
    v = jax.random.normal(key, (B, n, KV, hd))
    scores = jnp.ones((B, KV, n))
    cache = ev.evict_layer(scores, k, v, capacity=8,
                           layer_budget=jnp.asarray(5))
    assert int(cache.mask.sum()) == 5
    masked = np.asarray(cache.k)[~np.asarray(cache.mask)]
    assert (masked == 0).all()


def test_adaptive_head_budgets_pool_invariant():
    """Ada-KV allocation: per-head budgets vary with score concentration but
    the global pool KV·budget is preserved (±KV from int rounding)."""
    key = jax.random.PRNGKey(42)
    B, KV, n, budget, cap = 3, 4, 64, 12, 24
    # head 0: spiky scores; head 3: flat
    base = jax.random.uniform(key, (B, KV, n)) * 0.1
    spike = base.at[:, 0, :3].add(5.0)
    b = ev.adaptive_head_budgets(spike, budget, cap)
    assert b.shape == (B, KV)
    assert bool((b >= 4).all()) and bool((b <= cap).all())
    np.testing.assert_allclose(np.asarray(b.sum(axis=1)), KV * budget,
                               atol=KV)
    # the spiky head gets more than the flat ones
    assert bool((b[:, 0] >= b[:, 3]).all())


def test_select_topk_per_head_respects_budgets():
    key = jax.random.PRNGKey(7)
    B, KV, n, cap = 2, 3, 40, 16
    scores = jax.random.uniform(key, (B, KV, n))
    hb = jnp.asarray([[4, 8, 12], [16, 5, 9]], jnp.int32)
    idx, mask = ev.select_topk_per_head(scores, cap, hb)
    np.testing.assert_array_equal(np.asarray(mask.sum(-1)), np.asarray(hb))
    # valid indices are the true top-k of each head
    for b in range(B):
        for h in range(KV):
            got = set(np.asarray(idx[b, h])[np.asarray(mask[b, h])].tolist())
            want = set(np.argsort(-np.asarray(scores[b, h]))
                       [: int(hb[b, h])].tolist())
            assert got == want


def test_adaptive_uniform_equivalence_when_flat():
    """With perfectly uniform scores every head gets ~the same budget."""
    B, KV, n, budget, cap = 1, 4, 64, 12, 24
    scores = jnp.ones((B, KV, n))
    b = ev.adaptive_head_budgets(scores, budget, cap)
    assert int(b.max() - b.min()) <= 1
