"""Shared test utilities.

No ``hypothesis`` offline — ``sweep_cases`` provides seeded random shape
sweeps with the same spirit: each property test runs across a randomized
family of shapes/dtypes and any failure prints the exact case for replay.
"""

import numpy as np
import pytest


def sweep_cases(seed: int, n: int, gen):
    """Deterministic pseudo-random case list: gen(rng) -> case dict."""
    rng = np.random.default_rng(seed)
    return [gen(rng) for _ in range(n)]


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
