"""RoPE / M-RoPE properties + sharding-spec validity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import sweep_cases
from repro.models import rope


def _case(rng):
    return dict(B=int(rng.integers(1, 3)), S=int(rng.integers(4, 40)),
                H=int(rng.integers(1, 4)),
                hd=int(rng.choice([16, 32, 64])),
                seed=int(rng.integers(1 << 30)))


@pytest.mark.parametrize("case", sweep_cases(31, 6, _case))
def test_rope_preserves_norm_and_relativity(case):
    key = jax.random.PRNGKey(case["seed"])
    B, S, H, hd = case["B"], case["S"], case["H"], case["hd"]
    x = jax.random.normal(key, (B, S, H, hd))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    r = rope.apply_rope(x, pos, 10_000.0)
    # rotation preserves norms
    np.testing.assert_allclose(
        jnp.linalg.norm(r, axis=-1), jnp.linalg.norm(x, axis=-1),
        atol=1e-4, rtol=1e-4)
    # relativity: <q_i, k_j> depends only on i - j
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, hd))
    def dot_at(pi, pj):
        qi = rope.apply_rope(q, jnp.full((1, 1), pi), 1e4)
        kj = rope.apply_rope(k, jnp.full((1, 1), pj), 1e4)
        return float(jnp.sum(qi * kj))
    assert abs(dot_at(5, 3) - dot_at(10, 8)) < 1e-4
    assert abs(dot_at(7, 0) - dot_at(17, 10)) < 1e-4


def test_mrope_text_equals_rope():
    """With t == h == w == position, M-RoPE must reduce to plain RoPE."""
    key = jax.random.PRNGKey(0)
    B, S, H, hd = 2, 12, 2, 32
    x = jax.random.normal(key, (B, S, H, hd))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    plain = rope.apply_rope(x, pos, 1e4)
    sections = (4, 6, 6)
    m = rope.apply_mrope(x, rope.text_mrope_positions(pos), 1e4, sections)
    np.testing.assert_allclose(plain, m, atol=1e-5, rtol=1e-5)


def test_mrope_streams_differ():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, 4, 1, 32))
    pos = jnp.arange(4)[None]
    mp = rope.text_mrope_positions(pos)
    mp2 = mp.at[1].add(7)  # shift the height stream
    a = rope.apply_mrope(x, mp, 1e4, (4, 6, 6))
    b = rope.apply_mrope(x, mp2, 1e4, (4, 6, 6))
    assert not np.allclose(a, b)


# ---------------------------------------------------------------------------
# sharding specs
# ---------------------------------------------------------------------------


def test_param_specs_structure_and_divisibility():
    """Every spec matches its leaf's rank, and any sharded dim divides the
    production-mesh axis size — for all ten architectures."""
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.common import sharding as sh
    from repro.configs import ARCH_IDS, get_config
    from repro.models import transformer as tf

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    sizes = {"data": 16, "model": 16, "pod": 2}
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        shapes = jax.eval_shape(
            lambda cfg=cfg: tf.init_params(jax.random.PRNGKey(0), cfg))
        specs = sh.param_specs(cfg, FakeMesh())
        for (path, leaf), (_, spec) in zip(
                jax.tree_util.tree_flatten_with_path(shapes)[0],
                jax.tree_util.tree_flatten_with_path(
                    specs, is_leaf=lambda x: isinstance(x, P))[0]):
            assert isinstance(spec, P), (arch, path)
            assert len(spec) <= leaf.ndim, (arch, path, spec, leaf.shape)
            for dim, ax in enumerate(spec):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                total = int(np.prod([sizes[a] for a in axes]))
                assert leaf.shape[dim] % total == 0, (
                    arch, path, spec, leaf.shape)


def test_cache_specs_cover_cache_tree():
    from repro.common import sharding as sh
    from repro.configs import get_config
    from repro.models import transformer as tf

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    for arch in ("qwen2-1.5b", "hymba-1.5b", "whisper-small", "mamba2-130m"):
        cfg = get_config(arch)
        cap = 32768 if cfg.uses_attention else 0
        cache = jax.eval_shape(
            lambda cfg=cfg, cap=cap: tf.init_decode_cache(cfg, 128, cap,
                                                          fill_len=cap - 1
                                                          if cap else 0))
        specs = sh.cache_specs(cfg, FakeMesh(), 128, cap)
        jax.tree.map(lambda s, sp: None, cache, specs)  # structure matches
