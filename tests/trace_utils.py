"""Differential trace-testing harness for prefix-aware KV reuse.

The load-bearing assertion of the prompt cache: serving a randomized trace
through ``ContinuousEngine`` with the prefix cache **on** must emit
*bit-identical tokens and kept (layer, head, position) sets* per request
as serving the same trace with the cache **off** — for every servable
policy, across chunk sizes, including prompts not divisible by the chunk.

Helpers here are shared by ``tests/test_prefix_cache.py`` (and usable by
future suites): a seeded Zipf-prefix trace (wrapping
``repro.data.synthetic.make_prefix_trace`` into ``Request`` objects), a
single-engine trace runner that captures each request's admitted cache
(``capture_admission``), and the differential assertion itself.
"""

from __future__ import annotations

from typing import Optional

from repro.common.config import EvictionConfig
from repro.data.synthetic import make_prefix_trace
from repro.serving import (ContinuousEngine, PrefixCache, Request,
                           ServingConfig)

__all__ = ["make_trace_requests", "kept_sets", "run_trace",
           "assert_differential", "make_prefix_trace"]


def make_trace_requests(cfg, *, chunk, seed=0, n_requests=5, max_new=3,
                        **trace_kw) -> list[Request]:
    """Seeded randomized request trace: Zipf-shared chunk-aligned prefixes,
    mixed (non-divisible) prompt lengths, staggered Poisson arrivals."""
    trace = make_prefix_trace(seed, n_requests, cfg.vocab_size, chunk=chunk,
                              **trace_kw)
    return [Request(uid=i, prompt=p, max_new_tokens=max_new,
                    arrival_s=arr)
            for i, (p, arr) in enumerate(trace)]


def _clone(reqs: list[Request]) -> list[Request]:
    return [r.clone() for r in reqs]


def kept_sets(admission: dict) -> dict:
    """{(layer, head): frozenset(kept positions)} from a captured
    admission cache (batch axis is the single prefill row)."""
    m, p = admission["mask"], admission["pos"]
    L, _, _, KV = m.shape
    return {
        (lyr, h): frozenset(p[lyr, 0, m[lyr, 0, :, h], h].tolist())
        for lyr in range(L) for h in range(KV)
    }


def run_trace(cfg, params, lkv, *, policy, requests, chunk,
              prefix_cache: Optional[PrefixCache] = None, budget=8,
              num_slots=2, trace=None, drift=None, **engine_kw):
    """Serve a clone of ``requests``; returns ({uid: Request}, engine).

    By default ``max_context`` covers the whole trace so every request
    shares the engine's base KV-buffer rung — the standard-traffic
    configuration.  Pass ``max_context`` explicitly to exercise mixed
    rungs (the cache then only serves same-rung snapshots).  ``trace``
    (an ``obs.trace.TraceRecorder``) and ``drift`` (an
    ``obs.quality.DriftMonitor``) attach the observability layer — the
    span-invariant tests in ``tests/test_obs.py`` ride this harness."""
    max_new = max(r.max_new_tokens for r in requests)
    max_len = max(len(r.prompt) for r in requests)
    # ``engine_kw`` still uses the historical kwarg names; route them
    # through the same mapping the deprecation shim uses, but hand the
    # engine a ServingConfig (the supported API) — no warning emitted.
    # The obs fields are not legacy kwargs, so they land via ``replace``.
    sc = ServingConfig.from_legacy(
        policy=policy, evict=EvictionConfig(budget=budget),
        num_slots=num_slots, chunk=chunk,
        max_context=engine_kw.pop("max_context", max_len),
        max_new_tokens=max_new, eos_id=-1, prefix_cache=prefix_cache,
        capture_admission=True, **engine_kw)
    if trace is not None or drift is not None:
        sc = sc.replace(trace=trace, drift=drift)
    eng = ContinuousEngine(
        params, cfg, sc,
        lkv_params=lkv if policy == "lookaheadkv" else None)
    done = eng.run(_clone(requests))
    assert len(done) == len(requests)
    return {r.uid: r for r in done}, eng


def assert_differential(cfg, params, lkv, *, policy, requests, chunk,
                        cache_bytes=1 << 30, **kw):
    """The headline property: cache-on serving is observationally
    bit-identical to cache-off serving, request by request.  Returns
    (cache-on engine, cache) so callers can additionally assert hit
    counts, compile counts, or budget behaviour."""
    base, _ = run_trace(cfg, params, lkv, policy=policy, requests=requests,
                        chunk=chunk, prefix_cache=None, **kw)
    cache = PrefixCache(chunk=chunk, max_bytes=cache_bytes)
    got, eng = run_trace(cfg, params, lkv, policy=policy, requests=requests,
                         chunk=chunk, prefix_cache=cache, **kw)
    for uid, ref in base.items():
        r = got[uid]
        assert r.out_tokens == ref.out_tokens, \
            f"policy={policy} chunk={chunk} uid={uid}: tokens diverged"
        assert kept_sets(r.admission_cache) == kept_sets(
            ref.admission_cache), \
            f"policy={policy} chunk={chunk} uid={uid}: kept sets diverged"
    return eng, cache
