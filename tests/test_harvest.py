"""The learning loop end-to-end: trace harvest -> distillation -> trainer
checkpoint/resume -> serving load path.

* Harvested records carry exactly the frozen-model gt_oracle scores of the
  served prompt under its *generated* continuation (the future the oracle
  policy needs, captured at retirement).
* ``launch/train.py --harvest`` distills against those targets; a killed
  run (periodic ``--ckpt-every`` save, no final save) resumed with
  ``--resume`` finishes bit-identical to an uninterrupted run.
* ``ServingConfig.lkv_checkpoint`` loads the trained modules into
  ``ContinuousEngine`` and serves the lookaheadkv policy end-to-end,
  bit-identical to passing the same tree as ``lkv_params``.
"""

import jax
import numpy as np
import pytest

from repro.checkpoint import io as ckpt
from repro.common.config import EvictionConfig
from repro.configs import get_smoke_config
from repro.core import objective
from repro.core.lookahead import (init_lookahead_params,
                                  load_lookahead_params, lookahead_count)
from repro.data import harvest
from repro.launch import train as train_mod
from repro.models import transformer as tf
from repro.serving import (ChunkingConfig, ContinuousEngine, Request,
                           ServingConfig)

CHUNK = 16
MAX_NEW = 4
N_REQUESTS = 6


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def harvest_dir(model, tmp_path_factory):
    cfg, params = model
    out = str(tmp_path_factory.mktemp("harvest"))
    w = harvest.harvest_trace(params, cfg, out_dir=out, requests=N_REQUESTS,
                              policy="h2o", budget=32, chunk=CHUNK,
                              max_new=MAX_NEW, max_obs=MAX_NEW, num_slots=2,
                              seed=3)
    assert w.records_written == N_REQUESTS
    return out


def _train_argv(harvest_dir, ckpt_path, steps):
    return ["--arch", "smollm-135m", "--smoke", "--harvest", harvest_dir,
            "--steps", str(steps), "--batch", "2", "--seed", "1",
            "--ckpt", ckpt_path]


@pytest.fixture(scope="module")
def trained_ckpt(harvest_dir, tmp_path_factory):
    p = str(tmp_path_factory.mktemp("ckpt") / "lkv.npz")
    train_mod.main(_train_argv(harvest_dir, p, steps=3))
    return p


# ---------------------------------------------------------------------------
# harvest capture
# ---------------------------------------------------------------------------


def test_records_carry_gt_oracle_scores(model, harvest_dir):
    """Every stored score tensor equals the frozen-model oracle pass over
    [prompt; generated]: (L, H, n_in), rows scored by the *served* future."""
    cfg, params = model
    records = harvest.load_records(harvest_dir)
    assert len(records) == N_REQUESTS
    L = cfg.num_layers
    H = cfg.attn.num_heads
    for r in records:
        assert r["s"].shape == (L, H, len(r["x"]))
        assert 1 <= len(r["y"]) <= MAX_NEW
    r = records[0]
    import jax.numpy as jnp
    xy = jnp.asarray(np.concatenate([r["x"], r["y"]]))[None]
    s = np.asarray(objective.gt_scores(params, cfg, xy, len(r["x"]))[:, 0])
    np.testing.assert_allclose(r["s"], s, rtol=1e-5, atol=1e-7)


def test_iterator_is_deterministic(harvest_dir):
    a = harvest.HarvestIterator(harvest_dir, 2, seed=7)
    b = harvest.HarvestIterator(harvest_dir, 2, seed=7)
    for _ in range(4):
        ba, bb = next(a), next(b)
        assert ba["x"].shape[0] == 2
        assert ba["s_gt"].shape[1] == 2
        assert ba["s_gt"].shape[3] == ba["x"].shape[1]
        np.testing.assert_array_equal(ba["x"], bb["x"])
        np.testing.assert_array_equal(ba["s_gt"], bb["s_gt"])


def test_writer_appends_after_existing_shards(model, harvest_dir):
    """A second harvest into the same directory extends the dataset instead
    of clobbering shard_00000."""
    before = len(harvest.load_records(harvest_dir))
    cfg, params = model
    w = harvest.HarvestWriter(
        params, cfg, harvest.HarvestConfig(out_dir=harvest_dir, max_obs=4))
    rec = harvest.load_records(harvest_dir)[0]

    class _Req:
        prompt = rec["x"]
        out_tokens = [int(t) for t in rec["y"]]

    w.on_retire(_Req())
    w.flush()
    assert len(harvest.load_records(harvest_dir)) == before + 1


# ---------------------------------------------------------------------------
# distillation trainer: kill-and-resume
# ---------------------------------------------------------------------------


def test_kill_and_resume_is_bit_exact(harvest_dir, tmp_path):
    a = str(tmp_path / "straight.npz")
    b = str(tmp_path / "killed.npz")
    # uninterrupted 4-step run (--verify also gates loss decrease +
    # round-trip on the way)
    train_mod.main(_train_argv(harvest_dir, a, steps=4) + ["--verify"])
    # same run killed after step 2 (periodic save, no final save) ...
    train_mod.main(_train_argv(harvest_dir, b, steps=4)
                   + ["--ckpt-every", "2", "--stop-after", "2"])
    assert ckpt.metadata(b)["step"] == 2
    # ... then resumed: optimizer moments, step count and the data stream
    # all continue, so the final state matches bit-for-bit
    train_mod.main(_train_argv(harvest_dir, b, steps=4) + ["--resume"])
    fa, fb = ckpt.load(a), ckpt.load(b)
    assert set(fa) == set(fb)
    for k in fa:
        np.testing.assert_array_equal(np.asarray(fa[k]), np.asarray(fb[k]),
                                      err_msg=k)
    assert ckpt.metadata(b)["step"] == 4
    assert ckpt.metadata(b)["source"] == harvest_dir


# ---------------------------------------------------------------------------
# serving load path
# ---------------------------------------------------------------------------


def _serving_config(**over):
    base = dict(
        policy="lookaheadkv",
        evict=EvictionConfig(budget=24, draft_len=8),
        chunking=ChunkingConfig(chunk=CHUNK, max_context=64),
        num_slots=2, max_new_tokens=MAX_NEW, eos_id=-1)
    base.update(over)
    return ServingConfig(**base)


def _requests(cfg, seed=5):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, n).astype(np.int32),
                    max_new_tokens=MAX_NEW)
            for i, n in enumerate((40, 56, 24))]


def test_lkv_checkpoint_serves_end_to_end(model, trained_ckpt):
    cfg, params = model
    lkv = load_lookahead_params(trained_ckpt, cfg, params["layers"])
    assert lookahead_count(lkv) > 0
    # the engine loads the trained tree itself ...
    e1 = ContinuousEngine(params, cfg,
                          _serving_config(lkv_checkpoint=trained_ckpt))
    done1 = e1.run(_requests(cfg))
    # ... and serves bit-identically to the same tree passed directly
    e2 = ContinuousEngine(params, cfg, _serving_config(), lkv_params=lkv)
    done2 = e2.run(_requests(cfg))
    by_uid = {r.uid: r for r in done2}
    for r in done1:
        assert len(r.out_tokens) == MAX_NEW
        assert r.out_tokens == by_uid[r.uid].out_tokens, r.uid
    # the trained tree is not the random init
    init = init_lookahead_params(jax.random.PRNGKey(1), cfg,
                                 params["layers"])
    diffs = [not np.array_equal(np.asarray(a), np.asarray(b))
             for a, b in zip(jax.tree.leaves(lkv), jax.tree.leaves(init))]
    assert any(diffs)


def test_lkv_checkpoint_and_params_conflict(model, trained_ckpt):
    cfg, params = model
    lkv = load_lookahead_params(trained_ckpt, cfg, params["layers"])
    with pytest.raises(AssertionError):
        ContinuousEngine(params, cfg,
                         _serving_config(lkv_checkpoint=trained_ckpt),
                         lkv_params=lkv)


def test_load_lookahead_params_both_layouts(model, trained_ckpt, tmp_path):
    """Bare lkv trees (the old export) and trainer-state layouts load to
    the same tree."""
    cfg, params = model
    lkv = load_lookahead_params(trained_ckpt, cfg, params["layers"])
    bare = str(tmp_path / "bare.npz")
    ckpt.save(bare, jax.device_get(lkv))
    lkv2 = load_lookahead_params(bare, cfg, params["layers"])
    for a, b in zip(jax.tree.leaves(lkv), jax.tree.leaves(lkv2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
