"""Policy-level behaviour: every policy yields a valid budgeted cache; the
"full" policy's decode continuation matches an un-evicted reference; draft
policies (LAQ / SpecKV) compose; decode with evicted caches is causally
consistent (positions of kept slots are original prompt positions)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import EvictionConfig
from repro.configs import get_smoke_config
from repro.core import policies
from repro.core.lookahead import init_lookahead_params
from repro.models import transformer as tf


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("qwen2-1.5b")  # GQA + bias family
    key = jax.random.PRNGKey(0)
    params = tf.init_params(key, cfg)
    lkv = init_lookahead_params(jax.random.PRNGKey(1), cfg, params["layers"])
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 72), 0,
                                cfg.vocab_size)
    return cfg, params, lkv, tokens


ALL = ["full", "random", "streaming_llm", "snapkv", "pyramidkv", "tova",
       "h2o", "lookaheadkv", "laq"]


@pytest.mark.parametrize("policy", ALL)
def test_policy_produces_valid_cache(setup, policy):
    cfg, params, lkv, tokens = setup
    ev = EvictionConfig(budget=16, draft_len=4)
    res = policies.run_eviction(policy, params, cfg, tokens, evict=ev,
                                lkv_params=lkv, extra_slots=8)
    n = tokens.shape[1]
    cap = res.cache["attn"]["k"].shape[2]
    if policy == "full":
        assert cap == n + 8
    elif policy == "pyramidkv":
        assert cap <= int(2 * 2.0 / 3.0 * 16) + 1 + 8
    else:
        assert cap == 16 + 8
    pos = np.asarray(res.cache["attn"]["pos"])
    mask = np.asarray(res.cache["attn"]["mask"])
    assert ((pos < n) | ~mask).all()  # kept slots reference prompt positions
    # decode continues
    tok = jnp.argmax(res.logits, -1)[:, None]
    lg, _ = tf.decode_step(params, cfg, tok, res.cache)
    assert bool(jnp.isfinite(lg).all())


def test_full_policy_decode_matches_reference(setup):
    """Budget = everything => greedy continuation must equal the reference
    continuation computed by re-prefilling each step (slow oracle)."""
    cfg, params, _, tokens = setup
    res = policies.run_eviction("full", params, cfg, tokens,
                                evict=EvictionConfig(budget=0),
                                extra_slots=6)
    toks, _ = policies.greedy_decode(
        params, cfg, jnp.argmax(res.logits, -1)[:, None].astype(jnp.int32),
        res.cache, 5)
    # slow oracle: argmax from full re-prefill each step
    cur = tokens
    want = []
    for _ in range(5):
        r = tf.prefill(params, cfg, cur, want_logits="last")
        nxt = jnp.argmax(r.logits, -1)[:, None]
        want.append(int(nxt[0, 0]))
        cur = jnp.concatenate([cur, nxt.astype(cur.dtype)], axis=1)
    got = np.asarray(toks)[0, :5].tolist()
    assert got == want, (got, want)


def test_speckv_with_draft_model(setup):
    cfg, params, _, tokens = setup
    dcfg = get_smoke_config("tiny-llama")
    dparams = tf.init_params(jax.random.PRNGKey(9), dcfg)
    res = policies.run_eviction(
        "speckv", params, cfg, tokens, evict=EvictionConfig(budget=16,
                                                            draft_len=4),
        draft_params=dparams, draft_cfg=dcfg)
    assert res.cache["attn"]["k"].shape[2] == 16
    assert bool(jnp.isfinite(res.logits).all())


def test_draft_policies_return_boundary_logits(setup):
    """LAQ/SpecKV logits == the exact full-model next-token logits after X
    (prefill attention is exact; eviction only affects decode)."""
    cfg, params, _, tokens = setup
    want = tf.prefill(params, cfg, tokens, want_logits="last").logits
    res = policies.run_eviction("laq", params, cfg, tokens,
                                evict=EvictionConfig(budget=16, draft_len=4))
    np.testing.assert_allclose(res.logits, want, atol=2e-2, rtol=2e-2)


def test_sampled_decode_temperature_changes_tokens(setup):
    cfg, params, _, tokens = setup
    res = policies.run_eviction("full", params, cfg, tokens,
                                evict=EvictionConfig(), extra_slots=10)
    t0, _ = policies.sample_decode(params, cfg, res.logits, res.cache, 8,
                                   temperature=0.0)
    t1, _ = policies.sample_decode(params, cfg, res.logits, res.cache, 8,
                                   temperature=5.0,
                                   key=jax.random.PRNGKey(3))
    assert t0.shape == t1.shape == (2, 8)
    assert not np.array_equal(np.asarray(t0), np.asarray(t1))
