"""Per-architecture smoke tests (deliverable f): a reduced same-family
variant of each assigned config runs one forward + one train step on CPU,
asserting output shapes and finiteness."""

import jax
import jax.numpy as jnp
import pytest

from repro.common.config import EvictionConfig, TrainConfig
from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.core import objective
from repro.core.lookahead import init_lookahead_params
from repro.models import transformer as tf
from repro.optim import adam


def _inputs(cfg, key, B=2, S=48):
    if cfg.embeds_in:
        return jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    return jax.random.randint(key, (B, S), 0, cfg.vocab_size)


def _kw(cfg, key, B=2):
    if cfg.is_encoder_decoder:
        return {"encoder_embeds": jax.random.normal(
            key, (B, cfg.encoder.num_frames, cfg.d_model), jnp.float32)}
    return {}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_decode(arch):
    cfg = get_smoke_config(arch)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    key = jax.random.PRNGKey(0)
    params = tf.init_params(key, cfg)
    B, S = 2, 48
    x = _inputs(cfg, key, B, S)
    kw = _kw(cfg, key, B)
    if cfg.technique_applies and cfg.lookahead:
        lkv = init_lookahead_params(key, cfg, params["layers"])
        res = tf.prefill(params, cfg, x, lkv_params=lkv, policy="lookaheadkv",
                         evict=EvictionConfig(budget=16), extra_slots=4, **kw)
        assert res.cache["attn"]["k"].shape[:3] == (cfg.num_layers, B, 20)
    else:
        res = tf.prefill(params, cfg, x, want_ssm_cache=True, **kw)
    assert res.logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(res.logits).all())
    tok = jnp.argmax(res.logits, -1)[:, None]
    lg, cache = tf.decode_step(params, cfg, tok, res.cache)
    assert lg.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(lg).all())
    assert int(cache["next_pos"][0, 0]) == S + 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(1)
    params = tf.init_params(key, cfg)
    tc = TrainConfig(steps=10, lr=1e-3)
    B, n_in, n_out = 2, 40, 8
    kw = _kw(cfg, key, B)
    if not cfg.technique_applies:
        tokens = jax.random.randint(key, (B, n_in), 0, cfg.vocab_size)

        def loss_fn(p):
            return objective.lm_loss(p, cfg, tokens)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        opt = adam.init(params)
        new_params, opt, m = adam.update(params, grads, opt, tc)
        assert bool(jnp.isfinite(loss))
        assert float(m["grad_norm"]) > 0
        return

    lkv = init_lookahead_params(key, cfg, params["layers"])
    if cfg.embeds_in:
        x = _inputs(cfg, key, B, n_in)
        y = jax.random.randint(key, (B, n_out), 0, cfg.vocab_size)
        y_emb = jnp.take(params["embed"], y, axis=0)
        xy = jnp.concatenate([x.astype(y_emb.dtype), y_emb], axis=1)

        def loss_fn(l):
            s_gt = objective.gt_scores(params, cfg, xy, n_in, **kw)
            s_lkv = objective.lookahead_scores(params, cfg, l, x, **kw)
            from repro.core.scoring import normalize_l1

            return objective.kl_divergence(
                normalize_l1(s_gt), normalize_l1(s_lkv)).mean()

    else:
        x = jax.random.randint(key, (B, n_in), 0, cfg.vocab_size)
        xy = jnp.concatenate(
            [x, jax.random.randint(key, (B, n_out), 0, cfg.vocab_size)], 1)

        def loss_fn(l):
            return objective.lkv_loss(params, cfg, l, x, xy, n_in, **kw)[0]

    loss, grads = jax.value_and_grad(loss_fn)(lkv)
    assert bool(jnp.isfinite(loss)) and float(loss) >= 0
    opt = adam.init(lkv)
    new_lkv, opt, m = adam.update(lkv, grads, opt, tc)
    assert float(m["grad_norm"]) > 0
    # something actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        lkv, new_lkv)
    assert max(jax.tree.leaves(moved)) > 0


def test_full_configs_match_assignment():
    """Exact assigned hyper-parameters (the public-pool table)."""
    spec = {
        "mamba2-130m": dict(num_layers=24, d_model=768, d_ff=0,
                            vocab_size=50280),
        "smollm-135m": dict(num_layers=30, d_model=576, d_ff=1536,
                            vocab_size=49152),
        "deepseek-moe-16b": dict(num_layers=28, d_model=2048, d_ff=1408,
                                 vocab_size=102400),
        "phi3.5-moe-42b-a6.6b": dict(num_layers=32, d_model=4096, d_ff=6400,
                                     vocab_size=32064),
        "minitron-8b": dict(num_layers=32, d_model=4096, d_ff=16384,
                            vocab_size=256000),
        "qwen2-vl-72b": dict(num_layers=80, d_model=8192, d_ff=29568,
                             vocab_size=152064),
        "gemma3-1b": dict(num_layers=26, d_model=1152, d_ff=6912,
                          vocab_size=262144),
        "qwen2-1.5b": dict(num_layers=28, d_model=1536, d_ff=8960,
                           vocab_size=151936),
        "whisper-small": dict(num_layers=12, d_model=768, d_ff=3072,
                              vocab_size=51865),
        "hymba-1.5b": dict(num_layers=32, d_model=1600, d_ff=5504,
                           vocab_size=32001),
    }
    heads = {
        "smollm-135m": (9, 3), "deepseek-moe-16b": (16, 16),
        "phi3.5-moe-42b-a6.6b": (32, 8), "minitron-8b": (32, 8),
        "qwen2-vl-72b": (64, 8), "gemma3-1b": (4, 1), "qwen2-1.5b": (12, 2),
        "whisper-small": (12, 12), "hymba-1.5b": (25, 5),
    }
    for arch, want in spec.items():
        cfg = get_config(arch)
        for k, v in want.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
        if arch in heads:
            assert (cfg.attn.num_heads, cfg.attn.num_kv_heads) == heads[arch]
        assert cfg.source, arch
    assert get_config("mamba2-130m").ssm.d_state == 128
    assert get_config("hymba-1.5b").ssm.d_state == 16
    assert get_config("deepseek-moe-16b").moe.num_experts == 64
    assert get_config("deepseek-moe-16b").moe.top_k == 6
    assert get_config("deepseek-moe-16b").moe.num_shared_experts == 2
    assert get_config("phi3.5-moe-42b-a6.6b").moe.num_experts == 16
    assert get_config("phi3.5-moe-42b-a6.6b").moe.top_k == 2
    assert get_config("gemma3-1b").attn.global_every == 6  # 5:1 local:global
    assert get_config("qwen2-1.5b").attn.qkv_bias
    assert get_config("qwen2-vl-72b").attn.mrope
