"""Fused streaming score accumulation: the attention kernels emit the
eviction-score partials themselves.

Three layers of coverage:

* kernel level — ``chunk_attention_masses_pallas`` (interpret mode) against
  the dense ``ref.chunk_column_masses`` oracle across masked (padded) rows,
  non-divisible prompt lengths and chunk sizes {128, 256}, with the fused
  attention output bit-equal to the unfused kernel;
* dispatch level — ``ops.chunk_attention(score_masses=True)`` and the
  ``ops.lookahead_score`` row-validity / traced-offset / window extensions
  on both the jnp fallback and the ``REPRO_FORCE_PALLAS=1`` interpret path,
  including the large-buffer streaming jnp fallback;
* pipeline level — kept sets stay bit-equal chunked-vs-monolithic for every
  single-pass policy now that scores ride the fused kernel outputs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import EvictionConfig
from repro.configs import get_smoke_config
from repro.core import policies
from repro.core.lookahead import init_lookahead_params
from repro.kernels import ops, ref
from repro.kernels.chunk_attention import (chunk_attention_masses_pallas,
                                           chunk_attention_pallas)
from repro.kernels.lookahead_score import lookahead_score_pallas
from repro.models import transformer as tf


def _case(B=2, C=32, K=96, H=6, KV=2, hd=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, C, H, hd))
    k = jax.random.normal(ks[1], (B, K, KV, hd))
    v = jax.random.normal(ks[2], (B, K, KV, hd))
    return q, k, v


# ---------------------------------------------------------------------------
# kernel level
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("C", [128, 256])
@pytest.mark.parametrize("off,n_total", [
    (0, 300),     # first chunk, everything valid
    (256, 300),   # partial final chunk: rows past 300 are masked
    (128, 140),   # nearly empty chunk: 12 valid rows
])
def test_fused_masses_match_dense_oracle(C, off, n_total):
    """Masses across chunk sizes {128, 256}, non-divisible prompt lengths
    and masked pad rows; the attention output is bit-equal to the unfused
    kernel (phase 0 is the identical recurrence)."""
    q, k, v = _case(B=1, C=C, K=384, H=4, KV=2, hd=16, seed=C + off)
    offs = jnp.asarray(off, jnp.int32)
    nt = jnp.asarray(n_total, jnp.int32)
    out, masses = chunk_attention_masses_pallas(q, k, v, offs, nt,
                                                block_k=64, interpret=True)
    plain = chunk_attention_pallas(q, k, v, offs, block_k=64, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(plain))
    rv = jnp.broadcast_to((off + jnp.arange(C))[None] < nt, (1, C))
    want = ref.chunk_column_masses(q, k, q_offset=offs, row_valid=rv)
    np.testing.assert_allclose(np.asarray(masses), np.asarray(want),
                               atol=2e-6, rtol=2e-6)
    # pad rows contribute exactly nothing: columns only they could see are 0
    n_vis = min(off + C, n_total)
    assert np.all(np.asarray(masses)[..., n_vis:] == 0.0)


def test_fused_masses_windowed_and_traced():
    """Sliding-window masses under a traced offset (the serving path jits
    the chunk program with the offset as an argument)."""
    q, k, v = _case(seed=7)
    fn = jax.jit(lambda q, k, v, o, n: chunk_attention_masses_pallas(
        q, k, v, o, n, window=24, block_k=32, interpret=True))
    off, nt = jnp.asarray(40, jnp.int32), jnp.asarray(60, jnp.int32)
    _, masses = fn(q, k, v, off, nt)
    rv = jnp.broadcast_to((40 + jnp.arange(32))[None] < 60, (2, 32))
    want = ref.chunk_column_masses(q, k, q_offset=40, window=24, row_valid=rv)
    np.testing.assert_allclose(np.asarray(masses), np.asarray(want),
                               atol=2e-6, rtol=2e-6)


# ---------------------------------------------------------------------------
# dispatch level (ops)
# ---------------------------------------------------------------------------


def test_ops_chunk_attention_masses_jnp_and_pallas(monkeypatch):
    """The public wrapper returns the same (out, masses) on the jnp
    fallback and the forced-Pallas interpret path."""
    q, k, v = _case(seed=3)
    off, nt = jnp.asarray(32, jnp.int32), jnp.asarray(50, jnp.int32)
    out_j, m_j = ops.chunk_attention(q, k, v, q_offset=off,
                                     score_masses=True, n_total=nt)
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    out_p, m_p = ops.chunk_attention(q, k, v, q_offset=off,
                                     score_masses=True, n_total=nt)
    np.testing.assert_allclose(np.asarray(out_j), np.asarray(out_p),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(m_j), np.asarray(m_p),
                               atol=2e-6, rtol=2e-6)


def test_ops_chunk_attention_masses_streaming_fallback():
    """Buffers past the direct-path threshold take the two-pass streaming
    jnp fallback — no (C, K) probability block — and still match dense."""
    K = ops._DIRECT_SEQ + 256
    q, k, v = _case(B=1, C=8, K=K, H=2, KV=1, hd=16, seed=5)
    off = jnp.asarray(K - 8, jnp.int32)
    nt = jnp.asarray(K - 3, jnp.int32)  # 5 valid rows, 3 masked
    out, masses = ops.chunk_attention(q, k, v, q_offset=off,
                                      score_masses=True, n_total=nt,
                                      block_k=512)
    rv = jnp.broadcast_to(((K - 8) + jnp.arange(8))[None] < nt, (1, 8))
    want = ref.chunk_column_masses(q, k, q_offset=off, row_valid=rv)
    np.testing.assert_allclose(np.asarray(masses), np.asarray(want),
                               atol=1e-5, rtol=1e-4)


@pytest.mark.parametrize("window", [None, 24])
def test_lookahead_score_row_validity_parity(window):
    """The masked streaming primitive: random row-validity masks, a traced
    observation base and a sliding window agree with the dense oracle on
    the Pallas interpret path and the streaming jnp fallback."""
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    B, n_obs, H, KV, hd, Sk = 2, 16, 4, 2, 16, 96
    qo = jax.random.normal(ks[0], (B, n_obs, H, hd))
    k = jax.random.normal(ks[1], (B, Sk, KV, hd))
    rv = jax.random.bernoulli(ks[2], 0.6, (B, n_obs))
    off = jnp.asarray(48, jnp.int32)  # traced, != default n_prompt base
    want = ref.lookahead_score(qo, k, Sk, q_offset=off, window=window,
                               row_valid=rv)
    got = jax.jit(lambda qo, k, off: lookahead_score_pallas(
        qo, k, Sk, q_offset=off, window=window, row_valid=rv,
        block_k=32, interpret=True))(qo, k, off)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-6, rtol=2e-5)
    got2 = ops._chunked_lookahead_score(qo, k, Sk, kv_mask=None,
                                        window=window, q_offset=off,
                                        row_valid=rv, block_k=32)
    np.testing.assert_allclose(np.asarray(got2), np.asarray(want),
                               atol=2e-6, rtol=2e-5)


def test_lookahead_score_all_valid_matches_unmasked():
    """row_valid=None and an all-True mask are the same computation."""
    ks = jax.random.split(jax.random.PRNGKey(13), 2)
    B, n_obs, H, KV, hd, Sk = 1, 8, 2, 1, 16, 64
    qo = jax.random.normal(ks[0], (B, n_obs, H, hd))
    k = jax.random.normal(ks[1], (B, Sk, KV, hd))
    base = lookahead_score_pallas(qo, k, Sk - n_obs, block_k=32,
                                  interpret=True)
    masked = lookahead_score_pallas(qo, k, Sk - n_obs,
                                    row_valid=jnp.ones((B, n_obs), bool),
                                    block_k=32, interpret=True)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(masked))


# ---------------------------------------------------------------------------
# pipeline level: kept-set regression over every single-pass policy
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    lkv = init_lookahead_params(jax.random.PRNGKey(1), cfg, params["layers"])
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 80))
                       .astype(np.int32))
    return cfg, params, lkv, toks


def _kept(cache):
    m = np.asarray(cache["attn"]["mask"])
    p = np.asarray(cache["attn"]["pos"])
    L, B, _, KV = m.shape
    return {
        (lyr, b, h): frozenset(p[lyr, b, m[lyr, b, :, h], h].tolist())
        for lyr in range(L) for b in range(B) for h in range(KV)
    }


@pytest.mark.parametrize("policy", policies.SINGLE_PASS)
def test_kept_sets_bit_equal_every_single_pass_policy(model, policy):
    """The non-negotiable invariant of the fused refactor: chunked prefill
    (kernel-emitted scores) evicts exactly like monolithic prefill for
    every single-pass policy, including gt_oracle's deferred Y suffix."""
    cfg, params, lkv, toks = model
    ev = EvictionConfig(budget=8)
    seeds = jnp.asarray([3], jnp.int32)
    gt_boundary = 64 if policy == "gt_oracle" else None
    if policy == "gt_oracle":
        mono = tf.prefill(params, cfg, toks, policy="gt_oracle",
                          gt_boundary=gt_boundary, evict=ev, extra_slots=2)
    else:
        mono = policies.run_eviction(
            policy, params, cfg, toks, evict=ev,
            lkv_params=lkv if policy == "lookaheadkv" else None,
            extra_slots=2, seeds=seeds)
    chunked = policies.run_eviction_chunked(
        policy, params, cfg, toks, chunk=32, evict=ev,
        lkv_params=lkv if policy == "lookaheadkv" else None,
        gt_boundary=gt_boundary, extra_slots=2, seeds=seeds)
    assert _kept(mono.cache) == _kept(chunked.cache)
