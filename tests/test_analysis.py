"""Unit tests for the roofline machinery: jaxpr FLOP counting (scan-aware),
collective-byte parsing, component cost model sanity."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import analysis


def test_jaxpr_cost_counts_matmul_exactly():
    def f(a, b):
        return a @ b

    jx = jax.make_jaxpr(f)(jnp.zeros((64, 128)), jnp.zeros((128, 32)))
    c = analysis.jaxpr_cost(jx.jaxpr)
    assert c["flops"] == 2 * 64 * 128 * 32


def test_jaxpr_cost_multiplies_scan_length():
    def f(x, w):
        def body(h, wi):
            return h @ wi, None

        h, _ = jax.lax.scan(body, x, w)
        return h

    jx = jax.make_jaxpr(f)(jnp.zeros((32, 32)), jnp.zeros((7, 32, 32)))
    c = analysis.jaxpr_cost(jx.jaxpr)
    assert c["flops"] == 7 * 2 * 32 ** 3  # XLA cost_analysis would say 1/7th


def test_jaxpr_cost_recurses_pjit():
    @jax.jit
    def inner(a, b):
        return a @ b

    def f(a, b):
        return inner(a, b) + inner(a, b)

    jx = jax.make_jaxpr(f)(jnp.zeros((16, 16)), jnp.zeros((16, 16)))
    c = analysis.jaxpr_cost(jx.jaxpr)
    assert c["flops"] == 2 * 2 * 16 ** 3


def test_collective_parse_synthetic_hlo():
    hlo = """
HloModule m
%fused (x: f32[]) -> f32[] {
  ROOT %y = f32[] add(%x, %x)
}
ENTRY %main () -> f32[2,4] {
  %ag = bf16[8,128]{1,0} all-gather(bf16[1,128]{1,0} %p0), dimensions={0}
  %ar = f32[2,4]{1,0} all-reduce(f32[2,4]{1,0} %p1), to_apply=%fused
  %rs.1 = f32[16]{0} reduce-scatter(f32[128]{0} %p2), dimensions={0}
  %cp = u32[4]{0} collective-permute(u32[4]{0} %p3)
}
"""
    out = analysis.collective_bytes(hlo)
    assert out["all-gather"] == 8 * 128 * 2
    assert out["all-reduce"] == 2 * 4 * 4
    assert out["reduce-scatter"] == 16 * 4
    assert out["collective-permute"] == 4 * 4
    assert out["total"] == sum(
        out[c] for c in ("all-gather", "all-reduce", "reduce-scatter",
                         "all-to-all", "collective-permute"))


def test_collective_loop_multiplier():
    hlo = """
ENTRY %main () -> f32[] {
  %ar0 = f32[10]{0} all-reduce(f32[10]{0} %p0)
}
%while_body_1 (p: f32[]) -> f32[] {
  %ar1 = f32[10]{0} all-reduce(f32[10]{0} %p1)
}
"""
    out = analysis.collective_bytes_with_loops(hlo, loop_multiplier=5)
    assert out["all-reduce"] == 10 * 4 + 5 * 10 * 4


def test_component_costs_expose_replication():
    """qwen2-1.5b: 12 heads don't divide model=16 => attention replicated;
    d_ff=8960 divides => MLP sharded."""
    from repro.configs import get_config

    cfg = get_config("qwen2-1.5b")
    comps = analysis.component_costs(cfg, "prefill", 32, 32768,
                                     {"data": 16, "model": 16})
    assert comps["attn_quadratic"]["model_shards"] == 1
    assert comps["attn_proj"]["model_shards"] == 1
    assert comps["mlp"]["model_shards"] == 16
    assert comps["logits"]["model_shards"] == 16  # padded vocab shards
    # minitron's 32 heads divide
    cfg2 = get_config("minitron-8b")
    comps2 = analysis.component_costs(cfg2, "prefill", 32, 32768,
                                      {"data": 16, "model": 16})
    assert comps2["attn_quadratic"]["model_shards"] == 16


def test_sparse_moe_cuts_component_flops():
    import dataclasses

    from repro.configs import get_config

    cfg = get_config("phi3.5-moe-42b-a6.6b")
    mesh = {"data": 16, "model": 16}
    dense = analysis.component_costs(cfg, "train", 256, 4096, mesh)
    sparse_cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch="sparse"))
    sparse = analysis.component_costs(sparse_cfg, "train", 256, 4096, mesh)
    ratio = dense["moe_experts"]["flops"] / sparse["moe_experts"]["flops"]
    assert abs(ratio - cfg.moe.num_experts
               / (cfg.moe.top_k * cfg.moe.capacity_factor)) < 1e-6


def test_roofline_terms_bottleneck():
    rl = analysis.roofline_terms(
        arch="x", shape="y", mesh="pod", chips=256,
        hlo_flops_per_dev=197e12,  # exactly 1s of compute
        hlo_bytes_per_dev=819e9 / 2,  # 0.5s memory
        coll_bytes_per_dev=50e9 / 4,  # 0.25s collective
        model_flops_global=197e12 * 256 / 2,
    )
    assert rl.bottleneck == "compute"
    assert abs(rl.compute_s - 1.0) < 1e-9
    assert abs(rl.useful_flop_ratio - 0.5) < 1e-9


def test_model_flops_moe_uses_active_params():
    from repro.configs import get_config

    cfg = get_config("deepseek-moe-16b")
    assert cfg.active_params() < 0.35 * cfg.num_params()
    mf = analysis.model_flops(cfg, "train", 1000)
    assert mf == 6.0 * cfg.active_params() * 1000
