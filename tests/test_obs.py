"""Observability layer: registry semantics, export formats, span
invariants over served traces, preemption/replay linkage, the deprecated
legacy stats view, and drift-monitor parity.

Layers, least to most end-to-end:

1. **Registry units** (no model): typed counter/gauge/histogram/info
   semantics, label series, get-or-create with kind mismatch failing
   loudly, per-run ``reset`` that zeroes written series but preserves
   callback gauges, Prometheus text exposition and JSON snapshot.
2. **Validator units** (no model): ``validate_trace`` rejects unclosed,
   crossed, and time-travelling span streams.
3. **Span invariants** (served): a traced run through the
   ``tests/trace_utils.py`` harness yields a well-nested, closed,
   monotone trace in which every request closes a complete span tree —
   one ``prefill_chunk`` per prompt chunk, ``finalize``, ``first_token``,
   ``decode``, outcome ``done`` — and jit compiles land on the engine
   track.
4. **Replay linkage** (served, tiny pool): a preempted request's spans
   close with outcome ``preempted`` and its re-serve opens a fresh
   ``request`` span whose ``replay_of`` names the original admission.
5. **Legacy view**: ``engine.stats`` still reads like the old dict but
   warns ``DeprecationWarning`` and mirrors the registry exactly.
6. **Drift monitor**: sampling rules (stride, short-prompt skip), and
   the streaming overlap equalling an offline recomputation from raw
   ``objective`` calls on the same records.
"""

import math
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import objective
from repro.core.lookahead import init_lookahead_params
from repro.models import transformer as tf
from repro.obs import (DriftMonitor, MetricsRegistry, TraceRecorder,
                       kept_overlaps, phase_table, request_span_trees,
                       validate_trace)
from repro.obs.metrics import bind_stat_gauges
from repro.serving import KVBlockPool
from trace_utils import make_trace_requests, run_trace


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    lkv = init_lookahead_params(jax.random.PRNGKey(1), cfg, params["layers"])
    return cfg, params, lkv


# ---------------------------------------------------------------------------
# 1. registry units
# ---------------------------------------------------------------------------


def test_counter_only_goes_up():
    reg = MetricsRegistry()
    c = reg.counter("requests_total", "Requests.", labelnames=("path",))
    c.inc(path="dense")
    c.inc(2, path="dense")
    c.inc(path="paged")
    assert c.value(path="dense") == 3
    assert c.value(path="paged") == 1
    with pytest.raises(ValueError):
        c.inc(-1, path="dense")
    with pytest.raises(ValueError):  # wrong label set fails loudly
        c.inc(mesh="x")


def test_gauge_set_inc_max_and_callback():
    reg = MetricsRegistry()
    g = reg.gauge("depth", "Queue depth.")
    g.set(3)
    g.inc(2)
    assert g.value() == 5
    g.max(4)
    assert g.value() == 5, "max keeps the running high water"
    g.max(9)
    assert g.value() == 9
    state = {"n": 7}
    live = reg.gauge("live", "Live mirror.")
    live.set_fn(lambda: state["n"])
    assert live.value() == 7
    state["n"] = 11
    assert live.value() == 11, "callback gauges read at collection time"


def test_reset_preserves_callbacks_and_info():
    reg = MetricsRegistry()
    reg.counter("c").inc(5)
    reg.gauge("g").set(5)
    state = {"n": 3}
    reg.gauge("live").set_fn(lambda: state["n"])
    reg.histogram("h").observe(0.2)
    reg.info("build").set(path="kernel")
    reg.reset()
    assert reg.value("c") == 0
    assert reg.value("g") == 0
    assert reg.value("live") == 3, "live mirrors survive the run boundary"
    assert reg.get("h").count() == 0
    assert reg.value("build") == {"path": "kernel"}


def test_get_or_create_and_kind_mismatch():
    reg = MetricsRegistry()
    a = reg.counter("x", "first")
    assert reg.counter("x") is a, "re-registration returns the metric"
    with pytest.raises(TypeError):
        reg.gauge("x")
    assert "x" in reg and "y" not in reg
    assert reg.value("never_registered", default=-1) == -1


def test_histogram_buckets_cumulative():
    reg = MetricsRegistry()
    h = reg.histogram("lat", "Latency.", buckets=(0.01, 0.1, 1.0))
    for v in (0.005, 0.05, 0.05, 0.5, 5.0):
        h.observe(v)
    val = h.collect()["values"]["lat"]
    assert val["buckets"] == {"0.01": 1, "0.1": 3, "1.0": 4, "+Inf": 5}
    assert val["count"] == 5
    assert math.isclose(val["sum"], 5.605)


def test_prometheus_text_and_json_snapshot(tmp_path):
    reg = MetricsRegistry()
    reg.counter("req_total", "Total requests.").inc(3)
    reg.histogram("lat_s", "Latency.", buckets=(0.1, 1.0)).observe(0.05)
    reg.info("build", "Build info.").set(mesh="none", path="kernel")
    text = reg.prometheus_text()
    assert "# HELP req_total Total requests." in text
    assert "# TYPE req_total counter" in text
    assert "req_total 3" in text
    assert 'lat_s_bucket{le="0.1"} 1' in text
    assert 'lat_s_bucket{le="+Inf"} 1' in text
    assert "lat_s_sum 0.05" in text and "lat_s_count 1" in text
    assert 'build_info{mesh="none",path="kernel"} 1' in text
    out = tmp_path / "metrics.json"
    reg.to_json(str(out))
    import json
    snap = json.loads(out.read_text())
    assert snap["req_total"]["kind"] == "counter"
    assert snap["req_total"]["values"]["req_total"] == 3


def test_bind_stat_gauges_numeric_only():
    reg = MetricsRegistry()
    state = {"hits": 2, "rate": 0.5, "enabled": True, "keys": [1, 2],
             "path": "kernel"}
    bound = bind_stat_gauges(reg, "comp", lambda: state)
    assert sorted(bound) == ["hits", "rate"], \
        "bools, lists and strings stay out of the numeric mirror"
    assert reg.value("comp_hits") == 2
    state["hits"] = 9
    assert reg.value("comp_hits") == 9


# ---------------------------------------------------------------------------
# 2. validator units
# ---------------------------------------------------------------------------


def _ev(ph, name, ts, tid="t"):
    return {"name": name, "ph": ph, "ts": ts, "tid": tid, "args": {}}


def test_validate_trace_accepts_well_nested():
    events = [_ev("B", "a", 0), _ev("B", "b", 1), _ev("i", "x", 2),
              _ev("E", "b", 3), _ev("E", "a", 4)]
    assert validate_trace(events) == {"tracks": 1, "spans": 2, "events": 5}


def test_validate_trace_rejects_violations():
    with pytest.raises(AssertionError):  # unclosed
        validate_trace([_ev("B", "a", 0)])
    with pytest.raises(AssertionError):  # crossed
        validate_trace([_ev("B", "a", 0), _ev("B", "b", 1),
                        _ev("E", "a", 2), _ev("E", "b", 3)])
    with pytest.raises(AssertionError):  # time travel
        validate_trace([_ev("B", "a", 5), _ev("E", "a", 1)])
    with pytest.raises(AssertionError):  # end with nothing open
        validate_trace([_ev("E", "a", 0)])


# ---------------------------------------------------------------------------
# 3. span invariants over a served trace
# ---------------------------------------------------------------------------


def _walk(node):
    yield node
    for c in node["children"]:
        yield from _walk(c)


def test_span_invariants_over_served_trace(model):
    cfg, params, lkv = model
    chunk = 64
    reqs = make_trace_requests(cfg, chunk=chunk, seed=0, n_requests=5,
                               max_new=3)
    rec = TraceRecorder()
    got, eng = run_trace(cfg, params, lkv, policy="lookaheadkv",
                         requests=reqs, chunk=chunk, trace=rec)
    summary = validate_trace(rec)  # raises on nesting/closure/monotone
    assert summary["tracks"] == len(reqs) + 1  # engine + one per request
    seqs = []
    for uid, r in got.items():
        trees = request_span_trees(rec, uid)
        assert len(trees) == 1, "no pool -> no replays"
        tree = trees[0]
        assert tree["name"] == "request"
        assert tree["args"]["n_prompt"] == len(r.prompt)
        assert tree["end_args"]["outcome"] == "done"
        seqs.append(tree["args"]["admission_seq"])
        names = [n["name"] for n in _walk(tree)]
        assert names.count("prefill_chunk") == math.ceil(
            len(r.prompt) / chunk), "one span per prompt chunk"
        assert "finalize" in names and "decode" in names
        instants = [i["name"] for n in _walk(tree) for i in n["instants"]]
        assert "first_token" in instants and "retire" in instants
    assert sorted(seqs) == list(range(len(reqs))), \
        "admission sequence numbers the serve attempts densely"
    # engine-track work: decode chunks spanned, fresh-engine compiles
    # surfaced as instants (the ChunkCompileCache proxy)
    eng_names = {e["name"] for e in rec.events if e["tid"] == rec.ENGINE}
    assert "decode_chunk" in eng_names
    assert "jit_compile" in eng_names
    # the trace was captured with device-synced timers (the default when
    # tracing), and the chrome export records that
    assert rec.sync and rec.chrome_trace()["otherData"]["sync_timers"]
    rows = {row["uid"]: row for row in phase_table(rec, got)}
    for uid, r in got.items():
        row = rows[uid]
        assert row["outcome"] == "done" and row["replays"] == 0
        assert row["prefill_ms"] > 0
        assert row["first_token_ms"] is not None
        assert row["decode_ms"] > 0


def test_preempted_request_carries_replay_linkage(model):
    cfg, params, lkv = model
    chunk = 128
    reqs = make_trace_requests(cfg, chunk=chunk, seed=5, n_requests=6,
                               max_new=8, suffix_lens=(0, 1, 77))
    for r in reqs:
        r.arrival_s = 0.0
    # the tiny-pool burst from test_kv_pool: admits optimistically, must
    # preempt mid-decode when the pool cannot cover every growth
    pool = KVBlockPool(cfg, block_size=4, num_blocks=7)
    rec = TraceRecorder()
    got, eng = run_trace(cfg, params, lkv, policy="streaming_llm",
                         requests=reqs, chunk=chunk, num_slots=3,
                         decode_chunk=1, kv_pool=pool,
                         reserve_appends=False, trace=rec)
    validate_trace(rec)
    assert eng.metrics.value("serving_preemptions_total") > 0
    preempted = 0
    for uid in got:
        trees = request_span_trees(rec, uid)
        assert trees and trees[-1]["end_args"]["outcome"] == "done"
        first_seq = trees[0]["args"]["admission_seq"]
        assert "replay_of" not in trees[0]["args"]
        for later in trees[1:]:
            assert later["args"]["replay_of"] == first_seq, \
                "every re-serve names its original admission"
        for tree in trees[:-1]:
            assert tree["end_args"]["outcome"] in ("preempted",
                                                   "admission_blocked")
            if tree["end_args"]["outcome"] == "preempted":
                preempted += 1
                instants = [i["name"] for n in _walk(tree)
                            for i in n["instants"]]
                assert "preempt" in instants
    assert preempted > 0, "the tiny pool must actually preempt a decode"
    rows = phase_table(rec, got)
    assert any(row["replays"] > 0 for row in rows)


# ---------------------------------------------------------------------------
# 5. legacy stats view
# ---------------------------------------------------------------------------


def test_legacy_stats_view_warns_and_mirrors_registry(model):
    cfg, params, lkv = model
    reqs = make_trace_requests(cfg, chunk=64, seed=1, n_requests=3,
                               max_new=2)
    got, eng = run_trace(cfg, params, lkv, policy="lookaheadkv",
                         requests=reqs, chunk=64)
    with pytest.warns(DeprecationWarning, match="engine.metrics"):
        s = eng.stats
    assert s["decode_steps"] == eng.metrics.value(
        "serving_decode_steps_total")
    assert s["decode_chunks"] == eng.metrics.value(
        "serving_decode_chunks_total")
    assert s["max_concurrency"] == eng.metrics.value(
        "serving_max_concurrency")
    assert s["decode_path"] == eng.metrics.value("serving_build")[
        "decode_path"]
    assert s["decode_time_s"] == pytest.approx(
        eng.metrics.value("serving_decode_seconds_total"))
    with pytest.raises(TypeError):  # a *view*: reads only
        s["decode_steps"] = 0
    assert "prefill_chunks" in dict(s)


def test_legacy_stats_view_empty_before_first_run(model):
    cfg, params, lkv = model
    from repro.serving import ContinuousEngine, ServingConfig
    eng = ContinuousEngine(params, cfg, ServingConfig(num_slots=1),
                           lkv_params=lkv)
    with pytest.warns(DeprecationWarning):
        assert dict(eng.stats) == {}, "the historical pre-run shape"


# ---------------------------------------------------------------------------
# 6. drift monitor
# ---------------------------------------------------------------------------


def _fake_req(prompt_len, out_len, seed=0):
    rng = np.random.default_rng(seed)
    return SimpleNamespace(
        prompt=rng.integers(0, 100, prompt_len).astype(np.int32),
        out_tokens=[int(t) for t in rng.integers(0, 100, out_len)])


def test_drift_monitor_sampling_rules():
    mon = DriftMonitor({}, None, {}, budget=8, ring_size=3,
                       sample_every=2, eval_every=10_000)
    reg = MetricsRegistry()
    mon.bind(metrics=reg)
    assert reg.value("lookahead_drift_overlap") == -1.0, \
        "sentinel before the first evaluation"
    for i in range(6):
        mon.on_retire(_fake_req(20, 4, seed=i))
    assert mon.samples == 3, "stride-2 sampling over 6 retirements"
    mon.on_retire(_fake_req(8, 4))  # len(x) <= budget: vacuous, skipped
    mon.on_retire(_fake_req(20, 0))  # no generated future: skipped
    assert mon.samples == 3
    assert len(mon._ring) == 3, "ring capped at ring_size"
    assert reg.value("lookahead_drift_ring") == 3
    assert reg.value("lookahead_drift_samples") == 3
    assert mon.evals == 0, "eval_every not reached"
    empty = DriftMonitor({}, None, {}, budget=8)
    assert empty.evaluate() is None, "empty ring evaluates to None"


def test_drift_gauge_matches_offline_recomputation(model):
    cfg, params, lkv = model
    budget = 8
    mon = DriftMonitor(params, cfg, lkv, budget=budget, ring_size=4)
    reg = MetricsRegistry()
    mon.bind(metrics=reg)
    rng = np.random.default_rng(7)
    records = [(rng.integers(0, cfg.vocab_size, n).astype(np.int32),
                rng.integers(0, cfg.vocab_size, 6).astype(np.int32))
               for n in (24, 33)]
    for x, y in records:
        mon.observe(x, y)
    online = mon.evaluate()
    assert reg.value("lookahead_drift_overlap") == online
    assert reg.value("lookahead_drift_evals") == 1
    # offline: raw objective calls + the shared kept-set machinery —
    # the bench_lookahead_quality computation on the same records
    ovs = []
    for x, y in records:
        xy = jnp.asarray(np.concatenate([x, y]))[None]
        gt = np.asarray(objective.gt_scores(params, cfg, xy, len(x))[:, 0],
                        np.float32)
        pred = np.asarray(
            objective.lookahead_scores(params, cfg, lkv,
                                       jnp.asarray(x)[None])[:, 0],
            np.float32)
        ovs.extend(kept_overlaps(pred, gt, budget))
    assert online == pytest.approx(float(np.mean(ovs)), abs=1e-6)
