"""End-to-end behaviour of the paper's system (integration tests):

  E1  the serving engine answers batched requests with a budgeted cache and
      reports the cache-shrink ratio;
  E2  trained LookaheadKV modules predict GT importance better than the
      untrained ones (Kendall-τ / recall@k improve — paper Table 8 metrics);
  E3  eviction quality ordering on a teacher-forced needle task:
      gt_oracle ≥ lookaheadkv(trained) > random at small budgets.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import EvictionConfig, TrainConfig
from repro.configs import get_smoke_config
from repro.core import objective, policies
from repro.core.lookahead import init_lookahead_params
from repro.data import synthetic
from repro.models import transformer as tf
from repro.optim import adam
from repro.serving.engine import Request, ServingEngine

# the lockstep engine is exercised on purpose as the paper-shaped baseline;
# silence only its expected deprecation so real warnings stay visible
pytestmark = pytest.mark.filterwarnings(
    r"ignore:ServingEngine \(lockstep\) is deprecated:DeprecationWarning")


def _recall_at_k(s_pred, s_gt, k):
    """Mean over (L,B,H) of |top-k(pred) ∩ top-k(gt)| / k."""
    _, top_p = jax.lax.top_k(s_pred, k)
    _, top_g = jax.lax.top_k(s_gt, k)
    hits = (top_p[..., :, None] == top_g[..., None, :]).any(-1).sum(-1)
    return float(jnp.mean(hits / k))


@pytest.fixture(scope="module")
def trained():
    cfg = get_smoke_config("smollm-135m")
    key = jax.random.PRNGKey(0)
    params = tf.init_params(key, cfg)
    lkv0 = init_lookahead_params(jax.random.PRNGKey(1), cfg,
                                 params["layers"])
    tc = TrainConfig(steps=60, lr=1e-3, warmup_frac=0.05)
    it = synthetic.MixtureIterator(cfg, 4, 48, 12, seed=7)

    @jax.jit
    def step(lkv, opt, x, xy):
        def loss_fn(l):
            return objective.lkv_loss(params, cfg, l, x, xy, x.shape[1])[0]

        loss, grads = jax.value_and_grad(loss_fn)(lkv)
        lkv, opt, _ = adam.update(lkv, grads, opt, tc)
        return lkv, opt, loss

    lkv, opt = lkv0, adam.init(lkv0)
    for i in range(60):
        b = next(it)
        x = jnp.asarray(b.x)
        xy = jnp.concatenate([x, jnp.asarray(b.y)], axis=1)
        lkv, opt, loss = step(lkv, opt, x, xy)
    return cfg, params, lkv0, lkv


@pytest.mark.slow
def test_trained_modules_predict_better(trained):
    """E2: recall@k of trained lookahead scores vs GT improves over init."""
    cfg, params, lkv0, lkv = trained
    it = synthetic.MixtureIterator(cfg, 4, 48, 12, seed=99)
    b = next(it)
    x = jnp.asarray(b.x)
    xy = jnp.concatenate([x, jnp.asarray(b.y)], axis=1)
    s_gt = objective.gt_scores(params, cfg, xy, x.shape[1])
    # k=6 (selective regime): the gap is widest at small k — the paper's
    # low-budget story.  60 training steps on the tiny smoke model give a
    # modest but consistent improvement.
    r0 = _recall_at_k(objective.lookahead_scores(params, cfg, lkv0, x),
                      s_gt, k=6)
    r1 = _recall_at_k(objective.lookahead_scores(params, cfg, lkv, x),
                      s_gt, k=6)
    assert r1 > r0 + 0.03, (r0, r1)


@pytest.mark.slow
def test_eviction_quality_ordering(trained):
    """E3: per-head kept-set overlap with the GT-oracle kept-set."""
    cfg, params, lkv0, lkv = trained
    it = synthetic.MixtureIterator(cfg, 4, 48, 12, seed=123)
    b = next(it)
    x = jnp.asarray(b.x)
    xy = jnp.concatenate([x, jnp.asarray(b.y)], axis=1)
    budget = 12
    ev = EvictionConfig(budget=budget)

    def kept(policy, lkv_params=None, gt=False):
        if gt:
            r = tf.prefill(params, cfg, xy, policy="gt_oracle",
                           gt_boundary=x.shape[1], evict=ev)
        else:
            r = policies.run_eviction(policy, params, cfg, x, evict=ev,
                                      lkv_params=lkv_params)
        return np.asarray(r.cache["attn"]["pos"]), np.asarray(
            r.cache["attn"]["mask"])

    gt_pos, gt_mask = kept(None, gt=True)

    def overlap(pos, mask):
        o = []
        L, B, C, KV = pos.shape
        for l in range(L):
            for bb in range(B):
                for h in range(KV):
                    a = set(pos[l, bb, mask[l, bb, :, h], h].tolist())
                    g = set(gt_pos[l, bb, gt_mask[l, bb, :, h], h].tolist())
                    o.append(len(a & g) / max(len(g), 1))
        return float(np.mean(o))

    ov_trained = overlap(*kept("lookaheadkv", lkv))
    ov_random = overlap(*kept("random"))
    assert ov_trained > ov_random + 0.05, (ov_trained, ov_random)


def test_serving_engine_end_to_end():
    """E1: batched requests through prefill→evict→decode."""
    cfg = get_smoke_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    lkv = init_lookahead_params(jax.random.PRNGKey(1), cfg, params["layers"])
    eng = ServingEngine(params, cfg, policy="lookaheadkv",
                        evict=EvictionConfig(budget=16), lkv_params=lkv,
                        max_new_tokens=8, eos_id=-1)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(
        0, cfg.vocab_size, 64).astype(np.int32), max_new_tokens=8)
        for i in range(3)]
    done = eng.serve(reqs)
    assert all(r.done and len(r.out_tokens) == 8 for r in done)
    assert all(r.ttft_s > 0 for r in done)
    cb = eng.cache_bytes(n_in=64)
    assert cb["ratio"] > 2.0  # 64 tokens -> 16+8+1 slots


def test_serving_engine_snapkv_policy():
    cfg = get_smoke_config("smollm-135m")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, policy="snapkv",
                        evict=EvictionConfig(budget=16), max_new_tokens=4,
                        eos_id=-1)
    rng = np.random.default_rng(1)
    reqs = [Request(uid=0, prompt=rng.integers(
        0, cfg.vocab_size, 48).astype(np.int32), max_new_tokens=4)]
    done = eng.serve(reqs)
    assert done[0].done and len(done[0].out_tokens) == 4
